"""Concurrent SQL serving tests (ISSUE 7).

Correctness of the ``repro.serve`` Executor/Session surface (scope,
UDFs, prepared statements), determinism of micro-batching (staged
batches with ``auto_start=False``), thread-safety regressions for the
compiled-plan cache and the interned string pool, and the serving
property: N concurrent sessions issuing randomized parameterized TPC-H
queries produce results identical to serial execution while the
admission queue actually batches and shares scans.

``REPRO_SERVE_STRESS=1`` (the CI stress lane) widens the thread pools
and iteration counts; the tests themselves never skip.
"""
import os
import random
import threading
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro import resilience, serve, sql, store
from repro.core import oracle as orc
from repro.core.config import CONFIG
from repro.core.frame import TensorFrame
from repro.serve.stats import STATS
from repro.sql import compile as plan_compile

STRESS = os.environ.get("REPRO_SERVE_STRESS") == "1"
THREADS = 8 if STRESS else 4
ROUNDS = 4 if STRESS else 2


@pytest.fixture(autouse=True)
def _fresh_serve_stats():
    STATS.reset()
    yield


@pytest.fixture()
def small_store():
    """A store-backed table with rle-friendly clustered values."""
    rng = np.random.default_rng(7)
    n = 4000
    return store.Table.from_arrays(
        {
            "g": np.repeat(rng.integers(0, 40, n // 50), 50),
            "k": np.sort(rng.integers(0, 500, n)),
            "v": rng.random(n),
        },
        chunk_rows=256,
    )


def _assert_same(out, ref):
    orc.assert_odf_equal(
        orc.frame_to_odf(out), orc.frame_to_odf(ref), sort=True, rtol=1e-8
    )


# ----------------------------------------------------------------------
# executor surface
# ----------------------------------------------------------------------
def test_executor_over_frames_and_store(small_store):
    frame = TensorFrame.from_arrays(
        {"a": np.arange(10), "b": np.arange(10) * 0.5}
    )
    with serve.Executor({"t": small_store, "f": frame}) as ex:
        out = ex.execute("SELECT g, SUM(v) AS s FROM t WHERE k < 200 GROUP BY g")
        ref = sql.execute(
            "SELECT g, SUM(v) AS s FROM t WHERE k < 200 GROUP BY g",
            {"t": small_store},
        )
        _assert_same(out, ref)
        out2 = ex.execute("SELECT a, b FROM f WHERE a >= 5")
        assert out2.nrows == 5
    assert STATS["admitted"] == 2


def test_executor_scope_update(small_store):
    with serve.Executor({"t": small_store}) as ex:
        ex.update(u={"x": np.array([1, 2, 3])})
        out = ex.execute("SELECT COUNT(*) AS c FROM u WHERE x > 1")
        assert int(np.asarray(out.column("c"))[0]) == 2


def test_executor_bad_query_raises(small_store):
    with serve.Executor({"t": small_store}) as ex:
        with pytest.raises(resilience.PlanError):
            ex.execute("SELECT nope FROM t")
        # the worker must survive a failed query
        assert ex.execute("SELECT COUNT(*) AS c FROM t").nrows == 1
    assert STATS["errors"] == 1
    assert STATS.snapshot()["errors"] == {"plan_error": 1}


def test_closed_executor_rejects(small_store):
    ex = serve.Executor({"t": small_store})
    ex.close()
    with pytest.raises(resilience.QueryCancelled):
        ex.submit("SELECT COUNT(*) AS c FROM t")


# ----------------------------------------------------------------------
# UDFs
# ----------------------------------------------------------------------
def test_udf_matches_inline_expression(small_store):
    with serve.Executor({"t": small_store}) as ex:
        ex.add_function("markup", lambda v, g: v * (1.0 + 0.01 * g))
        out = ex.execute("SELECT g, SUM(markup(v, g)) AS s FROM t GROUP BY g")
        ref = sql.execute(
            "SELECT g, SUM(v * (1.0 + 0.01 * g)) AS s FROM t GROUP BY g",
            {"t": small_store},
        )
        _assert_same(out, ref)


def test_udf_in_where(small_store):
    with serve.Executor({"t": small_store}) as ex:
        ex.add_function("hot", lambda k: k < 100, returns="bool")
        out = ex.execute("SELECT COUNT(*) AS c FROM t WHERE hot(k)")
        ref = sql.execute(
            "SELECT COUNT(*) AS c FROM t WHERE k < 100", {"t": small_store}
        )
        _assert_same(out, ref)


def test_udf_session_isolation(small_store):
    with serve.Executor({"t": small_store}) as ex:
        s1 = ex.session()
        s2 = ex.session()
        s1.add_function("boost", lambda v: v * 2.0)
        s2.add_function("boost", lambda v: v * 3.0)
        o1 = s1.execute("SELECT SUM(boost(v)) AS s FROM t")
        o2 = s2.execute("SELECT SUM(boost(v)) AS s FROM t")
        base = sql.execute("SELECT SUM(v) AS s FROM t", {"t": small_store})
        b = float(np.asarray(base.column("s"))[0])
        assert float(np.asarray(o1.column("s"))[0]) == pytest.approx(2 * b)
        assert float(np.asarray(o2.column("s"))[0]) == pytest.approx(3 * b)
        # neither session leaked into the executor scope
        with pytest.raises(resilience.PlanError):
            ex.execute("SELECT SUM(boost(v)) AS s FROM t")


def test_udf_declines_compiled_path(small_store):
    plan_compile.reset_stats()
    plan_compile.clear_cache()
    CONFIG.compiled = "force"
    try:
        with serve.Executor({"t": small_store}) as ex:
            ex.add_function("twice", lambda v: v * 2.0)
            ex.execute("SELECT g, SUM(twice(v)) AS s FROM t GROUP BY g")
        assert plan_compile.STATS["compiles"] == 0
        assert STATS["udf_queries"] == 1
    finally:
        CONFIG.compiled = "auto"


# ----------------------------------------------------------------------
# prepared statements
# ----------------------------------------------------------------------
def test_prepared_rides_plan_cache():
    rng = np.random.default_rng(3)
    n = 1 << 12
    frame = TensorFrame.from_arrays(
        {"a": rng.integers(0, 16, n), "w": rng.random(n),
         "b": rng.integers(0, 100, n)}
    )
    plan_compile.reset_stats()
    plan_compile.clear_cache()
    CONFIG.compiled = "force"
    try:
        with serve.Executor({"t": frame}) as ex:
            ps = ex.prepare(
                "SELECT a, SUM(w) AS s FROM t WHERE b > {k} GROUP BY a"
            )
            outs = [ps.execute(k=k) for k in (10, 20, 30, 40)]
        assert plan_compile.STATS["compiles"] == 1
        assert plan_compile.STATS["hits"] == 3
        assert STATS["prepared"] == 4
        assert STATS["plan_cache_hits"] == 3
        for k, out in zip((10, 20, 30, 40), outs):
            CONFIG.compiled = "off"
            ref = sql.execute(
                f"SELECT a, SUM(w) AS s FROM t WHERE b > {k} GROUP BY a",
                {"t": frame},
            )
            CONFIG.compiled = "force"
            _assert_same(out, ref)
    finally:
        CONFIG.compiled = "auto"
        CONFIG.compiled_min_rows = 1 << 15


# ----------------------------------------------------------------------
# micro-batching (deterministic: staged queue, one drain)
# ----------------------------------------------------------------------
def test_microbatch_shares_store_scans(small_store):
    ex = serve.Executor({"t": small_store}, auto_start=False)
    texts = [
        f"SELECT g, SUM(v) AS s FROM t WHERE k < {200 + i} GROUP BY g"
        for i in range(5)
    ]
    futs = [ex.submit(q) for q in texts]
    assert ex.drain_once() == 5
    snap = STATS.snapshot()
    assert snap["batches"] == 1
    assert snap["batched_queries"] == 5
    assert snap["shared_scan_groups"] == 1
    assert snap["shared_scan_queries"] == 5
    for q, f in zip(texts, futs):
        _assert_same(f.result(), sql.execute(q, {"t": small_store}))
    ex.close()


def test_microbatch_coalesces_duplicates(small_store):
    ex = serve.Executor({"t": small_store}, auto_start=False)
    q = "SELECT g, SUM(v) AS s FROM t GROUP BY g"
    futs = [ex.submit(q) for _ in range(4)]
    assert ex.drain_once() == 4
    assert STATS["coalesced"] == 3
    outs = [f.result() for f in futs]
    assert all(o is outs[0] for o in outs[1:])  # one shared result frame
    _assert_same(outs[0], sql.execute(q, {"t": small_store}))
    ex.close()


def test_microbatch_respects_cap(small_store):
    old = CONFIG.serve_max_batch
    CONFIG.serve_max_batch = 3
    try:
        ex = serve.Executor({"t": small_store}, auto_start=False)
        futs = [
            ex.submit(f"SELECT COUNT(*) AS c FROM t WHERE k < {i}")
            for i in range(5)
        ]
        assert ex.drain_once() == 3
        assert ex.drain_once() == 2
        assert all(f.result().nrows == 1 for f in futs)
        ex.close()
    finally:
        CONFIG.serve_max_batch = old


def test_shared_scans_can_be_disabled(small_store):
    old = CONFIG.serve_shared_scans
    CONFIG.serve_shared_scans = False
    try:
        ex = serve.Executor({"t": small_store}, auto_start=False)
        futs = [
            ex.submit(f"SELECT COUNT(*) AS c FROM t WHERE k < {100 + i}")
            for i in range(3)
        ]
        ex.drain_once()
        assert STATS["shared_scan_groups"] == 0
        assert all(f.result().nrows == 1 for f in futs)
        ex.close()
    finally:
        CONFIG.serve_shared_scans = old


# ----------------------------------------------------------------------
# thread-safety regressions (ISSUE 7 bugfix satellite)
# ----------------------------------------------------------------------
def test_compile_cache_thread_safe():
    """Concurrent first-compiles and hits against one shared LRU: no
    lost updates, consistent stats, correct results."""
    rng = np.random.default_rng(11)
    n = 1 << 11
    frame = TensorFrame.from_arrays(
        {"a": rng.integers(0, 8, n), "w": rng.random(n),
         "b": rng.integers(0, 50, n)}
    )
    frames = {"t": frame}
    CONFIG.compiled = "off"
    refs = {
        k: sql.execute(
            f"SELECT a, SUM(w) AS s FROM t WHERE b > {k} GROUP BY a", frames
        )
        for k in range(THREADS)
    }
    plan_compile.reset_stats()
    plan_compile.clear_cache()
    CONFIG.compiled = "force"
    try:
        def work(seed):
            r = random.Random(seed)
            for _ in range(6 * ROUNDS):
                k = r.randrange(THREADS)
                out = sql.execute(
                    f"SELECT a, SUM(w) AS s FROM t WHERE b > {k} "
                    f"GROUP BY a",
                    frames,
                )
                _assert_same(out, refs[k])

        with ThreadPoolExecutor(THREADS) as tp:
            list(tp.map(work, range(THREADS)))
        s = plan_compile.STATS
        # literals parameterize away: exactly one program, every other
        # call a hit, nothing lost to races
        assert s["compiles"] == 1
        assert s["fallbacks"] == 0
        # every call is either a hit or THE miss: no lost updates
        assert s["misses"] == 1
        assert s["hits"] + s["misses"] == THREADS * 6 * ROUNDS
    finally:
        CONFIG.compiled = "auto"
        CONFIG.compiled_min_rows = 1 << 15
        plan_compile.clear_cache()


def test_string_pool_thread_safe():
    """POOL.intern from many threads: equal dictionaries must resolve
    to one object and the pool must not corrupt its buckets."""
    dicts = [
        np.array([f"v{j}_{i}" for j in range(20)], dtype=object)
        for i in range(8)
    ]
    pool = store.StringPool(max_entries=64)
    out: dict = {}
    lock = threading.Lock()

    def work(seed):
        r = random.Random(seed)
        for _ in range(200 * ROUNDS):
            i = r.randrange(len(dicts))
            got = pool.intern(dicts[i].copy())
            with lock:
                prev = out.setdefault(i, got)
            assert prev is got  # same content -> same interned object
            assert list(got) == list(dicts[i])

    with ThreadPoolExecutor(THREADS) as tp:
        list(tp.map(work, range(THREADS)))
    assert len(out) == len(dicts)


# ----------------------------------------------------------------------
# concurrent == serial (TPC-H, randomized property)
# ----------------------------------------------------------------------
_TPCH_TEMPLATES = [
    "SELECT l_returnflag, l_linestatus, SUM(l_quantity) AS sum_qty, "
    "COUNT(*) AS cnt FROM lineitem WHERE l_quantity < {q} "
    "GROUP BY l_returnflag, l_linestatus",
    "SELECT SUM(l_extendedprice * l_discount) AS rev FROM lineitem "
    "WHERE l_shipdate >= DATE '1994-01-01' "
    "AND l_shipdate < DATE '1995-01-01' "
    "AND l_discount BETWEEN {lo} AND {hi} AND l_quantity < {q}",
    "SELECT l_shipmode, COUNT(*) AS c FROM lineitem "
    "WHERE l_quantity < {q} GROUP BY l_shipmode ORDER BY l_shipmode",
]


def _draw(rng):
    t = rng.randrange(len(_TPCH_TEMPLATES))
    lo = round(0.02 + 0.01 * rng.randrange(5), 2)
    return _TPCH_TEMPLATES[t].format(
        q=rng.randrange(10, 40), lo=lo, hi=round(lo + 0.02, 2)
    )


@pytest.fixture(scope="module")
def lineitem_store(tpch_small):
    tables, _ = tpch_small
    return store.Table.from_arrays(tables["lineitem"], chunk_rows=1024)


def test_concurrent_sessions_match_serial(lineitem_store):
    """The serving property: N sessions hammering randomized
    parameterized TPC-H queries concurrently get exactly the serial
    answers, and the admission queue demonstrably micro-batched."""
    scope = {"lineitem": lineitem_store}
    rng = random.Random(1234)
    texts = [_draw(rng) for _ in range(THREADS * 4 * ROUNDS)]
    serial = {q: sql.execute(q, scope) for q in set(texts)}

    with serve.Executor(scope) as ex:
        sessions = [ex.session() for _ in range(THREADS)]

        def work(i):
            got = []
            for q in texts[i::THREADS]:
                got.append((q, sessions[i].execute(q)))
            return got

        with ThreadPoolExecutor(THREADS) as tp:
            results = [p for chunk in tp.map(work, range(THREADS))
                       for p in chunk]

    assert len(results) == len(texts)
    for q, out in results:
        _assert_same(out, serial[q])
    snap = STATS.snapshot()
    assert snap["admitted"] == len(texts)
    assert snap["errors_total"] == 0
    # concurrency actually produced multi-query batches
    assert snap["batches"] < snap["admitted"]
    assert snap["batched_queries"] >= 2


def test_randomized_batches_match_serial_property(lineitem_store):
    """Property sweep over randomized staged batches: for any drawn
    batch of parameterized queries, batched execution (shared scans +
    coalescing on) equals one-at-a-time serial execution."""
    scope = {"lineitem": lineitem_store}
    for trial in range(6 if STRESS else 3):
        rng = random.Random(100 + trial)
        texts = [_draw(rng) for _ in range(rng.randrange(2, 9))]
        serial = {q: sql.execute(q, scope) for q in set(texts)}
        STATS.reset()
        ex = serve.Executor(scope, auto_start=False)
        futs = [ex.submit(q) for q in texts]
        assert ex.drain_once() == len(texts)
        for q, f in zip(texts, futs):
            _assert_same(f.result(), serial[q])
        snap = STATS.snapshot()
        assert snap["batches"] == 1
        if len(texts) >= 2:
            assert snap["batched_queries"] == len(texts)
            assert snap["shared_scan_queries"] + snap["coalesced"] >= 2
        ex.close()
