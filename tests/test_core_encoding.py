"""Edge cases for core/encoding.py: empty dictionaries, one-sided
merges, all-null columns — plus the same paths exercised end-to-end
through joins on empty string sides."""
import numpy as np
import pytest

from repro.core import TensorFrame, encoding


# ----------------------------------------------------------------------
# factorize
# ----------------------------------------------------------------------
def test_factorize_empty():
    codes, dictionary = encoding.factorize(np.array([], dtype=object))
    assert codes.shape == (0,)
    assert codes.dtype == np.int64
    assert dictionary.shape == (0,)


def test_factorize_single_value_column():
    codes, dictionary = encoding.factorize(np.array(["x", "x", "x"], dtype=object))
    assert list(codes) == [0, 0, 0]
    assert list(dictionary) == ["x"]


# ----------------------------------------------------------------------
# merge_dictionaries: one-sided / both-empty
# ----------------------------------------------------------------------
def test_merge_dictionaries_left_empty():
    da = np.array([], dtype="<U1")
    db = np.array(["a", "c"])
    merged, ra, rb = encoding.merge_dictionaries(da, db)
    assert list(merged) == ["a", "c"]
    assert ra.shape == (0,)
    assert list(merged[rb]) == ["a", "c"]


def test_merge_dictionaries_right_empty():
    da = np.array(["b", "d"])
    db = np.array([], dtype="<U1")
    merged, ra, rb = encoding.merge_dictionaries(da, db)
    assert list(merged) == ["b", "d"]
    assert rb.shape == (0,)
    assert list(merged[ra]) == ["b", "d"]


def test_merge_dictionaries_both_empty():
    e = np.array([], dtype="<U1")
    merged, ra, rb = encoding.merge_dictionaries(e, e)
    assert merged.shape == (0,) and ra.shape == (0,) and rb.shape == (0,)


def test_merge_dictionaries_disjoint_and_overlap():
    merged, ra, rb = encoding.merge_dictionaries(
        np.array(["a", "c"]), np.array(["b", "c"])
    )
    assert list(merged) == ["a", "b", "c"]
    assert list(merged[ra]) == ["a", "c"]
    assert list(merged[rb]) == ["b", "c"]


# ----------------------------------------------------------------------
# shared numeric codes
# ----------------------------------------------------------------------
def test_shared_codes_numeric_one_side_empty():
    ca, cb, domain = encoding.shared_codes_numeric(
        np.array([], dtype=np.int64), np.array([5, 7, 5])
    )
    assert ca.shape == (0,)
    assert domain == 2
    assert list(cb) == [0, 1, 0]


def test_cardinality_ratio_empty():
    assert encoding.cardinality_ratio(np.array([], dtype=object)) == 0.0


# ----------------------------------------------------------------------
# through the engine: empty dictionaries in joins
# ----------------------------------------------------------------------
def _frame(keys, vals):
    return TensorFrame.from_arrays(
        {"k": np.asarray(keys, dtype=object), "v": np.asarray(vals, dtype=float)}
    )


def test_join_against_empty_string_side():
    left = _frame(["a", "b", "a"], [1.0, 2.0, 3.0])
    right = TensorFrame.from_arrays(
        {"k": np.array([], dtype=object), "w": np.array([], dtype=float)}
    )
    inner = left.join(right, on="k", how="inner")
    assert inner.nrows == 0
    semi = left.join(right, on="k", how="semi")
    assert semi.nrows == 0
    anti = left.join(right, on="k", how="anti")
    assert anti.nrows == 3


def test_left_join_all_null_column_decodes_and_aggregates():
    """No matches -> every right column is null; decoding yields None,
    COUNT skips them, SUM treats them as zero contribution."""
    left = _frame(["a", "b", "c"], [1.0, 2.0, 3.0])
    right = TensorFrame.from_arrays(
        {"k": np.array(["x", "y"], dtype=object), "w": np.array([10.0, 20.0])}
    )
    out = left.join(right, on="k", how="left")
    assert out.nrows == 3
    w = out.column("w")
    assert np.isnan(w.astype(float)).all()
    agg = out.groupby("k").agg([("n", "count", "w"), ("s", "sum", "w")])
    assert list(agg.column("n")) == [0, 0, 0]
    assert list(agg.column("s")) == [0.0, 0.0, 0.0]


def test_groupby_on_empty_frame_dict_column():
    f = TensorFrame.from_arrays(
        {"k": np.array([], dtype=object), "v": np.array([], dtype=float)}
    )
    out = f.groupby("k").agg([("s", "sum", "v")])
    assert out.nrows == 0


def test_sort_empty_and_nunique_empty():
    f = TensorFrame.from_arrays(
        {"k": np.array([], dtype=object), "v": np.array([], dtype=float)}
    )
    assert f.sort_values("k").nrows == 0
    assert f.nunique("k") == 0


def test_stable_sort_tiebreak_keeps_input_order():
    f = TensorFrame.from_arrays(
        {"k": np.array([2, 1, 2, 1, 1, 2]), "v": np.arange(6)}
    )
    out = f.sort_values("k")
    assert list(out.column("v")) == [1, 3, 4, 0, 2, 5]
    # descending keys negate (not reverse): ties still keep input order
    out_d = f.sort_values("k", ascending=False)
    assert list(out_d.column("v")) == [0, 2, 5, 1, 3, 4]
    with pytest.raises(ValueError):
        f.sort_values(["k"], ascending=[True, False])
