"""Unified observability: spans, metrics registry, EXPLAIN ANALYZE,
exporters, and the serving tier's phase percentiles.

Covers the contract every layer now leans on:

- span tracing is a strict no-op when ``CONFIG.tracing="off"`` (shared
  noop instance, nothing recorded) and records nested parent/child
  spans with attributes when on;
- ``obs.metrics`` is the one registry: its own counters/gauges/
  histograms plus every legacy STATS group (join, pipeline, compile,
  serve, spill, pool) readable through ``snapshot()`` and zeroed
  through ``reset()`` while the legacy names stay aliases;
- snapshot/diff/reset algebra;
- the registry and the rings stay consistent under 8-thread hammering;
- Chrome-trace and JSON exporters emit the documented schema;
- ``execute(explain="analyze")`` annotates every operator with wall
  time, row counts and the join algorithm actually chosen.
"""
import json
import threading

import numpy as np
import pytest

from repro import obs, sql
from repro.core.config import CONFIG
from repro.core.frame import TensorFrame
from repro.obs import metrics
from repro.queries.tpch_sql import sql_text


@pytest.fixture()
def tracing_on():
    saved = CONFIG.tracing
    CONFIG.tracing = "on"
    obs.clear_trace()
    try:
        yield
    finally:
        CONFIG.tracing = saved


# ----------------------------------------------------------------------
# span tracer
# ----------------------------------------------------------------------
def test_disabled_tracing_is_noop():
    assert CONFIG.tracing == "off"
    assert not obs.enabled()
    # one shared sentinel: no allocation per call on the disabled path
    assert obs.span("a") is obs.span("b", rows=1)
    with obs.span("outer") as sp:
        sp.set(rows=5)
        assert obs.current_span_id() == 0
    assert obs.spans() == []


def test_span_nesting_and_attrs(tracing_on):
    with obs.span("outer", depth=0):
        with obs.span("inner") as sp:
            sp.set(rows=7)
            obs.annotate(tag="x")
    recs = obs.spans()
    assert [r.name for r in recs] == ["outer", "inner"]  # oldest first
    by_name = {r.name: r for r in recs}
    inner, outer = by_name["inner"], by_name["outer"]
    assert inner.parent_id == outer.span_id
    assert outer.parent_id == 0
    assert inner.attrs == {"rows": 7, "tag": "x"}
    assert outer.attrs == {"depth": 0}
    assert 0 < inner.dur_ns <= outer.dur_ns
    assert outer.start_ns <= inner.start_ns


def test_detailed_span_gated(tracing_on):
    with obs.detailed_span("chunk"):
        pass
    assert obs.spans() == []  # tracing="on" drops detailed spans
    CONFIG.tracing = "detailed"
    with obs.detailed_span("chunk"):
        pass
    assert [r.name for r in obs.spans()] == ["chunk"]


def test_spans_since_mark(tracing_on):
    with obs.span("before"):
        pass
    mark = obs.mark_ns()
    with obs.span("after"):
        pass
    assert [r.name for r in obs.spans(since_ns=mark)] == ["after"]


# ----------------------------------------------------------------------
# metrics registry
# ----------------------------------------------------------------------
def test_counter_gauge_histogram_snapshot_diff_reset():
    c = metrics.counter("t.count")
    g = metrics.gauge("t.gauge")
    h = metrics.histogram("t.hist")
    c.inc()
    c.inc(2)
    g.set(42)
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    snap = metrics.snapshot()
    assert snap["obs"]["t.count"] == 3
    assert snap["obs"]["t.gauge"] == 42
    assert snap["obs"]["t.hist"]["count"] == 4
    assert snap["obs"]["t.hist"]["min"] == 1.0
    assert snap["obs"]["t.hist"]["max"] == 4.0

    before = metrics.snapshot()
    c.inc(5)
    d = metrics.diff(before, metrics.snapshot())
    assert d["obs"]["t.count"] == 5
    assert "t.gauge" not in d.get("obs", {})  # zero deltas dropped

    metrics.reset()
    assert metrics.snapshot()["obs"]["t.count"] == 0


def test_legacy_stats_groups_registered():
    metrics.load_engine_groups()
    groups = set(metrics.groups())
    assert {
        "core.join",
        "core.pipeline",
        "sql.compile",
        "serve",
        "store.spill",
        "store.pool",
    } <= groups


def test_legacy_alias_and_registry_share_state():
    """Old STATS names keep working; the registry reads the same
    objects, and registry reset zeroes the legacy view too."""
    import importlib

    # repro.core re-exports a join *function*; reach the module itself
    join_mod = importlib.import_module("repro.core.join")

    join_mod.STATS["stats_unique_hits"] += 3
    assert metrics.snapshot()["core.join"]["stats_unique_hits"] == 3
    metrics.reset()
    assert join_mod.STATS["stats_unique_hits"] == 0

    from repro.sql import compile as plan_compile

    plan_compile.STATS["hits"] += 2
    assert metrics.snapshot()["sql.compile"]["hits"] == 2
    metrics.reset()
    assert plan_compile.STATS["hits"] == 0


def test_engine_run_populates_join_group():
    rng = np.random.default_rng(0)
    t = TensorFrame.from_arrays(
        {"k": rng.integers(0, 50, 300), "v": rng.normal(size=300)}
    )
    d = TensorFrame.from_arrays(
        {"k": np.arange(50), "w": rng.normal(size=50)}
    )
    before = metrics.snapshot()
    sql.execute(
        "SELECT t.k, SUM(d.w) AS s FROM t JOIN d ON t.k = d.k GROUP BY t.k",
        {"t": t, "d": d},
    )
    delta = metrics.diff(before, metrics.snapshot()).get("core.join", {})
    assert sum(delta.values()) > 0  # the join picker counted something


# ----------------------------------------------------------------------
# thread safety
# ----------------------------------------------------------------------
def test_registry_and_rings_race_free(tracing_on):
    """8 threads hammer one counter and emit nested spans: the counter
    total is exact, and every thread's spans nest consistently."""
    N_THREADS, N_ITER = 8, 400
    c = metrics.counter("race.count")
    errs = []
    # all threads alive at once: real contention, and no OS thread-id
    # reuse (each thread's ring must be its own)
    barrier = threading.Barrier(N_THREADS)

    def work(tid: int) -> None:
        try:
            barrier.wait()
            for i in range(N_ITER):
                c.inc()
                with obs.span("outer", tid=tid):
                    with obs.span("inner"):
                        pass
        except Exception as e:  # pragma: no cover
            errs.append(e)

    threads = [
        threading.Thread(target=work, args=(i,)) for i in range(N_THREADS)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert c.value == N_THREADS * N_ITER

    recs = obs.spans()
    # ring capacity is 64k/thread: nothing dropped at this volume
    assert obs.dropped() == 0
    assert len(recs) == N_THREADS * N_ITER * 2
    by_thread = {}
    for r in recs:
        by_thread.setdefault(r.tid, []).append(r)
    assert len(by_thread) == N_THREADS
    for tid, rs in by_thread.items():
        ids = {r.span_id: r for r in rs}
        inners = [r for r in rs if r.name == "inner"]
        assert len(inners) == N_ITER
        for r in inners:
            parent = ids[r.parent_id]  # parent recorded on SAME thread
            assert parent.name == "outer"
            assert parent.tid == r.tid


# ----------------------------------------------------------------------
# exporters
# ----------------------------------------------------------------------
def test_chrome_trace_schema(tracing_on, tmp_path):
    with obs.span("parent", rows=3):
        with obs.span("child"):
            pass
    out = tmp_path / "trace.json"
    n = obs.export_chrome_trace(str(out))
    doc = json.loads(out.read_text())
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    evs = doc["traceEvents"]
    assert n == len(evs)
    xs = [e for e in evs if e["ph"] == "X"]
    metas = [e for e in evs if e["ph"] == "M"]
    assert {e["name"] for e in xs} == {"parent", "child"}
    assert metas and metas[0]["name"] == "thread_name"
    for e in xs:
        assert {"name", "cat", "ph", "ts", "dur", "pid", "tid"} <= set(e)
    parent = next(e for e in xs if e["name"] == "parent")
    assert parent["args"] == {"rows": 3}


def test_export_json_operators(tracing_on):
    with obs.span("op.a"):
        with obs.span("op.b"):
            pass
    doc = obs.export_json()
    assert doc["schema"] == "repro-obs/v1"
    assert doc["spans_recorded"] == 2
    ops = doc["operators"]
    assert ops["op.a"]["count"] == 1
    # self time excludes the nested child
    assert ops["op.a"]["self_ms"] <= ops["op.a"]["total_ms"]
    assert "metrics" in doc


# ----------------------------------------------------------------------
# EXPLAIN ANALYZE
# ----------------------------------------------------------------------
@pytest.mark.parametrize("qname", ["q1", "q3", "q9"])
def test_explain_analyze_tpch(tpch_small, qname):
    _, frames = tpch_small
    res = sql.execute(sql_text(qname, 0.002), frames, explain="analyze")
    ref = sql.execute(sql_text(qname, 0.002), frames)
    assert res.frame.nrows == ref.nrows
    text = str(res)
    assert "EXPLAIN ANALYZE" in text
    assert "time=" in text and "rows=" in text and "bytes=" in text
    if qname in ("q3", "q9"):
        assert "algo=" in text  # join algorithm choice surfaced
    # tracing restored to off after the analyzed run
    assert CONFIG.tracing == "off"
    d = res.to_dict()
    assert d["total_ms"] > 0
    node = d["plan"]
    assert node["wall_ms"] >= 0 and node["rows_out"] == ref.nrows

    def walk(n):
        yield n
        for c in n["children"]:
            yield from walk(c)

    nodes = list(walk(node))
    assert all("wall_ms" in n for n in nodes)
    if qname in ("q3", "q9"):
        algos = {n.get("algorithm") for n in nodes if "algorithm" in n}
        assert algos <= {
            "direct_address", "sorted_probe", "membership", "sort_merge"
        }
        assert algos


def test_explain_analyze_rejects_unknown_mode():
    t = TensorFrame.from_arrays({"a": np.arange(4)})
    with pytest.raises(sql.SqlError):
        sql.execute("SELECT a FROM t", {"t": t}, explain="verbose")


# ----------------------------------------------------------------------
# serve phase percentiles
# ----------------------------------------------------------------------
def test_serve_phase_percentiles():
    from repro import serve

    t = TensorFrame.from_arrays(
        {"k": np.arange(100) % 7, "v": np.arange(100, dtype=float)}
    )
    with serve.Executor({"t": t}, auto_start=False) as ex:
        futs = [
            ex.submit("SELECT k, SUM(v) AS s FROM t GROUP BY k")
            for _ in range(3)
        ]
        assert ex.drain_once() == 3
        for f in futs:
            assert f.result().nrows == 7
    snap = metrics.snapshot()["serve"]
    phases = snap["phases"]
    assert set(phases) == {"queue", "plan", "compile", "execute"}
    for p in ("queue", "plan", "execute"):
        assert phases[p]["count"] >= 1
        assert {"p50_ms", "p95_ms", "p99_ms"} <= set(phases[p])
    assert phases["execute"]["p50_ms"] >= 0.0
    assert "p95_ms" in snap  # end-to-end reservoir gained p95
