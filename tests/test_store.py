"""repro.store: chunked columnar tables, zone maps, interned
dictionaries, the .tfb v2 format, and the dist chunk-input path.

The property tests (hypothesis) check the subsystem's core contract:
a chunked, encoded, zone-map-pruned scan returns exactly what a
whole-array numpy filter returns, for random data, chunk sizes, and
predicates.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import store
from repro.core import TensorFrame, encoding
from repro.core import io as tio


# ----------------------------------------------------------------------
# import hygiene: the storage layer must never pull in jax
# ----------------------------------------------------------------------
def test_store_imports_without_jax():
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    code = (
        "import repro.store, sys; "
        "assert 'jax' not in sys.modules, sorted(m for m in sys.modules if m.startswith('jax'))"
    )
    env = dict(os.environ, PYTHONPATH=os.path.abspath(src))
    subprocess.run([sys.executable, "-c", code], check=True, env=env)


# ----------------------------------------------------------------------
# encoding policy + stats
# ----------------------------------------------------------------------
def test_policy_picks_dict_rle_plain():
    n = 4000
    rng = np.random.default_rng(0)
    t = store.Table.from_arrays(
        {
            "low_card": np.array(["a", "b", "c"], dtype=object)[
                rng.integers(0, 3, n)
            ],
            "high_card": np.array([f"id{i}" for i in range(n)], dtype=object),
            "clustered": np.sort(rng.integers(0, 40, n)),
            "scattered": rng.integers(0, 1 << 40, n),
            "measure": rng.uniform(0, 1, n),
        },
        chunk_rows=512,
    )
    assert t.columns["low_card"].encoding == "dict"
    assert t.columns["high_card"].encoding == "plain"
    assert t.columns["clustered"].encoding == "rle"
    assert t.columns["scattered"].encoding == "plain"
    assert t.columns["measure"].encoding == "plain"
    assert t.n_chunks == (n + 511) // 512


def test_forced_encoding_overrides_policy():
    arr = {"s": np.array([f"u{i}" for i in range(100)], dtype=object)}
    t = store.Table.from_arrays(arr, encode={"s": "dict"})
    assert t.columns["s"].encoding == "dict"
    with pytest.raises(ValueError):
        store.Table.from_arrays({"x": np.arange(5.0)}, encode={"x": "rle"})


def test_chunk_stats_zone_maps_and_nulls():
    vals = np.array([3.0, np.nan, 7.0, np.nan, 5.0])
    s = store.compute_stats(vals, "float")
    assert s.vmin == 3.0 and s.vmax == 7.0
    assert s.null_count == 2 and s.distinct == 3
    all_null = store.compute_stats(np.array([np.nan, np.nan]), "float")
    assert all_null.vmin is None and all_null.null_count == 2
    # all-null chunks are skippable under every predicate except <>,
    # where NaN cells match (IEEE, like the engine's filter lowering)
    assert not store.chunk_may_match(all_null, ("=", 1.0))
    assert not store.chunk_may_match(all_null, ("<", 1.0))
    assert store.chunk_may_match(all_null, ("<>", 1.0))


def test_dict_zone_maps_are_code_ranges():
    t = store.Table.from_arrays(
        {"s": np.array(list("aabbccdd"), dtype=object)}, chunk_rows=4
    )
    col = t.columns["s"]
    assert col.encoding == "dict"
    # sorted dictionary => chunk 0 holds codes {0,1}, chunk 1 {2,3}
    assert (col.chunks[0].stats.vmin, col.chunks[0].stats.vmax) == (0, 1)
    assert (col.chunks[1].stats.vmin, col.chunks[1].stats.vmax) == (2, 3)
    r = store.scan(t, ["s"], [store.Pred("s", "=", "a")])
    assert r.chunks_skipped == 1 and r.nrows == 2


# ----------------------------------------------------------------------
# zone-map effectiveness on clustered data (the bench acceptance,
# asserted on deterministic skip counts rather than wall time)
# ----------------------------------------------------------------------
def test_clustered_scan_skips_chunks_at_low_selectivity():
    rng = np.random.default_rng(7)
    n = 40_000
    dates = np.sort(
        np.datetime64("1994-01-01", "D")
        + rng.integers(0, 2000, n).astype("timedelta64[D]")
    )
    t = store.Table.from_arrays(
        {"d": dates, "v": rng.uniform(0, 1, n)}, chunk_rows=1024
    )
    cut = dates[int(0.99 * (n - 1))]  # ~1% selectivity
    r = store.scan(t, ["v"], [store.Pred("d", ">=", cut)])
    assert r.nrows == int((dates >= cut).sum())
    assert r.chunks_skipped >= 0.9 * r.chunks_total
    assert r.rows_scanned <= 0.1 * n


# ----------------------------------------------------------------------
# interned dictionaries
# ----------------------------------------------------------------------
def test_intern_returns_same_object_for_equal_content():
    a = store.intern_dictionary(np.array(["a", "b"], dtype=object))
    b = store.intern_dictionary(np.array(["a", "b"], dtype=object))
    c = store.intern_dictionary(np.array(["a", "c"], dtype=object))
    assert a is b and a is not c
    with pytest.raises(ValueError):
        a[0] = "z"  # interned arrays are read-only


def test_merge_dictionaries_identity_fast_path():
    d = store.intern_dictionary(np.array(["a", "b", "c"], dtype=object))
    merged, ra, rb = encoding.merge_dictionaries(d, d)
    assert merged is d
    np.testing.assert_array_equal(ra, [0, 1, 2])
    np.testing.assert_array_equal(rb, [0, 1, 2])


def test_frames_from_same_store_share_dictionaries():
    data = {"k": np.array(list("xyzxyz"), dtype=object), "v": np.arange(6.0)}
    t = store.Table.from_arrays(data, chunk_rows=2)
    fa = TensorFrame.from_store(t, ["k", "v"])
    fb = TensorFrame.from_store(t, ["k"])
    assert fa.meta("k").dictionary is fb.meta("k").dictionary
    out = fa.join(fb, on="k", how="semi")
    assert out.nrows == 6


# ----------------------------------------------------------------------
# TensorFrame.from_store
# ----------------------------------------------------------------------
def test_from_store_matches_from_arrays():
    rng = np.random.default_rng(3)
    n = 700
    data = {
        "i": rng.integers(-5, 5, n),
        "f": rng.uniform(-1, 1, n),
        "b": rng.integers(0, 2, n).astype(bool),
        "d": np.datetime64("1996-01-01", "D")
        + rng.integers(0, 90, n).astype("timedelta64[D]"),
        "s": np.array(["lo", "mid", "hi"], dtype=object)[rng.integers(0, 3, n)],
        "hc": np.array([f"row{i}" for i in range(n)], dtype=object),
    }
    t = store.Table.from_arrays(data, chunk_rows=97)
    got = TensorFrame.from_store(t)
    ref = TensorFrame.from_arrays(data)
    assert got.column_names == ref.column_names
    for name in ref.column_names:
        a, b = got.column(name), ref.column(name)
        assert got.meta(name).kind == ref.meta(name).kind
        if a.dtype.kind == "f":
            np.testing.assert_allclose(a, b)
        else:
            np.testing.assert_array_equal(a, b)


def test_from_store_pushdown_equals_filter():
    rng = np.random.default_rng(4)
    n = 1500
    data = {"k": rng.integers(0, 100, n), "v": rng.uniform(0, 1, n)}
    t = store.Table.from_arrays(data, chunk_rows=128)
    got = TensorFrame.from_store(t, ["v"], [store.Pred("k", "<", 10)])
    ref = data["v"][data["k"] < 10]
    np.testing.assert_allclose(np.sort(got.column("v")), np.sort(ref))
    empty = TensorFrame.from_store(t, ["v"], [store.Pred("k", "=", 10_000)])
    assert empty.nrows == 0 and empty.column("v").shape == (0,)


# ----------------------------------------------------------------------
# .tfb v2 round trips + v1 compat
# ----------------------------------------------------------------------
def _mixed_table(n=300, seed=5):
    rng = np.random.default_rng(seed)
    return {
        "i": rng.integers(0, 1000, n),
        "f": np.round(rng.uniform(-10, 10, n), 3),
        "d": np.datetime64("1993-05-01", "D")
        + np.sort(rng.integers(0, 400, n)).astype("timedelta64[D]"),
        "s": np.array(["AA", "BB", "CC"], dtype=object)[rng.integers(0, 3, n)],
        "hc": np.array([f"key-{i:05d}" for i in range(n)], dtype=object),
    }


def test_v2_round_trip_lazy(tmp_path):
    data = _mixed_table()
    path = str(tmp_path / "t")
    written = store.write_arrays(path, data, chunk_rows=64)
    assert written.nrows == 300
    t = store.open_store(path)
    assert not any(
        c.loaded for col in t.columns.values() for c in col.chunks
    )
    out = t.to_arrays()
    for name, want in data.items():
        if want.dtype == object:
            assert list(out[name]) == list(want)
        else:
            np.testing.assert_array_equal(out[name], want)
    # persisted stats survive the round trip (zone maps in manifest)
    fresh = store.Table.from_arrays(data, chunk_rows=64)
    for name in data:
        got = [ (c.stats.vmin, c.stats.vmax, c.stats.null_count, c.stats.distinct)
                for c in t.columns[name].chunks ]
        want_stats = [ (c.stats.vmin, c.stats.vmax, c.stats.null_count, c.stats.distinct)
                for c in fresh.columns[name].chunks ]
        assert got == want_stats, name


def test_v1_and_v2_read_compat_through_io(tmp_path):
    """Both format versions read back identically through core.io."""
    data = _mixed_table()
    p1, p2 = str(tmp_path / "v1"), str(tmp_path / "v2")
    tio.write_tfb(p1, data, version=1)
    tio.write_tfb(p2, data, version=2, chunk_rows=50)
    a1 = tio.read_tfb_arrays(p1)
    a2 = tio.read_tfb_arrays(p2)
    assert set(a1) == set(a2) == set(data)
    for name in data:
        if data[name].dtype == object:
            assert list(a1[name]) == list(a2[name])
        else:
            np.testing.assert_array_equal(a1[name], a2[name])
    # frame-level: v2 read supports predicate pushdown, v1 rejects it
    cut = np.datetime64("1994-01-01", "D")
    f2 = tio.read_tfb(p2, ["f", "d"], [store.Pred("d", "<", cut)])
    assert f2.nrows == int((data["d"] < cut).sum())
    with pytest.raises(ValueError):
        tio.read_tfb(p1, ["f"], [store.Pred("d", "<", cut)])


def test_v2_projection_only_touches_requested_columns(tmp_path):
    data = _mixed_table()
    path = str(tmp_path / "t")
    store.write_arrays(path, data, chunk_rows=64)
    t = store.open_store(path)
    t.to_arrays(["i"])
    assert all(c.loaded for c in t.columns["i"].chunks)
    assert not any(c.loaded for c in t.columns["f"].chunks)
    assert t.columns["s"]._dictionary is None  # dictionary stays lazy


# ----------------------------------------------------------------------
# chunked inputs are the dist shard unit
# ----------------------------------------------------------------------
def test_dist_repartition_accepts_chunked_inputs():
    jax = pytest.importorskip("jax")
    jax.config.update("jax_enable_x64", True)
    from repro.dist import dframe

    rng = np.random.default_rng(6)
    n = 600
    keys = rng.integers(0, 37, n)
    vals = rng.normal(size=n)
    t = store.Table.from_arrays({"k": keys, "v": vals}, chunk_rows=100)
    mesh = dframe.data_mesh(1)
    key_chunks = [t.columns["k"].chunk_physical(i) for i in range(t.n_chunks)]
    val_chunks = [t.columns["v"].chunk_physical(i) for i in range(t.n_chunks)]
    k2, v2, valid, dropped = dframe.dist_repartition_by_key(
        mesh, key_chunks, val_chunks, capacity=n
    )
    km, vm, validm, droppedm = dframe.dist_repartition_by_key(
        mesh, np.concatenate(key_chunks), np.concatenate(val_chunks), capacity=n
    )
    assert int(dropped) == int(droppedm) == 0
    np.testing.assert_array_equal(np.asarray(k2), np.asarray(km))
    np.testing.assert_allclose(np.asarray(v2), np.asarray(vm))
    np.testing.assert_array_equal(np.asarray(valid), np.asarray(validm))


# ----------------------------------------------------------------------
# regression: predicate constants outside the column's domain
# ----------------------------------------------------------------------
def test_float_constants_against_int_columns_not_truncated():
    """`k < 2.5` means `k <= 2`, never `k < int(2.5) == 2`."""
    arr = np.arange(10)
    t = store.Table.from_arrays({"k": arr}, chunk_rows=3)

    def got(op, v):
        return list(store.scan(t, ["k"], [store.Pred("k", op, v)]).columns["k"].values)

    assert got("<", 2.5) == [0, 1, 2]
    assert got("<=", 2.5) == [0, 1, 2]
    assert got(">", 2.5) == [3, 4, 5, 6, 7, 8, 9]
    assert got(">=", 2.5) == [3, 4, 5, 6, 7, 8, 9]
    assert got("=", 2.5) == []
    assert got("<>", 2.5) == list(arr)
    assert got("between", (1.5, 3.5)) == [2, 3]
    assert got("in", (2.5, 3)) == [3]


def test_none_object_cells_stringify_like_v1(tmp_path):
    """write_tfb v2 must accept None object cells (the engine's null
    for offloaded columns) exactly like the v1 writer: stringified."""
    data = {"s": np.array(["a", None, "b"], dtype=object)}
    p1, p2 = str(tmp_path / "v1"), str(tmp_path / "v2")
    tio.write_tfb(p1, data, version=1)
    tio.write_tfb(p2, data, version=2)
    assert list(tio.read_tfb_arrays(p1)["s"]) == ["a", "None", "b"]
    assert list(tio.read_tfb_arrays(p2)["s"]) == ["a", "None", "b"]


def test_oracle_backend_applies_pushed_scan_predicates():
    """A store-optimized plan interpreted on the oracle must not drop
    the conjuncts that moved into the Scan."""
    from repro import sql
    from repro.sql.oracle_backend import execute_oracle
    from repro.sql.plan import format_plan

    data = {"k": np.arange(20), "v": np.arange(20) * 1.0}
    t = store.Table.from_arrays(data, chunk_rows=4)
    plan = sql.plan_query("SELECT v FROM t WHERE k >= 15", {"t": t})
    assert "pushed=" in format_plan(plan)  # the Filter left the plan
    ora = execute_oracle(plan, {"t": data})
    assert sorted(ora["v"]) == [15.0, 16.0, 17.0, 18.0, 19.0]


def test_unpruned_scan_uses_bulk_load(tmp_path):
    """A predicate that skips nothing must still bulk-load columns
    sequentially (one read per file), not per-chunk."""
    data = {"k": np.arange(1000), "v": np.arange(1000) * 0.5}
    path = str(tmp_path / "t")
    store.write_arrays(path, data, chunk_rows=64)
    t = store.open_store(path)
    r = store.scan(t, ["v"], [store.Pred("k", ">=", 0)])  # keeps all
    assert r.chunks_skipped == 0 and r.nrows == 1000
    assert all(c.loaded for c in t.columns["v"].chunks)
    np.testing.assert_allclose(r.columns["v"].values, data["v"])


def test_huge_int_constants_stay_exact():
    """Constants >= 2**53 must not round-trip through float64."""
    base = 1 << 53
    arr = np.array([base, base + 1, base + 2], dtype=np.int64)
    t = store.Table.from_arrays({"k": arr}, chunk_rows=2)
    r = store.scan(t, ["k"], [store.Pred("k", "=", base + 1)])
    assert list(r.columns["k"].values) == [base + 1]
    r = store.scan(t, ["k"], [store.Pred("k", "in", (base + 2,))])
    assert list(r.columns["k"].values) == [base + 2]
    r = store.scan(t, ["k"], [store.Pred("k", "between", (base + 1, base + 1))])
    assert list(r.columns["k"].values) == [base + 1]


def test_pushed_neq_matches_engine_semantics_on_nan():
    """`<>` over NaN floats: optimize=True (pushed into the scan) and
    optimize=False (explicit engine Filter) must agree row for row."""
    from repro import sql

    scope = {
        "t": store.Table.from_arrays(
            {"id": np.arange(4), "f": np.array([1.0, 2.0, np.nan, 3.0])},
            chunk_rows=2,
        )
    }
    q = "SELECT id FROM t WHERE f <> 1.0 ORDER BY id"
    a = list(sql.execute(q, scope).column("id"))
    b = list(sql.execute(q, scope, optimize=False).column("id"))
    assert a == b == [1, 2, 3]


def test_neq_keeps_chunks_with_nulls_among_uniform_values():
    """A chunk whose non-null values all equal v still has NaN rows
    that match `<>` — pruning must not skip it."""
    arr = np.array([5.0, 5.0, np.nan, 5.0, 1.0, 2.0])
    t = store.Table.from_arrays({"x": arr}, chunk_rows=4)
    got = store.scan(t, ["x"], [store.Pred("x", "<>", 5.0)]).columns["x"].values
    with np.errstate(invalid="ignore"):
        ref = arr[arr != 5.0]
    np.testing.assert_array_equal(got, ref)
    # and the uniform no-null chunk is still skippable
    t2 = store.Table.from_arrays({"x": np.array([5.0] * 4 + [1.0] * 4)}, chunk_rows=4)
    r = store.scan(t2, ["x"], [store.Pred("x", "<>", 5.0)])
    assert r.chunks_skipped == 1 and list(r.columns["x"].values) == [1.0] * 4
