"""Property-based tests: TensorFrame vs the independent oracle engine.

Requires the optional ``hypothesis`` dev dependency (see
requirements-dev.txt); skips cleanly when it is absent so the tier-1
``-x`` run never dies at collection.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import TensorFrame, col
from repro.core import oracle as orc

SETTINGS = dict(
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)


@st.composite
def tables(draw, min_rows=0, max_rows=60):
    n = draw(st.integers(min_rows, max_rows))
    k_card = draw(st.integers(1, 8))
    s_card = draw(st.integers(1, 6))
    rng = np.random.default_rng(draw(st.integers(0, 2**31)))
    return {
        "k": rng.integers(-3, k_card, n),
        "j": rng.integers(0, 4, n),
        "s": rng.choice([f"s{i}" for i in range(s_card)], n).astype(object) if n else np.array([], dtype=object),
        "v": np.round(rng.normal(size=n), 3),
    }


@given(tables(), st.integers(-3, 8))
@settings(**SETTINGS)
def test_filter_matches_oracle(data, thresh):
    f = TensorFrame.from_arrays(data)
    got = f.filter(col("k") > thresh)
    mask = data["k"] > thresh
    expect = orc.o_filter(orc.from_numpy(data), list(mask))
    orc.assert_odf_equal(orc.frame_to_odf(got), expect)


@given(tables(min_rows=1))
@settings(**SETTINGS)
def test_groupby_matches_oracle(data):
    f = TensorFrame.from_arrays(data)
    specs = [
        ("n", "size", ""),
        ("sv", "sum", "v"),
        ("mn", "min", "k"),
        ("mx", "max", "k"),
        ("nu", "nunique", "j"),
    ]
    got = f.groupby(["s", "j"]).agg(specs)
    expect = orc.o_groupby(orc.from_numpy(data), ["s", "j"], specs)
    orc.assert_odf_equal(orc.frame_to_odf(got), expect, rtol=1e-6)


@given(tables(min_rows=1))
@settings(**SETTINGS)
def test_groupby_partition_invariants(data):
    """Groups partition the rows: sizes sum to n; sums are preserved."""
    f = TensorFrame.from_arrays(data)
    g = f.groupby(["k"]).agg([("n", "size", ""), ("sv", "sum", "v")])
    assert int(np.sum(g.column("n"))) == f.nrows
    np.testing.assert_allclose(
        float(np.sum(g.column("sv"))), float(np.sum(data["v"])), rtol=1e-9
    )
    # distinct keys count matches numpy
    assert g.nrows == len(np.unique(data["k"]))


@given(tables(max_rows=40), tables(max_rows=40))
@settings(**SETTINGS)
def test_join_matches_oracle(left, right):
    fl, fr = TensorFrame.from_arrays(left), TensorFrame.from_arrays(right)
    for how in ("inner", "semi", "anti", "left"):
        got = fl.join(fr, on=["k", "s"], how=how)
        expect = orc.o_join(
            orc.from_numpy(left), orc.from_numpy(right), ["k", "s"], ["k", "s"], how=how
        )
        orc.assert_odf_equal(orc.frame_to_odf(got), expect, rtol=1e-6)


@given(tables(max_rows=40), tables(max_rows=40))
@settings(**SETTINGS)
def test_join_algorithms_agree(left, right):
    """direct-address, sorted-probe and sort-merge produce identical bags."""
    fl, fr = TensorFrame.from_arrays(left), TensorFrame.from_arrays(right)
    outs = [
        orc.frame_to_odf(fl.join(fr, on="j", algorithm=a))
        for a in ("auto", "sorted", "sortmerge")
    ]
    orc.assert_odf_equal(outs[0], outs[1], rtol=1e-6)
    orc.assert_odf_equal(outs[0], outs[2], rtol=1e-6)


@given(tables(min_rows=2))
@settings(**SETTINGS)
def test_sort_is_stable_permutation(data):
    f = TensorFrame.from_arrays(data)
    got = f.sort_values(["j", "k"], ascending=[True, False])
    # same multiset of rows
    orc.assert_odf_equal(orc.frame_to_odf(got), orc.frame_to_odf(f), sort=True)
    j = got.column("j")
    assert all(j[i] <= j[i + 1] for i in range(len(j) - 1))
    k = got.column("k")
    for i in range(len(j) - 1):
        if j[i] == j[i + 1]:
            assert k[i] >= k[i + 1]


@given(tables(min_rows=1))
@settings(**SETTINGS)
def test_composite_key_exactness(data):
    """Packed composite keys are collision-free: group count equals the
    true distinct tuple count."""
    f = TensorFrame.from_arrays(data)
    gb = f.groupby(["k", "j", "s"])
    tuples = set(zip(data["k"], data["j"], data["s"]))
    assert gb.ngroups == len(tuples)
    assert gb.exact
