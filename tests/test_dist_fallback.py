"""Single-device fallback of every ``repro.dist`` op, plus the CONFIG
routing that sends TensorFrame group-by sums and semi/anti joins through
the sharded path.

These run in-process on the default (1-device CPU) backend — the same
shard_map programs the multi-device tests (tests/test_distributed.py)
run under 8 forced host devices, here on a 1-device mesh.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import TensorFrame
from repro.core.config import CONFIG
from repro.dist import compression, dframe, pipeline


@pytest.fixture
def mesh1():
    return dframe.data_mesh(1)


def test_dist_groupby_sum_single_device(mesh1):
    rng = np.random.default_rng(0)
    n, domain = 1000, 13
    keys = jnp.asarray(rng.integers(0, domain, n).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    got = dframe.dist_groupby_sum(mesh1, keys, vals, domain)
    want = np.zeros(domain, np.float32)
    np.add.at(want, np.asarray(keys), np.asarray(vals))
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


def test_dist_groupby_sum_null_keys_and_pallas_reuse(mesh1):
    keys = jnp.asarray(np.array([0, -1, 1, 0, -1], dtype=np.int64))
    vals = jnp.asarray(np.array([1.0, 99.0, 2.0, 3.0, 99.0], dtype=np.float32))
    got = dframe.dist_groupby_sum(mesh1, keys, vals, 2)
    np.testing.assert_allclose(np.asarray(got), [4.0, 2.0])
    # shard-local reducer through the sorted-segment Pallas kernel
    got_p = dframe.local_dense_sum(keys, vals, 2, use_pallas=True)
    np.testing.assert_allclose(np.asarray(got_p), [4.0, 2.0], rtol=1e-6)


def test_dist_semi_join_mask_single_device(mesh1):
    rng = np.random.default_rng(1)
    probe = jnp.asarray(rng.integers(0, 50, 777).astype(np.int64))
    build = jnp.asarray(np.array([3, 7, 11, 42], dtype=np.int64))
    mask = dframe.dist_semi_join_mask(mesh1, probe, build)
    np.testing.assert_array_equal(
        np.asarray(mask), np.isin(np.asarray(probe), np.asarray(build))
    )


def test_dist_repartition_single_device_lossless(mesh1):
    rng = np.random.default_rng(2)
    n, domain = 500, 17
    keys = jnp.asarray(rng.integers(0, domain, n).astype(np.int64))
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    k2, v2, valid, dropped = dframe.dist_repartition_by_key(mesh1, keys, vals, capacity=n)
    assert int(dropped) == 0
    kept = np.asarray(k2)[np.asarray(valid)]
    assert kept.shape[0] == n
    want = np.zeros(domain, np.float32)
    np.add.at(want, np.asarray(keys), np.asarray(vals))
    got = np.zeros(domain, np.float32)
    np.add.at(got, kept, np.asarray(v2)[np.asarray(valid)])
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_dist_repartition_overflow_accounting(mesh1):
    """capacity below n: the excess is counted in dropped, survivors stay
    consistent."""
    n, cap = 100, 40
    keys = jnp.asarray(np.zeros(n, dtype=np.int64))
    vals = jnp.asarray(np.ones(n, dtype=np.float32))
    k2, v2, valid, dropped = dframe.dist_repartition_by_key(mesh1, keys, vals, capacity=cap)
    assert int(dropped) == n - cap
    assert int(np.asarray(valid).sum()) == cap


def test_quantize_roundtrip_and_error_feedback():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=700).astype(np.float32))
    q, s, r = compression.quantize(x)
    deq = compression.dequantize(q, s, 700)
    # error bound: half a quantization step per block
    step = np.asarray(s).max()
    assert float(jnp.abs(x - deq).max()) <= 0.51 * step
    np.testing.assert_allclose(np.asarray(x - deq), np.asarray(r), atol=1e-6)
    # feeding the residual back recovers the lost mass
    q2, s2, r2 = compression.quantize(jnp.zeros_like(x), resid=r)
    deq2 = compression.dequantize(q2, s2, 700)
    np.testing.assert_allclose(
        np.asarray(deq + deq2), np.asarray(x), atol=2e-2
    )
    # all-zero input: scale falls back to 1, residual exactly zero
    qz, sz, rz = compression.quantize(jnp.zeros(256, jnp.float32))
    assert np.all(np.asarray(qz) == 0) and np.all(np.asarray(sz) == 1.0)
    assert np.all(np.asarray(rz) == 0)


def test_compressed_mean_single_device():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",))
    rng = np.random.default_rng(4)
    g = jnp.asarray(rng.normal(size=(1, 512)).astype(np.float32))

    def f(gl):
        mean, resid = compression.compressed_mean(gl[0], "data")
        return mean[None], resid[None]

    fn = shard_map(
        f, mesh=mesh, in_specs=(P("data"),), out_specs=(P("data"), P("data")),
        check_rep=False,
    )
    mean, resid = fn(g)
    # 1-device mean == dequantized self; adding the residual restores x
    np.testing.assert_allclose(
        np.asarray(mean[0] + resid[0]), np.asarray(g[0]), atol=1e-6
    )


def test_pipeline_single_stage_matches_sequential():
    mesh = jax.make_mesh((1,), ("pipe",))
    rng = np.random.default_rng(5)
    L, D = 3, 8
    W = jnp.asarray(rng.normal(size=(L, D, D)).astype(np.float32) * 0.3)
    x = jnp.asarray(rng.normal(size=(4, 2, D)).astype(np.float32))

    def block(w, h):
        return jnp.tanh(h @ w)

    def seq(h):
        for l in range(L):
            h = block(W[l], h)
        return h

    got = pipeline.pipeline_forward(mesh, block, W, x, n_layers=L)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(jax.vmap(seq)(x)), rtol=1e-5, atol=1e-5
    )


# ----------------------------------------------------------------------
# CONFIG routing: the engine takes the sharded route end-to-end
# ----------------------------------------------------------------------
@pytest.fixture
def routed():
    prev = CONFIG.distributed
    CONFIG.distributed = "force"
    try:
        yield
    finally:
        CONFIG.distributed = prev


def _table(n=400, seed=6):
    rng = np.random.default_rng(seed)
    return TensorFrame.from_arrays(
        {
            "k": rng.integers(0, 11, n),
            "g": np.asarray(list("abc"))[rng.integers(0, 3, n)].astype(object),
            "v": rng.normal(size=n),
        }
    )


def test_routed_groupby_matches_local(routed):
    f = _table()
    got = f.groupby(["g", "k"]).agg([("s", "sum", "v"), ("m", "mean", "v"),
                                     ("n", "size", "")])
    CONFIG.distributed = "off"
    want = f.groupby(["g", "k"]).agg([("s", "sum", "v"), ("m", "mean", "v"),
                                      ("n", "size", "")])
    np.testing.assert_allclose(got.column("s"), want.column("s"), rtol=1e-12)
    np.testing.assert_allclose(got.column("m"), want.column("m"), rtol=1e-12)
    np.testing.assert_array_equal(got.column("n"), want.column("n"))


def test_routed_semi_and_anti_join_match_local(routed):
    f = _table(seed=7)
    right = TensorFrame.from_arrays({"k": np.array([1, 2, 3, 5, 8])})
    got_semi = f.join(right, on="k", how="semi")
    got_anti = f.join(right, on="k", how="anti")
    CONFIG.distributed = "off"
    want_semi = f.join(right, on="k", how="semi")
    want_anti = f.join(right, on="k", how="anti")
    np.testing.assert_array_equal(got_semi.column("v"), want_semi.column("v"))
    np.testing.assert_array_equal(got_anti.column("v"), want_anti.column("v"))


def test_auto_route_stays_local_on_one_device():
    assert CONFIG.distributed == "auto"
    # tier-1 runs on a single CPU device: auto must not shard
    assert not dframe.dist_enabled(1 << 30) or jax.device_count() > 1
