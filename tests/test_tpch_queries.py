"""Integration: all 22 TPC-H queries, TensorFrame vs the independent
row-at-a-time reference, on generated data."""
import numpy as np
import pytest

from repro.core import oracle as orc
from repro.queries import tpch_frames, tpch_numpy

SF = 0.002  # must match the shared tpch_small fixture (conftest.py)


@pytest.fixture(scope="module")
def data(tpch_small):
    return tpch_small


def rows_to_odf(rows):
    if not rows:
        return {}
    return {k: [r[k] for r in rows] for k in rows[0]}


# Fast default subset: covers left (q13) / semi (q4) / anti (q22)
# joins, grouped (q1) + scalar (q6) aggregation, and the string-UDF
# filters (q13/q22).  Inner-join coverage in the default lane comes
# from tests/test_sql.py, which runs six inner-join TPC-H queries
# three-way.  The remaining queries run via `-m "slow or not slow"`.
FAST_QUERIES = {"q1", "q4", "q6", "q13", "q22"}


def _params():
    return [
        q if q in FAST_QUERIES else pytest.param(q, marks=pytest.mark.slow)
        for q in sorted(tpch_frames.ALL, key=lambda s: int(s[1:]))
    ]


@pytest.mark.parametrize("qname", _params())
def test_query_matches_reference(data, qname):
    tables, frames = data
    got = tpch_frames.ALL[qname](frames, sf=SF, apply_limit=False)
    expect = tpch_numpy.ALL[qname](tables, sf=SF)
    if qname in tpch_frames.SCALAR_QUERIES:
        assert set(got.keys()) == set(expect.keys())
        for k in got:
            assert got[k] == pytest.approx(expect[k], rel=1e-9), (k, got, expect)
        return
    godf = orc.frame_to_odf(got)
    eodf = rows_to_odf(expect)
    if not eodf:
        assert all(len(v) == 0 for v in godf.values()), f"{qname}: expected empty"
        return
    orc.assert_odf_equal(godf, eodf, sort=True, rtol=1e-8)


def test_q19_branches_synthetic():
    """Q19's OR-of-conjunctions on hand-crafted rows hitting each branch."""
    import repro.queries.tpch_frames as QF
    from repro.core import TensorFrame

    part = {
        "p_partkey": np.array([1, 2, 3, 4]),
        "p_brand": np.array(["Brand#12", "Brand#23", "Brand#34", "Brand#11"], dtype=object),
        "p_size": np.array([3, 5, 10, 3]),
        "p_container": np.array(["SM CASE", "MED BOX", "LG PACK", "SM CASE"], dtype=object),
    }
    lineitem = {
        "l_partkey": np.array([1, 2, 3, 4, 1]),
        "l_quantity": np.array([5.0, 15.0, 25.0, 5.0, 50.0]),
        "l_extendedprice": np.array([100.0, 200.0, 400.0, 800.0, 1600.0]),
        "l_discount": np.array([0.0, 0.5, 0.25, 0.0, 0.0]),
        "l_shipmode": np.array(["AIR", "AIR REG", "AIR", "AIR", "AIR"], dtype=object),
        "l_shipinstruct": np.array(["DELIVER IN PERSON"] * 4 + ["NONE"], dtype=object),
    }
    t = {
        "part": TensorFrame.from_arrays(part),
        "lineitem": TensorFrame.from_arrays(lineitem),
    }
    got = QF.q19(t)
    # rows 1,2,3 match branches 1,2,3; row 4 wrong brand; row 5 wrong instruct
    assert got["revenue"] == pytest.approx(100.0 + 100.0 + 300.0)
