"""Training runtime tests: optimizer steps, grad accumulation,
checkpoint save/restore/resume, fault-tolerant loop, straggler skip,
serving engine."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get
from repro.models import lm
from repro.models.config import reduced
from repro.train import checkpoint
from repro.train.loop import PrefetchIterator, TrainLoop
from repro.train.train_step import init_train_state, make_train_step


def tiny_cfg(**kw):
    kw.setdefault("microbatches", 2)
    return reduced(get("phi3-mini-3.8b"), n_layers=2, **kw)


def batches(cfg, n, B=4, S=16, seed=0):
    rng = np.random.default_rng(seed)
    for _ in range(n):
        toks = rng.integers(0, cfg.vocab, (B, S + 1))
        yield {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
        }


def test_train_step_reduces_loss():
    cfg = tiny_cfg()
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg))
    batch = next(batches(cfg, 1))
    losses = []
    for _ in range(30):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[:3] + losses[-3:]
    assert int(state["step"]) == 30


def test_adafactor_reduces_loss():
    cfg = tiny_cfg(optimizer="adafactor")
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg))
    batch = next(batches(cfg, 1))
    losses = []
    for _ in range(40):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.3


def test_grad_accumulation_matches_full_batch():
    cfg1 = tiny_cfg(microbatches=1)
    cfg4 = tiny_cfg(microbatches=4)
    s1 = init_train_state(cfg1, jax.random.PRNGKey(1))
    s4 = init_train_state(cfg4, jax.random.PRNGKey(1))
    batch = next(batches(cfg1, 1, B=8))
    s1b, m1 = jax.jit(make_train_step(cfg1))(s1, batch)
    s4b, m4 = jax.jit(make_train_step(cfg4))(s4, batch)
    # same data, same init -> same grads up to accumulation order
    np.testing.assert_allclose(float(m1["grad_norm"]), float(m4["grad_norm"]), rtol=1e-5)
    a = jax.tree.leaves(s1b["params"])[0]
    b = jax.tree.leaves(s4b["params"])[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_checkpoint_roundtrip(tmp_path):
    cfg = tiny_cfg()
    state = init_train_state(cfg, jax.random.PRNGKey(2))
    d = str(tmp_path / "ckpt")
    checkpoint.save(state, d, 7)
    assert checkpoint.latest_step(d) == 7
    restored = checkpoint.restore(d, jax.eval_shape(lambda: state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_prune(tmp_path):
    cfg = tiny_cfg()
    state = init_train_state(cfg, jax.random.PRNGKey(2))
    d = str(tmp_path / "ckpt")
    for s in (1, 2, 3, 4, 5):
        checkpoint.save(state, d, s)
    checkpoint.prune(d, keep=2)
    assert checkpoint.latest_step(d) == 5
    assert sorted(int(x.split("_")[1]) for x in os.listdir(d)) == [4, 5]


def test_loop_crash_recovery(tmp_path):
    cfg = tiny_cfg()
    d = str(tmp_path / "ckpt")
    step = jax.jit(make_train_step(cfg))

    # first run: 6 steps, checkpoint every 2, then 'crash'
    state = init_train_state(cfg, jax.random.PRNGKey(3))
    loop = TrainLoop(step, state, batches(cfg, 6), ckpt_dir=d, ckpt_every=2)
    out = loop.run(6)
    assert out["final_step"] == 6

    # second run resumes from the checkpoint, not from scratch
    state2 = init_train_state(cfg, jax.random.PRNGKey(99))  # different init
    loop2 = TrainLoop(step, state2, batches(cfg, 10), ckpt_dir=d, ckpt_every=5)
    out2 = loop2.run(9)
    assert out2["final_step"] == 9
    assert len(out2["metrics"]) == 3  # only steps 6,7,8 executed


def test_loop_retries_transient_fault(tmp_path):
    cfg = tiny_cfg()
    step = jax.jit(make_train_step(cfg))
    state = init_train_state(cfg, jax.random.PRNGKey(4))
    fails = {"n": 0}

    def flaky(step_no):
        if step_no == 1 and fails["n"] < 2:
            fails["n"] += 1
            raise RuntimeError("injected node flake")

    loop = TrainLoop(step, state, batches(cfg, 3), max_step_retries=3, fault_hook=flaky)
    out = loop.run(3)
    assert out["final_step"] == 3
    assert fails["n"] == 2


def test_loop_fails_after_retry_budget(tmp_path):
    cfg = tiny_cfg()
    step = jax.jit(make_train_step(cfg))
    state = init_train_state(cfg, jax.random.PRNGKey(4))

    def always_fail(step_no):
        raise RuntimeError("hard fault")

    loop = TrainLoop(
        step, state, batches(cfg, 2), ckpt_dir=str(tmp_path / "c"),
        max_step_retries=1, fault_hook=always_fail,
    )
    with pytest.raises(RuntimeError):
        loop.run(2)
    # emergency checkpoint written
    assert checkpoint.latest_step(str(tmp_path / "c")) is not None


def test_prefetch_straggler_skip():
    import time

    def slow_gen():
        yield 1
        yield 2
        time.sleep(1.0)  # straggler
        yield 3

    it = PrefetchIterator(slow_gen(), deadline_s=0.2)
    got = [next(it), next(it), next(it)]
    assert got[:2] == [1, 2]
    assert got[2] == 2  # spare reused
    assert it.skipped == 1


def test_serve_engine_continuous_batching():
    from repro.serve.engine import Request, ServeEngine

    cfg = tiny_cfg()
    params = lm.init_params(cfg, jax.random.PRNGKey(5))
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, 5).astype(np.int32), max_new=4)
        for i in range(5)  # more requests than slots -> slot reuse
    ]
    done = eng.run(reqs, max_steps=200)
    assert all(r.done for r in done)
    assert all(len(r.out) == 4 for r in done)


def test_serve_matches_offline_decode():
    """Engine output for a single request == plain greedy decode."""
    from repro.serve.engine import Request, ServeEngine

    cfg = tiny_cfg()
    params = lm.init_params(cfg, jax.random.PRNGKey(6))
    prompt = np.array([5, 17, 3], dtype=np.int32)

    # offline greedy
    state = lm.init_decode_state(cfg, 1, 32)
    toks = list(prompt)
    out_ref = []
    for t in range(len(prompt) + 3):
        cur = toks[t] if t < len(toks) else out_ref[-1]
        lg, state = lm.decode_step(cfg, params, state, {"tokens": jnp.asarray([[cur]], jnp.int32)})
        if t >= len(prompt) - 1:
            out_ref.append(int(jnp.argmax(lg[0])))
    out_ref = out_ref[:4]

    eng = ServeEngine(cfg, params, batch_slots=2, max_len=32)
    req = Request(rid=0, prompt=prompt, max_new=4)
    eng.run([req], max_steps=50)
    assert req.out == out_ref, (req.out, out_ref)
