"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref oracle."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.hash32x2 import hash32x2_pallas
from repro.kernels.segment_reduce import segment_sum_sorted_pallas
from repro.kernels.substr_find import exists_before_pallas, substr_find_pallas
from repro.kernels.wkv6 import wkv6_pallas


# ----------------------------------------------------------------------
# hash32x2
# ----------------------------------------------------------------------
@pytest.mark.parametrize("n", [1, 7, 1024, 3000])
@pytest.mark.parametrize("k", [1, 2, 5])
def test_hash32x2_matches_ref(n, k):
    rng = np.random.default_rng(n * 31 + k)
    cols = jnp.asarray(rng.integers(0, 2**31, size=(n, k), dtype=np.int32))
    got = hash32x2_pallas(cols, block_rows=256)
    want = ref.hash32x2(cols)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_hash32x2_distributes():
    rng = np.random.default_rng(0)
    cols = jnp.asarray(rng.integers(0, 1000, size=(20000, 2), dtype=np.int32))
    h = np.asarray(ref.hash32x2(cols))
    buckets = h[:, 0] % 16
    counts = np.bincount(buckets, minlength=16)
    assert counts.min() > 0.8 * counts.mean()  # roughly uniform


# ----------------------------------------------------------------------
# substr_find
# ----------------------------------------------------------------------
def _pack(strings, L=64):
    n = len(strings)
    buf = np.zeros((n, L), np.uint8)
    lens = np.zeros((n,), np.int32)
    for i, s in enumerate(strings):
        b = s.encode()[:L]
        buf[i, : len(b)] = np.frombuffer(b, np.uint8)
        lens[i] = len(b)
    return jnp.asarray(buf), jnp.asarray(lens)


@pytest.mark.parametrize("L", [16, 64, 128])
@pytest.mark.parametrize("pat", ["ab", "special", "x"])
def test_substr_find_matches_ref(L, pat):
    rng = np.random.default_rng(hash((L, pat)) % 2**31)
    alphabet = list("abspecialx yz")
    strs = ["".join(rng.choice(alphabet, rng.integers(0, L))) for _ in range(733)]
    packed, lens = _pack(strs, L)
    p = jnp.asarray(np.frombuffer(pat.encode(), np.uint8))
    got = substr_find_pallas(packed, lens, p, block_rows=128)
    want = ref.substr_find(packed, lens, p)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # python ground truth
    truth = np.array([s.find(pat) if len(s) else -1 for s in strs], np.int32)
    np.testing.assert_array_equal(np.asarray(want), truth)


def test_exists_before_matches_python():
    strs = [
        "the special customer filed requests",
        "requests then special",
        "special",
        "",
        "specialrequests",
        "many special words and more requests here",
    ]
    packed, lens = _pack(strs, 64)
    a = jnp.asarray(np.frombuffer(b"special", np.uint8))
    b = jnp.asarray(np.frombuffer(b"requests", np.uint8))
    got = np.asarray(exists_before_pallas(packed, lens, a, b, block_rows=128))
    want = np.asarray(ref.exists_before(packed, lens, a, b))

    def truth(s):
        i = s.find("special")
        return i >= 0 and s.find("requests", i + len("special")) >= 0

    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(got, np.array([truth(s) for s in strs]))


# ----------------------------------------------------------------------
# segment_reduce
# ----------------------------------------------------------------------
@pytest.mark.parametrize("n,m", [(1, 1), (100, 5), (5000, 1000), (4096, 4096)])
@pytest.mark.parametrize("gaps", [False, True])
def test_segment_sum_sorted_matches_ref(n, m, gaps):
    rng = np.random.default_rng(n + m)
    ids = np.sort(rng.integers(0, m, n)).astype(np.int32)
    if gaps:  # sparse ids exercise the rank-based path
        ids = np.sort(rng.choice(np.arange(0, 4 * m, 4), n)).astype(np.int32)
        m_eff = 4 * m
    else:
        m_eff = m
    vals = rng.normal(size=n).astype(np.float32)
    got = segment_sum_sorted_pallas(jnp.asarray(vals), jnp.asarray(ids), m_eff, block_rows=256)
    want = ref.segment_sum_sorted(jnp.asarray(vals), jnp.asarray(ids), m_eff)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,S,D", [(1, 4, 2, 128, 32), (2, 8, 2, 256, 64), (1, 2, 2, 64, 16)])
def test_flash_attention_matches_ref(dtype, B, Hq, Hkv, S, D):
    rng = np.random.default_rng(B * S + Hq)
    q = jnp.asarray(rng.normal(size=(B, Hq, S, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), dtype)
    got = flash_attention_pallas(q, k, v, causal=True, bq=64, bk=64)
    want = ref.mha_reference(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_flash_attention_noncausal():
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 32)), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=False, bq=64, bk=64)
    want = ref.mha_reference(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


# ----------------------------------------------------------------------
# wkv6
# ----------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,T,D", [(1, 2, 64, 16), (2, 3, 128, 32)])
def test_wkv6_matches_ref(dtype, B, H, T, D):
    rng = np.random.default_rng(B + T)
    r = jnp.asarray(rng.normal(size=(B, H, T, D)) * 0.5, dtype)
    k = jnp.asarray(rng.normal(size=(B, H, T, D)) * 0.5, dtype)
    v = jnp.asarray(rng.normal(size=(B, H, T, D)) * 0.5, dtype)
    w = jnp.asarray(rng.uniform(0.7, 0.999, size=(B, H, T, D)), dtype)
    u = jnp.asarray(rng.normal(size=(H, D)) * 0.1, dtype)
    y_got, s_got = wkv6_pallas(r, k, v, w, u, bt=32)
    y_want, s_want = ref.wkv6_reference(r, k, v, w, u)
    tol = 4e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        np.asarray(y_got, np.float32), np.asarray(y_want, np.float32), rtol=tol, atol=tol
    )
    np.testing.assert_allclose(np.asarray(s_got), np.asarray(s_want), rtol=tol, atol=tol)


def test_wkv6_state_chaining():
    """Running two half-sequences with carried state == one full run."""
    rng = np.random.default_rng(9)
    B, H, T, D = 1, 2, 64, 16
    mk = lambda: jnp.asarray(rng.normal(size=(B, H, T, D)) * 0.5, jnp.float32)
    r, k, v = mk(), mk(), mk()
    w = jnp.asarray(rng.uniform(0.8, 0.99, size=(B, H, T, D)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, D)) * 0.1, jnp.float32)
    y_full, s_full = ref.wkv6_reference(r, k, v, w, u)
    half = T // 2
    y1, s1 = wkv6_pallas(r[:, :, :half], k[:, :, :half], v[:, :, :half], w[:, :, :half], u, bt=32)
    y2, s2 = wkv6_pallas(r[:, :, half:], k[:, :, half:], v[:, :, half:], w[:, :, half:], u, state=s1, bt=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y_full[:, :, :half]), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, :, half:]), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), rtol=1e-4, atol=1e-4)
