"""Three-way differential tests for the SQL front-end.

For every TPC-H query expressible in the dialect (11 of 22), the same
generated data is pushed through three independent stacks:

1. ``repro.sql.execute``      — parser -> planner -> optimizer ->
                                TensorFrame lowering,
2. ``queries.tpch_frames``    — the hand-written TensorFrame plans,
3. ``sql.oracle_backend``     — the *unoptimized* logical plan
                                interpreted row-at-a-time on
                                ``core.oracle``,

and all three result sets must agree.  A bug in the optimizer shows up
as SQL != oracle; a bug in the lowering or the engine shows up as
SQL != hand-written.
"""
import numpy as np
import pytest

from repro import sql
from repro.core import oracle as orc
from repro.queries import tpch_frames
from repro.queries.tpch_sql import SCALAR_SQL, TPCH_SQL
from repro.sql.oracle_backend import execute_oracle

SF = 0.002  # must match the shared tpch_small fixture (conftest.py)

# The heaviest multi-join queries cost several seconds of XLA compile
# each; they run in the slow lane, the rest keep the default suite fast.
SLOW_SQL = {"q3", "q5", "q7", "q8", "q9", "q10"}

QNAMES = sorted(TPCH_SQL, key=lambda s: int(s[1:]))


@pytest.fixture(scope="module")
def data(tpch_small):
    return tpch_small


def _params():
    return [
        pytest.param(q, marks=pytest.mark.slow) if q in SLOW_SQL else q
        for q in QNAMES
    ]


@pytest.mark.parametrize("qname", _params())
def test_sql_three_way(data, qname):
    tables, frames = data
    text = TPCH_SQL[qname]

    got = sql.execute(text, frames)
    hand = tpch_frames.ALL[qname](frames, sf=SF, apply_limit=False)
    naive_plan = sql.plan_query(text, frames, optimized=False)
    ora = execute_oracle(naive_plan, tables)
    godf = orc.frame_to_odf(got)

    if qname in SCALAR_SQL:
        (name,) = godf.keys()
        v_sql = godf[name][0]
        v_hand = hand[name] if isinstance(hand, dict) else hand.scalar(name)
        v_ora = ora[name][0]
        assert v_sql == pytest.approx(v_hand, rel=1e-8), (v_sql, v_hand)
        assert v_sql == pytest.approx(v_ora, rel=1e-8), (v_sql, v_ora)
        return

    hodf = orc.frame_to_odf(hand)
    assert set(godf) == set(hodf), "SQL column names must match hand-written"
    orc.assert_odf_equal(godf, hodf, sort=True, rtol=1e-8)
    orc.assert_odf_equal(godf, ora, sort=True, rtol=1e-8)


def test_sql_covers_at_least_ten_queries():
    """Acceptance guard: the dialect covers >= 10 TPC-H queries."""
    assert len(TPCH_SQL) >= 10


def test_optimized_matches_unoptimized_on_engine(data):
    """The optimizer must not change TensorFrame results (Q1)."""
    _, frames = data
    a = sql.execute(TPCH_SQL["q1"], frames)
    b = sql.execute(TPCH_SQL["q1"], frames, optimize=False)
    orc.assert_odf_equal(
        orc.frame_to_odf(a), orc.frame_to_odf(b), sort=True, rtol=1e-12
    )


def test_explain_shows_pushdown_on_q3(data):
    """Acceptance: explain() shows filter pushdown firing on Q3 — the
    single-table date/segment predicates sit above the join tree in the
    logical plan and directly above their scans afterwards."""
    _, frames = data
    txt = sql.explain(TPCH_SQL["q3"], frames)
    naive, opt = txt.split("== optimized plan ==")

    def depth_of(snippet, block):
        for line in block.splitlines():
            if snippet in line:
                return (len(line) - len(line.lstrip())) // 2
        raise AssertionError(f"{snippet!r} not found in plan:\n{block}")

    # naive: one Filter above the whole join tree (shallower than joins)
    assert depth_of("Filter", naive) < depth_of("Join", naive)
    # optimized: customer's segment predicate sits on its scan
    assert "Filter (customer.c_mktsegment = 'BUILDING')" in opt
    assert depth_of("c_mktsegment", opt) > depth_of("Join", opt)
    # and projection pruning narrowed the lineitem scan
    assert "Scan lineitem [l_orderkey, l_extendedprice, l_discount, l_shipdate]" in opt


def test_sql_limit_executes(data):
    _, frames = data
    out = sql.execute(
        "SELECT l_orderkey, l_quantity FROM lineitem "
        "ORDER BY l_orderkey LIMIT 5",
        frames,
    )
    assert out.nrows == 5
    ok = out.column("l_orderkey")
    assert list(ok) == sorted(ok)
