"""Three-way differential tests for the SQL front-end.

For every TPC-H query (all 22 since PR 2), the same generated data is
pushed through three independent stacks:

1. ``repro.sql.execute``      — parser -> planner -> optimizer (incl.
                                subquery decorrelation) -> TensorFrame
                                lowering,
2. ``queries.tpch_frames``    — the hand-written TensorFrame plans,
3. ``sql.oracle_backend``     — the *unoptimized* logical plan
                                interpreted row-at-a-time on
                                ``core.oracle`` (subqueries run
                                nested-loop, re-executed per outer row),

and all three result sets must agree.  A bug in the optimizer
(including a wrong decorrelation rewrite) shows up as SQL != oracle; a
bug in the lowering or the engine shows up as SQL != hand-written.
"""
import numpy as np
import pytest

from repro import sql
from repro.core import oracle as orc
from repro.queries import tpch_frames
from repro.queries.tpch_sql import SCALAR_SQL, TPCH_SQL, sql_text
from repro.sql.oracle_backend import execute_oracle

SF = 0.002  # must match the shared tpch_small fixture (conftest.py)

# The heaviest queries (multi-join XLA compiles, nested-loop oracle
# interpretation of correlated subqueries) run in the slow lane; the
# rest keep the default suite fast.
SLOW_SQL = {
    "q2", "q3", "q4", "q5", "q7", "q8", "q9", "q10",
    "q11", "q13", "q17", "q18", "q20", "q21",
}

QNAMES = sorted(TPCH_SQL, key=lambda s: int(s[1:]))


@pytest.fixture(scope="module")
def data(tpch_small):
    return tpch_small


def _params():
    return [
        pytest.param(q, marks=pytest.mark.slow) if q in SLOW_SQL else q
        for q in QNAMES
    ]


@pytest.mark.parametrize("qname", _params())
def test_sql_three_way(data, qname):
    tables, frames = data
    text = sql_text(qname, SF)

    got = sql.execute(text, frames)
    hand = tpch_frames.ALL[qname](frames, sf=SF, apply_limit=False)
    naive_plan = sql.plan_query(text, frames, optimized=False)
    ora = execute_oracle(naive_plan, tables)
    godf = orc.frame_to_odf(got)

    if qname in SCALAR_SQL:
        (name,) = godf.keys()
        v_sql = godf[name][0]
        v_hand = hand[name] if isinstance(hand, dict) else hand.scalar(name)
        v_ora = ora[name][0]
        assert v_sql == pytest.approx(v_hand, rel=1e-8), (v_sql, v_hand)
        assert v_sql == pytest.approx(v_ora, rel=1e-8), (v_sql, v_ora)
        return

    hodf = orc.frame_to_odf(hand)
    assert set(godf) == set(hodf), "SQL column names must match hand-written"
    orc.assert_odf_equal(godf, hodf, sort=True, rtol=1e-8)
    orc.assert_odf_equal(godf, ora, sort=True, rtol=1e-8)


def test_sql_covers_all_22_queries():
    """Acceptance guard: every TPC-H query runs through sql.execute."""
    assert QNAMES == [f"q{i}" for i in range(1, 23)]


def test_explain_decorrelates_q4_q17_q21(data):
    """Acceptance: the optimized plans of the subquery queries contain
    joins, not interpreted subquery markers.

    - q4's EXISTS becomes a semi join on the correlation key,
    - q17's correlated AVG becomes a group-by joined back in,
    - q21's EXISTS/NOT EXISTS (with <> residuals) become semi + anti
      joins over nunique/min aggregates of the inner lineitem."""
    _, frames = data

    def opt_plan(qname):
        txt = sql.explain(sql_text(qname, SF), frames)
        naive, opt = txt.split("== optimized plan ==")
        # the naive plan is the interpreted form: markers + subplans
        assert "subquery" in naive
        # the optimized plan must not fall back to interpretation
        assert "subquery[" not in opt and "scalar-subquery" not in opt
        assert "EXISTS" not in opt and "outer(" not in opt
        return opt

    q4 = opt_plan("q4")
    assert "Join semi on [orders.o_orderkey = lineitem.l_orderkey]" in q4

    q17 = opt_plan("q17")
    assert "Aggregate keys=[l2.l_partkey]" in q17
    assert "Join inner on [part.p_partkey = l2.l_partkey]" in q17

    q21 = opt_plan("q21")
    assert "Join semi on [l1.l_orderkey = l2.l_orderkey]" in q21
    assert "Join anti on" in q21 and "NUNIQUE" in q21


def test_explain_attaches_uncorrelated_scalar_q11(data):
    _, frames = data
    txt = sql.explain(sql_text("q11", SF), frames)
    opt = txt.split("== optimized plan ==")[1]
    assert "AttachScalar" in opt and "subquery[" not in opt


def test_sql_distinct_executes(data):
    _, frames = data
    out = sql.execute(
        "SELECT DISTINCT l_returnflag, l_linestatus FROM lineitem "
        "ORDER BY l_returnflag, l_linestatus",
        frames,
    )
    rows = list(
        zip(np.asarray(out.column("l_returnflag")),
            np.asarray(out.column("l_linestatus")))
    )
    assert len(rows) == len(set(rows))  # deduplicated
    assert rows == sorted(rows)
    # cross-check against COUNT(DISTINCT)-style grouping on the oracle
    tables, _ = data
    naive = sql.plan_query(
        "SELECT DISTINCT l_returnflag, l_linestatus FROM lineitem",
        frames,
        optimized=False,
    )
    ora = execute_oracle(naive, tables)
    assert sorted(zip(ora["l_returnflag"], ora["l_linestatus"])) == [
        (str(a), str(b)) for a, b in rows
    ]


def test_optimized_matches_unoptimized_on_engine(data):
    """The optimizer must not change TensorFrame results (Q1)."""
    _, frames = data
    a = sql.execute(TPCH_SQL["q1"], frames)
    b = sql.execute(TPCH_SQL["q1"], frames, optimize=False)
    orc.assert_odf_equal(
        orc.frame_to_odf(a), orc.frame_to_odf(b), sort=True, rtol=1e-12
    )


def test_explain_shows_pushdown_on_q3(data):
    """Acceptance: explain() shows filter pushdown firing on Q3 — the
    single-table date/segment predicates sit above the join tree in the
    logical plan and directly above their scans afterwards."""
    _, frames = data
    txt = sql.explain(TPCH_SQL["q3"], frames)
    naive, opt = txt.split("== optimized plan ==")

    def depth_of(snippet, block):
        for line in block.splitlines():
            if snippet in line:
                return (len(line) - len(line.lstrip())) // 2
        raise AssertionError(f"{snippet!r} not found in plan:\n{block}")

    # naive: one Filter above the whole join tree (shallower than joins)
    assert depth_of("Filter", naive) < depth_of("Join", naive)
    # optimized: customer's segment predicate sits on its scan
    assert "Filter (customer.c_mktsegment = 'BUILDING')" in opt
    assert depth_of("c_mktsegment", opt) > depth_of("Join", opt)
    # and projection pruning narrowed the lineitem scan
    assert "Scan lineitem [l_orderkey, l_extendedprice, l_discount, l_shipdate]" in opt


def test_sql_limit_executes(data):
    _, frames = data
    out = sql.execute(
        "SELECT l_orderkey, l_quantity FROM lineitem "
        "ORDER BY l_orderkey LIMIT 5",
        frames,
    )
    assert out.nrows == 5
    ok = out.column("l_orderkey")
    assert list(ok) == sorted(ok)
