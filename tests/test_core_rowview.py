"""RowView (late materialization) regression tests — ISSUE 5.

The invariant: a RowView frame is *indistinguishable* from the eager
frame it stands for.  Every column kind (int / float / dict / obj /
date / bool, with and without validity companions) must round-trip
``materialize()`` losslessly, and whole pipelines must decode the same
whether late materialization is on or off.

Plus the stats-cache contract: ``join(algorithm="auto")`` consults
cached uniqueness (store zone maps, group-by outputs, prior sort
tests) and only pays the build-side sort test when nothing is known.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro import store
from repro.core import TensorFrame
from repro.core.config import CONFIG
from repro.core.frame import _valid_name
import importlib

join_mod = importlib.import_module("repro.core.join")


def _full_frame(n=40, seed=0, tag=0):
    """One column of every kind, plus validity companions."""
    rng = np.random.default_rng(seed)
    f = TensorFrame.from_arrays(
        {
            "i": rng.integers(-5, 20, n),
            "f": rng.random(n) * 10,
            "s": rng.choice(["aa", "bb", "cc"], n).astype(object),
            "o": np.array([f"obj-{tag}-{v}" for v in range(n)], dtype=object),
            "d": (
                np.datetime64("2020-01-01")
                + rng.integers(0, 900, n).astype("timedelta64[D]")
            ),
            "b": rng.random(n) < 0.5,
            "k": rng.integers(0, 8, n),
        },
        encode={"s": "dict", "o": "obj"},
    )
    # nullable int + float columns via validity companions
    f = f._append_int_column(
        _valid_name("i"), jnp.asarray((rng.random(n) < 0.8).astype(np.int64)), "bool"
    )
    f = f._append_int_column(
        _valid_name("f"), jnp.asarray((rng.random(n) < 0.8).astype(np.int64)), "bool"
    )
    return f


def _decoded(frame):
    return {c: frame.column(c) for c in frame.column_names}


def _assert_same(a, b):
    assert sorted(a) == sorted(b)
    for c in a:
        x, y = a[c], b[c]
        assert x.shape == y.shape, c
        if x.dtype.kind == "f":
            np.testing.assert_allclose(
                x.astype(float), y.astype(float), rtol=0, atol=0, equal_nan=True
            )
        else:
            np.testing.assert_array_equal(x, y)


def test_take_is_lazy_and_roundtrips_every_kind():
    f = _full_frame()
    idx = np.array([7, 3, 3, 0, 31, 12])
    v = f.take(idx)
    assert v.is_view
    before = _decoded(v)  # decoding must NOT require materialization
    assert v.is_view
    v.materialize()
    assert not v.is_view
    _assert_same(before, _decoded(v))
    # against the eager reference
    CONFIG.late_materialization = False
    try:
        eager = f.take(idx)
        assert not eager.is_view
        _assert_same(before, _decoded(eager))
    finally:
        CONFIG.late_materialization = True


def test_view_composition_filter_sort_head():
    f = _full_frame(n=60)
    lazy = (
        f.filter(f.col_values("i") >= 0)
        .sort_values(["k", "i"], ascending=[True, False])
        .head(17)
    )
    assert lazy.is_view
    CONFIG.late_materialization = False
    try:
        eager = (
            f.filter(f.col_values("i") >= 0)
            .sort_values(["k", "i"], ascending=[True, False])
            .head(17)
        )
    finally:
        CONFIG.late_materialization = True
    _assert_same(_decoded(lazy), _decoded(eager))


def test_with_column_keeps_view_lazy():
    """Appending a computed column onto a RowView must not force the
    whole frame to materialize (ISSUE 6 satellite): the new payload
    lands in its own identity block and the view composes on."""
    from repro.core.expr import col, lit

    f = _full_frame(n=60)
    v = f.filter(f.col_values("i") >= 3)
    assert v.is_view
    v2 = v.with_column("i2", col("i") * lit(2))
    assert v2.is_view  # the append did not materialize the view
    v3 = v2.filter(v2.col_values("k") < 5)
    assert v3.is_view
    CONFIG.late_materialization = False
    try:
        e = f.filter(f.col_values("i") >= 3)
        e2 = e.with_column("i2", col("i") * lit(2))
        e3 = e2.filter(e2.col_values("k") < 5)
    finally:
        CONFIG.late_materialization = True
    _assert_same(_decoded(v3), _decoded(e3))


@pytest.mark.parametrize("how", ["inner", "left"])
def test_join_chain_threads_views(how):
    f = _full_frame(n=50, seed=1, tag=1)
    d1 = TensorFrame.from_arrays(
        {"k": np.arange(8), "name": np.array([f"n{v}" for v in range(8)], dtype=object)}
    )
    d2 = TensorFrame.from_arrays({"i": np.arange(-5, 20), "w": np.random.rand(25)})
    out = f.join(d1, on="k", how=how).join(d2, on="i", how=how)
    if how == "inner":
        assert out.is_view  # the chain composed selection vectors
    # (left joins exit through vconcat — a materialization point)
    CONFIG.late_materialization = False
    try:
        eager = f.join(d1, on="k", how=how).join(d2, on="i", how=how)
    finally:
        CONFIG.late_materialization = True
    got, want = _decoded(out), _decoded(eager)
    # row order may legally differ only if algorithms differed; both
    # runs take the same code path, so compare directly
    _assert_same(got, want)


def test_materialize_is_idempotent_and_canonical():
    f = _full_frame()
    v = f.take(np.arange(10))
    v.materialize()
    it_before = v.itensor
    v.materialize()
    assert v.itensor is it_before
    # canonical layout: slots are dense and in-range
    islots = sorted(m.slot for m in v.columns.values() if m.is_int_like())
    assert islots == list(range(len(islots)))


def test_groupby_on_view_gathers_only_needed_columns():
    f = _full_frame(n=80, seed=2)
    v = f.filter(f.col_values("i") >= 0)
    out = v.groupby("k").agg([("n", "size", ""), ("s", "sum", "f")])
    assert out.nrows == len(np.unique(np.asarray(v.col_values("k"))))
    CONFIG.late_materialization = False
    try:
        eager = (
            f.filter(f.col_values("i") >= 0)
            .groupby("k")
            .agg([("n", "size", ""), ("s", "sum", "f")])
        )
    finally:
        CONFIG.late_materialization = True
    _assert_same(_decoded(out), _decoded(eager))


# ----------------------------------------------------------------------
# stats-driven join algorithm pick
# ----------------------------------------------------------------------
def test_store_zone_maps_prove_uniqueness_no_sort_test():
    t = store.Table.from_arrays(
        {"k": np.arange(100), "p": np.random.rand(100)}, chunk_rows=32
    )
    dim = TensorFrame.from_store(t)
    st = dim.col_stats("k")
    assert st is not None and st.unique is True and st.distinct == 100
    fact = TensorFrame.from_arrays(
        {"k": np.random.default_rng(0).integers(0, 100, 500), "v": np.random.rand(500)}
    )
    join_mod.reset_stats()
    out = fact.join(dim, on="k")
    assert out.nrows == 500
    assert join_mod.STATS["stats_unique_hits"] == 1
    assert join_mod.STATS["uniqueness_sort_tests"] == 0


def test_uniqueness_survives_filtering():
    t = store.Table.from_arrays({"k": np.arange(100)}, chunk_rows=32)
    dim = TensorFrame.from_store(t).filter(
        TensorFrame.from_store(t).col_values("k") < 50
    )
    st = dim.col_stats("k")
    assert st is not None and st.unique is True
    assert st.distinct is None  # exact count is gone after the filter
    fact = TensorFrame.from_arrays({"k": np.arange(0, 100, 3)})
    join_mod.reset_stats()
    fact.join(dim, on="k")
    assert join_mod.STATS["uniqueness_sort_tests"] == 0
    assert join_mod.STATS["stats_unique_hits"] == 1


def test_zone_maps_prove_duplicates_skip_sort_test():
    t = store.Table.from_arrays({"k": np.array([1, 1, 2, 3] * 25)}, chunk_rows=32)
    nk = TensorFrame.from_store(t)
    assert nk.col_stats("k").unique is False
    fact = TensorFrame.from_arrays({"k": np.arange(5)})
    join_mod.reset_stats()
    fact.join(nk, on="k")
    assert join_mod.STATS["stats_nonunique_hits"] == 1
    assert join_mod.STATS["uniqueness_sort_tests"] == 0


def test_groupby_output_seeds_stats():
    f = TensorFrame.from_arrays(
        {"k": np.random.default_rng(1).integers(0, 20, 200), "v": np.random.rand(200)}
    )
    g = f.groupby("k").agg([("s", "sum", "v")])
    assert g.col_stats("k").unique is True
    join_mod.reset_stats()
    f.join(g, on="k")
    assert join_mod.STATS["stats_unique_hits"] == 1
    assert join_mod.STATS["uniqueness_sort_tests"] == 0


def test_column_replacement_invalidates_combo_stats():
    from repro.core import lit

    f = TensorFrame.from_arrays(
        {"a": np.array([0, 0, 1, 1]), "b": np.array([0, 1, 0, 1]),
         "v": np.arange(4.0)}
    )
    g = f.groupby(["a", "b"]).agg([("s", "sum", "v")])
    assert g.unique_hint(["a", "b"]) is True
    g2 = g.with_column("b", lit(0))  # collapses b: combo no longer unique
    assert g2.unique_hint(["a", "b"]) is None
    probe = TensorFrame.from_arrays({"a": np.array([0]), "b": np.array([0])})
    out = probe.join(g2, on=["a", "b"], algorithm="auto")
    assert out.nrows == 2  # both (0,0) build rows match — none dropped


def test_agg_output_overwriting_key_skips_stats_seed():
    f = TensorFrame.from_arrays(
        {"a": np.array([0, 0, 1, 1]), "b": np.array([0, 1, 0, 1]),
         "v": np.array([5.0, 3.0, 5.0, 3.0])}
    )
    g = f.groupby(["a", "b"]).agg([("a", "sum", "v")])  # 'a' overwritten
    assert g.unique_hint(["a", "b"]) is None


def test_unknown_build_pays_sort_test_once_then_caches():
    f = TensorFrame.from_arrays({"k": np.arange(50), "v": np.random.rand(50)})
    d = TensorFrame.from_arrays({"k": np.arange(30)})
    join_mod.reset_stats()
    f.join(d, on="k")
    assert join_mod.STATS["uniqueness_sort_tests"] == 1
    f.join(d, on="k")  # second join: the verdict was cached on d
    assert join_mod.STATS["uniqueness_sort_tests"] == 1
    assert join_mod.STATS["stats_unique_hits"] == 1
    assert d.col_stats("k").unique is True and d.col_stats("k").distinct == 30
