"""TPC-H SQL over store-backed tables (ISSUE 4 acceptance).

Every TPC-H query runs twice through ``repro.sql``: once over the
in-memory TensorFrames (the stack the three-way differential tests in
tests/test_sql.py already pin against the oracle and the hand plans)
and once over a scope of chunked ``repro.store`` tables — small chunks,
fact tables date-clustered, dictionaries interned, sargable predicates
pushed into the scans.  Results must be identical: scan pushdown and
chunk skipping are pure access-path changes.
"""
import numpy as np
import pytest

from repro import sql, store
from repro.core import oracle as orc
from repro.data import tpch
from repro.queries.tpch_sql import SCALAR_SQL, TPCH_SQL, sql_text

SF = 0.002  # matches the shared tpch_small fixture

# same split as tests/test_sql.py: multi-join compiles in the slow lane
SLOW_SQL = {
    "q2", "q3", "q4", "q5", "q7", "q8", "q9", "q10",
    "q11", "q13", "q17", "q18", "q20", "q21",
}

QNAMES = sorted(TPCH_SQL, key=lambda s: int(s[1:]))


@pytest.fixture(scope="module")
def scopes(tpch_small):
    tables, frames = tpch_small
    stores = tpch.as_store(tables, chunk_rows=512, sort_fact_by_date=True)
    return frames, stores


def _params():
    return [
        pytest.param(q, marks=pytest.mark.slow) if q in SLOW_SQL else q
        for q in QNAMES
    ]


@pytest.mark.parametrize("qname", _params())
def test_store_backed_sql_matches_frames(scopes, qname):
    frames, stores = scopes
    text = sql_text(qname, SF)
    want = sql.execute(text, frames)
    got = sql.execute(text, stores)
    godf, wodf = orc.frame_to_odf(got), orc.frame_to_odf(want)
    if qname in SCALAR_SQL:
        (name,) = godf.keys()
        assert godf[name][0] == pytest.approx(wodf[name][0], rel=1e-8)
        return
    assert set(godf) == set(wodf)
    orc.assert_odf_equal(godf, wodf, sort=True, rtol=1e-8)


def test_store_covers_all_22_queries():
    assert QNAMES == [f"q{i}" for i in range(1, 23)]


# ----------------------------------------------------------------------
# plan-level: the optimizer pushes into store scans, and only there
# ----------------------------------------------------------------------
def test_explain_pushes_sargable_predicates_into_store_scan(scopes):
    frames, stores = scopes
    text = sql_text("q6", SF)
    opt = sql.explain(text, stores).split("== optimized plan ==")[1]
    # q6 is one lineitem scan with date-range + discount-range +
    # quantity predicates: all sargable, all pushed, no residual Filter
    assert "pushed=" in opt
    assert "l_shipdate" in opt.split("pushed=")[1]
    assert "Filter" not in opt
    # same query over frames keeps the explicit Filter (no store scans)
    opt_f = sql.explain(text, frames).split("== optimized plan ==")[1]
    assert "pushed=" not in opt_f and "Filter" in opt_f


def test_explain_keeps_residual_filters_above_store_scan(scopes):
    _, stores = scopes
    # LIKE is not sargable: it must stay a residual Filter even though
    # the date conjunct pushes
    opt = sql.explain(
        "SELECT COUNT(*) AS n FROM orders "
        "WHERE o_orderdate >= DATE '1995-01-01' "
        "AND o_comment LIKE '%special%requests%'",
        stores,
    ).split("== optimized plan ==")[1]
    assert "pushed=" in opt and "o_orderdate" in opt.split("pushed=")[1]
    assert "Filter" in opt and "LIKE" in opt


def test_store_scan_skips_chunks_on_clustered_dates(scopes):
    """The access-path win the SQL layer rides on: a date predicate on
    the date-clustered lineitem store skips most chunks."""
    _, stores = scopes
    li = stores["lineitem"]
    r = store.scan(
        li,
        ["l_extendedprice"],
        [store.Pred("l_shipdate", ">=", np.datetime64("1998-06-01"))],
    )
    assert r.chunks_skipped >= 0.8 * r.chunks_total
    full = store.scan(li, ["l_extendedprice"])
    assert r.nrows < full.nrows


def test_store_scope_unoptimized_still_correct(scopes):
    """optimize=False lowers store scans without pushdown — full
    materialization plus explicit Filters must agree with pushdown."""
    frames, stores = scopes
    text = sql_text("q6", SF)
    a = sql.execute(text, stores)
    b = sql.execute(text, stores, optimize=False)
    orc.assert_odf_equal(
        orc.frame_to_odf(a), orc.frame_to_odf(b), sort=True, rtol=1e-12
    )
