"""Integration: the paper's 5 TPC-DS queries vs the independent
reference implementation."""
import pytest

from repro.core import oracle as orc
from repro.data import tpcds
from repro.queries import tpcds_frames, tpcds_numpy

SF = 0.005


@pytest.fixture(scope="module")
def data():
    tables = tpcds.generate(sf=SF, seed=7)
    frames = tpcds.as_frames(tables)
    return tables, frames


def rows_to_odf(rows):
    if not rows:
        return {}
    return {k: [r[k] for r in rows] for k in rows[0]}


# q3 (date-dim join + grouped agg) stays in the fast default lane; the
# full 5-query sweep runs with `-m "slow or not slow"`.
FAST_QUERIES = {"q3"}


def _params():
    return [
        q if q in FAST_QUERIES else pytest.param(q, marks=pytest.mark.slow)
        for q in sorted(tpcds_frames.ALL)
    ]


@pytest.mark.parametrize("qname", _params())
def test_query_matches_reference(data, qname):
    tables, frames = data
    got = tpcds_frames.ALL[qname](frames, sf=SF, apply_limit=False)
    expect = tpcds_numpy.ALL[qname](tables, sf=SF)
    if qname in tpcds_frames.SCALAR_QUERIES:
        for k in expect:
            assert got[k] == pytest.approx(expect[k]), (qname, got, expect)
        return
    godf = orc.frame_to_odf(got)
    eodf = rows_to_odf(expect)
    if not eodf:
        assert all(len(v) == 0 for v in godf.values()), f"{qname}: expected empty"
        return
    orc.assert_odf_equal(godf, eodf, sort=True, rtol=1e-8)
