"""Out-of-core execution: spill layer units, force==eager differentials,
zone-map pruning monotonicity, and the capped-budget TPC-H sweep
(ISSUE 8 acceptance).

Property-style coverage runs twice: a seeded plain-random sweep that
always runs, and a ``hypothesis`` suite that engages when the package
is installed (CI installs requirements-dev.txt; the bare container may
not have it, and the plain sweep keeps the invariants pinned there).
"""
import gc
import glob
import os

import numpy as np
import pytest

from repro import sql, store
from repro.core import oracle as orc
from repro.core import pipeline
from repro.core.config import CONFIG
from repro.data import tpch
from repro.queries.tpch_sql import SCALAR_SQL, TPCH_SQL, sql_text

SF = 0.002


@pytest.fixture
def ooc(tmp_path):
    """force + tiny budget + isolated spill dir; restores CONFIG."""
    saved = (
        CONFIG.out_of_core,
        CONFIG.memory_budget_bytes,
        CONFIG.spill_dir,
        CONFIG.ooc_merge_every,
    )
    CONFIG.out_of_core = "force"
    CONFIG.memory_budget_bytes = 1 << 14
    CONFIG.spill_dir = str(tmp_path)
    CONFIG.ooc_merge_every = 2
    # counter reset comes from conftest's autouse obs.metrics fixture
    yield tmp_path
    (
        CONFIG.out_of_core,
        CONFIG.memory_budget_bytes,
        CONFIG.spill_dir,
        CONFIG.ooc_merge_every,
    ) = saved


def _eager(query, scope):
    saved = CONFIG.out_of_core
    CONFIG.out_of_core = "off"
    try:
        return sql.execute(query, scope)
    finally:
        CONFIG.out_of_core = saved


def _assert_same(got, want, rtol=1e-9):
    godf, wodf = orc.frame_to_odf(got), orc.frame_to_odf(want)
    assert set(godf) == set(wodf)
    orc.assert_odf_equal(godf, wodf, sort=True, rtol=rtol)


# ----------------------------------------------------------------------
# spill manager units (jax-free layer)
# ----------------------------------------------------------------------
def test_spillable_roundtrip_and_lru_eviction(tmp_path):
    saved = (CONFIG.memory_budget_bytes, CONFIG.spill_dir)
    CONFIG.memory_budget_bytes = 3000
    CONFIG.spill_dir = str(tmp_path)
    mgr = store.SpillManager()
    try:
        rng = np.random.default_rng(0)
        blocks = []
        for i in range(4):
            data = {
                "a": rng.integers(0, 100, 128),
                "b": rng.standard_normal(128),
            }
            validity = {"a": (rng.integers(0, 2, 128) > 0)}
            blocks.append((mgr.register(data, validity), data, validity))
        # each block is ~2KB: under a 3000-byte budget the older ones
        # must have been written out
        assert mgr.counters["bytes_spilled"] > 0
        assert mgr.counters["evictions"] >= 2
        assert mgr.counters["peak_tracked_bytes"] >= store.block_bytes(
            blocks[0][1], blocks[0][2]
        )
        for handle, data, validity in blocks:
            got_data, got_validity = handle.get()
            for k, v in data.items():
                np.testing.assert_array_equal(got_data[k], v)
            np.testing.assert_array_equal(got_validity["a"], validity["a"])
        assert mgr.counters["bytes_reread"] > 0
    finally:
        CONFIG.memory_budget_bytes, CONFIG.spill_dir = saved


def test_spill_files_deleted_on_release_and_gc(tmp_path):
    saved = (CONFIG.memory_budget_bytes, CONFIG.spill_dir)
    CONFIG.memory_budget_bytes = 64  # everything spills immediately
    CONFIG.spill_dir = str(tmp_path)
    mgr = store.SpillManager()
    try:
        h1 = mgr.register({"a": np.arange(512)})
        h2 = mgr.register({"b": np.arange(512) * 2})
        spilled = glob.glob(os.path.join(str(tmp_path), "block-*"))
        assert len(spilled) >= 1
        h1.release()
        del h1
        del h2
        gc.collect()
        assert glob.glob(os.path.join(str(tmp_path), "block-*")) == []
    finally:
        CONFIG.memory_budget_bytes, CONFIG.spill_dir = saved


def test_respill_of_immutable_block_is_free(tmp_path):
    saved = (CONFIG.memory_budget_bytes, CONFIG.spill_dir)
    CONFIG.memory_budget_bytes = 64
    CONFIG.spill_dir = str(tmp_path)
    mgr = store.SpillManager()
    try:
        h = mgr.register({"a": np.arange(1024)})
        other = mgr.register({"b": np.arange(1024) * 2})  # evicts h
        first = mgr.counters["bytes_spilled"]
        assert first > 0
        h.get()  # rehydrate; over budget, so blocks re-evict at once
        other.get()
        h.get()
        settled = mgr.counters["bytes_spilled"]
        h.get()
        other.get()
        # every block has a spill file by now; later evictions re-use
        # them (blocks are immutable) instead of re-writing
        assert mgr.counters["bytes_spilled"] == settled
        assert mgr.counters["bytes_reread"] > 0
    finally:
        CONFIG.memory_budget_bytes, CONFIG.spill_dir = saved


# ----------------------------------------------------------------------
# seeded-random force==eager differential (always runs)
# ----------------------------------------------------------------------
def _random_scope(rng, nrows, chunk_rows):
    cols = {
        "k": rng.integers(0, max(2, nrows // 8), nrows),
        "g": rng.integers(-5, 5, nrows),
        "v": rng.integers(-1000, 1000, nrows),
        "w": np.round(rng.standard_normal(nrows), 3),
    }
    return {"t": store.Table.from_arrays(cols, chunk_rows=chunk_rows)}


QUERIES = [
    "SELECT g, SUM(v) AS sv, COUNT(*) AS n FROM t GROUP BY g",
    "SELECT g, MIN(v) AS lo, MAX(v) AS hi, AVG(w) AS aw FROM t GROUP BY g",
    "SELECT k, SUM(w) AS sw FROM t WHERE v > 0 GROUP BY k",
    "SELECT g, COUNT(*) AS n FROM t WHERE v > -500 AND v < 500 GROUP BY g",
    "SELECT SUM(v) AS sv, MAX(w) AS mw FROM t WHERE g >= 0",
]


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_force_tiny_budget_matches_eager_random(ooc, seed):
    rng = np.random.default_rng(seed)
    nrows = int(rng.integers(300, 3000))
    chunk_rows = int(rng.integers(64, 512))
    scope = _random_scope(rng, nrows, chunk_rows)
    for query in QUERIES:
        want = _eager(query, scope)
        got = sql.execute(query, scope)
        _assert_same(got, want)
    assert pipeline.STATS["chunks_streamed"] > 0


def test_hypothesis_force_matches_eager(ooc):
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=25, deadline=None)
    @hyp.given(
        seed=st.integers(0, 2**31 - 1),
        nrows=st.integers(64, 2000),
        chunk_rows=st.integers(32, 256),
        budget=st.integers(1 << 10, 1 << 16),
        query=st.sampled_from(QUERIES),
    )
    def inner(seed, nrows, chunk_rows, budget, query):
        CONFIG.memory_budget_bytes = budget
        scope = _random_scope(
            np.random.default_rng(seed), nrows, chunk_rows
        )
        _assert_same(sql.execute(query, scope), _eager(query, scope))

    inner()


# ----------------------------------------------------------------------
# zone-map pruning monotonicity through filter chains
# ----------------------------------------------------------------------
def test_pruning_counters_monotone_through_filter_chain(ooc):
    n = 4096
    cols = {
        "d": np.arange(n),  # clustered: zone maps are tight
        "v": np.arange(n) % 7,
    }
    scope = {"t": store.Table.from_arrays(cols, chunk_rows=256)}
    base = "SELECT SUM(v) AS sv FROM t"
    preds = [" WHERE d >= 1024", " WHERE d >= 1024 AND d < 2048"]
    pruned, streamed = [], []
    for extra in [""] + preds:
        pipeline.reset_stats()
        got = sql.execute(base + extra, scope)
        want = _eager(base + extra, scope)
        _assert_same(got, want)
        pruned.append(pipeline.STATS["chunks_pruned"])
        streamed.append(pipeline.STATS["chunks_streamed"])
    # each extra conjunct can only prune MORE chunks, never fewer
    assert pruned[0] <= pruned[1] <= pruned[2]
    assert streamed[0] >= streamed[1] >= streamed[2]
    assert pruned[2] > 0  # the range predicate provably skips chunks


# ----------------------------------------------------------------------
# capped-budget TPC-H differential (test_store_sql.py style)
# ----------------------------------------------------------------------
FAST_TPCH = ["q1", "q6", "q12", "q14", "q19"]


@pytest.fixture(scope="module")
def tpch_scopes(tpch_small):
    tables, frames = tpch_small
    stores = tpch.as_store(tables, chunk_rows=512, sort_fact_by_date=True)
    return frames, stores


@pytest.mark.parametrize("qname", FAST_TPCH)
def test_capped_tpch_matches_eager(tpch_scopes, ooc, qname):
    frames, stores = tpch_scopes
    text = sql_text(qname, SF)
    want = _eager(text, frames)
    got = sql.execute(text, stores)
    godf, wodf = orc.frame_to_odf(got), orc.frame_to_odf(want)
    if qname in SCALAR_SQL:
        (name,) = godf.keys()
        assert godf[name][0] == pytest.approx(wodf[name][0], rel=1e-8)
        return
    assert set(godf) == set(wodf)
    orc.assert_odf_equal(godf, wodf, sort=True, rtol=1e-8)


@pytest.mark.slow
@pytest.mark.parametrize(
    "qname", [q for q in sorted(TPCH_SQL, key=lambda s: int(s[1:]))]
)
def test_capped_tpch_full_sweep(tpch_scopes, ooc, qname):
    frames, stores = tpch_scopes
    text = sql_text(qname, SF)
    want = _eager(text, frames)
    got = sql.execute(text, stores)
    godf, wodf = orc.frame_to_odf(got), orc.frame_to_odf(want)
    if qname in SCALAR_SQL:
        (name,) = godf.keys()
        assert godf[name][0] == pytest.approx(wodf[name][0], rel=1e-8)
        return
    assert set(godf) == set(wodf)
    orc.assert_odf_equal(godf, wodf, sort=True, rtol=1e-8)
