import numpy as np
import pytest

from repro.core import TensorFrame, col, d, if_else, lit, udf
from repro.core import oracle as orc


def sample_data(n=100, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "k": rng.integers(0, 10, n),
        "v": rng.normal(size=n),
        "cat": rng.choice(["red", "green", "blue"], n).astype(object),
        "name": np.array([f"name_{i:05d}" for i in rng.integers(0, n * 2, n)], dtype=object),
        "dt": np.datetime64("1994-01-01") + rng.integers(0, 1000, n).astype("timedelta64[D]"),
        "flag": rng.integers(0, 2, n).astype(bool),
    }


def test_construction_and_encoding():
    data = sample_data(200)
    f = TensorFrame.from_arrays(data)
    # low-cardinality 'cat' is dict-encoded into the int tensor
    assert f.meta("cat").kind == "dict"
    # high-cardinality 'name' is offloaded
    assert f.meta("name").kind == "obj"
    assert f.meta("dt").kind == "date"
    assert f.meta("flag").kind == "bool"
    assert f.nrows == 200
    np.testing.assert_array_equal(f.column("k"), data["k"])
    np.testing.assert_allclose(f.column("v"), data["v"])
    assert list(f.column("cat")) == list(data["cat"])
    assert list(f.column("name")) == list(data["name"])
    np.testing.assert_array_equal(f.column("dt"), data["dt"].astype("datetime64[D]"))


def test_filter_numeric_and_string():
    data = sample_data(300)
    f = TensorFrame.from_arrays(data)
    out = f.filter((col("k") >= 5) & (col("cat") == "red"))
    mask = (data["k"] >= 5) & (data["cat"] == "red")
    assert out.nrows == int(mask.sum())
    np.testing.assert_array_equal(out.column("k"), data["k"][mask])
    assert list(out.column("cat")) == list(data["cat"][mask])
    # offloaded column follows via its row indexer
    assert list(out.column("name")) == list(data["name"][mask])


def test_filter_string_ops():
    names = np.array(["alpha", "beta", "alphabet", "gamma", "beta-x"], dtype=object)
    f = TensorFrame.from_arrays({"s": names, "i": np.arange(5)}, encode={"s": "dict"})
    assert f.filter(col("s").str.startswith("alpha")).nrows == 2
    assert f.filter(col("s").str.contains("bet")).nrows == 3
    assert f.filter(col("s").str.like("%a")).nrows == 3  # alpha, gamma... and?
    out = f.filter(col("s").str.like("alpha%"))
    assert set(out.column("s")) == {"alpha", "alphabet"}


def test_string_order_comparisons():
    vals = np.array(["b", "a", "c", "b", "d"], dtype=object)
    f = TensorFrame.from_arrays({"s": vals}, encode={"s": "dict"})
    assert f.filter(col("s") < "c").nrows == 3
    assert f.filter(col("s") >= "b").nrows == 4
    assert f.filter(col("s") != "b").nrows == 3


def test_dates_and_year():
    data = sample_data(50)
    f = TensorFrame.from_arrays(data)
    out = f.filter((col("dt") >= d("1995-01-01")) & (col("dt") < d("1996-01-01")))
    expect = (data["dt"] >= np.datetime64("1995-01-01")) & (
        data["dt"] < np.datetime64("1996-01-01")
    )
    assert out.nrows == int(expect.sum())
    years = f.with_column("y", col("dt").dt.year()).column("y")
    np.testing.assert_array_equal(
        years, data["dt"].astype("datetime64[Y]").astype(int) + 1970
    )


def test_with_column_and_arith():
    data = sample_data(80)
    f = TensorFrame.from_arrays(data)
    f2 = f.with_column("x", col("k") * 2 + 1).with_column("r", col("v") / (col("k") + 1))
    np.testing.assert_array_equal(f2.column("x"), data["k"] * 2 + 1)
    np.testing.assert_allclose(f2.column("r"), data["v"] / (data["k"] + 1))
    f3 = f.with_column("c", if_else(col("v") > 0, col("v"), lit(0.0)))
    np.testing.assert_allclose(f3.column("c"), np.where(data["v"] > 0, data["v"], 0.0))


def test_stateless_udf():
    data = sample_data(60)
    f = TensorFrame.from_arrays(data)
    import jax.numpy as jnp

    # the paper's Fig. 4 cyclical-feature UDF
    e = udf(
        lambda x, c: jnp.sin(2 * jnp.pi * x) > jnp.cos(2 * jnp.pi * c),
        col("v"),
        col("k"),
        returns="bool",
    )
    out = f.filter(e)
    expect = np.sin(2 * np.pi * data["v"]) > np.cos(2 * np.pi * data["k"])
    assert out.nrows == int(expect.sum())


def test_groupby_against_oracle():
    data = sample_data(500, seed=3)
    f = TensorFrame.from_arrays(data)
    specs = [
        ("total", "sum", "v"),
        ("cnt", "size", ""),
        ("kmax", "max", "k"),
        ("nuniq", "nunique", "k"),
        ("avg", "mean", "v"),
    ]
    got = f.groupby(["cat", "flag"]).agg(specs)
    odf = orc.from_numpy(data)
    expect = orc.o_groupby(odf, ["cat", "flag"], specs)
    orc.assert_odf_equal(
        orc.frame_to_odf(got.select(["cat", "flag", "total", "cnt", "kmax", "nuniq", "avg"])),
        expect,
    )


def test_groupby_multikey_with_offloaded():
    data = sample_data(400, seed=4)
    f = TensorFrame.from_arrays(data)
    specs = [("n", "size", ""), ("s", "sum", "k")]
    got = f.groupby(["name", "cat"]).agg(specs)
    odf = orc.from_numpy(data)
    expect = orc.o_groupby(odf, ["name", "cat"], specs)
    orc.assert_odf_equal(orc.frame_to_odf(got), expect)


def test_join_inner_against_oracle():
    rng = np.random.default_rng(7)
    left = {
        "id": rng.integers(0, 50, 200),
        "lv": rng.normal(size=200),
        "cat": rng.choice(["x", "y"], 200).astype(object),
    }
    right = {
        "id": np.arange(50),
        "rv": rng.normal(size=50),
        "tag": rng.choice(["a", "b", "c"], 50).astype(object),
    }
    fl = TensorFrame.from_arrays(left)
    fr = TensorFrame.from_arrays(right)
    got = fl.join(fr, on="id")
    expect = orc.o_join(orc.from_numpy(left), orc.from_numpy(right), ["id"], ["id"])
    orc.assert_odf_equal(orc.frame_to_odf(got), expect)


def test_join_many_to_many():
    left = {"k": np.array([1, 1, 2, 3]), "a": np.array([10, 11, 12, 13])}
    right = {"k": np.array([1, 1, 3, 4]), "b": np.array([100, 101, 102, 103])}
    fl, fr = TensorFrame.from_arrays(left), TensorFrame.from_arrays(right)
    got = fl.join(fr, on="k")
    expect = orc.o_join(orc.from_numpy(left), orc.from_numpy(right), ["k"], ["k"])
    orc.assert_odf_equal(orc.frame_to_odf(got), expect)
    # sort-merge gives identical rows
    got_sm = fl.join(fr, on="k", algorithm="sortmerge")
    orc.assert_odf_equal(orc.frame_to_odf(got_sm), expect)


def test_join_left_semi_anti():
    rng = np.random.default_rng(11)
    left = {
        "k": rng.integers(0, 30, 100),
        "lv": rng.integers(0, 5, 100),
        "s": rng.choice(["p", "q", "r"], 100).astype(object),
    }
    right = {"k": rng.choice(np.arange(40), 20, replace=False), "rv": rng.normal(size=20)}
    fl, fr = TensorFrame.from_arrays(left), TensorFrame.from_arrays(right)
    ol, orr = orc.from_numpy(left), orc.from_numpy(right)
    for how in ("left", "semi", "anti"):
        got = fl.join(fr, on="k", how=how)
        expect = orc.o_join(ol, orr, ["k"], ["k"], how=how)
        orc.assert_odf_equal(orc.frame_to_odf(got), expect)


def test_left_join_count_nulls():
    # TPC-H Q13 shape: count(col) must skip nulls from the outer join
    left = {"c": np.array([1, 2, 3, 4])}
    right = {"c": np.array([1, 1, 3]), "o": np.array([10, 11, 12])}
    fl, fr = TensorFrame.from_arrays(left), TensorFrame.from_arrays(right)
    j = fl.join(fr, on="c", how="left")
    got = j.groupby("c").agg([("cnt", "count", "o")]).sort_values("c")
    np.testing.assert_array_equal(got.column("c"), [1, 2, 3, 4])
    np.testing.assert_array_equal(got.column("cnt"), [2, 0, 1, 0])


def test_multikey_string_join():
    rng = np.random.default_rng(13)
    left = {
        "a": rng.choice(["u", "v", "w"], 60).astype(object),
        "b": rng.integers(0, 4, 60),
        "x": rng.normal(size=60),
    }
    right = {
        "a": np.array(["u", "u", "v", "w", "z"], dtype=object),
        "b": np.array([0, 1, 2, 3, 0]),
        "y": np.arange(5) * 1.5,
    }
    fl, fr = TensorFrame.from_arrays(left), TensorFrame.from_arrays(right)
    got = fl.join(fr, on=["a", "b"])
    expect = orc.o_join(orc.from_numpy(left), orc.from_numpy(right), ["a", "b"], ["a", "b"])
    orc.assert_odf_equal(orc.frame_to_odf(got), expect)


def test_sort_values():
    data = sample_data(150, seed=9)
    f = TensorFrame.from_arrays(data)
    got = f.sort_values(["cat", "k"], ascending=[True, False])
    odf = orc.from_numpy(data)
    expect = orc.o_sort(odf, ["cat", "k"], [True, False])
    ga = orc.frame_to_odf(got.select(["cat", "k"]))
    assert ga["cat"] == expect["cat"]
    assert ga["k"] == expect["k"]


def test_head_select_rename():
    f = TensorFrame.from_arrays(sample_data(30))
    assert f.head(7).nrows == 7
    s = f.select(["k", "cat"])
    assert s.column_names == ["k", "cat"]
    r = f.rename({"k": "kk"})
    assert "kk" in r.column_names and "k" not in r.column_names


def test_full_frame_agg():
    data = sample_data(100)
    f = TensorFrame.from_arrays(data)
    out = f.agg([("s", "sum", "v"), ("c", "size", ""), ("m", "mean", "k")])
    assert out["s"] == pytest.approx(float(data["v"].sum()))
    assert out["c"] == 100
    assert out["m"] == pytest.approx(float(data["k"].mean()))


def test_exists_before_udf():
    comments = np.array(
        [
            "nothing interesting here",
            "a special package of requests arrived",
            "requests before special do not count",
            "special but no r-word",
            "very special, many requests!",
        ],
        dtype=object,
    )
    f = TensorFrame.from_arrays({"c": comments}, encode={"c": "dict"})
    hit = f.filter(col("c").str.exists_before("special", "requests"))
    assert hit.nrows == 2
    miss = f.filter(col("c").str.not_exists_before("special", "requests"))
    assert miss.nrows == 3
