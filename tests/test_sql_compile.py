"""Whole-plan compilation tests (ISSUE 6).

Differential: every TPC-H query must produce identical results whether
the optimized plan is dispatched op-by-op or compiled into a single
jitted XLA program (``CONFIG.compiled = 'force'``).  Plan-cache: the
compiled executable is keyed on (parameterized plan structure, dtypes,
bucketed capacities), so a repeated query — or the same query with
different numeric/date literals, or same-bucket input sizes — must
reuse the executable with zero retraces, while dtype or bucket changes
must recompile.  Property tests drive random fixed-capacity
filter/join pipelines against the eager engine.
"""
import numpy as np
import pytest

from repro import sql
from repro.core import oracle as orc
from repro.core.config import CONFIG
from repro.core.frame import TensorFrame
from repro.queries.tpch_sql import SCALAR_SQL, TPCH_SQL, sql_text
from repro.sql import compile as plan_compile

SF = 0.002  # must match the shared tpch_small fixture (conftest.py)

# Same slow lane as test_sql.py: each of these compiles a multi-join
# XLA program (seconds); the fast rest keep tier-1 snappy.
SLOW_SQL = {
    "q2", "q3", "q4", "q5", "q7", "q8", "q9", "q10",
    "q11", "q13", "q15", "q16", "q17", "q18", "q20", "q21",
}

QNAMES = sorted(TPCH_SQL, key=lambda s: int(s[1:]))


@pytest.fixture()
def compiled_mode():
    """force-compile inside the test, restore defaults after.
    (Counter reset comes from conftest's autouse obs.metrics fixture.)"""
    plan_compile.clear_cache()
    CONFIG.compiled = "force"
    try:
        yield plan_compile.STATS
    finally:
        CONFIG.compiled = "auto"
        CONFIG.compiled_min_rows = 1 << 15


def _run_both(text, frames):
    CONFIG.compiled = "off"
    ref = sql.execute(text, frames)
    CONFIG.compiled = "force"
    out = sql.execute(text, frames)
    return out, ref


def _assert_match(qname, out, ref):
    if qname in SCALAR_SQL:
        godf, rodf = orc.frame_to_odf(out), orc.frame_to_odf(ref)
        for name in rodf:
            assert godf[name][0] == pytest.approx(rodf[name][0], rel=1e-8)
        return
    orc.assert_odf_equal(
        orc.frame_to_odf(out), orc.frame_to_odf(ref), sort=True, rtol=1e-8
    )


def _params():
    return [
        pytest.param(q, marks=pytest.mark.slow) if q in SLOW_SQL else q
        for q in QNAMES
    ]


@pytest.mark.parametrize("qname", _params())
def test_tpch_compiled_matches_dispatch(tpch_small, compiled_mode, qname):
    _, frames = tpch_small
    out, ref = _run_both(sql_text(qname, SF), frames)
    _assert_match(qname, out, ref)
    # the whole query really ran as one compiled program
    assert compiled_mode["compiles"] == 1
    assert compiled_mode["fallbacks"] == 0


def test_tpch_auto_mode_compiles_large_inputs(tpch_small, compiled_mode):
    """auto = compile iff the scanned base tables clear the size gate;
    either way the results match dispatch."""
    _, frames = tpch_small
    CONFIG.compiled = "off"
    ref = sql.execute(sql_text("q1", SF), frames)

    CONFIG.compiled = "auto"
    CONFIG.compiled_min_rows = 1 << 60  # unreachable -> dispatch
    out = sql.execute(sql_text("q1", SF), frames)
    _assert_match("q1", out, ref)
    assert compiled_mode["skipped_small"] == 1
    assert compiled_mode["compiles"] == 0

    CONFIG.compiled_min_rows = 0  # everything clears the gate
    out = sql.execute(sql_text("q1", SF), frames)
    _assert_match("q1", out, ref)
    assert compiled_mode["compiles"] == 1


# ----------------------------------------------------------------------
# plan cache keying
# ----------------------------------------------------------------------
def _frame(n, float_b=False, seed=0):
    rng = np.random.default_rng(seed)
    b = rng.random(n) * 10 if float_b else rng.integers(0, 50, n)
    return TensorFrame.from_arrays(
        {"a": rng.integers(0, 9, n), "b": b, "w": rng.random(n)}
    )


Q = "SELECT a, SUM(w) AS s, COUNT(*) AS c FROM t WHERE b > {k} GROUP BY a"


def test_repeat_query_compiles_once(compiled_mode):
    frames = {"t": _frame(100)}
    r1 = sql.execute(Q.format(k=10), frames)
    r2 = sql.execute(Q.format(k=10), frames)
    assert compiled_mode["compiles"] == 1
    assert compiled_mode["misses"] == 1
    assert compiled_mode["hits"] == 1
    orc.assert_odf_equal(
        orc.frame_to_odf(r1), orc.frame_to_odf(r2), sort=True
    )


def test_changed_literal_hits_cache(compiled_mode):
    """The serving case: same plan shape, different parameter — zero
    retraces, the literal travels as a runtime input."""
    frames = {"t": _frame(100)}
    sql.execute(Q.format(k=10), frames)
    for k in (20, 30, 5, 45):
        out = sql.execute(Q.format(k=k), frames)
        CONFIG.compiled = "off"
        ref = sql.execute(Q.format(k=k), frames)
        CONFIG.compiled = "force"
        orc.assert_odf_equal(
            orc.frame_to_odf(out), orc.frame_to_odf(ref), sort=True,
            rtol=1e-8,
        )
    assert compiled_mode["compiles"] == 1
    assert compiled_mode["hits"] == 4


def test_same_bucket_size_hits_cache(compiled_mode):
    # 100 and 120 rows both pad to the 128 bucket -> one executable
    sql.execute(Q.format(k=10), {"t": _frame(100)})
    sql.execute(Q.format(k=10), {"t": _frame(120, seed=1)})
    assert compiled_mode["compiles"] == 1
    assert compiled_mode["hits"] == 1


def test_bucket_change_recompiles(compiled_mode):
    sql.execute(Q.format(k=10), {"t": _frame(100)})
    sql.execute(Q.format(k=10), {"t": _frame(200)})  # bucket 128 -> 256
    assert compiled_mode["compiles"] == 2
    assert compiled_mode["hits"] == 0


def test_dtype_change_recompiles(compiled_mode):
    sql.execute(Q.format(k=10), {"t": _frame(100)})
    sql.execute(Q.format(k=10), {"t": _frame(100, float_b=True)})
    assert compiled_mode["compiles"] == 2
    assert compiled_mode["hits"] == 0


def test_stats_record_per_plan_timings(compiled_mode):
    sql.execute(Q.format(k=10), {"t": _frame(100)})
    sql.execute(Q.format(k=11), {"t": _frame(100)})
    (rec,) = compiled_mode["plans"].values()
    assert rec["calls"] == 2
    assert rec["trace_s"] > 0 and rec["compile_s"] > 0
    assert rec["exec_s"] > 0 and rec["tables"] == ["t"]


def test_unsupported_plan_falls_back_and_is_negative_cached(compiled_mode):
    # many-to-many self join: neither side is unique on the key
    f = TensorFrame.from_arrays(
        {"k": np.array([1, 1, 2, 2]), "v": np.arange(4.0)}
    )
    q = "SELECT a.v AS x, b.v AS y FROM t a, t b WHERE a.k = b.k"
    CONFIG.compiled = "off"
    ref = sql.execute(q, {"t": f})
    CONFIG.compiled = "force"
    out = sql.execute(q, {"t": f})
    orc.assert_odf_equal(
        orc.frame_to_odf(out), orc.frame_to_odf(ref), sort=True
    )
    assert compiled_mode["fallbacks"] == 1
    assert compiled_mode["compiles"] == 0
    sql.execute(q, {"t": f})  # negative-cached: no second trace attempt
    assert compiled_mode["fallbacks"] == 2


def test_prepared_statement_zero_recompiles(compiled_mode):
    from repro.serve.engine import PreparedStatement

    ps = PreparedStatement(Q, {"t": _frame(100)})
    ps.execute(k=10)
    for k in (15, 25, 35):
        ps.execute(k=k)
    assert ps.calls == 4
    assert compiled_mode["compiles"] == 1
    assert compiled_mode["hits"] == 3


# ----------------------------------------------------------------------
# fixed-capacity semantics vs the eager engine
# ----------------------------------------------------------------------
def _pipeline_scope(rng, n):
    t = TensorFrame.from_arrays(
        {
            "k": rng.integers(0, 12, n),
            "x": rng.integers(-20, 20, n),
            "y": rng.random(n) * 100,
        }
    )
    u = TensorFrame.from_arrays(
        {"k": np.arange(12), "w": rng.random(12)}  # unique build side
    )
    return {"t": t, "u": u}


PIPE = (
    "SELECT t.k AS k, SUM(t.y + u.w) AS s, COUNT(*) AS c, MIN(t.x) AS m "
    "FROM t, u WHERE t.k = u.k AND t.x > {thr} GROUP BY t.k"
)


def test_random_filter_join_agg_matches_eager(compiled_mode):
    rng = np.random.default_rng(7)
    for trial in range(8):
        scope = _pipeline_scope(rng, int(rng.integers(1, 90)))
        thr = int(rng.integers(-25, 25))  # sometimes filters out all rows
        out, ref = _run_both(PIPE.format(thr=thr), scope)
        orc.assert_odf_equal(
            orc.frame_to_odf(out), orc.frame_to_odf(ref), sort=True,
            rtol=1e-8,
        )
    assert compiled_mode["fallbacks"] == 0


def test_hypothesis_filter_join_agg_matches_eager(compiled_mode):
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=25, deadline=None)
    @hyp.given(
        xs=st.lists(st.integers(-20, 20), min_size=1, max_size=60),
        thr=st.integers(-25, 25),
        seed=st.integers(0, 2**16),
    )
    def check(xs, thr, seed):
        rng = np.random.default_rng(seed)
        n = len(xs)
        scope = _pipeline_scope(rng, n)
        scope["t"] = TensorFrame.from_arrays(
            {
                "k": rng.integers(0, 12, n),
                "x": np.asarray(xs, dtype=np.int64),
                "y": rng.random(n) * 100,
            }
        )
        out, ref = _run_both(PIPE.format(thr=thr), scope)
        orc.assert_odf_equal(
            orc.frame_to_odf(out), orc.frame_to_odf(ref), sort=True,
            rtol=1e-8,
        )

    check()
