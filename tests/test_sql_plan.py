"""Unit tests for the SQL front-end: tokenizer/parser, planner name
resolution (incl. error messages), each optimizer rule, and explain()
snapshots.  Pure plan-level tests — tiny frames only, no TPC data."""
import numpy as np
import pytest

from repro.core import TensorFrame
from repro import sql
from repro.sql.parser import (
    SqlError,
    SAnd,
    SBetween,
    SBin,
    SCase,
    SCmp,
    SCol,
    SDate,
    SExtract,
    SFunc,
    SIn,
    SLike,
    SLit,
    SNot,
    SOr,
    parse,
)
from repro.sql.optimize import fold_expr
from repro.sql.plan import (
    Aggregate,
    Filter,
    Join,
    Project,
    Scan,
    build_plan,
    format_plan,
)


# ----------------------------------------------------------------------
# fixtures: a tiny catalog/scope
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def scope():
    return {
        "emp": TensorFrame.from_arrays(
            {
                "id": np.arange(6),
                "dept": np.array(list("abacba"), dtype=object),
                "sal": np.array([10.0, 20.0, 30.0, 40.0, 50.0, 60.0]),
                "hired": np.array(
                    ["2020-01-01", "2021-06-01", "2020-03-01", "2022-01-01",
                     "2021-01-01", "2020-06-15"],
                    dtype="datetime64[D]",
                ),
            }
        ),
        "dept": TensorFrame.from_arrays(
            {
                "name": np.array(list("abc"), dtype=object),
                "loc": np.array(["x", "y", "x"], dtype=object),
                "budget": np.array([100.0, 200.0, 300.0]),
            }
        ),
    }


CATALOG = {
    "emp": ["id", "dept", "sal", "hired"],
    "dept": ["name", "loc", "budget"],
}


# ----------------------------------------------------------------------
# tokenizer / parser
# ----------------------------------------------------------------------
def test_parse_basic_select():
    ast = parse("SELECT a, b AS bee FROM t WHERE a > 1 ORDER BY a DESC LIMIT 3")
    assert ast.columns == ((SCol(None, "a"), None), (SCol(None, "b"), "bee"))
    assert ast.from_items[0].table == "t"
    assert ast.where == SCmp(">", SCol(None, "a"), SLit(1))
    assert ast.order_by == ((SCol(None, "a"), False),)
    assert ast.limit == 3


def test_parse_precedence_and_or():
    ast = parse("SELECT a FROM t WHERE a = 1 OR b = 2 AND c = 3")
    # AND binds tighter than OR
    assert isinstance(ast.where, SOr)
    assert isinstance(ast.where.b, SAnd)


def test_parse_arith_precedence():
    ast = parse("SELECT a + b * c AS x FROM t")
    e = ast.columns[0][0]
    assert e == SBin("+", SCol(None, "a"), SBin("*", SCol(None, "b"), SCol(None, "c")))


def test_parse_predicates():
    ast = parse(
        "SELECT a FROM t WHERE a IN (1, 2) AND b NOT LIKE 'x%' "
        "AND c BETWEEN 1 AND 5 AND d IS NOT NULL AND NOT e = 1"
    )
    conj = ast.where
    from repro.sql.parser import split_conjuncts

    parts = split_conjuncts(conj)
    assert parts[0] == SIn(SCol(None, "a"), (SLit(1), SLit(2)))
    assert parts[1] == SLike(SCol(None, "b"), "x%", negated=True)
    assert parts[2] == SBetween(SCol(None, "c"), SLit(1), SLit(5))
    assert parts[3].negated and parts[3].e == SCol(None, "d")
    assert parts[4] == SNot(SCmp("=", SCol(None, "e"), SLit(1)))


def test_parse_case_extract_date():
    ast = parse(
        "SELECT CASE WHEN a = 1 THEN 2 ELSE 0 END AS c, "
        "EXTRACT(YEAR FROM d) AS y, DATE '1994-01-01' AS t0 FROM t"
    )
    c, y, t0 = (e for e, _ in ast.columns)
    assert isinstance(c, SCase) and c.whens[0][1] == SLit(2)
    assert y == SExtract("year", SCol(None, "d"))
    assert t0 == SDate(int(np.datetime64("1994-01-01").astype(np.int64)))


def test_parse_agg_distinct_and_star():
    ast = parse("SELECT COUNT(*) AS n, COUNT(DISTINCT a) AS u, SUM(b) AS s FROM t")
    n, u, s = (e for e, _ in ast.columns)
    assert n.name == "count" and u.distinct and s == SFunc("sum", (SCol(None, "b"),))


def test_parse_joins():
    ast = parse(
        "SELECT a FROM t LEFT JOIN u ON t.k = u.k JOIN v ON v.j = t.j"
    )
    assert ast.joins[0].how == "left" and ast.joins[1].how == "inner"
    assert ast.joins[0].item.table == "u"


def test_parse_string_escapes_and_comments():
    ast = parse("SELECT a FROM t -- trailing comment\nWHERE b = 'it''s'")
    assert ast.where == SCmp("=", SCol(None, "b"), SLit("it's"))


@pytest.mark.parametrize(
    "bad, msg",
    [
        ("SELECT", "expected an expression"),
        ("SELECT a", "expected FROM"),
        ("SELECT a FROM t WHERE", "expected an expression"),
        ("SELECT a FROM t GROUP a", "expected BY"),
        ("SELECT a FROM t LIMIT x", "LIMIT expects an integer"),
        ("SELECT a FROM t; DROP TABLE t", "unexpected character"),
        ("SELECT a FROM t extra garbage (", "trailing input"),
        ("SELECT MAX(*) AS m FROM t", "MAX(*) is not supported"),
        (
            "SELECT a FROM t WHERE d < DATE '1993-10-01' + INTERVAL '3' MONTH",
            "INTERVAL ... MONTH is not supported",
        ),
    ],
)
def test_parse_errors(bad, msg):
    with pytest.raises(SqlError) as ei:
        parse(bad)
    assert msg in str(ei.value)


# ----------------------------------------------------------------------
# planner: resolution + errors
# ----------------------------------------------------------------------
def test_unknown_table_message():
    with pytest.raises(SqlError) as ei:
        build_plan(parse("SELECT x FROM nosuch"), CATALOG)
    assert "unknown table 'nosuch'" in str(ei.value)
    assert "emp" in str(ei.value)  # lists what IS in scope


def test_unknown_column_message():
    with pytest.raises(SqlError) as ei:
        build_plan(parse("SELECT wages FROM emp"), CATALOG)
    assert "unknown column 'wages'" in str(ei.value)


def test_unknown_qualified_column_message():
    with pytest.raises(SqlError) as ei:
        build_plan(parse("SELECT e.wages FROM emp e"), CATALOG)
    msg = str(ei.value)
    assert "unknown column 'wages'" in msg and "'emp'" in msg


def test_ambiguous_column_message():
    cat = {"a": ["k", "v"], "b": ["k", "w"]}
    with pytest.raises(SqlError) as ei:
        build_plan(parse("SELECT k FROM a, b WHERE v = w"), cat)
    assert "ambiguous column 'k'" in str(ei.value)


def test_cross_join_rejected():
    with pytest.raises(SqlError) as ei:
        build_plan(parse("SELECT id FROM emp, dept"), CATALOG)
    assert "cross joins" in str(ei.value)


def test_ungrouped_column_rejected():
    with pytest.raises(SqlError) as ei:
        build_plan(
            parse("SELECT sal, COUNT(*) AS n FROM emp GROUP BY dept"), CATALOG
        )
    assert "must appear in GROUP BY" in str(ei.value)


def test_order_by_must_be_in_select():
    with pytest.raises(SqlError) as ei:
        build_plan(
            parse("SELECT dept FROM emp ORDER BY sal"), CATALOG
        )
    assert "ORDER BY" in str(ei.value)


def test_self_join_aliases_resolve():
    plan = build_plan(
        parse(
            "SELECT e1.id AS a, e2.id AS b FROM emp e1, emp e2 "
            "WHERE e1.dept = e2.dept"
        ),
        CATALOG,
    )
    txt = format_plan(plan)
    assert "emp e1" in txt and "emp e2" in txt
    assert "e1.dept = e2.dept" in txt


# ----------------------------------------------------------------------
# optimizer rule 1: constant folding
# ----------------------------------------------------------------------
def test_fold_arith_and_cmp():
    assert fold_expr(SBin("+", SLit(5), SLit(10))) == SLit(15)
    assert fold_expr(SBin("*", SLit(2.0), SBin("-", SLit(1), SLit(0.5)))) == SLit(1.0)
    assert fold_expr(SCmp("<", SLit(1), SLit(2))) == SLit(True)


def test_fold_date_interval():
    from repro.sql.parser import SInterval

    d0 = SDate(int(np.datetime64("1998-12-01").astype(np.int64)))
    folded = fold_expr(SBin("-", d0, SInterval(90)))
    assert folded == SDate(int(np.datetime64("1998-09-02").astype(np.int64)))
    # date - date -> day count
    d1 = SDate(d0.days - 7)
    assert fold_expr(SBin("-", d0, d1)) == SLit(7)


def test_fold_bool_shortcuts():
    x = SCmp("=", SCol("t", "a"), SLit(1))
    assert fold_expr(SAnd(SLit(True), x)) == x
    assert fold_expr(SAnd(SLit(False), x)) == SLit(False)
    assert fold_expr(SOr(x, SLit(False))) == x
    assert fold_expr(SNot(SLit(False))) == SLit(True)
    # dead CASE branch elimination
    c = SCase(((SCmp("<", SLit(2), SLit(1)), SLit(10)),), SLit(0))
    assert fold_expr(c) == SLit(0)


def test_fold_inside_plan_via_explain(scope):
    txt = sql.explain(
        "SELECT id FROM emp WHERE hired < DATE '2021-01-01' + INTERVAL '31' DAY "
        "AND sal > 10 + 5",
        scope,
    )
    opt = txt.split("== optimized plan ==")[1]
    assert "DATE '2021-02-01'" in opt
    assert "> 15" in opt
    # the naive plan still shows the raw expressions
    naive = txt.split("== optimized plan ==")[0]
    assert "INTERVAL '31' DAY" in naive


# ----------------------------------------------------------------------
# optimizer rule 2: filter pushdown
# ----------------------------------------------------------------------
def _tree(node, kinds=()):
    """Flatten the plan tree into [(depth, node)] for shape asserts."""
    out = []

    def rec(n, d):
        out.append((d, n))
        for attr in ("child", "left", "right"):
            c = getattr(n, attr, None)
            if c is not None:
                rec(c, d + 1)

    rec(node, 0)
    return out


def test_filter_pushdown_below_join(scope):
    plan = sql.plan_query(
        "SELECT id FROM emp, dept WHERE dept = name AND loc = 'x' AND sal > 15",
        scope,
    )
    nodes = _tree(plan)
    # each single-table predicate must now sit directly above its Scan
    filters = [(d, n) for d, n in nodes if isinstance(n, Filter)]
    assert len(filters) == 2
    for _, f in filters:
        assert isinstance(f.child, Scan)
    by_table = {f.child.table: f for _, f in filters}
    assert "sal" in format_plan(by_table["emp"]).splitlines()[0]
    assert "loc" in format_plan(by_table["dept"]).splitlines()[0]


def test_filter_pushdown_keeps_cross_table_pred_above(scope):
    plan = sql.plan_query(
        "SELECT id FROM emp, dept WHERE dept = name AND sal > budget",
        scope,
    )
    # sal > budget references both sides: must stay above the Join
    assert isinstance(plan, Project)
    assert isinstance(plan.child, Filter)
    assert isinstance(plan.child.child, Join)


def test_filter_not_pushed_to_right_of_left_join(scope):
    plan = sql.plan_query(
        "SELECT id FROM emp LEFT JOIN dept ON dept = name WHERE loc = 'x'",
        scope,
    )
    # predicate on the null-extended side must NOT cross the left join
    assert isinstance(plan, Project)
    f = plan.child
    assert isinstance(f, Filter) and isinstance(f.child, Join)
    assert f.child.how == "left"


def test_having_on_group_key_pushed_below_aggregate(scope):
    plan = sql.plan_query(
        "SELECT dept, COUNT(*) AS n FROM emp GROUP BY dept HAVING dept <> 'c'",
        scope,
    )
    # the key-only HAVING conjunct commutes with grouping
    agg = plan.child
    assert isinstance(agg, Aggregate)
    assert isinstance(agg.child, Filter)
    assert isinstance(agg.child.child, Scan)


def test_having_on_aggregate_stays_above(scope):
    plan = sql.plan_query(
        "SELECT dept, SUM(sal) AS s FROM emp GROUP BY dept HAVING SUM(sal) > 50",
        scope,
    )
    assert isinstance(plan, Project)
    assert isinstance(plan.child, Filter)
    assert isinstance(plan.child.child, Aggregate)


# ----------------------------------------------------------------------
# optimizer rule 3: projection pruning
# ----------------------------------------------------------------------
def test_projection_pruning_narrows_scans(scope):
    plan = sql.plan_query(
        "SELECT loc, SUM(sal) AS s FROM emp, dept WHERE dept = name GROUP BY loc",
        scope,
    )
    scans = {n.table: n for _, n in _tree(plan) if isinstance(n, Scan)}
    assert scans["emp"].columns == ("dept", "sal")  # id, hired pruned
    assert scans["dept"].columns == ("name", "loc")  # budget pruned


def test_projection_pruning_drops_unused_aggregates(scope):
    # the derived table computes three aggregates but the outer query
    # reads only one: the others (and the column feeding them) must be
    # pruned from the Aggregate and the Scan
    plan = sql.plan_query(
        "SELECT dd, n FROM (SELECT dept AS dd, COUNT(*) AS n, "
        "SUM(sal) AS s, MIN(sal) AS m FROM emp GROUP BY dept) t",
        scope,
    )
    aggs = [n for _, n in _tree(plan) if isinstance(n, Aggregate)]
    assert len(aggs) == 1
    assert [a[1] for a in aggs[0].aggs] == ["size"]  # SUM/MIN pruned
    scans = {n.table: n for _, n in _tree(plan) if isinstance(n, Scan)}
    assert "sal" not in scans["emp"].columns


def test_unoptimized_scans_keep_all_columns(scope):
    plan = sql.plan_query(
        "SELECT loc, SUM(sal) AS s FROM emp, dept WHERE dept = name GROUP BY loc",
        scope,
        optimized=False,
    )
    scans = {n.table: n for _, n in _tree(plan) if isinstance(n, Scan)}
    assert scans["emp"].columns == ("id", "dept", "sal", "hired")
    assert scans["dept"].columns == ("name", "loc", "budget")


# ----------------------------------------------------------------------
# explain snapshot: stable plan rendering
# ----------------------------------------------------------------------
def test_explain_snapshot(scope):
    txt = sql.explain(
        "SELECT loc, SUM(sal) AS total FROM emp, dept "
        "WHERE dept = name AND sal > 15 GROUP BY loc ORDER BY total DESC",
        scope,
    )
    expected = """\
== logical plan ==
Sort [total DESC]
  Project [loc, total=__agg_0]
    Aggregate keys=[dept.loc] aggs=[__agg_0=SUM(emp.sal)]
      Filter (emp.sal > 15)
        Join inner on [emp.dept = dept.name]
          Scan emp [id, dept, sal, hired]
          Scan dept [name, loc, budget]
== optimized plan ==
Sort [total DESC]
  Project [loc, total=__agg_0]
    Aggregate keys=[dept.loc] aggs=[__agg_0=SUM(emp.sal)]
      Join inner on [emp.dept = dept.name]
        Filter (emp.sal > 15)
          Scan emp [dept, sal]
        Scan dept [name, loc]"""
    assert txt == expected


# ----------------------------------------------------------------------
# execution semantics on tiny frames
# ----------------------------------------------------------------------
def test_execute_order_limit_offsets(scope):
    out = sql.execute(
        "SELECT id, sal FROM emp WHERE sal >= 30 ORDER BY sal DESC LIMIT 2",
        scope,
    )
    assert list(out.column("id")) == [5, 4]
    assert list(out.column("sal")) == [60.0, 50.0]


def test_execute_case_and_extract(scope):
    out = sql.execute(
        "SELECT id, CASE WHEN sal >= 40 THEN 1 ELSE 0 END AS senior, "
        "EXTRACT(YEAR FROM hired) AS y FROM emp ORDER BY id",
        scope,
    )
    assert list(out.column("senior")) == [0, 0, 0, 1, 1, 1]
    assert list(out.column("y")) == [2020, 2021, 2020, 2022, 2021, 2020]


def test_left_join_on_residual_prefilters_right(scope):
    """Extra ON conditions on a LEFT JOIN restrict which right rows
    match — they must NOT become a post-join filter (that would turn
    the join inner and drop NULL-extended rows)."""
    out = sql.execute(
        "SELECT name, COUNT(id) AS n FROM dept "
        "LEFT JOIN emp ON dept = name AND sal >= 30 "
        "GROUP BY name ORDER BY name",
        scope,
    )
    # every dept row survives; only sal>=30 emps count as matches
    # (a: 30+60, b: 50, c: 40)
    assert list(out.column("name")) == ["a", "b", "c"]
    assert list(out.column("n")) == [2, 1, 1]


def test_left_join_on_left_side_residual_rejected(scope):
    with pytest.raises(SqlError) as ei:
        sql.execute(
            "SELECT name FROM dept LEFT JOIN emp ON dept = name AND budget > 100",
            scope,
        )
    assert "LEFT JOIN" in str(ei.value) and "WHERE" in str(ei.value)


def test_execute_left_join_counts(scope):
    out = sql.execute(
        "SELECT name, COUNT(id) AS n FROM dept LEFT JOIN emp ON dept = name "
        "GROUP BY name ORDER BY name",
        scope,
    )
    assert list(out.column("name")) == ["a", "b", "c"]
    assert list(out.column("n")) == [3, 2, 1]


def test_execute_select_star(scope):
    out = sql.execute("SELECT * FROM dept ORDER BY name", scope)
    assert out.column_names == ["name", "loc", "budget"]


def test_execute_global_aggregate(scope):
    out = sql.execute(
        "SELECT COUNT(*) AS n, SUM(sal) AS s, MAX(sal) AS mx, "
        "COUNT(DISTINCT dept) AS u FROM emp",
        scope,
    )
    assert out.nrows == 1
    assert out.column("n")[0] == 6
    assert out.column("s")[0] == 210.0
    assert out.column("mx")[0] == 60.0
    assert out.column("u")[0] == 3


def test_execute_unoptimized_matches_optimized(scope):
    q = (
        "SELECT loc, SUM(sal) AS total FROM emp, dept "
        "WHERE dept = name AND sal > 15 GROUP BY loc ORDER BY total DESC"
    )
    a = sql.execute(q, scope)
    b = sql.execute(q, scope, optimize=False)
    assert list(a.column("loc")) == list(b.column("loc"))
    assert list(a.column("total")) == list(b.column("total"))


def test_scope_accepts_raw_numpy_dicts():
    out = sql.execute(
        "SELECT k, SUM(v) AS s FROM t GROUP BY k ORDER BY k",
        {"t": {"k": np.array([1, 2, 1]), "v": np.array([1.0, 2.0, 3.0])}},
    )
    assert list(out.column("k")) == [1, 2]
    assert list(out.column("s")) == [4.0, 2.0]


def test_queries_scope_registry():
    from repro import queries

    with pytest.raises(KeyError):
        queries.scope("nosuch")
    frames = queries.scope("tpch", sf=0.0005, seed=3)
    assert "lineitem" in frames and frames["lineitem"].nrows > 0


# ----------------------------------------------------------------------
# parser error paths (PR 2 satellites)
# ----------------------------------------------------------------------
@pytest.mark.parametrize(
    "bad, msg",
    [
        ("SELECT 'unterminated FROM t", "unexpected character"),
        ("SELECT a FROM t WHERE b = 'still open", "unexpected character"),
        ("SELECT id FROM emp WHERE sal IN (", "expected an expression"),
        ("SELECT id FROM emp WHERE EXISTS sal", "expected '('"),
        ("SELECT a FROM (SELECT b FROM t)", "derived-table alias"),
    ],
)
def test_parse_error_paths(bad, msg):
    with pytest.raises(SqlError) as ei:
        parse(bad)
    assert msg in str(ei.value)


def test_unknown_aggregate_name_rejected_at_plan_time():
    with pytest.raises(SqlError) as ei:
        build_plan(
            parse("SELECT MEDIAN(sal) AS m FROM emp GROUP BY dept"), CATALOG
        )
    assert "unknown function 'MEDIAN'" in str(ei.value)
    assert "SUM" in str(ei.value)  # names what IS supported


def test_distinct_outside_count_rejected():
    with pytest.raises(SqlError) as ei:
        build_plan(
            parse("SELECT SUM(DISTINCT sal) AS s FROM emp GROUP BY dept"),
            CATALOG,
        )
    assert "DISTINCT is only supported inside COUNT" in str(ei.value)


# ----------------------------------------------------------------------
# round trip: rendered expressions/statements re-parse to equal ASTs
# ----------------------------------------------------------------------
ROUNDTRIP_QUERIES = [
    "SELECT a, b AS bee FROM t WHERE a > 1 ORDER BY a DESC LIMIT 3",
    "SELECT a FROM t WHERE a IN (1, 2) AND b NOT LIKE 'x%' "
    "AND c BETWEEN 1 AND 5 AND d IS NOT NULL AND NOT e = 1",
    "SELECT CASE WHEN a = 1 THEN 2 ELSE 0 END AS c, "
    "EXTRACT(YEAR FROM d) AS y, DATE '1994-01-01' AS t0 FROM t",
    "SELECT COUNT(*) AS n, COUNT(DISTINCT a) AS u, SUM(b + 1) AS s FROM t "
    "GROUP BY g HAVING COUNT(*) > 2",
    "SELECT DISTINCT a FROM t WHERE s = 'it''s' OR a * 2 < b / 3",
    "SELECT a FROM t WHERE d < DATE '1995-06-01' - INTERVAL '90' DAY",
    "SELECT a FROM t LEFT JOIN u ON t.k = u.k WHERE u.v IS NULL",
    "SELECT a FROM t WHERE EXISTS (SELECT * FROM u WHERE u.k = t.a)",
    "SELECT a FROM t WHERE b NOT IN (SELECT k FROM u WHERE w > 0)",
    "SELECT a FROM t WHERE c > (SELECT MAX(k) FROM u)",
    "SELECT x, SUM(v) AS s FROM (SELECT a AS x, b AS v FROM t) d GROUP BY x",
    "SELECT SUBSTRING(p, 1, 2) AS cc FROM t WHERE CASE WHEN a = 1 THEN 2 END = 2",
]


@pytest.mark.parametrize("q", ROUNDTRIP_QUERIES)
def test_format_select_round_trips(q):
    from repro.sql.parser import format_select

    ast = parse(q)
    rendered = format_select(ast)
    assert parse(rendered) == ast, rendered


def test_format_expr_round_trips_where_clause():
    from repro.sql.parser import format_expr

    ast = parse(
        "SELECT a FROM t WHERE a IN (1, 2) AND b LIKE 'x%' AND "
        "c BETWEEN 1 AND 5 AND NOT d = DATE '1994-01-01' AND e + 1 > 2 * f"
    )
    rendered = format_expr(ast.where)
    reparsed = parse(f"SELECT a FROM t WHERE {rendered}").where
    assert reparsed == ast.where


# ----------------------------------------------------------------------
# subqueries: planning, decorrelation, execution (tiny frames)
# ----------------------------------------------------------------------
def _threeway(q, scope_frames_):
    """engine result == oracle interpretation of the naive plan."""
    from repro.sql.oracle_backend import execute_oracle

    got = sql.execute(q, scope_frames_)
    godf = orc_frame_to_odf(got)
    naive = sql.plan_query(q, scope_frames_, optimized=False)
    tables = {
        name: {c: np.asarray(f.column(c)) for c in f.column_names}
        for name, f in scope_frames_.items()
    }
    ora = execute_oracle(naive, tables)
    from repro.core import oracle as orc

    orc.assert_odf_equal(godf, ora, sort=True, rtol=1e-9)
    return godf


def orc_frame_to_odf(f):
    from repro.core import oracle as orc

    return orc.frame_to_odf(f)


def test_exists_decorrelates_to_semi_join(scope):
    q = (
        "SELECT id FROM emp e WHERE EXISTS "
        "(SELECT * FROM dept d WHERE d.name = e.dept AND d.loc = 'x') "
        "ORDER BY id"
    )
    assert _threeway(q, scope) == {"id": [0, 2, 3, 5]}
    opt = sql.explain(q, scope).split("== optimized plan ==")[1]
    assert "Join semi on [e.dept = d.name]" in opt
    assert "EXISTS" not in opt


def test_not_exists_decorrelates_to_anti_join(scope):
    q = (
        "SELECT id FROM emp e WHERE NOT EXISTS "
        "(SELECT * FROM dept d WHERE d.name = e.dept AND d.loc = 'x') "
        "ORDER BY id"
    )
    assert _threeway(q, scope) == {"id": [1, 4]}
    opt = sql.explain(q, scope).split("== optimized plan ==")[1]
    assert "Join anti on [e.dept = d.name]" in opt


def test_in_subquery_decorrelates_to_semi_join(scope):
    q = (
        "SELECT id FROM emp e WHERE dept IN "
        "(SELECT name FROM dept d WHERE budget > 150) ORDER BY id"
    )
    assert _threeway(q, scope) == {"id": [1, 3, 4]}
    opt = sql.explain(q, scope).split("== optimized plan ==")[1]
    assert "Join semi on [e.dept = name]" in opt


def test_not_in_subquery_decorrelates_to_anti_join(scope):
    q = (
        "SELECT id FROM emp e WHERE dept NOT IN "
        "(SELECT name FROM dept d WHERE budget > 150) ORDER BY id"
    )
    assert _threeway(q, scope) == {"id": [0, 2, 5]}
    opt = sql.explain(q, scope).split("== optimized plan ==")[1]
    assert "Join anti on" in opt


def test_uncorrelated_scalar_attaches_constant(scope):
    q = (
        "SELECT id FROM emp e WHERE sal > (SELECT AVG(sal) FROM emp e2) "
        "ORDER BY id"
    )
    assert _threeway(q, scope) == {"id": [3, 4, 5]}
    opt = sql.explain(q, scope).split("== optimized plan ==")[1]
    assert "AttachScalar" in opt


def test_correlated_scalar_becomes_groupby_join(scope):
    q = (
        "SELECT id FROM emp e, dept d WHERE dept = name AND "
        "sal > (SELECT AVG(e2.sal) FROM emp e2 WHERE e2.dept = d.name) "
        "ORDER BY id"
    )
    assert _threeway(q, scope) == {"id": [4, 5]}
    opt = sql.explain(q, scope).split("== optimized plan ==")[1]
    assert "Aggregate keys=[e2.dept]" in opt
    assert "Join inner on [d.name = e2.dept]" in opt


def test_exists_with_neq_residual(scope):
    # the q21 shape: another emp in the same dept with a different id
    q = (
        "SELECT id FROM emp e1 WHERE EXISTS (SELECT * FROM emp e2 "
        "WHERE e2.dept = e1.dept AND e2.id <> e1.id) ORDER BY id"
    )
    # depts a (ids 0,2,5) and b (1,4) have >= 2 members; c (3) does not
    assert _threeway(q, scope) == {"id": [0, 1, 2, 4, 5]}
    opt = sql.explain(q, scope).split("== optimized plan ==")[1]
    assert "Join semi on" in opt and "Join anti on" in opt
    assert "NUNIQUE" in opt
    # the inner relation feeds BOTH the semi join and the grouped anti
    # join through one Shared node: it is scanned once, not twice
    assert "Shared #1" in opt
    assert "(reused, emitted once)" in opt
    assert opt.count("Scan emp e2") == 1


def test_derived_table_in_from(scope):
    q = (
        "SELECT loc, SUM(n) AS total FROM "
        "(SELECT dept AS dd, COUNT(*) AS n FROM emp GROUP BY dept) t, dept "
        "WHERE dd = name GROUP BY loc ORDER BY loc"
    )
    assert _threeway(q, scope) == {"loc": ["x", "y"], "total": [4, 2]}


def test_select_distinct(scope):
    q = "SELECT DISTINCT dept FROM emp ORDER BY dept"
    assert _threeway(q, scope) == {"dept": ["a", "b", "c"]}


def test_correlated_count_rejected(scope):
    with pytest.raises(SqlError) as ei:
        sql.execute(
            "SELECT id FROM emp e WHERE 1 < "
            "(SELECT COUNT(*) FROM emp e2 WHERE e2.dept = e.dept)",
            scope,
        )
    assert "COUNT" in str(ei.value)


def test_alias_shadowing_rejected(scope):
    with pytest.raises(SqlError) as ei:
        sql.execute(
            "SELECT id FROM emp WHERE EXISTS "
            "(SELECT * FROM emp WHERE sal > 10)",
            scope,
        )
    assert "shadows" in str(ei.value)


def test_subquery_in_or_rejected(scope):
    with pytest.raises(SqlError) as ei:
        sql.execute(
            "SELECT id FROM emp e WHERE sal > 50 OR EXISTS "
            "(SELECT * FROM dept d WHERE d.name = e.dept)",
            scope,
        )
    assert "top-level AND conjuncts" in str(ei.value)


def test_scalar_subquery_multiple_columns_rejected(scope):
    with pytest.raises(SqlError) as ei:
        sql.execute(
            "SELECT id FROM emp e WHERE sal > (SELECT sal, id FROM emp e2)",
            scope,
        )
    assert "exactly one column" in str(ei.value)


def test_limit_inside_uncorrelated_subquery_executes(scope):
    # the kept Limit(Sort(...)) subtree runs directly: dept 'a' sorts first
    out = sql.execute(
        "SELECT id FROM emp e WHERE dept IN "
        "(SELECT name FROM dept d ORDER BY name LIMIT 1)",
        scope,
    )
    assert list(out.column("id")) == [0, 2, 5]
    # scalar subquery idiom: ORDER BY ... LIMIT 1 == MAX
    top = sql.execute(
        "SELECT id FROM emp e WHERE sal >= "
        "(SELECT sal FROM emp e2 ORDER BY sal DESC LIMIT 1)",
        scope,
    )
    assert list(top.column("id")) == [5]


def test_limit_inside_correlated_subquery_rejected(scope):
    with pytest.raises(SqlError) as ei:
        sql.execute(
            "SELECT id FROM emp e WHERE sal > "
            "(SELECT budget FROM dept d WHERE d.name = e.dept "
            "ORDER BY budget LIMIT 1)",
            scope,
        )
    assert "LIMIT inside correlated" in str(ei.value)


def test_limit_under_sort_ties_is_deterministic(scope):
    """Stable tiebreak sort: equal keys keep input order, so LIMIT picks
    the same rows as the (stable, row-at-a-time) oracle backend."""
    from repro.sql.oracle_backend import execute_oracle

    tables = {
        "t": {
            "k": np.array([2, 1, 2, 1, 1, 2]),
            "v": np.arange(6),
        }
    }
    scope2 = {"t": TensorFrame.from_arrays(tables["t"])}
    q = "SELECT k, v FROM t ORDER BY k LIMIT 4"
    got = sql.execute(q, scope2)
    naive = sql.plan_query(q, scope2, optimized=False)
    ora = execute_oracle(naive, tables)
    assert list(got.column("k")) == list(ora["k"])
    assert list(got.column("v")) == list(ora["v"])
    # ascending ties keep original positions; DESC negation preserves it
    got_d = sql.execute("SELECT k, v FROM t ORDER BY k DESC LIMIT 3", scope2)
    assert list(got_d.column("v")) == [0, 2, 5]


def test_distinct_inside_scalar_subquery_rejected(scope):
    with pytest.raises(SqlError) as ei:
        sql.execute(
            "SELECT id FROM emp e WHERE sal > (SELECT DISTINCT sal FROM emp e2)",
            scope,
        )
    assert "DISTINCT inside scalar subqueries" in str(ei.value)


def test_distinct_inside_in_subquery_is_harmless(scope):
    q = (
        "SELECT id FROM emp e WHERE dept IN "
        "(SELECT DISTINCT name FROM dept d WHERE budget > 150) ORDER BY id"
    )
    assert _threeway(q, scope) == {"id": [1, 3, 4]}


def test_empty_scalar_subquery_is_null_like(scope):
    # zero-row scalar subquery -> NULL: every comparison is false, on
    # both the engine (NaN constant) and the oracle (None)
    q = (
        "SELECT id FROM emp e WHERE sal > "
        "(SELECT e2.sal FROM emp e2 WHERE e2.sal > 1000) ORDER BY id"
    )
    assert _threeway(q, scope) == {"id": []}


def test_string_scalar_subquery(scope):
    q = (
        "SELECT id FROM emp e WHERE dept = "
        "(SELECT d.name FROM dept d WHERE d.budget = 200) ORDER BY id"
    )
    assert _threeway(q, scope) == {"id": [1, 4]}


def test_uncorrelated_empty_sum_is_zero(scope):
    # pandas-style SUM() over empty = 0.0, consistently on both legs
    q = (
        "SELECT id FROM emp e WHERE sal > "
        "(SELECT SUM(e2.sal) FROM emp e2 WHERE e2.sal > 1000) ORDER BY id"
    )
    assert _threeway(q, scope) == {"id": [0, 1, 2, 3, 4, 5]}


def test_format_select_round_trips_joined_derived_table():
    from repro.sql.parser import format_select

    ast = parse("SELECT a FROM t INNER JOIN (SELECT k FROM u) d ON t.a = d.k")
    assert parse(format_select(ast)) == ast


def test_uncorrelated_scalar_subquery_in_select_list(scope):
    q = (
        "SELECT id, sal - (SELECT AVG(e2.sal) FROM emp e2) AS delta "
        "FROM emp e ORDER BY id"
    )
    got = _threeway(q, scope)
    assert got["id"] == [0, 1, 2, 3, 4, 5]
    assert got["delta"][0] == pytest.approx(10.0 - 35.0)


def test_correlated_scalar_in_select_list_rejected(scope):
    with pytest.raises(SqlError) as ei:
        sql.execute(
            "SELECT id, (SELECT AVG(e2.sal) FROM emp e2 "
            "WHERE e2.dept = e.dept) AS davg FROM emp e",
            scope,
        )
    assert "SELECT list" in str(ei.value)


def test_not_in_with_null_producing_subquery_uses_join_semantics(scope):
    # the derived left join NULL-extends loc for depts without... here:
    # emp rows whose dept has no entry in the filtered dept list yield
    # NULLs in the subquery output; both legs must agree on join
    # semantics (NULLs never match) rather than three-valued NOT IN
    q = (
        "SELECT name FROM dept WHERE name NOT IN "
        "(SELECT dd FROM (SELECT d2.name AS nm, e2.dept AS dd "
        " FROM dept d2 LEFT JOIN emp e2 ON d2.name = e2.dept "
        "   AND e2.sal > 45) j) "
        "ORDER BY name"
    )
    # only a (sal 60) and b (50) have > 45 emps; c NULL-extends, so the
    # list is [a, b, NULL].  Join semantics keep c (three-valued SQL
    # would return no rows at all).
    assert _threeway(q, scope) == {"name": ["c"]}


# ----------------------------------------------------------------------
# optimizer: filter pushdown through (derived-table) Projects
# ----------------------------------------------------------------------
def test_filter_pushes_through_derived_table_project(scope):
    plan = sql.plan_query(
        "SELECT name FROM (SELECT name, budget FROM dept) d "
        "WHERE d.budget > 150",
        scope,
    )
    filters = [n for _, n in _tree(plan) if isinstance(n, Filter)]
    assert len(filters) == 1
    # the predicate re-wrote to the defining expression and sank to the
    # scan instead of re-scanning the whole derived output
    assert isinstance(filters[0].child, Scan)
    assert "budget" in format_plan(filters[0]).splitlines()[0]


def test_filter_on_computed_derived_output_pushes_and_rewrites(scope):
    plan = sql.plan_query(
        "SELECT twice FROM (SELECT budget * 2 AS twice, loc FROM dept) d "
        "WHERE d.twice > 300",
        scope,
    )
    filters = [n for _, n in _tree(plan) if isinstance(n, Filter)]
    assert len(filters) == 1 and isinstance(filters[0].child, Scan)
    # output reference replaced by its defining expression
    assert "budget * 2" in format_plan(filters[0]).replace("(", "").replace(")", "")
    got = sql.execute(
        "SELECT twice FROM (SELECT budget * 2 AS twice, loc FROM dept) d "
        "WHERE d.twice > 300 ORDER BY twice",
        scope,
    )
    assert list(got.column("twice")) == [400.0, 600.0]


def test_filter_on_aggregate_derived_output_stops_at_aggregate(scope):
    # q15's shape: the derived output is an aggregate result — the
    # filter passes the qualifying Projects but must stay above the
    # Aggregate node
    plan = sql.plan_query(
        "SELECT loc2 FROM (SELECT loc AS loc2, SUM(budget) AS tot "
        "FROM dept GROUP BY loc) d WHERE d.tot > 250",
        scope,
    )
    filters = [n for _, n in _tree(plan) if isinstance(n, Filter)]
    assert len(filters) == 1
    assert isinstance(filters[0].child, Aggregate)


# ----------------------------------------------------------------------
# optimizer: projection narrowing (semi-join/derived build inputs)
# ----------------------------------------------------------------------
def test_derived_join_input_project_narrowed_to_required(scope):
    plan = sql.plan_query(
        "SELECT e.id FROM emp e, (SELECT name, loc, budget FROM dept) d "
        "WHERE e.dept = d.name",
        scope,
    )
    projects = [n for _, n in _tree(plan) if isinstance(n, Project)]
    derived = [
        p for p in projects
        if any(name.startswith("d.") for name, _ in p.outputs)
    ]
    assert derived, format_plan(plan)
    # only the join key survives; loc/budget are gone before the build
    assert all(
        [name for name, _ in p.outputs] == ["d.name"] for p in derived
    ), format_plan(plan)
    scans = {n.table: n for _, n in _tree(plan) if isinstance(n, Scan)}
    assert scans["dept"].columns == ("name",)


def test_decorrelated_in_subquery_right_side_narrowed(scope):
    plan = sql.plan_query(
        "SELECT id FROM emp WHERE dept IN "
        "(SELECT name FROM dept WHERE budget > 150)",
        scope,
    )
    joins = [n for _, n in _tree(plan) if isinstance(n, Join)]
    assert len(joins) == 1 and joins[0].how == "semi"
    scans = {n.table: n for _, n in _tree(plan) if isinstance(n, Scan)}
    # the semi-join build side loads only its key + filter columns
    assert scans["dept"].columns == ("name", "budget")
    assert scans["emp"].columns == ("id", "dept")
