"""x64 policy per test domain.

The relational engine (repro.core) enables jax_enable_x64 at import —
in production they live in separate processes (dataframe engine vs
model/launch), but the test suite shares one.  This autouse fixture
pins the flag per test file: dataframe tests run with x64 (exact int64
keys), model/kernel/runtime tests run with JAX defaults, matching their
deployment processes.
"""
import jax
import pytest

_X64_PREFIXES = (
    "test_core", "test_tpch", "test_tpcds", "test_sql", "test_dist",
    "test_store", "test_io", "test_serve", "test_obs",
    "test_resilience", "test_chaos",
)


def pytest_configure(config):
    # Registered here as well as pyproject.toml so a bare `pytest
    # tests/` from any rootdir still knows the marker.
    config.addinivalue_line(
        "markers",
        "slow: full-scale TPC-H/TPC-DS sweeps and other long-running "
        "tests (deselected by default; run with -m 'slow or not slow')",
    )


@pytest.fixture(scope="session")
def tpch_small():
    """Shared SF=0.002 TPC-H data: (raw tables, TensorFrames).

    Session-scoped so test_tpch_queries and test_sql build the frames
    once.  Session fixtures instantiate BEFORE the module-scoped
    _x64_policy fixture, so enable x64 here explicitly — the frames
    carry exact int64 keys.  Only x64 modules may request it."""
    jax.config.update("jax_enable_x64", True)
    from repro.data import tpch

    tables = tpch.generate(sf=0.002, seed=42)
    frames = tpch.as_frames(tables)
    return tables, frames


@pytest.fixture(autouse=True, scope="module")
def _x64_policy(request):
    # module-scoped so it runs BEFORE other module-scoped fixtures
    # (frames built in a module fixture must see the right flag)
    path = getattr(request.node, "path", None) or request.node.fspath
    fname = getattr(path, "name", None) or path.basename
    want = any(str(fname).startswith(p) for p in _X64_PREFIXES)
    jax.config.update("jax_enable_x64", want)
    yield


@pytest.fixture(autouse=True)
def _obs_metrics_reset():
    """Zero every registered metrics group (and the span rings) before
    each test: engine counters are process-wide, so without this a
    test's assertions would see other tests' increments.  Replaces the
    per-suite manual ``reset_stats()`` calls — the legacy STATS objects
    stay usable as aliases because the registry resets through the same
    underlying objects.  Autouse function fixtures run after session/
    module fixtures and before non-autouse function fixtures, so data
    built in shared fixtures never leaks counter state into tests."""
    from repro import obs

    obs.metrics.reset()
    obs.clear_trace()
    yield
