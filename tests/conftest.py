"""x64 policy per test domain.

The relational engine (repro.core) enables jax_enable_x64 at import —
in production they live in separate processes (dataframe engine vs
model/launch), but the test suite shares one.  This autouse fixture
pins the flag per test file: dataframe tests run with x64 (exact int64
keys), model/kernel/runtime tests run with JAX defaults, matching their
deployment processes.
"""
import jax
import pytest

_X64_PREFIXES = ("test_core", "test_tpch", "test_tpcds")


@pytest.fixture(autouse=True, scope="module")
def _x64_policy(request):
    # module-scoped so it runs BEFORE other module-scoped fixtures
    # (frames built in a module fixture must see the right flag)
    path = getattr(request.node, "path", None) or request.node.fspath
    fname = getattr(path, "name", None) or path.basename
    want = any(str(fname).startswith(p) for p in _X64_PREFIXES)
    jax.config.update("jax_enable_x64", want)
    yield
