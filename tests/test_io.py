"""core.io CSV loader: explicit dtype hints beat sniffing, null tokens
parse as nulls, and all-null columns round-trip (ISSUE 4 satellite —
extends the tests/test_core_encoding.py null cases through the io
path)."""
import numpy as np
import pytest

from repro.core import TensorFrame
from repro.core import io as tio


def _write(tmp_path, text):
    p = str(tmp_path / "t.csv")
    with open(p, "w") as f:
        f.write(text)
    return p


# ----------------------------------------------------------------------
# hints are authoritative
# ----------------------------------------------------------------------
def test_str_hint_beats_numeric_sniffing(tmp_path):
    p = _write(tmp_path, "code\n001\n002\n010\n")
    out = tio.read_csv_arrays(p, sep=",", dtypes={"code": "str"})
    assert list(out["code"]) == ["001", "002", "010"]  # not 1, 2, 10


def test_float_hint_beats_int_sniffing(tmp_path):
    p = _write(tmp_path, "x\n1\n2\n3\n")
    out = tio.read_csv_arrays(p, sep=",", dtypes={"x": "float"})
    assert out["x"].dtype == np.float64
    np.testing.assert_array_equal(out["x"], [1.0, 2.0, 3.0])
    # without the hint the same column sniffs to int64
    assert tio.read_csv_arrays(p, sep=",")["x"].dtype == np.int64


def test_unknown_hint_raises_instead_of_sniffing(tmp_path):
    p = _write(tmp_path, "x\n1\n")
    with pytest.raises(ValueError) as ei:
        tio.read_csv_arrays(p, sep=",", dtypes={"x": "int32"})
    assert "int32" in str(ei.value)


def test_int_hint_rejects_malformed_cells(tmp_path):
    p = _write(tmp_path, "x\n1\n2.5\n")
    with pytest.raises(ValueError):
        tio.read_csv_arrays(p, sep=",", dtypes={"x": "int"})


# ----------------------------------------------------------------------
# nulls
# ----------------------------------------------------------------------
def test_null_tokens_promote_int_to_float_nan(tmp_path):
    p = _write(tmp_path, "a|b\n1||\n2|3\n".replace("||", "|"))
    p = _write(tmp_path, "a|b\n1|\n2|3\n")
    out = tio.read_csv_arrays(p)
    assert out["a"].dtype == np.int64
    assert out["b"].dtype == np.float64
    assert np.isnan(out["b"][0]) and out["b"][1] == 3.0
    # same with an explicit int hint: nulls still force the promotion
    out = tio.read_csv_arrays(p, dtypes={"b": "int"})
    assert out["b"].dtype == np.float64 and np.isnan(out["b"][0])


def test_date_nulls_parse_as_nat(tmp_path):
    p = _write(tmp_path, "d\n1994-01-01\nNone\n1995-06-01\n")
    out = tio.read_csv_arrays(p, sep=",", dtypes={"d": "date"})
    assert np.isnat(out["d"][1])
    assert out["d"][0] == np.datetime64("1994-01-01")


def test_all_null_column_round_trips_through_io(tmp_path):
    """The test_core_encoding left-join case, through write_csv ->
    read_csv: an all-null measure survives as NaN floats and keeps
    aggregating as COUNT=0 / SUM=0."""
    left = TensorFrame.from_arrays(
        {"k": np.array(["a", "b", "c"], dtype=object),
         "v": np.array([1.0, 2.0, 3.0])}
    )
    right = TensorFrame.from_arrays(
        {"k": np.array(["x", "y"], dtype=object), "w": np.array([10.0, 20.0])}
    )
    joined = left.join(right, on="k", how="left")
    p = str(tmp_path / "j.csv")
    tio.write_csv(p, {n: joined.column(n) for n in ("k", "v", "w")})
    back = tio.read_csv(p)
    w = back.column("w")
    assert w.dtype == np.float64 and np.isnan(w.astype(float)).all()
    agg = back.groupby("k").agg([("n", "count", "w"), ("s", "sum", "w")])
    assert list(agg.column("n")) == [0, 0, 0]
    assert list(agg.column("s")) == [0.0, 0.0, 0.0]


def test_all_null_without_hint_is_nan_floats(tmp_path):
    p = _write(tmp_path, "x\nNone\nNone\n")
    out = tio.read_csv_arrays(p, sep=",")
    assert out["x"].dtype == np.float64 and np.isnan(out["x"]).all()


def test_string_columns_keep_null_tokens_verbatim(tmp_path):
    # a words column that happens to contain 'None' must not be nulled
    p = _write(tmp_path, "s\nNone\nhello\n")
    out = tio.read_csv_arrays(p, sep=",", dtypes={"s": "str"})
    assert list(out["s"]) == ["None", "hello"]
    # sniffed path: mixed non-parsing column stays verbatim strings too
    out = tio.read_csv_arrays(p, sep=",")
    assert list(out["s"]) == ["None", "hello"]
