"""Per-architecture smoke tests: reduced config, one forward/train step
on CPU, asserting output shapes and finiteness; plus decode-path
consistency and chunked-scan equivalence checks."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get
from repro.models import lm
from repro.models.config import reduced

# Big/exotic configs cost several seconds of CPU compile each; the
# default (tier-1) run keeps one representative per family and the
# rest go to the slow lane (`-m "slow or not slow"`).
HEAVY_ARCHS = {
    "dbrx-132b",
    "zamba2-2.7b",
    "llama-3.2-vision-90b",
    "rwkv6-7b",
    "kimi-k2-1t-a32b",
    "command-r-35b",
}


def _arch_params(archs=None):
    return [
        a if a not in HEAVY_ARCHS else pytest.param(a, marks=pytest.mark.slow)
        for a in (archs or ARCHS)
    ]


def make_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {}
    if cfg.embed_inputs:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    else:
        batch["embeddings"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)) * 0.02, jnp.float32
        )
    if cfg.cross_attn_every:
        batch["img_embed"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_img_tokens, cfg.d_model)) * 0.02, jnp.float32
        )
    batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", _arch_params())
def test_forward_and_grad(arch):
    cfg = reduced(get(arch))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    logits = lm.forward(cfg, params, batch)
    B = batch["labels"].shape[0]
    S = batch["labels"].shape[1]
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    # one gradient step
    loss, grads = jax.value_and_grad(lambda p: lm.loss_fn(cfg, p, batch)[0])(params)
    assert bool(jnp.isfinite(loss))
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves), f"{arch}: non-finite grads"
    assert any(float(jnp.abs(g).max()) > 0 for g in leaves), f"{arch}: all-zero grads"


@pytest.mark.parametrize("arch", _arch_params())
def test_decode_step_shapes(arch):
    cfg = reduced(get(arch))
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    B, Smax = 2, 16
    state = lm.init_decode_state(cfg, B, Smax)
    rng = np.random.default_rng(1)
    if cfg.embed_inputs:
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)), jnp.int32)}
    else:
        batch = {"embeddings": jnp.asarray(rng.normal(size=(B, 1, cfg.d_model)) * 0.02, jnp.float32)}
    logits, state2 = lm.decode_step(cfg, params, state, batch)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert int(state2["pos"]) == 1
    # second step consumes the updated cache
    logits3, state3 = lm.decode_step(cfg, params, state2, batch)
    assert bool(jnp.isfinite(logits3).all())
    assert int(state3["pos"]) == 2


@pytest.mark.parametrize(
    "arch",
    [
        "phi3-mini-3.8b",
        pytest.param("qwen3-14b", marks=pytest.mark.slow),
        pytest.param("musicgen-medium", marks=pytest.mark.slow),
    ],
)
def test_decode_matches_forward(arch):
    """Greedy decode logits must match teacher-forced forward logits."""
    cfg = reduced(get(arch))
    params = lm.init_params(cfg, jax.random.PRNGKey(2))
    B, S = 2, 8
    batch = make_batch(cfg, B=B, S=S, seed=3)
    full = lm.forward(cfg, params, batch)  # (B,S,V)
    state = lm.init_decode_state(cfg, B, S)
    outs = []
    for t in range(S):
        if cfg.embed_inputs:
            step = {"tokens": batch["tokens"][:, t : t + 1]}
        else:
            step = {"embeddings": batch["embeddings"][:, t : t + 1]}
        lg, state = lm.decode_step(cfg, params, state, step)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), rtol=2e-4, atol=2e-4)


@pytest.mark.slow
def test_rwkv_decode_matches_forward():
    cfg = reduced(get("rwkv6-7b"))
    params = lm.init_params(cfg, jax.random.PRNGKey(4))
    B, S = 1, 8
    batch = make_batch(cfg, B=B, S=S, seed=5)
    full = lm.forward(cfg, params, batch)
    state = lm.init_decode_state(cfg, B, S)
    outs = []
    for t in range(S):
        lg, state = lm.decode_step(cfg, params, state, {"tokens": batch["tokens"][:, t : t + 1]})
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), rtol=5e-4, atol=5e-4)


@pytest.mark.slow
def test_zamba_decode_matches_forward():
    cfg = reduced(get("zamba2-2.7b"))
    params = lm.init_params(cfg, jax.random.PRNGKey(6))
    B, S = 1, 8
    batch = make_batch(cfg, B=B, S=S, seed=7)
    full = lm.forward(cfg, params, batch)
    state = lm.init_decode_state(cfg, B, S)
    outs = []
    for t in range(S):
        lg, state = lm.decode_step(cfg, params, state, {"tokens": batch["tokens"][:, t : t + 1]})
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), rtol=5e-4, atol=5e-4)


def test_wkv6_chunked_matches_ref():
    from repro.kernels import ref
    from repro.models.rwkv import wkv6_chunked

    rng = np.random.default_rng(11)
    B, H, T, D = 2, 3, 64, 16
    r = jnp.asarray(rng.normal(size=(B, H, T, D)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, H, T, D)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, H, T, D)) * 0.5, jnp.float32)
    w = jnp.asarray(rng.uniform(0.85, 0.999, size=(B, H, T, D)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, D)) * 0.1, jnp.float32)
    y_c, s_c = wkv6_chunked(r, k, v, w, u, chunk=16)
    y_r, s_r = ref.wkv6_reference(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_r), rtol=2e-3, atol=2e-3)


def test_ssd_chunked_matches_naive_scan():
    from repro.models.mamba import ssd_chunked

    rng = np.random.default_rng(13)
    B, T, H, P, N = 2, 32, 3, 8, 4
    xbar = jnp.asarray(rng.normal(size=(B, T, H, P)) * 0.5, jnp.float32)
    loga = jnp.asarray(-rng.uniform(0.01, 0.4, size=(B, T, H)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, T, N)) * 0.5, jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, T, N)) * 0.5, jnp.float32)

    def naive(xbar, loga, Bm, Cm):
        h = np.zeros((B, H, P, N))
        ys = np.zeros((B, T, H, P))
        a = np.exp(np.asarray(loga))
        for t in range(T):
            for b in range(B):
                h[b] = a[b, t][:, None, None] * h[b] + np.einsum(
                    "hp,n->hpn", np.asarray(xbar)[b, t], np.asarray(Bm)[b, t]
                )
                ys[b, t] = np.einsum("hpn,n->hp", h[b], np.asarray(Cm)[b, t])
        return ys, h

    y_c, h_c = ssd_chunked(xbar, loga, Bm, Cm, chunk=8)
    y_n, h_n = naive(xbar, loga, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y_c), y_n, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_c), h_n, rtol=2e-4, atol=2e-4)


def test_moe_dispatch_capacity():
    """Tokens above expert capacity are dropped, not corrupted."""

    cfg = reduced(get("dbrx-132b"))
    params = lm.init_params(cfg, jax.random.PRNGKey(8))
    batch = make_batch(cfg, B=2, S=16, seed=9)
    logits = lm.forward(cfg, params, batch)
    assert bool(jnp.isfinite(logits).all())


def test_param_counts_match_published_scale():
    """Sanity: full configs land near their nameplate parameter counts."""
    expect = {
        "dbrx-132b": (120e9, 145e9),
        "kimi-k2-1t-a32b": (0.9e12, 1.15e12),
        "command-r-35b": (30e9, 40e9),
        "qwen3-14b": (13e9, 16.5e9),
        "qwen2.5-14b": (13e9, 16.5e9),
        "phi3-mini-3.8b": (3.3e9, 4.3e9),
        "rwkv6-7b": (6e9, 8.5e9),
        "zamba2-2.7b": (2.2e9, 3.3e9),
        "llama-3.2-vision-90b": (80e9, 100e9),
        "musicgen-medium": (1.2e9, 2.2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.1f}B params out of [{lo/1e9}, {hi/1e9}]"
