"""Property tests for repro.store (ISSUE 4 satellite).

The subsystem's core contract, fuzzed: a chunked, encoded,
zone-map-pruned scan returns exactly what a whole-array numpy filter
returns — for random data, random chunk sizes (including chunk_rows=1
and chunks larger than the data), random predicates, and both clustered
(sorted) and scattered layouts.

Requires the optional ``hypothesis`` dev dependency (see
requirements-dev.txt); skipped when absent, like
tests/test_core_properties.py.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro import store

_OPS = ("=", "<>", "<", "<=", ">", ">=")


@settings(
    max_examples=60,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
@given(
    data=st.lists(st.integers(-50, 50), min_size=0, max_size=300),
    chunk_rows=st.integers(1, 64),
    op=st.sampled_from(_OPS + ("between", "in")),
    v=st.integers(-55, 55),
    w=st.integers(-55, 55),
    sort=st.booleans(),
)
def test_scan_equals_whole_frame_scan_ints(data, chunk_rows, op, v, w, sort):
    arr = np.array(sorted(data) if sort else data, dtype=np.int64)
    t = store.Table.from_arrays(
        {"x": arr, "row": np.arange(arr.shape[0])}, chunk_rows=chunk_rows
    )
    if op == "between":
        lo, hi = min(v, w), max(v, w)
        pred, ref = store.Pred("x", "between", (lo, hi)), (arr >= lo) & (arr <= hi)
    elif op == "in":
        pred, ref = store.Pred("x", "in", (v, w)), np.isin(arr, [v, w])
    else:
        pred = store.Pred("x", op, v)
        ref = {
            "=": arr == v, "<>": arr != v, "<": arr < v,
            "<=": arr <= v, ">": arr > v, ">=": arr >= v,
        }[op]
    r = store.scan(t, ["x", "row"], [pred])
    assert r.nrows == int(ref.sum())
    np.testing.assert_array_equal(r.columns["x"].values, arr[ref])
    np.testing.assert_array_equal(
        r.columns["row"].values, np.arange(arr.shape[0])[ref]
    )
    # pruning may only drop whole chunks, never matching rows
    assert r.rows_scanned >= r.nrows


@settings(
    max_examples=40,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
@given(
    codes=st.lists(st.integers(0, 5), min_size=1, max_size=200),
    chunk_rows=st.integers(1, 32),
    op=st.sampled_from(_OPS),
    pick=st.integers(0, 6),
)
def test_scan_equals_whole_frame_scan_strings(codes, chunk_rows, op, pick):
    vocab = np.array(
        ["apple", "kiwi", "lime", "mango", "pear", "plum"], dtype=object
    )
    arr = vocab[np.array(codes)]
    needle = (list(vocab) + ["zzz"])[pick]  # present or absent values
    t = store.Table.from_arrays({"s": arr}, chunk_rows=chunk_rows)
    got = t.columns["s"].decode(
        store.scan(t, ["s"], [store.Pred("s", op, needle)]).columns["s"].values
    )
    ref = {
        "=": arr == needle, "<>": arr != needle, "<": arr < needle,
        "<=": arr <= needle, ">": arr > needle, ">=": arr >= needle,
    }[op]
    np.testing.assert_array_equal(got, arr[ref])


@settings(
    max_examples=30,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
@given(
    data=st.lists(
        st.floats(-100, 100, allow_nan=False) | st.just(float("nan")),
        min_size=0,
        max_size=200,
    ),
    chunk_rows=st.integers(1, 48),
    op=st.sampled_from(_OPS),
    v=st.floats(-110, 110, allow_nan=False),
)
def test_scan_equals_whole_frame_scan_floats_with_nulls(data, chunk_rows, op, v):
    """NaN cells follow the engine's IEEE comparison semantics exactly
    (no match for any op except <>), so pushed predicates select the
    same rows the equivalent frame filter would — regardless of how
    NaNs fall across chunks (all-null chunks included)."""
    arr = np.array(data, dtype=np.float64)
    t = store.Table.from_arrays({"x": arr}, chunk_rows=chunk_rows)
    with np.errstate(invalid="ignore"):
        ref = {
            "=": arr == v, "<>": arr != v, "<": arr < v,
            "<=": arr <= v, ">": arr > v, ">=": arr >= v,
        }[op]
    r = store.scan(t, ["x"], [store.Pred("x", op, v)])
    np.testing.assert_array_equal(r.columns["x"].values, arr[ref])


@settings(
    max_examples=30,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
@given(
    n=st.integers(0, 200),
    chunk_rows=st.integers(1, 64),
    v1_first=st.booleans(),
)
def test_tfb_v1_v2_round_trip_compat(tmp_path_factory, n, chunk_rows, v1_first):
    """Any table written as v1 and as v2 reads back identically through
    the one core.io entry point (version sniffed from the manifest)."""
    from repro.core import io as tio

    rng = np.random.default_rng(n)
    data = {
        "i": rng.integers(-1000, 1000, n),
        "f": rng.uniform(-1, 1, n),
        "s": np.array(["a", "bb", "ccc"], dtype=object)[rng.integers(0, 3, n)],
    }
    base = tmp_path_factory.mktemp("tfb")
    order = [(1, "v1"), (2, "v2")]
    if not v1_first:
        order.reverse()
    out = {}
    for version, tag in order:
        p = str(base / tag)
        tio.write_tfb(p, data, version=version, chunk_rows=chunk_rows)
        out[tag] = tio.read_tfb_arrays(p)
    for name in data:
        if data[name].dtype == object:
            assert list(out["v1"][name]) == list(out["v2"][name]) == list(data[name])
        else:
            np.testing.assert_array_equal(out["v1"][name], data[name])
            np.testing.assert_array_equal(out["v2"][name], data[name])
