"""Property tests: the three join algorithms agree (ISSUE 5 satellite).

``direct`` (perfect-hash table), ``sorted`` (searchsorted + CSR
expansion) and ``sortmerge`` (the paper's Fig. 12 baseline) must
return the same *row multiset* for any input — multi-column keys
(int + dict-encoded string), duplicate build keys, null keys on
either side, and empty frames.  Row order is an implementation
detail; content is not.

Requires the optional ``hypothesis`` dev dependency; skipped when
absent, like tests/test_core_properties.py.
"""
import numpy as np
import pytest

import jax.numpy as jnp

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import TensorFrame
from repro.core.frame import _valid_name

_ALGOS = ("direct", "sorted", "sortmerge")

_row = st.tuples(
    st.integers(0, 5),  # k1: small int domain -> guaranteed duplicates
    st.integers(0, 3),  # k2: tiny string domain
    st.booleans(),  # null flag for k1
)


def _frame(rows, tag):
    n = len(rows)
    k1 = np.array([r[0] for r in rows], dtype=np.int64)
    k2 = np.array([f"s{r[1]}" for r in rows], dtype=object)
    nulls = np.array([r[2] for r in rows], dtype=bool)
    f = TensorFrame.from_arrays(
        {"k1": k1, "k2": k2, f"payload{tag}": np.arange(n, dtype=np.int64)},
        encode={"k2": "dict"},
    )
    if n:
        f = f._append_int_column(
            _valid_name("k1"), jnp.asarray((~nulls).astype(np.int64)), "bool"
        )
    return f


def _row_multiset(frame):
    d = frame.to_dict()
    names = sorted(d)
    nulls = {c: np.asarray(frame.valid_array(c)) if frame.has_nulls(c) else None
             for c in names}

    def cell(c, i):
        v = d[c][i]
        if nulls[c] is not None and not nulls[c][i]:
            return "<null>"
        if isinstance(v, float) and np.isnan(v):
            return "<nan>"
        return str(v)

    return sorted(tuple(cell(c, i) for c in names) for i in range(frame.nrows))


@settings(
    max_examples=60,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
@given(
    lrows=st.lists(_row, min_size=0, max_size=30),
    rrows=st.lists(_row, min_size=0, max_size=30),
    how=st.sampled_from(["inner", "left"]),
    nkeys=st.integers(1, 2),
)
def test_join_algorithms_agree_as_row_multisets(lrows, rrows, how, nkeys):
    keys = ["k1", "k2"][:nkeys]
    results = []
    for algo in _ALGOS:
        # fresh frames per algorithm: no stats-cache cross-talk
        left, right = _frame(lrows, "L"), _frame(rrows, "R")
        out = left.join(right, on=keys, how=how, algorithm=algo)
        results.append(_row_multiset(out))
    assert results[0] == results[1], f"direct != sorted ({how}, {keys})"
    assert results[0] == results[2], f"direct != sortmerge ({how}, {keys})"


@settings(max_examples=40, deadline=None)
@given(
    lrows=st.lists(_row, min_size=0, max_size=25),
    rrows=st.lists(_row, min_size=0, max_size=25),
    how=st.sampled_from(["semi", "anti"]),
)
def test_semi_anti_match_inner_membership(lrows, rrows, how):
    left, right = _frame(lrows, "L"), _frame(rrows, "R")
    out = left.join(right, on=["k1", "k2"], how=how)
    # reference: membership through the inner join's matched left rows
    inner = left.join(right, on=["k1", "k2"], how="inner")
    matched = set(map(int, np.asarray(inner.column("payloadL"))))
    want = [
        i for i in range(left.nrows)
        if (i in matched) == (how == "semi")
    ]
    got = sorted(map(int, np.asarray(out.column("payloadL"))))
    assert got == sorted(want)
