"""Chaos suite (ISSUE 10): fault storms against the full serving stack.

The property under test: with faults injected at every I/O and compile
boundary, every query submitted to the engine terminates — with the
*exact clean-run answer* or a *typed QueryError* — never a hang, never
a silently wrong result.

``REPRO_CHAOS_SEED`` (CI matrix: 7 and 1234) seeds every fault rule
and the per-thread query generators, so a failing storm replays
exactly.  A ``faulthandler`` watchdog aborts the whole process with
thread dumps if any test exceeds its budget — a hang is a loud CI
failure, not a timeout mystery.

Fault storm composition (rates chosen so most queries survive but
every degradation tier fires across the suite):

- ``spill.write`` OSError at 0.3 under a tiny memory budget with
  ``out_of_core='force'`` — exercises retry then in-memory retention;
- ``compile`` RuntimeError at 1.0 under ``compiled='force'`` —
  exercises the negative cache + op-by-op dispatch fallback;
- ``store.read`` OSError at a low rate — mostly absorbed by retry,
  residue surfaces as typed ``TransientIOError``;
- ``exec.operator`` delays + per-query deadlines — exercises
  checkpoint timeouts under load.
"""
import faulthandler
import os
import random
import threading

import numpy as np
import pytest

from repro import resilience, serve, sql, store
from repro.core.config import CONFIG
from repro.resilience import QueryError, faults
from repro.serve.stats import STATS

CHAOS_SEED = int(os.environ.get("REPRO_CHAOS_SEED", "7"))
STRESS = os.environ.get("REPRO_SERVE_STRESS") == "1"
THREADS = 8
QUERIES_PER_THREAD = 8 if STRESS else 4

#: Per-test hang budget (seconds).  Generous — the point is catching
#: *forever*, not slowness; the watchdog dumps every thread and exits.
WATCHDOG_S = int(os.environ.get("REPRO_CHAOS_WATCHDOG_S", "300"))


@pytest.fixture(autouse=True)
def _watchdog():
    faulthandler.dump_traceback_later(WATCHDOG_S, exit=True)
    yield
    faulthandler.cancel_dump_traceback_later()


@pytest.fixture(autouse=True)
def _clean_slate():
    STATS.reset()
    faults.clear()
    yield
    faults.clear()


#: ``t`` is store-backed (streams out-of-core: spill + store.read
#: faults apply); ``m`` is an in-memory TensorFrame (the whole-plan
#: compiled path: compile faults apply — compilation requires
#: TensorFrame scans, so the two fault families need both table kinds).
_QUERIES = [
    "SELECT g, SUM(v) AS s, COUNT(*) AS c FROM t WHERE f > {q} GROUP BY g",
    "SELECT SUM(v * f) AS sv FROM t WHERE g < {g}",
    "SELECT g, MIN(v) AS lo, MAX(v) AS hi FROM t GROUP BY g",
    "SELECT COUNT(*) AS c FROM t WHERE f > {q} AND g >= {g}",
    "SELECT g, SUM(v) AS s FROM m WHERE f > {q} GROUP BY g",
    "SELECT COUNT(*) AS c FROM m WHERE g >= {g}",
]


def _draw(rng) -> str:
    t = rng.randrange(len(_QUERIES))
    return _QUERIES[t].format(q=rng.randrange(2, 9), g=rng.randrange(1, 7))


def _arrays(n: int):
    rng = np.random.default_rng(99)
    return {
        "g": rng.integers(0, 8, n),
        "f": rng.integers(0, 10, n),
        "v": np.round(rng.standard_normal(n) * 100).astype(np.int64),
    }


@pytest.fixture(scope="module")
def chaos_store():
    return store.Table.from_arrays(_arrays(20_000), chunk_rows=1024)


@pytest.fixture(scope="module")
def chaos_frame():
    from repro.core.frame import TensorFrame

    return TensorFrame.from_arrays(_arrays(4_000))


def _assert_same(out, ref) -> None:
    assert list(out.columns) == list(ref.columns)
    for name in ref.columns:
        a, b = np.asarray(out.column(name)), np.asarray(ref.column(name))
        if a.dtype.kind == "f" or b.dtype.kind == "f":
            np.testing.assert_allclose(a, b, rtol=1e-9)
        else:
            np.testing.assert_array_equal(a, b)


def _chaos_config():
    """(apply, restore) closures for the chaos engine configuration."""
    saved = (
        CONFIG.out_of_core,
        CONFIG.ooc_min_rows,
        CONFIG.memory_budget_bytes,
        CONFIG.compiled,
        CONFIG.io_retry_base_s,
        CONFIG.serve_shared_scans,
    )

    def apply():
        # auto + a floor of 1 row: store scans stream out-of-core while
        # TensorFrame scans stay eligible for the compiled path (which
        # out_of_core='force' would bypass entirely)
        CONFIG.out_of_core = "auto"
        CONFIG.ooc_min_rows = 1
        CONFIG.memory_budget_bytes = 1  # every partial must spill
        CONFIG.compiled = "force"  # force compile-path traffic
        CONFIG.io_retry_base_s = 1e-4
        # shared scans pre-materialize store tables, which (by design)
        # bypasses out-of-core streaming — off, so the storm's store
        # aggregates actually hit the spill path
        CONFIG.serve_shared_scans = False

    def restore():
        (
            CONFIG.out_of_core,
            CONFIG.ooc_min_rows,
            CONFIG.memory_budget_bytes,
            CONFIG.compiled,
            CONFIG.io_retry_base_s,
            CONFIG.serve_shared_scans,
        ) = saved
        from repro.sql import compile as plan_compile

        plan_compile.clear_cache()

    return apply, restore


def test_chaos_storm_correct_or_typed(chaos_store, chaos_frame):
    """8 threads, randomized queries, every boundary faulted: each
    future resolves to the clean answer or a typed QueryError."""
    scope = {"t": chaos_store, "m": chaos_frame}
    rng = random.Random(CHAOS_SEED)
    texts = sorted({_draw(rng) for _ in range(32)})
    assert any(" m " in q for q in texts)  # both table kinds covered
    clean = {q: sql.execute(q, scope) for q in texts}  # before any faults

    apply, restore = _chaos_config()
    apply()
    outcomes: list = []
    lock = threading.Lock()
    try:
        with serve.Executor(scope) as ex, faults.inject(
            "spill.write", OSError, rate=0.3, seed=CHAOS_SEED
        ), faults.inject(
            "compile", RuntimeError, rate=1.0, seed=CHAOS_SEED + 1
        ), faults.inject(
            "store.read", OSError, rate=0.02, seed=CHAOS_SEED + 2
        ):
            sessions = [ex.session() for _ in range(THREADS)]

            def work(i):
                r = random.Random(CHAOS_SEED * 1000 + i)
                # deterministic slice first (every text runs under
                # chaos at least once), then randomized re-draws
                mine = list(texts[i::THREADS]) + [
                    texts[r.randrange(len(texts))]
                    for _ in range(QUERIES_PER_THREAD)
                ]
                got = []
                for q in mine:
                    try:
                        got.append((q, sessions[i].execute(q), None))
                    except QueryError as e:
                        got.append((q, None, e))
                with lock:
                    outcomes.extend(got)

            threads = [
                threading.Thread(target=work, args=(i,))
                for i in range(THREADS)
            ]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=WATCHDOG_S)
                assert not t.is_alive(), "chaos worker hung"
    finally:
        restore()

    assert len(outcomes) == len(texts) + THREADS * QUERIES_PER_THREAD
    errs: dict = {}
    for q, out, err in outcomes:
        if err is not None:
            errs[type(err).__name__] = errs.get(type(err).__name__, 0) + 1
            continue
        _assert_same(out, clean[q])  # any result must be the clean one
    # the storm actually exercised the fault paths
    hit = faults.sites_hit()
    assert hit.get("compile", 0) >= 1
    assert hit.get("spill.write", 0) >= 1
    # degraded-mode bookkeeping matched what happened
    snap = STATS.snapshot()
    assert snap["admitted"] == len(outcomes)
    assert snap["errors_total"] == sum(errs.values())
    # compile crashes fell back through the negative cache, and any
    # spill write failures retained their blocks rather than failing
    from repro.sql import compile as plan_compile

    assert plan_compile.STATS["compile_failures"] >= 1
    assert plan_compile.STATS["compiles"] == 0


def test_chaos_with_deadlines(chaos_store):
    """Deadline pressure on top of delay faults: timeouts surface as
    QueryTimeout, survivors still match the clean answers."""
    scope = {"t": chaos_store}
    q_fast = "SELECT g, COUNT(*) AS c FROM t GROUP BY g"
    clean = sql.execute(q_fast, scope)

    timeouts = 0
    ok = 0
    with serve.Executor(scope) as ex, faults.inject(
        "exec.operator", delay_s=0.02, rate=0.5, seed=CHAOS_SEED
    ):
        for i in range(12):
            try:
                out = ex.execute(
                    q_fast, timeout_s=0.04 if i % 2 else None
                )
                _assert_same(out, clean)
                ok += 1
            except resilience.QueryTimeout:
                timeouts += 1
    assert ok >= 1  # unbounded requests always complete
    assert ok + timeouts == 12
    snap = STATS.snapshot()
    assert snap["errors_total"] == timeouts
    if timeouts:
        assert snap["errors"] == {"timeout": timeouts}


def test_chaos_spill_storm_exact_aggregates(chaos_store):
    """Out-of-core aggregation under a spill-write fault storm: the
    budget overruns (retention) but the aggregate stays exact."""
    scope = {"t": chaos_store}
    q = "SELECT g, SUM(v) AS s, COUNT(*) AS c FROM t GROUP BY g"
    clean = sql.execute(q, scope)

    apply, restore = _chaos_config()
    apply()
    CONFIG.compiled = "off"  # isolate the spill path
    try:
        with faults.inject(
            "spill.write", OSError, rate=0.5, seed=CHAOS_SEED
        ):
            for _ in range(3):
                _assert_same(sql.execute(q, scope), clean)
    finally:
        restore()
    assert faults.sites_hit().get("spill.write", 0) >= 1


def test_chaos_worker_never_wedges(chaos_store):
    """Back-to-back fault storms against one executor: the admission
    worker survives every round and still answers cleanly at the end."""
    scope = {"t": chaos_store}
    q = "SELECT COUNT(*) AS c FROM t"
    clean = int(np.asarray(sql.execute(q, scope).column("c"))[0])
    with serve.Executor(scope) as ex:
        for round_seed in range(CHAOS_SEED, CHAOS_SEED + 3):
            with faults.inject(
                "exec.operator", OSError, rate=0.5, seed=round_seed
            ):
                for _ in range(6):
                    try:
                        ex.execute(q)
                    except QueryError:
                        pass
        # all rules disarmed: the same executor must be fully healthy
        for _ in range(3):
            assert (
                int(np.asarray(ex.execute(q).column("c"))[0]) == clean
            )
    assert STATS["worker_restarts"] == 0  # faults never killed the loop
