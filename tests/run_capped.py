"""Memory-capped out-of-core TPC-H runner (the CI ``oom-guard`` lane).

Proves the tentpole claim end-to-end: under an address-space cap where
the eager engine cannot materialize the store-backed TPC-H working set,
``CONFIG.out_of_core=force`` completes the same sweep with identical
results.

Three subcommands:

``prepare``
    Uncapped: generate TPC-H, write the tables as ``.tfb`` v2 stores
    under ``--workdir``, run the sweep eagerly and record per-query
    result fingerprints (expected.json).

``run --mode {eager,ooc} --cap-mb N``
    Set ``resource.setrlimit(RLIMIT_AS)`` **before importing numpy or
    jax**, open the stores from disk, run the sweep in the requested
    mode and compare fingerprints.  Exit 0 only on a full match.

``sweep --cap-mb N``
    The CI entry: prepare, then spawn ``run --mode eager`` (which MUST
    die — if eager fits under the cap the lane is vacuous, so an eager
    pass fails the sweep) and ``run --mode ooc`` (which must pass).
    There is no SKIP path: every early-out is a hard failure.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import subprocess
import sys

QUERIES = ("q1", "q6", "q14")  # group-by, scalar filter-agg, join-agg
SF = float(os.environ.get("REPRO_OOMGUARD_SF", "1.0"))
CHUNK_ROWS = 1 << 16


# ----------------------------------------------------------------------
# fingerprints: order-insensitive, dtype-aware result digests
# ----------------------------------------------------------------------
def fingerprint(frame) -> dict:
    import numpy as np

    out = {}
    for name in frame.column_names:
        arr = frame.column(name)
        if arr.dtype.kind == "f":
            out[name] = ["f", int(arr.shape[0]), float(np.nansum(arr))]
        elif arr.dtype.kind in "iub":
            out[name] = ["i", int(arr.shape[0]), int(arr.sum())]
        else:
            digest = hashlib.sha256(
                "\n".join(sorted(str(v) for v in arr)).encode()
            ).hexdigest()
            out[name] = ["s", int(arr.shape[0]), digest]
    return out


def compare(got: dict, want: dict, query: str) -> bool:
    ok = True
    for name, w in want.items():
        g = got.get(name)
        if g is None or g[0] != w[0] or g[1] != w[1]:
            print(f"FAIL {query}.{name}: shape/kind {g} != {w}")
            ok = False
            continue
        if w[0] == "f":
            tol = 1e-6 * max(1.0, abs(w[2]))
            if abs(g[2] - w[2]) > tol:
                print(f"FAIL {query}.{name}: {g[2]} != {w[2]}")
                ok = False
        elif g[2] != w[2]:
            print(f"FAIL {query}.{name}: {g[2]} != {w[2]}")
            ok = False
    return ok


def _store_paths(workdir: str) -> dict:
    return {
        name: os.path.join(workdir, f"{name}.tfb")
        for name in (
            "lineitem",
            "orders",
            "customer",
            "part",
            "partsupp",
            "supplier",
            "nation",
            "region",
        )
    }


def _open_scope(workdir: str) -> dict:
    from repro.store import open_store

    return {
        name: open_store(path) for name, path in _store_paths(workdir).items()
    }


def _run_sweep(scope: dict) -> dict:
    from repro import sql
    from repro.queries.tpch_sql import sql_text

    results = {}
    for q in QUERIES:
        results[q] = fingerprint(sql.execute(sql_text(q), scope))
    return results


# ----------------------------------------------------------------------
# subcommands
# ----------------------------------------------------------------------
def cmd_prepare(args) -> int:
    os.environ.setdefault("JAX_ENABLE_X64", "1")
    import numpy as np  # noqa: F401  (heavy imports only after no-cap)

    from repro.data import tpch
    from repro.store import write_store

    os.makedirs(args.workdir, exist_ok=True)
    tables = tpch.generate(sf=SF, seed=11)
    stores = tpch.as_store(
        tables, chunk_rows=CHUNK_ROWS, sort_fact_by_date=True
    )
    for name, path in _store_paths(args.workdir).items():
        write_store(path, stores[name])
    from repro.core.config import CONFIG

    CONFIG.out_of_core = "off"
    expected = _run_sweep(_open_scope(args.workdir))
    with open(os.path.join(args.workdir, "expected.json"), "w") as fh:
        json.dump(expected, fh)
    print(f"prepared sf={SF} sweep={QUERIES} under {args.workdir}")
    return 0


def cmd_run(args) -> int:
    # The whole point: cap the address space BEFORE numpy/jax exist.
    import resource

    cap = args.cap_mb << 20
    resource.setrlimit(resource.RLIMIT_AS, (cap, cap))
    os.environ.setdefault("JAX_ENABLE_X64", "1")

    from repro.core.config import CONFIG

    CONFIG.out_of_core = "force" if args.mode == "ooc" else "off"
    scope = _open_scope(args.workdir)
    with open(os.path.join(args.workdir, "expected.json")) as fh:
        expected = json.load(fh)
    got = _run_sweep(scope)
    ok = all(compare(got[q], expected[q], q) for q in QUERIES)
    if ok and args.mode == "ooc":
        from repro.core import pipeline

        print(
            "ooc stats:",
            {k: v for k, v in pipeline.STATS.items() if v},
        )
    print("RESULT", "PASS" if ok else "FAIL")
    return 0 if ok else 1


def _spawn(mode: str, cap_mb: int, workdir: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env.setdefault("JAX_ENABLE_X64", "1")
    return subprocess.run(
        [
            sys.executable,
            os.path.abspath(__file__),
            "run",
            "--mode",
            mode,
            "--cap-mb",
            str(cap_mb),
            "--workdir",
            workdir,
        ],
        env=env,
        capture_output=True,
        text=True,
    )


def cmd_sweep(args) -> int:
    rc = cmd_prepare(args)
    if rc:
        return rc

    eager = _spawn("eager", args.cap_mb, args.workdir)
    if eager.returncode == 0:
        print(eager.stdout)
        print(
            f"::error::eager sweep survived the {args.cap_mb}MB cap — "
            f"the oom-guard lane is vacuous; raise SF or lower the cap"
        )
        return 1
    print(
        f"eager under {args.cap_mb}MB cap died as expected "
        f"(exit {eager.returncode})"
    )

    ooc = _spawn("ooc", args.cap_mb, args.workdir)
    sys.stdout.write(ooc.stdout)
    if ooc.returncode != 0:
        sys.stderr.write(ooc.stderr[-4000:])
        print(
            f"::error::out_of_core=force failed under the "
            f"{args.cap_mb}MB cap (exit {ooc.returncode})"
        )
        return 1
    print("oom-guard PASS: ooc sweep matched eager results under the cap")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    for name in ("prepare", "run", "sweep"):
        p = sub.add_parser(name)
        p.add_argument("--workdir", default="/tmp/repro-oomguard")
        if name != "prepare":
            # tuned: eager's q1/q14 scans need >1800MB at SF 1.0 while
            # the chunk-streamed path tops out under 1600MB
            p.add_argument("--cap-mb", type=int, default=1700)
        if name == "run":
            p.add_argument("--mode", choices=("eager", "ooc"), required=True)
    args = ap.parse_args(argv)
    return {"prepare": cmd_prepare, "run": cmd_run, "sweep": cmd_sweep}[
        args.cmd
    ](args)


if __name__ == "__main__":
    sys.exit(main())
