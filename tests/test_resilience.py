"""Resilience layer tests (ISSUE 10).

Unit coverage for every degradation tier on its own: the error
taxonomy and ``classify`` choke point, the seeded fault-injection
registry, bounded retry, deadline/cancel checkpoints at each layer
(scope primitive, pipeline chunk loop, operator dispatch, admission
dequeue), admission control (queue depth policies, per-session caps,
typed shutdown, worker-crash restart), spill write-failure retention
and corrupt-block recomputation, and the compile-failure negative
cache.  ``test_chaos.py`` composes them under randomized fault storms.
"""
import time

import numpy as np
import pytest

from repro import serve, sql, store
from repro.core.config import CONFIG
from repro.core.frame import TensorFrame
from repro.resilience import (
    ExecutionError,
    PlanError,
    QueryCancelled,
    QueryError,
    QueryTimeout,
    ResourceExhausted,
    TransientIOError,
    checkpoint,
    classify,
    deadline_scope,
    faults,
    retry,
)
from repro.serve.stats import STATS
from repro.sql.parser import SqlError
from repro.store.spill import SPILL


@pytest.fixture(autouse=True)
def _clean_slate():
    STATS.reset()
    faults.clear()
    yield
    faults.clear()


@pytest.fixture()
def small_scope():
    n = 64
    return {
        "t": {
            "k": np.arange(n) % 8,
            "v": np.arange(n, dtype=np.float64),
        }
    }


# ----------------------------------------------------------------------
# taxonomy / classify
# ----------------------------------------------------------------------
def test_classify_mapping():
    assert isinstance(classify(SqlError("boom")), PlanError)
    assert isinstance(classify(OSError("disk")), TransientIOError)
    assert isinstance(classify(EOFError()), TransientIOError)
    assert isinstance(classify(MemoryError()), ResourceExhausted)
    assert isinstance(
        classify(RuntimeError("RESOURCE_EXHAUSTED: oom")), ResourceExhausted
    )
    assert isinstance(classify(ValueError("x")), ExecutionError)
    assert isinstance(classify(ValueError("x"), phase="plan"), PlanError)


def test_classify_idempotent_and_chains_cause():
    orig = QueryTimeout("late")
    assert classify(orig) is orig
    src = ValueError("inner")
    err = classify(src)
    assert err.__cause__ is src
    assert not err.retryable
    assert classify(OSError("io")).retryable


def test_error_codes_stable():
    assert QueryTimeout.code == "timeout"
    assert QueryCancelled.code == "cancelled"
    assert ResourceExhausted.code == "resource_exhausted"
    assert TransientIOError.code == "transient_io"
    assert PlanError.code == "plan_error"
    assert ExecutionError.code == "execution_error"
    for cls in (QueryTimeout, QueryCancelled, PlanError):
        assert issubclass(cls, QueryError)


# ----------------------------------------------------------------------
# fault injection
# ----------------------------------------------------------------------
def test_fault_injection_deterministic():
    def run(seed):
        fired = []
        with faults.inject("x", OSError, rate=0.5, seed=seed):
            for i in range(40):
                try:
                    faults.fault_point("x")
                except OSError:
                    fired.append(i)
        return fired

    a, b = run(7), run(7)
    assert a == b and a  # same seed -> identical firing subsequence
    assert run(8) != a  # different seed -> different subsequence


def test_fault_injection_limit_and_sites():
    with faults.inject("y", ValueError, limit=2) as rule:
        hits = 0
        for _ in range(5):
            try:
                faults.fault_point("y")
            except ValueError:
                hits += 1
        assert hits == 2 and rule.triggered == 2
    assert faults.sites_hit().get("y") == 2
    faults.fault_point("y")  # disarmed after the with-block: no raise


def test_fault_injection_delay():
    with faults.inject("z", delay_s=0.05):
        t0 = time.perf_counter()
        faults.fault_point("z")  # sleeps instead of raising
        assert time.perf_counter() - t0 >= 0.04
    assert faults.STATS["delayed"].get("z") == 1


# ----------------------------------------------------------------------
# retry
# ----------------------------------------------------------------------
def test_retry_recovers_within_budget():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] <= 2:
            raise OSError("transient")
        return "ok"

    assert retry.call(flaky, site="test", base_s=1e-4) == "ok"
    assert calls["n"] == 3
    assert retry.STATS["retries"] >= 2


def test_retry_gives_up_and_reraises():
    with pytest.raises(OSError):
        retry.call(
            lambda: (_ for _ in ()).throw(OSError("always")),
            retries=2,
            base_s=1e-4,
        )
    assert retry.STATS["giveups"] == 1


def test_retry_nonretryable_propagates_immediately():
    calls = {"n": 0}

    def bad():
        calls["n"] += 1
        raise ValueError("semantic")

    with pytest.raises(ValueError):
        retry.call(bad, base_s=1e-4)
    assert calls["n"] == 1  # no retry on non-transient failures


# ----------------------------------------------------------------------
# deadlines / cancellation primitives
# ----------------------------------------------------------------------
def test_checkpoint_noop_without_scope():
    checkpoint("anywhere")  # must be free and silent


def test_deadline_scope_timeout_and_cancel():
    with deadline_scope(timeout_s=0.01):
        checkpoint("early")  # inside the budget
        time.sleep(0.03)
        with pytest.raises(QueryTimeout):
            checkpoint("late")
    with deadline_scope(timeout_s=None) as d:
        checkpoint("unbounded")
        d.token.cancel()
        with pytest.raises(QueryCancelled):
            checkpoint("after-cancel")


def test_pipeline_chunk_checkpoint(tmp_path):
    from repro.core.pipeline import ChunkScan

    n = 1000
    table = store.Table.from_arrays(
        {"a": np.arange(n), "b": np.arange(n) % 7}, chunk_rows=100
    )
    cs = ChunkScan(table, ["a", "b"], [])
    with deadline_scope(at=time.monotonic() - 1.0):
        with pytest.raises(QueryTimeout):
            list(cs)


def test_operator_checkpoint(small_scope):
    frames = {k: TensorFrame.from_arrays(v) for k, v in small_scope.items()}
    with deadline_scope(at=time.monotonic() - 1.0):
        with pytest.raises(QueryTimeout):
            sql.execute("SELECT SUM(v) AS s FROM t", frames)


# ----------------------------------------------------------------------
# executor deadlines / cancel / shedding
# ----------------------------------------------------------------------
def test_executor_timeout_mid_execution(small_scope):
    with serve.Executor(small_scope) as ex:
        with faults.inject("exec.operator", delay_s=0.1):
            with pytest.raises(QueryTimeout):
                ex.execute("SELECT SUM(v) AS s FROM t", timeout_s=0.03)
    assert STATS.snapshot()["errors"] == {"timeout": 1}


def test_executor_default_timeout_config(small_scope):
    CONFIG.serve_default_timeout_s = 0.03
    try:
        with serve.Executor(small_scope) as ex:
            with faults.inject("exec.operator", delay_s=0.1):
                with pytest.raises(QueryTimeout):
                    ex.execute("SELECT SUM(v) AS s FROM t")
    finally:
        CONFIG.serve_default_timeout_s = None


def test_expired_in_queue_is_shed(small_scope):
    ex = serve.Executor(small_scope, auto_start=False)
    fut = ex.submit("SELECT SUM(v) AS s FROM t", timeout_s=0.01)
    time.sleep(0.03)  # expire while queued; nothing is draining
    assert ex.drain_once() == 1
    with pytest.raises(QueryTimeout):
        fut.result(timeout=1)
    snap = STATS.snapshot()
    assert snap["shed"] == {"deadline": 1}
    assert snap["shed_requests"] == 1


def test_cancel_queued_request(small_scope):
    ex = serve.Executor(small_scope, auto_start=False)
    fut = ex.submit("SELECT SUM(v) AS s FROM t")
    assert ex.cancel(fut.request_id) is True
    assert ex.cancel(987654) is False  # unknown id
    ex.drain_once()
    with pytest.raises(QueryCancelled):
        fut.result(timeout=1)
    assert STATS.snapshot()["shed"] == {"cancelled": 1}
    # resolved request ids no longer cancel
    assert ex.cancel(fut.request_id) is False


def test_session_cancel_api(small_scope):
    ex = serve.Executor(small_scope, auto_start=False)
    s = ex.session()
    fut = s.submit("SELECT COUNT(*) AS c FROM t")
    assert s.cancel(fut.request_id) is True
    ex.drain_once()
    with pytest.raises(QueryCancelled):
        fut.result(timeout=1)


def test_timeout_does_not_starve_other_sessions(small_scope):
    """The ISSUE acceptance case: one session's query blows its
    deadline mid-execution while another session's queries are queued
    behind it — the victim gets QueryTimeout, the others complete."""
    with serve.Executor(small_scope) as ex:
        s1, s2 = ex.session(), ex.session()
        with faults.inject("exec.operator", delay_s=0.06):
            slow = s1.submit("SELECT SUM(v) AS s FROM t", timeout_s=0.02)
            queued = [
                s2.submit(f"SELECT COUNT(*) AS c FROM t WHERE k > {i}")
                for i in range(3)
            ]
            with pytest.raises(QueryTimeout):
                slow.result(timeout=30)
            for i, q in enumerate(queued):
                out = q.result(timeout=30)
                expect = int((np.arange(64) % 8 > i).sum())
                assert int(np.asarray(out.column("c"))[0]) == expect
    snap = STATS.snapshot()
    assert snap["errors"].get("timeout") == 1
    assert snap["errors_total"] == 1


def test_coalesced_group_uses_loosest_deadline(small_scope):
    """Two identical queries, one impatient: the shared execution runs
    under the loosest member deadline, so the patient member still gets
    its result and only the impatient one can time out in-queue."""
    ex = serve.Executor(small_scope, auto_start=False)
    impatient = ex.submit("SELECT SUM(v) AS s FROM t", timeout_s=0.01)
    patient = ex.submit("SELECT SUM(v) AS s FROM t")
    time.sleep(0.03)
    ex.drain_once()
    with pytest.raises(QueryTimeout):
        impatient.result(timeout=1)
    out = patient.result(timeout=1)
    assert float(np.asarray(out.column("s"))[0]) == float(
        np.arange(64, dtype=np.float64).sum()
    )


def test_cancelled_member_of_coalesced_group(small_scope):
    """Cancelling ONE member of a coalesced pair must not kill the
    other member's execution."""
    ex = serve.Executor(small_scope, auto_start=False)
    a = ex.submit("SELECT COUNT(*) AS c FROM t")
    b = ex.submit("SELECT COUNT(*) AS c FROM t")
    ex.cancel(a.request_id)
    ex.drain_once()
    with pytest.raises(QueryCancelled):
        a.result(timeout=1)
    assert int(np.asarray(b.result(timeout=1).column("c"))[0]) == 64


# ----------------------------------------------------------------------
# admission control
# ----------------------------------------------------------------------
def test_queue_depth_reject_new(small_scope):
    CONFIG.serve_queue_depth = 1
    CONFIG.serve_shed_policy = "reject-new"
    try:
        ex = serve.Executor(small_scope, auto_start=False)
        first = ex.submit("SELECT COUNT(*) AS c FROM t")
        with pytest.raises(ResourceExhausted):
            ex.submit("SELECT SUM(v) AS s FROM t")
        ex.drain_once()
        assert first.result(timeout=1).nrows == 1
    finally:
        CONFIG.serve_queue_depth = None


def test_queue_depth_drop_oldest(small_scope):
    CONFIG.serve_queue_depth = 1
    CONFIG.serve_shed_policy = "drop-oldest"
    try:
        ex = serve.Executor(small_scope, auto_start=False)
        oldest = ex.submit("SELECT COUNT(*) AS c FROM t")
        newest = ex.submit("SELECT SUM(v) AS s FROM t")
        with pytest.raises(ResourceExhausted):
            oldest.result(timeout=1)  # shed to admit the newcomer
        ex.drain_once()
        assert newest.result(timeout=1).nrows == 1
        snap = STATS.snapshot()
        assert snap["shed"] == {"queue_full": 1}
        assert snap["errors"].get("resource_exhausted") == 1
    finally:
        CONFIG.serve_queue_depth = None
        CONFIG.serve_shed_policy = "reject-new"


def test_session_inflight_cap(small_scope):
    CONFIG.serve_session_inflight = 2
    try:
        ex = serve.Executor(small_scope, auto_start=False)
        s = ex.session()
        futs = [s.submit("SELECT COUNT(*) AS c FROM t") for _ in range(2)]
        with pytest.raises(ResourceExhausted):
            s.submit("SELECT SUM(v) AS s FROM t")
        # the cap is per session: a sibling session still gets in
        other = ex.session().submit("SELECT COUNT(*) AS c FROM t")
        ex.drain_once()
        for f in futs + [other]:
            assert f.result(timeout=1).nrows == 1
        # resolution released the budget
        s.submit("SELECT COUNT(*) AS c FROM t")
    finally:
        CONFIG.serve_session_inflight = None


def test_close_drains_pending_with_typed_error(small_scope):
    ex = serve.Executor(small_scope, auto_start=False)
    futs = [ex.submit("SELECT COUNT(*) AS c FROM t") for _ in range(3)]
    ex.close()
    for f in futs:
        with pytest.raises(QueryCancelled):
            f.result(timeout=1)
    snap = STATS.snapshot()
    assert snap["shed"] == {"closed": 3}
    assert snap["errors"].get("cancelled") == 3


@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning"
)
def test_worker_crash_restarts(small_scope):
    ex = serve.Executor(small_scope)
    q = ex._queue
    real = q._run_batch
    try:
        q._run_batch = lambda batch: (_ for _ in ()).throw(SystemExit(1))
        crashed = ex.submit("SELECT COUNT(*) AS c FROM t")
        with pytest.raises(QueryError):
            crashed.result(timeout=10)
        deadline = time.monotonic() + 10
        while q._worker.is_alive() and time.monotonic() < deadline:
            time.sleep(0.01)
        assert not q._worker.is_alive()  # SystemExit killed the worker
        q._run_batch = real
        # the next submit detects the dead worker and restarts it
        out = ex.execute("SELECT COUNT(*) AS c FROM t")
        assert int(np.asarray(out.column("c"))[0]) == 64
        assert STATS["worker_restarts"] == 1
    finally:
        q._run_batch = real
        ex.close()


# ----------------------------------------------------------------------
# spill resilience
# ----------------------------------------------------------------------
def _block(seed, n=256):
    rng = np.random.default_rng(seed)
    return {
        "g": rng.integers(0, 10, n),
        "x": rng.standard_normal(n),
    }


def test_spill_write_failure_retains_in_memory(monkeypatch):
    monkeypatch.setattr(CONFIG, "memory_budget_bytes", 1)
    monkeypatch.setattr(CONFIG, "io_retry_base_s", 1e-4)
    b1, b2 = _block(1), _block(2)
    with faults.inject("spill.write", OSError, rate=1.0):
        h1 = SPILL.register(dict(b1))
        h2 = SPILL.register(dict(b2))  # tries (and fails) to evict h1
        data1, _ = h1.get()
        data2, _ = h2.get()
    try:
        np.testing.assert_array_equal(data1["x"], b1["x"])
        np.testing.assert_array_equal(data2["x"], b2["x"])
        assert SPILL.counters["write_failures"] >= 1
        assert SPILL.counters["retained_bytes"] > 0
        assert retry.STATS["giveups"] >= 1  # the budget was actually spent
    finally:
        h1.release()
        h2.release()


def test_spill_write_retry_recovers(monkeypatch):
    monkeypatch.setattr(CONFIG, "memory_budget_bytes", 1)
    monkeypatch.setattr(CONFIG, "io_retry_base_s", 1e-4)
    b1, b2 = _block(3), _block(4)
    with faults.inject("spill.write", OSError, limit=1):  # one-shot fault
        h1 = SPILL.register(dict(b1))
        h2 = SPILL.register(dict(b2))
    try:
        assert SPILL.counters["write_failures"] == 0  # retry absorbed it
        assert retry.STATS["retries"] >= 1
        data1, _ = h1.get()
        np.testing.assert_array_equal(data1["g"], b1["g"])
    finally:
        h1.release()
        h2.release()


def _spill_out(handle):
    """Force one block to disk regardless of LRU order."""
    wrote = handle._do_spill()
    assert handle.spilled
    return wrote


def test_corrupt_spill_block_recomputes(monkeypatch):
    monkeypatch.setattr(CONFIG, "io_retry_base_s", 1e-4)
    src = _block(5)
    h = SPILL.register(dict(src), recompute=lambda: (dict(src), {}))
    try:
        _spill_out(h)
        # truncate the manifest: open_store now fails to parse
        with open(f"{h._path}/manifest.json", "w") as f:
            f.write("{")
        data, _ = h.get()
        np.testing.assert_array_equal(data["x"], src["x"])
        assert SPILL.counters["corrupt_blocks"] == 1
        assert SPILL.counters["recomputes"] == 1
        # the bad file was discarded: the handle is re-spillable
        assert h._path is None
    finally:
        h.release()


def test_truncated_spill_block_detected(monkeypatch):
    """Row-count mismatch (a truncated rewrite) is caught by the
    written-block identity check, not silently served."""
    monkeypatch.setattr(CONFIG, "io_retry_base_s", 1e-4)
    src = _block(6)
    h = SPILL.register(dict(src))
    try:
        _spill_out(h)
        from repro.store import format as storefmt

        truncated = {k: v[: len(v) // 2] for k, v in src.items()}
        storefmt.write_arrays(h._path, truncated, chunk_rows=1024)
        with pytest.raises(TransientIOError):
            h.get()
        assert SPILL.counters["corrupt_blocks"] == 1
    finally:
        h.release()


def test_spill_delete_failure_counted(monkeypatch):
    from repro.store import spill as spill_mod

    def broken_rmtree(path, ignore_errors=False):
        if not ignore_errors:
            raise OSError("EBUSY")

    monkeypatch.setattr(spill_mod.shutil, "rmtree", broken_rmtree)
    before = SPILL.counters["delete_failures"]
    spill_mod._delete_dir(spill_mod._process_spill_root())  # must not raise
    assert SPILL.counters["delete_failures"] == before + 1


def test_streamagg_partial_recompute(monkeypatch):
    """A corrupt spilled partial rebuilds through its chunk closure and
    the final aggregate stays exact."""
    from repro.core.pipeline import StreamAgg

    monkeypatch.setattr(CONFIG, "io_retry_base_s", 1e-4)
    chunks = [
        TensorFrame.from_arrays(
            {"g": np.arange(100) % 5, "v": np.arange(100) + 100.0 * i}
        )
        for i in range(3)
    ]
    sagg = StreamAgg(["g"], [("s", "sum", "v"), ("c", "count", "v")])
    for f in chunks:
        sagg.add(f, rebuild=lambda f=f: f)
    assert sagg._pending, "partials should be registered"
    h = sagg._pending[0]
    _spill_out(h)
    with open(f"{h._path}/manifest.json", "w") as f:
        f.write("not json")
    out = sagg.finalize()
    assert SPILL.counters["recomputes"] == 1
    got = {
        int(g): (float(s), int(c))
        for g, s, c in zip(
            np.asarray(out.column("g")),
            np.asarray(out.column("s")),
            np.asarray(out.column("c")),
        )
    }
    all_g = np.concatenate([np.arange(100) % 5] * 3)
    all_v = np.concatenate(
        [np.arange(100) + 100.0 * i for i in range(3)]
    )
    for g in range(5):
        mask = all_g == g
        assert got[g][0] == pytest.approx(float(all_v[mask].sum()))
        assert got[g][1] == int(mask.sum())


# ----------------------------------------------------------------------
# store read retry
# ----------------------------------------------------------------------
def test_store_read_retries_through_transient_faults(tmp_path, monkeypatch):
    monkeypatch.setattr(CONFIG, "io_retry_base_s", 1e-4)
    from repro.store import format as storefmt

    src = {"a": np.arange(500), "b": (np.arange(500) % 3).astype(np.int64)}
    path = str(tmp_path / "t.tfb")
    storefmt.write_arrays(path, src, chunk_rows=128)
    # the first two reads (manifest, then its first retry) fail; the
    # retry budget (3) absorbs both deterministically
    with faults.inject("store.read", OSError, limit=2):
        table = storefmt.open_store(path)
        got = table.to_arrays()
    np.testing.assert_array_equal(got["a"], src["a"])
    np.testing.assert_array_equal(got["b"], src["b"])
    assert faults.sites_hit().get("store.read") == 2
    assert retry.STATS["retries"] >= 2


# ----------------------------------------------------------------------
# compile-failure negative cache
# ----------------------------------------------------------------------
def test_compile_failure_negative_cache(small_scope):
    from repro.sql import compile as plan_compile

    frames = {k: TensorFrame.from_arrays(v) for k, v in small_scope.items()}
    q = "SELECT k, SUM(v) AS s FROM t GROUP BY k"
    expected = sql.execute(q, frames)  # compiled off-path (small input)
    plan_compile.clear_cache()
    plan_compile.reset_stats()
    CONFIG.compiled = "force"
    try:
        with faults.inject("compile", RuntimeError, rate=1.0):
            out1 = sql.execute(q, frames)  # crash -> op-by-op fallback
            out2 = sql.execute(q, frames)  # negative cache, no re-crash
        s = plan_compile.STATS
        assert s["compile_failures"] == 1  # second call never recompiled
        assert s["fallbacks"] == 2
        assert s["compiles"] == 0
        for out in (out1, out2):
            np.testing.assert_allclose(
                np.asarray(out.column("s")), np.asarray(expected.column("s"))
            )
        # the trace lock was released: later queries aren't poisoned
        assert not plan_compile._TRACE_LOCKS
    finally:
        CONFIG.compiled = "auto"
        plan_compile.clear_cache()


# ----------------------------------------------------------------------
# shared-scan degradation stays observable
# ----------------------------------------------------------------------
def test_shared_scan_failure_falls_back(monkeypatch):
    n = 512
    table = store.Table.from_arrays(
        {"a": np.arange(n), "b": np.arange(n) % 7}, chunk_rows=128
    )
    import repro.store as store_pkg

    def broken(*a, **k):
        raise OSError("scan pass down")

    monkeypatch.setattr(store_pkg, "shared_scan", broken)
    ex = serve.Executor({"t": table}, auto_start=False)
    f1 = ex.submit("SELECT SUM(a) AS s FROM t WHERE b > 2")
    f2 = ex.submit("SELECT COUNT(*) AS c FROM t WHERE b > 2")
    ex.drain_once()
    mask = np.arange(n) % 7 > 2
    assert float(np.asarray(f1.result(1).column("s"))[0]) == float(
        np.arange(n)[mask].sum()
    )
    assert int(np.asarray(f2.result(1).column("c"))[0]) == int(mask.sum())
    assert STATS["shared_scan_errors"] == 1
    assert STATS.snapshot()["errors_total"] == 0  # degraded, not failed
