"""IS NULL / LIKE-prefix scan pushdown + bounded dictionary pool
(ISSUE 5 satellites; both were ROADMAP open items).
"""
import numpy as np

from repro import sql, store
from repro.core import TensorFrame
from repro.store.pool import StringPool


def _table(chunk_rows=16):
    rng = np.random.default_rng(7)
    n = 128
    price = rng.random(n) * 100
    price[rng.random(n) < 0.25] = np.nan
    # one chunk of all-null floats exercises the vmin=None paths
    price[:chunk_rows] = np.nan
    cat = rng.choice(
        ["alpha", "alphonse", "beta", "gamma", "delta"], n
    ).astype(object)
    ship = np.sort(rng.choice([f"MODE{i}" for i in range(40)], n)).astype(object)
    data = {"k": np.arange(n), "price": price, "cat": cat, "ship": ship}
    return data, store.Table.from_arrays(
        data, chunk_rows=chunk_rows, encode={"ship": "plain"}
    )


# ----------------------------------------------------------------------
# store-level predicates
# ----------------------------------------------------------------------
def test_scan_isnull_matches_numpy():
    data, t = _table()
    r = store.scan(t, ["k"], [store.Pred("price", "isnull")])
    ref = np.isnan(data["price"])
    np.testing.assert_array_equal(r.columns["k"].values, data["k"][ref])
    assert r.chunks_skipped >= 0


def test_scan_notnull_matches_numpy_and_skips_allnull_chunk():
    data, t = _table()
    r = store.scan(t, ["k"], [store.Pred("price", "notnull")])
    ref = ~np.isnan(data["price"])
    np.testing.assert_array_equal(r.columns["k"].values, data["k"][ref])
    assert r.chunks_skipped >= 1  # the all-null chunk pruned on null counts


def test_scan_isnull_on_non_nullable_column_is_empty():
    data, t = _table()
    r = store.scan(t, ["k"], [store.Pred("k", "isnull")])
    assert r.nrows == 0 and r.rows_scanned == 0
    r = store.scan(t, ["k"], [store.Pred("k", "notnull")])
    assert r.nrows == data["k"].shape[0]


def test_scan_like_prefix_dict_and_plain():
    data, t = _table()
    # dict-encoded column: prefix becomes a code range
    r = store.scan(t, ["cat"], [store.Pred("cat", "like", "alph")])
    ref = np.array([s.startswith("alph") for s in data["cat"]])
    mc = r.columns["cat"]
    np.testing.assert_array_equal(mc.dictionary[mc.values], data["cat"][ref])
    # plain (sorted) string column: zone maps prune non-matching chunks
    r2 = store.scan(t, ["ship"], [store.Pred("ship", "like", "MODE3")])
    ref2 = np.array([s.startswith("MODE3") for s in data["ship"]])
    np.testing.assert_array_equal(r2.columns["ship"].values, data["ship"][ref2])
    assert r2.chunks_skipped > 0  # sorted layout: most chunks out of range


def test_scan_like_no_match_prunes_everything():
    _, t = _table()
    r = store.scan(t, ["cat"], [store.Pred("cat", "like", "zzz")])
    assert r.nrows == 0 and r.rows_scanned == 0


# ----------------------------------------------------------------------
# SQL pushdown: pushed predicates == residual filters
# ----------------------------------------------------------------------
_QUERIES = [
    "SELECT k FROM t WHERE price IS NULL",
    "SELECT k FROM t WHERE price IS NOT NULL",
    "SELECT k FROM t WHERE cat LIKE 'alph%'",
    "SELECT k FROM t WHERE cat IS NOT NULL",
    "SELECT k FROM t WHERE ship LIKE 'MODE1%' AND price IS NOT NULL",
]


def test_sql_pushdown_matches_frame_residual():
    data, t = _table()
    f = TensorFrame.from_arrays(data)
    for q in _QUERIES:
        a = sql.execute(q, {"t": t}).column("k")
        b = sql.execute(q, {"t": f}).column("k")
        np.testing.assert_array_equal(np.sort(a), np.sort(b), err_msg=q)


def test_sql_pushdown_lands_in_scan():
    _, t = _table()
    plan = sql.explain(
        "SELECT k FROM t WHERE price IS NULL AND cat LIKE 'alph%'", {"t": t}
    )
    assert "pushed=" in plan
    assert "IS NULL" in plan.split("pushed=")[1]
    assert "LIKE" in plan.split("pushed=")[1]
    # non-prefix LIKE must stay a residual Filter
    plan2 = sql.explain("SELECT k FROM t WHERE cat LIKE '%eta'", {"t": t})
    opt = plan2.split("== optimized plan ==")[1]
    assert "Filter" in opt


# ----------------------------------------------------------------------
# bounded (LRU) dictionary pool
# ----------------------------------------------------------------------
def _dic(i):
    return np.array([f"v{i}a", f"v{i}b"], dtype=object)


def test_pool_interning_still_identical():
    p = StringPool(max_entries=8)
    a = p.intern(_dic(1))
    b = p.intern(_dic(1))
    assert a is b and p.hits == 1


def test_pool_evicts_past_bound_lru_order():
    p = StringPool(max_entries=3)
    first = p.intern(_dic(0))
    for i in range(1, 4):
        p.intern(_dic(i))
    assert len(p) == 3 and p.evictions == 1
    # dict 0 (least recently used) was evicted: re-interning misses
    again = p.intern(_dic(0))
    assert again is not first
    # but content equality still holds — eviction is always safe
    np.testing.assert_array_equal(again, first)


def test_pool_lru_touch_protects_hot_entries():
    p = StringPool(max_entries=2)
    hot = p.intern(_dic(0))
    p.intern(_dic(1))
    assert p.intern(_dic(0)) is hot  # touch 0 -> 1 becomes LRU
    p.intern(_dic(2))  # evicts 1, not 0
    assert p.intern(_dic(0)) is hot


def test_pool_clear_and_unbounded():
    p = StringPool(max_entries=None)
    for i in range(64):
        p.intern(_dic(i))
    assert len(p) == 64 and p.evictions == 0
    p.clear()
    assert len(p) == 0 and p.hits == 0 and p.misses == 0


def test_process_pool_is_bounded():
    from repro.store.pool import POOL

    assert POOL.max_entries is not None
