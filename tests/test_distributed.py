"""Multi-device tests (8 forced host devices, run in subprocesses so
the device-count flag never leaks into other tests)."""
import importlib.util
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Three of these tests exercise ``repro.dist`` (compression / pipeline /
# distributed dataframe ops), a subsystem that has not been implemented
# yet (see ROADMAP.md open items).  Skip rather than fail so tier-1
# reports real regressions only.
requires_dist = pytest.mark.skipif(
    importlib.util.find_spec("repro.dist") is None,
    reason="repro.dist subsystem not implemented yet",
)


def run_py(body: str, ndev: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", body],
        env=env,
        capture_output=True,
        text=True,
        timeout=900,
        cwd=REPO,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@requires_dist
def test_grad_compression_matches_exact_mean():
    run_py(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.dist.compression import compressed_mean, quantize

mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
g = jnp.asarray(rng.normal(size=(8, 1024)).astype(np.float32))

def f(gl):
    mean, resid = compressed_mean(gl[0], "data")
    return mean[None], resid[None]

fn = shard_map(f, mesh=mesh, in_specs=(P("data"),), out_specs=(P("data"), P("data")), check_rep=False)
mean, resid = fn(g)
exact = g.mean(axis=0)
for i in range(8):
    err = np.abs(np.asarray(mean[i]) - np.asarray(exact)).max()
    scale = np.abs(np.asarray(exact)).max() + 1e-6
    assert err < 0.02 * max(scale, 1.0), err
# error feedback: residual equals quantization error
q, s, r = quantize(g[0])
deq = (np.asarray(q, np.float32).reshape(-1, 256) * np.asarray(s)).reshape(-1)[:1024]
np.testing.assert_allclose(np.asarray(g[0]) - deq, np.asarray(r), atol=1e-6)
print("OK")
"""
    )


@requires_dist
def test_pipeline_matches_sequential():
    run_py(
        """
import numpy as np, jax, jax.numpy as jnp
from repro.dist.pipeline import pipeline_forward

mesh = jax.make_mesh((4,), ("pipe",))
rng = np.random.default_rng(1)
L, D = 8, 16
W = jnp.asarray(rng.normal(size=(L, D, D)).astype(np.float32) * 0.3)

def block(w, x):
    return jnp.tanh(x @ w)

x = jnp.asarray(rng.normal(size=(6, 4, D)).astype(np.float32))  # 6 microbatches

# sequential reference
def seq(x):
    for l in range(L):
        x = block(W[l], x)
    return x
ref = jax.vmap(seq)(x)

got = pipeline_forward(mesh, block, W, x, n_layers=L)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-5)
print("OK")
"""
    )


@requires_dist
def test_distributed_groupby_and_join():
    run_py(
        """
import numpy as np, jax, jax.numpy as jnp
from repro.dist.dframe import dist_groupby_sum, dist_semi_join_mask, dist_repartition_by_key

mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(2)
n, domain = 4096, 37
keys = jnp.asarray(rng.integers(0, domain, n).astype(np.int32))
vals = jnp.asarray(rng.normal(size=n).astype(np.float32))

got = dist_groupby_sum(mesh, keys, vals, domain)
want = np.zeros(domain, np.float32)
np.add.at(want, np.asarray(keys), np.asarray(vals))
np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)

build = jnp.asarray(rng.choice(np.arange(100), 64, replace=False).astype(np.int32))
probe = jnp.asarray(rng.integers(0, 100, n).astype(np.int32))
mask = dist_semi_join_mask(mesh, probe, build)
want_mask = np.isin(np.asarray(probe), np.asarray(build))
np.testing.assert_array_equal(np.asarray(mask), want_mask)

k2, v2, valid, dropped = dist_repartition_by_key(mesh, keys, vals, capacity=n)
assert int(dropped) == 0
# every row preserved; each key's rows land on one shard
k2n = np.asarray(k2)[np.asarray(valid)]
v2n = np.asarray(v2)[np.asarray(valid)]
assert k2n.shape[0] == n
got_sum = np.zeros(domain, np.float32)
np.add.at(got_sum, k2n, v2n)
np.testing.assert_allclose(got_sum, want, rtol=1e-4)
print("OK")
"""
    )


@requires_dist
def test_repartition_lossless_under_skew():
    """capacity >= n must mean dropped == 0 even when every row routes
    to ONE destination shard and n does not divide the mesh size
    (regression: floor-divided bucket sizing lost rows)."""
    run_py(
        """
import numpy as np, jax, jax.numpy as jnp
from repro.dist.dframe import dist_repartition_by_key

mesh = jax.make_mesh((8,), ("data",))
n = 500  # not a multiple of 8
keys = jnp.asarray(np.full(n, 7, dtype=np.int64))  # all rows -> one shard
vals = jnp.asarray(np.arange(n, dtype=np.float32))
k2, v2, valid, dropped = dist_repartition_by_key(mesh, keys, vals, capacity=n)
assert int(dropped) == 0, int(dropped)
kept = np.asarray(v2)[np.asarray(valid)]
assert kept.shape[0] == n
np.testing.assert_allclose(np.sort(kept), np.arange(n, dtype=np.float32))
print("OK")
"""
    )


@pytest.mark.slow
def test_elastic_checkpoint_reshard():
    """Checkpoint on a 1-device run restores onto an 8-device mesh."""
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        run_py(
            f"""
import jax, numpy as np
from repro.configs import get
from repro.models.config import reduced
from repro.train import checkpoint
from repro.train.train_step import init_train_state
cfg = reduced(get("phi3-mini-3.8b"), n_layers=2)
state = init_train_state(cfg, jax.random.PRNGKey(0))
checkpoint.save(state, {d!r}, 3)
print("SAVED")
""",
            ndev=1,
        )
        run_py(
            f"""
import jax, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get
from repro.models.config import reduced
from repro.models import partition
from repro.train import checkpoint
from repro.train.train_step import init_train_state

cfg = reduced(get("phi3-mini-3.8b"), n_layers=2)
mesh = jax.make_mesh((4, 2), ("data", "model"))
like = jax.eval_shape(lambda: init_train_state(cfg, jax.random.PRNGKey(0)))
pspecs = partition.param_specs(like["params"])
from repro.train.optimizer import get_optimizer
opt = get_optimizer(cfg.optimizer)
specs = {{"params": pspecs, "opt": opt.state_specs(pspecs, like["params"]), "step": P()}}
shardings = partition.shardings_from_specs(mesh, specs)
state = checkpoint.restore({d!r}, like, shardings=shardings)
assert int(jax.device_get(state["step"])) == 0
leaf = state["params"]["blocks"]["attn"]["wq"]
assert len(leaf.sharding.device_set) == 8
print("RESHARDED", leaf.sharding)
""",
            ndev=8,
        )


@pytest.mark.slow
def test_dryrun_cell_on_tiny_mesh():
    """The dry-run driver itself, on an 8-device (4,2) placeholder mesh
    with a reduced config — exercises lower+compile+analysis quickly."""
    run_py(
        """
import os
os.environ.setdefault("DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import jax
import repro.launch.dryrun as dr
import repro.launch.mesh as meshmod

meshmod.make_production_mesh = lambda multi_pod=False: jax.make_mesh(
    (2, 2, 2) if multi_pod else (4, 2),
    ("pod", "data", "model") if multi_pod else ("data", "model"),
)
dr.make_production_mesh = meshmod.make_production_mesh

cell = dr.run_cell(
    "qwen3-14b", "train_4k", multi_pod=False,
    extra=dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
               vocab=256, head_dim=16, microbatches=2, q_chunk=64,
               param_dtype="float32", compute_dtype="float32"),
)
assert cell["status"] == "ok", cell
assert cell["flops_per_device"] > 0
assert cell["collective_bytes_total"] > 0, cell["collectives"]
assert cell["roofline"]["dominant"] in ("compute", "memory", "collective")
cell2 = dr.run_cell(
    "qwen3-14b", "train_4k", multi_pod=True,
    extra=dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
               vocab=256, head_dim=16, microbatches=2, q_chunk=64,
               param_dtype="float32", compute_dtype="float32"),
)
assert cell2["status"] == "ok", cell2
print("OK", cell["roofline"]["dominant"], cell["collectives"])
"""
    )
