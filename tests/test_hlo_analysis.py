"""Calibration tests for the HLO roofline analyzer: known programs with
known FLOP/collective counts, including scan (while-loop) trip
weighting — run in subprocesses with forced multi-device CPU."""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str, ndev: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", body],
        env=env, capture_output=True, text=True, timeout=600, cwd=REPO,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_scan_dot_flops_trip_weighted():
    run_py(
        """
import jax, jax.numpy as jnp
from repro.launch import hlo_analysis

L, D, B = 8, 256, 64
def f(ws, x):
    def body(x, w):
        return jnp.tanh(x @ w), None
    y, _ = jax.lax.scan(body, x, ws)
    return y

ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
x = jax.ShapeDtypeStruct((B, D), jnp.float32)
hlo = jax.jit(f).lower(ws, x).compile().as_text()
a = hlo_analysis.analyze(hlo)
expected = 2.0 * B * D * D * L  # single device
ratio = a["dot_flops"] / expected
assert 0.9 <= ratio <= 1.2, (a["dot_flops"], expected, ratio)
print("OK", ratio)
""",
        ndev=1,
    )


def test_sharded_collective_bytes_detected():
    run_py(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch import hlo_analysis

mesh = jax.make_mesh((4,), ("model",))
D = 512
def f(w, x):
    return (x @ w).sum()  # contraction over sharded dim -> all-reduce

with mesh:
    comp = jax.jit(
        f,
        in_shardings=(NamedSharding(mesh, P("model", None)), NamedSharding(mesh, P(None, "model"))),
    ).lower(
        jax.ShapeDtypeStruct((D, D), jnp.float32), jax.ShapeDtypeStruct((64, D), jnp.float32)
    ).compile()
hlo = comp.as_text()
coll = hlo_analysis.collective_bytes(hlo)
assert coll["total"] > 0, coll
print("OK", coll)
"""
    )


def test_per_device_flops_convention():
    """cost_analysis is per-device: our analyzer on a sharded matmul
    reports ~global/ndev dot flops."""
    run_py(
        """
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch import hlo_analysis

mesh = jax.make_mesh((4,), ("data",))
B, D = 256, 256
def f(w, x):
    return x @ w

with mesh:
    comp = jax.jit(
        f,
        in_shardings=(NamedSharding(mesh, P(None, None)), NamedSharding(mesh, P("data", None))),
    ).lower(
        jax.ShapeDtypeStruct((D, D), jnp.float32), jax.ShapeDtypeStruct((B, D), jnp.float32)
    ).compile()
a = hlo_analysis.analyze(comp.as_text())
global_flops = 2.0 * B * D * D
ratio = a["dot_flops"] / (global_flops / 4)
assert 0.9 <= ratio <= 1.2, (a, ratio)
print("OK", ratio)
"""
    )
