"""Shared benchmark infrastructure: timing, data caching, reporting.

Output contract (benchmarks/run.py): CSV lines ``name,us_per_call,derived``
on stdout; ``write_json(path)`` additionally dumps the collected rows as
a JSON document (used by the CI bench-smoke job's artifact).

Every ``measure()`` also captures an observability profile for the row
it feeds: the ``repro.obs.metrics`` counter diff across the timed
repeats (free — two jax-free snapshots), and, when
``REPRO_BENCH_PROFILE=1`` is set and the workload is fast enough, a
per-operator wall-time breakdown from one *extra* traced call after
timing finishes.  The timed region itself always runs with whatever
``CONFIG.tracing`` the suite configured (default: off), so profiles
never contaminate the numbers the regression gate compares.
"""
from __future__ import annotations

import functools
import gc
import json
import os
import platform
import time
from typing import Callable, Optional

ROWS = []

#: Profile captured by the most recent ``measure()`` call; ``report``
#: consumes (and clears) it, attaching it to the row it records.
LAST_PROFILE: Optional[dict] = None

#: Skip the extra traced profiling call for workloads slower than this
#: (seconds) — the breakdown is not worth doubling a slow bench's cost.
_PROFILE_BUDGET_S = 2.0


def measure(fn: Callable, *, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall seconds per call."""
    global LAST_PROFILE
    from repro.obs import metrics

    for _ in range(warmup):
        fn()
    before = metrics.snapshot()
    times = []
    for _ in range(repeats):
        gc.collect()
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    counters = metrics.diff(before, metrics.snapshot())
    times.sort()
    med = times[len(times) // 2]
    profile = {"counters": counters, "repeats": repeats}
    if os.environ.get("REPRO_BENCH_PROFILE") and med < _PROFILE_BUDGET_S:
        profile.update(_traced_profile(fn))
    LAST_PROFILE = profile
    return med


def _traced_profile(fn: Callable) -> dict:
    """One extra call under ``CONFIG.tracing='on'``: per-operator wall
    time aggregated from the recorded spans.  Outside the timed region."""
    from repro import obs
    from repro.core.config import CONFIG

    saved = CONFIG.tracing
    if saved == "off":
        CONFIG.tracing = "on"
    mark = obs.mark_ns()
    try:
        fn()
    except Exception:
        return {}
    finally:
        CONFIG.tracing = saved
    records = obs.spans(since_ns=mark)
    return {
        "operators": obs.aggregate_operators(records),
        "spans_recorded": len(records),
    }


def report(name: str, seconds: float, derived: str = "") -> None:
    global LAST_PROFILE
    profile, LAST_PROFILE = LAST_PROFILE, None
    ROWS.append((name, seconds * 1e6, derived, profile))
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def write_json(path: str) -> None:
    """Dump every reported row (plus host metadata) as JSON."""
    rows = []
    for name, us, derived, profile in ROWS:
        row = {"name": name, "us_per_call": us, "derived": derived}
        if profile:
            row["profile"] = profile
        rows.append(row)
    doc = {
        "schema": "repro-bench/v1",
        "host": {
            "platform": platform.platform(),
            "python": platform.python_version(),
        },
        "generated_at": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "rows": rows,
    }
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"# wrote {len(ROWS)} rows to {path}", flush=True)


def compare_baseline(path: str, *, factor: float = 2.5, min_us: float = 500.0) -> bool:
    """Gate the collected ROWS against a committed baseline document.

    Returns False (and prints the offenders) when any row shared with
    the baseline got more than ``factor`` times slower.  Rows faster
    than ``min_us`` in this run are ignored — micro-rows on shared CI
    runners are too noisy to gate on.  Rows missing from either side
    (new benchmarks, retired benchmarks) never fail the gate.
    """
    with open(path) as fh:
        doc = json.load(fh)
    base = {r["name"]: float(r["us_per_call"]) for r in doc.get("rows", [])}
    offenders = []
    # a crashed suite would otherwise produce no comparable rows and
    # sail through the gate (and poison the next baseline refresh)
    crashed = [name for name, _, _, _ in ROWS if "SUITE_ERROR" in name]
    compared = 0
    for name, us, _, _ in ROWS:
        b = base.get(name)
        if b is None or b <= 0 or "SUITE_ERROR" in name:
            continue
        compared += 1
        if us > min_us and us > factor * b:
            offenders.append((name, b, us))
    for name, b, us in offenders:
        print(
            f"# REGRESSION {name}: {b:.1f}us -> {us:.1f}us "
            f"({us / b:.2f}x, limit {factor}x)",
            flush=True,
        )
    for name in crashed:
        print(f"# SUITE CRASHED: {name} — failing the gate", flush=True)
    print(
        f"# compare: {compared} rows vs {path}, "
        f"{len(offenders)} regression(s) beyond {factor}x, "
        f"{len(crashed)} crashed suite(s)",
        flush=True,
    )
    return not offenders and not crashed


@functools.lru_cache(maxsize=4)
def tpch_tables(sf: float, seed: int = 0):
    from repro.data import tpch

    return tpch.generate(sf=sf, seed=seed)


@functools.lru_cache(maxsize=4)
def tpch_frames(sf: float, seed: int = 0):
    from repro.data import tpch

    return tpch.as_frames(tpch_tables(sf, seed))


@functools.lru_cache(maxsize=2)
def tpcds_tables(sf: float, seed: int = 1):
    from repro.data import tpcds

    return tpcds.generate(sf=sf, seed=seed)


@functools.lru_cache(maxsize=2)
def tpcds_frames(sf: float, seed: int = 1):
    from repro.data import tpcds

    return tpcds.as_frames(tpcds_tables(sf, seed))
