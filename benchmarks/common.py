"""Shared benchmark infrastructure: timing, data caching, reporting.

Output contract (benchmarks/run.py): CSV lines ``name,us_per_call,derived``.
"""
from __future__ import annotations

import functools
import gc
import time
from typing import Callable, Dict, Optional

ROWS = []


def measure(fn: Callable, *, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall seconds per call."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        gc.collect()
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def report(name: str, seconds: float, derived: str = "") -> None:
    ROWS.append((name, seconds * 1e6, derived))
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


@functools.lru_cache(maxsize=4)
def tpch_tables(sf: float, seed: int = 0):
    from repro.data import tpch

    return tpch.generate(sf=sf, seed=seed)


@functools.lru_cache(maxsize=4)
def tpch_frames(sf: float, seed: int = 0):
    from repro.data import tpch

    return tpch.as_frames(tpch_tables(sf, seed))


@functools.lru_cache(maxsize=2)
def tpcds_tables(sf: float, seed: int = 1):
    from repro.data import tpcds

    return tpcds.generate(sf=sf, seed=seed)


@functools.lru_cache(maxsize=2)
def tpcds_frames(sf: float, seed: int = 1):
    from repro.data import tpcds

    return tpcds.as_frames(tpcds_tables(sf, seed))
