"""repro.store: zone-map scan pushdown, interned dictionary merges,
and .tfb v1-vs-v2 load time.

Three lanes (ISSUE 4 acceptance):

- **selectivity sweep** — TPC-H lineitem-shaped data, date-clustered
  (the layout real fact tables have), scanned at ~10% / ~1% / ~0.1%
  date selectivity with the predicate pushed into the store vs. a full
  materialize.  The acceptance bar is >=5x at <=1% selectivity;
  ``derived`` reports the measured speedup and the chunk skip ratio.
- **dictionary merges** — ``merge_dictionaries`` on two equal
  dictionaries: cold (distinct objects: concatenate + re-sort) vs
  interned (same pooled object: identity remap).
- **load time** — the same table written as flat ``.tfb`` v1 and
  chunked v2: full column loads for both, the lazy v2 open (manifest
  only), and a v2 predicate load that only touches surviving chunks.

Scan calls are microsecond-scale, so each lane times a tight loop and
reports per-call cost (single-call timing is dominated by allocator
noise at this size).
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np

from .common import report, tpch_tables


def _per_call(fn, loops: int) -> float:
    """Seconds per call over a warm tight loop."""
    fn()
    t0 = time.perf_counter()
    for _ in range(loops):
        fn()
    return (time.perf_counter() - t0) / loops


def _lineitem_sorted(sf: float):
    """lineitem columns, clustered by l_shipdate (zone-map-friendly)."""
    li = tpch_tables(sf)["lineitem"]
    order = np.argsort(li["l_shipdate"], kind="stable")
    return {k: v[order] for k, v in li.items()}


def _date_cut(dates: np.ndarray, selectivity: float) -> np.datetime64:
    """A >= cut-off hitting ~``selectivity`` of the (sorted) rows."""
    i = int(round((1.0 - selectivity) * (dates.shape[0] - 1)))
    return dates[i]


def run(sf: float = 0.01, quick: bool = False):
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro import store
    from repro.core import TensorFrame, col, io as tio

    li = _lineitem_sorted(sf)
    chunk_rows = 1 << 11 if quick else 1 << 13
    table = store.Table.from_arrays(li, chunk_rows=chunk_rows)
    n = li["l_orderkey"].shape[0]
    proj = ["l_orderkey", "l_extendedprice", "l_discount", "l_quantity"]
    loops = 100 if quick else 200

    t_full = _per_call(lambda: store.scan(table, proj), loops)
    report(
        "store/scan/full", t_full,
        f"n={n};chunks={table.n_chunks};chunk_rows={chunk_rows}",
    )
    for label, sel in (("10pct", 0.10), ("1pct", 0.01), ("01pct", 0.001)):
        cut = _date_cut(li["l_shipdate"], sel)
        preds = [store.Pred("l_shipdate", ">=", cut)]
        r = store.scan(table, proj, preds)
        t_push = _per_call(lambda: store.scan(table, proj, preds), loops)
        report(
            f"store/scan/sel_{label}",
            t_push,
            f"speedup={t_full / t_push:.1f}x;"
            f"skipped={r.chunks_skipped}/{r.chunks_total};rows={r.nrows}",
        )

    # frame-level: pushdown vs materialize-everything-then-filter
    cut = _date_cut(li["l_shipdate"], 0.01)
    preds = [store.Pred("l_shipdate", ">=", cut)]
    t_frame_push = _per_call(
        lambda: TensorFrame.from_store(table, proj, preds), 10
    )
    cutd = int(cut.astype("datetime64[D]").astype(np.int64))
    t_frame_full = _per_call(
        lambda: TensorFrame.from_store(table, proj + ["l_shipdate"]).filter(
            col("l_shipdate") >= cutd
        ),
        10,
    )
    report(
        "store/frame/pushdown_1pct",
        t_frame_push,
        f"vs_full_scan={t_frame_full / t_frame_push:.1f}x",
    )

    # ---- interned vs cold dictionary merges -------------------------
    from repro.core import encoding

    words = np.array(
        sorted({f"word{i:06d}" for i in range(20_000 if not quick else 5_000)}),
        dtype=object,
    )
    cold_a, cold_b = words.copy(), words.copy()
    interned_a = store.intern_dictionary(words)
    interned_b = store.intern_dictionary(words.copy())
    assert interned_a is interned_b
    t_cold = _per_call(
        lambda: encoding.merge_dictionaries(cold_a, cold_b), 10
    )
    t_interned = _per_call(
        lambda: encoding.merge_dictionaries(interned_a, interned_b), loops
    )
    report("store/dict_merge/cold", t_cold, f"k={words.shape[0]}")
    report(
        "store/dict_merge/interned",
        t_interned,
        f"speedup={t_cold / max(t_interned, 1e-9):.1f}x",
    )

    # ---- .tfb v1 vs v2 load -----------------------------------------
    d = tempfile.mkdtemp(prefix="tfb_store_bench_")
    try:
        p1, p2 = os.path.join(d, "li_v1"), os.path.join(d, "li_v2")
        tio.write_tfb(p1, li, version=1)
        tio.write_tfb(p2, li, version=2, chunk_rows=chunk_rows)
        t_v1 = _per_call(lambda: tio.read_tfb_arrays(p1, proj), 5)
        t_v2 = _per_call(lambda: tio.read_tfb_arrays(p2, proj), 5)
        report("store/load/v1_full", t_v1, f"n={n}")
        report("store/load/v2_full", t_v2, f"vs_v1={t_v1 / t_v2:.1f}x")
        t_open = _per_call(lambda: store.open_store(p2), 5)
        report("store/load/v2_open_lazy", t_open, "manifest+stats only")
        t_v2_pred = _per_call(
            lambda: store.scan(store.open_store(p2), proj, preds), 5
        )
        report(
            "store/load/v2_pushdown_1pct",
            t_v2_pred,
            f"vs_v1_full={t_v1 / t_v2_pred:.1f}x;"
            f"vs_v2_full={t_v2 / t_v2_pred:.1f}x",
        )
    finally:
        shutil.rmtree(d, ignore_errors=True)


if __name__ == "__main__":
    run(quick=True)
