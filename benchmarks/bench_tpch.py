"""Fig. 6: all 22 TPC-H queries — TensorFrame vs the row-at-a-time
Python reference (the Pandas-apply analog).  Reports per-query times
and the row/tensor speedup ratio."""
from __future__ import annotations

from .common import measure, report, tpch_frames, tpch_tables


def run(sf: float = 0.01, quick: bool = False, row_engine: bool = True):
    tables = tpch_tables(sf)
    frames = tpch_frames(sf)
    from repro.queries import tpch_frames as QF
    from repro.queries import tpch_numpy as QN

    qnames = [f"q{i}" for i in range(1, 23)]
    if quick:
        qnames = ["q1", "q3", "q6", "q9", "q13", "q16", "q18"]
    for qname in qnames:
        tf = measure(lambda: QF.ALL[qname](frames, sf=sf), repeats=3 if not quick else 1)
        if row_engine:
            tr = measure(lambda: QN.ALL[qname](tables, sf=sf), repeats=1, warmup=0)
            report(f"tpch/{qname}/tensorframe", tf, f"sf={sf}")
            report(f"tpch/{qname}/rowpython", tr, f"speedup={tr / tf:.1f}x")
        else:
            report(f"tpch/{qname}/tensorframe", tf, f"sf={sf}")
