"""Late-materialization join chains + stats-driven algorithm pick
(ISSUE 5 acceptance lanes).

Three lanes over a q5/q9-shaped star schema (one wide fact table,
three unique-key dimensions backed by store tables so zone-map stats
flow into the frames):

- **chain3 pipeline** — fact ⋈ orders ⋈ supplier ⋈ part then a
  grouped sum, run with late materialization ON (RowView selection
  vectors compose; payloads gather once at the group-by) vs OFF (the
  seed engine: every join copies every payload column).  The ISSUE 5
  acceptance bar is >=2x; ``derived`` reports the measured speedup.
- **chain3 join-only** — the same 3-join chain without the aggregate,
  materialized once at the end: the wall-time proxy for the removed
  per-join host syncs + payload copies.
- **auto pick** — fact ⋈ orders with ``algorithm="auto"`` answered by
  the stats cache (zone maps prove the build side unique: direct
  address, no build sort) vs forced ``"sorted"`` (what every join paid
  before stats threading).  ``derived`` includes the decision counters.
"""
from __future__ import annotations

import numpy as np

from .common import measure, report


def _star(n_fact: int, seed: int = 0):
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro import store
    from repro.core import TensorFrame

    rng = np.random.default_rng(seed)
    sizes = {
        "okey": n_fact // 8,  # orders
        "skey": n_fact // 60,  # supplier
        "pkey": n_fact // 30,  # part
        "ckey": n_fact // 10,  # customer
    }
    # lineitem-shaped width: 4 FKs + 10 measures + 4 int attributes
    fact = {
        **{k: rng.integers(0, nd, n_fact) for k, nd in sizes.items()},
        "price": rng.random(n_fact) * 1e4,
        "disc": rng.random(n_fact) * 0.1,
        "tax": rng.random(n_fact) * 0.08,
        **{f"m{i}": rng.random(n_fact) for i in range(7)},
        "qty": rng.integers(1, 50, n_fact),
        "flag": rng.integers(0, 3, n_fact),
        "ship": rng.integers(8000, 12000, n_fact),
        "commit": rng.integers(8000, 12000, n_fact),
    }
    dims = {}
    for key, nd in sizes.items():
        # orders-shaped dimension payloads ride along through the chain
        data = {
            key: np.arange(nd),
            f"attr_{key}": rng.integers(0, 25, nd),
            **{f"{key}_f{i}": rng.random(nd) for i in range(4)},
            **{f"{key}_i{i}": rng.integers(0, 99, nd) for i in range(3)},
        }
        # store-backed: chunk zone maps prove the key unique, seeding
        # the frame stats cache consumed by join(algorithm='auto')
        table = store.Table.from_arrays(data, chunk_rows=max(256, nd // 8))
        dims[key] = TensorFrame.from_store(table)
    return TensorFrame.from_arrays(fact), dims


def _interleaved(fn, reps: int = 9):
    """Best-of-reps seconds per mode, measured INTERLEAVED (late,
    eager, late, eager, ...) so allocator drift and background noise
    hit both modes equally — a per-mode tight loop does not.  Minimum
    (not median) because shared-box noise is strictly additive."""
    import gc
    import time

    from repro.core.config import CONFIG

    for mode in (True, False):  # warmup both modes (XLA kernel caches)
        CONFIG.late_materialization = mode
        fn()
    samples = {True: [], False: []}
    try:
        for _ in range(reps):
            for mode in (True, False):
                CONFIG.late_materialization = mode
                gc.collect()
                t0 = time.perf_counter()
                fn()
                samples[mode].append(time.perf_counter() - t0)
    finally:
        CONFIG.late_materialization = True
    return min(samples[True]), min(samples[False])


def run(sf: float = 0.01, quick: bool = False):
    import importlib

    from repro.core import TensorFrame  # noqa: F401  (x64 flip in _star)

    join_mod = importlib.import_module("repro.core.join")

    # 250k keeps quick mode under ~30s while the eager baseline's wide
    # intermediates are already past cache (the regime q5/q9 live in)
    n_fact = 250_000 if quick else 500_000
    fact, dims = _star(n_fact)
    chain = list(dims)  # okey, skey, pkey, ckey — a q5-shaped 4-chain

    def chain_pipeline() -> float:
        out = fact
        for key in chain:
            out = out.join(dims[key], on=key)
        res = out.groupby("attr_skey").agg([("rev", "sum", "price")])
        return float(np.asarray(res.col_values("rev")).sum())

    def chain_join_only() -> None:
        out = fact
        for key in chain:
            out = out.join(dims[key], on=key)
        out.materialize().itensor.block_until_ready()

    t_pipe_late, t_pipe_eager = _interleaved(chain_pipeline)
    t_join_late, t_join_eager = _interleaved(chain_join_only)

    report(
        "join/chain4/late",
        t_pipe_late,
        f"n={n_fact};joins={len(chain)};"
        f"speedup_vs_eager={t_pipe_eager / t_pipe_late:.1f}x",
    )
    report("join/chain4/eager", t_pipe_eager, f"n={n_fact}")
    report(
        "join/chain4_joinonly/late",
        t_join_late,
        f"speedup_vs_eager={t_join_eager / t_join_late:.1f}x",
    )
    report("join/chain4_joinonly/eager", t_join_eager, "")

    # ---- stats-driven auto pick vs forced build sort ----------------
    orders = dims["okey"]
    join_mod.reset_stats()
    t_auto = measure(lambda: fact.join(orders, on="okey").nrows)
    stats = dict(join_mod.STATS)
    t_sorted = measure(
        lambda: fact.join(orders, on="okey", algorithm="sorted").nrows
    )
    report(
        "join/auto_pick/stats_direct",
        t_auto,
        f"vs_sorted={t_sorted / t_auto:.1f}x;"
        f"stats_hits={stats['stats_unique_hits']};"
        f"sort_tests={stats['uniqueness_sort_tests']}",
    )
    report("join/auto_pick/forced_sorted", t_sorted, "")
