"""Fig. 7: query time vs dataset scale — near-linear scaling check.
Fits log(time) ~ a*log(sf); a ≈ 1 is linear."""
from __future__ import annotations

import numpy as np

from .common import measure, report, tpch_frames


def run(quick: bool = False):
    from repro.queries import tpch_frames as QF

    sfs = [0.004, 0.008, 0.016] if quick else [0.004, 0.008, 0.016, 0.032]
    for qname in ("q1", "q6", "q9", "q13"):
        times = []
        for sf in sfs:
            frames = tpch_frames(sf)
            t = measure(lambda: QF.ALL[qname](frames, sf=sf), repeats=2, warmup=1)
            times.append(t)
            report(f"scaling/{qname}/sf{sf}", t)
        a = np.polyfit(np.log(sfs), np.log(times), 1)[0]
        report(f"scaling/{qname}/exponent", 0.0, f"alpha={a:.2f} (1.0=linear)")
