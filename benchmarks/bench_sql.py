"""SQL front-end vs hand-written TensorFrame plans on TPC-H.

Three timings per query:

- ``handplan``  — the hand-translated ``tpch_frames`` plan (the paper's
  Fig. 5/6 style),
- ``sql``       — ``repro.sql.execute`` with the optimizer on,
- ``sql_noopt`` — same SQL, optimizer off (filter pushdown, projection
  pruning and constant folding disabled),

so both the compilation overhead of the SQL layer (sql vs handplan)
and the optimizer's pushdown win (sql_noopt vs sql) are measurable.
"""
from __future__ import annotations

from .common import measure, report, tpch_frames


# q4 and q22 exercise the decorrelation path (semi/anti joins, attached
# scalars) so the quick lane also times the subquery machinery
QUICK_QUERIES = ("q1", "q3", "q4", "q6", "q14", "q22")


def run(sf: float = 0.01, quick: bool = False):
    from repro import sql
    from repro.queries import tpch_frames as QF
    from repro.queries.tpch_sql import TPCH_SQL, sql_text

    frames = tpch_frames(sf)
    qnames = sorted(TPCH_SQL, key=lambda s: int(s[1:]))
    if quick:
        qnames = [q for q in qnames if q in QUICK_QUERIES]
    repeats = 1 if quick else 3
    for qname in qnames:
        text = sql_text(qname, sf)
        t_hand = measure(
            lambda: QF.ALL[qname](frames, sf=sf, apply_limit=False),
            repeats=repeats,
        )
        t_sql = measure(lambda: sql.execute(text, frames), repeats=repeats)
        t_noopt = measure(
            lambda: sql.execute(text, frames, optimize=False), repeats=repeats
        )
        report(f"sql/{qname}/handplan", t_hand, f"sf={sf}")
        report(f"sql/{qname}/sql", t_sql, f"vs_hand={t_sql / t_hand:.2f}x")
        report(
            f"sql/{qname}/sql_noopt",
            t_noopt,
            f"pushdown_speedup={t_noopt / t_sql:.2f}x",
        )


if __name__ == "__main__":
    run(quick=True)
