"""Out-of-core chunk-pipelined execution vs eager (ISSUE 8 acceptance).

Lanes over a date-clustered lineitem store, all through the SQL layer:

- **q1/eager vs q1/ooc_uncapped** — TPC-H q1 (the widest streaming
  group-by) at a realistic scale floor (sf >= 0.1, 64Ki-row chunks):
  whole-scan materialize + one group-by vs ``out_of_core=force`` with
  no memory budget (chunk-pipelined scan + streaming partials, nothing
  spilled).  The per-chunk dispatch overhead only amortizes with
  full-size chunks, so these lanes pin their own scale instead of the
  suite's quick sf.  The acceptance bar is within 2x of eager;
  ``derived`` reports the ratio.
- **hicard/capped@{1MiB,256KiB,64KiB}** — a high-cardinality group-by
  (``GROUP BY l_orderkey``: partial pools are tens of thousands of
  rows, unlike q1's four groups) under a shrinking
  ``memory_budget_bytes``: partials spill to ``.tfb`` and re-hydrate
  on merge.  ``derived`` reports spilled/re-read bytes and evictions
  from ``core.pipeline.STATS``.
- **overlap** — the same q1 stream over a *disk-backed* ``.tfb`` copy
  of the store (chunk decode actually costs something) with
  ``ooc_prefetch=0`` (strictly alternating decode and compute) vs the
  default prefetch depth, isolating the win from decoding chunk k+1 on
  the host while the device works on chunk k.
"""
from __future__ import annotations

import os
import shutil
import tempfile

import numpy as np

from .common import measure, report, tpch_tables


def _lineitem_store(sf: float, chunk_rows: int):
    from repro import store

    li = tpch_tables(sf)["lineitem"]
    order = np.argsort(li["l_shipdate"], kind="stable")
    li = {k: v[order] for k, v in li.items()}
    return store.Table.from_arrays(li, chunk_rows=chunk_rows)


def run(sf: float = 0.01, quick: bool = False):
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro import sql
    from repro.core import pipeline
    from repro.core.config import CONFIG
    from repro.queries.tpch_sql import sql_text

    # q1 lanes need full-size chunks to amortize per-chunk dispatch;
    # hicard lanes need small chunks + small budgets to exercise spill
    big = _lineitem_store(max(sf, 0.1), 1 << 16)
    small = _lineitem_store(sf, 1 << 13 if quick else 1 << 15)
    big_scope = {"lineitem": big}
    scope = {"lineitem": small}
    q1 = sql_text("q1")
    hicard = (
        "SELECT l_orderkey, SUM(l_extendedprice) AS revenue, "
        "COUNT(*) AS n, MAX(l_quantity) AS maxq "
        "FROM lineitem GROUP BY l_orderkey"
    )
    repeats = 2 if quick else 5

    saved = (
        CONFIG.out_of_core,
        CONFIG.memory_budget_bytes,
        CONFIG.ooc_prefetch,
    )
    try:
        CONFIG.out_of_core = "off"
        CONFIG.memory_budget_bytes = None
        t_eager = measure(lambda: sql.execute(q1, big_scope), repeats=repeats)
        report(
            "spill/q1/eager",
            t_eager,
            f"n={big.nrows};chunks={big.n_chunks}",
        )

        CONFIG.out_of_core = "force"
        pipeline.reset_stats()
        t_ooc = measure(lambda: sql.execute(q1, big_scope), repeats=repeats)
        pipeline.sync_spill_stats()
        ratio = t_ooc / t_eager
        report(
            "spill/q1/ooc_uncapped",
            t_ooc,
            f"vs_eager={ratio:.2f}x;within2x={ratio <= 2.0};"
            f"streamed={pipeline.STATS['chunks_streamed']}",
        )

        CONFIG.out_of_core = "off"
        t_hc_eager = measure(lambda: sql.execute(hicard, scope), repeats=repeats)
        report(
            "spill/hicard/eager",
            t_hc_eager,
            f"n={small.nrows};chunks={small.n_chunks}",
        )
        CONFIG.out_of_core = "force"
        for label, budget in (
            ("1MiB", 1 << 20),
            ("256KiB", 1 << 18),
            ("64KiB", 1 << 16),
        ):
            CONFIG.memory_budget_bytes = budget
            pipeline.reset_stats()
            t_cap = measure(lambda: sql.execute(hicard, scope), repeats=repeats)
            pipeline.sync_spill_stats()
            s = pipeline.STATS
            report(
                f"spill/hicard/capped@{label}",
                t_cap,
                f"vs_eager={t_cap / t_hc_eager:.2f}x;"
                f"spilled={s['bytes_spilled']};reread={s['bytes_reread']};"
                f"evictions={s['evictions']};peak={s['peak_tracked_bytes']}",
            )

        CONFIG.memory_budget_bytes = None
        tmp = tempfile.mkdtemp(prefix="bench-spill-")
        try:
            from repro import store

            path = os.path.join(tmp, "lineitem.tfb")
            store.write_store(path, big)
            disk_scope = {"lineitem": store.open_store(path)}
            CONFIG.ooc_prefetch = 0
            t_sync = measure(
                lambda: sql.execute(q1, disk_scope), repeats=repeats
            )
            CONFIG.ooc_prefetch = saved[2]
            t_pre = measure(
                lambda: sql.execute(q1, disk_scope), repeats=repeats
            )
            report(
                "spill/q1/disk_prefetch",
                t_pre,
                f"overlap_win={t_sync / max(t_pre, 1e-9):.2f}x;"
                f"depth={CONFIG.ooc_prefetch}",
            )
        finally:
            shutil.rmtree(tmp, ignore_errors=True)
    finally:
        (
            CONFIG.out_of_core,
            CONFIG.memory_budget_bytes,
            CONFIG.ooc_prefetch,
        ) = saved
