"""Tables I/II: in-memory footprint of TensorFrame representations vs
raw on-disk CSV bytes, whole tables and per-column classes."""
from __future__ import annotations


from .common import report, tpch_frames, tpch_tables


def _csv_bytes(cols: dict) -> int:
    n = next(iter(cols.values())).shape[0]
    total = 0
    for arr in cols.values():
        total += sum(len(str(v)) + 1 for v in arr[: min(n, 20000)]) * max(1, n // min(n, 20000))
    return total


def run(sf: float = 0.01, quick: bool = False):
    tables = tpch_tables(sf)
    frames = tpch_frames(sf)
    for tname in ("partsupp", "lineitem", "orders"):
        f = frames[tname]
        mem = f.memory_bytes()
        total = sum(mem.values())
        disk = _csv_bytes(tables[tname])
        report(
            f"memory/{tname}/total",
            0.0,
            f"mem={total/1e6:.1f}MB disk_csv={disk/1e6:.1f}MB ratio={total/max(disk,1):.2f} "
            f"(itensor={mem['itensor']/1e6:.1f} ftensor={mem['ftensor']/1e6:.1f} "
            f"dicts={mem['dicts']/1e6:.1f} offloaded={mem['offloaded']/1e6:.1f})",
        )

    # Table II: per-column classes on lineitem
    li = tables["lineitem"]
    n = li["l_orderkey"].shape[0]
    specs = {
        "orderkey_int": ("l_orderkey", 8 * n),
        "quantity_float": ("l_quantity", 8 * n),
        "returnflag_lowcard": ("l_returnflag", None),
        "comment_highcard": ("l_comment", None),
    }
    f = frames["lineitem"]
    for label, (colname, tensor_bytes) in specs.items():
        m = f.meta(colname)
        if m.kind in ("int", "float", "date"):
            size = 8 * n
        elif m.kind == "dict":
            size = 8 * n + sum(len(str(s)) + 8 for s in m.dictionary)
        else:
            oc = f.offloaded[colname]
            size = sum(len(str(s)) + 20 for s in oc.values) + 8 * n
        raw = sum(len(str(v)) for v in li[colname][: min(n, 20000)]) * max(1, n // min(n, 20000))
        report(f"memory/lineitem/{label}", 0.0, f"mem={size/1e6:.2f}MB raw={raw/1e6:.2f}MB kind={m.kind}")
