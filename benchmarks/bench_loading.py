"""Fig. 14: data loading — binary columnar (projection pushdown) vs CSV
text parsing, on TPC-H partsupp's 3 needed columns (the Q2 scenario)."""
from __future__ import annotations

import os
import shutil
import tempfile

from .common import measure, report, tpch_tables


def run(sf: float = 0.01, quick: bool = False):
    from repro.core import io as tio

    tables = tpch_tables(sf)
    ps = tables["partsupp"]
    d = tempfile.mkdtemp(prefix="tfb_bench_")
    try:
        tfb_path = os.path.join(d, "partsupp.tfb")
        csv_path = os.path.join(d, "partsupp.csv")
        tio.write_tfb(tfb_path, ps)
        tio.write_csv(csv_path, ps)
        cols = ["ps_partkey", "ps_suppkey", "ps_supplycost"]

        t_bin = measure(lambda: tio.read_tfb_arrays(tfb_path, cols))
        report("loading/partsupp3/binary_pushdown", t_bin, f"n={ps['ps_partkey'].shape[0]}")
        t_csv_cols = measure(lambda: tio.read_csv_arrays(csv_path, cols), repeats=1)
        report("loading/partsupp3/csv_usecols", t_csv_cols, f"slowdown={t_csv_cols / t_bin:.1f}x")
        t_csv_full = measure(lambda: tio.read_csv_arrays(csv_path), repeats=1)
        report("loading/partsupp3/csv_full", t_csv_full, f"slowdown={t_csv_full / t_bin:.1f}x")

        # string-heavy table: the paper's limitation case
        orders_cols = {k: tables["orders"][k] for k in ("o_orderkey", "o_comment")}
        tfb_o = os.path.join(d, "orders.tfb")
        tio.write_tfb(tfb_o, orders_cols)
        t_str = measure(lambda: tio.read_tfb_arrays(tfb_o, ["o_comment"]), repeats=2)
        report("loading/orders_comment/binary", t_str, "string payload")
    finally:
        shutil.rmtree(d, ignore_errors=True)
