"""Distributed op scaling: 1 device vs every visible device.

Run under forced host devices to see the multi-device lanes::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.run --only dist

On a 1-device host only the ``ndev1`` rows are produced (they are the
single-device fallback path, and double as the bench-compare anchor);
with N devices each op is measured on both mesh sizes so the derived
column reports the speedup (host-device "scaling" on CPU mostly checks
the collectives do not regress; real scaling needs accelerators).
"""
from __future__ import annotations

import numpy as np

from .common import measure, report


def run(quick: bool = False):
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.dist import compression, dframe, pipeline

    n = 1 << 16 if quick else 1 << 20
    domain = 1 << 10
    rng = np.random.default_rng(0)
    keys = jnp.asarray(rng.integers(0, domain, n).astype(np.int64))
    vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
    build = jnp.asarray(
        rng.choice(np.arange(4 * domain), domain // 2, replace=False).astype(np.int64)
    )

    ndevs = sorted({1, jax.device_count()})
    times = {}
    for ndev in ndevs:
        mesh = dframe.data_mesh(ndev)

        def gsum():
            return jax.block_until_ready(
                dframe.dist_groupby_sum(mesh, keys, vals, domain)
            )

        def semi():
            return jax.block_until_ready(
                dframe.dist_semi_join_mask(mesh, keys, build)
            )

        def repart():
            return jax.block_until_ready(
                dframe.dist_repartition_by_key(mesh, keys, vals, capacity=n)[0]
            )

        g = jnp.asarray(rng.normal(size=(ndev, n // ndev)).astype(np.float32))

        def f(gl):
            mean, resid = compression.compressed_mean(gl[0], "data")
            return mean[None], resid[None]

        cmean_fn = shard_map(
            f, mesh=mesh, in_specs=(P("data"),),
            out_specs=(P("data"), P("data")), check_rep=False,
        )

        def cmean():
            return jax.block_until_ready(cmean_fn(g)[0])

        for name, fn in (
            ("groupby_sum", gsum),
            ("semi_join", semi),
            ("repartition", repart),
            ("compressed_mean", cmean),
        ):
            t = measure(fn, repeats=3, warmup=1)
            times[(name, ndev)] = t
            derived = f"rows_per_s={n / t:.3e}"
            if ndev > 1 and (name, 1) in times:
                derived += f";speedup_vs_1dev={times[(name, 1)] / t:.2f}"
            report(f"dist/{name}/ndev{ndev}", t, derived)

    # pipeline: stages = all devices (only meaningful with >1, but the
    # 1-stage lane anchors the schedule overhead)
    L, D, B, M = 8, 64, 8, 8
    W = jnp.asarray(rng.normal(size=(L, D, D)).astype(np.float32) * 0.3)
    x = jnp.asarray(rng.normal(size=(M, B, D)).astype(np.float32))
    for ndev in ndevs:
        if L % ndev:
            continue
        pmesh = jax.make_mesh((ndev,), ("pipe",))

        def pipe():
            return jax.block_until_ready(
                pipeline.pipeline_forward(pmesh, lambda w, h: jnp.tanh(h @ w), W, x, L)
            )

        report(f"dist/pipeline/ndev{ndev}", measure(pipe, repeats=3, warmup=1))
