"""Roofline report: reads the dry-run JSON artifacts and prints the
three-term table per (arch x shape x mesh) — the §Roofline deliverable."""
from __future__ import annotations

import glob
import json
import os

from .common import report

ARTIFACTS = os.environ.get("DRYRUN_ARTIFACTS", "artifacts/dryrun")


def load_cells(pattern: str = "*.json"):
    cells = []
    for f in sorted(glob.glob(os.path.join(ARTIFACTS, pattern))):
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def run(quick: bool = False):
    cells = load_cells()
    if not cells:
        report("roofline/missing", 0.0, f"no artifacts under {ARTIFACTS}; run repro.launch.dryrun --all")
        return
    ok = [c for c in cells if c.get("status") == "ok"]
    skipped = [c for c in cells if c.get("status") == "skipped"]
    err = [c for c in cells if c.get("status") == "error"]
    report("roofline/cells", 0.0, f"ok={len(ok)} skipped={len(skipped)} error={len(err)}")
    for c in ok:
        r = c["roofline"]
        peak = (c.get("memory") or {}).get("peak_bytes") or 0
        derived = (
            f"mesh={c['mesh']} kind={c['kind']} dominant={r['dominant']} "
            f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
            f"collective={r['collective_s']:.5f}s useful={r['useful_flops_ratio'] and round(r['useful_flops_ratio'], 3)} "
            f"peakGB={peak/2**30:.2f}"
        )
        report(f"roofline/{c['arch']}/{c['shape']}", 0.0, derived)
    for c in err:
        report(f"roofline/{c['arch']}/{c['shape']}", 0.0, f"ERROR mesh={c['mesh']}: {c['error'][:120]}")
