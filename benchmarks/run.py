"""Benchmark harness entry point: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only name,...]
                                            [--json out.json]
                                            [--compare baseline.json]

Prints ``name,us_per_call,derived`` CSV rows (see common.report);
``--json PATH`` additionally writes the rows as a JSON document (the CI
bench-smoke job uploads it as the ``BENCH_PR.json`` artifact), and
``--compare PATH`` gates the run against a committed baseline document
(exit 1 on any shared row slower than 2.5x — the CI regression gate;
the baseline refreshes from main pushes).
Default is quick mode (small scale factors) so the whole suite runs in
minutes on CPU; --full uses larger data.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--sf", type=float, default=None)
    ap.add_argument(
        "--json",
        default="",
        metavar="PATH",
        help="also write the result rows as a JSON document",
    )
    ap.add_argument(
        "--compare",
        default="",
        metavar="BASELINE",
        help="fail (exit 1) if any row regresses >2.5x vs this baseline JSON",
    )
    args = ap.parse_args()
    quick = not args.full
    sf = args.sf or (0.01 if quick else 0.05)

    from . import (
        bench_compile,
        bench_cores,
        bench_dist,
        bench_join,
        bench_loading,
        bench_memory,
        bench_operators,
        bench_roofline,
        bench_scaling,
        bench_serve,
        bench_spill,
        bench_sql,
        bench_store,
        bench_tpch,
        bench_tpcds,
    )

    suites = {
        "tpch": lambda: bench_tpch.run(sf=sf, quick=quick),
        "dist": lambda: bench_dist.run(quick=quick),
        "join": lambda: bench_join.run(sf=sf, quick=quick),
        "store": lambda: bench_store.run(sf=sf, quick=quick),
        "tpcds": lambda: bench_tpcds.run(sf=sf, quick=quick),
        "sql": lambda: bench_sql.run(sf=sf, quick=quick),
        "operators": lambda: bench_operators.run(sf=sf, quick=quick),
        "scaling": lambda: bench_scaling.run(quick=quick),
        "compile": lambda: bench_compile.run(sf=sf, quick=quick),
        "serve": lambda: bench_serve.run(sf=sf, quick=quick),
        "spill": lambda: bench_spill.run(sf=sf, quick=quick),
        "loading": lambda: bench_loading.run(sf=sf, quick=quick),
        "memory": lambda: bench_memory.run(sf=sf, quick=quick),
        "cores": lambda: bench_cores.run(sf=sf, quick=quick),
        "roofline": lambda: bench_roofline.run(quick=quick),
    }
    only = set(filter(None, args.only.split(",")))
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            print(f"{name}/SUITE_ERROR,0,{type(e).__name__}: {e}", flush=True)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    if args.json:
        from .common import write_json

        write_json(args.json)
    if args.compare:
        from .common import compare_baseline

        if not compare_baseline(args.compare):
            sys.exit(1)


if __name__ == "__main__":
    main()
