"""Fig. 13: JIT compilation overhead — trace+compile time is
dataset-size agnostic while compute scales, so amortization improves
with scale (the Mojo-JIT study, XLA edition).

Plus the whole-plan compiler (ISSUE 6): per-query rows comparing
op-by-op dispatch against the single-program compiled path — first
call (trace+compile+exec) vs plan-cache hit — for q1/q3/q9."""
from __future__ import annotations

import time

import numpy as np

from .common import measure, report, tpch_frames

# representative shapes: q1 scan+agg, q3 3-way join, q9 6-way join
PLAN_QUERIES = ("q1", "q3", "q9")


def run(sf: float = 0.01, quick: bool = False):
    import jax
    import jax.numpy as jnp

    def pipeline(keys, vals, thresh):
        # filter + groupby-sum + normalize: a fused mini query plan
        mask = vals > thresh
        v = jnp.where(mask, vals, 0.0)
        sums = jax.ops.segment_sum(v, keys, num_segments=1024)
        return sums / jnp.maximum(sums.sum(), 1e-9)

    sizes = [10_000, 100_000, 1_000_000] if quick else [10_000, 100_000, 1_000_000, 4_000_000]
    rng = np.random.default_rng(0)
    for n in sizes:
        keys = jnp.asarray(rng.integers(0, 1024, n).astype(np.int32))
        vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
        jitted = jax.jit(pipeline)
        t0 = time.perf_counter()
        jax.block_until_ready(jitted(keys, vals, 0.1))  # trace+compile+run
        t_first = time.perf_counter() - t0
        t_exec = measure(lambda: jax.block_until_ready(jitted(keys, vals, 0.1)), repeats=5)
        t_compile = max(t_first - t_exec, 0.0)
        report(f"compile/n{n}/compile_time", t_compile, "size-agnostic")
        report(f"compile/n{n}/exec_time", t_exec, f"compile/exec={t_compile / max(t_exec, 1e-9):.1f}x")

    _run_plan_queries(sf, quick)


def _run_plan_queries(sf: float, quick: bool):
    """Whole-plan compilation vs op-by-op dispatch on TPC-H."""
    from repro import sql
    from repro.core.config import CONFIG
    from repro.queries.tpch_sql import sql_text
    from repro.sql import compile as plan_compile

    frames = tpch_frames(sf)
    repeats = 3 if quick else 5
    for qname in PLAN_QUERIES:
        text = sql_text(qname, sf)
        CONFIG.compiled = "off"
        try:
            t_dispatch = measure(
                lambda: sql.execute(text, frames), repeats=repeats
            )
            CONFIG.compiled = "force"
            plan_compile.clear_cache()
            plan_compile.reset_stats()
            t0 = time.perf_counter()
            sql.execute(text, frames)
            t_first = time.perf_counter() - t0
            t_hit = measure(
                lambda: sql.execute(text, frames), repeats=repeats
            )
            stats = plan_compile.STATS
            assert stats["compiles"] == 1 and stats["fallbacks"] == 0
            # regression gate: cache hits must stay well ahead of
            # dispatch (steady-state sits at 3-6x; 1.5 absorbs shared
            # runner noise while still catching a compile-path stall)
            assert t_dispatch / max(t_hit, 1e-9) >= 1.5, (
                f"{qname}: compiled cache-hit {t_hit:.0f}us is not "
                f">=1.5x faster than dispatch {t_dispatch:.0f}us"
            )
        finally:
            CONFIG.compiled = "auto"
        report(f"sql_compile/{qname}/dispatch", t_dispatch, "op-by-op")
        report(
            f"sql_compile/{qname}/first_call", t_first, "trace+compile+exec"
        )
        report(
            f"sql_compile/{qname}/cache_hit",
            t_hit,
            f"vs dispatch {t_dispatch / max(t_hit, 1e-9):.1f}x",
        )
