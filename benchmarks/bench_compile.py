"""Fig. 13: JIT compilation overhead — trace+compile time is
dataset-size agnostic while compute scales, so amortization improves
with scale (the Mojo-JIT study, XLA edition)."""
from __future__ import annotations

import time

import numpy as np

from .common import measure, report


def run(quick: bool = False):
    import jax
    import jax.numpy as jnp

    def pipeline(keys, vals, thresh):
        # filter + groupby-sum + normalize: a fused mini query plan
        mask = vals > thresh
        v = jnp.where(mask, vals, 0.0)
        sums = jax.ops.segment_sum(v, keys, num_segments=1024)
        return sums / jnp.maximum(sums.sum(), 1e-9)

    sizes = [10_000, 100_000, 1_000_000] if quick else [10_000, 100_000, 1_000_000, 4_000_000]
    rng = np.random.default_rng(0)
    for n in sizes:
        keys = jnp.asarray(rng.integers(0, 1024, n).astype(np.int32))
        vals = jnp.asarray(rng.normal(size=n).astype(np.float32))
        jitted = jax.jit(pipeline)
        t0 = time.perf_counter()
        jax.block_until_ready(jitted(keys, vals, 0.1))  # trace+compile+run
        t_first = time.perf_counter() - t0
        t_exec = measure(lambda: jax.block_until_ready(jitted(keys, vals, 0.1)), repeats=5)
        t_compile = max(t_first - t_exec, 0.0)
        report(f"compile/n{n}/compile_time", t_compile, "size-agnostic")
        report(f"compile/n{n}/exec_time", t_exec, f"compile/exec={t_compile / max(t_exec, 1e-9):.1f}x")
