"""Figs. 10/11/12: per-operator ablations.

- Fig 10 (string-UDF filter): row-at-a-time Python vs numpy-vectorized
  vs TensorFrame dictionary-LUT vs device packed-byte kernel path.
- Fig 11 (group-by key building): Python-dict-of-tuples ("PandasMojo",
  the mutable-key pathology) vs per-column incremental (Alg. 1) vs
  transposed packed composite (Alg. 2, ours).
- Fig 12 (join): direct-address (factorized perfect hash) vs
  sorted-probe vs full sort-merge.
"""
from __future__ import annotations

import numpy as np

from .common import measure, report, tpch_frames, tpch_tables


def _filter_udf(sf: float, quick: bool):
    import jax

    from repro.core import col, strings

    tables = tpch_tables(sf)
    comments = tables["orders"]["o_comment"]
    n = comments.shape[0]

    # row-at-a-time python (Pandas .apply analog)
    def row_loop():
        out = np.empty(n, dtype=bool)
        for i, s in enumerate(comments):
            j = s.find("special")
            out[i] = not (j >= 0 and s.find("requests", j + 7) >= 0)
        return out

    t_row = measure(row_loop, repeats=1, warmup=0)
    report("operators/filter_udf/rowpython", t_row, f"n={n}")

    # numpy-vectorized over unique values (dictionary trick, host)
    frames = tpch_frames(sf)

    def tf_lut():
        return frames["orders"].filter(
            col("o_comment").str.not_exists_before("special", "requests")
        )

    t_lut = measure(tf_lut)
    report("operators/filter_udf/tensorframe_dictlut", t_lut, f"speedup={t_row / t_lut:.1f}x")

    # device packed-bytes path (jnp reference of the Pallas kernel)
    packed, lens = strings.pack_strings(comments, 96)

    @jax.jit
    def dev():
        return ~strings.exists_before(packed, lens, "special", "requests")

    dev()  # compile
    t_dev = measure(lambda: jax.block_until_ready(dev()))
    report("operators/filter_udf/device_packed", t_dev, f"speedup={t_row / t_dev:.1f}x")


def _groupby(sf: float, quick: bool):
    from repro.core.groupby import (
        incremental_group_ids,
        pydict_group_ids,
        transposed_group_ids,
    )

    tables = tpch_tables(sf)
    li = tables["lineitem"]
    cols = [
        li["l_orderkey"] % 1_000_000,
        li["l_partkey"] % 10_000,
        (li["l_quantity"].astype(np.int64)),
    ]
    n = cols[0].shape[0]

    t_py = measure(lambda: pydict_group_ids(cols), repeats=1, warmup=0)
    report("operators/groupby/pydict_tuples", t_py, f"n={n} (PandasMojo analog)")
    t_inc = measure(lambda: incremental_group_ids(cols))
    report("operators/groupby/incremental_alg1", t_inc, f"speedup_vs_pydict={t_py / t_inc:.1f}x")
    t_tr = measure(lambda: transposed_group_ids(cols))
    report(
        "operators/groupby/transposed_alg2",
        t_tr,
        f"speedup_vs_pydict={t_py / t_tr:.1f}x speedup_vs_alg1={t_inc / t_tr:.1f}x",
    )


def _join(sf: float, quick: bool):
    from repro.core.join import join as J

    frames = tpch_frames(sf)
    orders = frames["orders"].select(["o_orderkey", "o_custkey", "o_totalprice"])
    cust = frames["customer"].select(["c_custkey", "c_acctbal"])

    t_direct = measure(
        lambda: J(orders, cust, left_on="o_custkey", right_on="c_custkey", algorithm="direct")
    )
    report("operators/join/direct_address", t_direct, f"n={orders.nrows}")
    t_sorted = measure(
        lambda: J(orders, cust, left_on="o_custkey", right_on="c_custkey", algorithm="sorted")
    )
    report("operators/join/sorted_probe", t_sorted, f"vs_direct={t_sorted / t_direct:.2f}x")
    t_sm = measure(
        lambda: J(orders, cust, left_on="o_custkey", right_on="c_custkey", algorithm="sortmerge")
    )
    report("operators/join/sort_merge", t_sm, f"vs_direct={t_sm / t_direct:.2f}x")

    # row-python dict join baseline
    tables = tpch_tables(sf)

    def pyjoin():
        idx = {}
        for i, k in enumerate(tables["customer"]["c_custkey"]):
            idx[k] = i
        out = []
        for i, k in enumerate(tables["orders"]["o_custkey"]):
            j = idx.get(k)
            if j is not None:
                out.append((i, j))
        return out

    t_py = measure(pyjoin, repeats=1, warmup=0)
    report("operators/join/rowpython_dict", t_py, f"vs_direct={t_py / t_direct:.2f}x")


def run(sf: float = 0.01, quick: bool = False):
    _filter_udf(sf, quick)
    _groupby(sf if not quick else 0.005, quick)
    _join(sf, quick)
