"""Concurrent SQL serving throughput (ISSUE 7).

Queries/sec through ``serve.Executor`` at 1/4/16 concurrent sessions
against one-at-a-time ``sql.execute`` dispatch of the same workload.
The workload is the serving layer's sweet spot: a chunked store table
with run-clustered (rle-encoded) columns and a small pool of sargable
parameterized aggregations over overlapping hot ranges — concurrent
sessions form micro-batches whose store scans collapse into one shared
zone-map pass (chunk decodes and predicate masks computed once) and
whose duplicate texts coalesce into one execution.

Also: prepared-statement latency (compiled-plan cache hit) vs a cold
first call (trace+compile) for the same parameterized text.
"""
from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from .common import measure, report

SESSIONS = (1, 4, 16)


def _build_table(n: int):
    from repro import store

    rng = np.random.default_rng(42)
    # run-clustered columns encode as rle; shared scans then amortize
    # the per-chunk np.repeat decode across the whole micro-batch
    run = 512
    groups = np.repeat(rng.integers(0, 64, n // run + 1), run)[:n]
    status = np.repeat(rng.integers(0, 4, n // run + 1), run)[:n]
    return store.Table.from_arrays(
        {
            "g": groups,
            "st": status,
            "k": np.sort(rng.integers(0, 10_000, n)),
            "v": rng.random(n),
            "w": rng.random(n),
        },
        chunk_rows=8192,
    )


def _query_pool():
    # the dashboard pattern: per hot range, several aggregates over the
    # SAME filter and columns — within a micro-batch those distinct
    # texts share one scan identity (one chunk-decode + one
    # materialization), on top of duplicate-text coalescing and the
    # cross-range shared zone-map pass
    pool = []
    for i in range(4):
        hi = 3500 + 800 * i
        where = f"WHERE k < {hi}"
        pool.append(f"SELECT g, SUM(v) AS s FROM t {where} GROUP BY g")
        pool.append(f"SELECT g, AVG(v) AS a FROM t {where} GROUP BY g")
        pool.append(
            f"SELECT g, MIN(v) AS lo, MAX(v) AS hi FROM t {where} "
            f"GROUP BY g"
        )
    return pool


def _serial_qps(texts, scope):
    from repro import sql

    t0 = time.perf_counter()
    for q in texts:
        sql.execute(q, scope)
    wall = time.perf_counter() - t0
    return len(texts) / wall, wall


def _serve_qps(texts, scope, sessions: int):
    from repro import serve

    with serve.Executor(scope) as ex:
        # warm the plan path once per distinct text
        for q in sorted(set(texts)):
            ex.execute(q)
        t0 = time.perf_counter()
        with ThreadPoolExecutor(sessions) as tp:
            list(tp.map(ex.execute, texts))
        wall = time.perf_counter() - t0
    return len(texts) / wall, wall


def run(sf: float = 0.01, quick: bool = False):
    from repro import sql
    from repro.core.config import CONFIG
    from repro.core.frame import TensorFrame
    from repro.sql import compile as plan_compile

    n = 300_000 if quick else 1_500_000
    m = 64 if quick else 192
    table = _build_table(n)
    scope = {"t": table}
    rng = np.random.default_rng(7)
    pool = _query_pool()
    rng.shuffle(pool)  # decouple popularity rank from query cost
    # zipf-skewed traffic (alpha=1.5): serving workloads concentrate on
    # a few hot dashboard queries, so concurrent batches hold
    # duplicates to coalesce alongside the shared-scan groups
    w = 1.0 / np.arange(1, len(pool) + 1) ** 1.5
    texts = [pool[i] for i in rng.choice(len(pool), m, p=w / w.sum())]

    # one-at-a-time dispatch: parse+plan+scan per query, nothing shared
    for q in sorted(set(texts)):
        sql.execute(q, scope)  # warm (jax dispatch, zone maps)
    serial_qps, serial_wall = _serial_qps(texts, scope)
    report(
        "serve/serial_dispatch",
        serial_wall / len(texts),
        f"qps={serial_qps:.0f}",
    )

    for s in SESSIONS:
        qps, wall = _serve_qps(texts, scope, s)
        report(
            f"serve/qps/s{s}",
            wall / len(texts),
            f"qps={qps:.0f},vs_serial={qps / serial_qps:.2f}x",
        )
        if s == max(SESSIONS):
            # the ISSUE 7 acceptance floor: micro-batching must at
            # least double throughput at 16 concurrent sessions
            assert qps >= 2.0 * serial_qps, (
                f"serving at {s} sessions reached only "
                f"{qps / serial_qps:.2f}x serial dispatch"
            )

    # prepared statements: compiled-cache hit vs cold trace+compile
    rng2 = np.random.default_rng(3)
    nf = 1 << (15 if quick else 17)
    frame = TensorFrame.from_arrays(
        {
            "a": rng2.integers(0, 32, nf),
            "b": rng2.integers(0, 1000, nf),
            "w": rng2.random(nf),
        }
    )
    tmpl = "SELECT a, SUM(w) AS s FROM t WHERE b > {k} GROUP BY a"
    CONFIG.compiled = "force"
    try:
        from repro import serve

        with serve.Executor({"t": frame}) as ex:
            ps = ex.prepare(tmpl)
            plan_compile.clear_cache()
            t0 = time.perf_counter()
            ps.execute(k=500)  # trace + compile + run
            cold = time.perf_counter() - t0
            ks = iter(range(1000))
            hot = measure(lambda: ps.execute(k=next(ks)), repeats=7)
        report("serve/prepared/cold", cold, "trace+compile+exec")
        report(
            "serve/prepared/hit",
            hot,
            f"cold/hit={cold / max(hot, 1e-9):.0f}x",
        )
    finally:
        CONFIG.compiled = "auto"
        CONFIG.compiled_min_rows = 1 << 15
