"""Fig. 8: scaling with core count — query kernels re-run in
subprocesses pinned (sched_setaffinity) to 1/2/4/8 cores."""
from __future__ import annotations

import os
import subprocess
import sys

from .common import report

_CHILD = """
import os, sys, time
os.sched_setaffinity(0, set(range(int(sys.argv[1]))))
sys.path.insert(0, "src")
import numpy as np
from repro.data import tpch
from repro.queries import tpch_frames as QF
tables = tpch.generate(sf=float(sys.argv[2]), seed=0)
frames = tpch.as_frames(tables)
qname = sys.argv[3]
QF.ALL[qname](frames, sf=float(sys.argv[2]))  # warmup/compile
best = 1e9
for _ in range(2):
    t0 = time.perf_counter()
    QF.ALL[qname](frames, sf=float(sys.argv[2]))
    best = min(best, time.perf_counter() - t0)
print(f"RESULT {best}")
"""


def run(sf: float = 0.01, quick: bool = False):
    ncpu = os.cpu_count() or 8
    cores = [c for c in (1, 2, 4, 8) if c <= ncpu]
    if quick:
        cores = cores[:2]
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    for qname in ("q1", "q6") if quick else ("q1", "q6", "q9"):
        base = None
        for c in cores:
            out = subprocess.run(
                [sys.executable, "-c", _CHILD, str(c), str(sf), qname],
                capture_output=True, text=True, env=env, timeout=900,
            )
            line = [l for l in out.stdout.splitlines() if l.startswith("RESULT")]
            if not line:
                report(f"cores/{qname}/c{c}", 0.0, f"FAILED: {out.stderr[-200:]}")
                continue
            t = float(line[0].split()[1])
            base = base or t
            report(f"cores/{qname}/c{c}", t, f"speedup_vs_1core={base / t:.2f}x")
