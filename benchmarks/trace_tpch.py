"""Capture a Chrome/Perfetto trace of TPC-H queries.

    PYTHONPATH=src python -m benchmarks.trace_tpch \
        [--queries q1,q3,q9] [--sf 0.01] [--out trace.json] [--analyze]

Runs each query twice — once to warm caches, once traced under
``CONFIG.tracing="on"`` — and writes every recorded span as a Chrome
``trace_event`` JSON document (open in ``chrome://tracing`` or
https://ui.perfetto.dev).  ``--analyze`` additionally prints each
query's EXPLAIN ANALYZE tree (per-operator wall time, row counts,
join-algorithm choices).

This is the CI bench-smoke job's ``obs-trace`` artifact producer.
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", default="q1,q3,q9")
    ap.add_argument("--sf", type=float, default=0.01)
    ap.add_argument("--out", default="trace.json", metavar="PATH")
    ap.add_argument(
        "--analyze",
        action="store_true",
        help="also print each query's EXPLAIN ANALYZE tree",
    )
    args = ap.parse_args()

    from repro import obs, sql
    from repro.core.config import CONFIG
    from repro.queries.tpch_sql import sql_text

    from .common import tpch_frames

    frames = tpch_frames(args.sf)
    qnames = [q.strip() for q in args.queries.split(",") if q.strip()]

    texts = {q: sql_text(q, args.sf) for q in qnames}
    for q in qnames:
        sql.execute(texts[q], frames)  # warm: caches + jit out of the trace

    obs.clear_trace()
    saved = CONFIG.tracing
    CONFIG.tracing = "on"
    try:
        for q in qnames:
            t0 = time.perf_counter()
            with obs.span("query", query=q):
                out = sql.execute(texts[q], frames)
            dt = (time.perf_counter() - t0) * 1e3
            print(f"# {q}: {out.nrows} row(s) in {dt:.1f}ms", flush=True)
            if args.analyze:
                print(
                    sql.execute(texts[q], frames, explain="analyze"),
                    flush=True,
                )
    finally:
        CONFIG.tracing = saved

    n = obs.export_chrome_trace(args.out)
    print(f"# wrote {n} trace events to {args.out}", flush=True)


if __name__ == "__main__":
    main()
