"""Fig. 9: the 5 TPC-DS queries, same engine comparison."""
from __future__ import annotations

from .common import measure, report, tpcds_frames, tpcds_tables


def run(sf: float = 0.01, quick: bool = False):
    tables = tpcds_tables(sf)
    frames = tpcds_frames(sf)
    from repro.queries import tpcds_frames as QF
    from repro.queries import tpcds_numpy as QN

    for qname in ("q3", "q6", "q7", "q42", "q96"):
        tf = measure(lambda: QF.ALL[qname](frames, sf=sf), repeats=3 if not quick else 1)
        tr = measure(lambda: QN.ALL[qname](tables, sf=sf), repeats=1, warmup=0)
        report(f"tpcds/{qname}/tensorframe", tf, f"sf={sf}")
        report(f"tpcds/{qname}/rowpython", tr, f"speedup={tr / tf:.1f}x")
