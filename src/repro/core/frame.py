"""TensorFrame: the paper's tensor-backed dataframe, adapted to JAX.

Representation (paper §III, Fig. 3), TPU-adapted per DESIGN.md §2:

- ``itensor``: one 2-D int64 device tensor holding ALL integer-like
  columns — raw ints, dates (days since epoch), bools (0/1) and the
  dense dictionary codes of low-cardinality non-numeric columns.
- ``ftensor``: one 2-D float device tensor holding all measures.
- high-cardinality non-numeric columns are *offloaded*
  (``OffloadedColumn``): the physical host array never moves; a device
  row indexer maps logical rows to physical positions, so relational
  ops only update the indexer (paper §III-f).
- ``columns``: the column indexer — an ordered map from logical column
  name to its physical (tensor, slot) location.  Logical column order is
  decoupled from physical slot order.

Late materialization (ISSUE 5): row-subsetting ops do not copy the
tensors.  A frame may instead carry a ``RowView`` — a list of
``ViewBlock`` s, each pairing base (itensor, ftensor) payloads with a
row-index *selection vector* — and every ``ColumnMeta`` names the block
it lives in.  ``take``/``mask_rows``/``filter``/``sort`` compose the
selection vectors (an int64 gather per block, never a payload copy) and
joins stack blocks from both sides, so a multi-join chain accumulates
indices and performs **one** payload gather per base table when
``materialize()`` fires at a pipeline exit (column decode, tensor
append, vconcat).  Column accessors (``col_values``/``col_codes``/
``valid_array``/``column``) gather single columns through the view
without materializing the rest.

Null semantics: nullable columns carry a hidden companion column
``__v__<name>`` (0/1 in the int tensor) that flows through every
relational op like any other column.

Stats cache: each frame carries ``_stats`` — per-column(-combination)
distinct counts and provable-uniqueness flags (``ColStats``), populated
by ``TensorFrame.from_store`` (zone maps), ``GroupBy`` and ``distinct``,
and consulted by ``join(algorithm="auto")`` so proving build-key
uniqueness no longer costs a full sort of the build side.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from .config import CONFIG
from . import encoding

INT = jnp.int64
# Sentinel stored in int/code slots of null cells (the hidden validity
# column is authoritative; the sentinel just keeps gathers in-range).
INT_NULL = np.int64(-1)

VALID_PREFIX = "__v__"


def _valid_name(name: str) -> str:
    return VALID_PREFIX + name


def _is_hidden(name: str) -> bool:
    return name.startswith(VALID_PREFIX)


def float_dtype():
    return jnp.dtype(CONFIG.float_dtype)


@dataclasses.dataclass
class ColumnMeta:
    """Logical column → physical storage mapping (the column indexer).

    ``block`` names the ``RowView`` block holding the column's payload
    (always 0 for materialized frames).
    """

    name: str
    kind: str  # 'int' | 'float' | 'bool' | 'date' | 'dict' | 'obj'
    slot: int  # slot in itensor (int-like kinds) or ftensor ('float'); -1 for 'obj'
    dictionary: Optional[np.ndarray] = None  # sorted uniques for 'dict'
    block: int = 0  # RowView block index (0 when the frame is eager)

    def is_int_like(self) -> bool:
        return self.kind in ("int", "bool", "date", "dict")


@dataclasses.dataclass
class ColStats:
    """Cached per-column(-combination) statistics for algorithm picks.

    ``unique=True`` is a correctness-grade guarantee (the values — as a
    tuple, for multi-column keys — are pairwise distinct over the
    frame's rows); ``unique=False`` is a perf hint only (duplicates were
    observed in a superset of the rows — a row subset may have become
    unique, but treating it as non-unique is always safe).  ``distinct``
    is the exact non-null distinct count when known.

    ``vmin``/``vmax`` bound an int-like column's values (possibly over a
    *superset* of the rows — row subsetting and join gathers can only
    shrink the true range, so stale bounds stay valid for range
    compression).  Seeded from store zone maps, computed once (one
    fused device fetch) otherwise — they make repeat joins sync-free.
    """

    unique: Optional[bool] = None
    distinct: Optional[int] = None
    vmin: Optional[int] = None
    vmax: Optional[int] = None


class ViewBlock:
    """One late-materialization source: base payload tensors + the id
    of its selection vector in the owning view's row matrix.
    ``row_id=None`` means identity (the base tensors are already
    aligned with the frame's logical rows)."""

    __slots__ = ("itensor", "ftensor", "row_id")

    def __init__(self, itensor, ftensor, row_id: Optional[int]):
        self.itensor = itensor
        self.ftensor = ftensor
        self.row_id = row_id


class RowView:
    """The selection-vector representation.

    ``blocks`` are the payload sources; ``rowmat`` is ONE stacked
    ``(R, nrows)`` int64 matrix holding every block's selection vector
    as a row.  Keeping the vectors stacked means ``take`` composes the
    whole view with a single 2-D gather (``rowmat[:, rows]``) no
    matter how many base tables a join chain has accumulated.
    """

    __slots__ = ("blocks", "rowmat", "_row_cache")

    def __init__(self, blocks: List[ViewBlock], rowmat: Optional[jax.Array]):
        self.blocks = blocks
        self.rowmat = rowmat
        self._row_cache: Dict[int, jax.Array] = {}

    def rows_of(self, block: ViewBlock) -> Optional[jax.Array]:
        rid = block.row_id
        if rid is None:
            return None
        if rid not in self._row_cache:
            self._row_cache[rid] = self.rowmat[rid]
        return self._row_cache[rid]


class OffloadedColumn:
    """High-cardinality non-numeric column, offloaded from the tensor.

    ``values`` is the immutable physical host array; ``idx`` is a device
    int64 row indexer (logical row -> physical position).  Factorized
    codes and packed byte tensors are cached on the *physical* array so
    filtered/joined views share them.
    """

    def __init__(self, values: np.ndarray, idx: Optional[jax.Array] = None,
                 _cache: Optional[dict] = None):
        self.values = values
        if idx is None:
            idx = jnp.arange(values.shape[0], dtype=INT)
        self.idx = idx
        # cache shared across views of the same physical array
        self._cache = _cache if _cache is not None else {}

    @property
    def nrows(self) -> int:
        return int(self.idx.shape[0])

    def take(self, rows: jax.Array) -> "OffloadedColumn":
        return OffloadedColumn(self.values, self.idx[rows], self._cache)

    def materialize(self) -> np.ndarray:
        return self.values[np.asarray(self.idx)]

    def phys_factorize(self) -> Tuple[np.ndarray, np.ndarray]:
        """(codes over physical rows, sorted dictionary), cached."""
        if "fact" not in self._cache:
            self._cache["fact"] = encoding.factorize(self.values)
        return self._cache["fact"]

    def codes(self) -> Tuple[jax.Array, np.ndarray]:
        """Dense codes aligned with *logical* rows + dictionary."""
        phys_codes, dictionary = self.phys_factorize()
        if "dev_codes" not in self._cache:
            self._cache["dev_codes"] = jnp.asarray(phys_codes, dtype=INT)
        return self._cache["dev_codes"][self.idx], dictionary

    def packed(self, max_len: Optional[int] = None):
        """Packed (n_phys, L) uint8 byte tensor + lengths, cached."""
        from . import strings  # local import to avoid cycle

        key = ("packed", max_len)
        if key not in self._cache:
            self._cache[key] = strings.pack_strings(self.values, max_len)
        return self._cache[key]


def _empty_tensor(n: int, dtype) -> jax.Array:
    return jnp.zeros((n, 0), dtype=dtype)


def _assemble_frame(
    int_cols: List[Tuple[str, np.ndarray, str, Optional[np.ndarray]]],
    float_cols: List[Tuple[str, np.ndarray]],
    offloaded: Dict[str, "OffloadedColumn"],
    order: List[str],
    n: int,
) -> "TensorFrame":
    """Stack encoded host columns into the two device tensors."""
    columns: Dict[str, ColumnMeta] = {}
    islots: Dict[str, int] = {}
    fslots: Dict[str, int] = {}
    for i, (name, _, _, _) in enumerate(int_cols):
        islots[name] = i
    for i, (name, _) in enumerate(float_cols):
        fslots[name] = i
    itensor = (
        jnp.asarray(np.column_stack([c[1] for c in int_cols]).astype(np.int64))
        if int_cols
        else _empty_tensor(n, INT)
    )
    ftensor = (
        jnp.asarray(
            np.column_stack([c[1] for c in float_cols]).astype(
                np.dtype(CONFIG.float_dtype)
            )
        )
        if float_cols
        else _empty_tensor(n, float_dtype())
    )
    imeta = {name: (kind, dic) for name, _, kind, dic in int_cols}
    for name in order:
        if name in islots:
            kind, dic = imeta[name]
            columns[name] = ColumnMeta(name, kind, islots[name], dic)
        elif name in fslots:
            columns[name] = ColumnMeta(name, "float", fslots[name])
        else:
            columns[name] = ColumnMeta(name, "obj", -1)
    return TensorFrame(itensor, ftensor, columns, offloaded, n)


class TensorFrame:
    def __init__(
        self,
        itensor: Optional[jax.Array],
        ftensor: Optional[jax.Array],
        columns: Dict[str, ColumnMeta],
        offloaded: Dict[str, OffloadedColumn],
        nrows: int,
        view: Optional[RowView] = None,
    ):
        self._itensor = itensor
        self._ftensor = ftensor
        self.columns = columns
        self.offloaded = offloaded
        self.nrows = int(nrows)
        self._view = view
        self._stats: Dict[Tuple[str, ...], ColStats] = {}

    @classmethod
    def _from_view(
        cls,
        columns: Dict[str, ColumnMeta],
        offloaded: Dict[str, OffloadedColumn],
        nrows: int,
        blocks: List[ViewBlock],
        rowmat: Optional[jax.Array],
    ) -> "TensorFrame":
        return cls(None, None, columns, offloaded, nrows, RowView(blocks, rowmat))

    # ------------------------------------------------------------------
    # late materialization
    # ------------------------------------------------------------------
    @property
    def is_view(self) -> bool:
        return self._view is not None

    def _view_parts(self) -> Tuple[List[ViewBlock], Optional[jax.Array]]:
        """(blocks, rowmat) — identity block for an eager frame; the
        join's zero-copy stacking input."""
        if self._view is not None:
            return self._view.blocks, self._view.rowmat
        return [ViewBlock(self._itensor, self._ftensor, None)], None

    def materialize(self) -> "TensorFrame":
        """Resolve the view: ONE fused 2-D gather per (block, tensor)
        of exactly the live slots, then a single horizontal concat —
        this is the "one payload gather per base table" a join chain
        deferred to.  In-place (caching) — logical content is
        unchanged.  Returns ``self`` for chaining."""
        if self._view is None:
            return self
        blocks = self._view.blocks
        # live slots per block, in column order (dead columns from
        # select()/projection pruning are never gathered)
        per_int: List[List[int]] = [[] for _ in blocks]
        per_float: List[List[int]] = [[] for _ in blocks]
        within: Dict[str, int] = {}
        for name, m in self.columns.items():
            if m.kind == "obj":
                continue
            lst = per_float[m.block] if m.kind == "float" else per_int[m.block]
            if m.slot in lst:  # two logical columns sharing a payload
                within[name] = lst.index(m.slot)
            else:
                within[name] = len(lst)
                lst.append(m.slot)

        def _gather(tensor: jax.Array, slots: List[int], rows) -> jax.Array:
            # whole-tensor row gather when every slot is live in order
            # (the common join-chain case) — XLA's fast contiguous-row
            # path; otherwise slice the live columns, then gather rows
            full = slots == list(range(tensor.shape[1]))
            sub = tensor if full else tensor[:, jnp.asarray(slots, dtype=INT)]
            return sub if rows is None else sub[rows]

        iparts: List[jax.Array] = []
        fparts: List[jax.Array] = []
        ioffs: List[int] = []
        foffs: List[int] = []
        ioff = foff = 0
        for b, isl, fsl in zip(blocks, per_int, per_float):
            ioffs.append(ioff)
            foffs.append(foff)
            rows = self._view.rows_of(b)
            if isl:
                iparts.append(_gather(b.itensor, isl, rows))
                ioff += len(isl)
            if fsl:
                fparts.append(_gather(b.ftensor, fsl, rows))
                foff += len(fsl)
        self._itensor = (
            jnp.concatenate(iparts, axis=1)
            if len(iparts) > 1
            else (iparts[0] if iparts else _empty_tensor(self.nrows, INT))
        )
        self._ftensor = (
            jnp.concatenate(fparts, axis=1)
            if len(fparts) > 1
            else (fparts[0] if fparts else _empty_tensor(self.nrows, float_dtype()))
        )
        newcols: Dict[str, ColumnMeta] = {}
        for name, m in self.columns.items():  # original order preserved
            if m.kind == "obj":
                newcols[name] = dataclasses.replace(m, block=0)
                continue
            base = foffs[m.block] if m.kind == "float" else ioffs[m.block]
            newcols[name] = dataclasses.replace(
                m, slot=base + within[name], block=0
            )
        self.columns = newcols
        self._view = None
        return self

    @property
    def itensor(self) -> jax.Array:
        if self._view is not None:
            self.materialize()
        return self._itensor

    @property
    def ftensor(self) -> jax.Array:
        if self._view is not None:
            self.materialize()
        return self._ftensor

    # ------------------------------------------------------------------
    # stats cache
    # ------------------------------------------------------------------
    @staticmethod
    def _stats_key(cols: Sequence[str]) -> Tuple[str, ...]:
        return tuple(sorted(cols))

    def set_stats(
        self,
        cols: Union[str, Sequence[str]],
        *,
        unique: Optional[bool] = None,
        distinct: Optional[int] = None,
        vmin: Optional[int] = None,
        vmax: Optional[int] = None,
    ) -> None:
        key = self._stats_key([cols] if isinstance(cols, str) else cols)
        st = self._stats.setdefault(key, ColStats())
        if unique is not None:
            st.unique = unique
        if distinct is not None:
            st.distinct = distinct
        if vmin is not None:
            st.vmin = vmin
        if vmax is not None:
            st.vmax = vmax

    def col_stats(self, cols: Union[str, Sequence[str]]) -> Optional[ColStats]:
        key = self._stats_key([cols] if isinstance(cols, str) else cols)
        return self._stats.get(key)

    def int_bounds(self, name: str) -> Tuple[int, int]:
        """(lo, hi) bounds of a (non-empty) int-like column.

        Answered from the stats cache when possible — store zone maps
        seed it, joins/filters propagate it — else computed with ONE
        fused device fetch and cached on this frame.  Bounds may cover
        a superset of the rows; callers use them for range compression,
        where a wider span is still correct.
        """
        st = self.col_stats(name)
        if st is not None and st.vmin is not None:
            return int(st.vmin), int(st.vmax)
        arr = self.col_values(name)
        b = np.asarray(jnp.stack([arr.min(), arr.max()]))
        lo, hi = int(b[0]), int(b[1])
        self.set_stats(name, vmin=lo, vmax=hi)
        return lo, hi

    def unique_hint(self, cols: Sequence[str]) -> Optional[bool]:
        """Is the column combination provably unique (/ non-unique)?

        ``True`` is correctness-grade (safe to direct-address a join
        build side); ``False`` is a perf hint; ``None`` means unknown.
        Any single member column being unique makes the combination
        unique.
        """
        st = self._stats.get(self._stats_key(cols))
        if st is not None and st.unique is not None:
            return st.unique
        for c in cols:
            s1 = self._stats.get((c,))
            if s1 is not None and s1.unique:
                return True
        return None

    def _drop_stats_mentioning(self, name: str) -> None:
        """Invalidate every cached stat involving ``name`` — a column
        replacement voids single-column AND combination entries (a
        stale combo uniqueness would mis-drive the join pick)."""
        self._stats = {k: v for k, v in self._stats.items() if name not in k}

    def _inherit_stats(
        self,
        child: "TensorFrame",
        mode: str,
        mapping: Optional[Dict[str, str]] = None,
    ) -> None:
        """Propagate stats onto ``child``.

        ``mode``: 'permutation' keeps everything (same row multiset),
        'subset' keeps unique flags and value bounds but drops distinct
        counts (rows were removed), 'bounds' keeps only value bounds
        (rows may repeat — join gathers), 'columns' keeps everything
        (row set unchanged).  Entries whose columns do not all survive
        in ``child`` are dropped; ``mapping`` renames columns.
        """
        for key, st in self._stats.items():
            cols = [mapping.get(c, c) for c in key] if mapping else list(key)
            if not all(c in child.columns for c in cols):
                continue
            if mode == "permutation" or mode == "columns":
                new = dataclasses.replace(st)
            elif mode == "subset":
                if st.unique is None and st.vmin is None:
                    continue
                new = ColStats(unique=st.unique, vmin=st.vmin, vmax=st.vmax)
            elif mode == "bounds":
                if st.vmin is None:
                    continue
                new = ColStats(vmin=st.vmin, vmax=st.vmax)
            else:
                raise ValueError(mode)
            child._stats[child._stats_key(cols)] = new

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    @staticmethod
    def from_arrays(
        data: Dict[str, np.ndarray],
        *,
        card_threshold: Optional[float] = None,
        encode: Optional[Dict[str, str]] = None,
    ) -> "TensorFrame":
        """Build a frame from host numpy arrays.

        ``encode`` optionally forces 'dict' or 'obj' per column name,
        overriding the cardinality policy (paper lets users set the
        threshold; default 50%).
        """
        threshold = CONFIG.card_threshold if card_threshold is None else card_threshold
        encode = encode or {}
        int_cols: List[Tuple[str, np.ndarray, str, Optional[np.ndarray]]] = []
        float_cols: List[Tuple[str, np.ndarray]] = []
        offloaded: Dict[str, OffloadedColumn] = {}
        order: List[str] = []
        n = None
        for name, arr in data.items():
            arr = np.asarray(arr)
            if n is None:
                n = arr.shape[0]
            elif arr.shape[0] != n:
                raise ValueError(f"column {name}: length {arr.shape[0]} != {n}")
            order.append(name)
            if np.issubdtype(arr.dtype, np.datetime64):
                days = arr.astype("datetime64[D]").astype(np.int64)
                int_cols.append((name, days, "date", None))
            elif arr.dtype == np.bool_:
                int_cols.append((name, arr.astype(np.int64), "bool", None))
            elif np.issubdtype(arr.dtype, np.integer):
                int_cols.append((name, arr.astype(np.int64), "int", None))
            elif np.issubdtype(arr.dtype, np.floating):
                float_cols.append((name, arr))
            elif encoding.is_string_like(arr):
                forced = encode.get(name)
                if forced == "obj":
                    offloaded[name] = OffloadedColumn(arr)
                    continue
                codes, dictionary = encoding.factorize(arr)
                if forced == "dict" or dictionary.shape[0] <= threshold * max(1, n):
                    int_cols.append((name, codes, "dict", dictionary))
                else:
                    offloaded[name] = OffloadedColumn(arr)
            else:
                raise TypeError(f"column {name}: unsupported dtype {arr.dtype}")
        n = 0 if n is None else n
        return _assemble_frame(int_cols, float_cols, offloaded, order, n)

    @staticmethod
    def from_store(
        table,
        columns: Optional[Sequence[str]] = None,
        predicates: Sequence = (),
        *,
        card_threshold: Optional[float] = None,
        encode: Optional[Dict[str, str]] = None,
        result=None,
    ) -> "TensorFrame":
        """Materialize a frame from a ``repro.store`` chunked table.

        ``predicates`` are sargable store conjuncts
        (``repro.store.Pred``): zone maps skip whole chunks and the
        survivors are row-filtered host-side, so only matching rows
        ever reach the device tensors (scan pushdown).  Dictionary
        columns keep the store's *interned* dictionary — no
        re-factorization, and frames built from the same store share
        dictionary objects, making join-time dictionary merges
        identity operations.

        Zone-map statistics thread through: columns the chunk stats
        prove unique (or duplicate-bearing) seed the frame's stats
        cache, so downstream ``join(algorithm="auto")`` picks its
        build strategy without sorting the build side.

        ``result`` short-circuits the scan with a precomputed
        ``store.ScanResult`` for exactly these columns/predicates —
        the serving layer's shared-scan path (``store.shared_scan``)
        answers many concurrent scans in one pass and materializes
        each frame from its own result here.
        """
        from repro import store as _store

        if result is None:
            result = _store.scan(table, columns, list(predicates))
        threshold = (
            CONFIG.card_threshold if card_threshold is None else card_threshold
        )
        encode = encode or {}
        n = result.nrows
        int_cols: List[Tuple[str, np.ndarray, str, Optional[np.ndarray]]] = []
        float_cols: List[Tuple[str, np.ndarray]] = []
        offloaded: Dict[str, OffloadedColumn] = {}
        order: List[str] = []
        for name, mc in result.columns.items():
            order.append(name)
            forced = encode.get(name)
            if mc.dictionary is not None:
                if forced == "obj":
                    safe = np.clip(
                        mc.values, 0, max(0, mc.dictionary.shape[0] - 1)
                    )
                    offloaded[name] = OffloadedColumn(mc.dictionary[safe])
                else:
                    int_cols.append((name, mc.values, "dict", mc.dictionary))
            elif mc.ctype == "float":
                float_cols.append((name, mc.values))
            elif mc.ctype == "str":
                # plain (high-cardinality) strings: same policy as
                # from_arrays — dict-encode below the threshold, offload
                # above it
                if forced == "obj":
                    offloaded[name] = OffloadedColumn(mc.values)
                    continue
                codes, dictionary = encoding.factorize(mc.values)
                if forced == "dict" or dictionary.shape[0] <= threshold * max(1, n):
                    int_cols.append((name, codes, "dict", dictionary))
                else:
                    offloaded[name] = OffloadedColumn(mc.values)
            else:  # int / date / bool days already in physical form
                int_cols.append((name, mc.values, mc.ctype, None))
        # explicit store validity bitmaps become the engine's hidden
        # __v__ companion columns (float nulls stay NaN-encoded)
        for name, mc in result.columns.items():
            if mc.validity is not None and not bool(mc.validity.all()):
                vname = _valid_name(name)
                order.append(vname)
                int_cols.append(
                    (vname, mc.validity.astype(np.int64), "bool", None)
                )
        out = _assemble_frame(int_cols, float_cols, offloaded, order, n)
        # thread zone-map uniqueness/distinct/bounds stats into the
        # frame so joins and group-bys skip their probing work
        unfiltered = not predicates and n == table.nrows
        for name in order:
            if name not in table.columns:
                continue
            col = table.columns[name]
            if col.ctype == "float":
                continue
            unique, distinct = col.uniqueness_from_stats()
            if unique is True:
                # uniqueness survives any row filtering (subset)
                out.set_stats(
                    name,
                    unique=True,
                    distinct=distinct if unfiltered else None,
                )
            elif unique is False and unfiltered:
                out.set_stats(name, unique=False, distinct=distinct)
            if n and col.ctype in ("int", "date", "bool") and col.encoding != "dict":
                mins, maxs, exact = col.zone_bounds()
                if exact and not np.isnan(mins).all():
                    out.set_stats(
                        name,
                        vmin=int(np.nanmin(mins)),
                        vmax=int(np.nanmax(maxs)),
                    )
        return out

    # ------------------------------------------------------------------
    # basic introspection
    # ------------------------------------------------------------------
    @property
    def column_names(self) -> List[str]:
        return [c for c in self.columns if not _is_hidden(c)]

    def meta(self, name: str) -> ColumnMeta:
        try:
            return self.columns[name]
        except KeyError:
            raise KeyError(f"no column {name!r}; have {self.column_names}") from None

    def has_column(self, name: str) -> bool:
        return name in self.columns

    def has_nulls(self, name: str) -> bool:
        return _valid_name(name) in self.columns

    def valid_array(self, name: str) -> Optional[jax.Array]:
        vn = _valid_name(name)
        if vn in self.columns:
            return self.col_values(vn) != 0
        return None

    # ------------------------------------------------------------------
    # column access
    # ------------------------------------------------------------------
    def _raw_values(self, m: ColumnMeta) -> jax.Array:
        """Device payload of one non-obj column, gathered through the
        view when lazy (a single-column gather — no materialization)."""
        if self._view is not None:
            b = self._view.blocks[m.block]
            arr = (b.ftensor if m.kind == "float" else b.itensor)[:, m.slot]
            rows = self._view.rows_of(b)
            return arr if rows is None else arr[rows]
        t = self._ftensor if m.kind == "float" else self._itensor
        return t[:, m.slot]

    def col_values(self, name: str) -> jax.Array:
        """Device numeric representation (codes for dict columns)."""
        m = self.meta(name)
        if m.kind == "obj":
            codes, _ = self.offloaded[name].codes()
            return codes
        return self._raw_values(m)

    def col_codes(self, name: str) -> Tuple[jax.Array, np.ndarray]:
        """(codes, dictionary) for any string-typed column."""
        m = self.meta(name)
        if m.kind == "dict":
            return self._raw_values(m), m.dictionary
        if m.kind == "obj":
            return self.offloaded[name].codes()
        raise TypeError(f"column {name} is not string-typed (kind={m.kind})")

    def column(self, name: str) -> np.ndarray:
        """Decode a column back to host numpy (for users/tests).

        A pipeline exit: gathers exactly this column through the view.
        """
        m = self.meta(name)
        valid = self.valid_array(name)
        if m.kind == "obj":
            out = self.offloaded[name].materialize()
            if valid is not None:
                out = out.astype(object)
                out[~np.asarray(valid)] = None
            return out
        if m.kind == "float":
            out = np.asarray(self._raw_values(m))
            if valid is not None:
                out = out.copy()
                out[~np.asarray(valid)] = np.nan
            return out
        raw = np.asarray(self._raw_values(m))
        if m.kind == "dict":
            if m.dictionary.shape[0] == 0:
                # empty dictionary (e.g. null rows stitched against an
                # empty build side): every cell is null
                return np.full(raw.shape, None, dtype=object)
            safe = np.clip(raw, 0, m.dictionary.shape[0] - 1)
            out = m.dictionary[safe].astype(object)
            if valid is not None:
                out[~np.asarray(valid)] = None
            elif (raw < 0).any():
                out[raw < 0] = None
            return out
        if m.kind == "date":
            out = raw.astype("datetime64[D]")
            return out
        if m.kind == "bool":
            return raw != 0
        return raw

    def to_dict(self) -> Dict[str, np.ndarray]:
        return {name: self.column(name) for name in self.column_names}

    def __len__(self) -> int:
        return self.nrows

    # ------------------------------------------------------------------
    # row ops
    # ------------------------------------------------------------------
    def take(
        self,
        rows: Union[jax.Array, np.ndarray],
        *,
        stats: str = "none",
    ) -> "TensorFrame":
        """Select rows by index.

        Late-materializing by default: the result is a ``RowView``
        frame whose blocks compose ``rows`` into their selection
        vectors — no payload is copied until ``materialize()``.

        ``stats`` declares what the caller knows about ``rows`` for
        stats propagation: 'permutation' (every row exactly once),
        'subset' (no row more than once), 'none' (may repeat rows —
        drop all cached stats).
        """
        rows = jnp.asarray(rows, dtype=INT)
        n = int(rows.shape[0])
        off = {k: v.take(rows) for k, v in self.offloaded.items()}
        if CONFIG.late_materialization:
            v = self._view
            if v is None:
                blocks = [ViewBlock(self._itensor, self._ftensor, 0)]
                rowmat = rows[None, :]
            else:
                # compose EVERY block's selection vector in one 2-D
                # gather; identity blocks share one new vector (= rows)
                mats = []
                ident_id: Optional[int] = None
                if v.rowmat is not None:
                    mats.append(v.rowmat[:, rows])
                if any(b.row_id is None for b in v.blocks):
                    ident_id = 0 if v.rowmat is None else int(v.rowmat.shape[0])
                    mats.append(rows[None, :])
                rowmat = mats[0] if len(mats) == 1 else jnp.concatenate(mats, axis=0)
                blocks = [
                    ViewBlock(
                        b.itensor,
                        b.ftensor,
                        ident_id if b.row_id is None else b.row_id,
                    )
                    for b in v.blocks
                ]
            out = TensorFrame._from_view(dict(self.columns), off, n, blocks, rowmat)
        else:
            it = (
                self.itensor[rows]
                if self.itensor.shape[1]
                else _empty_tensor(n, INT)
            )
            ft = (
                self.ftensor[rows]
                if self.ftensor.shape[1]
                else _empty_tensor(n, float_dtype())
            )
            out = TensorFrame(it, ft, dict(self.columns), off, n)
        if stats in ("permutation", "subset"):
            self._inherit_stats(out, stats)
        return out

    def head(self, n: int) -> "TensorFrame":
        n = min(n, self.nrows)
        return self.take(jnp.arange(n, dtype=INT), stats="subset")

    def mask_rows(self, mask: jax.Array) -> "TensorFrame":
        """Compact rows where mask is True (one host sync for the
        count; the payload gather is deferred behind the view)."""
        mask = jnp.asarray(mask)
        count = int(mask.sum())
        idx = jnp.nonzero(mask, size=count)[0].astype(INT)
        return self.take(idx, stats="subset")

    def filter(self, expr) -> "TensorFrame":
        from .expr import Expr

        if isinstance(expr, Expr):
            mask = expr.eval_bool(self)
        else:
            mask = jnp.asarray(expr)
        return self.mask_rows(mask)

    # ------------------------------------------------------------------
    # column ops
    # ------------------------------------------------------------------
    def select(self, names: Sequence[str]) -> "TensorFrame":
        cols: Dict[str, ColumnMeta] = {}
        off: Dict[str, OffloadedColumn] = {}
        for name in names:
            m = self.meta(name)
            cols[name] = m
            if m.kind == "obj":
                off[name] = self.offloaded[name]
            vn = _valid_name(name)
            if vn in self.columns:
                cols[vn] = self.columns[vn]
        out = TensorFrame(
            self._itensor, self._ftensor, cols, off, self.nrows, self._view
        )
        self._inherit_stats(out, "columns")
        return out

    def drop(self, names: Sequence[str]) -> "TensorFrame":
        keep = [c for c in self.column_names if c not in set(names)]
        return self.select(keep)

    def rename(self, mapping: Dict[str, str]) -> "TensorFrame":
        cols: Dict[str, ColumnMeta] = {}
        off: Dict[str, OffloadedColumn] = {}
        for name, m in self.columns.items():
            if _is_hidden(name):
                base = name[len(VALID_PREFIX):]
                new = _valid_name(mapping.get(base, base))
            else:
                new = mapping.get(name, name)
            cols[new] = dataclasses.replace(m, name=new)
            if m.kind == "obj":
                off[new] = self.offloaded[name]
        out = TensorFrame(
            self._itensor, self._ftensor, cols, off, self.nrows, self._view
        )
        self._inherit_stats(out, "columns", mapping=mapping)
        return out

    def _append_int_column(
        self,
        name: str,
        values: jax.Array,
        kind: str = "int",
        dictionary: Optional[np.ndarray] = None,
    ) -> "TensorFrame":
        values = jnp.asarray(values, dtype=INT).reshape(self.nrows, 1)
        cols = dict(self.columns)
        cols.pop(name, None)
        off = dict(self.offloaded)
        off.pop(name, None)
        if self._view is not None:
            # Lazy append: the computed column is already aligned with
            # the logical rows, so it rides along as its own identity
            # block — the view's deferred gathers survive (a
            # filter -> with_column -> join chain stays one gather per
            # base table at materialize time).
            blocks = list(self._view.blocks)
            blocks.append(
                ViewBlock(values, _empty_tensor(self.nrows, float_dtype()), None)
            )
            cols[name] = ColumnMeta(name, kind, 0, dictionary, len(blocks) - 1)
            out = TensorFrame._from_view(
                cols, off, self.nrows, blocks, self._view.rowmat
            )
        else:
            it = jnp.concatenate([self._itensor, values], axis=1)
            cols[name] = ColumnMeta(name, kind, self._itensor.shape[1], dictionary)
            out = TensorFrame(it, self._ftensor, cols, off, self.nrows)
        self._inherit_stats(out, "columns")
        out._drop_stats_mentioning(name)  # the name may have been replaced
        return out

    def _append_float_column(self, name: str, values: jax.Array) -> "TensorFrame":
        values = jnp.asarray(values, dtype=float_dtype()).reshape(self.nrows, 1)
        cols = dict(self.columns)
        cols.pop(name, None)
        off = dict(self.offloaded)
        off.pop(name, None)
        if self._view is not None:
            blocks = list(self._view.blocks)
            blocks.append(
                ViewBlock(_empty_tensor(self.nrows, INT), values, None)
            )
            cols[name] = ColumnMeta(name, "float", 0, None, len(blocks) - 1)
            out = TensorFrame._from_view(
                cols, off, self.nrows, blocks, self._view.rowmat
            )
        else:
            ft = jnp.concatenate([self._ftensor, values], axis=1)
            cols[name] = ColumnMeta(name, "float", self._ftensor.shape[1])
            out = TensorFrame(self._itensor, ft, cols, off, self.nrows)
        self._inherit_stats(out, "columns")
        out._drop_stats_mentioning(name)
        return out

    def _append_offloaded(self, name: str, col: OffloadedColumn) -> "TensorFrame":
        cols = dict(self.columns)
        cols[name] = ColumnMeta(name, "obj", -1)
        off = dict(self.offloaded)
        off[name] = col
        out = TensorFrame(
            self._itensor, self._ftensor, cols, off, self.nrows, self._view
        )
        self._inherit_stats(out, "columns")
        out._drop_stats_mentioning(name)
        return out

    def with_column(self, name: str, expr) -> "TensorFrame":
        from .expr import Expr, Value

        if isinstance(expr, Expr):
            val = expr.eval(self)
        elif isinstance(expr, Value):
            val = expr
        else:  # raw array
            arr = jnp.asarray(expr)
            if jnp.issubdtype(arr.dtype, jnp.floating):
                return self._append_float_column(name, arr)
            return self._append_int_column(name, arr)
        if val.kind == "str":
            return self._append_int_column(name, val.arr, "dict", val.dictionary)
        if val.kind == "bool":
            return self._append_int_column(name, val.arr.astype(INT), "bool")
        if val.kind == "date":
            return self._append_int_column(name, val.arr, "date")
        arr = val.arr
        if jnp.issubdtype(arr.dtype, jnp.floating):
            out = self._append_float_column(name, arr)
        else:
            out = self._append_int_column(name, arr)
        if val.valid is not None:
            out = out._append_int_column(_valid_name(name), val.valid.astype(INT), "bool")
        return out

    # ------------------------------------------------------------------
    # relational ops (implemented in sibling modules)
    # ------------------------------------------------------------------
    def groupby(self, keys: Union[str, Sequence[str]]):
        from .groupby import GroupBy

        if isinstance(keys, str):
            keys = [keys]
        return GroupBy(self, list(keys))

    def agg(self, specs) -> Dict[str, float]:
        from .agg import frame_agg

        return frame_agg(self, specs)

    def sort_values(self, by, ascending=True) -> "TensorFrame":
        from .sort import sort_values

        return sort_values(self, by, ascending)

    def join(self, other: "TensorFrame", **kwargs) -> "TensorFrame":
        from .join import join

        return join(self, other, **kwargs)

    def nunique(self, name: str) -> int:
        from .groupby import nunique_column

        return nunique_column(self, name)

    def unique_rows(self, names: Sequence[str]) -> "TensorFrame":
        from .groupby import unique_rows

        return unique_rows(self, list(names))

    def scalar(self, name: str):
        arr = self.column(name)
        if arr.shape[0] != 1:
            raise ValueError(f"scalar() on column with {arr.shape[0]} rows")
        return arr[0]

    # ------------------------------------------------------------------
    # memory accounting (paper §VI-H)
    # ------------------------------------------------------------------
    def memory_bytes(self) -> Dict[str, int]:
        dict_bytes = 0
        for m in self.columns.values():
            if m.kind == "dict" and m.dictionary is not None:
                dict_bytes += sum(len(str(s).encode()) + 8 for s in m.dictionary)
        offload_bytes = 0
        for oc in self.offloaded.values():
            # physical payload + per-string overhead (Mojo strings carry
            # ~20B of metadata per the paper; we report our own measured
            # layout in the benchmark, this is the payload estimate)
            offload_bytes += sum(len(str(s).encode()) + 20 for s in oc.values)
            offload_bytes += oc.idx.size * 8
        return {
            "itensor": int(np.prod(self.itensor.shape)) * self.itensor.dtype.itemsize,
            "ftensor": int(np.prod(self.ftensor.shape)) * self.ftensor.dtype.itemsize,
            "dicts": dict_bytes,
            "offloaded": offload_bytes,
        }

    def total_bytes(self) -> int:
        return sum(self.memory_bytes().values())

    # ------------------------------------------------------------------
    def __repr__(self) -> str:
        cols = ", ".join(
            f"{name}:{self.columns[name].kind}" for name in self.column_names
        )
        tag = " view" if self._view is not None else ""
        return f"TensorFrame({self.nrows} rows{tag}; {cols})"

    def show(self, n: int = 8) -> str:
        names = self.column_names
        data = {name: self.column(name)[: min(n, self.nrows)] for name in names}
        widths = {
            name: max(len(name), *(len(str(v)) for v in data[name])) if self.nrows else len(name)
            for name in names
        }
        lines = [" | ".join(name.ljust(widths[name]) for name in names)]
        lines.append("-+-".join("-" * widths[name] for name in names))
        for i in range(min(n, self.nrows)):
            lines.append(
                " | ".join(str(data[name][i]).ljust(widths[name]) for name in names)
            )
        if self.nrows > n:
            lines.append(f"... ({self.nrows} rows)")
        return "\n".join(lines)


def concat_rows(frames: Sequence[TensorFrame]) -> TensorFrame:
    """Vertical concatenation (schemas must match by name & kind)."""
    if not frames:
        raise ValueError("concat of zero frames")
    base = frames[0]
    names = list(base.columns.keys())
    for f in frames[1:]:
        if list(f.columns.keys()) != names:
            raise ValueError("concat: schema mismatch")
    import numpy as _np

    data: Dict[str, np.ndarray] = {}
    # Decode through host; concat is rare in the workloads (correctness
    # over speed here).
    for name in base.column_names:
        data[name] = _np.concatenate([f.column(name) for f in frames])
    return TensorFrame.from_arrays(data)
