"""TensorFrame: the paper's tensor-backed dataframe, adapted to JAX.

Representation (paper §III, Fig. 3), TPU-adapted per DESIGN.md §2:

- ``itensor``: one 2-D int64 device tensor holding ALL integer-like
  columns — raw ints, dates (days since epoch), bools (0/1) and the
  dense dictionary codes of low-cardinality non-numeric columns.
- ``ftensor``: one 2-D float device tensor holding all measures.
- high-cardinality non-numeric columns are *offloaded*
  (``OffloadedColumn``): the physical host array never moves; a device
  row indexer maps logical rows to physical positions, so relational
  ops only update the indexer (paper §III-f).
- ``columns``: the column indexer — an ordered map from logical column
  name to its physical (tensor, slot) location.  Logical column order is
  decoupled from physical slot order.

Null semantics: nullable columns carry a hidden companion column
``__v__<name>`` (0/1 in the int tensor) that flows through every
relational op like any other column.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from .config import CONFIG
from . import encoding

INT = jnp.int64
# Sentinel stored in int/code slots of null cells (the hidden validity
# column is authoritative; the sentinel just keeps gathers in-range).
INT_NULL = np.int64(-1)

VALID_PREFIX = "__v__"


def _valid_name(name: str) -> str:
    return VALID_PREFIX + name


def _is_hidden(name: str) -> bool:
    return name.startswith(VALID_PREFIX)


def float_dtype():
    return jnp.dtype(CONFIG.float_dtype)


@dataclasses.dataclass
class ColumnMeta:
    """Logical column → physical storage mapping (the column indexer)."""

    name: str
    kind: str  # 'int' | 'float' | 'bool' | 'date' | 'dict' | 'obj'
    slot: int  # slot in itensor (int-like kinds) or ftensor ('float'); -1 for 'obj'
    dictionary: Optional[np.ndarray] = None  # sorted uniques for 'dict'

    def is_int_like(self) -> bool:
        return self.kind in ("int", "bool", "date", "dict")


class OffloadedColumn:
    """High-cardinality non-numeric column, offloaded from the tensor.

    ``values`` is the immutable physical host array; ``idx`` is a device
    int64 row indexer (logical row -> physical position).  Factorized
    codes and packed byte tensors are cached on the *physical* array so
    filtered/joined views share them.
    """

    def __init__(self, values: np.ndarray, idx: Optional[jax.Array] = None,
                 _cache: Optional[dict] = None):
        self.values = values
        if idx is None:
            idx = jnp.arange(values.shape[0], dtype=INT)
        self.idx = idx
        # cache shared across views of the same physical array
        self._cache = _cache if _cache is not None else {}

    @property
    def nrows(self) -> int:
        return int(self.idx.shape[0])

    def take(self, rows: jax.Array) -> "OffloadedColumn":
        return OffloadedColumn(self.values, self.idx[rows], self._cache)

    def materialize(self) -> np.ndarray:
        return self.values[np.asarray(self.idx)]

    def phys_factorize(self) -> Tuple[np.ndarray, np.ndarray]:
        """(codes over physical rows, sorted dictionary), cached."""
        if "fact" not in self._cache:
            self._cache["fact"] = encoding.factorize(self.values)
        return self._cache["fact"]

    def codes(self) -> Tuple[jax.Array, np.ndarray]:
        """Dense codes aligned with *logical* rows + dictionary."""
        phys_codes, dictionary = self.phys_factorize()
        if "dev_codes" not in self._cache:
            self._cache["dev_codes"] = jnp.asarray(phys_codes, dtype=INT)
        return self._cache["dev_codes"][self.idx], dictionary

    def packed(self, max_len: Optional[int] = None):
        """Packed (n_phys, L) uint8 byte tensor + lengths, cached."""
        from . import strings  # local import to avoid cycle

        key = ("packed", max_len)
        if key not in self._cache:
            self._cache[key] = strings.pack_strings(self.values, max_len)
        return self._cache[key]


def _empty_tensor(n: int, dtype) -> jax.Array:
    return jnp.zeros((n, 0), dtype=dtype)


def _assemble_frame(
    int_cols: List[Tuple[str, np.ndarray, str, Optional[np.ndarray]]],
    float_cols: List[Tuple[str, np.ndarray]],
    offloaded: Dict[str, "OffloadedColumn"],
    order: List[str],
    n: int,
) -> "TensorFrame":
    """Stack encoded host columns into the two device tensors."""
    columns: Dict[str, ColumnMeta] = {}
    islots: Dict[str, int] = {}
    fslots: Dict[str, int] = {}
    for i, (name, _, _, _) in enumerate(int_cols):
        islots[name] = i
    for i, (name, _) in enumerate(float_cols):
        fslots[name] = i
    itensor = (
        jnp.asarray(np.column_stack([c[1] for c in int_cols]).astype(np.int64))
        if int_cols
        else _empty_tensor(n, INT)
    )
    ftensor = (
        jnp.asarray(
            np.column_stack([c[1] for c in float_cols]).astype(
                np.dtype(CONFIG.float_dtype)
            )
        )
        if float_cols
        else _empty_tensor(n, float_dtype())
    )
    imeta = {name: (kind, dic) for name, _, kind, dic in int_cols}
    for name in order:
        if name in islots:
            kind, dic = imeta[name]
            columns[name] = ColumnMeta(name, kind, islots[name], dic)
        elif name in fslots:
            columns[name] = ColumnMeta(name, "float", fslots[name])
        else:
            columns[name] = ColumnMeta(name, "obj", -1)
    return TensorFrame(itensor, ftensor, columns, offloaded, n)


class TensorFrame:
    def __init__(
        self,
        itensor: jax.Array,
        ftensor: jax.Array,
        columns: Dict[str, ColumnMeta],
        offloaded: Dict[str, OffloadedColumn],
        nrows: int,
    ):
        self.itensor = itensor
        self.ftensor = ftensor
        self.columns = columns
        self.offloaded = offloaded
        self.nrows = int(nrows)

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    @staticmethod
    def from_arrays(
        data: Dict[str, np.ndarray],
        *,
        card_threshold: Optional[float] = None,
        encode: Optional[Dict[str, str]] = None,
    ) -> "TensorFrame":
        """Build a frame from host numpy arrays.

        ``encode`` optionally forces 'dict' or 'obj' per column name,
        overriding the cardinality policy (paper lets users set the
        threshold; default 50%).
        """
        threshold = CONFIG.card_threshold if card_threshold is None else card_threshold
        encode = encode or {}
        int_cols: List[Tuple[str, np.ndarray, str, Optional[np.ndarray]]] = []
        float_cols: List[Tuple[str, np.ndarray]] = []
        offloaded: Dict[str, OffloadedColumn] = {}
        order: List[str] = []
        n = None
        for name, arr in data.items():
            arr = np.asarray(arr)
            if n is None:
                n = arr.shape[0]
            elif arr.shape[0] != n:
                raise ValueError(f"column {name}: length {arr.shape[0]} != {n}")
            order.append(name)
            if np.issubdtype(arr.dtype, np.datetime64):
                days = arr.astype("datetime64[D]").astype(np.int64)
                int_cols.append((name, days, "date", None))
            elif arr.dtype == np.bool_:
                int_cols.append((name, arr.astype(np.int64), "bool", None))
            elif np.issubdtype(arr.dtype, np.integer):
                int_cols.append((name, arr.astype(np.int64), "int", None))
            elif np.issubdtype(arr.dtype, np.floating):
                float_cols.append((name, arr))
            elif encoding.is_string_like(arr):
                forced = encode.get(name)
                if forced == "obj":
                    offloaded[name] = OffloadedColumn(arr)
                    continue
                codes, dictionary = encoding.factorize(arr)
                if forced == "dict" or dictionary.shape[0] <= threshold * max(1, n):
                    int_cols.append((name, codes, "dict", dictionary))
                else:
                    offloaded[name] = OffloadedColumn(arr)
            else:
                raise TypeError(f"column {name}: unsupported dtype {arr.dtype}")
        n = 0 if n is None else n
        return _assemble_frame(int_cols, float_cols, offloaded, order, n)

    @staticmethod
    def from_store(
        table,
        columns: Optional[Sequence[str]] = None,
        predicates: Sequence = (),
        *,
        card_threshold: Optional[float] = None,
        encode: Optional[Dict[str, str]] = None,
    ) -> "TensorFrame":
        """Materialize a frame from a ``repro.store`` chunked table.

        ``predicates`` are sargable store conjuncts
        (``repro.store.Pred``): zone maps skip whole chunks and the
        survivors are row-filtered host-side, so only matching rows
        ever reach the device tensors (scan pushdown).  Dictionary
        columns keep the store's *interned* dictionary — no
        re-factorization, and frames built from the same store share
        dictionary objects, making join-time dictionary merges
        identity operations.
        """
        from repro import store as _store

        result = _store.scan(table, columns, list(predicates))
        threshold = (
            CONFIG.card_threshold if card_threshold is None else card_threshold
        )
        encode = encode or {}
        n = result.nrows
        int_cols: List[Tuple[str, np.ndarray, str, Optional[np.ndarray]]] = []
        float_cols: List[Tuple[str, np.ndarray]] = []
        offloaded: Dict[str, OffloadedColumn] = {}
        order: List[str] = []
        for name, mc in result.columns.items():
            order.append(name)
            forced = encode.get(name)
            if mc.dictionary is not None:
                if forced == "obj":
                    safe = np.clip(
                        mc.values, 0, max(0, mc.dictionary.shape[0] - 1)
                    )
                    offloaded[name] = OffloadedColumn(mc.dictionary[safe])
                else:
                    int_cols.append((name, mc.values, "dict", mc.dictionary))
            elif mc.ctype == "float":
                float_cols.append((name, mc.values))
            elif mc.ctype == "str":
                # plain (high-cardinality) strings: same policy as
                # from_arrays — dict-encode below the threshold, offload
                # above it
                if forced == "obj":
                    offloaded[name] = OffloadedColumn(mc.values)
                    continue
                codes, dictionary = encoding.factorize(mc.values)
                if forced == "dict" or dictionary.shape[0] <= threshold * max(1, n):
                    int_cols.append((name, codes, "dict", dictionary))
                else:
                    offloaded[name] = OffloadedColumn(mc.values)
            else:  # int / date / bool days already in physical form
                int_cols.append((name, mc.values, mc.ctype, None))
        return _assemble_frame(int_cols, float_cols, offloaded, order, n)

    # ------------------------------------------------------------------
    # basic introspection
    # ------------------------------------------------------------------
    @property
    def column_names(self) -> List[str]:
        return [c for c in self.columns if not _is_hidden(c)]

    def meta(self, name: str) -> ColumnMeta:
        try:
            return self.columns[name]
        except KeyError:
            raise KeyError(f"no column {name!r}; have {self.column_names}") from None

    def has_column(self, name: str) -> bool:
        return name in self.columns

    def has_nulls(self, name: str) -> bool:
        return _valid_name(name) in self.columns

    def valid_array(self, name: str) -> Optional[jax.Array]:
        vn = _valid_name(name)
        if vn in self.columns:
            return self.itensor[:, self.columns[vn].slot] != 0
        return None

    # ------------------------------------------------------------------
    # column access
    # ------------------------------------------------------------------
    def col_values(self, name: str) -> jax.Array:
        """Device numeric representation (codes for dict columns)."""
        m = self.meta(name)
        if m.kind == "obj":
            codes, _ = self.offloaded[name].codes()
            return codes
        if m.kind == "float":
            return self.ftensor[:, m.slot]
        return self.itensor[:, m.slot]

    def col_codes(self, name: str) -> Tuple[jax.Array, np.ndarray]:
        """(codes, dictionary) for any string-typed column."""
        m = self.meta(name)
        if m.kind == "dict":
            return self.itensor[:, m.slot], m.dictionary
        if m.kind == "obj":
            return self.offloaded[name].codes()
        raise TypeError(f"column {name} is not string-typed (kind={m.kind})")

    def column(self, name: str) -> np.ndarray:
        """Decode a column back to host numpy (for users/tests)."""
        m = self.meta(name)
        valid = self.valid_array(name)
        if m.kind == "obj":
            out = self.offloaded[name].materialize()
            if valid is not None:
                out = out.astype(object)
                out[~np.asarray(valid)] = None
            return out
        if m.kind == "float":
            out = np.asarray(self.ftensor[:, m.slot])
            if valid is not None:
                out = out.copy()
                out[~np.asarray(valid)] = np.nan
            return out
        raw = np.asarray(self.itensor[:, m.slot])
        if m.kind == "dict":
            safe = np.clip(raw, 0, max(0, m.dictionary.shape[0] - 1))
            out = m.dictionary[safe].astype(object)
            if valid is not None:
                out[~np.asarray(valid)] = None
            elif (raw < 0).any():
                out[raw < 0] = None
            return out
        if m.kind == "date":
            out = raw.astype("datetime64[D]")
            return out
        if m.kind == "bool":
            return raw != 0
        return raw

    def to_dict(self) -> Dict[str, np.ndarray]:
        return {name: self.column(name) for name in self.column_names}

    def __len__(self) -> int:
        return self.nrows

    # ------------------------------------------------------------------
    # row ops
    # ------------------------------------------------------------------
    def take(self, rows: Union[jax.Array, np.ndarray]) -> "TensorFrame":
        rows = jnp.asarray(rows, dtype=INT)
        it = self.itensor[rows] if self.itensor.shape[1] else _empty_tensor(rows.shape[0], INT)
        ft = (
            self.ftensor[rows]
            if self.ftensor.shape[1]
            else _empty_tensor(rows.shape[0], float_dtype())
        )
        off = {k: v.take(rows) for k, v in self.offloaded.items()}
        return TensorFrame(it, ft, dict(self.columns), off, int(rows.shape[0]))

    def head(self, n: int) -> "TensorFrame":
        n = min(n, self.nrows)
        return self.take(jnp.arange(n, dtype=INT))

    def mask_rows(self, mask: jax.Array) -> "TensorFrame":
        """Compact rows where mask is True (eager: host-syncs the count)."""
        mask = jnp.asarray(mask)
        count = int(mask.sum())
        idx = jnp.nonzero(mask, size=count)[0].astype(INT)
        return self.take(idx)

    def filter(self, expr) -> "TensorFrame":
        from .expr import Expr

        if isinstance(expr, Expr):
            mask = expr.eval_bool(self)
        else:
            mask = jnp.asarray(expr)
        return self.mask_rows(mask)

    # ------------------------------------------------------------------
    # column ops
    # ------------------------------------------------------------------
    def select(self, names: Sequence[str]) -> "TensorFrame":
        cols: Dict[str, ColumnMeta] = {}
        off: Dict[str, OffloadedColumn] = {}
        for name in names:
            m = self.meta(name)
            cols[name] = m
            if m.kind == "obj":
                off[name] = self.offloaded[name]
            vn = _valid_name(name)
            if vn in self.columns:
                cols[vn] = self.columns[vn]
        return TensorFrame(self.itensor, self.ftensor, cols, off, self.nrows)

    def drop(self, names: Sequence[str]) -> "TensorFrame":
        keep = [c for c in self.column_names if c not in set(names)]
        return self.select(keep)

    def rename(self, mapping: Dict[str, str]) -> "TensorFrame":
        cols: Dict[str, ColumnMeta] = {}
        off: Dict[str, OffloadedColumn] = {}
        for name, m in self.columns.items():
            if _is_hidden(name):
                base = name[len(VALID_PREFIX):]
                new = _valid_name(mapping.get(base, base))
            else:
                new = mapping.get(name, name)
            cols[new] = dataclasses.replace(m, name=new)
            if m.kind == "obj":
                off[new] = self.offloaded[name]
        return TensorFrame(self.itensor, self.ftensor, cols, off, self.nrows)

    def _append_int_column(
        self,
        name: str,
        values: jax.Array,
        kind: str = "int",
        dictionary: Optional[np.ndarray] = None,
    ) -> "TensorFrame":
        values = jnp.asarray(values, dtype=INT).reshape(self.nrows, 1)
        it = jnp.concatenate([self.itensor, values], axis=1)
        cols = dict(self.columns)
        cols.pop(name, None)
        cols[name] = ColumnMeta(name, kind, self.itensor.shape[1], dictionary)
        off = dict(self.offloaded)
        off.pop(name, None)
        return TensorFrame(it, self.ftensor, cols, off, self.nrows)

    def _append_float_column(self, name: str, values: jax.Array) -> "TensorFrame":
        values = jnp.asarray(values, dtype=float_dtype()).reshape(self.nrows, 1)
        ft = jnp.concatenate([self.ftensor, values], axis=1)
        cols = dict(self.columns)
        cols.pop(name, None)
        cols[name] = ColumnMeta(name, "float", self.ftensor.shape[1])
        off = dict(self.offloaded)
        off.pop(name, None)
        return TensorFrame(self.itensor, ft, cols, off, self.nrows)

    def _append_offloaded(self, name: str, col: OffloadedColumn) -> "TensorFrame":
        cols = dict(self.columns)
        cols[name] = ColumnMeta(name, "obj", -1)
        off = dict(self.offloaded)
        off[name] = col
        return TensorFrame(self.itensor, self.ftensor, cols, off, self.nrows)

    def with_column(self, name: str, expr) -> "TensorFrame":
        from .expr import Expr, Value

        if isinstance(expr, Expr):
            val = expr.eval(self)
        elif isinstance(expr, Value):
            val = expr
        else:  # raw array
            arr = jnp.asarray(expr)
            if jnp.issubdtype(arr.dtype, jnp.floating):
                return self._append_float_column(name, arr)
            return self._append_int_column(name, arr)
        if val.kind == "str":
            return self._append_int_column(name, val.arr, "dict", val.dictionary)
        if val.kind == "bool":
            return self._append_int_column(name, val.arr.astype(INT), "bool")
        if val.kind == "date":
            return self._append_int_column(name, val.arr, "date")
        arr = val.arr
        if jnp.issubdtype(arr.dtype, jnp.floating):
            out = self._append_float_column(name, arr)
        else:
            out = self._append_int_column(name, arr)
        if val.valid is not None:
            out = out._append_int_column(_valid_name(name), val.valid.astype(INT), "bool")
        return out

    # ------------------------------------------------------------------
    # relational ops (implemented in sibling modules)
    # ------------------------------------------------------------------
    def groupby(self, keys: Union[str, Sequence[str]]):
        from .groupby import GroupBy

        if isinstance(keys, str):
            keys = [keys]
        return GroupBy(self, list(keys))

    def agg(self, specs) -> Dict[str, float]:
        from .agg import frame_agg

        return frame_agg(self, specs)

    def sort_values(self, by, ascending=True) -> "TensorFrame":
        from .sort import sort_values

        return sort_values(self, by, ascending)

    def join(self, other: "TensorFrame", **kwargs) -> "TensorFrame":
        from .join import join

        return join(self, other, **kwargs)

    def nunique(self, name: str) -> int:
        from .groupby import nunique_column

        return nunique_column(self, name)

    def unique_rows(self, names: Sequence[str]) -> "TensorFrame":
        from .groupby import unique_rows

        return unique_rows(self, list(names))

    def scalar(self, name: str):
        arr = self.column(name)
        if arr.shape[0] != 1:
            raise ValueError(f"scalar() on column with {arr.shape[0]} rows")
        return arr[0]

    # ------------------------------------------------------------------
    # memory accounting (paper §VI-H)
    # ------------------------------------------------------------------
    def memory_bytes(self) -> Dict[str, int]:
        dict_bytes = 0
        for m in self.columns.values():
            if m.kind == "dict" and m.dictionary is not None:
                dict_bytes += sum(len(str(s).encode()) + 8 for s in m.dictionary)
        offload_bytes = 0
        for oc in self.offloaded.values():
            # physical payload + per-string overhead (Mojo strings carry
            # ~20B of metadata per the paper; we report our own measured
            # layout in the benchmark, this is the payload estimate)
            offload_bytes += sum(len(str(s).encode()) + 20 for s in oc.values)
            offload_bytes += oc.idx.size * 8
        return {
            "itensor": int(np.prod(self.itensor.shape)) * self.itensor.dtype.itemsize,
            "ftensor": int(np.prod(self.ftensor.shape)) * self.ftensor.dtype.itemsize,
            "dicts": dict_bytes,
            "offloaded": offload_bytes,
        }

    def total_bytes(self) -> int:
        return sum(self.memory_bytes().values())

    # ------------------------------------------------------------------
    def __repr__(self) -> str:
        cols = ", ".join(
            f"{name}:{self.columns[name].kind}" for name in self.column_names
        )
        return f"TensorFrame({self.nrows} rows; {cols})"

    def show(self, n: int = 8) -> str:
        names = self.column_names
        data = {name: self.column(name)[: min(n, self.nrows)] for name in names}
        widths = {
            name: max(len(name), *(len(str(v)) for v in data[name])) if self.nrows else len(name)
            for name in names
        }
        lines = [" | ".join(name.ljust(widths[name]) for name in names)]
        lines.append("-+-".join("-" * widths[name] for name in names))
        for i in range(min(n, self.nrows)):
            lines.append(
                " | ".join(str(data[name][i]).ljust(widths[name]) for name in names)
            )
        if self.nrows > n:
            lines.append(f"... ({self.nrows} rows)")
        return "\n".join(lines)


def concat_rows(frames: Sequence[TensorFrame]) -> TensorFrame:
    """Vertical concatenation (schemas must match by name & kind)."""
    if not frames:
        raise ValueError("concat of zero frames")
    base = frames[0]
    names = list(base.columns.keys())
    for f in frames[1:]:
        if list(f.columns.keys()) != names:
            raise ValueError("concat: schema mismatch")
    import numpy as _np

    data: Dict[str, np.ndarray] = {}
    # Decode through host; concat is rare in the workloads (correctness
    # over speed here).
    for name in base.column_names:
        data[name] = _np.concatenate([f.column(name) for f in frames])
    return TensorFrame.from_arrays(data)
