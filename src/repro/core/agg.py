"""Aggregation functions: whole-frame and per-segment (group-by) forms.

Per-group reductions are XLA segment ops — the TPU-native replacement
for Mojo's dictionary accumulation (DESIGN.md §2).
"""
from __future__ import annotations

from typing import Dict, List, Tuple, Union

import numpy as np
import jax
import jax.numpy as jnp

from .frame import INT, TensorFrame, float_dtype

AggSpec = Tuple[str, str, str]  # (out_name, fn, column) — column '' for size

AGG_FNS = ("sum", "mean", "min", "max", "count", "size", "nunique", "first")


def normalize_specs(specs) -> List[AggSpec]:
    out: List[AggSpec] = []
    if isinstance(specs, dict):
        for out_name, v in specs.items():
            fn, colname = v if isinstance(v, (tuple, list)) else (v, "")
            out.append((out_name, fn, colname))
    else:
        for item in specs:
            out.append(tuple(item))  # type: ignore[arg-type]
    for name, fn, _ in out:
        if fn not in AGG_FNS:
            raise ValueError(f"unknown aggregation {fn!r} for {name!r}")
    return out


def _num_values(frame: TensorFrame, name: str) -> jax.Array:
    m = frame.meta(name)
    if m.kind in ("float", "int", "bool", "date"):
        # view-aware: a lazy frame gathers only this column
        return frame.col_values(name)
    raise TypeError(f"aggregation over non-numeric column {name!r}")


def _count_weights(frame: TensorFrame, name: str) -> jax.Array:
    """1 where the value is non-null else 0 (SQL COUNT(col))."""
    valid = frame.valid_array(name)
    if valid is None:
        return jnp.ones((frame.nrows,), dtype=INT)
    return valid.astype(INT)


def _segment_sum(vals: jax.Array, gids: jax.Array, m: int) -> jax.Array:
    """Segment sum with an optional sharded route.

    The single-device path is the plain XLA segment op; when the
    distributed route is enabled (CONFIG.distributed / device count, see
    repro.dist.dframe.dist_enabled) the reduction runs as shard-local
    dense sums + psum over a data mesh spanning all visible devices.
    """
    from repro.core.config import CONFIG

    if CONFIG.distributed != "off":
        from repro.dist import dframe

        if dframe.dist_enabled(int(vals.shape[0])):
            return dframe.dist_groupby_sum(dframe.data_mesh(), gids, vals, m)
    return jax.ops.segment_sum(vals, gids, m)


# ----------------------------------------------------------------------
# segment (grouped) aggregation
# ----------------------------------------------------------------------
def segment_agg(
    frame: TensorFrame,
    gids: jax.Array,
    m: int,
    fn: str,
    colname: str,
):
    if fn == "size":
        return _segment_sum(jnp.ones((frame.nrows,), dtype=INT), gids, m)
    if fn == "count":
        return _segment_sum(_count_weights(frame, colname), gids, m)
    if fn == "nunique":
        return _segment_nunique(frame, gids, m, colname)
    if fn == "first":
        rep = jax.ops.segment_min(
            jnp.arange(frame.nrows, dtype=INT), gids, m
        )
        return frame.col_values(colname)[rep]
    vals = _num_values(frame, colname)
    valid = frame.valid_array(colname)
    if fn == "sum":
        if valid is not None:
            vals = jnp.where(valid, vals, jnp.zeros((), dtype=vals.dtype))
        return _segment_sum(vals, gids, m)
    if fn == "mean":
        if valid is not None:
            vals = jnp.where(valid, vals, jnp.zeros((), dtype=vals.dtype))
        s = _segment_sum(vals.astype(float_dtype()), gids, m)
        c = _segment_sum(_count_weights(frame, colname), gids, m)
        return s / jnp.maximum(c, 1).astype(float_dtype())
    if fn == "min":
        if valid is not None:
            big = jnp.asarray(np.iinfo(np.int64).max if not jnp.issubdtype(vals.dtype, jnp.floating) else np.inf, dtype=vals.dtype)
            vals = jnp.where(valid, vals, big)
        return jax.ops.segment_min(vals, gids, m)
    if fn == "max":
        if valid is not None:
            small = jnp.asarray(np.iinfo(np.int64).min if not jnp.issubdtype(vals.dtype, jnp.floating) else -np.inf, dtype=vals.dtype)
            vals = jnp.where(valid, vals, small)
        return jax.ops.segment_max(vals, gids, m)
    raise ValueError(fn)


def _segment_nunique(frame: TensorFrame, gids: jax.Array, m: int, colname: str) -> jax.Array:
    """COUNT(DISTINCT col) per group: distinct (gid, code) pairs, then a
    per-gid count — pure tensor ops, no dictionaries."""
    from . import hashing

    codes, card = hashing.key_codes(frame, colname) if frame.meta(colname).kind != "float" else (None, 0)
    if codes is None:
        raise TypeError("nunique over float column")
    valid = frame.valid_array(colname)
    card64 = np.int64(max(1, card))
    pair = gids * card64 + codes.astype(INT)
    if valid is not None:
        # shunt nulls into a per-group sentinel bucket that we exclude
        pair = jnp.where(valid, pair, np.int64(-1))
    uniq, _, mu = hashing.distinct(pair)
    pair_gid = jnp.where(uniq >= 0, uniq // card64, np.int64(m))
    ones = (uniq >= 0).astype(INT)
    return jax.ops.segment_sum(ones, pair_gid, m + 1)[:m]


# ----------------------------------------------------------------------
# whole-frame aggregation
# ----------------------------------------------------------------------
def frame_agg(frame: TensorFrame, specs) -> Dict[str, Union[float, int]]:
    out: Dict[str, Union[float, int]] = {}
    gids = jnp.zeros((frame.nrows,), dtype=INT)
    for out_name, fn, colname in normalize_specs(specs):
        if frame.nrows == 0:
            # Pandas semantics (the paper's comparison target): empty
            # SUM is 0; empty mean/min/max are NaN
            if fn in ("count", "size", "nunique"):
                out[out_name] = 0
            elif fn == "sum":
                out[out_name] = 0.0
            else:
                out[out_name] = float("nan")
            continue
        v = segment_agg(frame, gids, 1, fn, colname)[0]
        v = np.asarray(v)[()]
        out[out_name] = v.item() if hasattr(v, "item") else v
    return out
