"""TensorFrame — MojoFrame's design in JAX (see DESIGN.md).

The relational engine requires exact 64-bit integer keys; enable x64
*for processes that use the engine*.  Model/launch code does not import
this package and keeps JAX defaults (explicit bf16/f32 dtypes).
"""
from jax import config as _jax_config

_jax_config.update("jax_enable_x64", True)

from .config import CONFIG, EngineConfig  # noqa: E402
from .frame import TensorFrame, concat_rows  # noqa: E402
from .expr import (  # noqa: E402
    DateLit,
    Expr,
    col,
    lit,
    d,
    if_else,
    parse_date,
    udf,
)
from .join import join  # noqa: E402
from .io import read_csv, read_tfb, write_csv, write_tfb  # noqa: E402

__all__ = [
    "CONFIG",
    "EngineConfig",
    "TensorFrame",
    "concat_rows",
    "DateLit",
    "Expr",
    "col",
    "lit",
    "d",
    "if_else",
    "parse_date",
    "udf",
    "join",
    "read_csv",
    "read_tfb",
    "write_csv",
    "write_tfb",
]
