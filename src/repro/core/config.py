"""Engine-wide configuration for the TensorFrame relational engine.

Mirrors MojoFrame's user-facing knobs (§VI-A of the paper): the
cardinality threshold that decides dictionary-encoding vs offloading,
plus TPU-adaptation knobs (measure dtype, device string path).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass
class EngineConfig:
    # Columns with (n_distinct / n_rows) <= card_threshold are
    # dictionary-encoded into the int tensor; above it they are offloaded
    # (paper §III-c/d uses 50%).
    card_threshold: float = 0.5
    # Measure tensor dtype. float64 on CPU hosts for exact analytics;
    # a TPU deployment would flip this to float32 (see DESIGN.md §2).
    float_dtype: str = "float64"
    # When True, string predicates on offloaded columns run on the packed
    # (n, maxlen) uint8 device tensor (Pallas kernel on TPU, jnp ref on
    # CPU) instead of the host dictionary-LUT path.
    use_device_strings: bool = False
    # Maximum packed string width for the device string path.
    max_packed_len: int = 128
    # Distributed execution route (repro.dist): 'off' never shards,
    # 'force' always takes the sharded route (tests exercise it on a
    # 1-device mesh), 'auto' shards group-by reduction sums and
    # semi/anti-join probes when more than one device is visible and the
    # input has at least dist_min_rows rows.
    distributed: str = "auto"
    dist_min_rows: int = 1 << 16
    # Late materialization (ISSUE 5): when True, row-subsetting ops
    # (take / mask_rows / filter / join / sort) return RowView frames
    # that compose gather indices instead of copying payload tensors;
    # payloads materialize once at pipeline exits.  False restores the
    # eager copy-per-op engine (benchmark baseline / debugging).
    late_materialization: bool = True
    # Whole-plan compilation (repro.sql.compile): 'off' never compiles,
    # 'force' compiles every supported plan regardless of size, 'auto'
    # compiles when the scanned base tables total at least
    # compiled_min_rows rows (small interactive queries skip the trace
    # cost; repeated large ones amortize it through the plan cache).
    # Plans with untraceable constructs fall back to op-by-op dispatch
    # in every mode.
    compiled: str = "auto"
    compiled_min_rows: int = 1 << 15
    # Concurrent SQL serving (repro.serve.sql): the admission queue
    # drains up to serve_max_batch requests per micro-batch, waiting at
    # most serve_batch_window_ms after the first request for stragglers
    # to accumulate.  serve_shared_scans=False disables the shared
    # zone-map scan pass (every query scans its store tables alone —
    # the benchmark baseline for the sharing win); serve_coalesce=False
    # disables duplicate-query coalescing within a batch.
    serve_max_batch: int = 32
    serve_batch_window_ms: float = 2.0
    serve_shared_scans: bool = True
    serve_coalesce: bool = True
    # Resilient serving (repro.resilience): default per-request deadline
    # (None = unbounded; Executor.execute(timeout_s=...) overrides per
    # call), admission-queue depth bound (None = unbounded) with the
    # load-shedding policy applied when it fills ('reject-new' fails the
    # incoming request, 'drop-oldest' sheds the head of the queue), and
    # a per-session in-flight request cap (None = uncapped).  Shed and
    # rejected requests resolve with typed QueryErrors and are counted
    # in serve.STATS.
    serve_default_timeout_s: Optional[float] = None
    serve_queue_depth: Optional[int] = None
    serve_shed_policy: str = "reject-new"
    serve_session_inflight: Optional[int] = None
    # Transient-I/O retry budget (repro.resilience.retry): spill/store
    # reads and writes retry OSError-class failures up to io_retries
    # times with exponential backoff starting at io_retry_base_s.
    io_retries: int = 3
    io_retry_base_s: float = 0.005
    # Out-of-core execution (repro.core.pipeline / repro.sql.stream):
    # 'off' never streams, 'force' streams every supported store-backed
    # aggregate/join pipeline chunk-by-chunk, 'auto' streams when the
    # probe-side store table has at least ooc_min_rows rows (mirrors
    # distributed/compiled).  Unsupported plan shapes fall back to the
    # eager path in every mode (counted in pipeline.STATS['fallbacks']).
    out_of_core: str = "auto"
    ooc_min_rows: int = 1 << 20
    # Byte budget for host-side intermediates held by the out-of-core
    # layer (partial aggregates, spillable frames).  None = unbounded
    # (never spill); small budgets force LRU spills to .tfb v2 chunk
    # files with transparent re-hydration on access.
    memory_budget_bytes: Optional[int] = None
    # Directory for spill files; None = a per-process temp dir cleaned
    # at exit (spilled frames are additionally deleted on GC).
    spill_dir: Optional[str] = None
    # Host-side chunk prefetch depth of the streaming scan: chunk k+1
    # decodes/filters on a worker thread while chunk k runs on device.
    # 0 disables the overlap (the bench_spill baseline).
    ooc_prefetch: int = 2
    # Merge accumulated per-chunk partial aggregates every N chunks
    # (bounds the partial pool even when the budget is unbounded).
    ooc_merge_every: int = 8
    # Span tracing (repro.obs): 'off' = no spans (one branch, no
    # allocation on every instrumented path), 'on' = operator-level
    # spans (plan nodes, join algorithm picks, compile phases, serve
    # batch phases, spill events), 'detailed' = additionally per-chunk
    # spans (chunk decode, prefetch waits, per-chunk probes/merges).
    # EXPLAIN ANALYZE flips this to 'on' for the analyzed execution.
    tracing: str = "off"


CONFIG = EngineConfig()
