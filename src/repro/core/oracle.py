"""Independent reference engine for correctness testing.

Deliberately naive: Python dict/list row-at-a-time semantics, written
without reference to the TensorFrame implementation, so shared bugs are
unlikely.  Columns are plain Python lists; None is the null.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Sequence, Tuple

import numpy as np

ODF = Dict[str, List[Any]]


def from_numpy(data: Dict[str, np.ndarray]) -> ODF:
    out: ODF = {}
    for name, arr in data.items():
        arr = np.asarray(arr)
        if np.issubdtype(arr.dtype, np.datetime64):
            out[name] = list(arr.astype("datetime64[D]").astype(np.int64))
        elif np.issubdtype(arr.dtype, np.floating):
            out[name] = [float(x) for x in arr]
        elif np.issubdtype(arr.dtype, np.integer):
            out[name] = [int(x) for x in arr]
        elif arr.dtype == np.bool_:
            out[name] = [bool(x) for x in arr]
        else:
            out[name] = [None if x is None else str(x) for x in arr]
    return out


def nrows(df: ODF) -> int:
    return len(next(iter(df.values()))) if df else 0


def o_filter(df: ODF, mask: Sequence[bool]) -> ODF:
    return {k: [v[i] for i in range(len(mask)) if mask[i]] for k, v in df.items()}


def o_take(df: ODF, rows: Sequence[int]) -> ODF:
    return {k: [v[i] for i in rows] for k, v in df.items()}


def _agg_one(vals: List[Any], fn: str):
    nn = [v for v in vals if v is not None and not (isinstance(v, float) and math.isnan(v))]
    if fn == "size":
        return len(vals)
    if fn == "count":
        return len(nn)
    if fn == "nunique":
        return len(set(nn))
    if fn == "first":
        return vals[0] if vals else None
    if not nn:
        return None
    if fn == "sum":
        return sum(nn)
    if fn == "mean":
        return sum(nn) / len(nn)
    if fn == "min":
        return min(nn)
    if fn == "max":
        return max(nn)
    raise ValueError(fn)


def o_groupby(df: ODF, keys: Sequence[str], specs: Sequence[Tuple[str, str, str]]) -> ODF:
    n = nrows(df)
    groups: Dict[tuple, List[int]] = {}
    for i in range(n):
        key = tuple(df[k][i] for k in keys)
        groups.setdefault(key, []).append(i)
    out: ODF = {k: [] for k in keys}
    for out_name, _, _ in specs:
        out[out_name] = []
    for key, rows in groups.items():
        for kname, kval in zip(keys, key):
            out[kname].append(kval)
        for out_name, fn, colname in specs:
            vals = [df[colname][i] for i in rows] if colname else [1] * len(rows)
            out[out_name].append(_agg_one(vals, fn))
    return out


def o_join(
    left: ODF,
    right: ODF,
    left_on: Sequence[str],
    right_on: Sequence[str],
    how: str = "inner",
    suffix: str = "_r",
) -> ODF:
    nl, nr = nrows(left), nrows(right)
    table: Dict[tuple, List[int]] = {}
    for j in range(nr):
        key = tuple(right[k][j] for k in right_on)
        if any(v is None for v in key):
            continue
        table.setdefault(key, []).append(j)
    drop_right = {rk for lk, rk in zip(left_on, right_on) if lk == rk}
    right_names = {
        name: (name + suffix if name in left else name)
        for name in right
        if name not in drop_right
    }
    if how in ("semi", "anti"):
        keep = []
        for i in range(nl):
            key = tuple(left[k][i] for k in left_on)
            hit = (not any(v is None for v in key)) and key in table
            if (how == "semi") == hit:
                keep.append(i)
        return o_take(left, keep)
    out: ODF = {k: [] for k in left}
    for _, new in right_names.items():
        out[new] = []
    for i in range(nl):
        key = tuple(left[k][i] for k in left_on)
        matches = [] if any(v is None for v in key) else table.get(key, [])
        if matches:
            for j in matches:
                for k in left:
                    out[k].append(left[k][i])
                for old, new in right_names.items():
                    out[new].append(right[old][j])
        elif how == "left":
            for k in left:
                out[k].append(left[k][i])
            for _, new in right_names.items():
                out[new].append(None)
    return out


def o_sort(df: ODF, by: Sequence[str], ascending: Sequence[bool]) -> ODF:
    n = nrows(df)

    def keyfn(i):
        parts = []
        for name, asc in zip(by, ascending):
            v = df[name][i]
            parts.append(v if asc else _neg(v))
        return tuple(parts)

    rows = sorted(range(n), key=keyfn)
    return o_take(df, rows)


class _RevStr:
    __slots__ = ("s",)

    def __init__(self, s):
        self.s = s

    def __lt__(self, other):
        return self.s > other.s

    def __eq__(self, other):
        return self.s == other.s


def _neg(v):
    if isinstance(v, str):
        return _RevStr(v)
    return -v


# ----------------------------------------------------------------------
# result comparison helpers
# ----------------------------------------------------------------------
def records(df: ODF) -> List[tuple]:
    names = sorted(df.keys())
    n = nrows(df)
    return [tuple(df[k][i] for k in names) for i in range(n)]


def frame_to_odf(frame) -> ODF:
    out: ODF = {}
    for name in frame.column_names:
        arr = frame.column(name)
        m = frame.meta(name)
        if m.kind == "date":
            out[name] = [None if v is None else int(np.asarray(v).astype("datetime64[D]").astype(np.int64)) for v in arr]
        elif m.kind == "float":
            out[name] = [None if (isinstance(v, float) and math.isnan(v)) else float(v) for v in arr]
        elif m.kind in ("int", "bool"):
            valid = frame.valid_array(name)
            vmask = np.asarray(valid) if valid is not None else None
            out[name] = [
                None if (vmask is not None and not vmask[i]) else (int(v) if m.kind == "int" else bool(v))
                for i, v in enumerate(arr)
            ]
        else:
            out[name] = [None if v is None else str(v) for v in arr]
    return out


def assert_odf_equal(a: ODF, b: ODF, sort: bool = True, rtol: float = 1e-9):
    assert set(a.keys()) == set(b.keys()), (sorted(a), sorted(b))
    ra, rb = records(a), records(b)
    assert len(ra) == len(rb), f"row count {len(ra)} != {len(rb)}"
    if sort:
        skey = lambda t: tuple((x is None, _sortable(x)) for x in t)
        ra, rb = sorted(ra, key=skey), sorted(rb, key=skey)
    for i, (ta, tb) in enumerate(zip(ra, rb)):
        for va, vb in zip(ta, tb):
            if va is None and vb is None:
                continue
            if isinstance(va, float) or isinstance(vb, float):
                assert va is not None and vb is not None, (i, ta, tb)
                denom = max(abs(va), abs(vb), 1.0)
                assert abs(va - vb) / denom <= rtol, (i, ta, tb)
            else:
                assert va == vb, (i, ta, tb)


def _sortable(x):
    if x is None:
        return ""
    if isinstance(x, float):
        return round(x, 6)
    return x
