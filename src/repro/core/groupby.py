"""Group-by aggregation (paper §IV-B).

Main path = Alg. 2 (MojoFrame Transposed Group-By), TPU-adapted:

1. gather the k key columns into row-major layout ("transpose"),
2. build one immutable composite key per row in a single pass
   (exact int64 packing; hash fallback) — ``hashing.composite_key``,
3. find distinct keys by sort + run boundaries (Mojo Dict replacement),
4. aggregate with segment ops.

Baselines for the Fig. 11 ablation:
- ``incremental_group_ids``: Alg. 1 (Pandas column-order incremental
  sparse-to-dense), vectorized in numpy, and
- ``pydict_group_ids``: the "PandasMojo" pathology — a Python dict of
  per-row tuples built row-by-row (the mutable-key deep-copy analog).
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from . import hashing
from .agg import normalize_specs, segment_agg
from .frame import INT, TensorFrame


class GroupBy:
    def __init__(self, frame: TensorFrame, keys: List[str]):
        self.frame = frame
        self.keys = keys
        packed, self.exact, dense_domain = hashing.composite_key(frame, keys)
        self.uniques, self.gids, self.m = hashing.distinct(packed, dense_domain)
        # representative (first-occurrence) row per group
        if frame.nrows:
            self.rep = jax.ops.segment_min(
                jnp.arange(frame.nrows, dtype=INT), self.gids, self.m
            )
        else:
            self.rep = jnp.zeros((0,), dtype=INT)

    @property
    def ngroups(self) -> int:
        return self.m

    def agg(self, specs) -> TensorFrame:
        specs = normalize_specs(specs)
        # key columns come from the representative rows, preserving
        # original values (and dictionaries) exactly
        out = self.frame.take(self.rep, stats="subset").select(self.keys)
        for out_name, fn, colname in specs:
            vals = segment_agg(self.frame, self.gids, self.m, fn, colname)
            if fn == "first":
                meta = self.frame.meta(colname)
                if meta.kind == "dict":
                    out = out._append_int_column(out_name, vals, "dict", meta.dictionary)
                    continue
                if meta.kind in ("date", "bool"):
                    out = out._append_int_column(out_name, vals, meta.kind)
                    continue
            if jnp.issubdtype(vals.dtype, jnp.floating):
                out = out._append_float_column(out_name, vals)
            else:
                out = out._append_int_column(out_name, vals)
        # the grouped output is unique by construction on its key
        # combination: seed the stats cache so a downstream
        # join(algorithm='auto') direct-addresses without a sort test.
        # Not when an aggregate output overwrote a key column — its
        # values are no longer the group keys.
        if not (set(self.keys) & {name for name, _, _ in specs}):
            out.set_stats(self.keys, unique=True, distinct=self.m)
            if len(self.keys) == 1:
                out.set_stats(self.keys[0], unique=True, distinct=self.m)
        return out

    def size(self, name: str = "size") -> TensorFrame:
        return self.agg([(name, "size", "")])

    def group_ids(self) -> jax.Array:
        return self.gids


def unique_rows(frame: TensorFrame, keys: List[str]) -> TensorFrame:
    gb = GroupBy(frame, keys)
    out = frame.take(gb.rep, stats="subset").select(keys)
    out.set_stats(keys, unique=True, distinct=gb.m)
    if len(keys) == 1:
        out.set_stats(keys[0], unique=True, distinct=gb.m)
    return out


def nunique_column(frame: TensorFrame, name: str) -> int:
    codes, _ = hashing.key_codes(frame, name)
    _, _, m = hashing.distinct(codes)
    # cache on the source frame: a later join build against this column
    # skips its uniqueness sort test
    if not frame.has_nulls(name):
        frame.set_stats(name, unique=(m == frame.nrows), distinct=m)
    return m


# ----------------------------------------------------------------------
# Fig. 11 ablation baselines (benchmark-only)
# ----------------------------------------------------------------------
def incremental_group_ids(cols: Sequence[np.ndarray]) -> np.ndarray:
    """Alg. 1: per-column incremental sparse-to-dense composite building
    (the Pandas strategy).  n re-densifications of the running key."""
    n = cols[0].shape[0]
    ids = np.zeros(n, dtype=np.int64)
    for c in cols:
        _, cc = np.unique(c, return_inverse=True)
        card = cc.max() + 1 if n else 1
        pairs = ids * np.int64(card) + cc
        _, ids = np.unique(pairs, return_inverse=True)
    return ids


def pydict_group_ids(cols: Sequence[np.ndarray]) -> np.ndarray:
    """'PandasMojo' analog: row-by-row tuple keys through a Python dict
    (what Mojo's deep-copying Dict forces; Fig. 11 right)."""
    n = cols[0].shape[0]
    ids = np.empty(n, dtype=np.int64)
    seen: Dict[tuple, int] = {}
    host = [np.asarray(c) for c in cols]
    for i in range(n):
        key = tuple(c[i] for c in host)
        gid = seen.get(key)
        if gid is None:
            gid = len(seen)
            seen[key] = gid
        ids[i] = gid
    return ids


def transposed_group_ids(cols: Sequence[np.ndarray]) -> np.ndarray:
    """Alg. 2 key-building in isolation (for the operator benchmark):
    one-pass packed composite + sort-based distinct."""
    arrs = [jnp.asarray(np.asarray(c).astype(np.int64)) for c in cols]
    packed = jnp.zeros(arrs[0].shape, dtype=INT)
    if arrs[0].shape[0]:
        # all k cardinalities in ONE device fetch (was: one int(a.max())
        # host sync per key column)
        cards = np.asarray(jnp.stack([a.max() for a in arrs])) + 1
    else:
        cards = np.ones((len(arrs),), dtype=np.int64)
    for a, card in zip(arrs, cards):
        packed = packed * np.int64(max(1, int(card))) + a
    _, gids, _ = hashing.distinct(packed)
    return np.asarray(gids)
