"""Trait-based stateless expression algebra (paper §IV-A, Fig. 4).

MojoFrame legalizes parallel UDF execution by forcing user filters to be
composed from a closed set of stateless, compiler-visible base
operations.  The JAX analog: ``Expr`` is a pure combinator tree over
columns/literals; evaluation lowers to fused vectorized XLA (and to
Pallas string kernels on TPU).  Statelessness is structural — there is
no escape hatch into row-at-a-time Python.

String predicates exploit cardinality-awareness twice: on
dictionary-encoded columns the predicate is evaluated over the (tiny)
dictionary and broadcast through a code-indexed LUT gather.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from . import encoding, strings
from .config import CONFIG
from .frame import INT, TensorFrame, float_dtype


# ----------------------------------------------------------------------
# evaluated values
# ----------------------------------------------------------------------
@dataclasses.dataclass
class Value:
    kind: str  # 'num' | 'bool' | 'str' | 'date'
    arr: jax.Array  # codes for 'str'
    dictionary: Optional[np.ndarray] = None
    valid: Optional[jax.Array] = None

    def as_num(self) -> "Value":
        if self.kind == "str":
            raise TypeError("string value used in numeric context")
        if self.kind in ("bool",):
            return Value("num", self.arr.astype(INT), valid=self.valid)
        return self


def _combine_valid(*vals: Optional[jax.Array]) -> Optional[jax.Array]:
    present = [v for v in vals if v is not None]
    if not present:
        return None
    out = present[0]
    for v in present[1:]:
        out = out & v
    return out


# ----------------------------------------------------------------------
# helpers for date math
# ----------------------------------------------------------------------
def parse_date(s: str) -> int:
    """'YYYY-MM-DD' -> days since 1970-01-01."""
    return int(np.datetime64(s, "D").astype(np.int64))


def civil_from_days(days: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Vectorized proleptic-Gregorian (y, m, d) from epoch days."""
    z = days.astype(INT) + 719468
    era = jnp.floor_divide(z, 146097)
    doe = z - era * 146097
    yoe = jnp.floor_divide(
        doe - doe // 1460 + doe // 36524 - doe // 146096, 365
    )
    y = yoe + era * 400
    doy = doe - (365 * yoe + yoe // 4 - yoe // 100)
    mp = jnp.floor_divide(5 * doy + 2, 153)
    dd = doy - jnp.floor_divide(153 * mp + 2, 5) + 1
    m = mp + 3 - 12 * (mp >= 10)
    y = y + (m <= 2)
    return y, m, dd


# ----------------------------------------------------------------------
# string predicate evaluation over a dictionary (host numpy)
# ----------------------------------------------------------------------
def _dict_lut_bool(dictionary: np.ndarray, fn: Callable[[np.ndarray], np.ndarray]) -> np.ndarray:
    as_u = dictionary.astype("U")
    return np.asarray(fn(as_u), dtype=bool)


def _like_regex(pattern: str) -> "re.Pattern":
    out = []
    for ch in pattern:
        if ch == "%":
            out.append(".*")
        elif ch == "_":
            out.append(".")
        else:
            out.append(re.escape(ch))
    return re.compile("".join(out), flags=re.S)


def _host_exists_before(s: str, first: str, second: str) -> bool:
    i = s.find(first)
    if i < 0:
        return False
    return s.find(second, i + len(first)) >= 0


# ----------------------------------------------------------------------
# Expr nodes
# ----------------------------------------------------------------------
class Expr:
    # -------- operators --------
    def __add__(self, o): return BinArith("add", self, wrap(o))
    def __radd__(self, o): return BinArith("add", wrap(o), self)
    def __sub__(self, o): return BinArith("sub", self, wrap(o))
    def __rsub__(self, o): return BinArith("sub", wrap(o), self)
    def __mul__(self, o): return BinArith("mul", self, wrap(o))
    def __rmul__(self, o): return BinArith("mul", wrap(o), self)
    def __truediv__(self, o): return BinArith("div", self, wrap(o))
    def __rtruediv__(self, o): return BinArith("div", wrap(o), self)
    def __eq__(self, o): return Cmp("eq", self, wrap(o))  # type: ignore[override]
    def __ne__(self, o): return Cmp("ne", self, wrap(o))  # type: ignore[override]
    def __lt__(self, o): return Cmp("lt", self, wrap(o))
    def __le__(self, o): return Cmp("le", self, wrap(o))
    def __gt__(self, o): return Cmp("gt", self, wrap(o))
    def __ge__(self, o): return Cmp("ge", self, wrap(o))
    def __and__(self, o): return BoolOp("and", self, wrap(o))
    def __or__(self, o): return BoolOp("or", self, wrap(o))
    def __invert__(self): return Not(self)
    def __hash__(self):  # Expr overrides __eq__; keep hashable by identity
        return id(self)

    def isin(self, values: Sequence) -> "Expr": return IsIn(self, list(values))
    def between(self, lo, hi) -> "Expr": return BoolOp("and", Cmp("ge", self, wrap(lo)), Cmp("le", self, wrap(hi)))
    def fillna(self, v) -> "Expr": return FillNa(self, wrap(v))
    def is_null(self) -> "Expr": return IsNull(self)
    def cast_float(self) -> "Expr": return Cast(self, "float")
    def cast_int(self) -> "Expr": return Cast(self, "int")

    # math traits
    def sin(self): return MathFn("sin", self)
    def cos(self): return MathFn("cos", self)
    def exp(self): return MathFn("exp", self)
    def log(self): return MathFn("log", self)
    def sqrt(self): return MathFn("sqrt", self)
    def abs(self): return MathFn("abs", self)
    def floor(self): return MathFn("floor", self)

    # string traits
    @property
    def str(self) -> "StrNamespace": return StrNamespace(self)
    # date traits
    @property
    def dt(self) -> "DtNamespace": return DtNamespace(self)

    # -------- evaluation --------
    def eval(self, frame: TensorFrame) -> Value:
        raise NotImplementedError

    def eval_bool(self, frame: TensorFrame) -> jax.Array:
        v = self.eval(frame)
        if v.kind != "bool":
            raise TypeError(f"filter expression is {v.kind}, not bool")
        arr = v.arr
        if v.valid is not None:
            arr = arr & v.valid  # SQL: NULL comparisons are not-true
        return arr


def wrap(x) -> Expr:
    return x if isinstance(x, Expr) else Lit(x)


@dataclasses.dataclass(eq=False)
class Col(Expr):
    name: str

    def eval(self, frame: TensorFrame) -> Value:
        # view-aware single-column access: a lazy (RowView) frame
        # gathers just this column, not the whole payload
        m = frame.meta(self.name)
        valid = frame.valid_array(self.name)
        if m.kind == "float":
            return Value("num", frame.col_values(self.name), valid=valid)
        if m.kind == "dict":
            return Value("str", frame.col_values(self.name), m.dictionary, valid)
        if m.kind == "obj":
            codes, dictionary = frame.offloaded[self.name].codes()
            return Value("str", codes, dictionary, valid)
        arr = frame.col_values(self.name)
        if m.kind == "date":
            return Value("date", arr, valid=valid)
        if m.kind == "bool":
            return Value("bool", arr != 0, valid=valid)
        return Value("num", arr, valid=valid)


@dataclasses.dataclass(eq=False)
class Lit(Expr):
    value: Any

    def eval(self, frame: TensorFrame) -> Value:
        v = self.value
        n = frame.nrows
        if isinstance(v, bool):
            return Value("bool", jnp.full((n,), v))
        if isinstance(v, (int, np.integer)):
            return Value("num", jnp.full((n,), v, dtype=INT))
        if isinstance(v, (float, np.floating)):
            return Value("num", jnp.full((n,), v, dtype=float_dtype()))
        if isinstance(v, str):
            # scalar string literal: single-entry dictionary, code 0
            return Value("str", jnp.zeros((n,), dtype=INT), np.array([v], dtype=object))
        if isinstance(v, (np.datetime64,)):
            return Value("date", jnp.full((n,), int(v.astype("datetime64[D]").astype(np.int64)), dtype=INT))
        raise TypeError(f"unsupported literal {type(v)}")


@dataclasses.dataclass(eq=False)
class DateLit(Expr):
    days: int

    def eval(self, frame: TensorFrame) -> Value:
        return Value("date", jnp.full((frame.nrows,), self.days, dtype=INT))


def d(s: str) -> DateLit:
    """Date literal: d('1994-01-01')."""
    return DateLit(parse_date(s))


_ARITH = {
    "add": jnp.add,
    "sub": jnp.subtract,
    "mul": jnp.multiply,
}


@dataclasses.dataclass(eq=False)
class BinArith(Expr):
    op: str
    a: Expr
    b: Expr

    def eval(self, frame: TensorFrame) -> Value:
        va, vb = self.a.eval(frame), self.b.eval(frame)
        valid = _combine_valid(va.valid, vb.valid)
        # date +/- int days stays a date
        if va.kind == "date" and vb.kind == "num" and self.op in ("add", "sub"):
            return Value("date", _ARITH[self.op](va.arr, vb.arr.astype(INT)), valid=valid)
        if va.kind == "date" and vb.kind == "date" and self.op == "sub":
            return Value("num", va.arr - vb.arr, valid=valid)
        a, b = va.as_num().arr, vb.as_num().arr
        if self.op == "div":
            fd = float_dtype()
            return Value("num", a.astype(fd) / b.astype(fd), valid=valid)
        return Value("num", _ARITH[self.op](a, b), valid=valid)


_CMPS = {
    "eq": jnp.equal,
    "ne": jnp.not_equal,
    "lt": jnp.less,
    "le": jnp.less_equal,
    "gt": jnp.greater,
    "ge": jnp.greater_equal,
}


@dataclasses.dataclass(eq=False)
class Cmp(Expr):
    op: str
    a: Expr
    b: Expr

    def eval(self, frame: TensorFrame) -> Value:
        va, vb = self.a.eval(frame), self.b.eval(frame)
        valid = _combine_valid(va.valid, vb.valid)
        if va.kind == "str" or vb.kind == "str":
            return self._eval_str(va, vb, valid)
        a, b = va.as_num().arr if va.kind != "date" else va.arr, None
        b = vb.as_num().arr if vb.kind != "date" else vb.arr
        return Value("bool", _CMPS[self.op](a, b), valid=valid)

    def _eval_str(self, va: Value, vb: Value, valid) -> Value:
        if va.kind != "str" or vb.kind != "str":
            raise TypeError("comparison between string and non-string")
        # scalar-literal fast path: dictionary of size 1 from Lit
        if vb.dictionary is not None and vb.dictionary.shape[0] == 1 and isinstance(self.b, Lit):
            return Value("bool", self._codes_vs_literal(va, str(vb.dictionary[0])), valid=valid)
        if va.dictionary is not None and va.dictionary.shape[0] == 1 and isinstance(self.a, Lit):
            flipped = {"lt": "gt", "le": "ge", "gt": "lt", "ge": "le"}.get(self.op, self.op)
            return Value("bool", Cmp(flipped, self.b, self.a)._codes_vs_literal(vb, str(va.dictionary[0])), valid=valid)
        # column vs column: shared factorization then code compare
        if va.dictionary is vb.dictionary:
            ca, cb = va.arr, vb.arr
        else:
            _, ra, rb = encoding.merge_dictionaries(va.dictionary, vb.dictionary)
            ca = jnp.asarray(ra, dtype=INT)[va.arr]
            cb = jnp.asarray(rb, dtype=INT)[vb.arr]
        return Value("bool", _CMPS[self.op](ca, cb), valid=valid)

    def _codes_vs_literal(self, v: Value, lit: str) -> jax.Array:
        dic = v.dictionary
        codes = v.arr
        left = int(np.searchsorted(dic.astype("U"), lit, side="left"))
        right = int(np.searchsorted(dic.astype("U"), lit, side="right"))
        present = right > left
        if self.op == "eq":
            return (codes == left) if present else jnp.zeros_like(codes, dtype=bool)
        if self.op == "ne":
            return (codes != left) if present else jnp.ones_like(codes, dtype=bool)
        if self.op == "lt":
            return codes < left
        if self.op == "le":
            return codes < right
        if self.op == "gt":
            return codes >= right
        if self.op == "ge":
            return codes >= left
        raise ValueError(self.op)


@dataclasses.dataclass(eq=False)
class BoolOp(Expr):
    op: str
    a: Expr
    b: Expr

    def eval(self, frame: TensorFrame) -> Value:
        va, vb = self.a.eval(frame), self.b.eval(frame)
        if va.kind != "bool" or vb.kind != "bool":
            raise TypeError("boolean op on non-boolean")
        # fold null as False before combining (three-valued logic is not
        # needed by the workloads; NULL predicates are not-true)
        a = va.arr if va.valid is None else (va.arr & va.valid)
        b = vb.arr if vb.valid is None else (vb.arr & vb.valid)
        out = (a & b) if self.op == "and" else (a | b)
        return Value("bool", out)


@dataclasses.dataclass(eq=False)
class Not(Expr):
    a: Expr

    def eval(self, frame: TensorFrame) -> Value:
        v = self.a.eval(frame)
        if v.kind != "bool":
            raise TypeError("~ on non-boolean")
        arr = v.arr if v.valid is None else (v.arr & v.valid)
        out = ~arr
        if v.valid is not None:
            out = out & v.valid  # NOT NULL is still NULL -> not-true
        return Value("bool", out)


@dataclasses.dataclass(eq=False)
class IsIn(Expr):
    a: Expr
    values: List[Any]

    def eval(self, frame: TensorFrame) -> Value:
        v = self.a.eval(frame)
        if v.kind == "str":
            lut = np.isin(v.dictionary.astype("U"), np.asarray(self.values, dtype="U"))
            return Value("bool", jnp.asarray(lut)[v.arr], valid=v.valid)
        arr = v.as_num().arr if v.kind != "date" else v.arr
        vals = [parse_date(x) if isinstance(x, str) and v.kind == "date" else x for x in self.values]
        out = jnp.zeros(arr.shape, dtype=bool)
        for x in vals:
            out = out | (arr == x)
        return Value("bool", out, valid=v.valid)


@dataclasses.dataclass(eq=False)
class MathFn(Expr):
    fn: str
    a: Expr

    def eval(self, frame: TensorFrame) -> Value:
        v = self.a.eval(frame).as_num()
        fd = float_dtype()
        x = v.arr.astype(fd)
        fns = {
            "sin": jnp.sin, "cos": jnp.cos, "exp": jnp.exp, "log": jnp.log,
            "sqrt": jnp.sqrt, "abs": jnp.abs, "floor": jnp.floor,
        }
        return Value("num", fns[self.fn](x), valid=v.valid)


@dataclasses.dataclass(eq=False)
class IfElse(Expr):
    cond: Expr
    t: Expr
    f: Expr

    def eval(self, frame: TensorFrame) -> Value:
        c = self.cond.eval(frame)
        vt = self.t.eval(frame)
        vf = self.f.eval(frame)
        carr = c.arr if c.valid is None else (c.arr & c.valid)
        if vt.kind == "str" or vf.kind == "str":
            raise TypeError("if_else on strings not supported")
        fd = float_dtype()
        ta, fa = vt.as_num().arr, vf.as_num().arr
        if ta.dtype != fa.dtype:
            ta, fa = ta.astype(fd), fa.astype(fd)
        return Value("num", jnp.where(carr, ta, fa),
                     valid=_combine_valid(vt.valid, vf.valid))


def if_else(cond, t, f) -> Expr:
    return IfElse(wrap(cond), wrap(t), wrap(f))


@dataclasses.dataclass(eq=False)
class Cast(Expr):
    a: Expr
    to: str

    def eval(self, frame: TensorFrame) -> Value:
        v = self.a.eval(frame).as_num()
        if self.to == "float":
            return Value("num", v.arr.astype(float_dtype()), valid=v.valid)
        return Value("num", v.arr.astype(INT), valid=v.valid)


@dataclasses.dataclass(eq=False)
class FillNa(Expr):
    a: Expr
    v: Expr

    def eval(self, frame: TensorFrame) -> Value:
        va = self.a.eval(frame)
        if va.valid is None:
            return va
        vb = self.v.eval(frame).as_num()
        arr = jnp.where(va.valid, va.as_num().arr, vb.arr.astype(va.as_num().arr.dtype))
        return Value(va.kind if va.kind != "bool" else "num", arr)


@dataclasses.dataclass(eq=False)
class IsNull(Expr):
    a: Expr

    def eval(self, frame: TensorFrame) -> Value:
        v = self.a.eval(frame)
        if v.valid is None:
            # no validity companion: float NaN cells are the nulls (the
            # store's representation; matches the oracle's math.isnan)
            if v.kind == "num" and jnp.issubdtype(v.arr.dtype, jnp.floating):
                return Value("bool", jnp.isnan(v.arr))
            return Value("bool", jnp.zeros((frame.nrows,), dtype=bool))
        return Value("bool", ~v.valid)


@dataclasses.dataclass(eq=False)
class Udf(Expr):
    """Stateless numeric UDF: a pure jnp function over column arrays.

    The 'trait' contract of the paper: the function sees only vector
    inputs and returns a vector — no cross-row state is expressible.
    """

    fn: Callable
    args: Tuple[Expr, ...]
    returns: str = "num"  # or 'bool'

    def eval(self, frame: TensorFrame) -> Value:
        vals = [a.eval(frame) for a in self.args]
        arrs = [v.as_num().arr if v.kind != "date" else v.arr for v in vals]
        out = self.fn(*arrs)
        return Value(self.returns, out, valid=_combine_valid(*[v.valid for v in vals]))


def udf(fn: Callable, *args, returns: str = "num") -> Expr:
    return Udf(fn, tuple(wrap(a) for a in args), returns)


# ----------------------------------------------------------------------
# string namespace
# ----------------------------------------------------------------------
import functools as _functools


@_functools.lru_cache(maxsize=256)
def _packed_op_jitted(op: str, args: tuple, L: int):
    """jit-compiled packed-byte string predicate, cached per
    (op, pattern args, packed width) — the stateless-UDF compilation
    step (the paper's Mojo-JIT analog)."""
    fns = {
        "contains": strings.contains,
        "startswith": strings.startswith,
        "endswith": strings.endswith,
        "like": strings.like,
        "exists_before": strings.exists_before,
    }
    f = fns[op]
    return jax.jit(lambda packed, lens: f(packed, lens, *args))


@dataclasses.dataclass(eq=False)
class StrOp(Expr):
    op: str
    a: Expr
    args: Tuple[Any, ...]

    def _device_path(self, frame: TensorFrame) -> Optional[jax.Array]:
        """Packed-byte device path for offloaded columns (TPU hot path)."""
        if not CONFIG.use_device_strings or not isinstance(self.a, Col):
            return None
        m = frame.meta(self.a.name)
        if m.kind != "obj":
            return None
        oc = frame.offloaded[self.a.name]
        packed, lens = oc.packed()
        fns = {
            "contains": strings.contains,
            "startswith": strings.startswith,
            "endswith": strings.endswith,
            "like": strings.like,
            "exists_before": strings.exists_before,
        }
        if self.op not in fns:
            return None
        phys = fns[self.op](packed, lens, *self.args)
        return phys[oc.idx]

    # ops whose dictionary-sized evaluation can run vectorized on the
    # packed byte tensor instead of a Python loop (high-card columns)
    _PACKABLE = ("contains", "startswith", "endswith", "like", "exists_before")
    _PACK_THRESHOLD = 2048

    def _packed_dict_lut(self, dic: np.ndarray) -> Optional[np.ndarray]:
        """Evaluate the predicate over the dictionary via the packed
        byte-tensor kernels (vectorized + jit-fused) — the
        cardinality-aware fast path for large dictionaries."""
        if self.op not in self._PACKABLE or dic.shape[0] < self._PACK_THRESHOLD:
            return None
        packed, lens = strings.pack_strings_cached(dic, CONFIG.max_packed_len)
        try:
            fn = _packed_op_jitted(self.op, self.args, int(packed.shape[1]))
            return np.asarray(fn(packed, lens))
        except Exception:
            return None

    def eval(self, frame: TensorFrame) -> Value:
        dev = self._device_path(frame)
        if dev is not None:
            return Value("bool", dev)
        v = self.a.eval(frame)
        if v.kind != "str":
            raise TypeError(f"string op {self.op} on {v.kind}")
        dic = v.dictionary
        plut = self._packed_dict_lut(dic)
        if plut is not None:
            return Value("bool", jnp.asarray(plut)[v.arr], valid=v.valid)
        as_u = dic.astype("U")
        if self.op == "contains":
            lut = np.char.find(as_u, self.args[0]) >= 0
        elif self.op == "startswith":
            lut = np.char.startswith(as_u, self.args[0])
        elif self.op == "endswith":
            lut = np.char.endswith(as_u, self.args[0])
        elif self.op == "like":
            rx = _like_regex(self.args[0])
            lut = np.array([bool(rx.fullmatch(s)) for s in as_u], dtype=bool)
        elif self.op == "exists_before":
            first, second = self.args
            lut = np.array([_host_exists_before(s, first, second) for s in as_u], dtype=bool)
        elif self.op == "slice":
            start, stop = self.args
            sliced = np.array([s[start:stop] for s in as_u], dtype=object)
            new_dic, remap = np.unique(sliced, return_inverse=True)
            codes = jnp.asarray(remap.astype(np.int64))[v.arr]
            return Value("str", codes, new_dic, v.valid)
        elif self.op == "len":
            lens = np.array([len(s) for s in as_u], dtype=np.int64)
            return Value("num", jnp.asarray(lens)[v.arr], valid=v.valid)
        else:
            raise ValueError(self.op)
        return Value("bool", jnp.asarray(lut)[v.arr], valid=v.valid)


class StrNamespace:
    def __init__(self, e: Expr):
        self._e = e

    def contains(self, pat: str) -> Expr: return StrOp("contains", self._e, (pat,))
    def startswith(self, pat: str) -> Expr: return StrOp("startswith", self._e, (pat,))
    def endswith(self, pat: str) -> Expr: return StrOp("endswith", self._e, (pat,))
    def like(self, pattern: str) -> Expr: return StrOp("like", self._e, (pattern,))
    def exists_before(self, first: str, second: str) -> Expr:
        return StrOp("exists_before", self._e, (first, second))
    def not_exists_before(self, first: str, second: str) -> Expr:
        """The paper's not_string_exists_before (Q13/Q16 UDF)."""
        return Not(StrOp("exists_before", self._e, (first, second)))
    def slice(self, start: int, stop: int) -> Expr: return StrOp("slice", self._e, (start, stop))
    def len(self) -> Expr: return StrOp("len", self._e, ())


class DtNamespace:
    def __init__(self, e: Expr):
        self._e = e

    def year(self) -> Expr: return DateField("year", self._e)
    def month(self) -> Expr: return DateField("month", self._e)
    def day(self) -> Expr: return DateField("day", self._e)


@dataclasses.dataclass(eq=False)
class DateField(Expr):
    field: str
    a: Expr

    def eval(self, frame: TensorFrame) -> Value:
        v = self.a.eval(frame)
        if v.kind != "date":
            raise TypeError("dt accessor on non-date")
        y, m, dd = civil_from_days(v.arr)
        out = {"year": y, "month": m, "day": dd}[self.field]
        return Value("num", out, valid=v.valid)


def col(name: str) -> Col:
    return Col(name)


def lit(v) -> Lit:
    return Lit(v)
