"""Composite-key construction (paper §IV-B).

The paper's insight — build the composite key **once per row** in
row-major (transposed) layout as an immutable tuple, instead of
incrementally per column with mutable keys — maps to TPU as:

- *exact packing*: factorized codes have known cardinalities, so a
  k-tuple packs into one int64 by Horner's rule when the domain product
  fits 63 bits.  The packed scalar IS the immutable tuple.
- *hash fallback*: when the domain overflows, a splitmix64-style mix
  combines the columns (collision odds ~ n^2 / 2^64; documented).

Finding distinct keys then becomes sort + run-boundary detection, and
the per-group reduction a segment op — the TPU replacement for Mojo's
Dict insert.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .frame import INT, TensorFrame

_SPLIT_K1 = np.uint64(0x9E3779B97F4A7C15)
_SPLIT_K2 = np.uint64(0xBF58476D1CE4E5B9)
_SPLIT_K3 = np.uint64(0x94D049BB133111EB)

MAX_PACK = (1 << 62)


def splitmix64(x: jax.Array) -> jax.Array:
    z = x.astype(jnp.uint64) + _SPLIT_K1
    z = (z ^ (z >> np.uint64(30))) * _SPLIT_K2
    z = (z ^ (z >> np.uint64(27))) * _SPLIT_K3
    return z ^ (z >> np.uint64(31))


def hash_combine(cols: Sequence[jax.Array]) -> jax.Array:
    """64-bit tuple hash of k integer columns (the Alg.2 hash step)."""
    h = jnp.zeros(cols[0].shape, dtype=jnp.uint64)
    for c in cols:
        h = splitmix64(h ^ splitmix64(c.astype(jnp.uint64)))
    # shift into non-negative int64 for sorting/searchsorted
    return (h >> np.uint64(1)).astype(INT)


def key_codes(frame: TensorFrame, name: str) -> Tuple[jax.Array, int]:
    """Dense (codes, cardinality) for a grouping/join key column.

    dict/obj columns already have dense codes; integer-like columns are
    range-compressed (val - min) when the range is sane, else densified
    via a device unique (host-syncs the count — eager engine).
    """
    m = frame.meta(name)
    if m.kind == "dict":
        return frame.col_values(name), int(m.dictionary.shape[0])
    if m.kind == "obj":
        codes, dictionary = frame.offloaded[name].codes()
        return codes, int(dictionary.shape[0])
    if m.kind == "float":
        # group-by on measures (e.g. TPC-H Q10's c_acctbal): bitcast to
        # integer lanes — bit equality == value equality for our data
        f = frame.col_values(name)
        arr = jax.lax.bitcast_convert_type(f, jnp.int64 if f.dtype == jnp.float64 else jnp.int32).astype(INT)
        if arr.shape[0] == 0:
            return arr, 1
        uniq = jnp.unique(arr)
        return jnp.searchsorted(uniq, arr).astype(INT), int(uniq.shape[0])
    arr = frame.col_values(name)
    if arr.shape[0] == 0:
        return arr, 1
    lo, hi = frame.int_bounds(name)  # cached; one fused fetch on miss
    span = hi - lo + 1
    if span <= max(4 * arr.shape[0], 1 << 20):
        return arr - lo, span
    # sparse domain: densify
    uniq = jnp.unique(arr)
    return jnp.searchsorted(uniq, arr).astype(INT), int(uniq.shape[0])


def composite_key(
    frame: TensorFrame, keys: Sequence[str]
) -> Tuple[jax.Array, bool, Optional[int]]:
    """Single int64 composite key per row.

    Returns (key_array, exact, dense_domain) — exact=True when keys
    pack losslessly; dense_domain is the packed domain size when every
    packed value is additionally guaranteed non-negative (no dict/obj
    null sentinels), which lets ``distinct`` take its sort-free dense
    path.  This is the transposed single-pass construction of Alg. 2:
    all key columns are gathered first ("transpose"), then combined
    row-wise.
    """
    cols: List[Tuple[jax.Array, int]] = [key_codes(frame, k) for k in keys]
    prod = 1
    for _, card in cols:
        prod *= max(1, card)
        if prod >= MAX_PACK:
            break
    if prod < MAX_PACK:
        packed = jnp.zeros((frame.nrows,), dtype=INT)
        for codes, card in cols:
            packed = packed * np.int64(max(1, card)) + codes.astype(INT)
        dense_ok = all(
            not (
                frame.meta(k).kind in ("dict", "obj") and frame.has_nulls(k)
            )
            for k in keys
        )
        return packed, True, (prod if dense_ok else None)
    return hash_combine([c for c, _ in cols]), False, None


#: Fuse the dense-distinct phases with jit at this size (shapes repeat
#: in real workloads; small test shapes stay op-by-op, no compiles).
_JIT_MIN_ROWS = 1 << 17


def _dense_rank(packed: jax.Array, domain: int):
    present = jnp.zeros((domain,), dtype=INT).at[packed].set(1)
    ranks = jnp.cumsum(present) - 1  # value -> its sorted-unique id
    return present, ranks


def _dense_compact(packed, present, ranks, m: int):
    gids = ranks[packed]
    dest = jnp.where(present == 1, ranks, np.int64(m))
    uniques = (
        jnp.zeros((m + 1,), dtype=packed.dtype)
        .at[dest]
        .set(jnp.arange(present.shape[0], dtype=packed.dtype))[:m]
    )
    return uniques, gids.astype(INT)


_dense_rank_jit = jax.jit(_dense_rank, static_argnums=(1,))
_dense_compact_jit = jax.jit(_dense_compact, static_argnums=(3,))


def distinct(
    packed: jax.Array, domain: Optional[int] = None
) -> Tuple[jax.Array, jax.Array, int]:
    """(sorted_uniques, group_ids, n_groups). Host-syncs n_groups.

    Dense path: when the caller knows the packed values are dense
    non-negative codes over a small ``domain`` (exact composite
    packing), distinct-finding needs NO sort at all — scatter presence
    bits, prefix-sum them into ranks, gather.  O(n + domain), the
    classic dense group-by.

    Sort path: one argsort + run boundaries + two scatters: group ids
    are the run ranks scattered back through the sort permutation, and
    the uniques compact by rank — no second sort (``jnp.unique``) and
    no binary search.  Either path syncs only the single deferred
    n_groups fetch.
    """
    n = int(packed.shape[0])
    if n == 0:
        return packed, packed, 0
    if domain is not None and 0 < domain <= 4 * n:  # O(n+domain) stays O(n)
        jit = n >= _JIT_MIN_ROWS
        present, ranks = (_dense_rank_jit if jit else _dense_rank)(
            packed, domain
        )
        m = int(ranks[-1]) + 1  # the one host sync
        uniques, gids = (_dense_compact_jit if jit else _dense_compact)(
            packed, present, ranks, m
        )
        return uniques, gids, m
    order = jnp.argsort(packed)
    sp = packed[order]
    boundary = jnp.concatenate(
        [jnp.ones((1,), dtype=bool), sp[1:] != sp[:-1]]
    )
    ranks = jnp.cumsum(boundary.astype(INT)) - 1  # group id, sorted order
    m = int(ranks[-1]) + 1  # the one host sync
    uniques = jnp.zeros((m,), dtype=packed.dtype).at[ranks].set(sp)
    gids = jnp.zeros((n,), dtype=INT).at[order].set(ranks)
    return uniques, gids, m
