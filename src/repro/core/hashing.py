"""Composite-key construction (paper §IV-B).

The paper's insight — build the composite key **once per row** in
row-major (transposed) layout as an immutable tuple, instead of
incrementally per column with mutable keys — maps to TPU as:

- *exact packing*: factorized codes have known cardinalities, so a
  k-tuple packs into one int64 by Horner's rule when the domain product
  fits 63 bits.  The packed scalar IS the immutable tuple.
- *hash fallback*: when the domain overflows, a splitmix64-style mix
  combines the columns (collision odds ~ n^2 / 2^64; documented).

Finding distinct keys then becomes sort + run-boundary detection, and
the per-group reduction a segment op — the TPU replacement for Mojo's
Dict insert.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .frame import INT, TensorFrame

_SPLIT_K1 = np.uint64(0x9E3779B97F4A7C15)
_SPLIT_K2 = np.uint64(0xBF58476D1CE4E5B9)
_SPLIT_K3 = np.uint64(0x94D049BB133111EB)

MAX_PACK = (1 << 62)


def splitmix64(x: jax.Array) -> jax.Array:
    z = x.astype(jnp.uint64) + _SPLIT_K1
    z = (z ^ (z >> np.uint64(30))) * _SPLIT_K2
    z = (z ^ (z >> np.uint64(27))) * _SPLIT_K3
    return z ^ (z >> np.uint64(31))


def hash_combine(cols: Sequence[jax.Array]) -> jax.Array:
    """64-bit tuple hash of k integer columns (the Alg.2 hash step)."""
    h = jnp.zeros(cols[0].shape, dtype=jnp.uint64)
    for c in cols:
        h = splitmix64(h ^ splitmix64(c.astype(jnp.uint64)))
    # shift into non-negative int64 for sorting/searchsorted
    return (h >> np.uint64(1)).astype(INT)


def key_codes(frame: TensorFrame, name: str) -> Tuple[jax.Array, int]:
    """Dense (codes, cardinality) for a grouping/join key column.

    dict/obj columns already have dense codes; integer-like columns are
    range-compressed (val - min) when the range is sane, else densified
    via a device unique (host-syncs the count — eager engine).
    """
    m = frame.meta(name)
    if m.kind == "dict":
        return frame.itensor[:, m.slot], int(m.dictionary.shape[0])
    if m.kind == "obj":
        codes, dictionary = frame.offloaded[name].codes()
        return codes, int(dictionary.shape[0])
    if m.kind == "float":
        # group-by on measures (e.g. TPC-H Q10's c_acctbal): bitcast to
        # integer lanes — bit equality == value equality for our data
        f = frame.ftensor[:, m.slot]
        arr = jax.lax.bitcast_convert_type(f, jnp.int64 if f.dtype == jnp.float64 else jnp.int32).astype(INT)
        if arr.shape[0] == 0:
            return arr, 1
        uniq = jnp.unique(arr)
        return jnp.searchsorted(uniq, arr).astype(INT), int(uniq.shape[0])
    arr = frame.itensor[:, m.slot]
    if arr.shape[0] == 0:
        return arr, 1
    lo = int(arr.min())
    hi = int(arr.max())
    span = hi - lo + 1
    if span <= max(4 * arr.shape[0], 1 << 20):
        return arr - lo, span
    # sparse domain: densify
    uniq = jnp.unique(arr)
    return jnp.searchsorted(uniq, arr).astype(INT), int(uniq.shape[0])


def composite_key(
    frame: TensorFrame, keys: Sequence[str]
) -> Tuple[jax.Array, bool]:
    """Single int64 composite key per row.

    Returns (key_array, exact) — exact=True when keys pack losslessly.
    This is the transposed single-pass construction of Alg. 2: all key
    columns are gathered first ("transpose"), then combined row-wise.
    """
    cols: List[Tuple[jax.Array, int]] = [key_codes(frame, k) for k in keys]
    prod = 1
    for _, card in cols:
        prod *= max(1, card)
        if prod >= MAX_PACK:
            break
    if prod < MAX_PACK:
        packed = jnp.zeros((frame.nrows,), dtype=INT)
        for codes, card in cols:
            packed = packed * np.int64(max(1, card)) + codes.astype(INT)
        return packed, True
    return hash_combine([c for c, _ in cols]), False


def distinct(packed: jax.Array) -> Tuple[jax.Array, jax.Array, int]:
    """(sorted_uniques, group_ids, n_groups). Host-syncs n_groups."""
    n = int(packed.shape[0])
    if n == 0:
        return packed, packed, 0
    sorted_p = jnp.sort(packed)
    m = int((jnp.diff(sorted_p) != 0).sum()) + 1
    uniques = jnp.unique(packed, size=m)
    gids = jnp.searchsorted(uniques, packed).astype(INT)
    return uniques, gids, m
