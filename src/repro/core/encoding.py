"""Cardinality-aware column encoding (paper §III).

Low-cardinality non-numeric columns are mapped to dense integer ids and
stored inside the frame's int tensor; high-cardinality columns are
offloaded.  Dictionaries are kept **sorted**, so codes are
order-isomorphic to the string order — string range predicates and
sort-by-string reduce to integer comparisons on codes.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def factorize(values: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Map values to dense ids against a sorted unique dictionary.

    Returns (codes int64, dictionary).  The dictionary is sorted, so
    ``dictionary[codes] == values`` and code order == value order.
    """
    values = np.asarray(values)
    dictionary, codes = np.unique(values, return_inverse=True)
    return codes.astype(np.int64).reshape(values.shape), dictionary


def merge_dictionaries(
    da: np.ndarray, db: np.ndarray
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Merge two sorted dictionaries into a shared integer space.

    This is the shared-factorization step of the paper's
    factorize-then-join (Alg. 3 line 5).  Returns
    (merged_dictionary, remap_a, remap_b) where ``remap_x[old_code]``
    gives the code in the merged (sorted) dictionary.

    Columns whose dictionaries were interned through the store's
    process-wide pool (``repro.store.pool``) arrive as the *same
    object*: that degenerates to an identity remap — no concatenate,
    no re-sort (the paper's "dictionary operations" hot spot).
    """
    if da is db:
        identity = np.arange(da.shape[0], dtype=np.int64)
        return da, identity, identity
    merged = np.unique(np.concatenate([da, db]))
    remap_a = np.searchsorted(merged, da).astype(np.int64)
    remap_b = np.searchsorted(merged, db).astype(np.int64)
    return merged, remap_a, remap_b


def shared_codes_numeric(
    a: np.ndarray, b: np.ndarray
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Densify two numeric key columns into one shared code space.

    Joins address a direct table indexed by code, so codes must be dense
    over the *combined* key domain.  Returns (codes_a, codes_b, domain).
    """
    domain_vals = np.unique(np.concatenate([a, b]))
    ca = np.searchsorted(domain_vals, a)
    cb = np.searchsorted(domain_vals, b)
    # searchsorted gives positions even for values absent from the other
    # side; both sides were included in domain_vals so lookups are exact.
    return ca.astype(np.int64), cb.astype(np.int64), int(domain_vals.shape[0])


def is_string_like(arr: np.ndarray) -> bool:
    return arr.dtype.kind in ("U", "S", "O")


def cardinality_ratio(values: np.ndarray) -> float:
    n = max(1, values.shape[0])
    return float(np.unique(values).shape[0]) / float(n)
