"""Data loading (paper §V-b, §VI-G).

Two loaders:

- **Binary columnar ("tfb")**: the paper's custom binary adaptor —
  little-endian packed column files + a JSON manifest, with projection
  pushdown (load only requested columns).  String columns are stored as
  dictionary + codes when encoded, else as a packed utf-8 payload with
  offsets (the Arrow-largestring-style layout the paper wished Mojo
  had).
- **CSV**: the deliberately text-bound baseline.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Sequence

import numpy as np

from .frame import TensorFrame

MAGIC = "tfb-v1"


def write_tfb(path: str, data: Dict[str, np.ndarray]) -> None:
    """Write a dict of host arrays as a binary columnar table."""
    os.makedirs(path, exist_ok=True)
    manifest = {"magic": MAGIC, "columns": []}
    n = None
    for name, arr in data.items():
        arr = np.asarray(arr)
        n = arr.shape[0] if n is None else n
        entry = {"name": name, "n": int(arr.shape[0])}
        base = os.path.join(path, name)
        if np.issubdtype(arr.dtype, np.datetime64):
            days = arr.astype("datetime64[D]").astype(np.int64)
            days.tofile(base + ".i64")
            entry["type"] = "date"
        elif np.issubdtype(arr.dtype, np.integer):
            arr.astype(np.int64).tofile(base + ".i64")
            entry["type"] = "int"
        elif np.issubdtype(arr.dtype, np.floating):
            arr.astype(np.float64).tofile(base + ".f64")
            entry["type"] = "float"
        else:
            payload = "\x00".join(str(s) for s in arr).encode("utf-8")
            offs = np.zeros(arr.shape[0] + 1, dtype=np.int64)
            lengths = np.array([len(str(s).encode("utf-8")) for s in arr], dtype=np.int64)
            offs[1:] = np.cumsum(lengths + 1)
            with open(base + ".str", "wb") as f:
                f.write(payload)
            offs.tofile(base + ".off")
            entry["type"] = "str"
        manifest["columns"].append(entry)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)


def read_tfb_arrays(
    path: str, columns: Optional[Sequence[str]] = None
) -> Dict[str, np.ndarray]:
    """Projection-pushdown read of raw host arrays."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    want = set(columns) if columns is not None else None
    out: Dict[str, np.ndarray] = {}
    order = columns if columns is not None else [c["name"] for c in manifest["columns"]]
    entries = {c["name"]: c for c in manifest["columns"]}
    for name in order:
        if want is not None and name not in want:
            continue
        e = entries[name]
        base = os.path.join(path, name)
        if e["type"] in ("int",):
            out[name] = np.fromfile(base + ".i64", dtype=np.int64)
        elif e["type"] == "date":
            out[name] = np.fromfile(base + ".i64", dtype=np.int64).astype("datetime64[D]")
        elif e["type"] == "float":
            out[name] = np.fromfile(base + ".f64", dtype=np.float64)
        else:
            offs = np.fromfile(base + ".off", dtype=np.int64)
            with open(base + ".str", "rb") as f:
                payload = f.read()
            # byte offsets delimit NUL-separated utf-8 entries
            out[name] = np.array(
                [
                    payload[offs[i]: offs[i + 1] - 1].decode("utf-8")
                    for i in range(len(offs) - 1)
                ],
                dtype=object,
            )
    return out


def read_tfb(
    path: str,
    columns: Optional[Sequence[str]] = None,
    **frame_kwargs,
) -> TensorFrame:
    return TensorFrame.from_arrays(read_tfb_arrays(path, columns), **frame_kwargs)


# ----------------------------------------------------------------------
# CSV baseline
# ----------------------------------------------------------------------
def write_csv(path: str, data: Dict[str, np.ndarray], sep: str = "|") -> None:
    names = list(data.keys())
    cols = [data[n] for n in names]
    n = cols[0].shape[0]
    with open(path, "w") as f:
        f.write(sep.join(names) + "\n")
        for i in range(n):
            f.write(sep.join(str(c[i]) for c in cols) + "\n")


def read_csv_arrays(
    path: str,
    columns: Optional[Sequence[str]] = None,
    sep: str = "|",
    dtypes: Optional[Dict[str, str]] = None,
) -> Dict[str, np.ndarray]:
    """Text-parsing CSV loader (the runtime-parsing baseline)."""
    with open(path) as f:
        header = f.readline().rstrip("\n").split(sep)
        rows = [line.rstrip("\n").split(sep) for line in f]
    idx = {name: i for i, name in enumerate(header)}
    want = list(columns) if columns is not None else header
    out: Dict[str, np.ndarray] = {}
    for name in want:
        j = idx[name]
        raw = [r[j] for r in rows]
        hint = (dtypes or {}).get(name)
        out[name] = _infer_column(raw, hint)
    return out


def _infer_column(raw: List[str], hint: Optional[str]) -> np.ndarray:
    if hint == "int":
        return np.array([int(x) for x in raw], dtype=np.int64)
    if hint == "float":
        return np.array([float(x) for x in raw], dtype=np.float64)
    if hint == "date":
        return np.array(raw, dtype="datetime64[D]")
    if hint == "str":
        return np.array(raw, dtype=object)
    # inference
    try:
        return np.array([int(x) for x in raw], dtype=np.int64)
    except ValueError:
        pass
    try:
        return np.array([float(x) for x in raw], dtype=np.float64)
    except ValueError:
        pass
    try:
        return np.array(raw, dtype="datetime64[D]")
    except ValueError:
        return np.array(raw, dtype=object)


def read_csv(path: str, columns=None, sep: str = "|", dtypes=None, **frame_kwargs) -> TensorFrame:
    return TensorFrame.from_arrays(read_csv_arrays(path, columns, sep, dtypes), **frame_kwargs)
