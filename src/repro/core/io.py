"""Data loading (paper §V-b, §VI-G).

Two loaders:

- **Binary columnar ("tfb")**: v2 (default) is the chunked store
  format (``repro.store.format``) — per-chunk zone maps, per-column
  encodings, lazy loading, scan pushdown via ``read_tfb(...,
  predicates=...)``.  v1 is the original flat layout (little-endian
  packed column files + a JSON manifest with projection pushdown);
  it stays fully readable and writable via ``version=1``.
- **CSV**: the deliberately text-bound baseline.  Explicit ``dtypes``
  hints always win over sniffing; the tokens ``''``/``'None'``/
  ``'NULL'``/``'nan'`` parse as nulls in numeric/date columns (all-null
  columns round-trip as NaN floats / NaT dates).
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Sequence

import numpy as np

from .frame import TensorFrame

MAGIC = "tfb-v1"


def _read_manifest(path: str) -> dict:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)


def write_tfb(
    path: str,
    data: Dict[str, np.ndarray],
    *,
    version: int = 2,
    chunk_rows: Optional[int] = None,
) -> None:
    """Write a dict of host arrays as a binary columnar table.

    ``version=2`` (default) writes the chunked store format with zone
    maps and per-column encodings; ``version=1`` writes the original
    flat layout.
    """
    if version == 2:
        from repro.store import DEFAULT_CHUNK_ROWS, format as storefmt

        storefmt.write_arrays(
            path, data, chunk_rows=chunk_rows or DEFAULT_CHUNK_ROWS
        )
        return
    if version != 1:
        raise ValueError(f"unknown tfb version {version!r}")
    os.makedirs(path, exist_ok=True)
    manifest = {"magic": MAGIC, "columns": []}
    n = None
    for name, arr in data.items():
        arr = np.asarray(arr)
        n = arr.shape[0] if n is None else n
        entry = {"name": name, "n": int(arr.shape[0])}
        base = os.path.join(path, name)
        if np.issubdtype(arr.dtype, np.datetime64):
            days = arr.astype("datetime64[D]").astype(np.int64)
            days.tofile(base + ".i64")
            entry["type"] = "date"
        elif np.issubdtype(arr.dtype, np.integer):
            arr.astype(np.int64).tofile(base + ".i64")
            entry["type"] = "int"
        elif np.issubdtype(arr.dtype, np.floating):
            arr.astype(np.float64).tofile(base + ".f64")
            entry["type"] = "float"
        else:
            payload = "\x00".join(str(s) for s in arr).encode("utf-8")
            offs = np.zeros(arr.shape[0] + 1, dtype=np.int64)
            lengths = np.array([len(str(s).encode("utf-8")) for s in arr], dtype=np.int64)
            offs[1:] = np.cumsum(lengths + 1)
            with open(base + ".str", "wb") as f:
                f.write(payload)
            offs.tofile(base + ".off")
            entry["type"] = "str"
        manifest["columns"].append(entry)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)


def read_tfb_arrays(
    path: str,
    columns: Optional[Sequence[str]] = None,
    manifest: Optional[dict] = None,
) -> Dict[str, np.ndarray]:
    """Projection-pushdown read of raw host arrays (v1 or v2)."""
    if manifest is None:
        manifest = _read_manifest(path)
    if manifest.get("magic") == "tfb-v2":
        from repro.store import format as storefmt

        return storefmt.read_arrays(path, columns, manifest=manifest)
    want = set(columns) if columns is not None else None
    out: Dict[str, np.ndarray] = {}
    order = columns if columns is not None else [c["name"] for c in manifest["columns"]]
    entries = {c["name"]: c for c in manifest["columns"]}
    for name in order:
        if want is not None and name not in want:
            continue
        e = entries[name]
        base = os.path.join(path, name)
        if e["type"] in ("int",):
            out[name] = np.fromfile(base + ".i64", dtype=np.int64)
        elif e["type"] == "date":
            out[name] = np.fromfile(base + ".i64", dtype=np.int64).astype("datetime64[D]")
        elif e["type"] == "float":
            out[name] = np.fromfile(base + ".f64", dtype=np.float64)
        else:
            offs = np.fromfile(base + ".off", dtype=np.int64)
            with open(base + ".str", "rb") as f:
                payload = f.read()
            # byte offsets delimit NUL-separated utf-8 entries
            out[name] = np.array(
                [
                    payload[offs[i]: offs[i + 1] - 1].decode("utf-8")
                    for i in range(len(offs) - 1)
                ],
                dtype=object,
            )
    return out


def read_tfb(
    path: str,
    columns: Optional[Sequence[str]] = None,
    predicates: Sequence = (),
    **frame_kwargs,
) -> TensorFrame:
    """Load a tfb table as a TensorFrame.

    On v2 stores this is a pushdown scan: ``predicates`` (sargable
    ``repro.store.Pred`` conjuncts) skip chunks via zone maps and only
    surviving rows are materialized; dictionaries stay interned.  v1
    tables load flat (predicates are rejected there — v1 has no
    chunk statistics to push into).
    """
    manifest = _read_manifest(path)
    if manifest.get("magic") == "tfb-v2":
        from repro.store import open_store

        return TensorFrame.from_store(
            open_store(path, manifest), columns, predicates, **frame_kwargs
        )
    if predicates:
        raise ValueError("predicate pushdown requires a tfb-v2 store")
    return TensorFrame.from_arrays(
        read_tfb_arrays(path, columns, manifest=manifest), **frame_kwargs
    )


# ----------------------------------------------------------------------
# CSV baseline
# ----------------------------------------------------------------------
def write_csv(path: str, data: Dict[str, np.ndarray], sep: str = "|") -> None:
    names = list(data.keys())
    cols = [data[n] for n in names]
    n = cols[0].shape[0]
    with open(path, "w") as f:
        f.write(sep.join(names) + "\n")
        for i in range(n):
            f.write(sep.join(str(c[i]) for c in cols) + "\n")


def read_csv_arrays(
    path: str,
    columns: Optional[Sequence[str]] = None,
    sep: str = "|",
    dtypes: Optional[Dict[str, str]] = None,
) -> Dict[str, np.ndarray]:
    """Text-parsing CSV loader (the runtime-parsing baseline)."""
    with open(path) as f:
        header = f.readline().rstrip("\n").split(sep)
        rows = [line.rstrip("\n").split(sep) for line in f]
    idx = {name: i for i, name in enumerate(header)}
    want = list(columns) if columns is not None else header
    out: Dict[str, np.ndarray] = {}
    for name in want:
        j = idx[name]
        raw = [r[j] for r in rows]
        hint = (dtypes or {}).get(name)
        out[name] = _infer_column(raw, hint)
    return out


# Tokens parsed as SQL NULL in numeric/date columns ('' is an empty
# field; 'None'/'nan' are what write_csv emits for null object cells
# and NaN floats, so null columns round-trip).
_NULL_TOKENS = frozenset({"", "None", "NULL", "null", "nan", "NaN"})


def _infer_column(raw: List[str], hint: Optional[str]) -> np.ndarray:
    """One CSV column -> numpy array.

    An explicit ``hint`` is authoritative: the column is parsed as that
    type (raising on malformed cells) instead of being sniffed — a
    digits-only string column hinted 'str' stays strings, a float
    column of round numbers hinted 'float' never collapses to int64.
    Unknown hints raise instead of silently falling back to sniffing.
    Null tokens in int columns promote the column to float64 (NaN is
    the engine's null); hint 'str' takes every cell verbatim.
    """
    if hint is not None and hint not in ("int", "float", "date", "str"):
        raise ValueError(
            f"unknown dtype hint {hint!r}; use 'int', 'float', 'date' or 'str'"
        )
    if hint == "str":
        return np.array(raw, dtype=object)
    nulls = [x in _NULL_TOKENS for x in raw]
    any_null = any(nulls)
    if raw and all(nulls) and hint != "date":
        # all-null column: no values to sniff — NaN floats (the
        # engine's null column representation) regardless of int hint
        return np.full(len(raw), np.nan, dtype=np.float64)
    if hint == "int":
        if any_null:
            return np.array(
                [np.nan if m else float(int(x)) for x, m in zip(raw, nulls)],
                dtype=np.float64,
            )
        return np.array([int(x) for x in raw], dtype=np.int64)
    if hint == "float":
        return np.array(
            [np.nan if m else float(x) for x, m in zip(raw, nulls)],
            dtype=np.float64,
        )
    if hint == "date":
        return np.array(
            ["NaT" if m else x for x, m in zip(raw, nulls)],
            dtype="datetime64[D]",
        )
    # inference over the non-null cells only
    vals = [x for x, m in zip(raw, nulls) if not m]
    try:
        ints = [int(x) for x in vals]
        if not any_null:
            return np.array(ints, dtype=np.int64)
        it = iter(ints)
        return np.array(
            [np.nan if m else float(next(it)) for m in nulls], dtype=np.float64
        )
    except ValueError:
        pass
    try:
        floats = [float(x) for x in vals]
        it = iter(floats)
        return np.array(
            [np.nan if m else next(it) for m in nulls], dtype=np.float64
        )
    except ValueError:
        pass
    try:
        return np.array(
            ["NaT" if m else x for x, m in zip(raw, nulls)],
            dtype="datetime64[D]",
        )
    except ValueError:
        # string column: cells verbatim (null tokens could be words)
        return np.array(raw, dtype=object)


def read_csv(path: str, columns=None, sep: str = "|", dtypes=None, **frame_kwargs) -> TensorFrame:
    """CSV -> TensorFrame.

    Null cells (NaN floats / NaT dates out of ``read_csv_arrays``) get
    a hidden validity companion column, so nullable columns round-trip
    with engine null semantics intact (COUNT skips them, SUM treats
    them as zero) instead of degrading to bare NaN values.
    """
    import jax.numpy as jnp

    from .frame import INT, _valid_name

    arrays = read_csv_arrays(path, columns, sep, dtypes)
    f = TensorFrame.from_arrays(arrays, **frame_kwargs)
    for name, arr in arrays.items():
        if arr.dtype.kind == "f":
            invalid = np.isnan(arr)
        elif np.issubdtype(arr.dtype, np.datetime64):
            invalid = np.isnat(arr)
        else:
            continue
        if invalid.any():
            f = f._append_int_column(
                _valid_name(name), jnp.asarray((~invalid).astype(np.int64), dtype=INT), "bool"
            )
    return f
