"""Joins (paper §IV-C, Alg. 3: factorize-then-join), TPU-adapted.

Pipeline:

1. **Shared factorization** of each key pair into one dense integer
   space (dictionary merge for strings, device-side range compression
   for ints — one bounds fetch, no host unique — with a combined-domain
   densify fallback for sparse domains) — Alg. 3 line 5.
2. **Composite packing** of multi-column keys (Horner over shared
   cardinalities, densifying between steps so the packed domain stays
   O(n) — always exact).
3. **Build/probe**: the Mojo hash table becomes a *direct-address
   table* (dense codes are a perfect hash): scatter build positions,
   gather probes — O(1) probes, no collisions, fully vectorized.  The
   build-side uniqueness decision is **stats-driven**: cached
   distinct/uniqueness stats (store zone maps, group-by outputs, prior
   joins) are consulted first, and only an unknown build side pays the
   sort-based test.  Non-unique build keys take sorted-probe
   (searchsorted + CSR expansion via the run-rank formulation shared
   with ``kernels/segment_reduce``).  ``sort_merge_join_rows`` is the
   paper's losing baseline (Fig. 12).
4. **Materialization**: *late* — matched row indices compose into the
   frames' ``RowView`` selection vectors and the two sides' payload
   blocks stack zero-copy, so a join chain gathers payloads once at the
   pipeline exit.  The probe compaction runs on-device (cumsum +
   scatter) behind a single deferred count fetch per join.

Supported: inner, left (outer), semi, anti — left/semi/anti go beyond
the paper (it defers them) but are required by TPC-H Q13/Q4/Q21/Q22.

Null keys never match (SQL semantics): left nulls are coded -1, right
nulls -2, and both build and probe paths reject negatives.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple, Union

import numpy as np
import jax
import jax.numpy as jnp

from repro import obs
from . import encoding
from .config import CONFIG
from .frame import (
    INT,
    ColumnMeta,
    OffloadedColumn,
    TensorFrame,
    ViewBlock,
    _empty_tensor,
    _is_hidden,
    _valid_name,
    float_dtype,
)

_DENSIFY_LIMIT_FACTOR = 4
VALID_PREFIX = "__v__"

#: Observable decision counters (tests / benchmarks): how often the
#: auto algorithm pick was answered by the stats cache vs. paying the
#: sort-based uniqueness test.
STATS = {
    "stats_unique_hits": 0,
    "stats_nonunique_hits": 0,
    "uniqueness_sort_tests": 0,
}


def reset_stats() -> None:
    for k in STATS:
        STATS[k] = 0


obs.metrics.register_group("core.join", lambda: dict(STATS), reset_stats)


def _as_list(x) -> List[str]:
    if x is None:
        return []
    return [x] if isinstance(x, str) else list(x)


# ----------------------------------------------------------------------
# shared factorization (Alg. 3 line 5)
# ----------------------------------------------------------------------
def shared_key_codes(
    left: TensorFrame, right: TensorFrame, lname: str, rname: str
) -> Tuple[jax.Array, jax.Array, int]:
    lm, rm = left.meta(lname), right.meta(rname)
    string_kinds = ("dict", "obj")
    if lm.kind in string_kinds or rm.kind in string_kinds:
        if lm.kind not in string_kinds or rm.kind not in string_kinds:
            raise TypeError(
                f"join key type mismatch: {lname}({lm.kind}) vs {rname}({rm.kind})"
            )
        lc, ld = left.col_codes(lname)
        rc, rd = right.col_codes(rname)
        if ld is rd:
            return lc, rc, int(ld.shape[0])
        merged, ra, rb = encoding.merge_dictionaries(ld, rd)
        return (
            jnp.asarray(ra, dtype=INT)[lc],
            jnp.asarray(rb, dtype=INT)[rc],
            int(merged.shape[0]),
        )
    if lm.kind == "float" or rm.kind == "float":
        raise TypeError("cannot join on float columns")
    la = left.col_values(lname)
    ra = right.col_values(rname)
    nl, nr = int(la.shape[0]), int(ra.shape[0])
    if nl == 0 and nr == 0:
        return la.astype(INT), ra.astype(INT), 1
    # range compression from cached bounds (store zone maps seed them,
    # joins/filters propagate them): after the first touch of a column
    # the join issues NO bounds sync — just the one count fetch
    los, his = [], []
    if nl:
        b = left.int_bounds(lname)
        los.append(b[0])
        his.append(b[1])
    if nr:
        b = right.int_bounds(rname)
        los.append(b[0])
        his.append(b[1])
    lo, hi = min(los), max(his)
    span = hi - lo + 1
    if span <= max(1 << 20, _DENSIFY_LIMIT_FACTOR * (nl + nr)):
        return (la - lo).astype(INT), (ra - lo).astype(INT), span
    # sparse domain fallback: densify over the combined key set
    ca, cb, domain = encoding.shared_codes_numeric(np.asarray(la), np.asarray(ra))
    return jnp.asarray(ca), jnp.asarray(cb), domain


def _densify_pair(lp: jax.Array, rp: jax.Array) -> Tuple[jax.Array, jax.Array, int]:
    uniq = jnp.unique(jnp.concatenate([lp, rp]))
    return (
        jnp.searchsorted(uniq, lp).astype(INT),
        jnp.searchsorted(uniq, rp).astype(INT),
        int(uniq.shape[0]),
    )


def composite_join_codes(
    left: TensorFrame,
    right: TensorFrame,
    left_on: Sequence[str],
    right_on: Sequence[str],
) -> Tuple[jax.Array, jax.Array, int]:
    """Pack multi-column join keys into one shared dense space (exact)."""
    nl, nr = left.nrows, right.nrows
    if len(left_on) == 1:  # single key: no Horner packing round
        lc, rc, card = shared_key_codes(left, right, left_on[0], right_on[0])
        return lc.astype(INT), rc.astype(INT), max(1, int(card))
    limit = max(1 << 20, _DENSIFY_LIMIT_FACTOR * (nl + nr))
    lp = jnp.zeros((nl,), dtype=INT)
    rp = jnp.zeros((nr,), dtype=INT)
    domain = 1
    for lk, rk in zip(left_on, right_on):
        lc, rc, card = shared_key_codes(left, right, lk, rk)
        card = max(1, card)
        if domain * card >= (1 << 62):
            lp, rp, domain = _densify_pair(lp, rp)
        lp = lp * np.int64(card) + lc.astype(INT)
        rp = rp * np.int64(card) + rc.astype(INT)
        domain = domain * card
        if domain > limit:
            lp, rp, domain = _densify_pair(lp, rp)
    return lp, rp, int(domain)


# ----------------------------------------------------------------------
# row-pair computation
# ----------------------------------------------------------------------
#: Inputs at least this long run the direct-address probe as two
#: jit-fused kernels (one per side of the single count sync).  Smaller
#: inputs stay op-by-op — compiling per (shape, domain) would cost more
#: than it saves on the many small unique shapes of a test suite.
_JIT_MIN_ROWS = 1 << 17


def _dar_probe(probe: jax.Array, build: jax.Array, domain: int):
    """Build + probe the direct-address table; everything up to (and
    fused behind) the one deferred match count."""
    nb = build.shape[0]
    # slot `domain` holds null build rows, slot `domain+1` is probed by
    # null probe rows and never written: matched is a single compare
    tbl = jnp.full((domain + 2,), np.int64(-1))
    build_idx = jnp.where(build >= 0, build, np.int64(domain))
    tbl = tbl.at[build_idx].set(jnp.arange(nb, dtype=INT))
    probe_idx = jnp.where(
        probe >= 0,
        jnp.minimum(probe, np.int64(max(0, domain - 1))),
        np.int64(domain + 1),
    )
    pos = tbl[probe_idx]
    matched = pos >= 0
    slots = jnp.cumsum(matched.astype(INT))
    return pos, matched, slots


def _dar_compact(pos, matched, slots, cnt: int):
    """Stream-compact the matched probe rows into (probe_rows,
    build_rows) given the synced count."""
    npr = matched.shape[0]
    dest = jnp.where(matched, slots - 1, np.int64(cnt))
    probe_rows = (
        jnp.zeros((cnt + 1,), dtype=INT)
        .at[dest]
        .set(jnp.arange(npr, dtype=INT))[:cnt]
    )
    return probe_rows, pos[probe_rows]


_dar_probe_jit = jax.jit(_dar_probe, static_argnums=(2,))
_dar_compact_jit = jax.jit(_dar_compact, static_argnums=(3,))


def direct_address_rows(
    probe: jax.Array, build: jax.Array, domain: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Unique-build-key probe via perfect-hash (direct-address) table.

    Returns (matched mask over probe rows, probe_rows, build_rows).
    Negative codes (nulls) on either side never match; null build rows
    scatter into a trash slot that probes cannot reach.  The matched
    rows are compacted on-device (prefix sum + scatter) behind a single
    deferred count fetch — no ``nonzero`` host round-trip — and large
    probes run the whole thing as two fused kernels.
    """
    npr = int(probe.shape[0])
    use_jit = npr >= _JIT_MIN_ROWS
    probe_fn = _dar_probe_jit if use_jit else _dar_probe
    pos, matched, slots = probe_fn(probe, build, domain)
    if npr == 0:
        empty = jnp.zeros((0,), dtype=INT)
        return matched, empty, empty
    cnt = int(slots[-1])  # the one host sync of the probe
    compact_fn = _dar_compact_jit if use_jit else _dar_compact
    probe_rows, build_rows = compact_fn(pos, matched, slots, cnt)
    return matched, probe_rows, build_rows


def sorted_probe_rows(
    probe: jax.Array, build: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Many-to-many probe: sort build side once, binary-search ranges,
    expand via CSR arithmetic.  Returns (counts, probe_rows, build_rows).

    The expansion ranks each output row within its probe's match run
    using the run-boundary formulation shared with the Pallas segmented
    reduction (``kernels.segment_reduce.run_ranks_sorted``), replacing
    two of the three repeats with gathers.
    """
    from repro.kernels.segment_reduce import run_ranks_sorted

    npr = int(probe.shape[0])
    order = jnp.argsort(build)
    sb = build[order]
    starts = jnp.searchsorted(sb, probe, side="left")
    ends = jnp.searchsorted(sb, probe, side="right")
    counts = (ends - starts).astype(INT)
    total = int(counts.sum())  # the one host sync of the probe
    probe_rows = jnp.repeat(
        jnp.arange(npr, dtype=INT), counts, total_repeat_length=total
    )
    within = run_ranks_sorted(probe_rows)
    build_rows = order[starts.astype(INT)[probe_rows] + within]
    return counts, probe_rows, build_rows


def sort_merge_join_rows(
    lcodes: jax.Array, rcodes: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Fig. 12 baseline: sort-merge join (sorts BOTH sides)."""
    lorder = jnp.argsort(lcodes)
    ls = lcodes[lorder]
    _, li_sorted, ri = sorted_probe_rows(ls, rcodes)
    return lorder[li_sorted], ri


def membership(probe: jax.Array, build: jax.Array) -> jax.Array:
    """exists(probe value in build) — for semi/anti joins."""
    if int(build.shape[0]) == 0:
        return jnp.zeros(probe.shape, dtype=bool)
    sb = jnp.sort(build)
    pos = jnp.clip(jnp.searchsorted(sb, probe), 0, sb.shape[0] - 1)
    return (sb[pos] == probe) & (probe >= 0)


def _membership_routed(probe: jax.Array, build: jax.Array) -> jax.Array:
    """Membership with an optional sharded route: the probe side shards
    over a data mesh, the (small) build side broadcasts to every shard
    (repro.dist.dframe.dist_semi_join_mask)."""
    if CONFIG.distributed != "off" and int(build.shape[0]) > 0:
        from repro.dist import dframe

        if dframe.dist_enabled(int(probe.shape[0])):
            return dframe.dist_semi_join_mask(dframe.data_mesh(), probe, build)
    return membership(probe, build)


# ----------------------------------------------------------------------
# frame stitching
# ----------------------------------------------------------------------
def _right_name_map(
    left: TensorFrame, right: TensorFrame, drop_right: Sequence[str], suffix: str
) -> Dict[str, str]:
    """Mapping right-column -> output name (suffix on collision)."""
    out: Dict[str, str] = {}
    dropset = set(drop_right)
    for name in right.column_names:
        if name in dropset:
            continue
        out[name] = name + suffix if name in left.columns else name
    return out


def _hstack(
    left: TensorFrame,
    right: TensorFrame,
    name_map: Dict[str, str],
) -> TensorFrame:
    """Horizontal stack of two equal-length frames; right columns are
    renamed per ``name_map`` (absent = dropped).

    Late path: both sides' view blocks stack zero-copy — no payload
    moves; the output is a ``RowView`` frame over every source block.
    """
    n = left.nrows
    assert right.nrows == n, (right.nrows, n)
    if CONFIG.late_materialization:
        lblocks, lmat = left._view_parts()
        rblocks, rmat = right._view_parts()
        ofs = len(lblocks)
        rbase = 0 if lmat is None else int(lmat.shape[0])
        blocks = list(lblocks) + [
            ViewBlock(
                b.itensor,
                b.ftensor,
                None if b.row_id is None else rbase + b.row_id,
            )
            for b in rblocks
        ]
        if lmat is None:
            rowmat = rmat
        elif rmat is None:
            rowmat = lmat
        else:
            rowmat = jnp.concatenate([lmat, rmat], axis=0)
        cols: Dict[str, ColumnMeta] = dict(left.columns)
        off: Dict[str, OffloadedColumn] = dict(left.offloaded)
        for name, m in right.columns.items():
            if _is_hidden(name):
                base = name[len(VALID_PREFIX):]
                if base not in name_map:
                    continue
                new = _valid_name(name_map[base])
            else:
                if name not in name_map:
                    continue
                new = name_map[name]
            if m.kind == "obj":
                off[new] = right.offloaded[name]
                cols[new] = ColumnMeta(new, "obj", -1)
            else:
                cols[new] = dataclasses.replace(m, name=new, block=ofs + m.block)
        out = TensorFrame._from_view(cols, off, n, blocks, rowmat)
        # value bounds survive the gather (rows repeat, never widen)
        left._inherit_stats(out, "bounds")
        right._inherit_stats(out, "bounds", mapping=name_map)
        return out
    it = (
        jnp.concatenate([left.itensor, right.itensor], axis=1)
        if right.itensor.shape[1]
        else left.itensor
    )
    ft = (
        jnp.concatenate([left.ftensor, right.ftensor], axis=1)
        if right.ftensor.shape[1]
        else left.ftensor
    )
    iofs, fofs = left.itensor.shape[1], left.ftensor.shape[1]
    cols = dict(left.columns)
    off = dict(left.offloaded)
    for name, m in right.columns.items():
        if _is_hidden(name):
            base = name[len(VALID_PREFIX):]
            if base not in name_map:
                continue
            new = _valid_name(name_map[base])
        else:
            if name not in name_map:
                continue
            new = name_map[name]
        if m.kind == "obj":
            off[new] = right.offloaded[name]
            cols[new] = ColumnMeta(new, "obj", -1)
        elif m.kind == "float":
            cols[new] = ColumnMeta(new, "float", fofs + m.slot, None)
        else:
            cols[new] = ColumnMeta(new, m.kind, iofs + m.slot, m.dictionary)
    return TensorFrame(it, ft, cols, off, n)


def _vconcat_same_schema(a: TensorFrame, b: TensorFrame) -> TensorFrame:
    """Vertical concat of two frames with identical column dicts.

    Pipeline exit: both sides materialize (one fused gather per base
    tensor each) and concatenate tensor-to-tensor; mismatched slot
    layouts fall back to per-column stitching.
    """
    assert list(a.columns.keys()) == list(b.columns.keys())
    a.materialize()
    b.materialize()
    same_layout = all(
        (m.kind, m.slot) == (b.columns[name].kind, b.columns[name].slot)
        for name, m in a.columns.items()
    )
    if same_layout:
        it = jnp.concatenate([a.itensor, b.itensor], axis=0)
        ft = jnp.concatenate([a.ftensor, b.ftensor], axis=0)
        off: Dict[str, OffloadedColumn] = {}
        for name, oa in a.offloaded.items():
            ob = b.offloaded[name]
            assert oa.values is ob.values, "vconcat requires shared physical storage"
            off[name] = OffloadedColumn(
                oa.values, jnp.concatenate([oa.idx, ob.idx]), oa._cache
            )
        return TensorFrame(it, ft, dict(a.columns), off, a.nrows + b.nrows)
    n = a.nrows + b.nrows
    cols: Dict[str, ColumnMeta] = {}
    off = {}
    icols: List[jax.Array] = []
    fcols: List[jax.Array] = []
    for name, ma in a.columns.items():
        mb = b.columns[name]
        if ma.kind == "obj":
            oa, ob = a.offloaded[name], b.offloaded[name]
            assert oa.values is ob.values, "vconcat requires shared physical storage"
            off[name] = OffloadedColumn(
                oa.values, jnp.concatenate([oa.idx, ob.idx]), oa._cache
            )
            cols[name] = ColumnMeta(name, "obj", -1)
        elif ma.kind == "float":
            cols[name] = ColumnMeta(name, "float", len(fcols))
            fcols.append(jnp.concatenate([a._raw_values(ma), b._raw_values(mb)]))
        else:
            cols[name] = ColumnMeta(name, ma.kind, len(icols), ma.dictionary)
            icols.append(jnp.concatenate([a._raw_values(ma), b._raw_values(mb)]))
    it = jnp.stack(icols, axis=1) if icols else _empty_tensor(n, INT)
    ft = jnp.stack(fcols, axis=1) if fcols else _empty_tensor(n, float_dtype())
    return TensorFrame(it, ft, cols, off, n)


def _null_right_rows(right: TensorFrame, n: int) -> TensorFrame:
    """A frame with right's schema, n rows, all values null-ish.

    Existing validity columns land at 0 automatically (itensor zeros);
    offloaded indexers point at physical row 0 and are masked by
    validity downstream.  When ``right`` is a view the null frame
    mirrors its block structure so the metas stay valid.
    """
    off = {
        name: OffloadedColumn(oc.values, jnp.zeros((n,), dtype=INT), oc._cache)
        for name, oc in right.offloaded.items()
    }
    if right._view is not None:
        blocks = [
            ViewBlock(
                jnp.zeros((n, b.itensor.shape[1]), dtype=INT),
                jnp.full((n, b.ftensor.shape[1]), np.nan, dtype=b.ftensor.dtype),
                None,
            )
            for b in right._view.blocks
        ]
        return TensorFrame._from_view(dict(right.columns), off, n, blocks, None)
    it = jnp.zeros((n, right.itensor.shape[1]), dtype=INT)
    ft = jnp.full((n, right.ftensor.shape[1]), np.nan, dtype=right.ftensor.dtype)
    return TensorFrame(it, ft, dict(right.columns), off, n)


# ----------------------------------------------------------------------
# public join
# ----------------------------------------------------------------------
def join(
    left: TensorFrame,
    right: TensorFrame,
    on: Union[str, Sequence[str], None] = None,
    left_on: Union[str, Sequence[str], None] = None,
    right_on: Union[str, Sequence[str], None] = None,
    how: str = "inner",
    suffix: str = "_r",
    algorithm: str = "auto",  # 'auto' | 'direct' | 'sorted' | 'sortmerge'
) -> TensorFrame:
    if on is not None:
        left_on = right_on = _as_list(on)
    else:
        left_on, right_on = _as_list(left_on), _as_list(right_on)
    if not left_on or len(left_on) != len(right_on):
        raise ValueError("join requires matching key lists")
    lcodes, rcodes, domain = composite_join_codes(left, right, left_on, right_on)

    # null keys never match: -1 on the left, -2 on the right
    null_keys = False
    for lk in left_on:
        v = left.valid_array(lk)
        if v is not None:
            lcodes = jnp.where(v, lcodes, np.int64(-1))
    for rk in right_on:
        v = right.valid_array(rk)
        if v is not None:
            null_keys = True
            rcodes = jnp.where(v, rcodes, np.int64(-2))

    if how in ("semi", "anti"):
        with obs.span(
            "core.join",
            how=how,
            algorithm="membership",
            probe_rows=left.nrows,
            build_rows=right.nrows,
        ):
            exists = _membership_routed(lcodes, rcodes)
        return left.mask_rows(exists if how == "semi" else ~exists)
    if how not in ("inner", "left"):
        raise ValueError(f"unsupported join type {how!r}")

    drop_right = [rk for lk, rk in zip(left_on, right_on) if lk == rk]
    name_map = _right_name_map(left, right, drop_right, suffix)

    nb = right.nrows
    matched_counts = None
    if algorithm == "sortmerge":
        with obs.span(
            "core.join",
            how=how,
            algorithm="sort_merge",
            probe_rows=left.nrows,
            build_rows=nb,
        ):
            lrows, rrows = sort_merge_join_rows(lcodes, rcodes)
    else:
        unique_build = False
        if algorithm in ("auto", "direct") and nb > 0:
            hint = right.unique_hint(right_on)
            if hint is not None:
                unique_build = bool(hint)
                STATS[
                    "stats_unique_hits" if hint else "stats_nonunique_hits"
                ] += 1
            else:
                # unknown build side: pay the sort-based test once and
                # cache the verdict on the frame (exact distinct count
                # of the key combination — unless null keys collapsed
                # codes, which would under-count)
                STATS["uniqueness_sort_tests"] += 1
                m_build = int((jnp.diff(jnp.sort(rcodes)) != 0).sum()) + 1
                unique_build = m_build == nb
                if not null_keys:
                    right.set_stats(
                        list(right_on), unique=unique_build, distinct=m_build
                    )
        algo = (
            "direct_address"
            if unique_build and algorithm != "sorted"
            else "sorted_probe"
        )
        with obs.span(
            "core.join",
            how=how,
            algorithm=algo,
            probe_rows=left.nrows,
            build_rows=nb,
        ):
            if algo == "direct_address":
                matched, lrows, rrows = direct_address_rows(
                    lcodes, rcodes, domain
                )
                matched_counts = matched.astype(INT)
            else:
                counts, lrows, rrows = sorted_probe_rows(lcodes, rcodes)
                matched_counts = counts

    inner = _hstack(left.take(lrows), right.take(rrows), name_map)
    if how == "inner":
        return inner

    # ---- left outer ----
    if matched_counts is None:  # sortmerge path
        matched_counts = jnp.zeros((left.nrows,), dtype=INT).at[lrows].add(1)
    unmatched = matched_counts == 0
    n_un = int(unmatched.sum())
    outer_part = _hstack(left.mask_rows(unmatched), _null_right_rows(right, n_un), name_map)

    # every right output column must carry validity in both parts;
    # columns that already had a __v__ flow it through (zeros in the
    # null part); the rest get it appended here, in identical order
    need_valid = [
        out_name
        for rname, out_name in name_map.items()
        if not right.has_nulls(rname)
    ]
    for out_name in need_valid:
        inner = inner._append_int_column(
            _valid_name(out_name), jnp.ones((inner.nrows,), dtype=INT), "bool"
        )
        outer_part = outer_part._append_int_column(
            _valid_name(out_name), jnp.zeros((outer_part.nrows,), dtype=INT), "bool"
        )
    return _vconcat_same_schema(inner, outer_part)
