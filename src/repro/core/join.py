"""Joins (paper §IV-C, Alg. 3: factorize-then-join), TPU-adapted.

Pipeline:

1. **Shared factorization** of each key pair into one dense integer
   space (dictionary merge for strings, combined-domain densify for
   ints) — Alg. 3 line 5.
2. **Composite packing** of multi-column keys (Horner over shared
   cardinalities, densifying between steps so the packed domain stays
   O(n) — always exact).
3. **Build/probe**: the Mojo hash table becomes a *direct-address
   table* (dense codes are a perfect hash): scatter build positions,
   gather probes — O(1) probes, no collisions, fully vectorized.
   Non-unique build keys fall back to sorted-probe (searchsorted + CSR
   expansion).  ``sort_merge_join_rows`` is the paper's losing baseline
   (Fig. 12).
4. **Materialization**: parallel row gathers on both sides (Alg. 3
   line 8), then a zero-copy horizontal stack of the two frames'
   tensors.

Supported: inner, left (outer), semi, anti — left/semi/anti go beyond
the paper (it defers them) but are required by TPC-H Q13/Q4/Q21/Q22.

Null keys never match (SQL semantics): left nulls are coded -1, right
nulls -2, and both build and probe paths reject negatives.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple, Union

import numpy as np
import jax
import jax.numpy as jnp

from . import encoding
from .frame import (
    INT,
    ColumnMeta,
    OffloadedColumn,
    TensorFrame,
    _is_hidden,
    _valid_name,
)

_DENSIFY_LIMIT_FACTOR = 4
VALID_PREFIX = "__v__"


def _as_list(x) -> List[str]:
    if x is None:
        return []
    return [x] if isinstance(x, str) else list(x)


# ----------------------------------------------------------------------
# shared factorization (Alg. 3 line 5)
# ----------------------------------------------------------------------
def shared_key_codes(
    left: TensorFrame, right: TensorFrame, lname: str, rname: str
) -> Tuple[jax.Array, jax.Array, int]:
    lm, rm = left.meta(lname), right.meta(rname)
    string_kinds = ("dict", "obj")
    if lm.kind in string_kinds or rm.kind in string_kinds:
        if lm.kind not in string_kinds or rm.kind not in string_kinds:
            raise TypeError(
                f"join key type mismatch: {lname}({lm.kind}) vs {rname}({rm.kind})"
            )
        lc, ld = left.col_codes(lname)
        rc, rd = right.col_codes(rname)
        if ld is rd:
            return lc, rc, int(ld.shape[0])
        merged, ra, rb = encoding.merge_dictionaries(ld, rd)
        return (
            jnp.asarray(ra, dtype=INT)[lc],
            jnp.asarray(rb, dtype=INT)[rc],
            int(merged.shape[0]),
        )
    if lm.kind == "float" or rm.kind == "float":
        raise TypeError("cannot join on float columns")
    la = np.asarray(left.itensor[:, lm.slot])
    ra_ = np.asarray(right.itensor[:, rm.slot])
    ca, cb, domain = encoding.shared_codes_numeric(la, ra_)
    return jnp.asarray(ca), jnp.asarray(cb), domain


def _densify_pair(lp: jax.Array, rp: jax.Array) -> Tuple[jax.Array, jax.Array, int]:
    uniq = np.unique(np.concatenate([np.asarray(lp), np.asarray(rp)]))
    u = jnp.asarray(uniq)
    return (
        jnp.searchsorted(u, lp).astype(INT),
        jnp.searchsorted(u, rp).astype(INT),
        int(uniq.shape[0]),
    )


def composite_join_codes(
    left: TensorFrame,
    right: TensorFrame,
    left_on: Sequence[str],
    right_on: Sequence[str],
) -> Tuple[jax.Array, jax.Array, int]:
    """Pack multi-column join keys into one shared dense space (exact)."""
    nl, nr = left.nrows, right.nrows
    limit = max(1 << 20, _DENSIFY_LIMIT_FACTOR * (nl + nr))
    lp = jnp.zeros((nl,), dtype=INT)
    rp = jnp.zeros((nr,), dtype=INT)
    domain = 1
    for lk, rk in zip(left_on, right_on):
        lc, rc, card = shared_key_codes(left, right, lk, rk)
        card = max(1, card)
        if domain * card >= (1 << 62):
            lp, rp, domain = _densify_pair(lp, rp)
        lp = lp * np.int64(card) + lc.astype(INT)
        rp = rp * np.int64(card) + rc.astype(INT)
        domain = domain * card
        if domain > limit:
            lp, rp, domain = _densify_pair(lp, rp)
    return lp, rp, int(domain)


# ----------------------------------------------------------------------
# row-pair computation
# ----------------------------------------------------------------------
def direct_address_rows(
    probe: jax.Array, build: jax.Array, domain: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Unique-build-key probe via perfect-hash (direct-address) table.

    Returns (matched mask over probe rows, probe_rows, build_rows).
    Negative codes (nulls) on either side never match; null build rows
    scatter into a trash slot that probes cannot reach.
    """
    nb = int(build.shape[0])
    tbl = jnp.full((domain + 1,), np.int64(-1))
    build_idx = jnp.where(build >= 0, build, np.int64(domain))
    tbl = tbl.at[build_idx].set(jnp.arange(nb, dtype=INT))
    pos = tbl[jnp.clip(probe, 0, max(0, domain - 1))]
    matched = (pos >= 0) & (probe >= 0)
    cnt = int(matched.sum())
    probe_rows = jnp.nonzero(matched, size=cnt)[0].astype(INT)
    build_rows = pos[probe_rows]
    return matched, probe_rows, build_rows


def sorted_probe_rows(
    probe: jax.Array, build: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Many-to-many probe: sort build side once, binary-search ranges,
    expand via CSR arithmetic.  Returns (counts, probe_rows, build_rows)."""
    npr = int(probe.shape[0])
    order = jnp.argsort(build)
    sb = build[order]
    starts = jnp.searchsorted(sb, probe, side="left")
    ends = jnp.searchsorted(sb, probe, side="right")
    counts = (ends - starts).astype(INT)
    total = int(counts.sum())
    probe_rows = jnp.repeat(
        jnp.arange(npr, dtype=INT), counts, total_repeat_length=total
    )
    offsets = jnp.cumsum(counts) - counts
    within = jnp.arange(total, dtype=INT) - jnp.repeat(
        offsets, counts, total_repeat_length=total
    )
    build_rows = order[
        jnp.repeat(starts.astype(INT), counts, total_repeat_length=total) + within
    ]
    return counts, probe_rows, build_rows


def sort_merge_join_rows(
    lcodes: jax.Array, rcodes: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Fig. 12 baseline: sort-merge join (sorts BOTH sides)."""
    lorder = jnp.argsort(lcodes)
    ls = lcodes[lorder]
    _, li_sorted, ri = sorted_probe_rows(ls, rcodes)
    return lorder[li_sorted], ri


def membership(probe: jax.Array, build: jax.Array) -> jax.Array:
    """exists(probe value in build) — for semi/anti joins."""
    if int(build.shape[0]) == 0:
        return jnp.zeros(probe.shape, dtype=bool)
    sb = jnp.sort(build)
    pos = jnp.clip(jnp.searchsorted(sb, probe), 0, sb.shape[0] - 1)
    return (sb[pos] == probe) & (probe >= 0)


def _membership_routed(probe: jax.Array, build: jax.Array) -> jax.Array:
    """Membership with an optional sharded route: the probe side shards
    over a data mesh, the (small) build side broadcasts to every shard
    (repro.dist.dframe.dist_semi_join_mask)."""
    from .config import CONFIG

    if CONFIG.distributed != "off" and int(build.shape[0]) > 0:
        from repro.dist import dframe

        if dframe.dist_enabled(int(probe.shape[0])):
            return dframe.dist_semi_join_mask(dframe.data_mesh(), probe, build)
    return membership(probe, build)


# ----------------------------------------------------------------------
# frame stitching
# ----------------------------------------------------------------------
def _right_name_map(
    left: TensorFrame, right: TensorFrame, drop_right: Sequence[str], suffix: str
) -> Dict[str, str]:
    """Mapping right-column -> output name (suffix on collision)."""
    out: Dict[str, str] = {}
    dropset = set(drop_right)
    for name in right.column_names:
        if name in dropset:
            continue
        out[name] = name + suffix if name in left.columns else name
    return out


def _hstack(
    left: TensorFrame,
    right: TensorFrame,
    name_map: Dict[str, str],
) -> TensorFrame:
    """Horizontal stack of two equal-length frames; right columns are
    renamed per ``name_map`` (absent = dropped)."""
    n = left.nrows
    assert right.nrows == n, (right.nrows, n)
    it = (
        jnp.concatenate([left.itensor, right.itensor], axis=1)
        if right.itensor.shape[1]
        else left.itensor
    )
    ft = (
        jnp.concatenate([left.ftensor, right.ftensor], axis=1)
        if right.ftensor.shape[1]
        else left.ftensor
    )
    iofs, fofs = left.itensor.shape[1], left.ftensor.shape[1]
    cols: Dict[str, ColumnMeta] = dict(left.columns)
    off: Dict[str, OffloadedColumn] = dict(left.offloaded)
    for name, m in right.columns.items():
        if _is_hidden(name):
            base = name[len(VALID_PREFIX):]
            if base not in name_map:
                continue
            new = _valid_name(name_map[base])
        else:
            if name not in name_map:
                continue
            new = name_map[name]
        if m.kind == "obj":
            off[new] = right.offloaded[name]
            cols[new] = ColumnMeta(new, "obj", -1)
        elif m.kind == "float":
            cols[new] = ColumnMeta(new, "float", fofs + m.slot, None)
        else:
            cols[new] = ColumnMeta(new, m.kind, iofs + m.slot, m.dictionary)
    return TensorFrame(it, ft, cols, off, n)


def _vconcat_same_schema(a: TensorFrame, b: TensorFrame) -> TensorFrame:
    assert list(a.columns.keys()) == list(b.columns.keys())
    it = jnp.concatenate([a.itensor, b.itensor], axis=0)
    ft = jnp.concatenate([a.ftensor, b.ftensor], axis=0)
    off: Dict[str, OffloadedColumn] = {}
    for name, oa in a.offloaded.items():
        ob = b.offloaded[name]
        assert oa.values is ob.values, "vconcat requires shared physical storage"
        off[name] = OffloadedColumn(
            oa.values, jnp.concatenate([oa.idx, ob.idx]), oa._cache
        )
    return TensorFrame(it, ft, dict(a.columns), off, a.nrows + b.nrows)


def _null_right_rows(right: TensorFrame, n: int) -> TensorFrame:
    """A frame with right's schema, n rows, all values null-ish.

    Existing validity columns land at 0 automatically (itensor zeros);
    offloaded indexers point at physical row 0 and are masked by
    validity downstream.
    """
    it = jnp.zeros((n, right.itensor.shape[1]), dtype=INT)
    ft = jnp.full((n, right.ftensor.shape[1]), np.nan, dtype=right.ftensor.dtype)
    off = {
        name: OffloadedColumn(oc.values, jnp.zeros((n,), dtype=INT), oc._cache)
        for name, oc in right.offloaded.items()
    }
    return TensorFrame(it, ft, dict(right.columns), off, n)


# ----------------------------------------------------------------------
# public join
# ----------------------------------------------------------------------
def join(
    left: TensorFrame,
    right: TensorFrame,
    on: Union[str, Sequence[str], None] = None,
    left_on: Union[str, Sequence[str], None] = None,
    right_on: Union[str, Sequence[str], None] = None,
    how: str = "inner",
    suffix: str = "_r",
    algorithm: str = "auto",  # 'auto' | 'direct' | 'sorted' | 'sortmerge'
) -> TensorFrame:
    if on is not None:
        left_on = right_on = _as_list(on)
    else:
        left_on, right_on = _as_list(left_on), _as_list(right_on)
    if not left_on or len(left_on) != len(right_on):
        raise ValueError("join requires matching key lists")
    lcodes, rcodes, domain = composite_join_codes(left, right, left_on, right_on)

    # null keys never match: -1 on the left, -2 on the right
    for lk in left_on:
        v = left.valid_array(lk)
        if v is not None:
            lcodes = jnp.where(v, lcodes, np.int64(-1))
    for rk in right_on:
        v = right.valid_array(rk)
        if v is not None:
            rcodes = jnp.where(v, rcodes, np.int64(-2))

    if how in ("semi", "anti"):
        exists = _membership_routed(lcodes, rcodes)
        return left.mask_rows(exists if how == "semi" else ~exists)
    if how not in ("inner", "left"):
        raise ValueError(f"unsupported join type {how!r}")

    drop_right = [rk for lk, rk in zip(left_on, right_on) if lk == rk]
    name_map = _right_name_map(left, right, drop_right, suffix)

    nb = right.nrows
    matched_counts = None
    if algorithm == "sortmerge":
        lrows, rrows = sort_merge_join_rows(lcodes, rcodes)
    else:
        unique_build = False
        if algorithm in ("auto", "direct") and nb > 0:
            m_build = int((jnp.diff(jnp.sort(rcodes)) != 0).sum()) + 1
            unique_build = m_build == nb
        if unique_build and algorithm != "sorted":
            matched, lrows, rrows = direct_address_rows(lcodes, rcodes, domain)
            matched_counts = matched.astype(INT)
        else:
            counts, lrows, rrows = sorted_probe_rows(lcodes, rcodes)
            matched_counts = counts

    inner = _hstack(left.take(lrows), right.take(rrows), name_map)
    if how == "inner":
        return inner

    # ---- left outer ----
    if matched_counts is None:  # sortmerge path
        matched_counts = jnp.zeros((left.nrows,), dtype=INT).at[lrows].add(1)
    unmatched = matched_counts == 0
    n_un = int(unmatched.sum())
    outer_part = _hstack(left.mask_rows(unmatched), _null_right_rows(right, n_un), name_map)

    # every right output column must carry validity in both parts;
    # columns that already had a __v__ flow it through (zeros in the
    # null part); the rest get it appended here, in identical order
    need_valid = [
        out_name
        for rname, out_name in name_map.items()
        if not right.has_nulls(rname)
    ]
    for out_name in need_valid:
        inner = inner._append_int_column(
            _valid_name(out_name), jnp.ones((inner.nrows,), dtype=INT), "bool"
        )
        outer_part = outer_part._append_int_column(
            _valid_name(out_name), jnp.zeros((outer_part.nrows,), dtype=INT), "bool"
        )
    return _vconcat_same_schema(inner, outer_part)
