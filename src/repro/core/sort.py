"""Multi-key sort.

Dictionary codes are order-isomorphic to string order (sorted
dictionaries — encoding.py), so sorting by a string column is an
integer sort on its codes: cardinality-awareness pays again.
"""
from __future__ import annotations

from typing import Sequence, Union

import jax
import jax.numpy as jnp

from .frame import INT, TensorFrame


def _sort_key(frame: TensorFrame, name: str) -> jax.Array:
    # view-aware: gathers only the sort-key column from a lazy frame
    return frame.col_values(name)


def sort_values(
    frame: TensorFrame,
    by: Union[str, Sequence[str]],
    ascending: Union[bool, Sequence[bool]] = True,
    stable: bool = True,
) -> TensorFrame:
    """Multi-key sort; ``stable`` (default) breaks ties by original row
    position, so equal-key rows keep their input order.  That makes
    ``head``/``LIMIT`` after a sort deterministic and matches any
    stable reference implementation (e.g. Python's ``sorted``) —
    descending keys are negated, which preserves tie order, unlike a
    post-hoc reversal."""
    by = [by] if isinstance(by, str) else list(by)
    if isinstance(ascending, bool):
        ascending = [ascending] * len(by)
    if len(ascending) != len(by):
        raise ValueError("ascending must match by")
    keys = []
    for name, asc in zip(by, ascending):
        k = _sort_key(frame, name)
        if not asc:
            k = -k
        keys.append(k)
    # lexsort: last key is primary -> reverse our by-list; the stable
    # tiebreak (original row index) goes first = least significant
    keys = list(reversed(keys))
    if stable:
        keys.insert(0, jnp.arange(frame.nrows, dtype=INT))
    order = jnp.lexsort(tuple(keys)).astype(INT)
    return frame.take(order, stats="permutation")
