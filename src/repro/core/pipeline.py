"""Morsel-driven out-of-core execution over the store's chunk grid.

Instead of materializing a whole store scan into device tensors, the
pipeline streams it chunk by chunk:

- ``ChunkScan`` iterates the zone-map survivors of a planned store scan
  as small per-chunk ``TensorFrame`` s.  A prefetch thread
  (``CONFIG.ooc_prefetch`` deep) decodes chunk ``k+1`` host-side while
  the device processes chunk ``k`` — decode and compute overlap.  Each
  chunk frame is seeded with its own zone-map bounds (``ColStats``), so
  chunk-level pruning stays available *downstream* of filters and
  joins.
- ``HashBuild`` is the build-once/probe-per-chunk join side: the build
  frame's key is coded once (dictionary identity for interned store
  dictionaries, range compression from the build's own bounds for
  ints), and every probe chunk reuses it — a direct-address table for
  provably-unique inner builds, sorted membership codes for semi/anti.
  Probe chunks whose key bounds miss the build's range are skipped
  outright (inner/semi) or passed through unprobed (anti).
- ``StreamAgg`` accumulates per-chunk partial aggregates (mean
  decomposes into sum+count) and re-aggregates the partials every
  ``CONFIG.ooc_merge_every`` chunks.  Partial blocks live under the
  spill manager (``repro.store.spill``), so a run under
  ``CONFIG.memory_budget_bytes`` keeps its working set bounded: cold
  partials go to ``.tfb`` chunk files and re-hydrate transparently.

``STATS`` makes the whole thing observable: chunks streamed/pruned,
rows streamed, pipeline and fallback counts, plus the spill manager's
bytes spilled/re-read, evictions and peak tracked bytes.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax.numpy as jnp

from repro.resilience import checkpoint

from .config import CONFIG
from .frame import INT, TensorFrame
from .join import _hstack, _right_name_map

#: Observable pipeline counters.  The spill keys mirror
#: ``repro.store.spill.SPILL.counters`` (synced after every pipeline).
STATS = {
    "pipelines": 0,
    "chunks_streamed": 0,
    "chunks_pruned": 0,
    "rows_streamed": 0,
    "partial_merges": 0,
    "generic_probes": 0,
    "fallbacks": 0,
    "bytes_spilled": 0,
    "bytes_reread": 0,
    "evictions": 0,
    "peak_tracked_bytes": 0,
}


def reset_stats() -> None:
    from repro.store.spill import SPILL

    for k in STATS:
        STATS[k] = 0
    SPILL.reset_counters()


def sync_spill_stats() -> None:
    from repro.store.spill import SPILL

    STATS.update(SPILL.counters)


def _stats_snapshot() -> Dict:
    sync_spill_stats()
    return dict(STATS)


from repro import obs as _obs  # noqa: E402

_obs.metrics.register_group("core.pipeline", _stats_snapshot, reset_stats)


_INT_DOMAIN = ("int", "date", "bool")


# ----------------------------------------------------------------------
# chunk-pipelined store scan
# ----------------------------------------------------------------------
class ChunkScan:
    """Iterate a predicated store scan as per-chunk TensorFrames.

    Chunk decode (numpy: rle expansion, row masks, validity) runs on a
    prefetch thread up to ``CONFIG.ooc_prefetch`` chunks ahead; the
    consuming thread only does the host->device transfer and compute.
    ``prefetch=0`` degrades to fully synchronous iteration.
    """

    def __init__(self, table, columns, predicates):
        from repro import store as _store

        self.table = table
        self.proj, self.phys_preds, self.survivors = _store.plan_scan(
            table, columns, predicates
        )
        STATS["chunks_pruned"] += table.n_chunks - len(self.survivors)

    def __len__(self) -> int:
        return len(self.survivors)

    def _results(self):
        from repro import store as _store

        depth = max(0, int(CONFIG.ooc_prefetch))
        if depth == 0 or len(self.survivors) <= 1:
            for i in self.survivors:
                with _obs.detailed_span(
                    "pipeline.chunk_decode", chunk=int(i)
                ):
                    res = _store.scan_chunk(
                        self.table, self.proj, self.phys_preds, int(i)
                    )
                yield int(i), res
            return
        q: "queue.Queue" = queue.Queue(maxsize=depth)
        DONE = object()
        stop = threading.Event()

        def put(item) -> bool:
            # never block forever: an abandoned consumer (exception in
            # the pipeline body closes this generator early) sets
            # ``stop`` and the producer bails out instead of deadlocking
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.05)
                    return True
                except queue.Full:
                    continue
            return False

        def producer():
            try:
                for i in self.survivors:
                    if stop.is_set():
                        return
                    with _obs.detailed_span(
                        "pipeline.chunk_decode", chunk=int(i)
                    ):
                        res = _store.scan_chunk(
                            self.table, self.proj, self.phys_preds, int(i)
                        )
                    if not put((int(i), res)):
                        return
            except BaseException as e:  # re-raised on the consumer side
                put(e)
            finally:
                put(DONE)

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                with _obs.detailed_span("pipeline.prefetch_wait"):
                    item = q.get()
                if item is DONE:
                    break
                if isinstance(item, BaseException):
                    raise item
                yield item
        finally:
            stop.set()
            while True:  # unblock a producer stuck on a full queue
                try:
                    q.get_nowait()
                except queue.Empty:
                    break
            t.join()

    def __iter__(self):
        for _, f in self.iter_indexed():
            yield f

    def iter_indexed(self):
        """Yield ``(chunk_index, frame)`` — the index lets callers build
        recompute closures that re-scan exactly this chunk."""
        for i, res in self._results():
            # deadline/cancel checkpoint: a streamed pipeline can abort
            # between chunks even when a single chunk's compute can't
            checkpoint("pipeline.chunk")
            STATS["chunks_streamed"] += 1
            STATS["rows_streamed"] += res.nrows
            f = TensorFrame.from_store(self.table, self.proj, [], result=res)
            # tighten the table-wide bounds from_store seeded down to
            # THIS chunk's zone map — downstream joins prune on them
            for name in self.proj:
                col = self.table.columns[name]
                if col.ctype in _INT_DOMAIN and col.encoding != "dict":
                    st = col.chunks[i].stats
                    if res.nrows and st.vmin is not None:
                        f.set_stats(
                            name, vmin=int(st.vmin), vmax=int(st.vmax)
                        )
            yield int(i), f


# ----------------------------------------------------------------------
# build-once / probe-per-chunk joins
# ----------------------------------------------------------------------
class HashBuild:
    """One join's build side, prepared once and probed per chunk.

    Single-column keys get a build-once fast path: int/date keys are
    range-compressed against the build's own bounds (out-of-range probe
    values can never match), interned dictionary keys share codes by
    identity.  Provably-unique inner builds scatter one direct-address
    table; semi/anti sort the build codes once.  Everything else —
    multi-column keys, non-unique inner builds, left outer, foreign
    dictionaries — probes through the engine's general join per chunk,
    which is still streaming-safe (each probe row is independent).
    """

    def __init__(
        self,
        probe_keys: Sequence[str],
        build: TensorFrame,
        build_keys: Sequence[str],
        how: str,
        suffix: str = "_r",
    ):
        self.probe_keys = list(probe_keys)
        self.build_keys = list(build_keys)
        self.build = build.materialize()
        self.how = how
        self.suffix = suffix
        self._fast = None  # (mode, kind, lo, hi, payload, domain)
        if len(self.build_keys) == 1 and how in ("inner", "semi", "anti"):
            self._prepare_fast()

    def _build_codes(self):
        """(codes, kind, lo, hi, domain) for the single build key, or
        None when the key shape has no build-once form."""
        bk = self.build_keys[0]
        m = self.build.meta(bk)
        if m.kind in _INT_DOMAIN:
            if self.build.nrows == 0:
                return None
            lo, hi = self.build.int_bounds(bk)
            codes = (self.build.col_values(bk) - np.int64(lo)).astype(INT)
            domain = hi - lo + 1
            kind = "intrange"
        elif m.kind == "dict":
            codes = self.build.col_values(bk).astype(INT)
            lo, hi = 0, max(0, int(m.dictionary.shape[0]) - 1)
            domain = int(m.dictionary.shape[0])
            kind = "dict"
        else:
            return None
        valid = self.build.valid_array(bk)
        if valid is not None:
            codes = jnp.where(valid, codes, np.int64(-1))
        return codes, kind, lo, hi, domain

    def _prepare_fast(self) -> None:
        got = self._build_codes()
        if got is None:
            return
        codes, kind, lo, hi, domain = got
        if self.how in ("semi", "anti"):
            self._fast = ("member", kind, lo, hi, jnp.sort(codes), domain)
            return
        # inner: need a provably-unique build side for direct addressing
        nb = self.build.nrows
        if domain > max(1 << 22, 16 * max(1, nb)):
            return  # direct-address table would dwarf the build side
        hint = self.build.unique_hint(self.build_keys)
        if hint is None:
            # pay the sort test ONCE at build time (not per chunk)
            m_build = int((jnp.diff(jnp.sort(codes)) != 0).sum()) + 1
            hint = m_build == nb
            self.build.set_stats(
                self.build_keys[0], unique=bool(hint), distinct=m_build
            )
        if not hint:
            return
        tbl = jnp.full((domain + 2,), np.int64(-1))
        idx = jnp.where(codes >= 0, codes, np.int64(domain))
        tbl = tbl.at[idx].set(jnp.arange(nb, dtype=INT))
        self._fast = ("dar", kind, lo, hi, tbl, domain)

    # -- chunk-level pruning -------------------------------------------
    def disjoint(self, f: TensorFrame) -> bool:
        """Can the chunk's key bounds prove zero matches?  (Callers may
        then skip the chunk for inner/semi, or pass it through unprobed
        for anti — never drop rows on an anti join.)"""
        if self._fast is None or self._fast[1] != "intrange":
            return False
        st = f.col_stats(self.probe_keys[0])
        if st is None or st.vmin is None:
            return False
        _, _, lo, hi, _, _ = self._fast
        return st.vmax < lo or st.vmin > hi

    def _probe_codes(self, f: TensorFrame) -> Optional[jnp.ndarray]:
        pk = self.probe_keys[0]
        m = f.meta(pk)
        _, kind, lo, hi, _, _ = self._fast
        if kind == "intrange":
            if m.kind not in _INT_DOMAIN:
                return None
            pv = f.col_values(pk)
            codes = jnp.where(
                (pv < lo) | (pv > hi), np.int64(-1), pv - np.int64(lo)
            ).astype(INT)
        else:  # dict: codes are shared only by dictionary identity
            if m.kind != "dict" or m.dictionary is not self.build.meta(
                self.build_keys[0]
            ).dictionary:
                return None
            codes = f.col_values(pk).astype(INT)
        valid = f.valid_array(pk)
        if valid is not None:
            codes = jnp.where(valid, codes, np.int64(-1))
        return codes

    # -- the probe ------------------------------------------------------
    def apply(self, f: TensorFrame) -> TensorFrame:
        with _obs.detailed_span(
            "pipeline.probe_chunk", rows=f.nrows, how=self.how
        ):
            return self._apply(f)

    def _apply(self, f: TensorFrame) -> TensorFrame:
        if self._fast is not None:
            codes = self._probe_codes(f)
            if codes is not None:
                mode, _, _, _, payload, domain = self._fast
                if mode == "member":
                    sb = payload
                    if int(sb.shape[0]) == 0:
                        exists = jnp.zeros(codes.shape, dtype=bool)
                    else:
                        pos = jnp.clip(
                            jnp.searchsorted(sb, codes), 0, sb.shape[0] - 1
                        )
                        exists = (sb[pos] == codes) & (codes >= 0)
                    return f.mask_rows(
                        exists if self.how == "semi" else ~exists
                    )
                # direct-address inner probe against the prebuilt table
                probe_idx = jnp.where(
                    codes >= 0,
                    jnp.minimum(codes, np.int64(max(0, domain - 1))),
                    np.int64(domain + 1),
                )
                pos = payload[probe_idx]
                matched = pos >= 0
                cnt = int(matched.sum())  # the one sync per chunk
                lrows = jnp.nonzero(matched, size=cnt)[0].astype(INT)
                rrows = pos[lrows]
                name_map = _right_name_map(
                    f, self.build, self._drop_right(), self.suffix
                )
                return _hstack(
                    f.take(lrows), self.build.take(rrows), name_map
                )
        STATS["generic_probes"] += 1
        return f.join(
            self.build,
            left_on=self.probe_keys,
            right_on=self.build_keys,
            how=self.how,
            suffix=self.suffix,
        )

    def _drop_right(self) -> List[str]:
        return [
            rk
            for lk, rk in zip(self.probe_keys, self.build_keys)
            if lk == rk
        ]


# ----------------------------------------------------------------------
# streaming group-by aggregation
# ----------------------------------------------------------------------
_PARTIAL_MERGE = {"sum": "sum", "count": "sum", "size": "sum",
                  "min": "min", "max": "max"}

STREAMABLE_AGGS = frozenset(("sum", "count", "size", "min", "max", "mean"))


class StreamAgg:
    """Accumulate per-chunk partial aggregates; merge under the budget.

    ``specs`` are engine agg specs ``(out_name, fn, column)`` with
    ``fn`` in ``STREAMABLE_AGGS``.  Mean decomposes into sum+count
    partials and reassembles at finalize.  Keyed partials are host
    blocks registered with the spill manager — under a tight
    ``CONFIG.memory_budget_bytes`` they spill to ``.tfb`` and re-hydrate
    at each merge; keyless aggregates fold into python scalars.
    """

    def __init__(self, key_names: List[str], specs):
        self.key_names = list(key_names)
        self.partials: List[Tuple[str, str, str]] = []
        self.finals: List[Tuple[str, str, Tuple[str, ...]]] = []
        for idx, (out_name, fn, colname) in enumerate(specs):
            if fn not in STREAMABLE_AGGS:
                raise ValueError(f"cannot stream aggregate {fn!r}")
            if fn == "mean":
                ps, pc = f"__p{idx}s", f"__p{idx}c"
                self.partials.append((ps, "sum", colname))
                self.partials.append((pc, "count", colname))
                self.finals.append((out_name, "mean", (ps, pc)))
            else:
                pn = f"__p{idx}"
                self.partials.append((pn, fn, colname))
                self.finals.append((out_name, fn, (pn,)))
        self._order = self.key_names + [p for p, _, _ in self.partials]
        self._merge_specs = [
            (pn, _PARTIAL_MERGE[fn], pn) for pn, fn, _ in self.partials
        ]
        self._pending: List = []  # Spillable partial blocks
        self._pending_rebuilds: List = []  # parallel recompute closures
        self._merged = None  # Spillable holding the running merge
        self._merged_rebuild = None
        # keyless accumulators
        self._scalars: Dict[str, object] = {}
        self._scalar_rows = 0

    # -- keyed path -----------------------------------------------------
    def _partial_block(self, part: TensorFrame) -> Dict[str, np.ndarray]:
        return {name: part.column(name) for name in self._order}

    def add(self, f: TensorFrame, rebuild=None) -> None:
        """Fold one chunk frame in.  ``rebuild`` (optional, zero-arg)
        re-produces the chunk frame from durable inputs; when given,
        the spilled partial carries a recompute closure, so a corrupt
        spill block repairs itself instead of failing the query."""
        if f.nrows == 0:
            return
        from repro.store.spill import SPILL

        if not self.key_names:
            self._add_scalar(f)
            return
        with _obs.detailed_span("pipeline.partial_agg", rows=f.nrows):
            part = f.groupby(self.key_names).agg(self.partials)
        block_rebuild = None
        if rebuild is not None:
            def block_rebuild(_rb=rebuild):
                p = _rb().groupby(self.key_names).agg(self.partials)
                return self._partial_block(p), {}
        self._pending.append(
            SPILL.register(self._partial_block(part), recompute=block_rebuild)
        )
        self._pending_rebuilds.append(block_rebuild)
        if len(self._pending) >= max(2, int(CONFIG.ooc_merge_every)):
            self._merge()

    def _merge_blocks(self, blocks) -> TensorFrame:
        if len(blocks) == 1:
            cat = blocks[0]
        else:
            cat = {
                name: np.concatenate([b[name] for b in blocks])
                for name in self._order
            }
        mf = TensorFrame.from_arrays(dict(cat))
        return mf.groupby(self.key_names).agg(self._merge_specs)

    def _merge(self) -> None:
        if not self._pending and self._merged is None:
            return
        with _obs.span("pipeline.merge_partials") as sp:
            blocks = []
            handles = list(self._pending)
            rebuilds = list(self._pending_rebuilds)
            if self._merged is not None:
                handles.append(self._merged)
                rebuilds.append(self._merged_rebuild)
            sp.set(partials=len(handles))
            for h in handles:
                data, _ = h.get()
                blocks.append(data)
                h.release()
            merged = self._merge_blocks(blocks)
            merged_rebuild = None
            if rebuilds and all(rb is not None for rb in rebuilds):
                # a merged block rebuilds by recomputing every
                # contributing partial and re-merging
                def merged_rebuild(_rbs=tuple(rebuilds)):
                    parts = [rb()[0] for rb in _rbs]
                    return self._partial_block(self._merge_blocks(parts)), {}
            from repro.store.spill import SPILL

            self._merged = SPILL.register(
                self._partial_block(merged), recompute=merged_rebuild
            )
            self._merged_rebuild = merged_rebuild
            self._pending = []
            self._pending_rebuilds = []
            STATS["partial_merges"] += 1

    # -- keyless path ---------------------------------------------------
    def _add_scalar(self, f: TensorFrame) -> None:
        got = f.agg(self.partials)
        self._scalar_rows += f.nrows
        for pn, fn, _ in self.partials:
            v = got[pn]
            if pn not in self._scalars:
                self._scalars[pn] = v
            elif fn in ("sum", "count", "size"):
                self._scalars[pn] = self._scalars[pn] + v
            elif fn == "min":
                self._scalars[pn] = min(self._scalars[pn], v)
            else:  # max
                self._scalars[pn] = max(self._scalars[pn], v)

    # -- finalize -------------------------------------------------------
    def finalize(self) -> Optional[TensorFrame]:
        from .expr import col

        if not self.key_names:
            if self._scalar_rows == 0:
                return None  # caller falls back to the eager empty path
            out: Dict[str, np.ndarray] = {}
            for out_name, fn, pns in self.finals:
                if fn == "mean":
                    s, c = self._scalars[pns[0]], self._scalars[pns[1]]
                    v = float(s) / c if c else float("nan")
                else:
                    v = self._scalars[pns[0]]
                out[out_name] = np.asarray([v])
            return TensorFrame.from_arrays(out)
        self._merge()
        if self._merged is None:
            return None
        data, _ = self._merged.get()
        self._merged.release()
        self._merged = None
        mf = TensorFrame.from_arrays(dict(data))
        rename: Dict[str, str] = {}
        for out_name, fn, pns in self.finals:
            if fn == "mean":
                mf = mf.with_column(out_name, col(pns[0]) / col(pns[1]))
            else:
                rename[pns[0]] = out_name
        mf = mf.rename(rename)
        return mf.select(
            self.key_names + [out_name for out_name, _, _ in self.finals]
        )
