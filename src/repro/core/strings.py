"""Device-side string operations on packed byte tensors.

MojoFrame's headline result (TPC-H Q13, §VI-E) is a *stateless* string
UDF (``not_string_exists_before``) compiled and parallelized instead of
applied row-by-row.  The TPU adaptation packs a string column into an
``(n, L) uint8`` tensor + lengths and evaluates substring searches as
vectorized sliding-window byte comparisons.  These jnp implementations
are also the oracles for the Pallas kernels in ``repro.kernels``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .config import CONFIG


def pack_strings(
    values: np.ndarray, max_len: Optional[int] = None
) -> Tuple[jax.Array, jax.Array]:
    """Pack a host string array into ((n, L) uint8, (n,) int32 lengths).

    Vectorized via numpy's fixed-width bytes dtype (ASCII fast path);
    non-ASCII data falls back to a per-string loop."""
    n = values.shape[0]
    cap = max_len or CONFIG.max_packed_len
    if n == 0:
        return jnp.zeros((0, 1), jnp.uint8), jnp.zeros((0,), jnp.int32)
    try:
        as_s = np.asarray(values).astype("S")  # null-padded fixed width
        W = as_s.dtype.itemsize or 1
        L = min(cap, W) if max_len is None else cap
        buf = np.frombuffer(as_s.tobytes(), dtype=np.uint8).reshape(n, W)
        lens = np.char.str_len(as_s).astype(np.int32)
        if W < L:
            buf = np.pad(buf, ((0, 0), (0, L - W)))
        else:
            buf = buf[:, :L]
        lens = np.minimum(lens, L)
        return jnp.asarray(np.ascontiguousarray(buf)), jnp.asarray(lens)
    except UnicodeEncodeError:
        pass
    encoded = [str(s).encode("utf-8") for s in values]
    actual = max((len(b) for b in encoded), default=1)
    L = min(cap, max(1, actual)) if max_len is None else cap
    buf = np.zeros((n, L), dtype=np.uint8)
    lens = np.zeros((n,), dtype=np.int32)
    for i, b in enumerate(encoded):
        b = b[:L]
        buf[i, : len(b)] = np.frombuffer(b, dtype=np.uint8)
        lens[i] = len(b)
    return jnp.asarray(buf), jnp.asarray(lens)


_PACK_CACHE: dict = {}


def pack_strings_cached(values: np.ndarray, max_len: Optional[int] = None):
    """Cached packing keyed on the array object (dictionaries are
    stable objects held by their frames)."""
    key = (id(values), max_len)
    hit = _PACK_CACHE.get(key)
    if hit is not None and hit[0] is values:
        return hit[1]
    packed = pack_strings(values, max_len)
    _PACK_CACHE[key] = (values, packed)  # keep a ref so id stays valid
    if len(_PACK_CACHE) > 256:
        _PACK_CACHE.pop(next(iter(_PACK_CACHE)))
    return packed


def _pat_array(pat: str) -> np.ndarray:
    b = pat.encode("utf-8")
    return np.frombuffer(b, dtype=np.uint8)


def find_first(packed: jax.Array, lens: jax.Array, pat: str,
               start: Optional[jax.Array] = None) -> jax.Array:
    """Per-row index of first occurrence of ``pat`` at or after ``start``
    (elementwise), or -1.  Pure-jnp sliding window."""
    p = _pat_array(pat)
    m = int(p.shape[0])
    n, L = packed.shape
    if m == 0:
        return jnp.zeros((n,), dtype=jnp.int32)
    if m > L:
        return jnp.full((n,), -1, dtype=jnp.int32)
    npos = L - m + 1
    # match[i, j] = all(packed[i, j + k] == p[k] for k)
    match = jnp.ones((n, npos), dtype=bool)
    for k in range(m):
        match = match & (packed[:, k : k + npos] == p[k])
    pos = jnp.arange(npos, dtype=jnp.int32)[None, :]
    ok = match & (pos + m <= lens[:, None].astype(jnp.int32))
    if start is not None:
        ok = ok & (pos >= start[:, None].astype(jnp.int32))
    any_match = ok.any(axis=1)
    first = jnp.argmax(ok, axis=1).astype(jnp.int32)
    return jnp.where(any_match, first, jnp.int32(-1))


def contains(packed: jax.Array, lens: jax.Array, pat: str) -> jax.Array:
    return find_first(packed, lens, pat) >= 0


def startswith(packed: jax.Array, lens: jax.Array, pat: str) -> jax.Array:
    p = _pat_array(pat)
    m = int(p.shape[0])
    n, L = packed.shape
    if m == 0:
        return jnp.ones((n,), dtype=bool)
    if m > L:
        return jnp.zeros((n,), dtype=bool)
    ok = lens >= m
    for k in range(m):
        ok = ok & (packed[:, k] == p[k])
    return ok


def endswith(packed: jax.Array, lens: jax.Array, pat: str) -> jax.Array:
    p = _pat_array(pat)
    m = int(p.shape[0])
    n, L = packed.shape
    if m == 0:
        return jnp.ones((n,), dtype=bool)
    start = lens.astype(jnp.int32) - m
    ok = start >= 0
    rows = jnp.arange(n)
    for k in range(m):
        idx = jnp.clip(start + k, 0, L - 1)
        ok = ok & (packed[rows, idx] == p[k])
    return ok


def exists_before(packed: jax.Array, lens: jax.Array, first: str, second: str) -> jax.Array:
    """True where ``first`` occurs and ``second`` occurs after it.

    The paper's ``not_string_exists_before`` (Q13/Q16) is the negation.
    """
    f = find_first(packed, lens, first)
    m = len(first.encode("utf-8"))
    s = find_first(packed, lens, second, start=jnp.where(f >= 0, f + m, 0))
    return (f >= 0) & (s >= 0)


def like(packed: jax.Array, lens: jax.Array, pattern: str) -> jax.Array:
    """SQL LIKE with ``%`` wildcards (the only wildcard in our workloads).

    Translates to anchored/ordered substring search: parts between ``%``
    must occur in order, the first/last parts anchor when the pattern
    does not start/end with ``%``.
    """
    parts = pattern.split("%")
    anchored_start = parts[0] != ""
    anchored_end = parts[-1] != ""
    inner = [p for p in parts if p != ""]
    n = packed.shape[0]
    ok = jnp.ones((n,), dtype=bool)
    pos = jnp.zeros((n,), dtype=jnp.int32)
    for i, part in enumerate(inner):
        m = len(part.encode("utf-8"))
        if i == 0 and anchored_start:
            ok = ok & startswith(packed, lens, part)
            pos = jnp.where(ok, m, pos)
            continue
        f = find_first(packed, lens, part, start=pos)
        ok = ok & (f >= 0)
        pos = jnp.where(f >= 0, f + m, pos)
    if anchored_end and inner:
        last = inner[-1]
        m = len(last.encode("utf-8"))
        if len(inner) == 1 and anchored_start:
            # pattern like 'abc' (no %): exact match
            ok = ok & (lens == m)
        else:
            ends = endswith(packed, lens, last)
            if len(inner) >= 2 or not anchored_start:
                # the last part must also be the trailing match; re-check
                # that an occurrence ends exactly at len
                ok = ok & ends
    return ok
