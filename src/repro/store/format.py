"""``.tfb`` v2: persisted chunked columnar tables, lazily loadable.

Layout (a directory, like v1):

- ``manifest.json`` — ``{"magic": "tfb-v2", "nrows", "chunk_rows",
  "columns": [...]}``; every column entry carries its ctype, encoding,
  dictionary size and the per-chunk descriptors — byte offsets into the
  column's data files plus the zone-map stats (min/max/nulls/distinct).
  The manifest is the only thing ``open_store`` reads: stats live here,
  so scan pruning decides chunk-by-chunk *before* any payload I/O.
- ``<col>.bin`` — the column's chunk payloads, concatenated:
  int64/float64 values (plain), int64 codes (dict), or per chunk the
  run values followed by int64 run lengths (rle).  Plain string chunks
  are NUL-separated utf-8 payloads.
- ``<col>.off`` — for plain string columns: per chunk ``n+1`` int64
  offsets into that chunk's payload.
- ``<col>.dict`` / ``<col>.dictoff`` — dict columns: the sorted
  dictionary, stored once per column (NUL-separated utf-8 + offsets).
  Dictionaries are interned into the process pool at load, so two
  tables (or two loads of one table) with equal dictionaries share one
  array object and merge in O(1).
- ``<col>.valid`` — nullable columns: per-chunk ``np.packbits``
  validity bitmaps (True = present).  Only chunks with nulls write one
  (their manifest entries carry a ``voffset``); files without it read
  as before, so pre-bitmap v2 tables stay compatible and int/date/str
  nulls now round-trip losslessly instead of surviving only as float
  NaN.

``open_store`` returns a ``Table`` whose chunks hold loader callbacks:
payloads hit disk on first access and are cached.  ``read_arrays`` is
the eager v1-compatible read (used by ``core.io.read_tfb_arrays``).
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.resilience import faults, retry

from .pool import intern_dictionary
from .table import (
    Chunk,
    ChunkStats,
    Column,
    DEFAULT_CHUNK_ROWS,
    EncodingPolicy,
    DEFAULT_POLICY,
    Table,
)

MAGIC_V2 = "tfb-v2"

_DTYPES = {"int": np.int64, "date": np.int64, "bool": np.int64, "float": np.float64}


def _payload_dtype(ctype: str, encoding: str):
    if encoding == "dict":
        return np.int64
    return _DTYPES[ctype]


# ----------------------------------------------------------------------
# string payload helpers (shared by dictionaries and plain-str chunks)
# ----------------------------------------------------------------------
def _pack_strings(values) -> tuple:
    payload = "\x00".join(str(s) for s in values).encode("utf-8")
    lengths = np.array(
        [len(str(s).encode("utf-8")) for s in values], dtype=np.int64
    )
    offs = np.zeros(len(lengths) + 1, dtype=np.int64)
    if len(lengths):
        offs[1:] = np.cumsum(lengths + 1)
    return payload, offs


def _unpack_strings(payload: bytes, offs: np.ndarray) -> np.ndarray:
    n = offs.shape[0] - 1
    out = np.empty(n, dtype=object)
    for i in range(n):
        out[i] = payload[offs[i]: offs[i + 1] - 1].decode("utf-8")
    return out


# ----------------------------------------------------------------------
# writer
# ----------------------------------------------------------------------
def write_store(path: str, table: Table) -> None:
    """Persist a chunked table as a ``.tfb`` v2 directory."""
    faults.fault_point("store.write")
    os.makedirs(path, exist_ok=True)
    manifest = {
        "magic": MAGIC_V2,
        "nrows": table.nrows,
        "chunk_rows": table.chunk_rows,
        "columns": [],
    }
    for name, col in table.columns.items():
        base = os.path.join(path, name)
        entry = {
            "name": name,
            "ctype": col.ctype,
            "encoding": col.encoding,
            "chunks": [],
        }
        if col.encoding == "dict":
            payload, offs = _pack_strings(col.dictionary)
            with open(base + ".dict", "wb") as f:
                f.write(payload)
            offs.tofile(base + ".dictoff")
            entry["dict_size"] = int(col.dictionary.shape[0])
        if col.ctype == "str" and col.encoding == "plain":
            _write_plain_str(base, col, entry)
        else:
            _write_binary(base, col, entry)
        if col.has_validity():
            _write_validity(base, col, entry)
        manifest["columns"].append(entry)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)


def _stats_doc(s: ChunkStats) -> dict:
    return {
        "min": s.vmin,
        "max": s.vmax,
        "nulls": int(s.null_count),
        "distinct": int(s.distinct),
    }


def _write_binary(base: str, col: Column, entry: dict) -> None:
    pos = 0
    with open(base + ".bin", "wb") as f:
        for c in col.chunks:
            cent = {"n": c.n, "offset": pos, "stats": _stats_doc(c.stats)}
            if col.encoding == "rle":
                values, runs = c.payload()
                vb = values.astype(_payload_dtype(col.ctype, "plain")).tobytes()
                rb = runs.astype(np.int64).tobytes()
                f.write(vb)
                f.write(rb)
                cent["runs"] = int(runs.shape[0])
                pos += len(vb) + len(rb)
            else:
                b = c.payload().astype(
                    _payload_dtype(col.ctype, col.encoding)
                ).tobytes()
                f.write(b)
                pos += len(b)
            entry["chunks"].append(cent)


def _write_plain_str(base: str, col: Column, entry: dict) -> None:
    bin_pos = 0
    off_pos = 0
    with open(base + ".bin", "wb") as fb, open(base + ".off", "wb") as fo:
        for c in col.chunks:
            payload, offs = _pack_strings(c.payload())
            fb.write(payload)
            fo.write(offs.tobytes())
            entry["chunks"].append(
                {
                    "n": c.n,
                    "offset": bin_pos,
                    "nbytes": len(payload),
                    "off_offset": off_pos,
                    "stats": _stats_doc(c.stats),
                }
            )
            bin_pos += len(payload)
            off_pos += offs.nbytes


def _write_validity(base: str, col: Column, entry: dict) -> None:
    """``<col>.valid``: per-chunk ``np.packbits`` validity bitmaps.

    Chunks without nulls write nothing; their manifest entries carry no
    ``voffset`` and load as all-valid.  Added alongside v2 without a
    magic bump — older readers ignored the unknown key, older files
    simply lack it (nulls then survive only as float NaN, the legacy
    behavior)."""
    pos = 0
    with open(base + ".valid", "wb") as f:
        for c, cent in zip(col.chunks, entry["chunks"]):
            v = c.validity()
            if v is None:
                continue
            packed = np.packbits(np.asarray(v, dtype=bool))
            f.write(packed.tobytes())
            cent["voffset"] = pos
            pos += packed.nbytes


def write_arrays(
    path: str,
    data: Dict[str, np.ndarray],
    *,
    chunk_rows: int = DEFAULT_CHUNK_ROWS,
    policy: EncodingPolicy = DEFAULT_POLICY,
    encode: Optional[Dict[str, str]] = None,
    validity: Optional[Dict[str, np.ndarray]] = None,
) -> Table:
    """Chunk/encode host arrays and persist them; returns the table."""
    table = Table.from_arrays(
        data, chunk_rows=chunk_rows, policy=policy, encode=encode,
        validity=validity,
    )
    write_store(path, table)
    return table


# ----------------------------------------------------------------------
# reader
# ----------------------------------------------------------------------
class _ColumnFile:
    """One column data file, opened lazily and kept open across chunk
    loads (per-chunk ``open()`` dominates small-chunk reads otherwise).
    The handle closes with the object (all chunk loaders of a column
    share one ``_ColumnFile``)."""

    __slots__ = ("path", "_fh")

    def __init__(self, path: str):
        self.path = path
        self._fh = None

    def _read_once(self, offset: int, nbytes: int) -> bytes:
        faults.fault_point("store.read")
        if self._fh is None:
            self._fh = open(self.path, "rb")
        self._fh.seek(offset)
        return self._fh.read(nbytes)

    def read(self, offset: int, nbytes: int) -> bytes:
        try:
            return self._read_once(offset, nbytes)
        except OSError:
            self._fh = None  # handle may be stale; reopen under retry
            return retry.call(
                lambda: self._read_once(offset, nbytes), site="store.read"
            )

    def read_array(self, offset: int, count: int, dtype) -> np.ndarray:
        nbytes = count * np.dtype(dtype).itemsize
        return np.frombuffer(self.read(offset, nbytes), dtype=dtype)


def is_v2(path: str) -> bool:
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            return json.load(f).get("magic") == MAGIC_V2
    except (OSError, ValueError):
        return False


def open_store(path: str, manifest: Optional[dict] = None) -> Table:
    """Open a ``.tfb`` v2 directory lazily (manifest + stats only).

    ``manifest`` may be passed pre-parsed (callers that already read it
    to sniff the magic, e.g. ``core.io``, skip the second JSON parse).
    """
    if manifest is None:
        def _load_manifest():
            faults.fault_point("store.read")
            with open(os.path.join(path, "manifest.json")) as f:
                return json.load(f)

        manifest = retry.call(_load_manifest, site="store.read")
    if manifest.get("magic") != MAGIC_V2:
        raise ValueError(
            f"{path} is not a tfb-v2 store "
            f"(magic={manifest.get('magic')!r}); use core.io.read_tfb "
            f"for v1 tables"
        )
    columns: Dict[str, Column] = {}
    for entry in manifest["columns"]:
        name, ctype, encoding = entry["name"], entry["ctype"], entry["encoding"]
        base = os.path.join(path, name)
        dict_loader = None
        if encoding == "dict":
            dict_loader = _make_dict_loader(base, entry["dict_size"])
        data = _ColumnFile(base + ".bin")
        offf = (
            _ColumnFile(base + ".off")
            if ctype == "str" and encoding == "plain"
            else None
        )
        validf = (
            _ColumnFile(base + ".valid")
            if any("voffset" in c for c in entry["chunks"])
            else None
        )
        chunks: List[Chunk] = []
        for cent in entry["chunks"]:
            stats = ChunkStats(
                cent["stats"]["min"],
                cent["stats"]["max"],
                cent["stats"]["nulls"],
                cent["stats"]["distinct"],
            )
            vloader = None
            if validf is not None and "voffset" in cent:
                vloader = _make_validity_loader(validf, cent)
            chunks.append(
                Chunk(cent["n"], stats, loader=_make_loader(
                    data, offf, ctype, encoding, cent
                ), vloader=vloader)
            )
        columns[name] = Column(
            name,
            ctype,
            encoding,
            chunks,
            dict_loader=dict_loader,
            bulk_loader=_make_bulk_loader(data, offf, ctype, encoding, entry),
        )
    return Table(columns, manifest["nrows"], manifest["chunk_rows"])


def _make_validity_loader(validf: _ColumnFile, cent: dict):
    n = int(cent["n"])
    voffset = int(cent["voffset"])
    nbytes = (n + 7) // 8

    def load_validity():
        packed = np.frombuffer(validf.read(voffset, nbytes), dtype=np.uint8)
        return np.unpackbits(packed, count=n).astype(bool)

    return load_validity


def _make_dict_loader(base: str, size: int):
    def load_dict():
        offs = np.fromfile(base + ".dictoff", dtype=np.int64, count=size + 1)
        with open(base + ".dict", "rb") as f:
            payload = f.read()
        return intern_dictionary(_unpack_strings(payload, offs))

    return load_dict


def _make_bulk_loader(
    data: _ColumnFile, offf: Optional[_ColumnFile],
    ctype: str, encoding: str, entry: dict,
):
    """One sequential read of the whole column file -> every chunk's
    payload (the full-materialization fast path; per-chunk seeks only
    pay off when pruning actually skips)."""
    cents = entry["chunks"]
    if not cents:
        return None
    if ctype == "str" and encoding == "plain":
        def bulk_str():
            last = cents[-1]
            payload = data.read(0, last["offset"] + last["nbytes"])
            n_offs = sum(c["n"] + 1 for c in cents)
            offs_all = offf.read_array(0, n_offs, np.int64)
            out = []
            for c in cents:
                start = c["off_offset"] // 8
                offs = offs_all[start: start + c["n"] + 1]
                out.append(
                    _unpack_strings(
                        payload[c["offset"]: c["offset"] + c["nbytes"]], offs
                    )
                )
            return out

        return bulk_str
    dt = _payload_dtype(ctype, encoding)
    isz = np.dtype(dt).itemsize
    if encoding == "rle":
        def bulk_rle():
            last = cents[-1]
            buf = data.read(0, last["offset"] + last["runs"] * (isz + 8))
            out = []
            for c in cents:
                nr = c["runs"]
                values = np.frombuffer(buf, dt, count=nr, offset=c["offset"])
                runs = np.frombuffer(
                    buf, np.int64, count=nr, offset=c["offset"] + nr * isz
                )
                out.append((values, runs))
            return out

        return bulk_rle

    def bulk_plain():
        last = cents[-1]
        buf = data.read(0, last["offset"] + last["n"] * isz)
        return [
            np.frombuffer(buf, dt, count=c["n"], offset=c["offset"])
            for c in cents
        ]

    return bulk_plain


def _make_loader(
    data: _ColumnFile, offf: Optional[_ColumnFile],
    ctype: str, encoding: str, cent: dict,
):
    if ctype == "str" and encoding == "plain":
        def load_str():
            offs = offf.read_array(cent["off_offset"], cent["n"] + 1, np.int64)
            payload = data.read(cent["offset"], cent["nbytes"])
            return _unpack_strings(payload, offs)

        return load_str
    dt = _payload_dtype(ctype, encoding)
    if encoding == "rle":
        def load_rle():
            nruns = cent["runs"]
            values = data.read_array(cent["offset"], nruns, dt)
            runs = data.read_array(
                cent["offset"] + values.nbytes, nruns, np.int64
            )
            return values, runs

        return load_rle

    return lambda: data.read_array(cent["offset"], cent["n"], dt)


def read_arrays(
    path: str,
    columns: Optional[Sequence[str]] = None,
    manifest: Optional[dict] = None,
) -> Dict[str, np.ndarray]:
    """Eager projection read of a v2 store back to host arrays."""
    table = open_store(path, manifest)
    return table.to_arrays(columns)
