"""Spill-to-disk for host-side intermediates under a memory budget.

The out-of-core layer (``repro.core.pipeline``) holds intermediate
results — per-chunk partial aggregates, buffered probe outputs — as
host array blocks.  ``SpillManager`` tracks their bytes against
``CONFIG.memory_budget_bytes`` and, when the pool overflows, evicts the
least-recently-used blocks to ``.tfb`` v2 chunk files (the store's own
format, so spilled frames keep zone maps, encodings and validity
bitmaps).  Access through ``Spillable.get`` transparently re-hydrates
and re-registers the block as most-recently-used.

Lifecycle: a spill file belongs to its ``Spillable`` — a
``weakref.finalize`` deletes the directory when the handle is garbage
collected, and the per-process spill root (used when
``CONFIG.spill_dir`` is unset) is removed at interpreter exit.

No jax imports: ``repro.store`` stays a host-side layer (CI-enforced).
"""
from __future__ import annotations

import atexit
import itertools
import os
import shutil
import tempfile
import threading
import weakref
from collections import OrderedDict
from typing import Dict, Optional, Tuple

import numpy as np

_IDS = itertools.count()

_PROC_DIR: Optional[str] = None
_PROC_LOCK = threading.Lock()


def _process_spill_root() -> str:
    """Lazily created per-process spill directory, removed atexit."""
    global _PROC_DIR
    with _PROC_LOCK:
        if _PROC_DIR is None:
            _PROC_DIR = tempfile.mkdtemp(prefix="repro-spill-")
            atexit.register(shutil.rmtree, _PROC_DIR, ignore_errors=True)
    return _PROC_DIR


def _nbytes(arr: np.ndarray) -> int:
    if arr.dtype == object:
        return int(sum(len(str(s).encode()) + 8 for s in arr))
    return int(arr.nbytes)


def block_bytes(
    data: Dict[str, np.ndarray], validity: Optional[Dict[str, np.ndarray]]
) -> int:
    total = sum(_nbytes(a) for a in data.values())
    if validity:
        total += sum(_nbytes(a) for a in validity.values())
    return total


def _delete_dir(path: str) -> None:
    shutil.rmtree(path, ignore_errors=True)


class Spillable:
    """One spillable block: a dict of host arrays (+ validity bitmaps).

    In-memory by default; ``spill()`` persists it as a ``.tfb`` v2
    directory and drops the arrays; ``get()`` re-hydrates on demand.
    The spill directory is deleted when the handle is GC'd.
    """

    def __init__(
        self,
        manager: "SpillManager",
        data: Dict[str, np.ndarray],
        validity: Optional[Dict[str, np.ndarray]] = None,
    ):
        self.id = next(_IDS)
        self._manager = manager
        self._data: Optional[Dict[str, np.ndarray]] = dict(data)
        self._validity: Dict[str, np.ndarray] = dict(validity or {})
        self.nbytes = block_bytes(data, validity)
        self._path: Optional[str] = None
        self._finalizer = None

    @property
    def spilled(self) -> bool:
        return self._data is None

    # -- called by the manager (under its lock) ------------------------
    def _spill_path(self) -> str:
        root = self._manager.spill_root()
        return os.path.join(root, f"block-{os.getpid()}-{self.id}.tfb")

    def _do_spill(self) -> int:
        """Write the block out and free the host arrays; returns bytes
        written (0 when a previous spill file is still valid — blocks
        are immutable, so re-hydrated copies can be dropped free)."""
        if self._data is None:
            return 0
        wrote = 0
        if self._path is None:
            from . import format as storefmt

            path = self._spill_path()
            n = max((a.shape[0] for a in self._data.values()), default=0)
            storefmt.write_arrays(
                path,
                self._data,
                chunk_rows=max(1, n),
                validity=self._validity or None,
            )
            self._path = path
            self._finalizer = weakref.finalize(self, _delete_dir, path)
            wrote = self.nbytes
        self._data = None
        return wrote

    def _do_load(self) -> None:
        if self._data is not None:
            return
        from . import format as storefmt

        table = storefmt.open_store(self._path)
        data: Dict[str, np.ndarray] = {}
        validity: Dict[str, np.ndarray] = {}
        for name, col in table.columns.items():
            phys = col.physical()
            if col.encoding == "dict":
                phys = col.dictionary[
                    np.clip(phys, 0, max(0, col.dictionary.shape[0] - 1))
                ]
            elif col.ctype == "date":
                phys = phys.astype("datetime64[D]")
            elif col.ctype == "bool":
                phys = phys != 0
            data[name] = phys
            v = col.validity()
            if v is not None:
                validity[name] = v
        self._data = data
        self._validity = validity

    # -- public --------------------------------------------------------
    def get(self) -> Tuple[Dict[str, np.ndarray], Dict[str, np.ndarray]]:
        """(data, validity), re-hydrating from disk when spilled."""
        return self._manager.touch(self)

    def release(self) -> None:
        """Stop tracking this block (arrays stay as they are)."""
        self._manager.unregister(self)


class SpillManager:
    """LRU byte-budget tracker over registered ``Spillable`` blocks.

    The budget is read from ``CONFIG.memory_budget_bytes`` at every
    enforcement point, so tests and the serving layer can flip it at
    runtime.  ``None`` disables spilling (blocks are still tracked, so
    ``peak_tracked_bytes`` stays observable).
    """

    def __init__(self):
        self._lock = threading.RLock()
        self._lru: "OrderedDict[int, Spillable]" = OrderedDict()
        self.counters = {
            "bytes_spilled": 0,
            "bytes_reread": 0,
            "evictions": 0,
            "peak_tracked_bytes": 0,
        }

    # -- config --------------------------------------------------------
    @staticmethod
    def budget() -> Optional[int]:
        from repro.core.config import CONFIG

        return CONFIG.memory_budget_bytes

    @staticmethod
    def spill_root() -> str:
        from repro.core.config import CONFIG

        if CONFIG.spill_dir:
            os.makedirs(CONFIG.spill_dir, exist_ok=True)
            return CONFIG.spill_dir
        return _process_spill_root()

    # -- introspection -------------------------------------------------
    def tracked_bytes(self) -> int:
        with self._lock:
            return sum(
                s.nbytes for s in self._lru.values() if not s.spilled
            )

    def reset_counters(self) -> None:
        with self._lock:
            for k in self.counters:
                self.counters[k] = 0

    # -- registration / LRU --------------------------------------------
    def register(
        self,
        data: Dict[str, np.ndarray],
        validity: Optional[Dict[str, np.ndarray]] = None,
    ) -> Spillable:
        s = Spillable(self, data, validity)
        with self._lock:
            self._lru[s.id] = s
            self._note_peak()
            self._enforce(keep=s)
        return s

    def unregister(self, s: Spillable) -> None:
        with self._lock:
            self._lru.pop(s.id, None)

    def touch(self, s: Spillable):
        with self._lock:
            reread = s.spilled
            if reread:
                from repro import obs

                with obs.span("spill.reread") as sp:
                    s._do_load()
                    sp.set(bytes=s.nbytes)
                self.counters["bytes_reread"] += s.nbytes
            else:
                s._do_load()
            if s.id in self._lru:
                self._lru.move_to_end(s.id)
            self._note_peak()
            self._enforce(keep=s)
            return s._data, s._validity

    def _note_peak(self) -> None:
        t = sum(s.nbytes for s in self._lru.values() if not s.spilled)
        if t > self.counters["peak_tracked_bytes"]:
            self.counters["peak_tracked_bytes"] = t

    def _enforce(self, keep: Optional[Spillable] = None) -> None:
        budget = self.budget()
        if budget is None:
            return
        resident = [s for s in self._lru.values() if not s.spilled]
        total = sum(s.nbytes for s in resident)
        for s in resident:  # LRU order (OrderedDict insertion/touch)
            if total <= budget:
                break
            if keep is not None and s.id == keep.id:
                continue
            from repro import obs

            with obs.span("spill.write") as sp:
                wrote = s._do_spill()
                sp.set(bytes=wrote)
            self.counters["bytes_spilled"] += wrote
            self.counters["evictions"] += 1
            total -= s.nbytes
        # the kept block alone may still overflow the budget — that's
        # fine, a block must be resident to be usable at all


#: process-wide manager (the out-of-core layer's single pool)
SPILL = SpillManager()

from repro import obs as _obs  # noqa: E402  (jax-free)

_obs.metrics.register_group(
    "store.spill", lambda: dict(SPILL.counters), SPILL.reset_counters
)
