"""Spill-to-disk for host-side intermediates under a memory budget.

The out-of-core layer (``repro.core.pipeline``) holds intermediate
results — per-chunk partial aggregates, buffered probe outputs — as
host array blocks.  ``SpillManager`` tracks their bytes against
``CONFIG.memory_budget_bytes`` and, when the pool overflows, evicts the
least-recently-used blocks to ``.tfb`` v2 chunk files (the store's own
format, so spilled frames keep zone maps, encodings and validity
bitmaps).  Access through ``Spillable.get`` transparently re-hydrates
and re-registers the block as most-recently-used.

Lifecycle: a spill file belongs to its ``Spillable`` — a
``weakref.finalize`` deletes the directory when the handle is garbage
collected, and the per-process spill root (used when
``CONFIG.spill_dir`` is unset) is removed at interpreter exit.

Resilience (ISSUE 10) — spilling is an *optimization*, so its I/O
failures degrade, never escalate:

- **writes** retry transient OSErrors (``resilience.retry``, budget
  ``CONFIG.io_retries``); a write that still fails keeps the block
  resident in memory — the budget overruns, counted in
  ``write_failures`` / ``retained_bytes`` — and the block is not
  re-offered for eviction;
- **reads** retry the same way; a block that comes back corrupt
  (unreadable, or its schema/row-count no longer matches what was
  written) is *recomputed* through the closure registered with the
  block (``register(..., recompute=)``) when one exists — counted in
  ``corrupt_blocks``/``recomputes`` — and surfaces as a typed
  ``TransientIOError`` only when it can't be;
- **deletes** never raise (a vanished spill dir is the desired end
  state); undeletable leftovers are counted in ``delete_failures``
  and swept again by the atexit root cleanup.

Fault-injection sites: ``spill.write``, ``spill.read``,
``spill.delete`` (armed by the chaos suite via ``resilience.faults``).

No jax imports: ``repro.store`` stays a host-side layer (CI-enforced).
"""
from __future__ import annotations

import atexit
import itertools
import os
import shutil
import tempfile
import threading
import weakref
from collections import OrderedDict
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.resilience import TransientIOError, faults, retry

_IDS = itertools.count()

_PROC_DIR: Optional[str] = None
_PROC_LOCK = threading.Lock()


def _process_spill_root() -> str:
    """Lazily created per-process spill directory, removed atexit."""
    global _PROC_DIR
    with _PROC_LOCK:
        if _PROC_DIR is None:
            _PROC_DIR = tempfile.mkdtemp(prefix="repro-spill-")
            atexit.register(shutil.rmtree, _PROC_DIR, ignore_errors=True)
    return _PROC_DIR


def _nbytes(arr: np.ndarray) -> int:
    if arr.dtype == object:
        return int(sum(len(str(s).encode()) + 8 for s in arr))
    return int(arr.nbytes)


def block_bytes(
    data: Dict[str, np.ndarray], validity: Optional[Dict[str, np.ndarray]]
) -> int:
    total = sum(_nbytes(a) for a in data.values())
    if validity:
        total += sum(_nbytes(a) for a in validity.values())
    return total


def _delete_dir(path: str) -> None:
    """Best-effort spill-dir removal: must never raise (it runs from
    weakref finalizers and GC), but a leftover dir is still counted."""
    try:
        faults.fault_point("spill.delete")
        shutil.rmtree(path)
    except FileNotFoundError:
        pass
    except Exception:
        try:
            shutil.rmtree(path, ignore_errors=True)
        except Exception:
            pass
        if os.path.exists(path):
            with SPILL._lock:
                SPILL.counters["delete_failures"] += 1


class Spillable:
    """One spillable block: a dict of host arrays (+ validity bitmaps).

    In-memory by default; ``spill()`` persists it as a ``.tfb`` v2
    directory and drops the arrays; ``get()`` re-hydrates on demand.
    The spill directory is deleted when the handle is GC'd.
    ``recompute`` (optional) rebuilds ``(data, validity)`` from durable
    inputs when the spill file comes back corrupt.
    """

    def __init__(
        self,
        manager: "SpillManager",
        data: Dict[str, np.ndarray],
        validity: Optional[Dict[str, np.ndarray]] = None,
        recompute: Optional[Callable[[], Tuple[Dict, Dict]]] = None,
    ):
        self.id = next(_IDS)
        self._manager = manager
        self._data: Optional[Dict[str, np.ndarray]] = dict(data)
        self._validity: Dict[str, np.ndarray] = dict(validity or {})
        self.nbytes = block_bytes(data, validity)
        self._path: Optional[str] = None
        self._finalizer = None
        self._recompute = recompute
        self._write_failed = False
        # written-block identity for corruption checks on re-read
        self._schema = tuple(sorted(data))
        self._rows = {k: int(a.shape[0]) for k, a in data.items()}

    @property
    def spilled(self) -> bool:
        return self._data is None

    # -- called by the manager (under its lock) ------------------------
    def _spill_path(self) -> str:
        root = self._manager.spill_root()
        return os.path.join(root, f"block-{os.getpid()}-{self.id}.tfb")

    def _write(self, path: str) -> None:
        from . import format as storefmt

        faults.fault_point("spill.write")
        n = max((a.shape[0] for a in self._data.values()), default=0)
        storefmt.write_arrays(
            path,
            self._data,
            chunk_rows=max(1, n),
            validity=self._validity or None,
        )

    def _do_spill(self) -> int:
        """Write the block out and free the host arrays; returns bytes
        written (0 when a previous spill file is still valid — blocks
        are immutable, so re-hydrated copies can be dropped free).

        A write that fails through the retry budget raises
        ``TransientIOError`` with the block left resident (the caller
        counts it and stops offering the block for eviction)."""
        if self._data is None:
            return 0
        wrote = 0
        if self._path is None:
            path = self._spill_path()
            try:
                retry.call(
                    lambda: self._write(path), site="spill.write"
                )
            except Exception:
                _delete_dir(path)  # never leave a half-written block
                raise
            self._path = path
            self._finalizer = weakref.finalize(self, _delete_dir, path)
            wrote = self.nbytes
        self._data = None
        return wrote

    def _read(self) -> Tuple[Dict, Dict]:
        from . import format as storefmt

        faults.fault_point("spill.read")
        table = storefmt.open_store(self._path)
        data: Dict[str, np.ndarray] = {}
        validity: Dict[str, np.ndarray] = {}
        for name, col in table.columns.items():
            phys = col.physical()
            if col.encoding == "dict":
                phys = col.dictionary[
                    np.clip(phys, 0, max(0, col.dictionary.shape[0] - 1))
                ]
            elif col.ctype == "date":
                phys = phys.astype("datetime64[D]")
            elif col.ctype == "bool":
                phys = phys != 0
            data[name] = phys
            v = col.validity()
            if v is not None:
                validity[name] = v
        if tuple(sorted(data)) != self._schema or any(
            int(data[k].shape[0]) != self._rows[k] for k in self._schema
        ):
            raise TransientIOError(
                f"corrupt spill block at {self._path}: schema/row-count "
                f"mismatch against what was written"
            )
        return data, validity

    def _do_load(self) -> None:
        """Re-hydrate from the spill file, recovering a corrupt or
        unreadable block through its recompute closure when one was
        registered (counted by the caller via the returned flag)."""
        if self._data is not None:
            return
        try:
            # corrupt-content mismatch (TransientIOError from _read) is
            # NOT retried: re-reading the same bad file cannot fix it
            data, validity = retry.call(
                self._read, site="spill.read", retry_on=(OSError, EOFError)
            )
        except Exception as e:
            with self._manager._lock:
                self._manager.counters["corrupt_blocks"] += 1
            if self._recompute is None:
                if isinstance(e, TransientIOError):
                    raise
                raise TransientIOError(
                    f"unreadable spill block at {self._path}"
                ) from e
            data, validity = self._recompute()
            validity = dict(validity or {})
            with self._manager._lock:
                self._manager.counters["recomputes"] += 1
            # the on-disk copy is bad: drop it so the next eviction
            # rewrites instead of re-reading garbage
            _delete_dir(self._path)
            self._path = None
            if self._finalizer is not None:
                self._finalizer.detach()
                self._finalizer = None
        self._data = dict(data)
        self._validity = dict(validity)

    # -- public --------------------------------------------------------
    def get(self) -> Tuple[Dict[str, np.ndarray], Dict[str, np.ndarray]]:
        """(data, validity), re-hydrating from disk when spilled."""
        return self._manager.touch(self)

    def release(self) -> None:
        """Stop tracking this block (arrays stay as they are)."""
        self._manager.unregister(self)


class SpillManager:
    """LRU byte-budget tracker over registered ``Spillable`` blocks.

    The budget is read from ``CONFIG.memory_budget_bytes`` at every
    enforcement point, so tests and the serving layer can flip it at
    runtime.  ``None`` disables spilling (blocks are still tracked, so
    ``peak_tracked_bytes`` stays observable).
    """

    def __init__(self):
        self._lock = threading.RLock()
        self._lru: "OrderedDict[int, Spillable]" = OrderedDict()
        self.counters = {
            "bytes_spilled": 0,
            "bytes_reread": 0,
            "evictions": 0,
            "peak_tracked_bytes": 0,
            "write_failures": 0,  # spill writes that exhausted retries
            "retained_bytes": 0,  # bytes kept resident past the budget
            "corrupt_blocks": 0,  # spill files unreadable/mismatched
            "recomputes": 0,  # corrupt blocks rebuilt from source
            "delete_failures": 0,  # spill dirs that would not delete
        }

    # -- config --------------------------------------------------------
    @staticmethod
    def budget() -> Optional[int]:
        from repro.core.config import CONFIG

        return CONFIG.memory_budget_bytes

    @staticmethod
    def spill_root() -> str:
        from repro.core.config import CONFIG

        if CONFIG.spill_dir:
            os.makedirs(CONFIG.spill_dir, exist_ok=True)
            return CONFIG.spill_dir
        return _process_spill_root()

    # -- introspection -------------------------------------------------
    def tracked_bytes(self) -> int:
        with self._lock:
            return sum(
                s.nbytes for s in self._lru.values() if not s.spilled
            )

    def reset_counters(self) -> None:
        with self._lock:
            for k in self.counters:
                self.counters[k] = 0

    # -- registration / LRU --------------------------------------------
    def register(
        self,
        data: Dict[str, np.ndarray],
        validity: Optional[Dict[str, np.ndarray]] = None,
        recompute: Optional[Callable[[], Tuple[Dict, Dict]]] = None,
    ) -> Spillable:
        s = Spillable(self, data, validity, recompute=recompute)
        with self._lock:
            self._lru[s.id] = s
            self._note_peak()
            self._enforce(keep=s)
        return s

    def unregister(self, s: Spillable) -> None:
        with self._lock:
            self._lru.pop(s.id, None)

    def touch(self, s: Spillable):
        with self._lock:
            reread = s.spilled
            if reread:
                from repro import obs

                with obs.span("spill.reread") as sp:
                    s._do_load()
                    sp.set(bytes=s.nbytes)
                self.counters["bytes_reread"] += s.nbytes
            else:
                s._do_load()
            if s.id in self._lru:
                self._lru.move_to_end(s.id)
            self._note_peak()
            self._enforce(keep=s)
            return s._data, s._validity

    def _note_peak(self) -> None:
        t = sum(s.nbytes for s in self._lru.values() if not s.spilled)
        if t > self.counters["peak_tracked_bytes"]:
            self.counters["peak_tracked_bytes"] = t

    def _enforce(self, keep: Optional[Spillable] = None) -> None:
        budget = self.budget()
        if budget is None:
            return
        resident = [s for s in self._lru.values() if not s.spilled]
        total = sum(s.nbytes for s in resident)
        for s in resident:  # LRU order (OrderedDict insertion/touch)
            if total <= budget:
                break
            if keep is not None and s.id == keep.id:
                continue
            if s._write_failed:
                continue  # already retained in memory; don't re-fail
            from repro import obs

            with obs.span("spill.write") as sp:
                try:
                    wrote = s._do_spill()
                except (OSError, TransientIOError):
                    # graceful degradation: keep the block resident and
                    # overrun the budget rather than lose the data
                    s._write_failed = True
                    self.counters["write_failures"] += 1
                    self.counters["retained_bytes"] += s.nbytes
                    sp.set(bytes=0, failed=1)
                    continue
                sp.set(bytes=wrote)
            self.counters["bytes_spilled"] += wrote
            self.counters["evictions"] += 1
            total -= s.nbytes
        # the kept block alone may still overflow the budget — that's
        # fine, a block must be resident to be usable at all


#: process-wide manager (the out-of-core layer's single pool)
SPILL = SpillManager()

from repro import obs as _obs  # noqa: E402  (jax-free)

_obs.metrics.register_group(
    "store.spill", lambda: dict(SPILL.counters), SPILL.reset_counters
)
