"""Predicate scans over chunked store tables (zone-map pushdown).

``scan(table, columns, predicates)`` is the store's read primitive:

1. every sargable conjunct is translated into the target column's
   *physical* domain (string constants become code bounds against the
   column's sorted dictionary — one ``searchsorted`` per predicate, not
   per row);
2. chunks whose zone maps prove the conjunct can never match are
   skipped before any payload is touched (lazy chunks stay on disk —
   Flare-style scan skipping: win the scan by not doing it);
3. surviving chunks are row-filtered exactly (numpy mask per chunk) and
   the projected columns are concatenated.

The result keeps dict columns as (codes, interned dictionary) so the
frame layer (``TensorFrame.from_store``) builds tensors without
re-factorizing — the store's second job after skipping I/O.

Predicates are conjuncts (implicit AND).  Supported ops:
``= <> < <= > >=`` against a scalar, ``between`` (inclusive pair),
``in`` (value tuple), ``isnull`` / ``notnull`` (float columns: NaN is
the store's null — chunks prune on the zone-map null counts), and
``like`` (prefix match, i.e. SQL ``LIKE 'prefix%'`` — dict columns
reduce it to a code range against the sorted dictionary, plain string
columns prune on the min/max string bounds).  Anything else stays a
residual filter above the scan (the SQL optimizer only pushes sargable
conjuncts).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .table import Column, Table, _empty_physical

_CMP_OPS = ("=", "<>", "<", "<=", ">", ">=")


@dataclasses.dataclass(frozen=True)
class Pred:
    """One sargable conjunct: ``column <op> value``.

    ``op`` is one of ``= <> < <= > >=`` (value: scalar), ``between``
    (value: inclusive ``(lo, hi)``), ``in`` (value: tuple), ``isnull``
    / ``notnull`` (value ignored) or ``like`` (value: the literal
    prefix of a ``LIKE 'prefix%'`` pattern).  Date values may be
    ``np.datetime64`` or int days since epoch.
    """

    column: str
    op: str
    value: object = None

    def __post_init__(self):
        if self.op not in _CMP_OPS + ("between", "in", "isnull", "notnull", "like"):
            raise ValueError(f"unknown predicate op {self.op!r}")


@dataclasses.dataclass(frozen=True)
class MaterializedColumn:
    """One scanned column: physical values (+ dictionary for dict).

    ``validity`` is a row-aligned bool array for columns whose chunks
    carry explicit null bitmaps (True = present); ``None`` when the
    column has no bitmaps (floats still encode nulls as NaN)."""

    ctype: str
    values: np.ndarray
    dictionary: Optional[np.ndarray] = None
    validity: Optional[np.ndarray] = None


@dataclasses.dataclass
class ScanResult:
    nrows: int
    columns: Dict[str, MaterializedColumn]
    chunks_total: int
    chunks_skipped: int
    rows_scanned: int  # rows materialized before the exact row filter


# ----------------------------------------------------------------------
# predicate normalization into the physical domain
# ----------------------------------------------------------------------
_ALL = "all"  # trivially true (drop)
_NONE = "none"  # trivially false (empty scan)


_INT_DOMAIN = ("int", "date", "bool")


def _as_days(v):
    if isinstance(v, np.datetime64):
        return int(v.astype("datetime64[D]").astype(np.int64))
    return v


def _normalize_value(col: Column, v):
    """Constant -> the column's physical domain.

    Integer-domain columns keep non-integral float constants as floats
    here; ``_to_physical`` rewrites the *predicate* instead (``k < 2.5``
    becomes ``k <= 2``) — truncating the constant would return wrong
    rows.
    """
    if col.ctype == "date":
        v = _as_days(v)
    if col.ctype == "float":
        return float(v)
    if col.ctype in _INT_DOMAIN:
        if isinstance(v, (bool, np.bool_)):
            return int(v)
        if isinstance(v, (int, np.integer)):
            return int(v)  # no float round-trip: 2**53+1 stays exact
        f = float(v)
        return int(f) if f == int(f) else f
    return str(v)


def _int_domain_scalar(op: str, v):
    """Rewrite ``col <op> v`` for an integer-domain column when ``v``
    is a non-integral float: no int equals 2.5, ``< 2.5`` means
    ``<= 2``, ``> 2.5`` means ``>= 3``."""
    if isinstance(v, int):
        return (op, v)
    import math

    if op == "=":
        return _NONE
    if op == "<>":
        return _ALL
    if op in ("<", "<="):
        return ("<=", math.floor(v))
    return (">=", math.ceil(v))  # '>' and '>='


def _to_physical(col: Column, p: Pred):
    """Translate ``p`` into physical-domain form: (op, value) | _ALL |
    _NONE.  For dict columns the value becomes a code (bound)."""
    import math

    if p.op in ("isnull", "notnull"):
        # float columns hold nulls as NaN; any ctype may carry explicit
        # validity bitmaps.  A bitmap-free non-float column decides
        # trivially.
        if col.ctype == "float" or col.has_validity():
            return (p.op, None)
        return _NONE if p.op == "isnull" else _ALL
    if p.op == "like":
        if col.ctype != "str":
            raise TypeError(
                f"LIKE predicate on non-string column {col.name!r}"
            )
        prefix = str(p.value)
        if col.encoding == "dict":
            # sorted dictionary: prefix matches are one contiguous code
            # range — one vectorized dictionary pass, zone maps then
            # prune on code bounds like any between
            lut = np.char.startswith(col.dictionary.astype("U"), prefix)
            idx = np.flatnonzero(lut)
            if idx.shape[0] == 0:
                return _NONE
            return ("between", (int(idx[0]), int(idx[-1])))
        return ("like", prefix)
    int_domain = col.ctype in _INT_DOMAIN and col.encoding != "dict"
    if p.op == "between":
        lo, hi = p.value
        lo, hi = _normalize_value(col, lo), _normalize_value(col, hi)
        if int_domain:
            lo, hi = math.ceil(lo), math.floor(hi)  # shrink to int bounds
            if lo > hi:
                return _NONE
        if col.encoding != "dict":
            return ("between", (lo, hi))
        d = col.dictionary
        a = int(np.searchsorted(d, lo, side="left"))
        b = int(np.searchsorted(d, hi, side="right")) - 1
        return ("between", (a, b)) if a <= b else _NONE
    if p.op == "in":
        vals = [_normalize_value(col, v) for v in p.value]
        if int_domain:
            vals = [v for v in vals if isinstance(v, int)]  # 2.5 in ints: never
            return ("in", tuple(vals)) if vals else _NONE
        if col.encoding != "dict":
            return ("in", tuple(vals))
        d = col.dictionary
        codes = []
        for v in vals:
            i = int(np.searchsorted(d, v))
            if i < d.shape[0] and d[i] == v:
                codes.append(i)
        return ("in", tuple(codes)) if codes else _NONE
    v = _normalize_value(col, p.value)
    if int_domain:
        return _int_domain_scalar(p.op, v)
    if col.encoding != "dict":
        return (p.op, v)
    # sorted dictionary: order-isomorphic codes, one searchsorted each
    d = col.dictionary
    left = int(np.searchsorted(d, v, side="left"))
    right = int(np.searchsorted(d, v, side="right"))
    present = right > left
    if p.op == "=":
        return ("=", left) if present else _NONE
    if p.op == "<>":
        return ("<>", left) if present else _ALL
    if p.op == "<":
        return ("<", left)  # codes [0, left) decode to strings < v
    if p.op == "<=":
        return ("<", right)
    if p.op == ">":
        return (">=", right)
    return (">=", left)  # '>='


# ----------------------------------------------------------------------
# zone-map chunk pruning
# ----------------------------------------------------------------------
def chunk_may_match(stats, phys) -> bool:
    """Can any row of a chunk with these zone maps satisfy ``phys``?"""
    if phys is _ALL:
        return True
    if phys is _NONE:
        return False
    lo, hi = stats.vmin, stats.vmax
    op, v = phys
    if op == "isnull":
        return stats.null_count > 0
    if op == "notnull":
        return lo is not None  # any non-null value in the chunk
    if op == "like":
        # prefix matches form the string interval [v, v_end); the chunk
        # range [lo, hi] intersects it iff hi >= v and lo < v_end
        # (lo < v_end  <=>  lo < v or lo startswith v)
        if lo is None:
            return False
        return str(hi) >= v and (str(lo) < v or str(lo).startswith(v))
    if lo is None:
        # all-null chunk: nothing compares true — except <>, where NaN
        # cells match under the engine's IEEE semantics
        return op == "<>"
    if op == "=":
        return lo <= v <= hi
    if op == "<>":
        # a chunk of all-v non-null values is skippable, but any NaN
        # null in it matches <> (IEEE), so nulls pin the chunk
        return stats.null_count > 0 or not (lo == hi == v)
    if op == "<":
        return lo < v
    if op == "<=":
        return lo <= v
    if op == ">":
        return hi > v
    if op == ">=":
        return hi >= v
    if op == "between":
        a, b = v
        return a <= hi and b >= lo
    # 'in'
    return any(lo <= x <= hi for x in v)


def _prune_mask(col: Column, ph) -> np.ndarray:
    """Vectorized keep-mask over the column's chunks for one physical
    predicate (the zone-map pass; one numpy op instead of a python call
    per chunk).  Falls back to exact per-chunk checks for plain-string
    stats and out-of-float64-range integer bounds."""
    n = len(col.chunks)
    if col.ctype == "str" and col.encoding != "dict":
        return np.fromiter(
            (chunk_may_match(c.stats, ph) for c in col.chunks), bool, count=n
        )
    mins, maxs, exact = col.zone_bounds()
    if not exact:
        return np.fromiter(
            (chunk_may_match(c.stats, ph) for c in col.chunks), bool, count=n
        )
    op, v = ph
    if op == "isnull":
        return np.fromiter(
            (c.stats.null_count > 0 for c in col.chunks), bool, count=n
        )
    if op == "notnull":
        return ~np.isnan(mins)  # NaN bound = all-null chunk
    if op == "=":
        return (mins <= v) & (v <= maxs)
    if op == "<>":
        # NaN cells match <> under IEEE semantics: all-null chunks
        # (NaN bounds give False inside, ~ keeps them) and chunks whose
        # non-null values are uniformly v but carry nulls both survive
        has_null = np.fromiter(
            (c.stats.null_count > 0 for c in col.chunks), bool, count=n
        )
        return has_null | ~((mins == maxs) & (maxs == v))
    if op == "<":
        return mins < v
    if op == "<=":
        return mins <= v
    if op == ">":
        return maxs > v
    if op == ">=":
        return maxs >= v
    if op == "between":
        a, b = v
        return (mins <= b) & (maxs >= a)
    out = np.zeros(n, dtype=bool)
    for x in v:
        out |= (mins <= x) & (x <= maxs)
    return out


def _eval_rows(
    values: np.ndarray, phys, valid: Optional[np.ndarray] = None
) -> np.ndarray:
    """Exact row mask of one chunk's physical values.

    ``valid`` is the chunk's explicit validity bitmap when it has one:
    null rows then match only ``isnull`` and ``<>`` (the engine's IEEE
    semantics — NaN satisfies ``<>``), never the ordered comparisons.
    """
    if valid is not None:
        op, _ = phys
        if op == "isnull":
            return ~valid
        if op == "notnull":
            return valid.copy()
        base = _eval_rows(values, phys)
        return (base | ~valid) if op == "<>" else (base & valid)
    op, v = phys
    if op == "isnull":
        return np.isnan(values.astype(np.float64))
    if op == "notnull":
        return ~np.isnan(values.astype(np.float64))
    if op == "like":
        return np.char.startswith(values.astype("U"), v)
    if op == "=":
        return values == v
    if op == "<>":
        # IEEE semantics, matching the engine's filter lowering: NaN
        # (null) cells DO satisfy <> — a pushed conjunct must select
        # exactly the rows the equivalent residual Filter would
        return values != v
    if op == "<":
        return values < v
    if op == "<=":
        return values <= v
    if op == ">":
        return values > v
    if op == ">=":
        return values >= v
    if op == "between":
        a, b = v
        return (values >= a) & (values <= b)
    mask = np.zeros(values.shape[0], dtype=bool)
    for x in v:
        mask |= values == x
    return mask


# ----------------------------------------------------------------------
# the scan
# ----------------------------------------------------------------------
def scan(
    table: Table,
    columns: Optional[Sequence[str]] = None,
    predicates: Sequence[Pred] = (),
) -> ScanResult:
    proj = list(columns) if columns is not None else table.column_names
    for name in proj:
        table.column(name)  # raises with a helpful message
    phys_preds: List[Tuple[Column, object]] = []
    trivially_empty = False
    for p in predicates:
        col = table.column(p.column)
        ph = _to_physical(col, p)
        if ph is _ALL:
            continue
        if ph is _NONE:
            trivially_empty = True
            continue
        phys_preds.append((col, ph))

    n_chunks = table.n_chunks
    survivors: List[int] = []
    if not trivially_empty:
        if phys_preds:
            keep = np.ones(n_chunks, dtype=bool)
            for col, ph in phys_preds:
                keep &= _prune_mask(col, ph)
            survivors = np.nonzero(keep)[0].tolist()
        else:
            survivors = list(range(n_chunks))

    parts: Dict[str, List[np.ndarray]] = {name: [] for name in proj}
    nullable = {
        name for name in proj if table.columns[name].has_validity()
    }
    vparts: Dict[str, List[np.ndarray]] = {name: [] for name in nullable}
    rows_scanned = 0
    nrows = 0
    any_col = next(iter(table.columns.values()), None)
    if len(survivors) == n_chunks:
        # nothing pruned: take the sequential bulk-read path instead of
        # paying a seek+read per chunk (the unpredicated read_tfb case)
        for name in proj:
            table.columns[name].ensure_loaded()
        for col, _ in phys_preds:
            col.ensure_loaded()
    for i in survivors:
        mask = None
        for col, ph in phys_preds:
            m = _eval_rows(col.chunk_physical(i), ph, col.chunk_validity(i))
            mask = m if mask is None else (mask & m)
        if mask is not None and bool(mask.all()):
            mask = None  # whole chunk passes: avoid the fancy-index copy
        chunk_n = any_col.chunks[i].n if any_col is not None else 0
        rows_scanned += chunk_n
        nrows += chunk_n if mask is None else int(mask.sum())
        for name in proj:
            col = table.columns[name]
            part = col.chunk_physical(i)
            parts[name].append(part if mask is None else part[mask])
            if name in nullable:
                v = col.chunk_validity(i)
                if v is None:
                    v = np.ones(col.chunks[i].n, dtype=bool)
                vparts[name].append(v if mask is None else v[mask])

    out: Dict[str, MaterializedColumn] = {}
    for name in proj:
        col = table.columns[name]
        if parts[name]:
            values = np.concatenate(parts[name])
        else:
            values = _empty_physical(col.ctype, col.encoding)
        valid = None
        if name in nullable:
            valid = (
                np.concatenate(vparts[name])
                if vparts[name]
                else np.ones(0, dtype=bool)
            )
        out[name] = MaterializedColumn(col.ctype, values, col.dictionary, valid)
    return ScanResult(
        nrows=nrows,
        columns=out,
        chunks_total=n_chunks,
        chunks_skipped=n_chunks - len(survivors),
        rows_scanned=rows_scanned,
    )


# ----------------------------------------------------------------------
# chunk-at-a-time scanning (the out-of-core pipeline's read primitive)
# ----------------------------------------------------------------------
def plan_scan(
    table: Table,
    columns: Optional[Sequence[str]] = None,
    predicates: Sequence[Pred] = (),
) -> Tuple[List[str], List[Tuple[Column, object]], np.ndarray]:
    """Plan a scan without materializing anything.

    Returns ``(projection, physical predicates, surviving chunk ids)``
    — the zone-map pruning half of ``scan``, split out so a streaming
    consumer (``repro.core.pipeline``) can materialize the survivors
    one chunk at a time via ``scan_chunk`` instead of all at once.
    """
    proj = list(columns) if columns is not None else table.column_names
    for name in proj:
        table.column(name)  # raises with a helpful message
    phys_preds: List[Tuple[Column, object]] = []
    trivially_empty = False
    for p in predicates:
        col = table.column(p.column)
        ph = _to_physical(col, p)
        if ph is _ALL:
            continue
        if ph is _NONE:
            trivially_empty = True
            continue
        phys_preds.append((col, ph))
    n_chunks = table.n_chunks
    if trivially_empty:
        survivors = np.zeros(0, dtype=np.int64)
    elif phys_preds:
        keep = np.ones(n_chunks, dtype=bool)
        for col, ph in phys_preds:
            keep &= _prune_mask(col, ph)
        survivors = np.nonzero(keep)[0]
    else:
        survivors = np.arange(n_chunks, dtype=np.int64)
    return proj, phys_preds, survivors


def scan_chunk(
    table: Table,
    proj: Sequence[str],
    phys_preds: Sequence[Tuple[Column, object]],
    i: int,
) -> ScanResult:
    """Materialize ONE chunk of a planned scan (see ``plan_scan``).

    Pure host-side numpy — safe to run on a prefetch thread while the
    device processes the previous chunk.  Semantics per chunk are
    identical to ``scan``'s inner loop: exact row masks, validity
    bitmaps carried through, dictionary codes left encoded.
    """
    mask = None
    for col, ph in phys_preds:
        m = _eval_rows(col.chunk_physical(i), ph, col.chunk_validity(i))
        mask = m if mask is None else (mask & m)
    if mask is not None and bool(mask.all()):
        mask = None
    any_col = next(iter(table.columns.values()), None)
    chunk_n = any_col.chunks[i].n if any_col is not None else 0
    nrows = chunk_n if mask is None else int(mask.sum())
    out: Dict[str, MaterializedColumn] = {}
    for name in proj:
        col = table.columns[name]
        part = col.chunk_physical(i)
        if mask is not None:
            part = part[mask]
        valid = None
        if col.has_validity():
            v = col.chunk_validity(i)
            if v is None:
                v = np.ones(chunk_n, dtype=bool)
            valid = v if mask is None else v[mask]
        out[name] = MaterializedColumn(col.ctype, part, col.dictionary, valid)
    return ScanResult(
        nrows=nrows,
        columns=out,
        chunks_total=1,
        chunks_skipped=0,
        rows_scanned=chunk_n,
    )


# ----------------------------------------------------------------------
# shared multi-predicate scan (serving micro-batches)
# ----------------------------------------------------------------------
def shared_scan(
    table: Table,
    requests: Sequence[Tuple[Optional[Sequence[str]], Sequence[Pred]]],
) -> List[ScanResult]:
    """One zone-map pass answering many predicated scans of ``table``.

    ``requests`` is a sequence of ``(columns, predicates)`` pairs — the
    arguments ``scan`` takes, one per concurrent query.  Semantics per
    request are identical to calling ``scan`` (same pruning, same exact
    row filters, same accounting), but the pass over the table is
    shared:

    * each chunk's physical values are decoded **once** (rle runs
      expanded once, lazy chunks loaded once) no matter how many
      requests touch it;
    * identical physical conjuncts across requests share their exact
      row masks (16 dashboards asking ``ts >= today`` evaluate the
      comparison once per chunk, not 16 times);
    * zone-map pruning stays per-request, so each request still skips
      the chunks its own predicates disprove.

    This is the serving layer's admission-batching primitive (ISSUE 7):
    many sargable predicates against one store table collapse into one
    shared scan pass.
    """
    n_chunks = table.n_chunks
    normed = []  # (proj, [(col, ph)], per-request chunk keep mask)
    for columns, predicates in requests:
        proj = list(columns) if columns is not None else table.column_names
        for name in proj:
            table.column(name)  # raises with a helpful message
        phys_preds: List[Tuple[Column, object]] = []
        trivially_empty = False
        for p in predicates:
            col = table.column(p.column)
            ph = _to_physical(col, p)
            if ph is _ALL:
                continue
            if ph is _NONE:
                trivially_empty = True
                continue
            phys_preds.append((col, ph))
        if trivially_empty:
            keep = np.zeros(n_chunks, dtype=bool)
        elif phys_preds:
            keep = np.ones(n_chunks, dtype=bool)
            for col, ph in phys_preds:
                keep &= _prune_mask(col, ph)
        else:
            keep = np.ones(n_chunks, dtype=bool)
        normed.append((proj, phys_preds, keep))

    # chunks any request materializes, per column (projection + filter)
    union = np.zeros(n_chunks, dtype=bool)
    for _, _, keep in normed:
        union |= keep
    if bool(union.all()):
        # nothing pruned anywhere: prefer one sequential bulk read per
        # column over per-chunk seeks (mirrors the single-scan path)
        needed = set()
        for proj, phys_preds, _ in normed:
            needed.update(proj)
            needed.update(col.name for col, _ in phys_preds)
        for name in needed:
            table.columns[name].ensure_loaded()

    values_cache: Dict[Tuple[int, int], np.ndarray] = {}

    def chunk_values(col: Column, i: int) -> np.ndarray:
        key = (id(col), i)
        got = values_cache.get(key)
        if got is None:
            got = values_cache[key] = col.chunk_physical(i)
        return got

    # exact row masks shared across requests carrying the same conjunct
    mask_cache: Dict[Tuple[int, int, object], np.ndarray] = {}

    def pred_mask(col: Column, i: int, ph) -> np.ndarray:
        try:
            key = (id(col), i, ph)
            got = mask_cache.get(key)
        except TypeError:  # unhashable predicate value: evaluate fresh
            return _eval_rows(chunk_values(col, i), ph, col.chunk_validity(i))
        if got is None:
            got = mask_cache[key] = _eval_rows(
                chunk_values(col, i), ph, col.chunk_validity(i)
            )
        return got

    any_col = next(iter(table.columns.values()), None)
    results: List[ScanResult] = []
    for proj, phys_preds, keep in normed:
        survivors = np.nonzero(keep)[0].tolist()
        parts: Dict[str, List[np.ndarray]] = {name: [] for name in proj}
        nullable = {
            name for name in proj if table.columns[name].has_validity()
        }
        vparts: Dict[str, List[np.ndarray]] = {name: [] for name in nullable}
        rows_scanned = 0
        nrows = 0
        for i in survivors:
            mask = None
            for col, ph in phys_preds:
                m = pred_mask(col, i, ph)
                mask = m if mask is None else (mask & m)
            if mask is not None and bool(mask.all()):
                mask = None  # whole chunk passes: skip the fancy-index copy
            chunk_n = any_col.chunks[i].n if any_col is not None else 0
            rows_scanned += chunk_n
            nrows += chunk_n if mask is None else int(mask.sum())
            for name in proj:
                col = table.columns[name]
                part = chunk_values(col, i)
                parts[name].append(part if mask is None else part[mask])
                if name in nullable:
                    v = col.chunk_validity(i)
                    if v is None:
                        v = np.ones(col.chunks[i].n, dtype=bool)
                    vparts[name].append(v if mask is None else v[mask])
        out: Dict[str, MaterializedColumn] = {}
        for name in proj:
            col = table.columns[name]
            if parts[name]:
                values = np.concatenate(parts[name])
            else:
                values = _empty_physical(col.ctype, col.encoding)
            valid = None
            if name in nullable:
                valid = (
                    np.concatenate(vparts[name])
                    if vparts[name]
                    else np.ones(0, dtype=bool)
                )
            out[name] = MaterializedColumn(
                col.ctype, values, col.dictionary, valid
            )
        results.append(
            ScanResult(
                nrows=nrows,
                columns=out,
                chunks_total=n_chunks,
                chunks_skipped=n_chunks - len(survivors),
                rows_scanned=rows_scanned,
            )
        )
    return results
