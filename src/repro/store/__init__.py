"""repro.store — chunked columnar storage under TensorFrame.

The storage layer the paper's conclusion asks for ("in-memory data
representation and dictionary operations"):

- ``table``  — fixed-size column chunks with zone-map statistics and a
  cardinality-aware per-column encoding (plain / dict / rle);
- ``pool``   — a process-wide interned string-pool: equal dictionaries
  are one object, so cross-frame dictionary merges become identity;
- ``scan``   — predicate scans that skip whole chunks via zone maps
  before materializing anything;
- ``format`` — the ``.tfb`` v2 on-disk format with lazy per-column /
  per-chunk loading (v1 stays readable through ``core.io``).

Import-time constraint (CI-enforced): this package must import without
jax — it is a host-side layer usable before any accelerator backend
initializes.  The device side enters only through
``TensorFrame.from_store`` (``repro.core``), which depends on this
package, never the reverse.
"""
from .pool import POOL, StringPool, intern_dictionary
from .table import (
    CTYPES,
    DEFAULT_CHUNK_ROWS,
    DEFAULT_POLICY,
    Chunk,
    ChunkStats,
    Column,
    EncodingPolicy,
    Table,
    compute_stats,
)
from .scan import (
    MaterializedColumn,
    Pred,
    ScanResult,
    chunk_may_match,
    plan_scan,
    scan,
    scan_chunk,
    shared_scan,
)
from .format import (
    MAGIC_V2,
    is_v2,
    open_store,
    read_arrays,
    write_arrays,
    write_store,
)
from .spill import SPILL, SpillManager, Spillable, block_bytes

__all__ = [
    "POOL",
    "StringPool",
    "intern_dictionary",
    "CTYPES",
    "DEFAULT_CHUNK_ROWS",
    "DEFAULT_POLICY",
    "Chunk",
    "ChunkStats",
    "Column",
    "EncodingPolicy",
    "Table",
    "compute_stats",
    "MaterializedColumn",
    "Pred",
    "ScanResult",
    "chunk_may_match",
    "plan_scan",
    "scan",
    "scan_chunk",
    "shared_scan",
    "MAGIC_V2",
    "is_v2",
    "open_store",
    "read_arrays",
    "write_arrays",
    "write_store",
    "SPILL",
    "SpillManager",
    "Spillable",
    "block_bytes",
]
