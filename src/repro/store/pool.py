"""Process-wide interned string-pool for column dictionaries.

Every dictionary that enters the store is canonicalized through one
process-wide pool: two columns (in the same table, across chunks, or in
different tables/frames) whose dictionaries have equal content share
the *same* ``np.ndarray`` object.  Downstream, identity is the fast
path — ``core.encoding.merge_dictionaries`` returns an O(1)/O(k)
identity remap when both sides are the same object, and the join's
shared-factorization step (``core.join.shared_key_codes``) skips the
dictionary merge entirely (``ld is rd``).  This is the paper's own
"dictionary operations" optimization opportunity: re-sorting and
re-merging identical dictionaries per frame was pure waste.

The pool is content-addressed (byte digest of the entries), guarded by
a full equality check so a digest collision can never alias two
different dictionaries.  Interned arrays are marked read-only; sharing
is only safe because nobody may write through them.

The pool is **bounded**: entries are kept in LRU order and evicted past
``max_entries`` (default 1024 dictionaries), so a long-running process
churning through many distinct table sets no longer accumulates strong
references for its lifetime.  Eviction is always *safe*: code still
holding an evicted canonical array keeps it alive through its own
reference; a later equal dictionary simply interns to a fresh object
and the identity fast path degrades to the content-merge slow path.
``POOL.clear()`` still empties the pool manually; ``POOL.max_entries``
is assignable (``None`` disables the bound).

The pool is **thread-safe**: a serving tier interns dictionaries from
many sessions concurrently (ISSUE 7), so lookup/insert/eviction run
under one mutex.  Interning is a short host-side critical section —
digesting happens outside the lock; only the bucket probe, insert and
LRU eviction are serialized.

No jax imports here: the pool (like all of ``repro.store``) is host-side
numpy and must stay importable without initializing any accelerator.
"""
from __future__ import annotations

import hashlib
import threading
from collections import OrderedDict
from typing import List, Optional, Tuple

import numpy as np

DEFAULT_MAX_ENTRIES = 1024


def _digest(dictionary: np.ndarray) -> Tuple[int, bytes]:
    h = hashlib.sha1()
    for s in dictionary:
        b = str(s).encode("utf-8")
        h.update(len(b).to_bytes(4, "little"))
        h.update(b)
    return int(dictionary.shape[0]), h.digest()


class StringPool:
    """Content-addressed, LRU-bounded intern table for sorted
    dictionary arrays."""

    def __init__(self, max_entries: Optional[int] = DEFAULT_MAX_ENTRIES) -> None:
        # key -> collision bucket, in LRU order (oldest first)
        self._by_key: "OrderedDict[Tuple[int, bytes], List[np.ndarray]]" = (
            OrderedDict()
        )
        self._count = 0  # total interned arrays, kept O(1)
        self._lock = threading.Lock()
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def intern(self, dictionary: np.ndarray) -> np.ndarray:
        """Return the canonical instance of ``dictionary``.

        Equal-content calls return the *same object* (``is``-identical),
        so identity checks downstream replace content comparisons.  The
        canonical array is read-only.  Safe to call from any thread.
        """
        dictionary = np.asarray(dictionary)
        key = _digest(dictionary)  # hash outside the lock
        with self._lock:
            bucket = self._by_key.get(key)
            if bucket is not None:
                self._by_key.move_to_end(key)  # LRU touch
                for cand in bucket:  # digest-collision guard: verify content
                    if cand.shape == dictionary.shape and bool(
                        np.all(cand == dictionary)
                    ):
                        self.hits += 1
                        return cand
            else:
                bucket = self._by_key[key] = []
            canonical = dictionary.copy()
            canonical.setflags(write=False)
            bucket.append(canonical)
            self._count += 1
            self.misses += 1
            self._evict()
        return canonical

    def _evict(self) -> None:
        if self.max_entries is None:
            return
        while self._count > self.max_entries and len(self._by_key) > 1:
            _, bucket = self._by_key.popitem(last=False)
            self._count -= len(bucket)
            self.evictions += len(bucket)

    def __len__(self) -> int:
        return self._count

    def clear(self) -> None:
        with self._lock:
            self._by_key.clear()
            self._count = 0
            self.hits = 0
            self.misses = 0
            self.evictions = 0

    def stats_snapshot(self) -> dict:
        with self._lock:
            return {
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "entries": self._count,
            }

    def reset_stats(self) -> None:
        """Zero the counters; interned entries stay (counter isolation
        must not evict canonical dictionaries other frames hold)."""
        with self._lock:
            self.hits = 0
            self.misses = 0
            self.evictions = 0


#: The process-wide pool every store table interns through.
POOL = StringPool()

from repro import obs as _obs  # noqa: E402  (jax-free)

_obs.metrics.register_group(
    "store.pool", POOL.stats_snapshot, POOL.reset_stats
)


def intern_dictionary(dictionary: np.ndarray) -> np.ndarray:
    return POOL.intern(dictionary)
