"""Process-wide interned string-pool for column dictionaries.

Every dictionary that enters the store is canonicalized through one
process-wide pool: two columns (in the same table, across chunks, or in
different tables/frames) whose dictionaries have equal content share
the *same* ``np.ndarray`` object.  Downstream, identity is the fast
path — ``core.encoding.merge_dictionaries`` returns an O(1)/O(k)
identity remap when both sides are the same object, and the join's
shared-factorization step (``core.join.shared_key_codes``) skips the
dictionary merge entirely (``ld is rd``).  This is the paper's own
"dictionary operations" optimization opportunity: re-sorting and
re-merging identical dictionaries per frame was pure waste.

The pool is content-addressed (byte digest of the entries), guarded by
a full equality check so a digest collision can never alias two
different dictionaries.  Interned arrays are marked read-only; sharing
is only safe because nobody may write through them.

The pool holds strong references for the process lifetime — identity
(`is`) comparisons stay valid for as long as any code might hold a
code array encoded against an interned dictionary.  Long-running
processes that churn through many distinct dictionaries should call
``POOL.clear()`` at table-set boundaries (a bounded / weak-referenced
pool is a ROADMAP follow-up).

No jax imports here: the pool (like all of ``repro.store``) is host-side
numpy and must stay importable without initializing any accelerator.
"""
from __future__ import annotations

import hashlib
from typing import Dict, List, Tuple

import numpy as np


def _digest(dictionary: np.ndarray) -> Tuple[int, bytes]:
    h = hashlib.sha1()
    for s in dictionary:
        b = str(s).encode("utf-8")
        h.update(len(b).to_bytes(4, "little"))
        h.update(b)
    return int(dictionary.shape[0]), h.digest()


class StringPool:
    """Content-addressed intern table for sorted dictionary arrays."""

    def __init__(self) -> None:
        self._by_key: Dict[Tuple[int, bytes], List[np.ndarray]] = {}
        self.hits = 0
        self.misses = 0

    def intern(self, dictionary: np.ndarray) -> np.ndarray:
        """Return the canonical instance of ``dictionary``.

        Equal-content calls return the *same object* (``is``-identical),
        so identity checks downstream replace content comparisons.  The
        canonical array is read-only.
        """
        dictionary = np.asarray(dictionary)
        key = _digest(dictionary)
        bucket = self._by_key.setdefault(key, [])
        for cand in bucket:  # digest-collision guard: verify content
            if cand.shape == dictionary.shape and bool(
                np.all(cand == dictionary)
            ):
                self.hits += 1
                return cand
        canonical = dictionary.copy()
        canonical.setflags(write=False)
        bucket.append(canonical)
        self.misses += 1
        return canonical

    def __len__(self) -> int:
        return sum(len(b) for b in self._by_key.values())

    def clear(self) -> None:
        self._by_key.clear()
        self.hits = 0
        self.misses = 0


#: The process-wide pool every store table interns through.
POOL = StringPool()


def intern_dictionary(dictionary: np.ndarray) -> np.ndarray:
    return POOL.intern(dictionary)
