"""Chunked columnar tables with per-chunk statistics.

The storage layer under TensorFrame (ISSUE 4 tentpole; the paper's
conclusion names "in-memory data representation and dictionary
operations" as the biggest remaining optimization surface):

- every column is split into fixed-size **chunks** (default 64Ki rows);
- every chunk carries **zone-map statistics**: min/max over the chunk's
  comparable domain, a null count, and a distinct count (exact for
  in-memory builds, an estimate once persisted loaders round-trip it);
- every column carries one **encoding**, chosen by a cardinality-aware
  policy generalizing ``core.encoding``:

  * ``dict`` — low-cardinality strings: one *sorted, interned*
    dictionary per column (shared across all chunks and, through the
    process-wide pool, across tables), chunks hold dense int64 codes.
    Sorted dictionaries make codes order-isomorphic to the strings, so
    zone maps and range predicates work on codes directly.
  * ``rle``  — run-clustered numeric/date/bool columns: chunks hold
    (run values, run lengths).
  * ``plain`` — everything else: raw numpy payloads (high-cardinality
    strings stay object arrays; the frame layer offloads them).

Chunks may be *lazy*: a chunk constructed with a loader callback reads
its payload from disk on first access (the ``.tfb`` v2 path) and caches
it.  Zone maps are always eager — they live in the manifest — so scan
pruning never touches the payload of a skipped chunk.

No jax imports: ``repro.store`` is a host-side layer and must import
without initializing any accelerator backend (CI asserts this).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .pool import intern_dictionary

DEFAULT_CHUNK_ROWS = 1 << 16

#: column types; 'date' is int64 days since epoch, 'bool' is int64 0/1
CTYPES = ("int", "float", "date", "bool", "str")
ENCODINGS = ("plain", "dict", "rle")


@dataclasses.dataclass(frozen=True)
class ChunkStats:
    """Zone-map statistics of one chunk.

    ``vmin``/``vmax`` are over the chunk's *physical* domain — values
    for numeric columns, codes for dict columns (sorted dictionaries
    make code order == string order), raw strings for plain string
    columns.  ``None`` bounds mean the chunk is all-null (every
    predicate may skip it).  ``distinct`` counts distinct non-null
    physical values (exact when built in memory).
    """

    vmin: object
    vmax: object
    null_count: int
    distinct: int


class Chunk:
    """One chunk of one column: stats + (possibly lazy) payload.

    ``payload`` is the encoded representation: a values array (plain /
    dict codes) or a ``(values, run_lengths)`` pair (rle).  A lazy chunk
    holds a zero-arg ``loader`` instead and caches its result.

    ``validity`` is an optional row-aligned bool array (True = value
    present); ``None`` means every row is valid — except float columns,
    where NaN cells stay the legacy null encoding.  Validity makes
    int/date/bool/str nulls first-class in the store (they previously
    survived only as float NaN), so spilled engine intermediates
    round-trip losslessly.  ``vloader`` defers the validity read (the
    ``.tfb`` v2 ``<col>.valid`` file).
    """

    __slots__ = ("n", "stats", "_payload", "_loader", "_validity", "_vloader")

    def __init__(
        self,
        n: int,
        stats: ChunkStats,
        payload=None,
        loader: Optional[Callable[[], object]] = None,
        validity: Optional[np.ndarray] = None,
        vloader: Optional[Callable[[], np.ndarray]] = None,
    ):
        if (payload is None) == (loader is None):
            raise ValueError("chunk needs exactly one of payload/loader")
        self.n = int(n)
        self.stats = stats
        self._payload = payload
        self._loader = loader
        self._validity = validity
        self._vloader = vloader

    @property
    def loaded(self) -> bool:
        return self._payload is not None

    def payload(self):
        if self._payload is None:
            self._payload = self._loader()
        return self._payload

    def validity(self) -> Optional[np.ndarray]:
        """Row-aligned bool validity (None = all rows valid)."""
        if self._validity is None and self._vloader is not None:
            self._validity = self._vloader()
        return self._validity


class Column:
    """One column: ctype, encoding, optional interned dictionary, chunks.

    Persisted columns may pass loaders instead of eager data:
    ``dict_loader`` defers the dictionary read to first use, and
    ``bulk_loader`` (returning every chunk's payload from one
    sequential read) accelerates full-column materialization when no
    chunk has been touched yet.
    """

    def __init__(
        self,
        name: str,
        ctype: str,
        encoding: str,
        chunks: List[Chunk],
        dictionary: Optional[np.ndarray] = None,
        dict_loader: Optional[Callable[[], np.ndarray]] = None,
        bulk_loader: Optional[Callable[[], List[object]]] = None,
    ):
        if ctype not in CTYPES:
            raise ValueError(f"unknown ctype {ctype!r}")
        if encoding not in ENCODINGS:
            raise ValueError(f"unknown encoding {encoding!r}")
        if (encoding == "dict") != (
            dictionary is not None or dict_loader is not None
        ):
            raise ValueError("dictionary required iff encoding == 'dict'")
        self.name = name
        self.ctype = ctype
        self.encoding = encoding
        self.chunks = chunks
        self._dictionary = dictionary
        self._dict_loader = dict_loader
        self._bulk_loader = bulk_loader
        self._zones = None  # cached (mins, maxs) float64 zone arrays

    @property
    def dictionary(self) -> Optional[np.ndarray]:
        if self._dictionary is None and self._dict_loader is not None:
            self._dictionary = self._dict_loader()
        return self._dictionary

    @property
    def nrows(self) -> int:
        return sum(c.n for c in self.chunks)

    def zone_bounds(self):
        """(mins, maxs, exact) zone arrays over chunks, NaN = all-null.

        float64 arrays for vectorized pruning; ``exact`` is False when
        any bound exceeds float64's integer range (2**53), in which
        case callers must fall back to exact per-chunk checks.  Only
        for numeric-domain stats (everything but plain strings);
        cached.
        """
        if self._zones is None:
            nan = float("nan")
            mins = np.array(
                [nan if c.stats.vmin is None else float(c.stats.vmin)
                 for c in self.chunks],
                dtype=np.float64,
            )
            maxs = np.array(
                [nan if c.stats.vmax is None else float(c.stats.vmax)
                 for c in self.chunks],
                dtype=np.float64,
            )
            if self.ctype == "float":
                exact = True  # bounds were float64 to begin with
            else:
                finite = np.concatenate(
                    [mins[~np.isnan(mins)], maxs[~np.isnan(maxs)]]
                )
                exact = bool(np.all(np.abs(finite) < float(1 << 53)))
            self._zones = (mins, maxs, exact)
        return self._zones

    def uniqueness_from_stats(self):
        """(unique, distinct) provable from the chunk zone maps alone.

        ``unique=True`` when every chunk's non-null values are
        internally distinct (per-chunk distinct == non-null count) and
        the chunks' [vmin, vmax] ranges are pairwise disjoint — the
        layout of primary-key-ish and sorted columns; the total
        distinct count is then exact.  ``unique=False`` when some chunk
        provably holds a duplicate.  ``(None, None)`` when the zone
        maps cannot decide (overlapping chunk ranges).  Consumed by
        ``TensorFrame.from_store`` to seed the frame stats cache so
        ``join(algorithm="auto")`` skips its build-side sort test.
        """
        total = 0
        spans = []
        for c in self.chunks:
            nn = c.n - c.stats.null_count
            if nn == 0:
                continue
            if c.stats.distinct < nn:
                return False, None
            total += c.stats.distinct
            spans.append((c.stats.vmin, c.stats.vmax))
        spans.sort()
        for (_, a_hi), (b_lo, _) in zip(spans, spans[1:]):
            if b_lo <= a_hi:
                return None, None  # ranges overlap: zone maps can't prove
        return True, total

    def chunk_physical(self, i: int) -> np.ndarray:
        """Decoded *physical* values of chunk ``i`` (codes for dict)."""
        c = self.chunks[i]
        if self.encoding == "rle":
            values, runs = c.payload()
            return np.repeat(values, runs)
        return c.payload()

    def chunk_validity(self, i: int) -> Optional[np.ndarray]:
        """Row-aligned validity of chunk ``i`` (None = all valid)."""
        return self.chunks[i].validity()

    def has_validity(self) -> bool:
        """Does any chunk carry an explicit validity bitmap?

        Answered from the chunk objects (payload/validity stay on disk
        for lazy columns: persisted validity always pairs a vloader)."""
        return any(
            c._validity is not None or c._vloader is not None
            for c in self.chunks
        )

    def validity(self) -> Optional[np.ndarray]:
        """All chunks' validity, concatenated (None = no bitmaps)."""
        if not self.has_validity():
            return None
        parts = []
        for c in self.chunks:
            v = c.validity()
            parts.append(np.ones(c.n, dtype=bool) if v is None else v)
        return np.concatenate(parts) if parts else np.ones(0, dtype=bool)

    def ensure_loaded(self) -> None:
        """Populate every chunk's payload, preferring one sequential
        bulk read over per-chunk seeks when nothing is loaded yet."""
        if self._bulk_loader is not None and not any(
            c.loaded for c in self.chunks
        ):
            for c, payload in zip(self.chunks, self._bulk_loader()):
                c._payload = payload

    def physical(self) -> np.ndarray:
        """All chunks' physical values, concatenated."""
        self.ensure_loaded()
        parts = [self.chunk_physical(i) for i in range(len(self.chunks))]
        if not parts:
            return _empty_physical(self.ctype, self.encoding)
        return np.concatenate(parts)

    def decode(self, physical: np.ndarray) -> np.ndarray:
        """Physical values -> user-facing values."""
        if self.encoding == "dict":
            safe = np.clip(physical, 0, max(0, self.dictionary.shape[0] - 1))
            return self.dictionary[safe]
        if self.ctype == "date":
            return physical.astype("datetime64[D]")
        if self.ctype == "bool":
            return physical != 0
        return physical

    def values(self) -> np.ndarray:
        return self.decode(self.physical())


def _empty_physical(ctype: str, encoding: str) -> np.ndarray:
    if encoding == "dict" or ctype in ("int", "date", "bool"):
        return np.zeros((0,), dtype=np.int64)
    if ctype == "float":
        return np.zeros((0,), dtype=np.float64)
    return np.array([], dtype=object)


# ----------------------------------------------------------------------
# statistics + encoding policy
# ----------------------------------------------------------------------
def compute_stats(
    physical: np.ndarray, ctype: str, validity: Optional[np.ndarray] = None
) -> ChunkStats:
    """Zone-map stats of one chunk's physical values.

    Nulls are NaN in float columns (the engine's convention); other
    ctypes hold nulls through an explicit ``validity`` bitmap (True =
    present).  Stats cover the non-null values only.
    """
    n = physical.shape[0]
    if ctype == "float":
        mask = ~np.isnan(physical.astype(np.float64))
        if validity is not None:
            mask &= validity
        nn = physical[mask]
        nulls = n - int(mask.sum())
    elif validity is not None:
        nn = physical[validity]
        nulls = n - int(validity.sum())
    else:
        nn = physical
        nulls = 0
    if nn.shape[0] == 0:
        return ChunkStats(None, None, nulls, 0)
    if ctype == "str":  # plain strings: python-comparable min/max
        vmin, vmax = min(nn), max(nn)
        distinct = len(set(nn))
        return ChunkStats(str(vmin), str(vmax), nulls, distinct)
    vmin = nn.min()
    vmax = nn.max()
    distinct = int(np.unique(nn).shape[0])
    vmin = float(vmin) if ctype == "float" else int(vmin)
    vmax = float(vmax) if ctype == "float" else int(vmax)
    return ChunkStats(vmin, vmax, nulls, distinct)


def _run_count(arr: np.ndarray) -> int:
    if arr.shape[0] <= 1:
        return arr.shape[0]
    return int((arr[1:] != arr[:-1]).sum()) + 1


@dataclasses.dataclass(frozen=True)
class EncodingPolicy:
    """Cardinality-aware per-column encoding choice.

    Generalizes ``core.encoding``'s single dict-vs-offload threshold:

    - strings dict-encode when ``distinct/n <= dict_threshold`` (the
      paper's 50% default), else stay plain (the frame layer offloads
      them);
    - numeric/date/bool columns RLE-encode when the column's run count
      is at most ``rle_threshold`` of its rows (clustered/sorted data:
      dates in time-ordered fact tables, repeated foreign keys), else
      stay plain.  Floats never RLE (NaN runs compare False).
    """

    dict_threshold: float = 0.5
    rle_threshold: float = 0.5

    def choose(self, arr: np.ndarray, ctype: str) -> str:
        n = max(1, arr.shape[0])
        if ctype == "str":
            distinct = np.unique(arr).shape[0]
            return "dict" if distinct <= self.dict_threshold * n else "plain"
        if ctype == "float":
            return "plain"
        return "rle" if _run_count(arr) <= self.rle_threshold * n else "plain"


DEFAULT_POLICY = EncodingPolicy()


# ----------------------------------------------------------------------
# the table
# ----------------------------------------------------------------------
class Table:
    """A chunked columnar table (the store's unit of storage).

    All columns share one chunk grid: chunk ``i`` covers the same row
    range in every column, so a zone-map skip decision on one column
    drops the same rows from all of them (and a chunk is the natural
    shard unit for ``repro.dist``).
    """

    def __init__(self, columns: Dict[str, Column], nrows: int, chunk_rows: int):
        self.columns = columns
        self.nrows = int(nrows)
        self.chunk_rows = int(chunk_rows)

    # ---- construction ------------------------------------------------
    @staticmethod
    def from_arrays(
        data: Dict[str, np.ndarray],
        *,
        chunk_rows: int = DEFAULT_CHUNK_ROWS,
        policy: EncodingPolicy = DEFAULT_POLICY,
        encode: Optional[Dict[str, str]] = None,
        validity: Optional[Dict[str, np.ndarray]] = None,
    ) -> "Table":
        """Chunk + encode a dict of host arrays.

        ``encode`` forces an encoding per column name ('plain' | 'dict'
        | 'rle'), overriding the policy.  ``validity`` maps column name
        -> row-aligned bool array (True = present) for nullable non-
        float columns; null cells' payload values are kept verbatim
        (callers pass an in-domain sentinel), the bitmap is
        authoritative.
        """
        if chunk_rows <= 0:
            raise ValueError("chunk_rows must be positive")
        encode = encode or {}
        validity = validity or {}
        columns: Dict[str, Column] = {}
        n = None
        for name, arr in data.items():
            arr = np.asarray(arr)
            if n is None:
                n = arr.shape[0]
            elif arr.shape[0] != n:
                raise ValueError(f"column {name}: length {arr.shape[0]} != {n}")
            valid = validity.get(name)
            if valid is not None:
                valid = np.asarray(valid, dtype=bool)
                if valid.shape[0] != arr.shape[0]:
                    raise ValueError(f"column {name}: validity length mismatch")
                if bool(valid.all()):
                    valid = None  # all-valid bitmap: store nothing
            columns[name] = _build_column(
                name, arr, chunk_rows, policy, encode.get(name), valid
            )
        return Table(columns, 0 if n is None else n, chunk_rows)

    # ---- introspection -----------------------------------------------
    @property
    def column_names(self) -> List[str]:
        return list(self.columns)

    def column(self, name: str) -> Column:
        try:
            return self.columns[name]
        except KeyError:
            raise KeyError(
                f"no column {name!r}; have {self.column_names}"
            ) from None

    @property
    def n_chunks(self) -> int:
        if not self.columns:
            return 0
        return len(next(iter(self.columns.values())).chunks)

    def schema(self) -> Dict[str, str]:
        return {name: c.ctype for name, c in self.columns.items()}

    def to_arrays(
        self, columns: Optional[Sequence[str]] = None
    ) -> Dict[str, np.ndarray]:
        """Full decode to host arrays (dates back to datetime64)."""
        names = list(columns) if columns is not None else self.column_names
        return {name: self.column(name).values() for name in names}

    def stats(self, name: str) -> List[ChunkStats]:
        return [c.stats for c in self.column(name).chunks]

    def memory_bytes(self) -> int:
        """Bytes of every *loaded* payload (lazy chunks count 0)."""
        total = 0
        for col in self.columns.values():
            if col._dictionary is not None:  # loaded dictionaries only
                total += sum(
                    len(str(s).encode()) + 8 for s in col._dictionary
                )
            for c in col.chunks:
                if not c.loaded:
                    continue
                p = c.payload()
                parts = p if isinstance(p, tuple) else (p,)
                for a in parts:
                    if a.dtype == object:
                        total += sum(len(str(s).encode()) + 8 for s in a)
                    else:
                        total += a.nbytes
        return total

    def __repr__(self) -> str:
        cols = ", ".join(
            f"{name}:{c.ctype}/{c.encoding}" for name, c in self.columns.items()
        )
        return (
            f"store.Table({self.nrows} rows x {self.n_chunks} chunks; {cols})"
        )


# ----------------------------------------------------------------------
# column construction
# ----------------------------------------------------------------------
def _normalize(arr: np.ndarray) -> Tuple[np.ndarray, str]:
    """Host array -> (physical int64/float64/object array, ctype)."""
    if np.issubdtype(arr.dtype, np.datetime64):
        return arr.astype("datetime64[D]").astype(np.int64), "date"
    if arr.dtype == np.bool_:
        return arr.astype(np.int64), "bool"
    if np.issubdtype(arr.dtype, np.integer):
        return arr.astype(np.int64), "int"
    if np.issubdtype(arr.dtype, np.floating):
        return arr.astype(np.float64), "float"
    if arr.dtype.kind in ("U", "S", "O"):
        arr = arr.astype(object)
        if any(not isinstance(x, str) for x in arr):
            # match the v1 tfb writer: object cells stringify (None ->
            # "None") — mixed None/str arrays would otherwise crash the
            # sort-based encoders
            arr = np.array([str(x) for x in arr], dtype=object)
        return arr, "str"
    raise TypeError(f"unsupported dtype {arr.dtype}")


def _factorize_sorted(values: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    dictionary, codes = np.unique(values, return_inverse=True)
    return codes.astype(np.int64).reshape(values.shape), dictionary


def _build_column(
    name: str,
    arr: np.ndarray,
    chunk_rows: int,
    policy: EncodingPolicy,
    forced: Optional[str],
    valid: Optional[np.ndarray] = None,
) -> Column:
    phys, ctype = _normalize(arr)
    encoding = forced if forced is not None else policy.choose(phys, ctype)
    if encoding == "dict" and ctype != "str":
        raise ValueError(f"column {name}: dict encoding is for strings")
    if encoding == "rle" and ctype in ("str", "float"):
        raise ValueError(f"column {name}: rle is for int/date/bool columns")

    dictionary = None
    stats_ctype = ctype
    if encoding == "dict":
        codes, dictionary = _factorize_sorted(phys)
        dictionary = intern_dictionary(dictionary)
        phys, stats_ctype = codes, "int"  # zone maps over codes

    chunks: List[Chunk] = []
    for lo in range(0, max(phys.shape[0], 1), chunk_rows):
        part = phys[lo: lo + chunk_rows]
        if part.shape[0] == 0 and phys.shape[0] != 0:
            break
        vpart = None
        if valid is not None:
            vpart = valid[lo: lo + chunk_rows]
            if bool(vpart.all()):
                vpart = None  # chunk without nulls: no bitmap
        stats = compute_stats(part, stats_ctype, vpart)
        if encoding == "rle":
            payload = _rle_encode(part)
        else:
            payload = part
        chunks.append(
            Chunk(part.shape[0], stats, payload=payload, validity=vpart)
        )
        if phys.shape[0] == 0:
            break
    return Column(name, ctype, encoding, chunks, dictionary)


def _rle_encode(part: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    n = part.shape[0]
    if n == 0:
        return part, np.zeros((0,), dtype=np.int64)
    starts = np.empty(n, dtype=bool)
    starts[0] = True
    np.not_equal(part[1:], part[:-1], out=starts[1:])
    idx = np.nonzero(starts)[0]
    values = part[idx]
    runs = np.diff(np.append(idx, n)).astype(np.int64)
    return values, runs
