"""Exporters: Chrome/Perfetto ``trace_event`` JSON and bench profiles.

``export_chrome_trace(path)`` writes the recorded spans as complete
("ph": "X") trace events — open the file at https://ui.perfetto.dev or
chrome://tracing to see per-thread operator timelines.

``export_json()`` returns the machine-readable profile the bench
runner attaches to every row: an operator-time breakdown aggregated
by span name (count, total/self milliseconds) plus a full metrics
snapshot.  Jax-free.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

from . import metrics as _metrics
from . import trace as _trace

__all__ = ["aggregate_operators", "export_chrome_trace", "export_json"]


def _span_args(attrs) -> Dict:
    if not attrs:
        return {}
    out = {}
    for k, v in attrs.items():
        if isinstance(v, (str, int, float, bool)) or v is None:
            out[k] = v
        else:
            out[k] = repr(v)
    return out


def export_chrome_trace(
    path: str, since_ns: Optional[int] = None
) -> int:
    """Write recorded spans as Chrome ``trace_event`` JSON; returns the
    number of events written."""
    records = _trace.spans(since_ns=since_ns)
    pid = os.getpid()
    events: List[Dict] = []
    names = {}
    for s in records:
        events.append(
            {
                "name": s.name,
                "cat": s.name.split(".", 1)[0],
                "ph": "X",
                "ts": s.start_ns / 1e3,  # microseconds
                "dur": s.dur_ns / 1e3,
                "pid": pid,
                "tid": s.tid,
                "args": _span_args(s.attrs),
            }
        )
        names.setdefault(s.tid, s.thread)
    # thread-name metadata rows make the Perfetto timeline readable
    for tid, tname in names.items():
        events.append(
            {
                "name": "thread_name",
                "ph": "M",
                "pid": pid,
                "tid": tid,
                "args": {"name": tname},
            }
        )
    doc = {"traceEvents": events, "displayTimeUnit": "ms"}
    with open(path, "w") as fh:
        json.dump(doc, fh)
        fh.write("\n")
    return len(events)


def aggregate_operators(records) -> Dict[str, Dict]:
    """Per span-name totals: ``{name: {count, total_ms, self_ms}}``.

    ``self_ms`` subtracts each span's direct children, so nested
    operator spans don't double-count toward a breakdown."""
    child_ns: Dict[int, int] = {}
    for s in records:
        if s.parent_id:
            child_ns[s.parent_id] = child_ns.get(s.parent_id, 0) + s.dur_ns
    out: Dict[str, Dict] = {}
    for s in records:
        rec = out.setdefault(
            s.name, {"count": 0, "total_ms": 0.0, "self_ms": 0.0}
        )
        rec["count"] += 1
        rec["total_ms"] += s.dur_ns / 1e6
        rec["self_ms"] += max(s.dur_ns - child_ns.get(s.span_id, 0), 0) / 1e6
    for rec in out.values():
        rec["total_ms"] = round(rec["total_ms"], 3)
        rec["self_ms"] = round(rec["self_ms"], 3)
    return out


def export_json(since_ns: Optional[int] = None) -> Dict:
    """Profile document: operator-time breakdown + metrics snapshot."""
    records = _trace.spans(since_ns=since_ns)
    return {
        "schema": "repro-obs/v1",
        "operators": aggregate_operators(records),
        "spans_recorded": len(records),
        "spans_dropped": _trace.dropped(),
        "metrics": _metrics.snapshot(),
    }
