"""Span tracer: nestable timed regions in per-thread ring buffers.

``span("join.direct_probe", rows=...)`` opens a context manager that
records name, monotonic wall-clock interval, thread, parent span, and
free-form attributes on exit.  Records land in a fixed-capacity ring
buffer owned by the writing thread — appends take no lock (only the
owner writes; readers snapshot under the GIL), so tracing from the
serve worker, prefetch threads, and client threads never contend.

Toggled by ``CONFIG.tracing``:

- ``"off"`` (default): ``span()`` returns one shared no-op context
  manager — a single branch, no allocation, no clock read;
- ``"on"``: operator-level spans record (plan nodes, joins, compile
  phases, serve batch phases, pipeline/spill events);
- ``"detailed"``: additionally records per-chunk spans
  (``detailed_span``): chunk decode, prefetch waits, per-chunk probes.

This module must import without jax (CI-enforced via ``repro.obs``);
``CONFIG`` is resolved lazily on first use, mirroring
``repro.store.spill``.
"""
from __future__ import annotations

import itertools
import threading
import time
from typing import Any, Dict, List, NamedTuple, Optional

__all__ = [
    "SpanRecord",
    "annotate",
    "clear",
    "current_span_id",
    "detailed",
    "detailed_span",
    "enabled",
    "span",
    "spans",
]

_CFG = None


def _cfg():
    global _CFG
    if _CFG is None:
        from repro.core.config import CONFIG  # lazy: keeps obs jax-free

        _CFG = CONFIG
    return _CFG


#: Per-thread ring capacity (spans).  A full ring overwrites its oldest
#: records and counts them in ``dropped``.
CAPACITY = 1 << 16

_IDS = itertools.count(1)  # next() is atomic under the GIL
_LOCK = threading.Lock()
_RINGS: List["_Ring"] = []


class SpanRecord(NamedTuple):
    name: str
    tid: int
    thread: str
    start_ns: int
    dur_ns: int
    span_id: int
    parent_id: int  # 0 = top-level
    attrs: Optional[Dict[str, Any]]


class _Ring:
    """Fixed-capacity overwrite-oldest buffer; single-writer."""

    __slots__ = ("buf", "cap", "i", "dropped", "tid", "thread")

    def __init__(self, cap: int, tid: int, thread: str) -> None:
        self.buf: List[SpanRecord] = []
        self.cap = cap
        self.i = 0
        self.dropped = 0
        self.tid = tid
        self.thread = thread

    def append(self, rec: SpanRecord) -> None:
        if len(self.buf) < self.cap:
            self.buf.append(rec)
        else:
            self.buf[self.i] = rec
            self.i = (self.i + 1) % self.cap
            self.dropped += 1


class _State(threading.local):
    """Per-thread open-span stack + ring, created on first span."""

    def __init__(self) -> None:
        t = threading.current_thread()
        self.stack: List["_Span"] = []
        self.ring = _Ring(CAPACITY, t.ident or 0, t.name)
        with _LOCK:
            _RINGS.append(self.ring)


_STATE = _State()


class _NoopSpan:
    """Shared disabled-mode span: every call is a no-op."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass

    def set(self, **attrs) -> None:
        pass

    span_id = 0


_NOOP = _NoopSpan()


class _Span:
    __slots__ = ("name", "attrs", "t0", "span_id", "parent_id")

    def __init__(self, name: str, attrs: Optional[Dict[str, Any]]) -> None:
        self.name = name
        self.attrs = attrs or None

    def set(self, **attrs) -> None:
        if self.attrs is None:
            self.attrs = attrs
        else:
            self.attrs.update(attrs)

    def __enter__(self) -> "_Span":
        st = _STATE
        self.parent_id = st.stack[-1].span_id if st.stack else 0
        self.span_id = next(_IDS)
        st.stack.append(self)
        self.t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> None:
        t1 = time.perf_counter_ns()
        st = _STATE
        if st.stack and st.stack[-1] is self:
            st.stack.pop()
        else:  # mis-nested exit (should not happen); drop gracefully
            try:
                st.stack.remove(self)
            except ValueError:
                pass
        st.ring.append(
            SpanRecord(
                self.name,
                st.ring.tid,
                st.ring.thread,
                self.t0,
                t1 - self.t0,
                self.span_id,
                self.parent_id,
                self.attrs,
            )
        )


def enabled() -> bool:
    return _cfg().tracing != "off"


def detailed() -> bool:
    return _cfg().tracing == "detailed"


def span(name: str, **attrs):
    """Open a traced region; ``with obs.span("x", rows=n) as sp: ...``.

    Disabled mode returns one shared no-op object (no allocation)."""
    if _cfg().tracing == "off":
        return _NOOP
    return _Span(name, attrs)


def detailed_span(name: str, **attrs):
    """A span recorded only under ``CONFIG.tracing = "detailed"``
    (per-chunk events that would dominate the ring at scale)."""
    if _cfg().tracing != "detailed":
        return _NOOP
    return _Span(name, attrs)


def annotate(**attrs) -> None:
    """Attach attributes to the innermost open span of this thread
    (no-op when tracing is off or no span is open).  Lets deep engine
    code report decisions — e.g. the chosen join algorithm — without
    plumbing a handle through every call."""
    st = _STATE
    if st.stack:
        st.stack[-1].set(**attrs)


def current_span_id() -> int:
    st = _STATE
    return st.stack[-1].span_id if st.stack else 0


def spans(since_ns: Optional[int] = None) -> List[SpanRecord]:
    """Snapshot every thread's recorded spans, oldest first."""
    with _LOCK:
        rings = list(_RINGS)
    out: List[SpanRecord] = []
    for r in rings:
        out.extend(r.buf)  # GIL-atomic enough: records are immutable
    if since_ns is not None:
        out = [s for s in out if s.start_ns >= since_ns]
    out.sort(key=lambda s: s.start_ns)
    return out


def dropped() -> int:
    with _LOCK:
        return sum(r.dropped for r in _RINGS)


def clear() -> None:
    """Drop all recorded spans (open spans on other threads may lose
    their record — tracing is best-effort by design)."""
    with _LOCK:
        for r in _RINGS:
            r.buf = []
            r.i = 0
            r.dropped = 0


def mark_ns() -> int:
    """A monotonic timestamp usable as ``spans(since_ns=...)`` floor."""
    return time.perf_counter_ns()
