"""Central metrics registry: one namespace over every engine counter.

The engine grew five ad-hoc STATS objects (``core.join.STATS``,
``sql.compile.STATS``, ``core.pipeline.STATS``, ``serve.STATS``, and
the store's spill/pool counters).  They all stay where they are — the
old names keep working — but each module registers itself here at
import time, so ``obs.metrics.snapshot()`` reads every layer through
one interface and ``reset()`` clears them all (the per-test isolation
fixture in ``tests/conftest.py`` relies on this).

Native instruments (``counter``/``gauge``/``histogram``) cover new
instrumentation that has no legacy dict; they appear in snapshots under
the ``"obs"`` group.  ``diff(before, after)`` subtracts two snapshots
leaf-wise (numeric leaves only) — the bench runner attaches these
deltas to every row.

This module is a *namespace*, not a class: ``obs.metrics.snapshot()``
etc. delegate to one process-wide ``Registry``.  Thread-safe: group
snapshot/reset functions are called under the registry lock, and the
legacy objects guard their own mutation.  Must import without jax.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Callable, Dict, Optional

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Registry",
    "counter",
    "diff",
    "gauge",
    "groups",
    "histogram",
    "load_engine_groups",
    "register_group",
    "reset",
    "snapshot",
]


class Counter:
    """Monotonic (between resets) thread-safe counter."""

    __slots__ = ("_lock", "_v")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._v = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._v += n

    @property
    def value(self) -> int:
        return self._v

    def reset(self) -> None:
        with self._lock:
            self._v = 0

    def snapshot(self):
        return self._v


class Gauge:
    """Last-write-wins value."""

    __slots__ = ("_v",)

    def __init__(self) -> None:
        self._v = 0.0

    def set(self, v: float) -> None:
        self._v = v

    @property
    def value(self) -> float:
        return self._v

    def reset(self) -> None:
        self._v = 0.0

    def snapshot(self):
        return self._v


_RESERVOIR = 4096


class Histogram:
    """count/sum/min/max plus a bounded recent-biased reservoir for
    percentiles (same halving policy as ``serve.ServeStats``)."""

    __slots__ = ("_lock", "count", "total", "vmin", "vmax", "_res")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")
        self._res: list = []

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.total += v
            if v < self.vmin:
                self.vmin = v
            if v > self.vmax:
                self.vmax = v
            if len(self._res) >= _RESERVOIR:
                del self._res[: _RESERVOIR // 2]
            self._res.append(v)

    def percentile(self, p: float) -> float:
        with self._lock:
            res = sorted(self._res)
        if not res:
            return 0.0
        i = min(len(res) - 1, int(p * (len(res) - 1) + 0.5))
        return res[i]

    def reset(self) -> None:
        with self._lock:
            self.count = 0
            self.total = 0.0
            self.vmin = float("inf")
            self.vmax = float("-inf")
            self._res = []

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            n, total = self.count, self.total
            vmin, vmax = self.vmin, self.vmax
        if n == 0:
            return {"count": 0, "sum": 0.0}
        return {
            "count": n,
            "sum": total,
            "min": vmin,
            "max": vmax,
            "p50": self.percentile(0.50),
            "p95": self.percentile(0.95),
            "p99": self.percentile(0.99),
        }


class Registry:
    """Named groups of metrics; each group snapshots/resets as a unit."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._groups: "OrderedDict[str, tuple]" = OrderedDict()
        self._own: "OrderedDict[str, object]" = OrderedDict()

    # -- legacy/group registration --------------------------------------
    def register_group(
        self,
        name: str,
        snapshot: Callable[[], Dict],
        reset: Optional[Callable[[], None]] = None,
    ) -> None:
        """Expose an existing stats object through the registry.
        Re-registration replaces (modules may be reloaded in tests)."""
        with self._lock:
            self._groups[name] = (snapshot, reset)

    # -- native instruments ---------------------------------------------
    def _instrument(self, name: str, cls):
        with self._lock:
            inst = self._own.get(name)
            if inst is None:
                inst = self._own[name] = cls()
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(inst).__name__}"
                )
        return inst

    def counter(self, name: str) -> Counter:
        return self._instrument(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._instrument(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._instrument(name, Histogram)

    # -- snapshot / reset / diff ----------------------------------------
    def groups(self) -> list:
        with self._lock:
            return list(self._groups)

    def snapshot(self) -> Dict[str, Dict]:
        """``{group: {key: value}}`` over every registered group plus
        native instruments (group ``"obs"``)."""
        with self._lock:
            items = list(self._groups.items())
            own = list(self._own.items())
        out: Dict[str, Dict] = {}
        for name, (snap, _) in items:
            try:
                out[name] = snap()
            except Exception as e:  # a broken group must not hide the rest
                out[name] = {"__error__": f"{type(e).__name__}: {e}"}
        if own:
            out["obs"] = {n: inst.snapshot() for n, inst in own}
        return out

    def reset(self) -> None:
        with self._lock:
            items = list(self._groups.items())
            own = list(self._own.values())
        for _, (_, rst) in items:
            if rst is not None:
                rst()
        for inst in own:
            inst.reset()

    @staticmethod
    def diff(before: Dict, after: Dict) -> Dict:
        """Leaf-wise ``after - before`` over numeric leaves (recursing
        into nested dicts); non-numeric leaves are dropped, keys only in
        ``after`` count from zero."""
        out: Dict = {}
        for k, av in after.items():
            bv = before.get(k)
            if isinstance(av, dict):
                sub = Registry.diff(bv if isinstance(bv, dict) else {}, av)
                if sub:
                    out[k] = sub
            elif isinstance(av, bool):
                continue
            elif isinstance(av, (int, float)):
                b = bv if isinstance(bv, (int, float)) and not isinstance(bv, bool) else 0
                d = av - b
                if d:
                    out[k] = d
        return out


#: The process-wide registry behind the module-level functions.
REGISTRY = Registry()

register_group = REGISTRY.register_group
counter = REGISTRY.counter
gauge = REGISTRY.gauge
histogram = REGISTRY.histogram
snapshot = REGISTRY.snapshot
reset = REGISTRY.reset
groups = REGISTRY.groups
diff = Registry.diff


def load_engine_groups() -> list:
    """Import every engine layer that self-registers a metrics group
    (pulls jax — callers wanting the full engine view opt in; a bare
    ``import repro.obs`` stays jax-free).  Returns the group names."""
    import repro.core.join  # noqa: F401
    import repro.core.pipeline  # noqa: F401
    import repro.sql.compile  # noqa: F401
    import repro.serve.stats  # noqa: F401
    import repro.store  # noqa: F401  (pool + spill)
    import repro.resilience  # noqa: F401  (faults + retries)

    return groups()
