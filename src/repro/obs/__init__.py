"""Unified engine observability (ISSUE 9).

Three pieces behind one namespace:

- **Span tracing** (``obs.span`` / ``obs.detailed_span``): nestable
  timed regions in lock-free per-thread ring buffers, toggled by
  ``CONFIG.tracing = "off" | "on" | "detailed"``.  Disabled mode is one
  branch and no allocation.
- **Metrics** (``obs.metrics``): a central registry absorbing every
  layer's STATS object (``core.join`` / ``sql.compile`` /
  ``core.pipeline`` / ``serve`` / ``store.pool`` + ``store.spill`` —
  the legacy names stay valid aliases) plus native counters/gauges/
  histograms, with ``snapshot()`` / ``reset()`` / ``diff()``.
- **Exporters**: ``obs.export_chrome_trace(path)`` (open in Perfetto /
  chrome://tracing) and ``obs.export_json()`` (operator-time breakdown
  + metrics snapshot; the bench runner attaches it to every row).

``EXPLAIN ANALYZE`` (``repro.sql.execute(..., explain="analyze")``)
builds on the tracer: see ``repro.sql.analyze``.

Import-time constraint (CI-enforced): ``import repro.obs`` must not
initialize jax — engine layers register their metrics groups when they
import, and ``obs.metrics.load_engine_groups()`` pulls them all in
explicitly.
"""
from . import metrics  # noqa: F401  (module-as-namespace)
from .export import aggregate_operators, export_chrome_trace, export_json
from .trace import (
    SpanRecord,
    annotate,
    clear as clear_trace,
    current_span_id,
    detailed,
    detailed_span,
    dropped,
    enabled,
    mark_ns,
    span,
    spans,
)

__all__ = [
    "SpanRecord",
    "aggregate_operators",
    "annotate",
    "clear_trace",
    "current_span_id",
    "detailed",
    "detailed_span",
    "dropped",
    "enabled",
    "export_chrome_trace",
    "export_json",
    "mark_ns",
    "metrics",
    "span",
    "spans",
]
