"""Serving observability: lock-guarded counters + latency reservoir.

One process-wide ``STATS`` object mirrors ``repro.sql.compile.STATS``
for the serving tier: how many queries were admitted, how they were
grouped into micro-batches, how often batching paid off (shared store
scans, coalesced duplicates, compiled-plan cache hits), and end-to-end
latency percentiles from a bounded reservoir.  Every mutation happens
under one lock — the admission worker and arbitrary client threads
both write here.
"""
from __future__ import annotations

import threading
from typing import Dict, List

__all__ = ["STATS", "ServeStats"]

_RESERVOIR = 4096


def _fresh() -> Dict[str, int]:
    return {
        "admitted": 0,  # queries accepted into the queue
        "batches": 0,  # micro-batches executed
        "batched_queries": 0,  # queries that shared a batch with >=1 other
        "shared_scan_groups": 0,  # store-scan groups answered by one pass
        "shared_scan_queries": 0,  # queries that rode a shared scan
        "shared_scan_errors": 0,  # shared passes that fell back per-query
        "plan_cache_hits": 0,  # compiled-plan cache hits during serving
        "coalesced": 0,  # duplicate queries answered by one execution
        "prepared": 0,  # executions through a Prepared statement
        "udf_queries": 0,  # executions under a non-empty UDF registry
        "shed_requests": 0,  # requests resolved without executing
        "worker_restarts": 0,  # admission workers found dead + restarted
    }


#: Per-query latency decomposition recorded by the executor: time in
#: the admission queue, batch planning, compiled-path trace+compile,
#: and engine execution.
PHASES = ("queue", "plan", "compile", "execute")


def _quantiles(lat: List[float], points: Dict[str, float]) -> Dict[str, float]:
    lat = sorted(lat)
    if not lat:
        return {name: 0.0 for name in points}

    def q(p: float) -> float:
        i = min(len(lat) - 1, int(p * (len(lat) - 1) + 0.5))
        return lat[i] * 1e3

    return {name: q(p) for name, p in points.items()}


class ServeStats:
    """Counters + latency reservoir for the serving layer."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counts = _fresh()
        self._errors: Dict[str, int] = {}  # QueryError code -> count
        self._shed: Dict[str, int] = {}  # shed reason -> count
        self._lat: List[float] = []  # seconds, bounded reservoir
        self._phase: Dict[str, List[float]] = {p: [] for p in PHASES}

    def bump(self, **deltas: int) -> None:
        with self._lock:
            for k, d in deltas.items():
                self._counts[k] += d

    def bump_error(self, code: str, n: int = 1) -> None:
        """Count ``n`` queries resolved with a typed error of ``code``
        (``repro.resilience.errors`` class tags)."""
        with self._lock:
            self._errors[code] = self._errors.get(code, 0) + n

    def bump_shed(self, reason: str, n: int = 1) -> None:
        """Count ``n`` requests shed without executing (deadline passed
        in queue, queue overflow, cancel, shutdown)."""
        with self._lock:
            self._counts["shed_requests"] += n
            self._shed[reason] = self._shed.get(reason, 0) + n

    @staticmethod
    def _push(lat: List[float], seconds: float) -> None:
        if len(lat) >= _RESERVOIR:
            # drop the oldest half; percentiles stay recent-biased
            del lat[: _RESERVOIR // 2]
        lat.append(float(seconds))

    def record_latency(self, seconds: float) -> None:
        with self._lock:
            self._push(self._lat, seconds)

    def record_phase(self, phase: str, seconds: float) -> None:
        """Record one query's time in an admission/execution phase
        (``PHASES``); each phase keeps its own percentile reservoir."""
        with self._lock:
            self._push(self._phase[phase], seconds)

    def percentiles(self) -> Dict[str, float]:
        """p50/p90/p95/p99 end-to-end latency in milliseconds."""
        with self._lock:
            lat = list(self._lat)
        return _quantiles(
            lat, {"p50_ms": 0.50, "p90_ms": 0.90, "p95_ms": 0.95,
                  "p99_ms": 0.99}
        )

    def phase_percentiles(self) -> Dict[str, Dict[str, float]]:
        """Per-phase p50/p95/p99 (ms): where a served query's latency
        goes — queueing, planning, compiling, or executing."""
        with self._lock:
            phases = {p: list(lat) for p, lat in self._phase.items()}
        return {
            p: dict(
                _quantiles(
                    lat, {"p50_ms": 0.50, "p95_ms": 0.95, "p99_ms": 0.99}
                ),
                count=len(lat),
            )
            for p, lat in phases.items()
        }

    def snapshot(self) -> Dict:
        with self._lock:
            out = dict(self._counts)
            out["errors"] = dict(self._errors)
            out["errors_total"] = sum(self._errors.values())
            out["shed"] = dict(self._shed)
            n = len(self._lat)
        out["latencies_recorded"] = n
        out.update(self.percentiles())
        out["phases"] = self.phase_percentiles()
        return out

    def reset(self) -> None:
        with self._lock:
            self._counts = _fresh()
            self._errors = {}
            self._shed = {}
            self._lat = []
            self._phase = {p: [] for p in PHASES}

    def __getitem__(self, key: str) -> int:
        with self._lock:
            if key == "errors":  # legacy alias: total across classes
                return sum(self._errors.values())
            return self._counts[key]


STATS = ServeStats()

from repro import obs as _obs  # noqa: E402

_obs.metrics.register_group("serve", STATS.snapshot, STATS.reset)
