"""Batched serving engine: prefill + decode with a slot-based
continuous-batching scheduler, plus a prepared-statement surface for
serving repeated parameterized SQL queries off the whole-plan compile
cache (``repro.sql.compile``).

Requests join a fixed pool of batch slots; finished/empty slots are
refilled between decode steps (the static-shape TPU idiom for
continuous batching — the decode step itself never recompiles).
The same static-shape idiom powers ``PreparedStatement``: the first
execution traces and compiles one XLA program for the query's plan
shape, and every later execution with different literal parameters is
a plan-cache hit — zero retraces, one device launch per query.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, batch_slots: int = 4, max_len: int = 128):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.state = lm.init_decode_state(cfg, batch_slots, max_len)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.slot_pos = np.zeros(batch_slots, dtype=np.int64)
        self.slot_start = np.zeros(batch_slots, dtype=np.int32)  # cache window start
        self._decode = jax.jit(lambda p, s, b: lm.decode_step(cfg, p, s, b))
        self.steps = 0

    # Slots advance in lockstep on a shared cache position; each slot
    # carries a kv_start window so a refilled slot never attends the
    # previous occupant's cache prefix (continuous batching).
    def add_request(self, req: Request) -> bool:
        for i, r in enumerate(self.slot_req):
            if r is None:
                self.slot_req[i] = req
                self.slot_pos[i] = 0
                self.slot_start[i] = int(jax.device_get(self.state["pos"]))
                return True
        return False

    def _next_tokens(self) -> np.ndarray:
        toks = np.zeros((self.slots, 1), dtype=np.int32)
        for i, r in enumerate(self.slot_req):
            if r is None:
                continue
            p = self.slot_pos[i]
            if p < len(r.prompt):
                toks[i, 0] = r.prompt[p]
            elif r.out:
                toks[i, 0] = r.out[-1]
        return toks

    def step(self, greedy: bool = True):
        toks = self._next_tokens()
        logits, self.state = self._decode(
            self.params,
            self.state,
            {"tokens": jnp.asarray(toks), "kv_start": jnp.asarray(self.slot_start)},
        )
        self.steps += 1
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i, r in enumerate(self.slot_req):
            if r is None:
                continue
            self.slot_pos[i] += 1
            if self.slot_pos[i] >= len(r.prompt):
                r.out.append(int(nxt[i]))
                if len(r.out) >= r.max_new or self.slot_pos[i] >= self.max_len - 1:
                    r.done = True
                    self.slot_req[i] = None  # free the slot (continuous batching)

    def run(self, requests: List[Request], max_steps: int = 1000) -> List[Request]:
        pending = list(requests)
        done: List[Request] = []
        while (pending or any(self.slot_req)) and self.steps < max_steps:
            while pending and self.add_request(pending[0]):
                pending.pop(0)
            self.step()
            done += [r for r in requests if r.done and r not in done]
        return requests


class PreparedStatement:
    """A parameterized SQL query served off the whole-plan compile cache.

    ``template`` is SQL text with ``{name}`` placeholders for *numeric
    or date literals* (the parameters a serving tier varies per
    request).  Each ``execute(**params)`` formats and re-plans the text
    — cheap host work — and dispatches through ``sql.execute``; because
    the compiled-plan cache keys on the plan's *structure* with
    literals abstracted into parameter slots, every execution after the
    first reuses one compiled XLA executable regardless of the literal
    values.  Under ``CONFIG.compiled = 'off'`` (or for untraceable
    plans) this degrades transparently to op-by-op dispatch.

        ps = PreparedStatement(
            "SELECT ... WHERE l_quantity < {qty}", frames)
        out = ps.execute(qty=24)   # traces + compiles once
        out = ps.execute(qty=25)   # cache hit, zero retraces
    """

    def __init__(self, template: str, scope: Dict):
        from repro.sql.lower import scope_frames

        self.template = template
        self.frames = scope_frames(scope)
        self.calls = 0

    def execute(self, **params):
        from repro import sql

        self.calls += 1
        return sql.execute(self.template.format(**params), self.frames)
