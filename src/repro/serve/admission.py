"""Admission control: a queue that forms micro-batches of SQL requests.

Clients (any thread) submit work and get a ``concurrent.futures.Future``
back.  A single worker drains the queue, waits out a short straggler
window (``CONFIG.serve_batch_window_ms``) so concurrent submitters land
in the same batch, caps the batch at ``CONFIG.serve_max_batch``, and
hands the whole group to the executor's batch runner.  One worker
serializes engine entry — the jax dispatch path is protected by the
GIL anyway — so the concurrency win comes from *work sharing across
the batch* (shared store scans, coalesced duplicates, compiled-plan
cache adjacency), not from parallel kernels.

``auto_start=False`` keeps the worker off so tests can stage a precise
set of requests and run exactly one batch with ``drain_once()``.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from typing import Callable, List, Optional

from repro.core.config import CONFIG

__all__ = ["AdmissionQueue"]


class _Closed:
    pass


_CLOSED = _Closed()


class AdmissionQueue:
    """Single-worker micro-batching queue.

    ``run_batch(requests)`` receives the drained list and must resolve
    every request's future (it gets the full objects the executor
    enqueued; this class only groups and times them).
    """

    def __init__(
        self,
        run_batch: Callable[[List], None],
        *,
        auto_start: bool = True,
        name: str = "repro-serve",
    ) -> None:
        self._run_batch = run_batch
        self._q: "queue.Queue" = queue.Queue()
        self._closed = False
        self._lock = threading.Lock()
        self._worker: Optional[threading.Thread] = None
        if auto_start:
            self.start(name=name)

    # -- client side ----------------------------------------------------
    def submit(self, request) -> Future:
        """Enqueue ``request`` (must carry a ``future`` attribute)."""
        with self._lock:
            if self._closed:
                raise RuntimeError("admission queue is closed")
            self._q.put(request)
        from .stats import STATS

        STATS.bump(admitted=1)
        return request.future

    # -- worker side ----------------------------------------------------
    def _drain(self, block: bool) -> List:
        """Pull one micro-batch: first item (optionally blocking), then
        whatever lands inside the straggler window, up to the cap."""
        from repro import obs

        batch: List = []
        try:
            first = self._q.get(block=block, timeout=0.2 if block else None)
        except queue.Empty:
            return batch
        if first is _CLOSED:
            raise StopIteration
        batch.append(first)
        cap = max(1, int(CONFIG.serve_max_batch))
        # the straggler wait is deliberate batching latency, not work —
        # its own span keeps it distinguishable in traces
        with obs.span("serve.batch_assembly") as sp:
            deadline = time.monotonic() + CONFIG.serve_batch_window_ms / 1e3
            while len(batch) < cap:
                remaining = deadline - time.monotonic()
                try:
                    item = self._q.get(
                        block=remaining > 0, timeout=max(remaining, 0) or None
                    )
                except queue.Empty:
                    break
                if item is _CLOSED:
                    self._q.put(_CLOSED)  # leave the sentinel for the loop
                    break
                batch.append(item)
            sp.set(batch=len(batch))
        return batch

    def drain_once(self) -> int:
        """Synchronously run one micro-batch from whatever is queued.
        Test/bench hook (requires ``auto_start=False``).  Returns the
        batch size."""
        batch = self._drain(block=False)
        if batch:
            self._run_batch(batch)
        return len(batch)

    def _loop(self) -> None:
        while True:
            try:
                batch = self._drain(block=True)
            except StopIteration:
                return
            if batch:
                self._run_batch(batch)

    def start(self, name: str = "repro-serve") -> None:
        with self._lock:
            if self._worker is not None or self._closed:
                return
            self._worker = threading.Thread(
                target=self._loop, name=name, daemon=True
            )
            self._worker.start()

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            worker = self._worker
        self._q.put(_CLOSED)
        if worker is not None:
            worker.join(timeout=30)
        # fail anything that raced past the closed check
        while True:
            try:
                item = self._q.get_nowait()
            except queue.Empty:
                break
            if item is not _CLOSED and not item.future.done():
                item.future.set_exception(
                    RuntimeError("admission queue closed")
                )
