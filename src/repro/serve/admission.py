"""Admission control: a bounded queue forming micro-batches of requests.

Clients (any thread) submit work and get a ``concurrent.futures.Future``
back.  A single worker drains the queue, waits out a short straggler
window (``CONFIG.serve_batch_window_ms``) so concurrent submitters land
in the same batch, caps the batch at ``CONFIG.serve_max_batch``, and
hands the whole group to the executor's batch runner.  One worker
serializes engine entry — the jax dispatch path is protected by the
GIL anyway — so the concurrency win comes from *work sharing across
the batch* (shared store scans, coalesced duplicates, compiled-plan
cache adjacency), not from parallel kernels.

Resilience (ISSUE 10):

- **bounded depth** — ``CONFIG.serve_queue_depth`` caps queued
  requests; overflow applies ``CONFIG.serve_shed_policy``:
  ``'reject-new'`` raises ``ResourceExhausted`` at the submitter,
  ``'drop-oldest'`` sheds the queue head (its future resolves with
  ``ResourceExhausted``) to admit the newcomer;
- **worker-crash recovery** — a worker thread found dead at submit
  time is restarted (``serve.STATS['worker_restarts']``); requests
  still queued when it died are simply drained by the replacement.  A
  batch-runner exception never kills the worker: unresolved futures in
  the failed batch get a classified error and the loop continues;
- **typed shutdown** — ``close()`` joins the worker, then resolves
  every still-pending request with ``QueryCancelled`` instead of
  dropping its future (the pre-ISSUE-10 bug: ``join(timeout=30)``
  could return with requests queued and futures that never fired).

Requests must carry ``future`` and a ``fail(exc, shed_reason=)``
callable (the executor's ``_Request`` provides both; ``fail`` keeps
the executor's in-flight registry and error counters coherent).

``auto_start=False`` keeps the worker off so tests can stage a precise
set of requests and run exactly one batch with ``drain_once()``.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from typing import Callable, List, Optional

from repro.core.config import CONFIG
from repro.resilience import QueryCancelled, ResourceExhausted, classify

__all__ = ["AdmissionQueue"]


class _Closed:
    pass


_CLOSED = _Closed()


def _fail(item, exc, shed_reason: Optional[str] = None) -> None:
    """Resolve a request with ``exc`` through its own bookkeeping hook
    when it has one, its bare future otherwise."""
    fail = getattr(item, "fail", None)
    if fail is not None:
        fail(exc, shed_reason=shed_reason)
    elif not item.future.done():
        item.future.set_exception(exc)


class AdmissionQueue:
    """Single-worker micro-batching queue with admission control.

    ``run_batch(requests)`` receives the drained list and must resolve
    every request's future (it gets the full objects the executor
    enqueued; this class only bounds, groups and times them).
    """

    def __init__(
        self,
        run_batch: Callable[[List], None],
        *,
        auto_start: bool = True,
        name: str = "repro-serve",
    ) -> None:
        self._run_batch = run_batch
        self._q: "queue.Queue" = queue.Queue()
        self._closed = False
        self._lock = threading.Lock()
        self._name = name
        self._worker: Optional[threading.Thread] = None
        if auto_start:
            self.start(name=name)

    # -- client side ----------------------------------------------------
    def submit(self, request) -> Future:
        """Enqueue ``request`` (must carry ``future``/``fail``).

        Raises ``QueryCancelled`` after ``close()`` and
        ``ResourceExhausted`` when the queue is full under the
        ``reject-new`` policy.
        """
        from .stats import STATS

        dropped = None
        with self._lock:
            if self._closed:
                raise QueryCancelled("admission queue is closed")
            self._restart_if_dead()
            depth = CONFIG.serve_queue_depth
            if depth is not None and self._q.qsize() >= int(depth):
                if CONFIG.serve_shed_policy == "drop-oldest":
                    try:
                        dropped = self._q.get_nowait()
                    except queue.Empty:
                        dropped = None
                    if dropped is _CLOSED:  # never shed the sentinel
                        self._q.put(_CLOSED)
                        dropped = None
                else:  # reject-new
                    raise ResourceExhausted(
                        f"admission queue full (depth={depth}, "
                        f"policy=reject-new)"
                    )
            self._q.put(request)
        if dropped is not None:
            _fail(
                dropped,
                ResourceExhausted(
                    "shed by drop-oldest admission policy"
                ),
                shed_reason="queue_full",
            )
        STATS.bump(admitted=1)
        return request.future

    def _restart_if_dead(self) -> None:
        """Under ``self._lock``: revive a worker thread that died (a
        non-Exception escape like SystemExit).  The queue object — and
        thus every still-queued request — survives the old thread, so
        the replacement simply resumes draining them."""
        w = self._worker
        if w is None or w.is_alive() or self._closed:
            return
        from .stats import STATS

        STATS.bump(worker_restarts=1)
        self._worker = threading.Thread(
            target=self._loop, name=self._name, daemon=True
        )
        self._worker.start()

    # -- worker side ----------------------------------------------------
    def _drain(self, block: bool) -> List:
        """Pull one micro-batch: first item (optionally blocking), then
        whatever lands inside the straggler window, up to the cap."""
        from repro import obs

        batch: List = []
        try:
            first = self._q.get(block=block, timeout=0.2 if block else None)
        except queue.Empty:
            return batch
        if first is _CLOSED:
            raise StopIteration
        batch.append(first)
        cap = max(1, int(CONFIG.serve_max_batch))
        # the straggler wait is deliberate batching latency, not work —
        # its own span keeps it distinguishable in traces
        with obs.span("serve.batch_assembly") as sp:
            deadline = time.monotonic() + CONFIG.serve_batch_window_ms / 1e3
            while len(batch) < cap:
                remaining = deadline - time.monotonic()
                try:
                    item = self._q.get(
                        block=remaining > 0, timeout=max(remaining, 0) or None
                    )
                except queue.Empty:
                    break
                if item is _CLOSED:
                    self._q.put(_CLOSED)  # leave the sentinel for the loop
                    break
                batch.append(item)
            sp.set(batch=len(batch))
        return batch

    def drain_once(self) -> int:
        """Synchronously run one micro-batch from whatever is queued.
        Test/bench hook (requires ``auto_start=False``).  Returns the
        batch size."""
        batch = self._drain(block=False)
        if batch:
            self._run_batch(batch)
        return len(batch)

    def _loop(self) -> None:
        while True:
            try:
                batch = self._drain(block=True)
            except StopIteration:
                return
            if not batch:
                continue
            try:
                self._run_batch(batch)
            except BaseException as e:
                # the batch runner resolves futures itself; anything
                # escaping it is a harness bug or an injected crash —
                # fail what it left unresolved so no caller hangs
                err = classify(e)
                for item in batch:
                    if not item.future.done():
                        _fail(item, err)
                if not isinstance(e, Exception):
                    raise  # SystemExit/KeyboardInterrupt: thread dies,
                    # _restart_if_dead revives it on the next submit

    def start(self, name: str = "repro-serve") -> None:
        with self._lock:
            if self._worker is not None or self._closed:
                return
            self._name = name
            self._worker = threading.Thread(
                target=self._loop, name=name, daemon=True
            )
            self._worker.start()

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            worker = self._worker
        self._q.put(_CLOSED)
        if worker is not None:
            worker.join(timeout=30)
        # drain everything still queued — racers past the closed check
        # AND requests a wedged/dead worker never got to — with a typed
        # cancellation instead of silently dropping their futures
        while True:
            try:
                item = self._q.get_nowait()
            except queue.Empty:
                break
            if item is not _CLOSED and not item.future.done():
                _fail(
                    item,
                    QueryCancelled("executor closed with request pending"),
                    shed_reason="closed",
                )
