"""Concurrent SQL serving: sessions, prepared statements, micro-batches.

``Executor`` is the framequery-style entry point: a scope of tables
(TensorFrames, ``repro.store`` chunked tables, or raw dict-of-numpy),
``execute``/``submit`` for queries, ``add_function`` for scalar UDFs
(lowered through ``jax.vmap``), ``prepare`` for parameterized
statements, and ``session()`` for isolated per-client UDF registries
over the shared scope.

All execution funnels through one ``AdmissionQueue`` worker.  Queries
submitted concurrently land in the same micro-batch and share work:

- **shared store scans** — every store-backed Scan in the batch is
  grouped by ``(table, columns, predicates)``; each table with two or
  more participating scans is answered by *one*
  ``store.shared_scan`` pass (chunk decodes and predicate row-masks
  computed once), and the per-query plans consume the pre-materialized
  frames through ``lower_plan``'s scan cache;
- **coalescing** — textually identical queries under the same UDF
  environment execute once and share the result frame;
- **plan-cache adjacency** — batch members are dispatched grouped by
  parameterized plan shape, so prepared-statement traffic with varying
  literals runs as consecutive zero-retrace compiled-cache hits.

Resilience (ISSUE 10, ``repro.resilience``):

- every failure reaching a caller is a typed ``QueryError`` —
  ``PlanError`` for parse/plan rejections, ``QueryTimeout`` /
  ``QueryCancelled`` / ``ResourceExhausted`` for policy, classified
  ``ExecutionError``/``TransientIOError`` otherwise — counted
  per-class in ``serve.STATS.snapshot()["errors"]``;
- ``submit``/``execute`` take ``timeout_s`` (default
  ``CONFIG.serve_default_timeout_s``); the deadline is enforced at
  admission dequeue (an expired-in-queue request is shed, not
  executed) and cooperatively at operator/chunk checkpoints during
  execution.  ``cancel(request_id)`` — the id rides on the returned
  future — aborts a queued request immediately and an executing one at
  its next checkpoint.  Coalesced groups execute under the *loosest*
  member deadline and only abort when every member is cancelled;
  members cancelled mid-flight get ``QueryCancelled`` at resolution;
- per-session in-flight caps (``CONFIG.serve_session_inflight``)
  reject floods with ``ResourceExhausted`` before they queue.

Results come back through ``concurrent.futures.Future``; ``execute``
is ``submit().result()``.  ``serve.STATS`` counts what the batcher
actually did.
"""
from __future__ import annotations

import itertools
import threading
import time
from concurrent.futures import Future
from typing import Callable, Dict, List, Optional

from repro.core.config import CONFIG
from repro.resilience import (
    QueryCancelled,
    QueryTimeout,
    ResourceExhausted,
    classify,
    deadline_scope,
)
from repro.resilience.deadline import CancelToken

from .admission import AdmissionQueue
from .stats import STATS

__all__ = ["Executor", "Prepared", "Session"]

_REQUEST_IDS = itertools.count(1)


class _Request:
    __slots__ = (
        "text",
        "udfs",
        "prepared",
        "future",
        "t_submit",
        "plan",
        "scan_keys",
        "shape_key",
        "request_id",
        "expires_at",
        "token",
        "session_key",
        "_owner",
    )

    def __init__(
        self,
        owner: "Executor",
        text: str,
        udfs: Dict,
        prepared: bool,
        timeout_s: Optional[float],
        session_key: Optional[int],
    ) -> None:
        self.text = text
        self.udfs = udfs
        self.prepared = prepared
        self.future: Future = Future()
        self.t_submit = time.perf_counter()
        self.plan = None
        self.scan_keys: List[tuple] = []
        self.shape_key = text
        self.request_id = next(_REQUEST_IDS)
        self.future.request_id = self.request_id
        if timeout_s is None:
            timeout_s = CONFIG.serve_default_timeout_s
        self.expires_at = (
            None if timeout_s is None else time.monotonic() + float(timeout_s)
        )
        self.token = CancelToken()
        self.session_key = session_key
        self._owner = owner

    @property
    def expired(self) -> bool:
        return (
            self.expires_at is not None
            and time.monotonic() > self.expires_at
        )

    # bookkeeping hooks (the admission queue calls ``fail`` for sheds)
    def fail(self, exc, shed_reason: Optional[str] = None) -> None:
        self._owner._fail(self, exc, shed_reason=shed_reason)

    def finish(self, out) -> None:
        self._owner._resolve(self, out)


class _GroupToken:
    """Cancel view of a coalesced group: cancelled only when *every*
    member asked to cancel — one client must not kill a result other
    members still want."""

    __slots__ = ("_tokens",)

    def __init__(self, tokens: List[CancelToken]) -> None:
        self._tokens = tokens

    @property
    def cancelled(self) -> bool:
        return all(t.cancelled for t in self._tokens)


class Prepared:
    """A parameterized statement bound to an executor or session.

    ``{name}`` placeholders are formatted per call; the formatted text
    re-plans (cheap host work — optimizer constant folding makes plan
    *structure* depend on literal values, so substituting into a saved
    plan would be unsafe) and rides the compiled-plan cache, which
    abstracts literals into parameter slots: after the first call,
    every parameter set reuses one XLA executable."""

    def __init__(self, owner, template: str) -> None:
        self._owner = owner
        self.template = template
        self.calls = 0

    def submit(self, *, timeout_s: Optional[float] = None, **params) -> Future:
        self.calls += 1
        return self._owner._submit(
            self.template.format(**params),
            prepared=True,
            timeout_s=timeout_s,
        )

    def execute(self, *, timeout_s: Optional[float] = None, **params):
        return self.submit(timeout_s=timeout_s, **params).result()


class Session:
    """Per-client view of an executor: shared tables, isolated UDFs,
    its own in-flight budget."""

    def __init__(self, executor: "Executor") -> None:
        self._executor = executor
        self._udfs: Dict[str, object] = {}

    def add_function(self, name: str, fn: Callable, *, returns: str = "num"):
        from repro.sql.udf import Udf

        u = Udf(name, fn, returns=returns)
        self._udfs[u.name] = u
        return u

    def _active(self) -> Dict:
        # session registrations shadow executor-level ones
        return {**self._executor._udfs, **self._udfs}

    def _submit(
        self,
        text: str,
        prepared: bool = False,
        timeout_s: Optional[float] = None,
    ) -> Future:
        return self._executor._enqueue(
            text,
            self._active(),
            prepared,
            timeout_s=timeout_s,
            session_key=id(self),
        )

    def submit(self, query: str, *, timeout_s: Optional[float] = None) -> Future:
        return self._submit(query, timeout_s=timeout_s)

    def execute(self, query: str, *, timeout_s: Optional[float] = None):
        return self._submit(query, timeout_s=timeout_s).result()

    def cancel(self, request_id: int) -> bool:
        """Cancel one of this session's in-flight requests (see
        ``Executor.cancel``)."""
        return self._executor.cancel(request_id)

    def prepare(self, template: str) -> Prepared:
        return Prepared(self, template)


class Executor:
    """Serve SQL queries over a fixed scope, batching concurrent work.

    ``auto_start=False`` leaves the admission worker off; tests then
    stage submissions and run exactly one micro-batch with
    ``drain_once()``.
    """

    def __init__(self, scope: Dict, *, auto_start: bool = True) -> None:
        from repro.sql.lower import scope_frames

        self._frames = scope_frames(scope)
        self._udfs: Dict[str, object] = {}
        self._inflight: Dict[int, _Request] = {}
        self._session_load: Dict[int, int] = {}
        self._reg_lock = threading.Lock()
        self._queue = AdmissionQueue(self._run_batch, auto_start=auto_start)

    # -- scope / registry -----------------------------------------------
    def update(self, **tables) -> None:
        """Add or replace scope entries (copy-on-write: in-flight
        batches keep the scope they were planned against)."""
        from repro.sql.lower import scope_frames

        self._frames = {**self._frames, **scope_frames(tables)}

    def add_function(self, name: str, fn: Callable, *, returns: str = "num"):
        """Register a scalar python UDF, visible to every session."""
        from repro.sql.udf import Udf

        u = Udf(name, fn, returns=returns)
        self._udfs[u.name] = u
        return u

    def session(self) -> Session:
        return Session(self)

    # -- submission ------------------------------------------------------
    def _enqueue(
        self,
        text: str,
        udfs: Dict,
        prepared: bool,
        timeout_s: Optional[float] = None,
        session_key: Optional[int] = None,
    ) -> Future:
        cap = CONFIG.serve_session_inflight
        with self._reg_lock:
            if (
                cap is not None
                and session_key is not None
                and self._session_load.get(session_key, 0) >= int(cap)
            ):
                STATS.bump_shed("session_cap")
                STATS.bump_error("resource_exhausted")
                raise ResourceExhausted(
                    f"session has {cap} requests in flight "
                    f"(CONFIG.serve_session_inflight)"
                )
            req = _Request(self, text, udfs, prepared, timeout_s, session_key)
            self._inflight[req.request_id] = req
            if session_key is not None:
                self._session_load[session_key] = (
                    self._session_load.get(session_key, 0) + 1
                )
        try:
            return self._queue.submit(req)
        except Exception:
            self._unregister(req)
            raise

    def _submit(
        self,
        text: str,
        prepared: bool = False,
        timeout_s: Optional[float] = None,
    ) -> Future:
        return self._enqueue(
            text, dict(self._udfs), prepared, timeout_s=timeout_s
        )

    def submit(self, query: str, *, timeout_s: Optional[float] = None) -> Future:
        return self._submit(query, timeout_s=timeout_s)

    def execute(self, query: str, *, timeout_s: Optional[float] = None):
        return self._submit(query, timeout_s=timeout_s).result()

    def cancel(self, request_id: int) -> bool:
        """Request cooperative cancellation of an in-flight query.

        A still-queued request is shed with ``QueryCancelled`` before
        it executes; an executing one aborts at its next checkpoint
        (unless it shares a coalesced execution with members that did
        not cancel — then only this member's future gets
        ``QueryCancelled``).  Returns False when the id is unknown or
        already resolved.
        """
        with self._reg_lock:
            req = self._inflight.get(request_id)
        if req is None:
            return False
        req.token.cancel()
        return True

    def prepare(self, template: str) -> Prepared:
        return Prepared(self, template)

    def drain_once(self) -> int:
        """Run one micro-batch synchronously (``auto_start=False``)."""
        return self._queue.drain_once()

    def close(self) -> None:
        self._queue.close()

    def __enter__(self) -> "Executor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- resolution bookkeeping ------------------------------------------
    def _unregister(self, req: _Request) -> None:
        with self._reg_lock:
            self._inflight.pop(req.request_id, None)
            if req.session_key is not None:
                n = self._session_load.get(req.session_key, 0) - 1
                if n > 0:
                    self._session_load[req.session_key] = n
                else:
                    self._session_load.pop(req.session_key, None)

    def _fail(
        self, req: _Request, exc, shed_reason: Optional[str] = None
    ) -> None:
        self._unregister(req)
        err = classify(exc)
        STATS.bump_error(err.code)
        if shed_reason is not None:
            STATS.bump_shed(shed_reason)
        if not req.future.done():
            req.future.set_exception(err)

    def _resolve(self, req: _Request, out) -> None:
        self._unregister(req)
        STATS.record_latency(time.perf_counter() - req.t_submit)
        if not req.future.done():
            req.future.set_result(out)

    # -- batch execution (admission worker thread) -----------------------
    def _shed_stale(self, batch: List[_Request]) -> List[_Request]:
        """Admission-dequeue deadline/cancel enforcement: a request
        whose deadline passed (or that was cancelled) while queued is
        shed with a typed error, never executed."""
        live: List[_Request] = []
        for req in batch:
            if req.token.cancelled:
                self._fail(
                    req,
                    QueryCancelled("cancelled while queued"),
                    shed_reason="cancelled",
                )
            elif req.expired:
                waited = time.perf_counter() - req.t_submit
                self._fail(
                    req,
                    QueryTimeout(
                        f"deadline exceeded after {waited * 1e3:.1f}ms in "
                        f"admission queue"
                    ),
                    shed_reason="deadline",
                )
            else:
                live.append(req)
        return live

    def _run_batch(self, batch: List[_Request]) -> None:
        from repro import obs
        from repro.sql import compile as _compile

        batch = self._shed_stale(batch)
        if not batch:
            return
        t_start = time.perf_counter()
        for req in batch:  # queue phase: submit -> batch start
            STATS.record_phase("queue", t_start - req.t_submit)

        with obs.span("serve.batch", size=len(batch)):
            frames = self._frames  # one snapshot for the whole batch
            groups = self._coalesce(batch)
            live = self._plan_batch(groups, frames)
            with obs.span("serve.shared_scan"):
                scan_cache = self._share_scans(live, frames)

            STATS.bump(batches=1)
            if len(batch) >= 2:
                STATS.bump(batched_queries=len(batch))

            # dispatch grouped by parameterized plan shape: same-shape
            # members run back-to-back as compiled-cache hits
            live.sort(key=lambda g: g[0].shape_key)
            hits_before = _compile.STATS["hits"]
            for group in live:
                self._run_group(group, frames, scan_cache)
            with_hits = _compile.STATS["hits"] - hits_before
            if with_hits > 0:
                STATS.bump(plan_cache_hits=with_hits)

    def _coalesce(self, batch: List[_Request]) -> List[List[_Request]]:
        """Group identical (text, UDF environment) requests: each group
        parses, plans, and executes once, sharing one result frame."""
        if not CONFIG.serve_coalesce:
            return [[req] for req in batch]
        groups: Dict[tuple, List[_Request]] = {}
        for req in batch:
            ckey = (
                req.text,
                tuple(sorted((n, id(u)) for n, u in req.udfs.items())),
            )
            groups.setdefault(ckey, []).append(req)
        for members in groups.values():
            if len(members) > 1:
                STATS.bump(coalesced=len(members) - 1)
        return list(groups.values())

    def _plan_batch(
        self, groups: List[List[_Request]], frames: Dict
    ) -> List[List[_Request]]:
        """Plan each group's representative; planning failures resolve
        every member of that group with a typed (usually ``PlanError``)
        exception."""
        from repro import sql
        from repro.sql import compile as _compile
        from repro.sql.lower import scan_cache_key
        from repro.sql.plan import walk_scans
        from repro.sql.udf import udf_scope
        from repro.store import Table as StoreTable

        from repro import obs

        live: List[List[_Request]] = []
        for group in groups:
            req = group[0]
            t0 = time.perf_counter()
            try:
                with obs.span("serve.plan"), udf_scope(req.udfs):
                    req.plan = sql.plan_query(
                        req.text, frames, optimized=True
                    )
                for node in walk_scans(req.plan):
                    if isinstance(frames.get(node.table), StoreTable):
                        req.scan_keys.append(scan_cache_key(node))
                try:
                    req.shape_key = repr(_compile.parameterize(req.plan)[0])
                except Exception:
                    req.shape_key = req.text
            except Exception as e:  # parse/plan error -> the caller(s)
                err = classify(e, phase="plan")
                for member in group:
                    self._fail(member, err)
                continue
            finally:
                STATS.record_phase("plan", time.perf_counter() - t0)
            live.append(group)
        return live

    def _share_scans(
        self, live: List[List[_Request]], frames: Dict
    ) -> Dict:
        """One ``store.shared_scan`` pass per table that two or more
        executed queries scan; returns the lower-layer scan cache."""
        from repro import store
        from repro.core import TensorFrame

        scan_cache: Dict[tuple, object] = {}
        if not CONFIG.serve_shared_scans:
            return scan_cache

        by_table: Dict[str, Dict[tuple, int]] = {}
        for group in live:
            for key in group[0].scan_keys:
                by_table.setdefault(key[0], {})
                by_table[key[0]][key] = by_table[key[0]].get(key, 0) + 1

        for tname, keys in by_table.items():
            participants = sum(keys.values())
            if participants < 2:
                continue  # nothing to share for this table
            table = frames[tname]
            specs = list(keys)  # unique (table, cols, preds) identities
            try:
                results = store.shared_scan(
                    table,
                    [(list(k[1]), list(k[2])) for k in specs],
                )
                for k, res in zip(specs, results):
                    scan_cache[k] = TensorFrame.from_store(
                        table, list(k[1]), list(k[2]), result=res
                    )
            except Exception:
                # graceful degradation: every member falls back to its
                # own scan — observable, never silent
                STATS.bump(shared_scan_errors=1)
                continue
            STATS.bump(
                shared_scan_groups=1, shared_scan_queries=participants
            )
        return scan_cache

    @staticmethod
    def _compile_seconds() -> float:
        """Cumulative trace+compile seconds the compiled path has spent
        (deltas around a group attribute its compile cost)."""
        from repro.sql import compile as _compile

        with _compile._LOCK:
            return sum(
                r["trace_s"] + r["compile_s"]
                for r in _compile.STATS["plans"].values()
            )

    @staticmethod
    def _group_deadline(group: List[_Request]) -> Optional[float]:
        """The loosest member deadline (None if any member is
        unbounded): a shared execution must not be aborted by its most
        impatient member while others still want the result."""
        expiries = [m.expires_at for m in group]
        if any(e is None for e in expiries):
            return None
        return max(expiries)

    def _run_group(
        self, group: List[_Request], frames: Dict, scan_cache: Dict
    ) -> None:
        from repro import obs, sql
        from repro.sql.udf import udf_scope

        group = self._shed_stale(group)
        if not group:
            return
        req = group[0]
        cache = (
            scan_cache
            if scan_cache and any(k in scan_cache for k in req.scan_keys)
            else None
        )
        t0 = time.perf_counter()
        c0 = self._compile_seconds()
        try:
            with obs.span("serve.execute", queries=len(group)), udf_scope(
                req.udfs
            ), deadline_scope(
                at=self._group_deadline(group),
                token=_GroupToken([m.token for m in group]),
            ):
                out = sql.execute_plan(req.plan, frames, scan_cache=cache)
        except Exception as e:
            err = classify(e)
            for member in group:
                self._fail(member, err)
            return
        finally:
            compile_s = max(self._compile_seconds() - c0, 0.0)
            STATS.record_phase("compile", compile_s)
            STATS.record_phase(
                "execute",
                max(time.perf_counter() - t0 - compile_s, 0.0),
            )
        if req.udfs:
            STATS.bump(udf_queries=1)
        for member in group:
            if member.token.cancelled:
                # cancelled mid-flight but the shared execution carried
                # on for the other members
                self._fail(
                    member, QueryCancelled("cancelled during execution")
                )
                continue
            if member.prepared:
                STATS.bump(prepared=1)
            self._resolve(member, out)
