"""Serving runtime.

Two tiers live here:

- ``engine`` — batched LM-style decode with KV caches / recurrent
  state, plus a simple continuous-batching request scheduler;
- ``sql``   — the concurrent SQL serving layer: ``Executor`` sessions
  over store tables and frames, per-session ``jax.vmap``-lowered UDFs,
  prepared statements riding the whole-plan compile cache, and an
  admission queue that micro-batches compatible concurrent queries
  (shared zone-map store scans, duplicate coalescing).

``STATS`` (``serve.stats``) counts what the SQL batcher did —
admissions, batches, shared-scan groups, coalesced duplicates,
compiled-plan cache hits — with latency percentiles.
"""
from .stats import STATS, ServeStats

__all__ = ["STATS", "ServeStats", "Executor", "Prepared", "Session"]


def __getattr__(name):
    # Executor pulls in the SQL stack (and, on first execution, jax);
    # keep ``import repro.serve`` light for engine-only users
    if name in ("Executor", "Prepared", "Session"):
        from . import sql as _sql

        return getattr(_sql, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
