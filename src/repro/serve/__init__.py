"""Serving runtime: batched decode with KV caches / recurrent state,
plus a simple continuous-batching request scheduler."""
