"""Deterministic fault injection for chaos testing.

``inject(site, exc=OSError, rate=0.3, seed=7)`` arms a seeded fault
rule for the duration of a ``with`` block; every instrumented boundary
in the engine calls ``fault_point(site)`` and an armed rule either
raises a fresh ``exc`` or (with ``delay_s``) sleeps — the mechanism the
resilience tests use to make queries slow without touching engine code.

Rules are *scoped by the context manager but visible process-wide*
while armed: the serving worker, chunk-prefetch producers and spill
I/O all run on threads that do not inherit the arming thread's
contextvars, so a thread-local registry would silently miss exactly the
paths chaos tests need to hit.  Each rule draws from its own
``random.Random(seed)`` under a lock, so a single-threaded run triggers
on an exactly reproducible subsequence of hits; multi-threaded runs
stay seeded per rule (which *hit* fires varies with scheduling, the
trigger count distribution does not).

``fault_point`` is one attribute read + truthiness check when nothing
is armed, so production paths keep their hooks for free.

Must import without jax.
"""
from __future__ import annotations

import contextlib
import random
import threading
import time
from typing import Dict, List, Optional, Type

__all__ = ["FaultRule", "clear", "fault_point", "inject", "sites_hit"]

_LOCK = threading.Lock()
_RULES: List["FaultRule"] = []

#: Observable injection counters (registered as the ``resilience``
#: metrics group by the package __init__).
STATS: Dict[str, Dict[str, int]] = {"injected": {}, "delayed": {}}


def reset_stats() -> None:
    with _LOCK:
        STATS["injected"] = {}
        STATS["delayed"] = {}


class FaultRule:
    """One armed fault: raise ``exc`` (or sleep ``delay_s``) at
    ``site`` with probability ``rate``, at most ``limit`` times."""

    def __init__(
        self,
        site: str,
        exc: Optional[Type[BaseException]] = OSError,
        *,
        rate: float = 1.0,
        seed: int = 0,
        limit: Optional[int] = None,
        delay_s: Optional[float] = None,
    ) -> None:
        self.site = site
        self.exc = exc
        self.rate = float(rate)
        self.limit = limit
        self.delay_s = delay_s
        self._rng = random.Random(seed)
        self.triggered = 0
        self.hits = 0

    def _roll(self) -> bool:
        """Under _LOCK: should this hit trigger?"""
        self.hits += 1
        if self.limit is not None and self.triggered >= self.limit:
            return False
        if self._rng.random() >= self.rate:
            return False
        self.triggered += 1
        return True


def fault_point(site: str) -> None:
    """Engine-side hook: every I/O and compile boundary calls this."""
    if not _RULES:  # unlocked fast path: chaos-off costs one check
        return
    fire: List[FaultRule] = []
    with _LOCK:
        for rule in _RULES:
            if rule.site == site and rule._roll():
                kind = "delayed" if rule.delay_s is not None else "injected"
                STATS[kind][site] = STATS[kind].get(site, 0) + 1
                fire.append(rule)
    for rule in fire:
        if rule.delay_s is not None:
            time.sleep(rule.delay_s)
        else:
            raise rule.exc(f"injected fault at {site}")


@contextlib.contextmanager
def inject(
    site: str,
    exc: Optional[Type[BaseException]] = OSError,
    *,
    rate: float = 1.0,
    seed: int = 0,
    limit: Optional[int] = None,
    delay_s: Optional[float] = None,
):
    """Arm a fault rule for the with-block (process-wide; see module
    docstring).  Yields the rule so tests can read ``triggered``."""
    rule = FaultRule(
        site, exc, rate=rate, seed=seed, limit=limit, delay_s=delay_s
    )
    with _LOCK:
        _RULES.append(rule)
    try:
        yield rule
    finally:
        with _LOCK:
            try:
                _RULES.remove(rule)
            except ValueError:
                pass


def clear() -> None:
    """Disarm every rule (test teardown safety net)."""
    with _LOCK:
        _RULES.clear()


def sites_hit() -> Dict[str, int]:
    """``{site: times a rule actually fired}`` since the last reset."""
    with _LOCK:
        out: Dict[str, int] = {}
        for kind in ("injected", "delayed"):
            for site, n in STATS[kind].items():
                out[site] = out.get(site, 0) + n
        return out
