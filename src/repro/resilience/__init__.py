"""Resilience layer: typed errors, deadlines, retries, fault injection.

The machinery that routes failures toward the engine's degradation
tiers instead of letting them escape as bare exceptions or hangs:

- ``errors`` — the ``QueryError`` taxonomy (``QueryTimeout``,
  ``QueryCancelled``, ``ResourceExhausted``, ``TransientIOError``,
  ``PlanError``, ``ExecutionError``) plus ``classify`` for foreign
  exceptions;
- ``deadline`` — per-request deadlines and cooperative cancellation
  checked at operator/chunk/admission checkpoints;
- ``retry`` — bounded exponential backoff with deterministic jitter
  for transient I/O;
- ``faults`` — the seeded fault-injection registry the chaos suite
  arms at every I/O and compile boundary.

Whole package imports without jax (the store layer depends on it; the
tier-1 CI step asserts it).
"""
from . import deadline, errors, faults, retry
from .deadline import (
    CancelToken,
    Deadline,
    checkpoint,
    current,
    deadline_scope,
)
from .errors import (
    ExecutionError,
    PlanError,
    QueryCancelled,
    QueryError,
    QueryTimeout,
    ResourceExhausted,
    TransientIOError,
    classify,
)

__all__ = [
    "CancelToken",
    "Deadline",
    "ExecutionError",
    "PlanError",
    "QueryCancelled",
    "QueryError",
    "QueryTimeout",
    "ResourceExhausted",
    "TransientIOError",
    "checkpoint",
    "classify",
    "current",
    "deadline",
    "deadline_scope",
    "errors",
    "faults",
    "retry",
]


def _snapshot() -> dict:
    return {
        "faults": {k: dict(v) for k, v in faults.STATS.items()},
        "retries": retry.STATS["retries"],
        "retry_giveups": retry.STATS["giveups"],
    }


def _reset() -> None:
    faults.reset_stats()
    retry.reset_stats()


from repro import obs as _obs  # noqa: E402  (jax-free)

_obs.metrics.register_group("resilience", _snapshot, _reset)
