"""Typed error taxonomy for the serving/execution stack.

Every failure that crosses a subsystem boundary (admission queue ->
client, engine -> serving layer, spill manager -> pipeline) is wrapped
in a ``QueryError`` subclass, so callers can tell *retryable* faults
(``TransientIOError``) from *semantic* ones (``PlanError``), *policy*
ones (``QueryTimeout`` / ``QueryCancelled`` / ``ResourceExhausted``)
and *unclassified* engine failures (``ExecutionError``) without string
matching.  ``classify`` is the single choke point that maps foreign
exceptions onto the taxonomy; the original exception always rides along
as ``__cause__``.

Must import without jax (the store and obs layers depend on it).
"""
from __future__ import annotations

__all__ = [
    "ExecutionError",
    "PlanError",
    "QueryCancelled",
    "QueryError",
    "QueryTimeout",
    "ResourceExhausted",
    "TransientIOError",
    "classify",
]


class QueryError(Exception):
    """Base of every typed serving/execution failure.

    ``retryable`` tells callers whether re-submitting the same request
    can reasonably succeed; ``code`` is a stable machine-readable tag
    (the per-class error counters key on it).
    """

    retryable = False
    code = "query_error"


class PlanError(QueryError):
    """Parse / plan / optimize rejected the query (semantic: the same
    text will fail again)."""

    code = "plan_error"


class QueryTimeout(QueryError):
    """The request's deadline passed — while queued (shed before
    execution) or at a cooperative checkpoint mid-execution."""

    code = "timeout"


class QueryCancelled(QueryError):
    """Explicitly cancelled (``Session.cancel``) or abandoned by an
    executor shutdown while still pending."""

    code = "cancelled"


class ResourceExhausted(QueryError):
    """A budget said no: admission queue full, per-session in-flight
    cap, memory budget, device OOM.  Retryable later, not immediately."""

    code = "resource_exhausted"


class TransientIOError(QueryError):
    """An I/O fault (spill read/write, store payload read) that
    survived its retry budget.  Safe to retry from the top."""

    retryable = True
    code = "transient_io"


class ExecutionError(QueryError):
    """Unclassified engine failure during execution — the typed
    replacement for a bare ``Exception`` reaching a caller."""

    code = "execution_error"


def classify(exc: BaseException, phase: str = "execute") -> QueryError:
    """Wrap ``exc`` into the taxonomy (idempotent for QueryErrors).

    ``phase`` biases the mapping: SQL front-end errors raised while
    planning are ``PlanError``; the same class escaping execution (e.g.
    a scalar subquery returning two rows) still maps to ``PlanError``
    because resubmitting cannot help either way.
    """
    if isinstance(exc, QueryError):
        return exc
    name = type(exc).__name__
    if name == "SqlError":  # avoid importing the sql package here
        err: QueryError = PlanError(str(exc))
    elif isinstance(exc, (OSError, EOFError)):
        err = TransientIOError(f"{name}: {exc}")
    elif isinstance(exc, MemoryError) or "RESOURCE_EXHAUSTED" in str(exc):
        err = ResourceExhausted(f"{name}: {exc}")
    elif phase == "plan":
        err = PlanError(f"{name}: {exc}")
    else:
        err = ExecutionError(f"{name}: {exc}")
    err.__cause__ = exc
    return err
