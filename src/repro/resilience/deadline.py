"""Per-request deadlines and cooperative cancellation.

A ``Deadline`` pairs an optional absolute expiry (monotonic clock) with
a ``CancelToken``.  The executing thread enters a ``deadline_scope``
(contextvar-backed, nestable — the innermost scope wins) and the engine
calls ``checkpoint()`` at safe boundaries: between plan operators
(``sql.lower``), at every streamed chunk (``core.pipeline``), and at
admission-queue dequeue.  ``checkpoint`` is a single contextvar read
when no scope is active, so instrumented hot paths cost nothing in
normal operation.

Cancellation is *cooperative*: a request inside one fused XLA program
cannot be interrupted mid-kernel; it is caught at the next checkpoint.

Must import without jax.
"""
from __future__ import annotations

import contextlib
import contextvars
import threading
import time
from typing import Optional

from .errors import QueryCancelled, QueryTimeout

__all__ = [
    "CancelToken",
    "Deadline",
    "checkpoint",
    "current",
    "deadline_scope",
]


class CancelToken:
    """Thread-safe one-way cancellation flag."""

    __slots__ = ("_event",)

    def __init__(self) -> None:
        self._event = threading.Event()

    def cancel(self) -> None:
        self._event.set()

    @property
    def cancelled(self) -> bool:
        return self._event.is_set()


class Deadline:
    """An expiry instant plus a cancel token, checked at checkpoints."""

    __slots__ = ("expires_at", "token")

    def __init__(
        self,
        timeout_s: Optional[float] = None,
        *,
        at: Optional[float] = None,
        token=None,
    ) -> None:
        if at is not None:
            self.expires_at: Optional[float] = at
        elif timeout_s is not None:
            self.expires_at = time.monotonic() + float(timeout_s)
        else:
            self.expires_at = None
        self.token = token if token is not None else CancelToken()

    @property
    def expired(self) -> bool:
        return (
            self.expires_at is not None
            and time.monotonic() > self.expires_at
        )

    def remaining(self) -> Optional[float]:
        if self.expires_at is None:
            return None
        return self.expires_at - time.monotonic()

    def check(self, site: str = "") -> None:
        """Raise the typed error if cancelled or past the deadline."""
        if self.token is not None and self.token.cancelled:
            raise QueryCancelled(
                f"cancelled at {site or 'checkpoint'}"
            )
        if self.expired:
            over = time.monotonic() - self.expires_at
            raise QueryTimeout(
                f"deadline exceeded by {over * 1e3:.1f}ms at "
                f"{site or 'checkpoint'}"
            )


_SCOPE: "contextvars.ContextVar[Optional[Deadline]]" = (
    contextvars.ContextVar("repro_deadline", default=None)
)


def current() -> Optional[Deadline]:
    return _SCOPE.get()


@contextlib.contextmanager
def deadline_scope(
    timeout_s: Optional[float] = None,
    *,
    at: Optional[float] = None,
    token=None,
):
    """Run the body under a ``Deadline`` visible to ``checkpoint()``.

    Note contextvars do not propagate into worker threads spawned
    *inside* the scope body; the engine's own threads (chunk prefetch)
    deliberately leave deadline enforcement to the consuming thread.
    """
    d = Deadline(timeout_s, at=at, token=token)
    tok = _SCOPE.set(d)
    try:
        yield d
    finally:
        _SCOPE.reset(tok)


def checkpoint(site: str = "") -> None:
    """Cooperative cancellation/deadline check — cheap no-op without an
    active scope."""
    d = _SCOPE.get()
    if d is not None:
        d.check(site)
