"""Bounded retry with exponential backoff + deterministic jitter.

``call(fn, site=...)`` re-invokes ``fn`` on *retryable* failures
(``OSError`` and ``TransientIOError`` by default — the classes spill
and store I/O raise, including injected chaos faults) up to
``CONFIG.io_retries`` times, sleeping ``base * 2**attempt * jitter``
between attempts.  Jitter draws from a module-level seeded RNG so test
runs are reproducible; sleeps are capped so a misconfigured budget can
never stall a worker for long.

Anything non-retryable (corrupt data -> ``ValueError``, semantic
errors, cancellation) propagates immediately — retrying cannot fix it
and must not delay the typed error on its way to the caller.

Must import without jax.
"""
from __future__ import annotations

import random
import threading
import time
from typing import Callable, Dict, Optional, Tuple, Type

from .errors import TransientIOError

__all__ = ["call"]

DEFAULT_RETRY_ON: Tuple[Type[BaseException], ...] = (
    OSError,
    EOFError,
    TransientIOError,
)

_MAX_SLEEP_S = 0.25

_LOCK = threading.Lock()
_RNG = random.Random(0xC0FFEE)

#: Observable retry counters (exposed through the ``resilience``
#: metrics group).
STATS: Dict[str, int] = {"retries": 0, "giveups": 0}


def reset_stats() -> None:
    with _LOCK:
        STATS["retries"] = 0
        STATS["giveups"] = 0


def call(
    fn: Callable,
    *,
    site: str = "",
    retries: Optional[int] = None,
    base_s: Optional[float] = None,
    retry_on: Tuple[Type[BaseException], ...] = DEFAULT_RETRY_ON,
):
    """Run ``fn`` with up to ``retries`` backoff retries on transient
    failures; re-raises the last failure when the budget is spent."""
    if retries is None or base_s is None:
        from repro.core.config import CONFIG

        if retries is None:
            retries = max(0, int(CONFIG.io_retries))
        if base_s is None:
            base_s = float(CONFIG.io_retry_base_s)
    attempt = 0
    while True:
        try:
            return fn()
        except retry_on as e:
            if attempt >= retries:
                with _LOCK:
                    STATS["giveups"] += 1
                raise
            with _LOCK:
                STATS["retries"] += 1
                jitter = 0.5 + _RNG.random()  # [0.5, 1.5)
            delay = min(base_s * (2.0 ** attempt) * jitter, _MAX_SLEEP_S)
            from repro import obs

            with obs.detailed_span(
                "resilience.backoff", site=site, attempt=attempt
            ):
                time.sleep(delay)
            attempt += 1
            last = e  # noqa: F841  (kept for debugger visibility)
