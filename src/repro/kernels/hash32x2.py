"""Pallas TPU kernel: two-lane 32-bit tuple hashing (Alg. 2's hash step).

TPU adaptation of the paper's xxhash-based composite hashing: the VPU
has native 32-bit integer lanes (no 64-bit vector ops), so a 64-bit
tuple hash is computed as two independent 32-bit murmur-finalizer lanes
with different seeds.  Used by the distributed engine to hash-partition
rows for all_to_all repartitioning (group-by/join shuffles).

Block layout: rows are tiled (BN, k) into VMEM; each grid step mixes k
columns into both lanes entirely in registers.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

_BN = 1024


def _kernel(cols_ref, out_ref, *, k: int):
    cols = cols_ref[...].astype(jnp.uint32)  # (BN, k)
    n = cols.shape[0]
    lanes = []
    for seed in ref._SEEDS:
        h = jnp.full((n,), seed, dtype=jnp.uint32)
        for j in range(k):
            h = ref.fmix32(h ^ ref.fmix32(cols[:, j] + np.uint32(j + 1)))
        lanes.append(h)
    out_ref[...] = jnp.stack(lanes, axis=1)


def hash32x2_pallas(cols: jax.Array, *, block_rows: int = _BN, interpret: bool = True) -> jax.Array:
    n, k = cols.shape
    pad = (-n) % block_rows
    if pad:
        cols = jnp.pad(cols, ((0, pad), (0, 0)))
    grid = (cols.shape[0] // block_rows,)
    out = pl.pallas_call(
        functools.partial(_kernel, k=k),
        out_shape=jax.ShapeDtypeStruct((cols.shape[0], 2), jnp.uint32),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, 2), lambda i: (i, 0)),
        interpret=interpret,
    )(cols)
    return out[:n]
