"""Pallas TPU kernel: segmented sum over *sorted* segment ids.

The group-by aggregation hot spot (Alg. 2 step 4).  TPUs have no fast
vector scatter, so the per-block reduction is reformulated as an MXU
matmul: within a row block the (sorted) ids are *ranked* by run
boundaries (rank = cumsum of id-changes, always < BN regardless of id
gaps), a (BN, BN) one-hot over ranks reduces the block to per-run
partials with one ``values @ one_hot`` — systolic-array friendly.  The
(tiny) cross-block combine is a scatter-add done by the XLA wrapper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BN = 512


def _kernel(vals_ref, ids_ref, partial_ref, slotid_ref):
    vals = vals_ref[...].astype(jnp.float32)  # (BN,)
    ids = ids_ref[...].astype(jnp.int32)  # (BN,) sorted ascending
    bn = vals.shape[0]
    prev = jnp.concatenate([ids[:1], ids[:-1]])
    rank = jnp.cumsum((ids != prev).astype(jnp.int32))  # (BN,) in [0, BN)
    iota = jax.lax.broadcasted_iota(jnp.int32, (bn, bn), 1)
    onehot = (rank[:, None] == iota).astype(jnp.float32)  # (BN rows, BN slots)
    partial_ref[...] = vals[None, :] @ onehot  # (1, BN) per-slot sums
    # segment id owning each slot (integer max over the slot's rows;
    # empty slots get 0 and carry a zero partial)
    slotid_ref[...] = jnp.max(
        (rank[:, None] == iota).astype(jnp.int32) * ids[:, None], axis=0
    )[None, :]


def segment_sum_sorted_pallas(
    values: jax.Array,
    seg_ids: jax.Array,
    num_segments: int,
    *,
    block_rows: int = _BN,
    interpret: bool = True,
) -> jax.Array:
    """values (n,), seg_ids (n,) sorted ascending, ids >= 0."""
    n = values.shape[0]
    if n == 0:
        return jnp.zeros((num_segments,), dtype=jnp.float32)
    pad = (-n) % block_rows
    if pad:
        values = jnp.pad(values, (0, pad))
        # pad with the last id so padding lands in an existing bucket
        # with zero value contribution
        seg_ids = jnp.pad(seg_ids, (0, pad), mode="edge")
    nblocks = values.shape[0] // block_rows
    partials, slotids = pl.pallas_call(
        _kernel,
        out_shape=(
            jax.ShapeDtypeStruct((nblocks, block_rows), jnp.float32),
            jax.ShapeDtypeStruct((nblocks, block_rows), jnp.int32),
        ),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=(
            pl.BlockSpec((1, block_rows), lambda i: (i, 0)),
            pl.BlockSpec((1, block_rows), lambda i: (i, 0)),
        ),
        interpret=interpret,
    )(values.astype(jnp.float32), seg_ids.astype(jnp.int32))
    out = jnp.zeros((num_segments,), dtype=jnp.float32)
    out = out.at[slotids.reshape(-1)].add(partials.reshape(-1))
    return out


def run_ranks_sorted(ids: jax.Array) -> jax.Array:
    """Within-run rank (0-based) of each element of a *sorted* id vector.

    The same run-boundary formulation the kernel above uses to rank ids
    inside a block (``rank = cumsum(id-changes)``), turned inside out:
    instead of the run index we want each element's offset *within* its
    run, which is ``position - run_start`` with run starts recovered by
    a cumulative max over boundary positions.  Fully on-device — no
    host sync — which is what the join's sorted-probe CSR expansion
    needs (it runs behind a single deferred total-count fetch).
    """
    n = ids.shape[0]
    if n == 0:
        return jnp.zeros((0,), dtype=jnp.int64)
    idx = jnp.arange(n, dtype=jnp.int64)
    boundary = jnp.concatenate(
        [jnp.zeros((1,), dtype=bool), ids[1:] != ids[:-1]]
    )
    starts = jax.lax.cummax(jnp.where(boundary, idx, 0))
    return idx - starts
