"""Public jit'd entry points for the Pallas kernels.

Dispatch policy: on TPU the compiled Pallas kernel runs natively; on
any other backend (this container is CPU) the kernel body executes in
``interpret=True`` mode, and callers that need raw speed on CPU use the
pure-XLA reference path (``ref.py``) — which is also what the multi-pod
dry-run lowers, since Pallas TPU kernels cannot lower on the CPU
backend (DESIGN.md §5).
"""
from __future__ import annotations

from functools import partial

import jax

from . import ref  # noqa: F401  (re-exported reference path)
from .flash_attention import flash_attention_pallas
from .hash32x2 import hash32x2_pallas
from .segment_reduce import segment_sum_sorted_pallas
from .substr_find import exists_before_pallas, substr_find_pallas
from .wkv6 import wkv6_pallas


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not on_tpu()


@jax.jit
def hash32x2(cols: jax.Array) -> jax.Array:
    return hash32x2_pallas(cols, interpret=_interpret())


@partial(jax.jit, static_argnames=("num_segments",))
def segment_sum_sorted(values, seg_ids, num_segments: int):
    return segment_sum_sorted_pallas(
        values, seg_ids, num_segments, interpret=_interpret()
    )


def substr_find(packed, lens, pattern, start=None):
    return substr_find_pallas(packed, lens, pattern, start, interpret=_interpret())


def exists_before(packed, lens, pat_a, pat_b):
    return exists_before_pallas(packed, lens, pat_a, pat_b, interpret=_interpret())


@partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def flash_attention(q, k, v, causal: bool = True, bq: int = 128, bk: int = 128):
    return flash_attention_pallas(
        q, k, v, causal=causal, bq=bq, bk=bk, interpret=_interpret()
    )


@partial(jax.jit, static_argnames=("bt",))
def wkv6(r, k, v, w, u, state=None, bt: int = 64):
    return wkv6_pallas(r, k, v, w, u, state, bt=bt, interpret=_interpret())
