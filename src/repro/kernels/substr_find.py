"""Pallas TPU kernel: per-row first-occurrence substring search on a
packed (n, L) uint8 string tensor.

This is the compute core of the paper's headline TPC-H Q13 UDF
(``not_string_exists_before``): stateless string matching, evaluated as
sliding-window byte comparisons over VMEM tiles — one row block per
grid step, the m pattern bytes unrolled statically so the VPU sees pure
vector compares/ands.
"""
from __future__ import annotations

from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BN = 512


def _make_kernel(pattern_bytes: tuple, L: int, use_start: bool):
    m = len(pattern_bytes)

    def kernel(packed_ref, lens_ref, start_ref, out_ref):
        b = packed_ref[...].astype(jnp.int32)  # (BN, L)
        lens = lens_ref[...].astype(jnp.int32)
        npos = L - m + 1
        match = jnp.ones((b.shape[0], npos), dtype=jnp.bool_)
        for j in range(m):
            match = match & (b[:, j : j + npos] == jnp.int32(pattern_bytes[j]))
        pos = jax.lax.broadcasted_iota(jnp.int32, (b.shape[0], npos), 1)
        ok = match & (pos + m <= lens[:, None])
        if use_start:
            ok = ok & (pos >= start_ref[...].astype(jnp.int32)[:, None])
        scores = jnp.where(ok, pos, jnp.int32(npos + 1))
        first = scores.min(axis=1)
        out_ref[...] = jnp.where(first <= npos, first, jnp.int32(-1))

    return kernel


def substr_find_pallas(
    packed: jax.Array,
    lens: jax.Array,
    pattern: jax.Array,
    start: Optional[jax.Array] = None,
    *,
    block_rows: int = _BN,
    interpret: bool = True,
) -> jax.Array:
    n, L = packed.shape
    m = int(pattern.shape[0])
    if m == 0:
        return jnp.zeros((n,), dtype=jnp.int32)
    if m > L:
        return jnp.full((n,), -1, dtype=jnp.int32)
    pat = tuple(int(x) for x in np.asarray(pattern))
    use_start = start is not None
    if start is None:
        start = jnp.zeros((n,), dtype=jnp.int32)
    pad = (-n) % block_rows
    if pad:
        packed = jnp.pad(packed, ((0, pad), (0, 0)))
        lens = jnp.pad(lens, (0, pad))
        start = jnp.pad(start, (0, pad))
    grid = (packed.shape[0] // block_rows,)
    out = pl.pallas_call(
        _make_kernel(pat, L, use_start),
        out_shape=jax.ShapeDtypeStruct((packed.shape[0],), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, L), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        interpret=interpret,
    )(packed, lens, start)
    return out[:n]


def exists_before_pallas(packed, lens, pat_a, pat_b, **kw) -> jax.Array:
    fa = substr_find_pallas(packed, lens, pat_a, **kw)
    start = jnp.where(fa >= 0, fa + int(pat_a.shape[0]), 0).astype(jnp.int32)
    fb = substr_find_pallas(packed, lens, pat_b, start=start, **kw)
    return (fa >= 0) & (fb >= 0)
