"""Pallas TPU kernel: causal GQA flash attention.

Online-softmax attention with (BQ, BK) VMEM tiles, running max/denom in
VMEM scratch carried across the innermost kv grid dimension, and causal
block skipping.  GQA is expressed in the BlockSpec index maps (q head h
reads kv head h // group) — no materialized head replication.

Block sizes default to MXU-aligned (128, 128).
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *, scale, bq, bk, sq, sk, causal):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    offset = sk - sq  # query block rows map to positions offset..offset+sq-1
    run = True
    if causal:
        # skip kv blocks strictly after the last query position of this block
        run = ki * bk <= qi * bq + bq - 1 + offset

    @pl.when(run if causal else True)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)  # (BK, D)
        s = (q @ k.T) * scale  # (BQ, BK)
        if causal:
            rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + offset
            cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / l_ref[...][:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
) -> jax.Array:
    """q (B, Hq, Sq, D); k,v (B, Hkv, Sk, D) -> (B, Hq, Sq, D)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    scale = float(1.0 / np.sqrt(D))  # python float: weak-typed, x64-proof
    grid = (B, Hq, Sq // bq, Sk // bk)
    return pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, bq=bq, bk=bk, sq=Sq, sk=Sk, causal=causal
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, qi, ki: (b, h // group, ki, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, qi, ki: (b, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
