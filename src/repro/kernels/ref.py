"""Pure-jnp oracles for every Pallas kernel in this package.

Self-contained (no repro.core import — kernels must not flip the x64
flag).  Each function is the semantic ground truth the kernel tests
assert against.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

# ----------------------------------------------------------------------
# hash32x2: two-lane murmur-style tuple hash over k integer columns
# ----------------------------------------------------------------------
_M1 = np.uint32(0x85EBCA6B)
_M2 = np.uint32(0xC2B2AE35)
_SEEDS = (np.uint32(0x9E3779B9), np.uint32(0x7F4A7C15))


def fmix32(h: jax.Array) -> jax.Array:
    h = h ^ (h >> np.uint32(16))
    h = h * _M1
    h = h ^ (h >> np.uint32(13))
    h = h * _M2
    h = h ^ (h >> np.uint32(16))
    return h


def hash32x2(cols: jax.Array) -> jax.Array:
    """cols: (n, k) int32/uint32 -> (n, 2) uint32 tuple hashes."""
    cols = cols.astype(jnp.uint32)
    n, k = cols.shape
    out = []
    for seed in _SEEDS:
        h = jnp.full((n,), seed, dtype=jnp.uint32)
        for j in range(k):
            h = fmix32(h ^ fmix32(cols[:, j] + np.uint32(j + 1)))
        out.append(h)
    return jnp.stack(out, axis=1)


# ----------------------------------------------------------------------
# substr_find: first occurrence of a byte pattern per row
# ----------------------------------------------------------------------
def substr_find(
    packed: jax.Array,
    lens: jax.Array,
    pattern: jax.Array,
    start: Optional[jax.Array] = None,
) -> jax.Array:
    """packed (n, L) uint8, pattern (m,) uint8 -> (n,) int32 index|-1."""
    n, L = packed.shape
    m = int(pattern.shape[0])
    if m == 0:
        return jnp.zeros((n,), dtype=jnp.int32)
    if m > L:
        return jnp.full((n,), -1, dtype=jnp.int32)
    npos = L - m + 1
    match = jnp.ones((n, npos), dtype=bool)
    for j in range(m):
        match = match & (packed[:, j : j + npos] == pattern[j])
    pos = jnp.arange(npos, dtype=jnp.int32)[None, :]
    ok = match & (pos + m <= lens[:, None].astype(jnp.int32))
    if start is not None:
        ok = ok & (pos >= start[:, None].astype(jnp.int32))
    scores = jnp.where(ok, pos, jnp.int32(npos + 1))
    first = scores.min(axis=1)
    return jnp.where(first <= npos, first, jnp.int32(-1)).astype(jnp.int32)


def exists_before(packed, lens, pat_a, pat_b) -> jax.Array:
    fa = substr_find(packed, lens, pat_a)
    start = jnp.where(fa >= 0, fa + pat_a.shape[0], 0).astype(jnp.int32)
    fb = substr_find(packed, lens, pat_b, start=start)
    return (fa >= 0) & (fb >= 0)


# ----------------------------------------------------------------------
# segment_sum on sorted segment ids
# ----------------------------------------------------------------------
def segment_sum_sorted(values: jax.Array, seg_ids: jax.Array, num_segments: int) -> jax.Array:
    return jax.ops.segment_sum(
        values, seg_ids, num_segments=num_segments, indices_are_sorted=True
    )


# ----------------------------------------------------------------------
# causal GQA attention
# ----------------------------------------------------------------------
def mha_reference(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True) -> jax.Array:
    """q (B, Hq, Sq, D); k,v (B, Hkv, Sk, D); GQA via head grouping."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    group = Hq // Hkv
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    scale = float(1.0 / np.sqrt(D))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)) * scale
    if causal:
        qi = jnp.arange(Sq)[:, None] + (Sk - Sq)
        ki = jnp.arange(Sk)[None, :]
        logits = jnp.where(ki <= qi, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, vv.astype(jnp.float32)).astype(q.dtype)


# ----------------------------------------------------------------------
# RWKV6 WKV recurrence (data-dependent decay)
# ----------------------------------------------------------------------
def wkv6_reference(
    r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array, u: jax.Array,
    state: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """All of r,k,v,w: (B, H, T, D); u: (H, D).

      y_t = r_t · (S_{t-1} + diag(u) k_t v_t^T)
      S_t = diag(w_t) S_{t-1} + k_t v_t^T

    Returns (y (B,H,T,D), final state (B,H,D,D))."""
    B, H, T, D = r.shape
    if state is None:
        state = jnp.zeros((B, H, D, D), dtype=jnp.float32)

    def step(S, xs):
        rt, kt, vt, wt = xs  # (B,H,D) each
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,D,D)
        y = jnp.einsum("bhi,bhij->bhj", rt, S + u[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, y

    xs = tuple(
        jnp.moveaxis(x.astype(jnp.float32), 2, 0) for x in (r, k, v, w)
    )  # (T, B, H, D)
    final, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 2).astype(r.dtype), final
