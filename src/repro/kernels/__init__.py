"""Pallas TPU kernels for the framework's compute hot spots.

- hash32x2: two-lane 32-bit tuple hashing (distributed repartitioning)
- substr_find / exists_before: packed-byte string UDFs (TPC-H Q13)
- segment_reduce: MXU-friendly sorted segmented sum (group-by)
- flash_attention: causal GQA online-softmax attention (prefill/train)
- wkv6: RWKV6 data-dependent-decay recurrence (rwkv6-7b)

Each kernel has a pure-jnp oracle in ``ref.py``; ``ops.py`` is the
jit'd public API with backend dispatch (native on TPU, interpret mode
elsewhere).
"""
from . import ops, ref  # noqa: F401
