"""Pallas TPU kernel: RWKV6 (Finch) WKV recurrence with data-dependent
per-channel decay.

    y_t = r_t @ S_{t-1} + (r_t · (u ⊙ k_t)) v_t
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

The (D, D) state lives in VMEM scratch and is carried across the
time-block grid dimension; each grid step streams a (BT, D) tile of
r/k/v/w through registers.  One grid row per (batch, head).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BT = 64


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sout_ref, s_ref, *, bt):
    ti = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(ti == 0)
    def _init():
        s_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)  # (D,)

    def body(t, _):
        rt = r_ref[0, 0, t, :].astype(jnp.float32)
        kt = k_ref[0, 0, t, :].astype(jnp.float32)
        vt = v_ref[0, 0, t, :].astype(jnp.float32)
        wt = w_ref[0, 0, t, :].astype(jnp.float32)
        S = s_ref[...]
        y = rt @ S + jnp.sum(rt * u * kt) * vt
        y_ref[0, 0, t, :] = y.astype(y_ref.dtype)
        s_ref[...] = wt[:, None] * S + kt[:, None] * vt[None, :]
        return 0

    jax.lax.fori_loop(0, bt, body, 0)

    @pl.when(ti == nt - 1)
    def _finish():
        sout_ref[0, 0] = s_ref[...]


def wkv6_pallas(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    state: jax.Array | None = None,
    *,
    bt: int = DEFAULT_BT,
    interpret: bool = True,
):
    """r/k/v/w (B, H, T, D); u (H, D); state (B, H, D, D) or None.

    Returns (y (B,H,T,D), final_state (B,H,D,D) f32)."""
    B, H, T, D = r.shape
    bt = min(bt, T)
    assert T % bt == 0, (T, bt)
    if state is None:
        state = jnp.zeros((B, H, D, D), dtype=jnp.float32)
    grid = (B, H, T // bt)
    y, sout = pl.pallas_call(
        functools.partial(_kernel, bt=bt),
        out_shape=(
            jax.ShapeDtypeStruct(r.shape, r.dtype),
            jax.ShapeDtypeStruct((B, H, D, D), jnp.float32),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bt, D), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, bt, D), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, bt, D), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, bt, D), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, D), lambda b, h, t: (h, 0)),
            pl.BlockSpec((1, 1, D, D), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, bt, D), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, D, D), lambda b, h, t: (b, h, 0, 0)),
        ),
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, state)
    return y, sout
