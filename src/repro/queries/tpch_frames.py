"""All 22 TPC-H queries in the TensorFrame API (paper §VI, Fig. 5).

Each function takes ``t`` = dict of TensorFrames keyed by table name.
``apply_limit=False`` disables the final LIMIT so tests can compare the
full result set against the reference implementation (LIMIT with sort
ties is non-deterministic across engines).

Translations follow the paper's style: explicit column selection
(projection pushdown by hand), trait-based filter expressions, and
per-operation chained calls.
"""
from __future__ import annotations

from repro.core import col, d, if_else, lit


def _rev():
    return col("l_extendedprice") * (1 - col("l_discount"))


def q1(t, sf=1.0, apply_limit=True):
    le = t["lineitem"].filter(col("l_shipdate") <= d("1998-12-01") - 90)
    le = le.with_column("disc_price", _rev())
    le = le.with_column("charge", col("disc_price") * (1 + col("l_tax")))
    res = le.groupby(["l_returnflag", "l_linestatus"]).agg(
        [
            ("sum_qty", "sum", "l_quantity"),
            ("sum_base_price", "sum", "l_extendedprice"),
            ("sum_disc_price", "sum", "disc_price"),
            ("sum_charge", "sum", "charge"),
            ("avg_qty", "mean", "l_quantity"),
            ("avg_price", "mean", "l_extendedprice"),
            ("avg_disc", "mean", "l_discount"),
            ("count_order", "size", ""),
        ]
    )
    return res.sort_values(["l_returnflag", "l_linestatus"])


def q2(t, sf=1.0, apply_limit=True):
    p = t["part"].filter((col("p_size") == 15) & col("p_type").str.like("%BRASS"))
    p = p.select(["p_partkey", "p_mfgr"])
    eu = t["region"].filter(col("r_name") == "EUROPE").select(["r_regionkey"])
    n = t["nation"].select(["n_nationkey", "n_name", "n_regionkey"]).join(
        eu, left_on="n_regionkey", right_on="r_regionkey"
    )
    s = t["supplier"].select(
        ["s_suppkey", "s_name", "s_address", "s_nationkey", "s_phone", "s_acctbal", "s_comment"]
    ).join(n, left_on="s_nationkey", right_on="n_nationkey")
    ps = t["partsupp"].select(["ps_partkey", "ps_suppkey", "ps_supplycost"]).join(
        s, left_on="ps_suppkey", right_on="s_suppkey"
    )
    ps = ps.join(p, left_on="ps_partkey", right_on="p_partkey")
    mins = ps.groupby("ps_partkey").agg([("min_cost", "min", "ps_supplycost")])
    ps = ps.join(mins, on="ps_partkey")
    res = ps.filter(col("ps_supplycost") == col("min_cost"))
    res = res.select(
        ["s_acctbal", "s_name", "n_name", "ps_partkey", "p_mfgr", "s_address", "s_phone", "s_comment"]
    ).rename({"ps_partkey": "p_partkey"})
    res = res.sort_values(
        ["s_acctbal", "n_name", "s_name", "p_partkey"], ascending=[False, True, True, True]
    )
    return res.head(100) if apply_limit else res


def q3(t, sf=1.0, apply_limit=True):
    c = t["customer"].filter(col("c_mktsegment") == "BUILDING").select(["c_custkey"])
    o = t["orders"].filter(col("o_orderdate") < d("1995-03-15")).select(
        ["o_orderkey", "o_custkey", "o_orderdate", "o_shippriority"]
    )
    o = o.join(c, left_on="o_custkey", right_on="c_custkey")
    le = t["lineitem"].filter(col("l_shipdate") > d("1995-03-15")).select(
        ["l_orderkey", "l_extendedprice", "l_discount"]
    )
    j = le.join(o, left_on="l_orderkey", right_on="o_orderkey")
    j = j.with_column("rev", _rev())
    res = j.groupby(["l_orderkey", "o_orderdate", "o_shippriority"]).agg(
        [("revenue", "sum", "rev")]
    )
    res = res.sort_values(["revenue", "o_orderdate"], ascending=[False, True])
    return res.head(10) if apply_limit else res


def q4(t, sf=1.0, apply_limit=True):
    o = t["orders"].filter(
        (col("o_orderdate") >= d("1993-07-01")) & (col("o_orderdate") < d("1993-10-01"))
    )
    late = t["lineitem"].filter(col("l_commitdate") < col("l_receiptdate")).select(["l_orderkey"])
    o = o.join(late, left_on="o_orderkey", right_on="l_orderkey", how="semi")
    return o.groupby("o_orderpriority").agg([("order_count", "size", "")]).sort_values(
        "o_orderpriority"
    )


def q5(t, sf=1.0, apply_limit=True):
    r = t["region"].filter(col("r_name") == "ASIA").select(["r_regionkey"])
    n = t["nation"].select(["n_nationkey", "n_name", "n_regionkey"]).join(
        r, left_on="n_regionkey", right_on="r_regionkey"
    )
    s = t["supplier"].select(["s_suppkey", "s_nationkey"]).join(
        n, left_on="s_nationkey", right_on="n_nationkey"
    )
    o = t["orders"].filter(
        (col("o_orderdate") >= d("1994-01-01")) & (col("o_orderdate") < d("1995-01-01"))
    ).select(["o_orderkey", "o_custkey"])
    le = t["lineitem"].select(["l_orderkey", "l_suppkey", "l_extendedprice", "l_discount"])
    j = le.join(o, left_on="l_orderkey", right_on="o_orderkey")
    j = j.join(s, left_on="l_suppkey", right_on="s_suppkey")
    j = j.join(
        t["customer"].select(["c_custkey", "c_nationkey"]),
        left_on="o_custkey",
        right_on="c_custkey",
    )
    j = j.filter(col("c_nationkey") == col("s_nationkey"))
    j = j.with_column("rev", _rev())
    return (
        j.groupby("n_name")
        .agg([("revenue", "sum", "rev")])
        .sort_values("revenue", ascending=False)
    )


def q6(t, sf=1.0, apply_limit=True):
    le = t["lineitem"].filter(
        (col("l_shipdate") >= d("1994-01-01"))
        & (col("l_shipdate") < d("1995-01-01"))
        & (col("l_discount") >= 0.05)
        & (col("l_discount") <= 0.07)
        & (col("l_quantity") < 24.0)
    )
    le = le.with_column("rev", col("l_extendedprice") * col("l_discount"))
    return le.agg([("revenue", "sum", "rev")])


def q7(t, sf=1.0, apply_limit=True):
    n1 = t["nation"].select(["n_nationkey", "n_name"]).rename(
        {"n_nationkey": "s_nk", "n_name": "supp_nation"}
    )
    n2 = t["nation"].select(["n_nationkey", "n_name"]).rename(
        {"n_nationkey": "c_nk", "n_name": "cust_nation"}
    )
    s = t["supplier"].select(["s_suppkey", "s_nationkey"]).join(
        n1, left_on="s_nationkey", right_on="s_nk"
    )
    c = t["customer"].select(["c_custkey", "c_nationkey"]).join(
        n2, left_on="c_nationkey", right_on="c_nk"
    )
    le = t["lineitem"].filter(
        (col("l_shipdate") >= d("1995-01-01")) & (col("l_shipdate") <= d("1996-12-31"))
    ).select(["l_orderkey", "l_suppkey", "l_extendedprice", "l_discount", "l_shipdate"])
    j = le.join(s, left_on="l_suppkey", right_on="s_suppkey")
    j = j.join(t["orders"].select(["o_orderkey", "o_custkey"]), left_on="l_orderkey", right_on="o_orderkey")
    j = j.join(c, left_on="o_custkey", right_on="c_custkey")
    j = j.filter(
        ((col("supp_nation") == "FRANCE") & (col("cust_nation") == "GERMANY"))
        | ((col("supp_nation") == "GERMANY") & (col("cust_nation") == "FRANCE"))
    )
    j = j.with_column("l_year", col("l_shipdate").dt.year()).with_column("volume", _rev())
    return (
        j.groupby(["supp_nation", "cust_nation", "l_year"])
        .agg([("revenue", "sum", "volume")])
        .sort_values(["supp_nation", "cust_nation", "l_year"])
    )


def q8(t, sf=1.0, apply_limit=True):
    am = t["region"].filter(col("r_name") == "AMERICA").select(["r_regionkey"])
    n_am = t["nation"].select(["n_nationkey", "n_regionkey"]).join(
        am, left_on="n_regionkey", right_on="r_regionkey"
    )
    c = t["customer"].select(["c_custkey", "c_nationkey"]).join(
        n_am.select(["n_nationkey"]), left_on="c_nationkey", right_on="n_nationkey", how="semi"
    )
    p = t["part"].filter(col("p_type") == "ECONOMY ANODIZED STEEL").select(["p_partkey"])
    o = t["orders"].filter(
        (col("o_orderdate") >= d("1995-01-01")) & (col("o_orderdate") <= d("1996-12-31"))
    ).select(["o_orderkey", "o_custkey", "o_orderdate"])
    le = t["lineitem"].select(
        ["l_orderkey", "l_partkey", "l_suppkey", "l_extendedprice", "l_discount"]
    )
    j = le.join(p, left_on="l_partkey", right_on="p_partkey")
    j = j.join(o, left_on="l_orderkey", right_on="o_orderkey")
    j = j.join(c, left_on="o_custkey", right_on="c_custkey")
    n2 = t["nation"].select(["n_nationkey", "n_name"]).rename({"n_name": "supp_nation"})
    j = j.join(t["supplier"].select(["s_suppkey", "s_nationkey"]), left_on="l_suppkey", right_on="s_suppkey")
    j = j.join(n2, left_on="s_nationkey", right_on="n_nationkey")
    j = j.with_column("volume", _rev()).with_column("o_year", col("o_orderdate").dt.year())
    j = j.with_column(
        "brazil_volume", if_else(col("supp_nation") == "BRAZIL", col("volume"), lit(0.0))
    )
    g = j.groupby("o_year").agg(
        [("bv", "sum", "brazil_volume"), ("tv", "sum", "volume")]
    )
    g = g.with_column("mkt_share", col("bv") / col("tv"))
    return g.select(["o_year", "mkt_share"]).sort_values("o_year")


def q9(t, sf=1.0, apply_limit=True):
    p = t["part"].filter(col("p_name").str.contains("green")).select(["p_partkey"])
    le = t["lineitem"].select(
        ["l_orderkey", "l_partkey", "l_suppkey", "l_quantity", "l_extendedprice", "l_discount"]
    )
    j = le.join(p, left_on="l_partkey", right_on="p_partkey")
    j = j.join(t["supplier"].select(["s_suppkey", "s_nationkey"]), left_on="l_suppkey", right_on="s_suppkey")
    j = j.join(
        t["partsupp"].select(["ps_partkey", "ps_suppkey", "ps_supplycost"]),
        left_on=["l_partkey", "l_suppkey"],
        right_on=["ps_partkey", "ps_suppkey"],
    )
    j = j.join(t["orders"].select(["o_orderkey", "o_orderdate"]), left_on="l_orderkey", right_on="o_orderkey")
    j = j.join(t["nation"].select(["n_nationkey", "n_name"]), left_on="s_nationkey", right_on="n_nationkey")
    j = j.with_column("o_year", col("o_orderdate").dt.year())
    j = j.with_column("amount", _rev() - col("ps_supplycost") * col("l_quantity"))
    return (
        j.groupby(["n_name", "o_year"])
        .agg([("sum_profit", "sum", "amount")])
        .sort_values(["n_name", "o_year"], ascending=[True, False])
    )


def q10(t, sf=1.0, apply_limit=True):
    o = t["orders"].filter(
        (col("o_orderdate") >= d("1993-10-01")) & (col("o_orderdate") < d("1994-01-01"))
    ).select(["o_orderkey", "o_custkey"])
    le = t["lineitem"].filter(col("l_returnflag") == "R").select(
        ["l_orderkey", "l_extendedprice", "l_discount"]
    )
    j = le.join(o, left_on="l_orderkey", right_on="o_orderkey")
    j = j.join(
        t["customer"].select(
            ["c_custkey", "c_name", "c_acctbal", "c_phone", "c_nationkey", "c_address", "c_comment"]
        ),
        left_on="o_custkey",
        right_on="c_custkey",
    )
    j = j.join(t["nation"].select(["n_nationkey", "n_name"]), left_on="c_nationkey", right_on="n_nationkey")
    j = j.with_column("rev", _rev())
    res = j.groupby(
        ["o_custkey", "c_name", "c_acctbal", "c_phone", "n_name", "c_address", "c_comment"]
    ).agg([("revenue", "sum", "rev")])
    res = res.sort_values("revenue", ascending=False)
    return res.head(20) if apply_limit else res


def q11(t, sf=1.0, apply_limit=True):
    g = t["nation"].filter(col("n_name") == "GERMANY").select(["n_nationkey"])
    s = t["supplier"].select(["s_suppkey", "s_nationkey"]).join(
        g, left_on="s_nationkey", right_on="n_nationkey", how="semi"
    )
    ps = t["partsupp"].select(["ps_partkey", "ps_suppkey", "ps_availqty", "ps_supplycost"])
    ps = ps.join(s.select(["s_suppkey"]), left_on="ps_suppkey", right_on="s_suppkey", how="semi")
    ps = ps.with_column("value", col("ps_supplycost") * col("ps_availqty"))
    total = ps.agg([("tv", "sum", "value")])["tv"]
    res = ps.groupby("ps_partkey").agg([("value", "sum", "value")])
    res = res.filter(col("value") > total * (0.0001 / sf))
    return res.sort_values("value", ascending=False)


def q12(t, sf=1.0, apply_limit=True):
    le = t["lineitem"].filter(
        col("l_shipmode").isin(["MAIL", "SHIP"])
        & (col("l_commitdate") < col("l_receiptdate"))
        & (col("l_shipdate") < col("l_commitdate"))
        & (col("l_receiptdate") >= d("1994-01-01"))
        & (col("l_receiptdate") < d("1995-01-01"))
    ).select(["l_orderkey", "l_shipmode"])
    j = le.join(
        t["orders"].select(["o_orderkey", "o_orderpriority"]),
        left_on="l_orderkey",
        right_on="o_orderkey",
    )
    j = j.with_column(
        "high", if_else(col("o_orderpriority").isin(["1-URGENT", "2-HIGH"]), lit(1), lit(0))
    )
    j = j.with_column("low", 1 - col("high"))
    return (
        j.groupby("l_shipmode")
        .agg([("high_line_count", "sum", "high"), ("low_line_count", "sum", "low")])
        .sort_values("l_shipmode")
    )


def q13(t, sf=1.0, apply_limit=True):
    o = t["orders"].filter(
        col("o_comment").str.not_exists_before("special", "requests")
    ).select(["o_orderkey", "o_custkey"])
    c = t["customer"].select(["c_custkey"])
    j = c.join(o, left_on="c_custkey", right_on="o_custkey", how="left")
    counts = j.groupby("c_custkey").agg([("c_count", "count", "o_orderkey")])
    hist = counts.groupby("c_count").agg([("custdist", "size", "")])
    return hist.sort_values(["custdist", "c_count"], ascending=[False, False])


def q14(t, sf=1.0, apply_limit=True):
    le = t["lineitem"].filter(
        (col("l_shipdate") >= d("1995-09-01")) & (col("l_shipdate") < d("1995-10-01"))
    ).select(["l_partkey", "l_extendedprice", "l_discount"])
    j = le.join(t["part"].select(["p_partkey", "p_type"]), left_on="l_partkey", right_on="p_partkey")
    j = j.with_column("rev", _rev())
    j = j.with_column(
        "promo", if_else(col("p_type").str.like("PROMO%"), col("rev"), lit(0.0))
    )
    s = j.agg([("p", "sum", "promo"), ("r", "sum", "rev")])
    return {"promo_revenue": 100.0 * s["p"] / s["r"]}


def q15(t, sf=1.0, apply_limit=True):
    le = t["lineitem"].filter(
        (col("l_shipdate") >= d("1996-01-01")) & (col("l_shipdate") < d("1996-04-01"))
    ).select(["l_suppkey", "l_extendedprice", "l_discount"])
    le = le.with_column("rev", _rev())
    g = le.groupby("l_suppkey").agg([("total_revenue", "sum", "rev")])
    mx = g.agg([("m", "max", "total_revenue")])["m"]
    top = g.filter(col("total_revenue") == mx)
    res = t["supplier"].select(["s_suppkey", "s_name", "s_address", "s_phone"]).join(
        top, left_on="s_suppkey", right_on="l_suppkey"
    )
    return res.drop(["l_suppkey"]).sort_values("s_suppkey")


def q16(t, sf=1.0, apply_limit=True):
    bad = t["supplier"].filter(
        col("s_comment").str.exists_before("Customer", "Complaints")
    ).select(["s_suppkey"])
    p = t["part"].filter(
        (col("p_brand") != "Brand#45")
        & ~col("p_type").str.like("MEDIUM POLISHED%")
        & col("p_size").isin([49, 14, 23, 45, 19, 3, 36, 9])
    ).select(["p_partkey", "p_brand", "p_type", "p_size"])
    ps = t["partsupp"].select(["ps_partkey", "ps_suppkey"]).join(
        p, left_on="ps_partkey", right_on="p_partkey"
    )
    ps = ps.join(bad, left_on="ps_suppkey", right_on="s_suppkey", how="anti")
    res = ps.groupby(["p_brand", "p_type", "p_size"]).agg(
        [("supplier_cnt", "nunique", "ps_suppkey")]
    )
    return res.sort_values(
        ["supplier_cnt", "p_brand", "p_type", "p_size"], ascending=[False, True, True, True]
    )


def q17(t, sf=1.0, apply_limit=True):
    p = t["part"].filter(
        (col("p_brand") == "Brand#23") & (col("p_container") == "MED BOX")
    ).select(["p_partkey"])
    le = t["lineitem"].select(["l_partkey", "l_quantity", "l_extendedprice"])
    j = le.join(p, left_on="l_partkey", right_on="p_partkey")
    avg_q = j.groupby("l_partkey").agg([("avg_qty", "mean", "l_quantity")])
    j = j.join(avg_q, on="l_partkey")
    j = j.filter(col("l_quantity") < 0.2 * col("avg_qty"))
    s = j.agg([("s", "sum", "l_extendedprice")])
    return {"avg_yearly": s["s"] / 7.0}


def q18(t, sf=1.0, apply_limit=True):
    big = t["lineitem"].groupby("l_orderkey").agg([("sum_qty", "sum", "l_quantity")])
    big = big.filter(col("sum_qty") > 300.0)
    o = t["orders"].select(["o_orderkey", "o_custkey", "o_orderdate", "o_totalprice"]).join(
        big, left_on="o_orderkey", right_on="l_orderkey"
    )
    j = o.join(t["customer"].select(["c_custkey", "c_name"]), left_on="o_custkey", right_on="c_custkey")
    res = j.select(["c_name", "o_custkey", "o_orderkey", "o_orderdate", "o_totalprice", "sum_qty"])
    res = res.sort_values(["o_totalprice", "o_orderdate"], ascending=[False, True])
    return res.head(100) if apply_limit else res


def q19(t, sf=1.0, apply_limit=True):
    le = t["lineitem"].filter(
        col("l_shipmode").isin(["AIR", "AIR REG"])
        & (col("l_shipinstruct") == "DELIVER IN PERSON")
    ).select(["l_partkey", "l_quantity", "l_extendedprice", "l_discount"])
    j = le.join(
        t["part"].select(["p_partkey", "p_brand", "p_size", "p_container"]),
        left_on="l_partkey",
        right_on="p_partkey",
    )
    b1 = (
        (col("p_brand") == "Brand#12")
        & col("p_container").isin(["SM CASE", "SM BOX", "SM PACK", "SM PKG"])
        & col("l_quantity").between(1.0, 11.0)
        & col("p_size").between(1, 5)
    )
    b2 = (
        (col("p_brand") == "Brand#23")
        & col("p_container").isin(["MED BAG", "MED BOX", "MED PKG", "MED PACK"])
        & col("l_quantity").between(10.0, 20.0)
        & col("p_size").between(1, 10)
    )
    b3 = (
        (col("p_brand") == "Brand#34")
        & col("p_container").isin(["LG CASE", "LG BOX", "LG PACK", "LG PKG"])
        & col("l_quantity").between(20.0, 30.0)
        & col("p_size").between(1, 15)
    )
    j = j.filter(b1 | b2 | b3)
    j = j.with_column("rev", _rev())
    return j.agg([("revenue", "sum", "rev")])


def q20(t, sf=1.0, apply_limit=True):
    p = t["part"].filter(col("p_name").str.like("forest%")).select(["p_partkey"])
    l94 = t["lineitem"].filter(
        (col("l_shipdate") >= d("1994-01-01")) & (col("l_shipdate") < d("1995-01-01"))
    ).select(["l_partkey", "l_suppkey", "l_quantity"])
    sums = l94.groupby(["l_partkey", "l_suppkey"]).agg([("qty", "sum", "l_quantity")])
    ps = t["partsupp"].select(["ps_partkey", "ps_suppkey", "ps_availqty"]).join(
        p, left_on="ps_partkey", right_on="p_partkey"
    )
    ps = ps.join(sums, left_on=["ps_partkey", "ps_suppkey"], right_on=["l_partkey", "l_suppkey"])
    ok = ps.filter(col("ps_availqty").cast_float() > 0.5 * col("qty")).select(["ps_suppkey"])
    ca = t["nation"].filter(col("n_name") == "CANADA").select(["n_nationkey"])
    s = t["supplier"].select(["s_suppkey", "s_name", "s_address", "s_nationkey"]).join(
        ca, left_on="s_nationkey", right_on="n_nationkey", how="semi"
    )
    s = s.join(ok, left_on="s_suppkey", right_on="ps_suppkey", how="semi")
    return s.select(["s_name", "s_address"]).sort_values("s_name")


def q21(t, sf=1.0, apply_limit=True):
    sa = t["nation"].filter(col("n_name") == "SAUDI ARABIA").select(["n_nationkey"])
    s = t["supplier"].select(["s_suppkey", "s_name", "s_nationkey"]).join(
        sa, left_on="s_nationkey", right_on="n_nationkey", how="semi"
    )
    fo = t["orders"].filter(col("o_orderstatus") == "F").select(["o_orderkey"])
    le = t["lineitem"].select(["l_orderkey", "l_suppkey", "l_receiptdate", "l_commitdate"])
    l1 = le.filter(col("l_receiptdate") > col("l_commitdate"))
    l1 = l1.join(fo, left_on="l_orderkey", right_on="o_orderkey", how="semi")
    l1 = l1.join(s.select(["s_suppkey", "s_name"]), left_on="l_suppkey", right_on="s_suppkey")
    nsupp = le.groupby("l_orderkey").agg([("nsupp", "nunique", "l_suppkey")])
    nlate = le.filter(col("l_receiptdate") > col("l_commitdate")).groupby("l_orderkey").agg(
        [("nlate", "nunique", "l_suppkey")]
    )
    l1 = l1.join(nsupp, on="l_orderkey").join(nlate, on="l_orderkey")
    l1 = l1.filter((col("nsupp") >= 2) & (col("nlate") == 1))
    res = l1.groupby("s_name").agg([("numwait", "size", "")])
    res = res.sort_values(["numwait", "s_name"], ascending=[False, True])
    return res.head(100) if apply_limit else res


def q22(t, sf=1.0, apply_limit=True):
    codes = ["13", "31", "23", "29", "30", "18", "17"]
    c = t["customer"].select(["c_custkey", "c_phone", "c_acctbal"])
    c = c.with_column("cntrycode", col("c_phone").str.slice(0, 2))
    c = c.filter(col("cntrycode").isin(codes))
    avg_bal = c.filter(col("c_acctbal") > 0.0).agg([("a", "mean", "c_acctbal")])["a"]
    c = c.filter(col("c_acctbal") > avg_bal)
    c = c.join(t["orders"].select(["o_custkey"]), left_on="c_custkey", right_on="o_custkey", how="anti")
    return (
        c.groupby("cntrycode")
        .agg([("numcust", "size", ""), ("totacctbal", "sum", "c_acctbal")])
        .sort_values("cntrycode")
    )


ALL = {f"q{i}": globals()[f"q{i}"] for i in range(1, 23)}
SCALAR_QUERIES = {"q6", "q14", "q17", "q19"}
