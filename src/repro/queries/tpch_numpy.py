"""Reference TPC-H implementations: row-at-a-time Python, written
directly from the SQL text, independent of the TensorFrame engine.

Used only for correctness testing (tests compare full result sets,
unsorted, no LIMIT).  Dates are integer epoch-days.
"""
from __future__ import annotations

import math
from collections import defaultdict
from typing import Dict, List

import numpy as np


def dd(s: str) -> int:
    return int(np.datetime64(s, "D").astype(np.int64))


def rows_of(tbl: Dict[str, np.ndarray]) -> List[dict]:
    conv = {}
    n = None
    for k, arr in tbl.items():
        arr = np.asarray(arr)
        n = arr.shape[0] if n is None else n
        if np.issubdtype(arr.dtype, np.datetime64):
            conv[k] = arr.astype("datetime64[D]").astype(np.int64).tolist()
        elif np.issubdtype(arr.dtype, np.floating):
            conv[k] = [float(x) for x in arr]
        elif np.issubdtype(arr.dtype, np.integer):
            conv[k] = [int(x) for x in arr]
        else:
            conv[k] = [str(x) for x in arr]
    return [dict(zip(conv.keys(), vals)) for vals in zip(*conv.values())]


def gagg(rs: List[dict], keys: List[str], aggs: List[tuple]) -> List[dict]:
    """aggs: (out_name, fn, col_or_callable); fn in sum/mean/min/max/
    count/size/nunique."""
    groups: Dict[tuple, List[dict]] = defaultdict(list)
    for r in rs:
        groups[tuple(r[k] for k in keys)].append(r)
    out = []
    for key, members in groups.items():
        rec = dict(zip(keys, key))
        for out_name, fn, colspec in aggs:
            get = colspec if callable(colspec) else (lambda r, c=colspec: r[c])
            if fn == "size":
                rec[out_name] = len(members)
            else:
                vals = [get(r) for r in members]
                vals = [v for v in vals if v is not None]
                if fn == "count":
                    rec[out_name] = len(vals)
                elif fn == "nunique":
                    rec[out_name] = len(set(vals))
                elif fn == "sum":
                    rec[out_name] = sum(vals) if vals else None
                elif fn == "mean":
                    rec[out_name] = sum(vals) / len(vals) if vals else None
                elif fn == "min":
                    rec[out_name] = min(vals) if vals else None
                elif fn == "max":
                    rec[out_name] = max(vals) if vals else None
                else:
                    raise ValueError(fn)
        out.append(rec)
    return out


def hjoin(
    left: List[dict],
    right: List[dict],
    lkeys: List[str],
    rkeys: List[str],
    how: str = "inner",
    keep: List[str] = None,
) -> List[dict]:
    idx: Dict[tuple, List[dict]] = defaultdict(list)
    for r in right:
        idx[tuple(r[k] for k in rkeys)].append(r)
    out = []
    for l in left:
        key = tuple(l[k] for k in lkeys)
        matches = idx.get(key, [])
        if how == "semi":
            if matches:
                out.append(dict(l))
            continue
        if how == "anti":
            if not matches:
                out.append(dict(l))
            continue
        if matches:
            for r in matches:
                rec = dict(l)
                for k, v in r.items():
                    if k not in lkeys or k not in rec:
                        rec.setdefault(k, v)
                out.append(rec)
        elif how == "left":
            rec = dict(l)
            for k in right[0].keys() if right else []:
                rec.setdefault(k, None)
            out.append(rec)
    return out


def year_of(days: int) -> int:
    return int(np.int64(days).astype("datetime64[D]").astype("datetime64[Y]").astype(int)) + 1970


def _not_exists_before(s: str, a: str, b: str) -> bool:
    i = s.find(a)
    return not (i >= 0 and s.find(b, i + len(a)) >= 0)


# ----------------------------------------------------------------------
def q1(T, sf=1.0):
    cutoff = dd("1998-12-01") - 90
    rs = [r for r in rows_of(T["lineitem"]) if r["l_shipdate"] <= cutoff]
    return gagg(
        rs,
        ["l_returnflag", "l_linestatus"],
        [
            ("sum_qty", "sum", "l_quantity"),
            ("sum_base_price", "sum", "l_extendedprice"),
            ("sum_disc_price", "sum", lambda r: r["l_extendedprice"] * (1 - r["l_discount"])),
            ("sum_charge", "sum", lambda r: r["l_extendedprice"] * (1 - r["l_discount"]) * (1 + r["l_tax"])),
            ("avg_qty", "mean", "l_quantity"),
            ("avg_price", "mean", "l_extendedprice"),
            ("avg_disc", "mean", "l_discount"),
            ("count_order", "size", ""),
        ],
    )


def q2(T, sf=1.0):
    parts = {
        r["p_partkey"]: r
        for r in rows_of(T["part"])
        if r["p_size"] == 15 and r["p_type"].endswith("BRASS")
    }
    eu = {r["r_regionkey"] for r in rows_of(T["region"]) if r["r_name"] == "EUROPE"}
    nat = {r["n_nationkey"]: r for r in rows_of(T["nation"]) if r["n_regionkey"] in eu}
    supp = {r["s_suppkey"]: r for r in rows_of(T["supplier"]) if r["s_nationkey"] in nat}
    ps = [
        r
        for r in rows_of(T["partsupp"])
        if r["ps_partkey"] in parts and r["ps_suppkey"] in supp
    ]
    mins: Dict[int, float] = {}
    for r in ps:
        k = r["ps_partkey"]
        mins[k] = min(mins.get(k, math.inf), r["ps_supplycost"])
    out = []
    for r in ps:
        if r["ps_supplycost"] == mins[r["ps_partkey"]]:
            s = supp[r["ps_suppkey"]]
            out.append(
                {
                    "s_acctbal": s["s_acctbal"],
                    "s_name": s["s_name"],
                    "n_name": nat[s["s_nationkey"]]["n_name"],
                    "p_partkey": r["ps_partkey"],
                    "p_mfgr": parts[r["ps_partkey"]]["p_mfgr"],
                    "s_address": s["s_address"],
                    "s_phone": s["s_phone"],
                    "s_comment": s["s_comment"],
                }
            )
    return out


def q3(T, sf=1.0):
    cutoff = dd("1995-03-15")
    cust = {r["c_custkey"] for r in rows_of(T["customer"]) if r["c_mktsegment"] == "BUILDING"}
    orders = {
        r["o_orderkey"]: r
        for r in rows_of(T["orders"])
        if r["o_orderdate"] < cutoff and r["o_custkey"] in cust
    }
    acc = defaultdict(float)
    meta = {}
    for r in rows_of(T["lineitem"]):
        if r["l_shipdate"] > cutoff and r["l_orderkey"] in orders:
            o = orders[r["l_orderkey"]]
            k = (r["l_orderkey"], o["o_orderdate"], o["o_shippriority"])
            acc[k] += r["l_extendedprice"] * (1 - r["l_discount"])
            meta[k] = o
    return [
        {"l_orderkey": k[0], "o_orderdate": k[1], "o_shippriority": k[2], "revenue": v}
        for k, v in acc.items()
    ]


def q4(T, sf=1.0):
    lo, hi = dd("1993-07-01"), dd("1993-10-01")
    late = {r["l_orderkey"] for r in rows_of(T["lineitem"]) if r["l_commitdate"] < r["l_receiptdate"]}
    rs = [
        r
        for r in rows_of(T["orders"])
        if lo <= r["o_orderdate"] < hi and r["o_orderkey"] in late
    ]
    return gagg(rs, ["o_orderpriority"], [("order_count", "size", "")])


def q5(T, sf=1.0):
    asia = {r["r_regionkey"] for r in rows_of(T["region"]) if r["r_name"] == "ASIA"}
    nat = {r["n_nationkey"]: r["n_name"] for r in rows_of(T["nation"]) if r["n_regionkey"] in asia}
    supp = {r["s_suppkey"]: r["s_nationkey"] for r in rows_of(T["supplier"]) if r["s_nationkey"] in nat}
    cust = {r["c_custkey"]: r["c_nationkey"] for r in rows_of(T["customer"])}
    lo, hi = dd("1994-01-01"), dd("1995-01-01")
    orders = {
        r["o_orderkey"]: r["o_custkey"]
        for r in rows_of(T["orders"])
        if lo <= r["o_orderdate"] < hi
    }
    acc = defaultdict(float)
    for r in rows_of(T["lineitem"]):
        ok, sk = r["l_orderkey"], r["l_suppkey"]
        if ok in orders and sk in supp:
            cnk = cust[orders[ok]]
            snk = supp[sk]
            if cnk == snk:
                acc[nat[snk]] += r["l_extendedprice"] * (1 - r["l_discount"])
    return [{"n_name": k, "revenue": v} for k, v in acc.items()]


def q6(T, sf=1.0):
    lo, hi = dd("1994-01-01"), dd("1995-01-01")
    tot = 0.0
    for r in rows_of(T["lineitem"]):
        if (
            lo <= r["l_shipdate"] < hi
            and 0.05 - 1e-12 <= r["l_discount"] <= 0.07 + 1e-12
            and r["l_quantity"] < 24
        ):
            tot += r["l_extendedprice"] * r["l_discount"]
    return {"revenue": tot}


def q7(T, sf=1.0):
    nat = {r["n_nationkey"]: r["n_name"] for r in rows_of(T["nation"])}
    supp = {r["s_suppkey"]: nat[r["s_nationkey"]] for r in rows_of(T["supplier"])}
    cust = {r["c_custkey"]: nat[r["c_nationkey"]] for r in rows_of(T["customer"])}
    orders = {r["o_orderkey"]: r["o_custkey"] for r in rows_of(T["orders"])}
    lo, hi = dd("1995-01-01"), dd("1996-12-31")
    acc = defaultdict(float)
    for r in rows_of(T["lineitem"]):
        if not (lo <= r["l_shipdate"] <= hi):
            continue
        sn = supp[r["l_suppkey"]]
        cn = cust[orders[r["l_orderkey"]]]
        if (sn, cn) in (("FRANCE", "GERMANY"), ("GERMANY", "FRANCE")):
            key = (sn, cn, year_of(r["l_shipdate"]))
            acc[key] += r["l_extendedprice"] * (1 - r["l_discount"])
    return [
        {"supp_nation": k[0], "cust_nation": k[1], "l_year": k[2], "revenue": v}
        for k, v in acc.items()
    ]


def q8(T, sf=1.0):
    am = {r["r_regionkey"] for r in rows_of(T["region"]) if r["r_name"] == "AMERICA"}
    nat_am = {r["n_nationkey"] for r in rows_of(T["nation"]) if r["n_regionkey"] in am}
    nat_name = {r["n_nationkey"]: r["n_name"] for r in rows_of(T["nation"])}
    cust = {r["c_custkey"] for r in rows_of(T["customer"]) if r["c_nationkey"] in nat_am}
    parts = {r["p_partkey"] for r in rows_of(T["part"]) if r["p_type"] == "ECONOMY ANODIZED STEEL"}
    lo, hi = dd("1995-01-01"), dd("1996-12-31")
    orders = {
        r["o_orderkey"]: r
        for r in rows_of(T["orders"])
        if lo <= r["o_orderdate"] <= hi and r["o_custkey"] in cust
    }
    supp = {r["s_suppkey"]: nat_name[r["s_nationkey"]] for r in rows_of(T["supplier"])}
    bv = defaultdict(float)
    tv = defaultdict(float)
    for r in rows_of(T["lineitem"]):
        if r["l_partkey"] in parts and r["l_orderkey"] in orders:
            o = orders[r["l_orderkey"]]
            y = year_of(o["o_orderdate"])
            vol = r["l_extendedprice"] * (1 - r["l_discount"])
            tv[y] += vol
            if supp[r["l_suppkey"]] == "BRAZIL":
                bv[y] += vol
    return [{"o_year": y, "mkt_share": bv[y] / tv[y]} for y in tv]


def q9(T, sf=1.0):
    parts = {r["p_partkey"] for r in rows_of(T["part"]) if "green" in r["p_name"]}
    nat = {r["n_nationkey"]: r["n_name"] for r in rows_of(T["nation"])}
    supp = {r["s_suppkey"]: nat[r["s_nationkey"]] for r in rows_of(T["supplier"])}
    pscost = {
        (r["ps_partkey"], r["ps_suppkey"]): r["ps_supplycost"] for r in rows_of(T["partsupp"])
    }
    odate = {r["o_orderkey"]: r["o_orderdate"] for r in rows_of(T["orders"])}
    acc = defaultdict(float)
    for r in rows_of(T["lineitem"]):
        if r["l_partkey"] in parts:
            amount = r["l_extendedprice"] * (1 - r["l_discount"]) - pscost[
                (r["l_partkey"], r["l_suppkey"])
            ] * r["l_quantity"]
            key = (supp[r["l_suppkey"]], year_of(odate[r["l_orderkey"]]))
            acc[key] += amount
    return [{"n_name": k[0], "o_year": k[1], "sum_profit": v} for k, v in acc.items()]


def q10(T, sf=1.0):
    lo, hi = dd("1993-10-01"), dd("1994-01-01")
    orders = {
        r["o_orderkey"]: r["o_custkey"]
        for r in rows_of(T["orders"])
        if lo <= r["o_orderdate"] < hi
    }
    cust = {r["c_custkey"]: r for r in rows_of(T["customer"])}
    nat = {r["n_nationkey"]: r["n_name"] for r in rows_of(T["nation"])}
    acc = defaultdict(float)
    for r in rows_of(T["lineitem"]):
        if r["l_returnflag"] == "R" and r["l_orderkey"] in orders:
            ck = orders[r["l_orderkey"]]
            acc[ck] += r["l_extendedprice"] * (1 - r["l_discount"])
    out = []
    for ck, rev in acc.items():
        c = cust[ck]
        out.append(
            {
                "o_custkey": ck,
                "c_name": c["c_name"],
                "c_acctbal": c["c_acctbal"],
                "c_phone": c["c_phone"],
                "n_name": nat[c["c_nationkey"]],
                "c_address": c["c_address"],
                "c_comment": c["c_comment"],
                "revenue": rev,
            }
        )
    return out


def q11(T, sf=1.0):
    ger = {r["n_nationkey"] for r in rows_of(T["nation"]) if r["n_name"] == "GERMANY"}
    supp = {r["s_suppkey"] for r in rows_of(T["supplier"]) if r["s_nationkey"] in ger}
    acc = defaultdict(float)
    total = 0.0
    for r in rows_of(T["partsupp"]):
        if r["ps_suppkey"] in supp:
            v = r["ps_supplycost"] * r["ps_availqty"]
            acc[r["ps_partkey"]] += v
            total += v
    thresh = total * (0.0001 / sf)
    return [{"ps_partkey": k, "value": v} for k, v in acc.items() if v > thresh]


def q12(T, sf=1.0):
    lo, hi = dd("1994-01-01"), dd("1995-01-01")
    prio = {r["o_orderkey"]: r["o_orderpriority"] for r in rows_of(T["orders"])}
    acc = defaultdict(lambda: [0, 0])
    for r in rows_of(T["lineitem"]):
        if (
            r["l_shipmode"] in ("MAIL", "SHIP")
            and r["l_commitdate"] < r["l_receiptdate"]
            and r["l_shipdate"] < r["l_commitdate"]
            and lo <= r["l_receiptdate"] < hi
        ):
            p = prio[r["l_orderkey"]]
            if p in ("1-URGENT", "2-HIGH"):
                acc[r["l_shipmode"]][0] += 1
            else:
                acc[r["l_shipmode"]][1] += 1
    return [
        {"l_shipmode": k, "high_line_count": v[0], "low_line_count": v[1]}
        for k, v in acc.items()
    ]


def q13(T, sf=1.0):
    per_cust = defaultdict(int)
    for r in rows_of(T["orders"]):
        if _not_exists_before(r["o_comment"], "special", "requests"):
            per_cust[r["o_custkey"]] += 1
    hist = defaultdict(int)
    for r in rows_of(T["customer"]):
        hist[per_cust.get(r["c_custkey"], 0)] += 1
    return [{"c_count": k, "custdist": v} for k, v in hist.items()]


def q14(T, sf=1.0):
    lo, hi = dd("1995-09-01"), dd("1995-10-01")
    ptype = {r["p_partkey"]: r["p_type"] for r in rows_of(T["part"])}
    promo = tot = 0.0
    for r in rows_of(T["lineitem"]):
        if lo <= r["l_shipdate"] < hi:
            rev = r["l_extendedprice"] * (1 - r["l_discount"])
            tot += rev
            if ptype[r["l_partkey"]].startswith("PROMO"):
                promo += rev
    return {"promo_revenue": 100.0 * promo / tot}


def q15(T, sf=1.0):
    lo, hi = dd("1996-01-01"), dd("1996-04-01")
    acc = defaultdict(float)
    for r in rows_of(T["lineitem"]):
        if lo <= r["l_shipdate"] < hi:
            acc[r["l_suppkey"]] += r["l_extendedprice"] * (1 - r["l_discount"])
    mx = max(acc.values()) if acc else 0.0
    supp = {r["s_suppkey"]: r for r in rows_of(T["supplier"])}
    out = []
    for sk, rev in acc.items():
        if rev == mx:
            s = supp[sk]
            out.append(
                {
                    "s_suppkey": sk,
                    "s_name": s["s_name"],
                    "s_address": s["s_address"],
                    "s_phone": s["s_phone"],
                    "total_revenue": rev,
                }
            )
    return out


def q16(T, sf=1.0):
    bad = {
        r["s_suppkey"]
        for r in rows_of(T["supplier"])
        if not _not_exists_before(r["s_comment"], "Customer", "Complaints")
    }
    sizes = {49, 14, 23, 45, 19, 3, 36, 9}
    parts = {
        r["p_partkey"]: r
        for r in rows_of(T["part"])
        if r["p_brand"] != "Brand#45"
        and not r["p_type"].startswith("MEDIUM POLISHED")
        and r["p_size"] in sizes
    }
    groups = defaultdict(set)
    for r in rows_of(T["partsupp"]):
        if r["ps_partkey"] in parts and r["ps_suppkey"] not in bad:
            p = parts[r["ps_partkey"]]
            groups[(p["p_brand"], p["p_type"], p["p_size"])].add(r["ps_suppkey"])
    return [
        {"p_brand": k[0], "p_type": k[1], "p_size": k[2], "supplier_cnt": len(v)}
        for k, v in groups.items()
    ]


def q17(T, sf=1.0):
    parts = {
        r["p_partkey"]
        for r in rows_of(T["part"])
        if r["p_brand"] == "Brand#23" and r["p_container"] == "MED BOX"
    }
    per_part = defaultdict(list)
    li = rows_of(T["lineitem"])
    for r in li:
        if r["l_partkey"] in parts:
            per_part[r["l_partkey"]].append(r["l_quantity"])
    avg = {k: sum(v) / len(v) for k, v in per_part.items()}
    tot = 0.0
    for r in li:
        pk = r["l_partkey"]
        if pk in parts and r["l_quantity"] < 0.2 * avg[pk]:
            tot += r["l_extendedprice"]
    return {"avg_yearly": tot / 7.0}


def q18(T, sf=1.0):
    qty = defaultdict(float)
    for r in rows_of(T["lineitem"]):
        qty[r["l_orderkey"]] += r["l_quantity"]
    big = {k: v for k, v in qty.items() if v > 300}
    cname = {r["c_custkey"]: r["c_name"] for r in rows_of(T["customer"])}
    out = []
    for r in rows_of(T["orders"]):
        if r["o_orderkey"] in big:
            out.append(
                {
                    "c_name": cname[r["o_custkey"]],
                    "o_custkey": r["o_custkey"],
                    "o_orderkey": r["o_orderkey"],
                    "o_orderdate": r["o_orderdate"],
                    "o_totalprice": r["o_totalprice"],
                    "sum_qty": big[r["o_orderkey"]],
                }
            )
    return out


def q19(T, sf=1.0):
    parts = {r["p_partkey"]: r for r in rows_of(T["part"])}
    tot = 0.0
    sm = {"SM CASE", "SM BOX", "SM PACK", "SM PKG"}
    med = {"MED BAG", "MED BOX", "MED PKG", "MED PACK"}
    lg = {"LG CASE", "LG BOX", "LG PACK", "LG PKG"}
    for r in rows_of(T["lineitem"]):
        if r["l_shipmode"] not in ("AIR", "AIR REG"):
            continue
        if r["l_shipinstruct"] != "DELIVER IN PERSON":
            continue
        p = parts[r["l_partkey"]]
        q = r["l_quantity"]
        ok = (
            (p["p_brand"] == "Brand#12" and p["p_container"] in sm and 1 <= q <= 11 and 1 <= p["p_size"] <= 5)
            or (p["p_brand"] == "Brand#23" and p["p_container"] in med and 10 <= q <= 20 and 1 <= p["p_size"] <= 10)
            or (p["p_brand"] == "Brand#34" and p["p_container"] in lg and 20 <= q <= 30 and 1 <= p["p_size"] <= 15)
        )
        if ok:
            tot += r["l_extendedprice"] * (1 - r["l_discount"])
    return {"revenue": tot}


def q20(T, sf=1.0):
    parts = {r["p_partkey"] for r in rows_of(T["part"]) if r["p_name"].startswith("forest")}
    lo, hi = dd("1994-01-01"), dd("1995-01-01")
    qty = defaultdict(float)
    for r in rows_of(T["lineitem"]):
        if lo <= r["l_shipdate"] < hi:
            qty[(r["l_partkey"], r["l_suppkey"])] += r["l_quantity"]
    ok_supp = set()
    for r in rows_of(T["partsupp"]):
        key = (r["ps_partkey"], r["ps_suppkey"])
        if r["ps_partkey"] in parts and key in qty and r["ps_availqty"] > 0.5 * qty[key]:
            ok_supp.add(r["ps_suppkey"])
    canada = {r["n_nationkey"] for r in rows_of(T["nation"]) if r["n_name"] == "CANADA"}
    return [
        {"s_name": r["s_name"], "s_address": r["s_address"]}
        for r in rows_of(T["supplier"])
        if r["s_nationkey"] in canada and r["s_suppkey"] in ok_supp
    ]


def q21(T, sf=1.0):
    saudi = {r["n_nationkey"] for r in rows_of(T["nation"]) if r["n_name"] == "SAUDI ARABIA"}
    sname = {
        r["s_suppkey"]: r["s_name"]
        for r in rows_of(T["supplier"])
        if r["s_nationkey"] in saudi
    }
    fstatus = {r["o_orderkey"] for r in rows_of(T["orders"]) if r["o_orderstatus"] == "F"}
    li = rows_of(T["lineitem"])
    supp_per_order = defaultdict(set)
    late_per_order = defaultdict(set)
    for r in li:
        supp_per_order[r["l_orderkey"]].add(r["l_suppkey"])
        if r["l_receiptdate"] > r["l_commitdate"]:
            late_per_order[r["l_orderkey"]].add(r["l_suppkey"])
    acc = defaultdict(int)
    for r in li:
        sk, ok = r["l_suppkey"], r["l_orderkey"]
        if sk not in sname or ok not in fstatus:
            continue
        if r["l_receiptdate"] <= r["l_commitdate"]:
            continue
        others = supp_per_order[ok] - {sk}
        if not others:
            continue
        late_others = late_per_order[ok] - {sk}
        if late_others:
            continue
        acc[sname[sk]] += 1
    return [{"s_name": k, "numwait": v} for k, v in acc.items()]


def q22(T, sf=1.0):
    codes = {"13", "31", "23", "29", "30", "18", "17"}
    cust = [
        r for r in rows_of(T["customer"]) if r["c_phone"][:2] in codes
    ]
    pos = [r["c_acctbal"] for r in cust if r["c_acctbal"] > 0]
    avg = sum(pos) / len(pos) if pos else 0.0
    has_orders = {r["o_custkey"] for r in rows_of(T["orders"])}
    acc = defaultdict(lambda: [0, 0.0])
    for r in cust:
        if r["c_acctbal"] > avg and r["c_custkey"] not in has_orders:
            a = acc[r["c_phone"][:2]]
            a[0] += 1
            a[1] += r["c_acctbal"]
    return [{"cntrycode": k, "numcust": v[0], "totacctbal": v[1]} for k, v in acc.items()]


ALL = {f"q{i}": globals()[f"q{i}"] for i in range(1, 23)}
