"""The paper's five TPC-DS queries (Fig. 9) in the TensorFrame API."""
from __future__ import annotations

from repro.core import col


def q3(t, sf=1.0, apply_limit=True):
    dt = t["date_dim"].filter(col("d_moy") == 11).select(["d_date_sk", "d_year"])
    it = t["item"].filter(col("i_manufact_id") == 128).select(
        ["i_item_sk", "i_brand_id", "i_brand"]
    )
    ss = t["store_sales"].select(["ss_sold_date_sk", "ss_item_sk", "ss_ext_sales_price"])
    j = ss.join(dt, left_on="ss_sold_date_sk", right_on="d_date_sk")
    j = j.join(it, left_on="ss_item_sk", right_on="i_item_sk")
    res = j.groupby(["d_year", "i_brand_id", "i_brand"]).agg(
        [("sum_agg", "sum", "ss_ext_sales_price")]
    )
    res = res.sort_values(
        ["d_year", "sum_agg", "i_brand_id"], ascending=[True, False, True]
    )
    return res.head(100) if apply_limit else res


def q6(t, sf=1.0, apply_limit=True):
    # scalar subquery 1: the month_seq of 2001-01
    seq_f = t["date_dim"].filter((col("d_year") == 2001) & (col("d_moy") == 1))
    month_seq = int(seq_f.column("d_month_seq")[0])
    dt = t["date_dim"].filter(col("d_month_seq") == month_seq).select(["d_date_sk"])
    # correlated subquery 2: category average price
    cat_avg = t["item"].groupby("i_category").agg([("cat_avg", "mean", "i_current_price")])
    it = t["item"].select(["i_item_sk", "i_category", "i_current_price"]).join(
        cat_avg, on="i_category"
    )
    it = it.filter(col("i_current_price") > 1.2 * col("cat_avg")).select(["i_item_sk"])
    ss = t["store_sales"].select(["ss_sold_date_sk", "ss_item_sk", "ss_customer_sk"])
    j = ss.join(dt, left_on="ss_sold_date_sk", right_on="d_date_sk", how="semi")
    j = j.join(it, left_on="ss_item_sk", right_on="i_item_sk", how="semi")
    j = j.join(
        t["customer"].select(["c_customer_sk", "c_current_addr_sk"]),
        left_on="ss_customer_sk",
        right_on="c_customer_sk",
    )
    j = j.join(
        t["customer_address"].select(["ca_address_sk", "ca_state"]),
        left_on="c_current_addr_sk",
        right_on="ca_address_sk",
    )
    res = j.groupby("ca_state").agg([("cnt", "size", "")])
    res = res.filter(col("cnt") >= 10).rename({"ca_state": "state"})
    res = res.sort_values(["cnt", "state"])
    return res.head(100) if apply_limit else res


def q7(t, sf=1.0, apply_limit=True):
    cd = t["customer_demographics"].filter(
        (col("cd_gender") == "M")
        & (col("cd_marital_status") == "S")
        & (col("cd_education_status") == "College")
    ).select(["cd_demo_sk"])
    dt = t["date_dim"].filter(col("d_year") == 2000).select(["d_date_sk"])
    pr = t["promotion"].filter(
        (col("p_channel_email") == "N") | (col("p_channel_event") == "N")
    ).select(["p_promo_sk"])
    ss = t["store_sales"].select(
        [
            "ss_sold_date_sk", "ss_item_sk", "ss_cdemo_sk", "ss_promo_sk",
            "ss_quantity", "ss_list_price", "ss_coupon_amt", "ss_sales_price",
        ]
    )
    j = ss.join(cd, left_on="ss_cdemo_sk", right_on="cd_demo_sk", how="semi")
    j = j.join(dt, left_on="ss_sold_date_sk", right_on="d_date_sk", how="semi")
    j = j.join(pr, left_on="ss_promo_sk", right_on="p_promo_sk", how="semi")
    j = j.join(t["item"].select(["i_item_sk", "i_item_id"]), left_on="ss_item_sk", right_on="i_item_sk")
    res = j.groupby("i_item_id").agg(
        [
            ("agg1", "mean", "ss_quantity"),
            ("agg2", "mean", "ss_list_price"),
            ("agg3", "mean", "ss_coupon_amt"),
            ("agg4", "mean", "ss_sales_price"),
        ]
    )
    res = res.sort_values("i_item_id")
    return res.head(100) if apply_limit else res


def q42(t, sf=1.0, apply_limit=True):
    dt = t["date_dim"].filter((col("d_moy") == 11) & (col("d_year") == 2000)).select(
        ["d_date_sk", "d_year"]
    )
    it = t["item"].filter(col("i_manager_id") == 1).select(
        ["i_item_sk", "i_category_id", "i_category"]
    )
    ss = t["store_sales"].select(["ss_sold_date_sk", "ss_item_sk", "ss_ext_sales_price"])
    j = ss.join(dt, left_on="ss_sold_date_sk", right_on="d_date_sk")
    j = j.join(it, left_on="ss_item_sk", right_on="i_item_sk")
    res = j.groupby(["d_year", "i_category_id", "i_category"]).agg(
        [("sum_agg", "sum", "ss_ext_sales_price")]
    )
    res = res.sort_values(
        ["sum_agg", "d_year", "i_category_id", "i_category"],
        ascending=[False, True, True, True],
    )
    return res.head(100) if apply_limit else res


def q96(t, sf=1.0, apply_limit=True):
    td = t["time_dim"].filter((col("t_hour") == 20) & (col("t_minute") >= 30)).select(["t_time_sk"])
    hd = t["household_demographics"].filter(col("hd_dep_count") == 7).select(["hd_demo_sk"])
    st = t["store"].filter(col("s_store_name") == "ese").select(["s_store_sk"])
    ss = t["store_sales"].select(["ss_sold_time_sk", "ss_hdemo_sk", "ss_store_sk"])
    j = ss.join(td, left_on="ss_sold_time_sk", right_on="t_time_sk", how="semi")
    j = j.join(hd, left_on="ss_hdemo_sk", right_on="hd_demo_sk", how="semi")
    j = j.join(st, left_on="ss_store_sk", right_on="s_store_sk", how="semi")
    return {"cnt": j.nrows}


ALL = {"q3": q3, "q6": q6, "q7": q7, "q42": q42, "q96": q96}
SCALAR_QUERIES = {"q96"}
