"""Reference TPC-DS implementations (row-at-a-time, independent)."""
from __future__ import annotations

from collections import defaultdict

from .tpch_numpy import rows_of


def q3(T, sf=1.0):
    dates = {r["d_date_sk"]: r["d_year"] for r in rows_of(T["date_dim"]) if r["d_moy"] == 11}
    items = {
        r["i_item_sk"]: (r["i_brand_id"], r["i_brand"])
        for r in rows_of(T["item"])
        if r["i_manufact_id"] == 128
    }
    acc = defaultdict(float)
    for r in rows_of(T["store_sales"]):
        if r["ss_sold_date_sk"] in dates and r["ss_item_sk"] in items:
            b = items[r["ss_item_sk"]]
            acc[(dates[r["ss_sold_date_sk"]], b[0], b[1])] += r["ss_ext_sales_price"]
    return [
        {"d_year": k[0], "i_brand_id": k[1], "i_brand": k[2], "sum_agg": v}
        for k, v in acc.items()
    ]


def q6(T, sf=1.0):
    seqs = {
        r["d_month_seq"]
        for r in rows_of(T["date_dim"])
        if r["d_year"] == 2001 and r["d_moy"] == 1
    }
    dates = {r["d_date_sk"] for r in rows_of(T["date_dim"]) if r["d_month_seq"] in seqs}
    items = rows_of(T["item"])
    cat_sum = defaultdict(float)
    cat_cnt = defaultdict(int)
    for r in items:
        cat_sum[r["i_category"]] += r["i_current_price"]
        cat_cnt[r["i_category"]] += 1
    pricey = {
        r["i_item_sk"]
        for r in items
        if r["i_current_price"] > 1.2 * cat_sum[r["i_category"]] / cat_cnt[r["i_category"]]
    }
    addr = {r["ca_address_sk"]: r["ca_state"] for r in rows_of(T["customer_address"])}
    cust = {r["c_customer_sk"]: addr[r["c_current_addr_sk"]] for r in rows_of(T["customer"])}
    acc = defaultdict(int)
    for r in rows_of(T["store_sales"]):
        if r["ss_sold_date_sk"] in dates and r["ss_item_sk"] in pricey:
            acc[cust[r["ss_customer_sk"]]] += 1
    return [{"state": k, "cnt": v} for k, v in acc.items() if v >= 10]


def q7(T, sf=1.0):
    cd = {
        r["cd_demo_sk"]
        for r in rows_of(T["customer_demographics"])
        if r["cd_gender"] == "M"
        and r["cd_marital_status"] == "S"
        and r["cd_education_status"] == "College"
    }
    dates = {r["d_date_sk"] for r in rows_of(T["date_dim"]) if r["d_year"] == 2000}
    promos = {
        r["p_promo_sk"]
        for r in rows_of(T["promotion"])
        if r["p_channel_email"] == "N" or r["p_channel_event"] == "N"
    }
    item_id = {r["i_item_sk"]: r["i_item_id"] for r in rows_of(T["item"])}
    acc = defaultdict(lambda: [0.0, 0.0, 0.0, 0.0, 0])
    for r in rows_of(T["store_sales"]):
        if (
            r["ss_cdemo_sk"] in cd
            and r["ss_sold_date_sk"] in dates
            and r["ss_promo_sk"] in promos
        ):
            a = acc[item_id[r["ss_item_sk"]]]
            a[0] += r["ss_quantity"]
            a[1] += r["ss_list_price"]
            a[2] += r["ss_coupon_amt"]
            a[3] += r["ss_sales_price"]
            a[4] += 1
    return [
        {
            "i_item_id": k,
            "agg1": v[0] / v[4],
            "agg2": v[1] / v[4],
            "agg3": v[2] / v[4],
            "agg4": v[3] / v[4],
        }
        for k, v in acc.items()
    ]


def q42(T, sf=1.0):
    dates = {
        r["d_date_sk"]: r["d_year"]
        for r in rows_of(T["date_dim"])
        if r["d_moy"] == 11 and r["d_year"] == 2000
    }
    items = {
        r["i_item_sk"]: (r["i_category_id"], r["i_category"])
        for r in rows_of(T["item"])
        if r["i_manager_id"] == 1
    }
    acc = defaultdict(float)
    for r in rows_of(T["store_sales"]):
        if r["ss_sold_date_sk"] in dates and r["ss_item_sk"] in items:
            c = items[r["ss_item_sk"]]
            acc[(dates[r["ss_sold_date_sk"]], c[0], c[1])] += r["ss_ext_sales_price"]
    return [
        {"d_year": k[0], "i_category_id": k[1], "i_category": k[2], "sum_agg": v}
        for k, v in acc.items()
    ]


def q96(T, sf=1.0):
    times = {
        r["t_time_sk"]
        for r in rows_of(T["time_dim"])
        if r["t_hour"] == 20 and r["t_minute"] >= 30
    }
    hd = {r["hd_demo_sk"] for r in rows_of(T["household_demographics"]) if r["hd_dep_count"] == 7}
    stores = {r["s_store_sk"] for r in rows_of(T["store"]) if r["s_store_name"] == "ese"}
    cnt = 0
    for r in rows_of(T["store_sales"]):
        if (
            r["ss_sold_time_sk"] in times
            and r["ss_hdemo_sk"] in hd
            and r["ss_store_sk"] in stores
        ):
            cnt += 1
    return {"cnt": cnt}


ALL = {"q3": q3, "q6": q6, "q7": q7, "q42": q42, "q96": q96}
