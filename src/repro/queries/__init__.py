"""TPC-H (all 22) and TPC-DS (5) queries, each in two independent
implementations: the TensorFrame API (tpch_frames / tpcds_frames) and a
row-at-a-time Python reference (tpch_numpy / tpcds_numpy) used for
correctness testing."""
