"""TPC-H (all 22) and TPC-DS (5) queries, each in two independent
implementations: the TensorFrame API (tpch_frames / tpcds_frames) and a
row-at-a-time Python reference (tpch_numpy / tpcds_numpy) used for
correctness testing.  ``tpch_sql`` carries the SQL text of the queries
expressible through the ``repro.sql`` front-end.

This module is also the table registry for SQL scope lookup: benchmark
table-sets are registered by name so ``repro.sql.execute(query,
scope("tpch", sf=0.01))`` works without hand-assembling frame dicts.
"""
from __future__ import annotations

from typing import Callable, Dict

_SCOPE_LOADERS: Dict[str, Callable] = {}


def register_scope(name: str, loader: Callable) -> None:
    """Register a named table-set loader: ``loader(**kwargs) -> dict of
    TensorFrames keyed by table name``."""
    _SCOPE_LOADERS[name] = loader


def scope(name: str, **kwargs):
    """Build the named scope (e.g. ``scope("tpch", sf=0.01, seed=0)``)."""
    if name not in _SCOPE_LOADERS:
        raise KeyError(
            f"unknown scope {name!r}; registered: {sorted(_SCOPE_LOADERS)}"
        )
    return _SCOPE_LOADERS[name](**kwargs)


def _tpch_scope(sf: float = 0.01, seed: int = 0):
    from repro.data import tpch

    return tpch.as_frames(tpch.generate(sf=sf, seed=seed))


def _tpcds_scope(sf: float = 0.01, seed: int = 1):
    from repro.data import tpcds

    return tpcds.as_frames(tpcds.generate(sf=sf, seed=seed))


register_scope("tpch", _tpch_scope)
register_scope("tpcds", _tpcds_scope)
