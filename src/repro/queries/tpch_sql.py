"""All 22 TPC-H queries as SQL text for the ``repro.sql`` front-end.

Column aliases match the hand-written plans' output names so the
differential tests can compare all three engines row-for-row.  The 11
single-block queries ride the base dialect; the other 11 use the
subquery forms added in PR 2: scalar subqueries (q2, q11, q15, q17,
q20, q22), ``IN``/``NOT IN (SELECT ...)`` (q16, q18, q20),
``EXISTS``/``NOT EXISTS`` incl. correlated ``<>`` residuals (q4, q21,
q22), and derived tables in FROM (q13, q15).

Conventions forced by the dialect:

- LIMIT clauses are omitted: sort ties make LIMIT non-deterministic
  across engines, and the reference tests compare full result sets
  (same convention as ``tpch_frames(..., apply_limit=False)``).
- ``INTERVAL '3' MONTH``-style calendar arithmetic is written as
  explicit DATE bounds (the dialect refuses approximate month math).
- Subquery aliases are distinct from enclosing aliases (the planner
  rejects shadowing so correlated references stay unambiguous).
- Cheap predicates come before EXISTS/IN conjuncts: the oracle's
  nested-loop interpreter short-circuits ANDs left-to-right.
- q11's threshold fraction is scale-dependent (0.0001/SF per the TPC-H
  spec); use ``sql_text("q11", sf=...)`` for non-unit scale factors.
"""
from __future__ import annotations

TPCH_SQL = {
    "q1": """
        SELECT l_returnflag, l_linestatus,
               SUM(l_quantity) AS sum_qty,
               SUM(l_extendedprice) AS sum_base_price,
               SUM(l_extendedprice * (1 - l_discount)) AS sum_disc_price,
               SUM(l_extendedprice * (1 - l_discount) * (1 + l_tax)) AS sum_charge,
               AVG(l_quantity) AS avg_qty,
               AVG(l_extendedprice) AS avg_price,
               AVG(l_discount) AS avg_disc,
               COUNT(*) AS count_order
        FROM lineitem
        WHERE l_shipdate <= DATE '1998-12-01' - INTERVAL '90' DAY
        GROUP BY l_returnflag, l_linestatus
        ORDER BY l_returnflag, l_linestatus
    """,
    "q2": """
        SELECT s_acctbal, s_name, n_name, p_partkey, p_mfgr, s_address,
               s_phone, s_comment
        FROM part, supplier, partsupp, nation, region
        WHERE p_partkey = ps_partkey AND s_suppkey = ps_suppkey
          AND p_size = 15 AND p_type LIKE '%BRASS'
          AND s_nationkey = n_nationkey AND n_regionkey = r_regionkey
          AND r_name = 'EUROPE'
          AND ps_supplycost = (
            SELECT MIN(ps2.ps_supplycost)
            FROM partsupp ps2, supplier s2, nation n2, region r2
            WHERE p_partkey = ps2.ps_partkey AND s2.s_suppkey = ps2.ps_suppkey
              AND s2.s_nationkey = n2.n_nationkey
              AND n2.n_regionkey = r2.r_regionkey AND r2.r_name = 'EUROPE')
        ORDER BY s_acctbal DESC, n_name, s_name, p_partkey
    """,
    "q3": """
        SELECT l_orderkey, o_orderdate, o_shippriority,
               SUM(l_extendedprice * (1 - l_discount)) AS revenue
        FROM customer, orders, lineitem
        WHERE c_mktsegment = 'BUILDING'
          AND c_custkey = o_custkey AND l_orderkey = o_orderkey
          AND o_orderdate < DATE '1995-03-15'
          AND l_shipdate > DATE '1995-03-15'
        GROUP BY l_orderkey, o_orderdate, o_shippriority
        ORDER BY revenue DESC, o_orderdate
    """,
    "q4": """
        SELECT o_orderpriority, COUNT(*) AS order_count
        FROM orders
        WHERE o_orderdate >= DATE '1993-07-01'
          AND o_orderdate < DATE '1993-10-01'
          AND EXISTS (
            SELECT * FROM lineitem
            WHERE l_orderkey = o_orderkey AND l_commitdate < l_receiptdate)
        GROUP BY o_orderpriority
        ORDER BY o_orderpriority
    """,
    "q5": """
        SELECT n_name, SUM(l_extendedprice * (1 - l_discount)) AS revenue
        FROM customer, orders, lineitem, supplier, nation, region
        WHERE c_custkey = o_custkey AND l_orderkey = o_orderkey
          AND l_suppkey = s_suppkey AND c_nationkey = s_nationkey
          AND s_nationkey = n_nationkey AND n_regionkey = r_regionkey
          AND r_name = 'ASIA'
          AND o_orderdate >= DATE '1994-01-01'
          AND o_orderdate < DATE '1995-01-01'
        GROUP BY n_name
        ORDER BY revenue DESC
    """,
    "q6": """
        SELECT SUM(l_extendedprice * l_discount) AS revenue
        FROM lineitem
        WHERE l_shipdate >= DATE '1994-01-01'
          AND l_shipdate < DATE '1994-01-01' + INTERVAL '365' DAY
          AND l_discount BETWEEN 0.05 AND 0.07
          AND l_quantity < 24
    """,
    "q7": """
        SELECT n1.n_name AS supp_nation, n2.n_name AS cust_nation,
               EXTRACT(YEAR FROM l_shipdate) AS l_year,
               SUM(l_extendedprice * (1 - l_discount)) AS revenue
        FROM supplier, lineitem, orders, customer, nation n1, nation n2
        WHERE s_suppkey = l_suppkey AND o_orderkey = l_orderkey
          AND c_custkey = o_custkey
          AND s_nationkey = n1.n_nationkey AND c_nationkey = n2.n_nationkey
          AND ((n1.n_name = 'FRANCE' AND n2.n_name = 'GERMANY')
            OR (n1.n_name = 'GERMANY' AND n2.n_name = 'FRANCE'))
          AND l_shipdate BETWEEN DATE '1995-01-01' AND DATE '1996-12-31'
        GROUP BY supp_nation, cust_nation, l_year
        ORDER BY supp_nation, cust_nation, l_year
    """,
    "q8": """
        SELECT EXTRACT(YEAR FROM o_orderdate) AS o_year,
               SUM(CASE WHEN n2.n_name = 'BRAZIL'
                        THEN l_extendedprice * (1 - l_discount)
                        ELSE 0.0 END)
                 / SUM(l_extendedprice * (1 - l_discount)) AS mkt_share
        FROM part, lineitem, orders, customer, nation n1, region, supplier,
             nation n2
        WHERE p_partkey = l_partkey AND o_orderkey = l_orderkey
          AND c_custkey = o_custkey
          AND n1.n_nationkey = c_nationkey AND r_regionkey = n1.n_regionkey
          AND s_suppkey = l_suppkey AND n2.n_nationkey = s_nationkey
          AND r_name = 'AMERICA' AND p_type = 'ECONOMY ANODIZED STEEL'
          AND o_orderdate BETWEEN DATE '1995-01-01' AND DATE '1996-12-31'
        GROUP BY o_year
        ORDER BY o_year
    """,
    "q9": """
        SELECT n_name, EXTRACT(YEAR FROM o_orderdate) AS o_year,
               SUM(l_extendedprice * (1 - l_discount)
                   - ps_supplycost * l_quantity) AS sum_profit
        FROM part, supplier, lineitem, partsupp, orders, nation
        WHERE s_suppkey = l_suppkey AND ps_suppkey = l_suppkey
          AND ps_partkey = l_partkey AND p_partkey = l_partkey
          AND o_orderkey = l_orderkey AND s_nationkey = n_nationkey
          AND p_name LIKE '%green%'
        GROUP BY n_name, o_year
        ORDER BY n_name, o_year DESC
    """,
    "q10": """
        SELECT o_custkey, c_name, c_acctbal, c_phone, n_name, c_address,
               c_comment,
               SUM(l_extendedprice * (1 - l_discount)) AS revenue
        FROM customer, orders, lineitem, nation
        WHERE c_custkey = o_custkey AND l_orderkey = o_orderkey
          AND c_nationkey = n_nationkey
          AND o_orderdate >= DATE '1993-10-01'
          AND o_orderdate < DATE '1994-01-01'
          AND l_returnflag = 'R'
        GROUP BY o_custkey, c_name, c_acctbal, c_phone, n_name, c_address,
                 c_comment
        ORDER BY revenue DESC
    """,
    "q11": """
        SELECT ps_partkey, SUM(ps_supplycost * ps_availqty) AS value
        FROM partsupp, supplier, nation
        WHERE ps_suppkey = s_suppkey AND s_nationkey = n_nationkey
          AND n_name = 'GERMANY'
        GROUP BY ps_partkey
        HAVING SUM(ps_supplycost * ps_availqty) > (
            SELECT SUM(ps2.ps_supplycost * ps2.ps_availqty) * {q11_fraction}
            FROM partsupp ps2, supplier s2, nation n2
            WHERE ps2.ps_suppkey = s2.s_suppkey
              AND s2.s_nationkey = n2.n_nationkey AND n2.n_name = 'GERMANY')
        ORDER BY value DESC
    """,
    "q12": """
        SELECT l_shipmode,
               SUM(CASE WHEN o_orderpriority = '1-URGENT'
                          OR o_orderpriority = '2-HIGH'
                        THEN 1 ELSE 0 END) AS high_line_count,
               SUM(CASE WHEN o_orderpriority <> '1-URGENT'
                         AND o_orderpriority <> '2-HIGH'
                        THEN 1 ELSE 0 END) AS low_line_count
        FROM orders, lineitem
        WHERE o_orderkey = l_orderkey AND l_shipmode IN ('MAIL', 'SHIP')
          AND l_commitdate < l_receiptdate AND l_shipdate < l_commitdate
          AND l_receiptdate >= DATE '1994-01-01'
          AND l_receiptdate < DATE '1995-01-01'
        GROUP BY l_shipmode
        ORDER BY l_shipmode
    """,
    "q13": """
        SELECT c_count, COUNT(*) AS custdist
        FROM (SELECT c_custkey, COUNT(o_orderkey) AS c_count
              FROM customer LEFT JOIN orders
                ON c_custkey = o_custkey
               AND o_comment NOT LIKE '%special%requests%'
              GROUP BY c_custkey) c_orders
        GROUP BY c_count
        ORDER BY custdist DESC, c_count DESC
    """,
    "q14": """
        SELECT 100.00 * SUM(CASE WHEN p_type LIKE 'PROMO%'
                                 THEN l_extendedprice * (1 - l_discount)
                                 ELSE 0.0 END)
               / SUM(l_extendedprice * (1 - l_discount)) AS promo_revenue
        FROM lineitem, part
        WHERE l_partkey = p_partkey
          AND l_shipdate >= DATE '1995-09-01'
          AND l_shipdate < DATE '1995-10-01'
    """,
    "q15": """
        SELECT s_suppkey, s_name, s_address, s_phone, total_revenue
        FROM supplier,
             (SELECT l_suppkey,
                     SUM(l_extendedprice * (1 - l_discount)) AS total_revenue
              FROM lineitem
              WHERE l_shipdate >= DATE '1996-01-01'
                AND l_shipdate < DATE '1996-04-01'
              GROUP BY l_suppkey) revenue0
        WHERE s_suppkey = l_suppkey
          AND total_revenue = (
            SELECT MAX(r1.total_revenue)
            FROM (SELECT l2.l_suppkey AS supplier_no,
                         SUM(l2.l_extendedprice * (1 - l2.l_discount))
                           AS total_revenue
                  FROM lineitem l2
                  WHERE l2.l_shipdate >= DATE '1996-01-01'
                    AND l2.l_shipdate < DATE '1996-04-01'
                  GROUP BY l2.l_suppkey) r1)
        ORDER BY s_suppkey
    """,
    "q16": """
        SELECT p_brand, p_type, p_size,
               COUNT(DISTINCT ps_suppkey) AS supplier_cnt
        FROM partsupp, part
        WHERE p_partkey = ps_partkey
          AND p_brand <> 'Brand#45'
          AND p_type NOT LIKE 'MEDIUM POLISHED%'
          AND p_size IN (49, 14, 23, 45, 19, 3, 36, 9)
          AND ps_suppkey NOT IN (
            SELECT s_suppkey FROM supplier
            WHERE s_comment LIKE '%Customer%Complaints%')
        GROUP BY p_brand, p_type, p_size
        ORDER BY supplier_cnt DESC, p_brand, p_type, p_size
    """,
    "q17": """
        SELECT SUM(l_extendedprice) / 7.0 AS avg_yearly
        FROM lineitem, part
        WHERE p_partkey = l_partkey
          AND p_brand = 'Brand#23' AND p_container = 'MED BOX'
          AND l_quantity < (
            SELECT 0.2 * AVG(l2.l_quantity) FROM lineitem l2
            WHERE l2.l_partkey = p_partkey)
    """,
    "q18": """
        SELECT c_name, o_custkey, o_orderkey, o_orderdate, o_totalprice,
               SUM(l_quantity) AS sum_qty
        FROM customer, orders, lineitem
        WHERE c_custkey = o_custkey AND o_orderkey = l_orderkey
          AND o_orderkey IN (
            SELECT l2.l_orderkey FROM lineitem l2
            GROUP BY l2.l_orderkey HAVING SUM(l2.l_quantity) > 300)
        GROUP BY c_name, o_custkey, o_orderkey, o_orderdate, o_totalprice
        ORDER BY o_totalprice DESC, o_orderdate
    """,
    "q19": """
        SELECT SUM(l_extendedprice * (1 - l_discount)) AS revenue
        FROM lineitem, part
        WHERE p_partkey = l_partkey
          AND l_shipinstruct = 'DELIVER IN PERSON'
          AND l_shipmode IN ('AIR', 'AIR REG')
          AND ((p_brand = 'Brand#12'
                AND p_container IN ('SM CASE', 'SM BOX', 'SM PACK', 'SM PKG')
                AND l_quantity BETWEEN 1 AND 11 AND p_size BETWEEN 1 AND 5)
            OR (p_brand = 'Brand#23'
                AND p_container IN ('MED BAG', 'MED BOX', 'MED PKG', 'MED PACK')
                AND l_quantity BETWEEN 10 AND 20 AND p_size BETWEEN 1 AND 10)
            OR (p_brand = 'Brand#34'
                AND p_container IN ('LG CASE', 'LG BOX', 'LG PACK', 'LG PKG')
                AND l_quantity BETWEEN 20 AND 30 AND p_size BETWEEN 1 AND 15))
    """,
    "q20": """
        SELECT s_name, s_address
        FROM supplier, nation
        WHERE s_nationkey = n_nationkey AND n_name = 'CANADA'
          AND s_suppkey IN (
            SELECT ps_suppkey FROM partsupp
            WHERE ps_partkey IN (
                SELECT p_partkey FROM part WHERE p_name LIKE 'forest%')
              AND ps_availqty > (
                SELECT 0.5 * SUM(l_quantity) FROM lineitem
                WHERE l_partkey = ps_partkey AND l_suppkey = ps_suppkey
                  AND l_shipdate >= DATE '1994-01-01'
                  AND l_shipdate < DATE '1995-01-01'))
        ORDER BY s_name
    """,
    "q21": """
        SELECT s_name, COUNT(*) AS numwait
        FROM supplier, lineitem l1, orders, nation
        WHERE s_suppkey = l1.l_suppkey AND o_orderkey = l1.l_orderkey
          AND s_nationkey = n_nationkey AND n_name = 'SAUDI ARABIA'
          AND o_orderstatus = 'F'
          AND l1.l_receiptdate > l1.l_commitdate
          AND EXISTS (
            SELECT * FROM lineitem l2
            WHERE l2.l_orderkey = l1.l_orderkey
              AND l2.l_suppkey <> l1.l_suppkey)
          AND NOT EXISTS (
            SELECT * FROM lineitem l3
            WHERE l3.l_orderkey = l1.l_orderkey
              AND l3.l_suppkey <> l1.l_suppkey
              AND l3.l_receiptdate > l3.l_commitdate)
        GROUP BY s_name
        ORDER BY numwait DESC, s_name
    """,
    "q22": """
        SELECT SUBSTRING(c_phone, 1, 2) AS cntrycode, COUNT(*) AS numcust,
               SUM(c_acctbal) AS totacctbal
        FROM customer
        WHERE SUBSTRING(c_phone, 1, 2)
                IN ('13', '31', '23', '29', '30', '18', '17')
          AND c_acctbal > (
            SELECT AVG(c2.c_acctbal) FROM customer c2
            WHERE c2.c_acctbal > 0.00
              AND SUBSTRING(c2.c_phone, 1, 2)
                    IN ('13', '31', '23', '29', '30', '18', '17'))
          AND NOT EXISTS (
            SELECT * FROM orders WHERE o_custkey = c_custkey)
        GROUP BY cntrycode
        ORDER BY cntrycode
    """,
}


_Q11_TEMPLATE = TPCH_SQL["q11"]


def sql_text(qname: str, sf: float = 1.0) -> str:
    """SQL text of a TPC-H query at scale factor ``sf``.

    Only q11 is scale-dependent (its HAVING threshold fraction is
    0.0001/SF per the TPC-H spec); every other query returns the
    ``TPCH_SQL`` entry verbatim."""
    if qname == "q11":
        return _Q11_TEMPLATE.replace("{q11_fraction}", f"{0.0001 / sf:.12f}")
    return TPCH_SQL[qname]


# the plain dict entry carries the SF=1 threshold so every TPCH_SQL
# text is directly executable
TPCH_SQL["q11"] = sql_text("q11", 1.0)


# queries whose SQL form returns a single aggregate row
SCALAR_SQL = {"q6", "q14", "q17", "q19"}
