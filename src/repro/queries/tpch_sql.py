"""TPC-H queries as SQL text for the ``repro.sql`` front-end.

Eleven of the 22 queries are expressible in the supported dialect
(single SELECT block — no subqueries yet); the rest need correlated or
scalar subqueries and stay hand-written in ``tpch_frames``.  Column
aliases match the hand-written plans' output names so the differential
tests can compare all three engines row-for-row.

LIMIT clauses are omitted: sort ties make LIMIT non-deterministic
across engines, and the reference tests compare full result sets
(same convention as ``tpch_frames(..., apply_limit=False)``).
"""
from __future__ import annotations

TPCH_SQL = {
    "q1": """
        SELECT l_returnflag, l_linestatus,
               SUM(l_quantity) AS sum_qty,
               SUM(l_extendedprice) AS sum_base_price,
               SUM(l_extendedprice * (1 - l_discount)) AS sum_disc_price,
               SUM(l_extendedprice * (1 - l_discount) * (1 + l_tax)) AS sum_charge,
               AVG(l_quantity) AS avg_qty,
               AVG(l_extendedprice) AS avg_price,
               AVG(l_discount) AS avg_disc,
               COUNT(*) AS count_order
        FROM lineitem
        WHERE l_shipdate <= DATE '1998-12-01' - INTERVAL '90' DAY
        GROUP BY l_returnflag, l_linestatus
        ORDER BY l_returnflag, l_linestatus
    """,
    "q3": """
        SELECT l_orderkey, o_orderdate, o_shippriority,
               SUM(l_extendedprice * (1 - l_discount)) AS revenue
        FROM customer, orders, lineitem
        WHERE c_mktsegment = 'BUILDING'
          AND c_custkey = o_custkey AND l_orderkey = o_orderkey
          AND o_orderdate < DATE '1995-03-15'
          AND l_shipdate > DATE '1995-03-15'
        GROUP BY l_orderkey, o_orderdate, o_shippriority
        ORDER BY revenue DESC, o_orderdate
    """,
    "q5": """
        SELECT n_name, SUM(l_extendedprice * (1 - l_discount)) AS revenue
        FROM customer, orders, lineitem, supplier, nation, region
        WHERE c_custkey = o_custkey AND l_orderkey = o_orderkey
          AND l_suppkey = s_suppkey AND c_nationkey = s_nationkey
          AND s_nationkey = n_nationkey AND n_regionkey = r_regionkey
          AND r_name = 'ASIA'
          AND o_orderdate >= DATE '1994-01-01'
          AND o_orderdate < DATE '1995-01-01'
        GROUP BY n_name
        ORDER BY revenue DESC
    """,
    "q6": """
        SELECT SUM(l_extendedprice * l_discount) AS revenue
        FROM lineitem
        WHERE l_shipdate >= DATE '1994-01-01'
          AND l_shipdate < DATE '1994-01-01' + INTERVAL '365' DAY
          AND l_discount BETWEEN 0.05 AND 0.07
          AND l_quantity < 24
    """,
    "q7": """
        SELECT n1.n_name AS supp_nation, n2.n_name AS cust_nation,
               EXTRACT(YEAR FROM l_shipdate) AS l_year,
               SUM(l_extendedprice * (1 - l_discount)) AS revenue
        FROM supplier, lineitem, orders, customer, nation n1, nation n2
        WHERE s_suppkey = l_suppkey AND o_orderkey = l_orderkey
          AND c_custkey = o_custkey
          AND s_nationkey = n1.n_nationkey AND c_nationkey = n2.n_nationkey
          AND ((n1.n_name = 'FRANCE' AND n2.n_name = 'GERMANY')
            OR (n1.n_name = 'GERMANY' AND n2.n_name = 'FRANCE'))
          AND l_shipdate BETWEEN DATE '1995-01-01' AND DATE '1996-12-31'
        GROUP BY supp_nation, cust_nation, l_year
        ORDER BY supp_nation, cust_nation, l_year
    """,
    "q8": """
        SELECT EXTRACT(YEAR FROM o_orderdate) AS o_year,
               SUM(CASE WHEN n2.n_name = 'BRAZIL'
                        THEN l_extendedprice * (1 - l_discount)
                        ELSE 0.0 END)
                 / SUM(l_extendedprice * (1 - l_discount)) AS mkt_share
        FROM part, lineitem, orders, customer, nation n1, region, supplier,
             nation n2
        WHERE p_partkey = l_partkey AND o_orderkey = l_orderkey
          AND c_custkey = o_custkey
          AND n1.n_nationkey = c_nationkey AND r_regionkey = n1.n_regionkey
          AND s_suppkey = l_suppkey AND n2.n_nationkey = s_nationkey
          AND r_name = 'AMERICA' AND p_type = 'ECONOMY ANODIZED STEEL'
          AND o_orderdate BETWEEN DATE '1995-01-01' AND DATE '1996-12-31'
        GROUP BY o_year
        ORDER BY o_year
    """,
    "q9": """
        SELECT n_name, EXTRACT(YEAR FROM o_orderdate) AS o_year,
               SUM(l_extendedprice * (1 - l_discount)
                   - ps_supplycost * l_quantity) AS sum_profit
        FROM part, supplier, lineitem, partsupp, orders, nation
        WHERE s_suppkey = l_suppkey AND ps_suppkey = l_suppkey
          AND ps_partkey = l_partkey AND p_partkey = l_partkey
          AND o_orderkey = l_orderkey AND s_nationkey = n_nationkey
          AND p_name LIKE '%green%'
        GROUP BY n_name, o_year
        ORDER BY n_name, o_year DESC
    """,
    "q10": """
        SELECT o_custkey, c_name, c_acctbal, c_phone, n_name, c_address,
               c_comment,
               SUM(l_extendedprice * (1 - l_discount)) AS revenue
        FROM customer, orders, lineitem, nation
        WHERE c_custkey = o_custkey AND l_orderkey = o_orderkey
          AND c_nationkey = n_nationkey
          AND o_orderdate >= DATE '1993-10-01'
          AND o_orderdate < DATE '1994-01-01'
          AND l_returnflag = 'R'
        GROUP BY o_custkey, c_name, c_acctbal, c_phone, n_name, c_address,
                 c_comment
        ORDER BY revenue DESC
    """,
    "q12": """
        SELECT l_shipmode,
               SUM(CASE WHEN o_orderpriority = '1-URGENT'
                          OR o_orderpriority = '2-HIGH'
                        THEN 1 ELSE 0 END) AS high_line_count,
               SUM(CASE WHEN o_orderpriority <> '1-URGENT'
                         AND o_orderpriority <> '2-HIGH'
                        THEN 1 ELSE 0 END) AS low_line_count
        FROM orders, lineitem
        WHERE o_orderkey = l_orderkey AND l_shipmode IN ('MAIL', 'SHIP')
          AND l_commitdate < l_receiptdate AND l_shipdate < l_commitdate
          AND l_receiptdate >= DATE '1994-01-01'
          AND l_receiptdate < DATE '1995-01-01'
        GROUP BY l_shipmode
        ORDER BY l_shipmode
    """,
    "q14": """
        SELECT 100.00 * SUM(CASE WHEN p_type LIKE 'PROMO%'
                                 THEN l_extendedprice * (1 - l_discount)
                                 ELSE 0.0 END)
               / SUM(l_extendedprice * (1 - l_discount)) AS promo_revenue
        FROM lineitem, part
        WHERE l_partkey = p_partkey
          AND l_shipdate >= DATE '1995-09-01'
          AND l_shipdate < DATE '1995-10-01'
    """,
    "q19": """
        SELECT SUM(l_extendedprice * (1 - l_discount)) AS revenue
        FROM lineitem, part
        WHERE p_partkey = l_partkey
          AND l_shipinstruct = 'DELIVER IN PERSON'
          AND l_shipmode IN ('AIR', 'AIR REG')
          AND ((p_brand = 'Brand#12'
                AND p_container IN ('SM CASE', 'SM BOX', 'SM PACK', 'SM PKG')
                AND l_quantity BETWEEN 1 AND 11 AND p_size BETWEEN 1 AND 5)
            OR (p_brand = 'Brand#23'
                AND p_container IN ('MED BAG', 'MED BOX', 'MED PKG', 'MED PACK')
                AND l_quantity BETWEEN 10 AND 20 AND p_size BETWEEN 1 AND 10)
            OR (p_brand = 'Brand#34'
                AND p_container IN ('LG CASE', 'LG BOX', 'LG PACK', 'LG PKG')
                AND l_quantity BETWEEN 20 AND 30 AND p_size BETWEEN 1 AND 15))
    """,
}

# queries whose SQL form returns a single aggregate row
SCALAR_SQL = {"q6", "q14", "q19"}
