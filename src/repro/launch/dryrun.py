import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape x mesh) cell:
  jax.jit(step, in_shardings, out_shardings).lower(**input_specs).compile()
then record memory_analysis / cost_analysis / parsed collective bytes
into a JSON artifact consumed by benchmarks/bench_roofline.py and
EXPERIMENTS.md.

The two lines ABOVE the docstring run before any jax import: jax locks
the device count at first init, and the production meshes need 512
placeholder CPU devices.  (Do not set this flag globally — smoke tests
and benches must see 1 device.)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out artifacts/]
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax

from repro.configs import ARCHS, get
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, cell_applicable, input_specs
from repro.models import lm, partition
from repro.train.train_step import make_train_step

# TPU v5e roofline constants
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link
HBM_PER_CHIP = 16 * 1024**3


def run_cell(arch: str, shape: str, multi_pod: bool, extra: Dict[str, Any] | None = None) -> Dict[str, Any]:
    cfg = get(arch)
    if extra:
        import dataclasses

        cfg = dataclasses.replace(cfg, **extra)
    ok, why = cell_applicable(cfg, shape)
    cell = {
        "arch": arch,
        "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
    }
    if not ok:
        cell.update({"status": "skipped", "reason": why})
        return cell

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    specs = input_specs(cfg, shape)
    kind = specs["kind"]
    t0 = time.time()

    with mesh:
        pspecs = partition.param_specs(
            jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0))), cfg
        )
        if kind == "train":
            from repro.train.optimizer import get_optimizer

            opt = get_optimizer(cfg.optimizer)
            state_specs = {
                "params": pspecs,
                "opt": opt.state_specs(pspecs, jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))),
                "step": jax.sharding.PartitionSpec(),
            }
            step = make_train_step(cfg)
        elif kind == "prefill":
            state_specs = pspecs
            step = lambda params, batch: lm.prefill(cfg, params, batch)
        else:  # decode
            state_specs = partition.decode_state_specs(mesh, specs["state"])
            step = None  # built below with params closed over specs

        batch_sp = partition.batch_specs(mesh, specs["batch"])
        if kind == "decode":
            # decode step signature: (params, state, batch)
            def step(params, state, batch):  # noqa: F811
                return lm.decode_step(cfg, params, state, batch)

            jitted = jax.jit(
                step,
                in_shardings=(
                    partition.shardings_from_specs(mesh, pspecs),
                    partition.shardings_from_specs(mesh, state_specs),
                    partition.shardings_from_specs(mesh, batch_sp),
                ),
            )
            params_shape = jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
            lowered = jitted.lower(params_shape, specs["state"], specs["batch"])
        else:
            jitted = jax.jit(
                step,
                in_shardings=(
                    partition.shardings_from_specs(mesh, state_specs),
                    partition.shardings_from_specs(mesh, batch_sp),
                ),
            )
            lowered = jitted.lower(specs["state"], specs["batch"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older JAX: one entry per computation
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    ha = hlo_analysis.analyze(hlo)
    coll = {k[len("coll_"):]: int(v) for k, v in ha.items() if k.startswith("coll_")}

    # cost_analysis is per-device and counts while (scan) bodies once;
    # the HLO walk trip-weights dots, so take the max of both estimates
    flops_per_device = max(float(cost.get("flops", 0.0)), float(ha.get("dot_flops", 0.0)))
    bytes_per_device = max(
        float(cost.get("bytes accessed", 0.0)), float(ha.get("dot_bytes", 0.0))
    )
    coll_total = int(coll.get("total", 0))

    sp = SHAPES[shape]
    tokens = sp.global_batch * (sp.seq_len if kind != "decode" else 1)
    n_par = cfg.param_count()
    n_act = cfg.active_param_count()
    model_flops = (6 if kind == "train" else 2) * n_act * tokens

    compute_t = flops_per_device / PEAK_FLOPS
    memory_t = bytes_per_device / HBM_BW
    collective_t = coll_total / (chips * ICI_BW)

    def _mem(attr):
        v = getattr(mem, attr, None)
        return int(v) if v is not None else None

    cell.update(
        {
            "status": "ok",
            "kind": kind,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "flops_per_device": flops_per_device,
            "bytes_per_device": bytes_per_device,
            "collective_bytes_total": coll_total,
            "collectives": {k: v for k, v in coll.items() if k != "total"},
            "cost_analysis_flops": float(cost.get("flops", 0.0)),
            "dot_flops_trip_weighted": float(ha.get("dot_flops", 0.0)),
            "giant_intermediate_bytes": float(ha.get("giant_bytes", 0.0)),
            "memory_s_fused_kernels": max(
                0.0, (bytes_per_device - float(ha.get("giant_bytes", 0.0))) / HBM_BW
            ),
            "memory": {
                "argument_bytes": _mem("argument_size_in_bytes"),
                "output_bytes": _mem("output_size_in_bytes"),
                "temp_bytes": _mem("temp_size_in_bytes"),
                "peak_bytes": _mem("peak_memory_in_bytes"),
            },
            "tokens": tokens,
            "params": n_par,
            "active_params": n_act,
            "model_flops": model_flops,
            "roofline": {
                "compute_s": compute_t,
                "memory_s": memory_t,
                "collective_s": collective_t,
                "dominant": max(
                    [("compute", compute_t), ("memory", memory_t), ("collective", collective_t)],
                    key=lambda kv: kv[1],
                )[0],
                "useful_flops_ratio": (
                    model_flops / (flops_per_device * chips)
                    if flops_per_device
                    else None
                ),
            },
        }
    )
    return cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--override", default="", help="k=v,... ModelConfig overrides")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    extra: Dict[str, Any] = {}
    for kv in filter(None, args.override.split(",")):
        k, v = kv.split("=")
        try:
            extra[k] = int(v)
        except ValueError:
            try:
                extra[k] = float(v)
            except ValueError:
                extra[k] = v

    cells = []
    if args.all:
        targets = [(a, s) for a in ARCHS for s in SHAPES]
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        targets = [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    for arch, shape in targets:
        for mp in meshes:
            name = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
            if args.tag:
                name += f"__{args.tag}"
            path = os.path.join(args.out, name + ".json")
            try:
                cell = run_cell(arch, shape, mp, extra or None)
            except Exception as e:  # noqa: BLE001
                cell = {
                    "arch": arch, "shape": shape,
                    "mesh": "2x16x16" if mp else "16x16",
                    "status": "error",
                    "error": f"{type(e).__name__}: {e}",
                    "trace": traceback.format_exc()[-4000:],
                }
            with open(path, "w") as f:
                json.dump(cell, f, indent=1)
            cells.append(cell)
            st = cell["status"]
            ro = cell.get("roofline", {})
            print(
                f"[{st:7s}] {name} "
                f"compile={cell.get('compile_s', '-')}s "
                f"dominant={ro.get('dominant', '-')} "
                f"mem_peak={cell.get('memory', {}).get('peak_bytes', '-')}",
                flush=True,
            )
    bad = [c for c in cells if c["status"] == "error"]
    print(f"done: {len(cells)} cells, {len(bad)} errors")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
