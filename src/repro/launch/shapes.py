"""The assigned input-shape set and per-(arch, shape) input specs.

ShapeDtypeStruct stand-ins only — weak-type-correct, shardable, no
device allocation.  ``long_500k`` applies only to sub-quadratic
architectures (rwkv6, zamba2); the skip is recorded per cell.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and not cfg.subquadratic:
        return False, "full-attention arch: 500k decode requires sub-quadratic state (skip per assignment)"
    return True, ""


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_specs_for(cfg: ModelConfig, sp: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStructs for the step-function's batch argument."""
    B, S = sp.global_batch, sp.seq_len
    if sp.kind == "decode":
        S_in = 1
    else:
        S_in = S
    batch: Dict[str, Any] = {}
    if cfg.embed_inputs:
        batch["tokens"] = sds((B, S_in), "int32")
    else:
        batch["embeddings"] = sds((B, S_in, cfg.d_model), cfg.compute_dtype)
    if cfg.cross_attn_every:
        batch["img_embed"] = sds((B, cfg.n_img_tokens, cfg.d_model), cfg.compute_dtype)
    if sp.kind == "train":
        batch["labels"] = sds((B, S_in), "int32")
    return batch


def state_shapes_for(cfg: ModelConfig, sp: ShapeSpec):
    """eval_shape of train state / decode state (no allocation)."""
    if sp.kind == "train":
        from repro.train.train_step import init_train_state

        return jax.eval_shape(lambda: init_train_state(cfg, jax.random.PRNGKey(0)))
    if sp.kind == "decode":
        return jax.eval_shape(
            lambda: lm.init_decode_state(cfg, sp.global_batch, sp.seq_len)
        )
    # prefill: params only
    return jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    sp = SHAPES[shape_name]
    return {
        "state": state_shapes_for(cfg, sp),
        "batch": batch_specs_for(cfg, sp),
        "kind": sp.kind,
    }
