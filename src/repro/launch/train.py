"""Training launcher.

CPU-real mode (default): trains a reduced config end-to-end with the
TensorFrame-curated data pipeline, checkpointing, and fault tolerance.
Full configs lower/compile via the dry-run driver (this box has no TPU).

  PYTHONPATH=src python -m repro.launch.train --arch phi3-mini-3.8b \
      --steps 50 --reduced --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import logging

import jax
import jax.numpy as jnp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)

    from repro.configs import get
    from repro.data import tokens as tok
    from repro.models.config import reduced
    from repro.train.loop import TrainLoop
    from repro.train.train_step import init_train_state, make_train_step

    cfg = get(args.arch)
    if args.reduced:
        cfg = reduced(cfg, microbatches=2)

    # --- the paper's technique, in the data plane ---
    corpus = tok.synthetic_corpus(2000, seed=args.seed)
    doc_ids, weights = tok.curate(corpus, mixture={"web": 1.0, "books": 2.0, "wiki": 1.5, "code": 1.0})
    print(f"curated corpus: {len(doc_ids)} docs after filter/dedup")

    data = (
        {k: jnp.asarray(v) for k, v in b.items()}
        for b in tok.token_batches(
            doc_ids, weights, cfg.vocab, args.batch, args.seq, seed=args.seed, steps=args.steps + 5
        )
    )
    state = init_train_state(cfg, jax.random.PRNGKey(args.seed))
    step = jax.jit(make_train_step(cfg))
    loop = TrainLoop(
        step, state, data,
        ckpt_dir=args.ckpt_dir or None,
        ckpt_every=args.ckpt_every,
    )
    loop.install_signal_handler()
    out = loop.run(args.steps)
    losses = [m["loss"] for m in out["metrics"]]
    for i, m in enumerate(out["metrics"]):
        if i % args.log_every == 0 or i == len(out["metrics"]) - 1:
            print(f"step {i}: loss={m['loss']:.4f} gnorm={m['grad_norm']:.3f}")
    print(
        f"done: steps={out['final_step']} loss {losses[0]:.3f} -> {losses[-1]:.3f} "
        f"stragglers_skipped={out['stragglers_skipped']}"
    )


if __name__ == "__main__":
    main()
