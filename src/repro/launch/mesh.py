"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run process sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax
import (see dryrun.py).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU runs)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))
