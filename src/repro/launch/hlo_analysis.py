"""Post-compile HLO analysis for the roofline terms.

``compiled.cost_analysis()`` on this backend (a) reports per-device
numbers and (b) counts while-loop bodies ONCE — scanned layer stacks
would be undercounted ~L×.  (Calibrated empirically; see
tests/test_hlo_analysis.py.)  This module therefore walks the optimized
HLO text and accumulates, with while-loop trip-count weighting
(recovered from the loop condition's comparison constant):

- collective operand bytes (all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute),
- dot FLOPs (2 x prod(result shape) x prod(contracting dims)),
- dot memory traffic (operand + result bytes — an upper-ish estimate of
  HBM traffic for matmul-dominated programs; elementwise traffic rides
  mostly inside fusions).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s1": 1, "u1": 1,
}

COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(
    r"\b(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([\d,]*)\]"
)
_CALLSITE_RE = re.compile(
    r"(?:calls=|body=|condition=|to_apply=|true_computation=|false_computation=)%?([\w\.\-]+)"
)
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def _dims(dims: str) -> List[int]:
    return [int(d) for d in dims.split(",")] if dims else []


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in _dims(dims):
        n *= d
    return n * _DTYPE_BYTES[dtype]


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if stripped.startswith("ENTRY"):
            m2 = re.match(r"^ENTRY\s+(%?[\w\.\-]+)", stripped)
            cur = m2.group(1).lstrip("%") if m2 else "entry"
            comps[cur] = []
            comps["__entry__"] = comps[cur]
            continue
        m = re.match(r"^(%?[\w\.\-]+)\s*\(.*\)\s*->.*\{$", stripped)
        if m:
            cur = m.group(1).lstrip("%")
            comps[cur] = []
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None and stripped:
            comps[cur].append(stripped)
    return comps


def _while_trip_count(cond_lines: List[str]) -> int:
    consts = []
    for line in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            consts.append(int(m.group(1)))
    return max(consts) if consts else 1


_NAME_RE = re.compile(r"%([\w\.\-]+)")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\w+\[[\d,]*\])|\(.*?\))"
)


def _symbols(lines) -> Dict[str, tuple]:
    """instruction name -> (dtype, dims) for simple-typed results."""
    sym: Dict[str, tuple] = {}
    for line in lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        shapes = _SHAPE_RE.findall(m.group(2))
        if len(shapes) == 1:
            sym[m.group(1)] = shapes[0]
    return sym


def _operand_shapes(call: str, sym: Dict[str, tuple]):
    """Shapes of call operands: inline types if present, else resolved
    through the computation's symbol table."""
    inline = _SHAPE_RE.findall(call)
    if inline:
        return inline
    depth = 0
    end = len(call)
    for i, ch in enumerate(call):
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                end = i
                break
            depth -= 1
    names = _NAME_RE.findall(call[:end])
    return [sym[n] for n in names if n in sym]


def _line_metrics(line: str, sym: Dict[str, tuple]) -> Dict[str, float]:
    """Metrics for one (non-control-flow) HLO instruction line."""
    out: Dict[str, float] = {}
    # collectives
    for kind in COLLECTIVES:
        m = re.search(rf"\b{kind}(-start)?\(", line)
        if m:
            call = line[m.end():]
            shapes = _operand_shapes(call, sym)
            if not shapes:
                shapes = _SHAPE_RE.findall(line.split("=", 1)[0])
            out["coll_" + kind] = sum(_shape_bytes(d, s) for d, s in shapes)
            return out
    # dots
    m = re.search(r"\bdot\(", line)
    if m:
        head = line[: m.start()]
        call = line[m.end():]
        res = _SHAPE_RE.findall(head)
        opers = _operand_shapes(call, sym)
        if res and opers:
            res_elems = 1
            for d in _dims(res[0][1]):
                res_elems *= d
            lhs_dims = _dims(opers[0][1])
            mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
            contract = 1
            if mc and mc.group(1):
                for ci in mc.group(1).split(","):
                    ci = int(ci)
                    if ci < len(lhs_dims):
                        contract *= lhs_dims[ci]
            out["dot_flops"] = 2.0 * res_elems * contract
            res_bytes = _shape_bytes(*res[0])
            oper_bytes = [_shape_bytes(d, s) for d, s in opers[:2]]
            out["dot_bytes"] = float(res_bytes + sum(oper_bytes))
            # 'giant' intermediates: blow-up results (attention logits,
            # full-sequence lm-head logits) or giant operands (the
            # softmaxed logits read back by the PV matmul).  These are
            # exactly the HBM round-trips that flash-attention /
            # fused-CE kernels keep in VMEM on TPU.
            GIANT = 64 * 1024 * 1024
            if res_bytes >= GIANT and res_bytes >= 4 * max(1, sum(oper_bytes)):
                out["giant_bytes"] = out.get("giant_bytes", 0.0) + float(res_bytes)
            for ob in oper_bytes:
                if ob >= GIANT and ob >= 4 * max(1, res_bytes):
                    out["giant_bytes"] = out.get("giant_bytes", 0.0) + float(ob)
    return out


def analyze(hlo: str) -> Dict[str, float]:
    """Trip-weighted totals over the entry computation."""
    comps = _split_computations(hlo)
    cache: Dict[str, Dict[str, float]] = {}

    def walk(name: str, depth=0) -> Dict[str, float]:
        if name in cache:
            return cache[name]
        acc: Dict[str, float] = defaultdict(float)
        if name not in comps or depth > 16:
            return acc
        cache[name] = acc  # guard cycles
        sym = _symbols(comps[name])
        for line in comps[name]:
            if re.search(r"\bwhile\(", line):
                body = re.search(r"body=%?([\w\.\-]+)", line)
                cond = re.search(r"condition=%?([\w\.\-]+)", line)
                trips = _while_trip_count(comps.get(cond.group(1), [])) if cond else 1
                if body:
                    sub = walk(body.group(1), depth + 1)
                    for k, v in sub.items():
                        acc[k] += v * max(1, trips)
                continue
            lm = _line_metrics(line, sym)
            if lm:
                for k, v in lm.items():
                    acc[k] += v
                continue
            for sub_name in _CALLSITE_RE.findall(line):
                sub = walk(sub_name, depth + 1)
                for k, v in sub.items():
                    acc[k] += v
            mb = _BRANCHES_RE.search(line)
            if mb:
                for sub_name in re.findall(r"%?([\w\.\-]+)", mb.group(1)):
                    sub = walk(sub_name, depth + 1)
                    for k, v in sub.items():
                        acc[k] += v
        cache[name] = dict(acc)
        return cache[name]

    entry = "__entry__" if "__entry__" in comps else next(iter(comps), None)
    if entry is None:
        return {}
    out = dict(walk(entry))
    out["coll_total"] = sum(v for k, v in out.items() if k.startswith("coll_"))
    return out


def collective_bytes(hlo: str) -> Dict[str, int]:
    """Back-compat helper: collective bytes per kind + total."""
    a = analyze(hlo)
    out = {k[len("coll_"):]: int(v) for k, v in a.items() if k.startswith("coll_") and k != "coll_total"}
    out["total"] = int(a.get("coll_total", 0))
    return out


def count_op(hlo: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo))
