"""Launch layer: production meshes, per-cell input specs, the multi-pod
dry-run driver, and the train/serve entry points."""
