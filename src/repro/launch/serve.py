"""Serving launcher: batched continuous-batching decode on a reduced
config (CPU-real); full configs exercise serve_step via the dry-run.

  PYTHONPATH=src python -m repro.launch.serve --arch phi3-mini-3.8b \
      --requests 16 --slots 4
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get
    from repro.models import lm
    from repro.models.config import reduced
    from repro.serve.engine import Request, ServeEngine

    cfg = reduced(get(args.arch))
    params = lm.init_params(cfg, jax.random.PRNGKey(args.seed))
    eng = ServeEngine(cfg, params, batch_slots=args.slots, max_len=args.max_len)
    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, rng.integers(3, 12)).astype(np.int32),
            max_new=args.max_new,
        )
        for i in range(args.requests)
    ]
    t0 = time.time()
    eng.run(reqs, max_steps=2000)
    dt = time.time() - t0
    toks = sum(len(r.out) for r in reqs)
    print(
        f"arch={cfg.name} served {sum(r.done for r in reqs)}/{len(reqs)} requests, "
        f"{toks} tokens, {eng.steps} decode steps over {args.slots} slots in {dt:.1f}s"
    )


if __name__ == "__main__":
    main()
