"""Distributed execution subsystem (``repro.dist``).

Scales the single-device TensorFrame kernels past one accelerator:

- ``dframe`` — sharded relational ops over a 1-D ``data`` mesh axis:
  dense group-by sums via shard-local segment reduction + ``psum``,
  semi-join membership via broadcast build sides, and a hash-partition
  all-to-all repartition with capacity/overflow accounting.
- ``compression`` — per-block int8 gradient quantization with
  error-feedback residuals and a ``compressed_mean`` collective for the
  training leg (1-bit/error-feedback SGD lineage).
- ``pipeline`` — a GPipe-style microbatch pipeline schedule over a
  ``pipe`` mesh axis using ``ppermute`` stage-to-stage shifts.

All ops are ``shard_map`` programs that accept *global* arrays plus a
mesh, and degrade gracefully to a 1-device mesh (single-device
fallback), so the same code path runs in CPU tests and on real
multi-device topologies (``XLA_FLAGS=--xla_force_host_platform_device_count=N``
for forced host devices).

The relational ops are wired into the engine: ``TensorFrame.groupby``
aggregation sums and semi/anti-join probes route here when
``repro.core.config.CONFIG.distributed`` allows it (see
``dframe.dist_enabled``).
"""
from . import compression, dframe, pipeline
from .dframe import (
    data_mesh,
    dist_enabled,
    dist_groupby_sum,
    dist_repartition_by_key,
    dist_semi_join_mask,
)
from .compression import compressed_mean, dequantize, quantize
from .pipeline import pipeline_forward

__all__ = [
    "compression",
    "dframe",
    "pipeline",
    "data_mesh",
    "dist_enabled",
    "dist_groupby_sum",
    "dist_repartition_by_key",
    "dist_semi_join_mask",
    "compressed_mean",
    "dequantize",
    "quantize",
    "pipeline_forward",
]
