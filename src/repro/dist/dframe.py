"""Sharded dataframe ops over a 1-D device mesh.

The paper's single-device kernels (factorize-then-join, dense group-by)
scale out the way partitioned dataframe engines do:

- ``dist_groupby_sum`` — every shard reduces its rows into a dense
  ``(domain,)`` accumulator (the same segment-reduction the single-device
  engine uses; optionally through the Pallas sorted-segment kernel), then
  one ``psum`` over the mesh axis combines the shard partials.  The
  all-reduce moves ``O(domain)`` floats per device instead of
  ``O(n)`` rows — the classic dense-aggregate shuffle avoidance.
- ``dist_semi_join_mask`` — the build side is broadcast (replicated) to
  every shard; each shard probes its local rows with the engine's
  sorted-membership kernel.  Right-sized for TPC-H-style selective
  semi/anti joins where the build side is small.
- ``dist_repartition_by_key`` — a hash-partition all-to-all: rows are
  routed to ``splitmix64(key) % ndev`` so each key's rows land on
  exactly one shard (the precondition for shard-local joins or
  group-bys over huge key domains).  Fixed-capacity send buckets give
  static shapes; rows beyond a bucket's capacity are counted in
  ``dropped`` (capacity >= n guarantees a lossless shuffle).

Every op takes *global* arrays plus a mesh and works unchanged on a
1-device mesh (single-device fallback).  Inputs whose length does not
divide the mesh size are padded with null keys (negative), which every
op already ignores.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import hashing
from repro.core.config import CONFIG
from repro.core.join import membership

AXIS = "data"


# ----------------------------------------------------------------------
# mesh + routing helpers
# ----------------------------------------------------------------------
def data_mesh(ndev: int | None = None):
    """A 1-D mesh over (the first ``ndev``) visible devices."""
    n = jax.device_count() if ndev is None else ndev
    return jax.make_mesh((n,), (AXIS,))


def dist_enabled(nrows: int) -> bool:
    """Should the engine take the sharded route for an ``nrows`` input?

    ``CONFIG.distributed``: 'off' never; 'force' always (tests use this
    with a 1-device mesh); 'auto' only when more than one device is
    visible and the input is large enough to amortize dispatch.
    """
    mode = CONFIG.distributed
    if mode == "off":
        return False
    if mode == "force":
        return True
    return jax.device_count() > 1 and nrows >= CONFIG.dist_min_rows


def _from_chunks(arr, dtype) -> jax.Array:
    """Accept a chunk sequence (repro.store column chunks, or any list
    of host arrays) as one device array — a chunk is the store's
    natural shard unit, so chunked columns feed the all-to-all without
    a host-side copy round-trip through a monolithic array."""
    if isinstance(arr, (list, tuple)):
        parts = [jnp.asarray(np.asarray(c), dtype=dtype) for c in arr]
        if not parts:
            return jnp.zeros((0,), dtype=dtype)
        return jnp.concatenate(parts)
    return jnp.asarray(arr)


def _pad_to(mesh, axis: str, keys: jax.Array, vals: jax.Array | None):
    """Pad to a multiple of the mesh size with null keys / zero values."""
    ndev = mesh.shape[axis]
    n = int(keys.shape[0])
    pad = (-n) % ndev
    if pad:
        keys = jnp.concatenate([keys, jnp.full((pad,), -1, keys.dtype)])
        if vals is not None:
            vals = jnp.concatenate([vals, jnp.zeros((pad,), vals.dtype)])
    return keys, vals, n


# ----------------------------------------------------------------------
# shard-local dense segment sum (reused by the group-by route)
# ----------------------------------------------------------------------
def local_dense_sum(
    keys: jax.Array, vals: jax.Array, domain: int, *, use_pallas: bool = False
) -> jax.Array:
    """Dense ``(domain,)`` sums of ``vals`` bucketed by ``keys``.

    Negative keys (nulls / padding) contribute nothing.  With
    ``use_pallas`` the reduction runs through the sorted-segment Pallas
    kernel (``kernels/segment_reduce``) after a shard-local sort —
    the TPU MXU formulation; the default is the XLA scatter-add, which
    preserves the input dtype (float64 analytics on CPU hosts).
    """
    ok = keys >= 0
    safe = jnp.where(ok, keys, domain).astype(jnp.int32)
    vz = jnp.where(ok, vals, jnp.zeros((), vals.dtype))
    if use_pallas:
        from repro.kernels.segment_reduce import segment_sum_sorted_pallas

        order = jnp.argsort(safe)
        return segment_sum_sorted_pallas(vz[order], safe[order], domain + 1)[:domain]
    out = jnp.zeros((domain + 1,), vz.dtype).at[safe].add(vz)
    return out[:domain]


# ----------------------------------------------------------------------
# sharded group-by sum
# ----------------------------------------------------------------------
def dist_groupby_sum(
    mesh,
    keys: jax.Array,
    vals: jax.Array,
    domain: int,
    axis: str = AXIS,
    *,
    use_pallas: bool = False,
) -> jax.Array:
    """Global dense group-by sum: shard-local segment sums + ``psum``.

    ``keys`` are dense group ids in ``[0, domain)`` (negative = null);
    returns the replicated ``(domain,)`` per-group sums.
    """
    keys, vals, _ = _pad_to(mesh, axis, keys, vals)

    def shard(k, v):
        return jax.lax.psum(local_dense_sum(k, v, domain, use_pallas=use_pallas), axis)

    fn = shard_map(
        shard,
        mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=P(),
        check_rep=False,
    )
    return fn(keys, vals)


# ----------------------------------------------------------------------
# broadcast-build semi join
# ----------------------------------------------------------------------
def dist_semi_join_mask(
    mesh, probe: jax.Array, build: jax.Array, axis: str = AXIS
) -> jax.Array:
    """``exists(probe[i] in build)`` with the probe side sharded.

    The build side is broadcast to every shard (replicated in-spec) and
    probed with the engine's sorted-membership kernel; negative probe
    keys (nulls) never match, matching SQL semi/anti-join semantics.
    """
    probe, _, n = _pad_to(mesh, axis, probe, None)

    def shard(p, b):
        return membership(p, b)

    fn = shard_map(
        shard,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(axis),
        check_rep=False,
    )
    return fn(probe, build)[:n]


# ----------------------------------------------------------------------
# hash-partition all-to-all
# ----------------------------------------------------------------------
def dist_repartition_by_key(
    mesh,
    keys: jax.Array,
    vals: jax.Array,
    capacity: int,
    axis: str = AXIS,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Shuffle rows so each key's rows land on exactly one shard.

    Rows are routed to shard ``splitmix64(key) % ndev``.  Each source
    shard owns a fixed send bucket of ``ceil(capacity / ndev)`` slots
    per destination (static shapes for the all-to-all); a source's rows
    beyond its bucket are counted in ``dropped``.  A source shard holds
    at most ``ceil(len(keys) / ndev)`` rows, so ``capacity >= len(keys)``
    guarantees no bucket overflows — the shuffle is lossless.

    Returns ``(keys2, vals2, valid, dropped)``: global slot arrays of
    length ``ndev * ndev * ceil(capacity / ndev)``, a boolean mask of
    the occupied slots, and the replicated global overflow count.

    ``keys``/``vals`` may each be a *sequence of chunks* (e.g. the
    per-chunk physical arrays of a ``repro.store`` column) instead of
    one array — the chunk grid is the store's natural shard unit, and
    the row order is the chunks' concatenation order.
    """
    ndev = mesh.shape[axis]
    keys = _from_chunks(keys, jnp.int64)
    vals = _from_chunks(vals, None)
    # ceil: a source shard holds ceil(n/ndev) rows, so capacity >= n
    # really does guarantee every row fits its bucket (lossless)
    bucket = max(1, -(-capacity // ndev))
    keys, vals, _ = _pad_to(mesh, axis, keys, vals)

    def shard(k, v):
        nl = k.shape[0]
        ok = k >= 0
        dest = (hashing.splitmix64(k.astype(jnp.int64)) % np.uint64(ndev)).astype(
            jnp.int32
        )
        dest = jnp.where(ok, dest, ndev)  # nulls/padding -> trash bucket
        order = jnp.argsort(dest)  # stable: ties keep row order
        ks, vs, ds, oks = k[order], v[order], dest[order], ok[order]
        counts = jnp.zeros((ndev + 1,), jnp.int32).at[ds].add(1)
        offs = jnp.cumsum(counts) - counts
        within = jnp.arange(nl, dtype=jnp.int32) - offs[ds]
        fits = oks & (within < bucket)
        dropped_local = jnp.sum(oks & ~fits)
        slot = jnp.where(fits, ds * bucket + within, ndev * bucket)
        buf_k = (
            jnp.full((ndev * bucket + 1,), -1, ks.dtype)
            .at[slot]
            .set(jnp.where(fits, ks, -1))[: ndev * bucket]
            .reshape(ndev, bucket)
        )
        buf_v = (
            jnp.zeros((ndev * bucket + 1,), vs.dtype)
            .at[slot]
            .set(jnp.where(fits, vs, jnp.zeros((), vs.dtype)))[: ndev * bucket]
            .reshape(ndev, bucket)
        )
        rk = jax.lax.all_to_all(buf_k, axis, split_axis=0, concat_axis=0)
        rv = jax.lax.all_to_all(buf_v, axis, split_axis=0, concat_axis=0)
        return (
            rk.reshape(-1),
            rv.reshape(-1),
            (rk >= 0).reshape(-1),
            jax.lax.psum(dropped_local, axis),
        )

    fn = shard_map(
        shard,
        mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=(P(axis), P(axis), P(axis), P()),
        check_rep=False,
    )
    return fn(keys, vals)
