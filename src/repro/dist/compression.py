"""Gradient compression: per-block int8 quantization + error feedback.

The training leg's answer to interconnect-bound data parallelism
(1-bit / error-feedback SGD lineage): each device quantizes its local
gradient to int8 with one float32 scale per 256-element block, the
all-reduce runs over the dequantized tensors, and the quantization
error is *kept locally* as a residual to be added back into the next
step's gradient — so the error feeds back instead of accumulating.

``quantize`` is the jnp reference of the Pallas quantization-kernel
pattern (block-wise absmax scales); a TPU deployment would swap the
body for the stochastic-rounding kernel without changing the contract.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def quantize(
    x: jax.Array, block: int = BLOCK, resid: Optional[jax.Array] = None
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per-block symmetric int8 quantization with error feedback.

    ``resid`` (the previous step's residual) is added to ``x`` before
    quantizing.  Returns ``(q, scales, residual)``:

    - ``q``: int8 codes, length padded up to a block multiple,
    - ``scales``: float32 ``(nblocks, 1)`` per-block scales
      (``dequantized = q.reshape(-1, block) * scales``),
    - ``residual``: ``x + resid - dequantized`` over the original
      (unpadded) length — the error to feed back next step.
    """
    n = x.shape[0]
    if resid is not None:
        x = x + resid
    pad = (-n) % block
    xb = jnp.pad(x, (0, pad)).reshape(-1, block)
    absmax = jnp.max(jnp.abs(xb), axis=1, keepdims=True)
    scales = jnp.where(absmax > 0, absmax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(xb / scales), -127, 127).astype(jnp.int8)
    deq = (q.astype(x.dtype) * scales).reshape(-1)[:n]
    return q.reshape(-1), scales, x - deq


def dequantize(q: jax.Array, scales: jax.Array, n: Optional[int] = None) -> jax.Array:
    """Invert ``quantize``: codes * per-block scales, cut back to ``n``."""
    nblocks = scales.shape[0]
    out = (q.astype(scales.dtype).reshape(nblocks, -1) * scales).reshape(-1)
    return out if n is None else out[:n]


def compressed_mean(
    x: jax.Array, axis_name: str, resid: Optional[jax.Array] = None
) -> Tuple[jax.Array, jax.Array]:
    """Mean of ``x`` over a mesh axis, communicating quantized values.

    Call inside ``shard_map``: each device quantizes its local shard
    (after folding in ``resid``), the mean is taken over the
    dequantized tensors with one ``psum``, and the local quantization
    error comes back as the residual for error feedback.
    """
    q, scales, residual = quantize(x, resid=resid)
    deq = dequantize(q, scales, x.shape[0]).astype(x.dtype)
    ndev = jax.lax.psum(jnp.ones((), x.dtype), axis_name)
    return jax.lax.psum(deq, axis_name) / ndev, residual
