"""GPipe-style microbatch pipeline over a ``pipe`` mesh axis.

Layers are split contiguously across ``S`` pipeline stages (each stage
holds ``n_layers / S`` consecutive layer weights via the sharded
in-spec).  Microbatches stream through the classic ``M + S - 1`` tick
schedule: at tick ``t`` stage 0 ingests microbatch ``t``, every stage
applies its layer slice to the activation it holds, and activations
shift one stage to the right with a ring ``ppermute`` (the wrap-around
into stage 0 is overwritten by the next ingest).  The last stage
finishes microbatch ``t - (S - 1)`` at tick ``t``.

This is the forward-only schedule — exactly what the serving engine
needs for model-parallel layer sharding — and it matches the sequential
reference bit-for-bit per microbatch since stages apply the very same
``block`` function.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

AXIS = "pipe"


def pipeline_forward(
    mesh,
    block: Callable[[jax.Array, jax.Array], jax.Array],
    weights: jax.Array,
    x: jax.Array,
    n_layers: int,
    axis: str = AXIS,
) -> jax.Array:
    """Run ``x`` (microbatches, batch, d) through ``n_layers`` blocks.

    ``weights`` is the stacked ``(n_layers, ...)`` per-layer parameters;
    ``block(w_l, h)`` applies one layer.  ``n_layers`` must divide
    evenly across the mesh's ``axis`` dimension.
    """
    stages = mesh.shape[axis]
    if n_layers % stages:
        raise ValueError(f"{n_layers} layers do not split over {stages} stages")
    n_micro = x.shape[0]

    def apply_stage(w, h):
        out, _ = jax.lax.scan(lambda c, wl: (block(wl, c), None), h, w)
        return out

    def shard(w, xs):
        # w: (n_layers/stages, ...) local slice; xs: (M, B, D) replicated
        sidx = jax.lax.axis_index(axis)
        buf = jnp.zeros(xs.shape[1:], xs.dtype)
        outs = jnp.zeros_like(xs)
        fwd = [(i, (i + 1) % stages) for i in range(stages)]

        def tick(t, carry):
            buf, outs = carry
            inp = xs[jnp.clip(t, 0, n_micro - 1)]
            h = apply_stage(w, jnp.where(sidx == 0, inp, buf))
            out_t = t - (stages - 1)
            write = (sidx == stages - 1) & (out_t >= 0)
            outs = jnp.where(
                write, outs.at[jnp.clip(out_t, 0, n_micro - 1)].set(h), outs
            )
            return jax.lax.ppermute(h, axis, fwd), outs

        _, outs = jax.lax.fori_loop(0, n_micro + stages - 1, tick, (buf, outs))
        # only the last stage holds real outputs; replicate them
        return jax.lax.psum(
            jnp.where(sidx == stages - 1, outs, jnp.zeros((), outs.dtype)), axis
        )

    fn = shard_map(
        shard,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False,
    )
    return fn(weights, x)
