"""Model facade: init / forward / prefill / decode for every assigned
architecture family, built on lax.scan over stacked layer parameters
(compact HLO for the 512-device dry-run) with configurable remat.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from . import layers, mamba, rwkv
from .config import ModelConfig
from .layers import cdtype


# ----------------------------------------------------------------------
# remat policy
# ----------------------------------------------------------------------
def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)  # 'nothing' saveable


# ----------------------------------------------------------------------
# init
# ----------------------------------------------------------------------
def _stack_init(fn, key, n):
    return jax.vmap(fn)(jax.random.split(key, n))


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict:
    pd = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    D, V = cfg.d_model, cfg.vocab
    p: Dict = {
        "final_norm": jnp.ones((D,), pd),
        "lm_head": (jax.random.normal(keys[0], (D, V)) / np.sqrt(D)).astype(pd),
    }
    if cfg.embed_inputs:
        p["embed"] = (jax.random.normal(keys[1], (V, D)) * 0.02).astype(pd)

    if cfg.family == "rwkv6":
        p["blocks"] = _stack_init(
            lambda k: rwkv.init_rwkv_block(k, cfg), keys[2], cfg.n_layers
        )
        return p

    if cfg.family == "mamba_hybrid":
        p["blocks"] = _stack_init(
            lambda k: mamba.init_mamba_block(k, cfg), keys[2], cfg.n_layers
        )
        # ONE shared attention+MLP block, reused every attn_every layers
        p["shared_attn"] = {
            "norm1": jnp.ones((D,), pd),
            "attn": layers.init_attention(keys[3], cfg),
            "norm2": jnp.ones((D,), pd),
            "mlp": layers.init_mlp(keys[4], cfg),
        }
        return p

    def init_block(k):
        k1, k2 = jax.random.split(k)
        blk = {
            "norm1": jnp.ones((D,), pd),
            "attn": layers.init_attention(k1, cfg),
            "norm2": jnp.ones((D,), pd),
        }
        if cfg.moe:
            blk["moe"] = layers.init_moe(k2, cfg)
        else:
            blk["mlp"] = layers.init_mlp(k2, cfg)
        return blk

    if cfg.cross_attn_every:
        n_cross = cfg.n_layers // cfg.cross_attn_every
        n_self = cfg.n_layers - n_cross
        p["blocks"] = _stack_init(init_block, keys[2], n_self)

        def init_cross(k):
            k1, k2 = jax.random.split(k)
            return {
                "norm1": jnp.ones((D,), pd),
                "attn": layers.init_attention(k1, cfg, cross=True),
                "norm2": jnp.ones((D,), pd),
                "mlp": layers.init_mlp(k2, cfg),
            }

        p["cross_blocks"] = _stack_init(init_cross, keys[5], n_cross)
        return p

    p["blocks"] = _stack_init(init_block, keys[2], cfg.n_layers)
    return p


# ----------------------------------------------------------------------
# forward (train / prefill)
# ----------------------------------------------------------------------
def _self_block(cfg: ModelConfig, x, bp, positions):
    h, _ = layers.attention(
        bp["attn"], cfg, layers.rms_norm(x, bp["norm1"], cfg.norm_eps), positions
    )
    x = x + h
    xn = layers.rms_norm(x, bp["norm2"], cfg.norm_eps)
    if "moe" in bp:
        x = x + layers.moe_ffn(bp["moe"], cfg, xn)
    else:
        x = x + layers.mlp(bp["mlp"], cfg, xn)
    return x


def _cross_block(cfg: ModelConfig, x, bp, img):
    h, _ = layers.attention(
        bp["attn"], cfg, layers.rms_norm(x, bp["norm1"], cfg.norm_eps),
        positions=None, kv_x=img, causal=False,
    )
    x = x + h
    x = x + layers.mlp(bp["mlp"], cfg, layers.rms_norm(x, bp["norm2"], cfg.norm_eps))
    return x


def forward(
    cfg: ModelConfig,
    params: Dict,
    batch: Dict[str, jax.Array],
    return_hidden: bool = False,
) -> jax.Array:
    """batch: {'tokens' (B,S) | 'embeddings' (B,S,D)} [+ 'img_embed'].
    Returns logits (B, S, V) in f32 (or final hidden states)."""
    dt = cdtype(cfg)
    if cfg.embed_inputs:
        x = params["embed"].astype(dt)[batch["tokens"]]
    else:
        x = batch["embeddings"].astype(dt)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    if cfg.family == "rwkv6":
        def block(x, bp):
            y, _ = rwkv.rwkv_block(bp, cfg, x)
            return y, None
        x, _ = jax.lax.scan(_remat(block, cfg), x, params["blocks"])
    elif cfg.family == "mamba_hybrid":
        sp = params["shared_attn"]
        groups = cfg.n_layers // cfg.attn_every

        def mblock(x, bp):
            y, _ = mamba.mamba_block(bp, cfg, x)
            return y, None

        stacked = jax.tree.map(
            lambda a: a.reshape(groups, cfg.attn_every, *a.shape[1:]), params["blocks"]
        )

        def group(x, gp):
            x, _ = jax.lax.scan(_remat(mblock, cfg), x, gp)
            # shared attention block (same params every group)
            h, _ = layers.attention(
                sp["attn"], cfg, layers.rms_norm(x, sp["norm1"], cfg.norm_eps), positions
            )
            x = x + h
            x = x + layers.mlp(sp["mlp"], cfg, layers.rms_norm(x, sp["norm2"], cfg.norm_eps))
            return x, None

        x, _ = jax.lax.scan(group, x, stacked)
    elif cfg.cross_attn_every:
        img = batch["img_embed"].astype(dt)
        per = cfg.cross_attn_every - 1
        groups = cfg.n_layers // cfg.cross_attn_every
        stacked = jax.tree.map(
            lambda a: a.reshape(groups, per, *a.shape[1:]), params["blocks"]
        )

        def sblock(x, bp):
            return _remat(lambda x, bp: _self_block(cfg, x, bp, positions), cfg)(x, bp), None

        def group(x, gp):
            selfs, crossp = gp
            x, _ = jax.lax.scan(sblock, x, selfs)
            x = _remat(lambda x, bp: _cross_block(cfg, x, bp, img), cfg)(x, crossp)
            return x, None

        x, _ = jax.lax.scan(group, x, (stacked, params["cross_blocks"]))
    else:
        def block(x, bp):
            return _remat(lambda x, bp: _self_block(cfg, x, bp, positions), cfg)(x, bp), None
        x, _ = jax.lax.scan(block, x, params["blocks"])

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(dt))
    return logits.astype(jnp.float32)


def loss_fn(cfg: ModelConfig, params, batch) -> Tuple[jax.Array, Dict]:
    logits = forward(cfg, params, batch)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(ll)
    loss = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss, {"loss": loss, "tokens": mask.sum()}


# ----------------------------------------------------------------------
# decode state
# ----------------------------------------------------------------------
def init_decode_state(cfg: ModelConfig, batch_size: int, max_len: int) -> Dict:
    dt = cdtype(cfg)
    L, D = cfg.n_layers, cfg.d_model
    if cfg.family == "rwkv6":
        H = cfg.n_heads
        hd = D // H
        return {
            "S": jnp.zeros((L, batch_size, H, hd, hd), jnp.float32),
            "tm_prev": jnp.zeros((L, batch_size, 1, D), dt),
            "cm_prev": jnp.zeros((L, batch_size, 1, D), dt),
            "pos": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "mamba_hybrid":
        groups = L // cfg.attn_every
        return {
            "h": jnp.zeros((L, batch_size, cfg.ssm_heads or cfg.n_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
            "k": jnp.zeros((groups, batch_size, max_len, cfg.n_kv_heads, cfg.hd), dt),
            "v": jnp.zeros((groups, batch_size, max_len, cfg.n_kv_heads, cfg.hd), dt),
            "pos": jnp.zeros((), jnp.int32),
        }
    state = {
        "k": jnp.zeros((L, batch_size, max_len, cfg.n_kv_heads, cfg.hd), dt),
        "v": jnp.zeros((L, batch_size, max_len, cfg.n_kv_heads, cfg.hd), dt),
        "pos": jnp.zeros((), jnp.int32),
    }
    if cfg.cross_attn_every:
        n_cross = cfg.n_layers // cfg.cross_attn_every
        state["xk"] = jnp.zeros((n_cross, batch_size, cfg.n_img_tokens, cfg.n_kv_heads, cfg.hd), dt)
        state["xv"] = jnp.zeros((n_cross, batch_size, cfg.n_img_tokens, cfg.n_kv_heads, cfg.hd), dt)
        # self-attn cache excludes cross layers
        n_self = cfg.n_layers - n_cross
        state["k"] = jnp.zeros((n_self, batch_size, max_len, cfg.n_kv_heads, cfg.hd), dt)
        state["v"] = jnp.zeros((n_self, batch_size, max_len, cfg.n_kv_heads, cfg.hd), dt)
    return state


# ----------------------------------------------------------------------
# decode step (one new token against the cache)
# ----------------------------------------------------------------------
def decode_step(
    cfg: ModelConfig,
    params: Dict,
    state: Dict,
    batch: Dict[str, jax.Array],
) -> Tuple[jax.Array, Dict]:
    """batch: {'tokens' (B,1) | 'embeddings' (B,1,D)} [+ 'img_embed'].
    Returns (logits (B, V) f32, new state)."""
    dt = cdtype(cfg)
    if cfg.embed_inputs:
        x = params["embed"].astype(dt)[batch["tokens"]]
    else:
        x = batch["embeddings"].astype(dt)
    pos = state["pos"]
    new_state = dict(state)

    if cfg.family == "rwkv6":
        def block(x, xs):
            bp, S, tm, cm = xs
            y, ns = rwkv.rwkv_block(bp, cfg, x, state={"S": S, "tm_prev": tm, "cm_prev": cm})
            return y, (ns["S"], ns["tm_prev"], ns["cm_prev"])

        x, (S2, tm2, cm2) = jax.lax.scan(
            block, x, (params["blocks"], state["S"], state["tm_prev"], state["cm_prev"])
        )
        new_state.update({"S": S2, "tm_prev": tm2, "cm_prev": cm2})
    elif cfg.family == "mamba_hybrid":
        sp = params["shared_attn"]
        groups = cfg.n_layers // cfg.attn_every
        stacked = jax.tree.map(
            lambda a: a.reshape(groups, cfg.attn_every, *a.shape[1:]), params["blocks"]
        )
        hstk = state["h"].reshape(groups, cfg.attn_every, *state["h"].shape[1:])

        def mblock(x, xs):
            bp, h = xs
            y, h2 = mamba.mamba_block(bp, cfg, x, state=h)
            return y, h2

        def group(x, xs):
            gp, hs, ck, cv = xs
            x, h2 = jax.lax.scan(mblock, x, (gp, hs))
            hh, ck2, cv2 = layers.decode_attention(
                sp["attn"], cfg, layers.rms_norm(x, sp["norm1"], cfg.norm_eps), ck, cv, pos
            )
            x = x + hh
            x = x + layers.mlp(sp["mlp"], cfg, layers.rms_norm(x, sp["norm2"], cfg.norm_eps))
            return x, (h2, ck2, cv2)

        x, (h2, k2, v2) = jax.lax.scan(group, x, (stacked, hstk, state["k"], state["v"]))
        new_state.update({"h": h2.reshape(state["h"].shape), "k": k2, "v": v2})
    elif cfg.cross_attn_every:
        per = cfg.cross_attn_every - 1
        groups = cfg.n_layers // cfg.cross_attn_every
        stacked = jax.tree.map(
            lambda a: a.reshape(groups, per, *a.shape[1:]), params["blocks"]
        )
        kstk = state["k"].reshape(groups, per, *state["k"].shape[1:])
        vstk = state["v"].reshape(groups, per, *state["v"].shape[1:])

        def sblock(x, xs):
            bp, ck, cv = xs
            h, ck2, cv2 = layers.decode_attention(
                bp["attn"], cfg, layers.rms_norm(x, bp["norm1"], cfg.norm_eps), ck, cv, pos
            )
            x = x + h
            x = x + layers.mlp(bp["mlp"], cfg, layers.rms_norm(x, bp["norm2"], cfg.norm_eps))
            return x, (ck2, cv2)

        def group2(x, xs):
            gp, crossp, ks, vs, xk, xv = xs
            x, (k2, v2) = jax.lax.scan(sblock, x, (gp, ks, vs))
            h, _, _ = layers.decode_attention(
                crossp["attn"], cfg,
                layers.rms_norm(x, crossp["norm1"], cfg.norm_eps),
                xk, xv, pos, rope=False, update_cache=False,
                kv_len=cfg.n_img_tokens,
            )
            x = x + h
            x = x + layers.mlp(crossp["mlp"], cfg, layers.rms_norm(x, crossp["norm2"], cfg.norm_eps))
            return x, (k2, v2)

        x, (k2, v2) = jax.lax.scan(
            group2, x, (stacked, params["cross_blocks"], kstk, vstk, state["xk"], state["xv"])
        )
        new_state.update({
            "k": k2.reshape(state["k"].shape),
            "v": v2.reshape(state["v"].shape),
        })
    else:
        kv_start = batch.get("kv_start")

        def block(x, xs):
            bp, ck, cv = xs
            h, ck2, cv2 = layers.decode_attention(
                bp["attn"], cfg, layers.rms_norm(x, bp["norm1"], cfg.norm_eps), ck, cv, pos,
                kv_start=kv_start,
            )
            x = x + h
            xn = layers.rms_norm(x, bp["norm2"], cfg.norm_eps)
            if "moe" in bp:
                x = x + layers.moe_ffn(bp["moe"], cfg, xn)
            else:
                x = x + layers.mlp(bp["mlp"], cfg, xn)
            return x, (ck2, cv2)

        x, (k2, v2) = jax.lax.scan(block, x, (params["blocks"], state["k"], state["v"]))
        new_state.update({"k": k2, "v": v2})

    new_state["pos"] = pos + 1
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(dt))
    return logits[:, 0].astype(jnp.float32), new_state


def prefill(cfg: ModelConfig, params: Dict, batch: Dict) -> jax.Array:
    """Prefill: run the stack over the prompt (the KV-cache writes are
    the same compute) and emit logits for the LAST position only — a
    production prefill never materializes (B, S, V) logits."""
    h = forward(cfg, params, batch, return_hidden=True)
    dt = layers.cdtype(cfg)
    logits = jnp.einsum(
        "bd,dv->bv", h[:, -1, :], params["lm_head"].astype(dt)
    )
    return logits.astype(jnp.float32)
