"""Mamba2 (SSD) blocks for the Zamba2 hybrid.

Chunked state-space-dual form: within-chunk work is matmuls over a
segment-sum decay matrix (MXU-friendly), the (H, P, N) state is carried
across chunks by a scan — and is the O(1) decode state.

Simplifications vs the full Mamba2 block (documented in DESIGN.md):
single B/C group, no depthwise conv1d prefix.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import cdtype, rms_norm


def init_mamba_block(key, cfg: ModelConfig) -> Dict:
    D = cfg.d_model
    H = cfg.ssm_heads or cfg.n_heads
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    d_inner = H * P
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(D)
    return {
        "norm": jnp.ones((D,), pd),
        "in_proj": (
            jax.random.normal(ks[0], (D, 2 * d_inner + 2 * N + H)) * s
        ).astype(pd),
        "out_proj": (jax.random.normal(ks[1], (d_inner, D)) / np.sqrt(d_inner)).astype(pd),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "gated_norm": jnp.ones((d_inner,), pd),
    }


def _segsum(loga: jax.Array) -> jax.Array:
    """loga (..., C) -> (..., C, C) lower-tri cumulative sums:
    out[t, s] = sum_{r=s+1..t} loga[r] (0 on diagonal, -inf above)."""
    C = loga.shape[-1]
    cs = jnp.cumsum(loga, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # sum_{r=s+1..t}
    ti = jnp.arange(C)[:, None]
    si = jnp.arange(C)[None, :]
    return jnp.where(si <= ti, diff, -jnp.inf)


def ssd_chunked(
    xbar: jax.Array,  # (B, T, H, P)  (already dt-scaled inputs)
    loga: jax.Array,  # (B, T, H)     log decay per step
    Bm: jax.Array,  # (B, T, N)
    Cm: jax.Array,  # (B, T, N)
    state: Optional[jax.Array] = None,  # (B, H, P, N) f32
    chunk: int = 64,
) -> Tuple[jax.Array, jax.Array]:
    B_, T, H, P = xbar.shape
    N = Bm.shape[-1]
    while T % chunk:
        chunk //= 2
    nchunks = T // chunk
    if state is None:
        state = jnp.zeros((B_, H, P, N), dtype=jnp.float32)

    def to_chunks(x, extra_dims):
        return jnp.moveaxis(
            x.astype(jnp.float32).reshape(B_, nchunks, chunk, *extra_dims), 1, 0
        )

    xc = to_chunks(xbar, (H, P))
    ac = to_chunks(loga, (H,))
    bc = to_chunks(Bm, (N,))
    cc = to_chunks(Cm, (N,))

    def step(h, xs):
        xb, la, bm, cm = xs  # (B,C,H,P), (B,C,H), (B,C,N), (B,C,N)
        la_h = jnp.moveaxis(la, -1, 1)  # (B,H,C)
        L = jnp.exp(_segsum(la_h))  # (B,H,C,C) includes diagonal (decay s->t)
        # intra-chunk: y_t += C_t . sum_s L[t,s] (xbar_s B_s)
        y_intra = jnp.einsum("btn,bhts,bsn,bshp->bthp", cm, L, bm, xb)
        # inter-chunk: decay from chunk start to t
        dec0 = jnp.exp(jnp.cumsum(la_h, axis=-1))  # (B,H,C) decay including step t
        y_inter = jnp.einsum("bcn,bhc,bhpn->bchp", cm, dec0, h)
        # new state: h' = total_decay * h + sum_s decay(s->end) xbar_s B_s
        total = dec0[..., -1]  # (B,H)
        dec_end = jnp.exp(
            jnp.cumsum(la_h[..., ::-1], axis=-1)[..., ::-1] - la_h
        )  # decay s+1..end
        h_new = total[..., None, None] * h + jnp.einsum(
            "bhs,bshp,bsn->bhpn", dec_end, xb, bm
        )
        return h_new, y_intra + y_inter

    final, ys = jax.lax.scan(step, state, (xc, ac, bc, cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B_, T, H, P)
    return y, final


def mamba_block(
    p: Dict,
    cfg: ModelConfig,
    x: jax.Array,
    state: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """x (B,S,D) -> (y, new_state (B,H,P,N) f32)."""
    B, S, D = x.shape
    H, P, N = (cfg.ssm_heads or cfg.n_heads), cfg.ssm_head_dim, cfg.ssm_state
    d_inner = H * P
    dt = cdtype(cfg)
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    proj = jnp.einsum("bsd,de->bse", xn.astype(dt), p["in_proj"].astype(dt))
    z, xin, Bm, Cm, dt_raw = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N], axis=-1
    )
    delta = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])  # (H,) negative
    loga = delta * A  # (B,S,H)
    xh = xin.reshape(B, S, H, P).astype(jnp.float32)
    xbar = xh * delta[..., None]
    y, h_new = ssd_chunked(xbar, loga, Bm.astype(jnp.float32), Cm.astype(jnp.float32), state)
    y = y + p["D_skip"][None, None, :, None] * xh
    y = y.reshape(B, S, d_inner).astype(dt)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, p["gated_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dt))
    return x + out, h_new
