"""LM architecture zoo: dense/MoE/SSM/hybrid/VLM/audio decoder stacks.

Pure-JAX, explicit dtypes, lax.scan over stacked layer parameters,
pjit-shardable (partition.py).  Does NOT import repro.core (which flips
x64): the relational engine and the model stack are separate layers of
the framework.
"""
