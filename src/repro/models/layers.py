"""Shared neural net layers: RMSNorm, RoPE, GQA attention (train /
chunked-prefill / cached-decode), SwiGLU MLP, and sort-based MoE.

Everything is expressed with einsums over explicitly-shaped weights so
the XLA SPMD partitioner can shard from the weight PartitionSpecs in
partition.py.  No framework dependencies (no flax) — parameters are
plain pytrees of jax.Arrays.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .config import ModelConfig


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * scale.astype(dt)


# ----------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------
def rope_frequencies(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (np.arange(0, hd, 2, dtype=np.float32) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (..., S, H, hd); positions (..., S) int32."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(hd, theta))  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# attention
# ----------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig, cross: bool = False) -> Dict:
    D, hd = cfg.d_model, cfg.hd
    Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
    pd = jnp.dtype(cfg.param_dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(D)
    p = {
        "wq": (jax.random.normal(k1, (D, Hq * hd)) * s).astype(pd),
        "wk": (jax.random.normal(k2, (D, Hkv * hd)) * s).astype(pd),
        "wv": (jax.random.normal(k3, (D, Hkv * hd)) * s).astype(pd),
        "wo": (jax.random.normal(k4, (Hq * hd, D)) * s).astype(pd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Hq * hd,), pd)
        p["bk"] = jnp.zeros((Hkv * hd,), pd)
        p["bv"] = jnp.zeros((Hkv * hd,), pd)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), pd)
        p["k_norm"] = jnp.ones((hd,), pd)
    if cross:
        p["gate"] = jnp.zeros((), pd)  # tanh-gated cross-attn (llama-vision)
    return p


def _qkv(p, cfg: ModelConfig, x: jax.Array, kv_x: jax.Array):
    """x (B,S,D) -> q (B,S,Hq,hd), k/v (B,Skv,Hkv,hd)."""
    hd = cfg.hd
    dt = cdtype(cfg)
    q = jnp.einsum("bsd,dh->bsh", x.astype(dt), p["wq"].astype(dt))
    k = jnp.einsum("bsd,dh->bsh", kv_x.astype(dt), p["wk"].astype(dt))
    v = jnp.einsum("bsd,dh->bsh", kv_x.astype(dt), p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(*q.shape[:2], cfg.n_heads, hd)
    k = k.reshape(*k.shape[:2], cfg.n_kv_heads, hd)
    v = v.reshape(*v.shape[:2], cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _sdpa(q, k, v, *, causal: bool, q_offset, kv_len=None, kv_start=None):
    """q (B,Sq,Hq,hd); k,v (B,Sk,Hkv,hd).  Grouped-query attention with
    f32 softmax.  kv_len masks out positions >= kv_len (decode caches)."""
    B, Sq, Hq, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, group, hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32))
    logits = logits / float(np.sqrt(hd))
    ki = jnp.arange(Sk)[None, :]
    if causal:
        qi = (q_offset + jnp.arange(Sq))[:, None]
        logits = jnp.where(ki <= qi, logits, -1e30)
    if kv_len is not None:
        logits = jnp.where(ki < kv_len, logits, -1e30)
    if kv_start is not None:
        # per-batch-slot window start (continuous batching: refilled
        # slots must not attend the previous occupant's cache prefix)
        start = kv_start.astype(jnp.int32).reshape(-1, 1, 1, 1, 1)  # (B,1,1,1,1)
        logits = jnp.where(
            jnp.arange(Sk)[None, None, None, None, :] >= start, logits, -1e30
        )
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, hd).astype(q.dtype)


def attention(
    p,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    causal: bool = True,
    kv_x: Optional[jax.Array] = None,
    rope: bool = True,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Training / prefill attention.  Returns (out, (k, v)) for caching.

    Long sequences are processed in query chunks of cfg.q_chunk to bound
    the live logits buffer (XLA path used by the dry-run; the Pallas
    flash kernel replaces this on real TPUs)."""
    kv_src = x if kv_x is None else kv_x
    q, k, v = _qkv(p, cfg, x, kv_src)
    if rope and kv_x is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    S = x.shape[1]
    if S <= cfg.q_chunk or kv_x is not None:
        out = _sdpa(q, k, v, causal=causal and kv_x is None, q_offset=0)
    else:
        nchunk = S // cfg.q_chunk
        qs = q.reshape(q.shape[0], nchunk, cfg.q_chunk, *q.shape[2:])

        def chunk_fn(carry, inp):
            ci, qc = inp
            oc = _sdpa(qc, k, v, causal=causal, q_offset=ci * cfg.q_chunk)
            return carry, oc

        _, outs = jax.lax.scan(
            chunk_fn, 0, (jnp.arange(nchunk), jnp.moveaxis(qs, 1, 0))
        )
        out = jnp.moveaxis(outs, 0, 1).reshape(q.shape)
    dt = cdtype(cfg)
    y = jnp.einsum(
        "bsh,hd->bsd",
        out.reshape(out.shape[0], out.shape[1], -1).astype(dt),
        p["wo"].astype(dt),
    )
    if "gate" in p:
        y = jnp.tanh(p["gate"].astype(dt)) * y
    return y, (k, v)


def decode_attention(
    p,
    cfg: ModelConfig,
    x: jax.Array,
    cache_k: jax.Array,
    cache_v: jax.Array,
    pos: jax.Array,
    *,
    rope: bool = True,
    update_cache: bool = True,
    kv_len=None,
    kv_start=None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode against a (B, Smax, Hkv, hd) KV cache.

    Returns (out, new_cache_k, new_cache_v)."""
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    q, k, v = _qkv(p, cfg, x, x)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if update_cache:
        cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype), (0, pos, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype), (0, pos, 0, 0))
    out = _sdpa(q, cache_k, cache_v, causal=False, q_offset=0,
                kv_len=(pos + 1) if kv_len is None else kv_len,
                kv_start=kv_start)
    dt = cdtype(cfg)
    y = jnp.einsum("bsh,hd->bsd", out.reshape(B, 1, -1).astype(dt), p["wo"].astype(dt))
    if "gate" in p:
        y = jnp.tanh(p["gate"].astype(dt)) * y
    return y, cache_k, cache_v


# ----------------------------------------------------------------------
# MLP (SwiGLU)
# ----------------------------------------------------------------------
def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    pd = jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    s = 1.0 / np.sqrt(D)
    return {
        "w_gate": (jax.random.normal(k1, (D, F)) * s).astype(pd),
        "w_up": (jax.random.normal(k2, (D, F)) * s).astype(pd),
        "w_down": (jax.random.normal(k3, (F, D)) / np.sqrt(F)).astype(pd),
    }


def mlp(p, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    dt = cdtype(cfg)
    g = jnp.einsum("bsd,df->bsf", x.astype(dt), p["w_gate"].astype(dt))
    u = jnp.einsum("bsd,df->bsf", x.astype(dt), p["w_up"].astype(dt))
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, p["w_down"].astype(dt))


# ----------------------------------------------------------------------
# MoE: sort-based capacity dispatch (no one-hot einsum waste)
# ----------------------------------------------------------------------
def init_moe(key, cfg: ModelConfig) -> Dict:
    D, F = cfg.d_model, cfg.d_ff
    E = cfg.moe.n_experts
    pd = jnp.dtype(cfg.param_dtype)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    s = 1.0 / np.sqrt(D)
    p = {
        "router": (jax.random.normal(k1, (D, E)) * s).astype(jnp.float32),
        "w_gate": (jax.random.normal(k2, (E, D, F)) * s).astype(pd),
        "w_up": (jax.random.normal(k3, (E, D, F)) * s).astype(pd),
        "w_down": (jax.random.normal(k4, (E, F, D)) / np.sqrt(F)).astype(pd),
    }
    if cfg.moe.n_shared:
        p["shared"] = init_mlp(k5, cfg, d_ff=cfg.moe.n_shared * cfg.d_ff)
    return p


def moe_ffn(p, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """x (B, S, D).  Sort-based top-k dispatch into an (E, C, D) buffer:
    FLOPs stay ~ active-expert FLOPs (capacity_factor overhead only)."""
    mc = cfg.moe
    B, S, D = x.shape
    N = B * S
    dt = cdtype(cfg)
    xt = x.reshape(N, D)
    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, mc.top_k)  # (N, K)
    top_p = top_p / jnp.clip(top_p.sum(-1, keepdims=True), 1e-9)  # renormalize

    K = mc.top_k
    E = mc.n_experts
    C = int(np.ceil(N * K * mc.capacity_factor / E))
    C = max(1, min(C, N))
    flat_e = top_e.reshape(-1).astype(jnp.int32)  # (N*K,)
    order = jnp.argsort(flat_e)  # stable: ties keep token order
    sorted_e = flat_e[order]
    # rank within expert = position - first index of that expert's run
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank = jnp.arange(N * K, dtype=jnp.int32) - first.astype(jnp.int32)
    keep = rank < C
    dest = jnp.where(keep, sorted_e * C + rank, E * C)  # overflow -> trash row
    src_token = order // K
    buf = jnp.zeros((E * C + 1, D), dtype=dt)
    buf = buf.at[dest].set(xt[src_token].astype(dt), mode="drop")
    buf = buf[: E * C].reshape(E, C, D)

    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(dt))
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["w_down"].astype(dt))

    y_flat = jnp.concatenate([y.reshape(E * C, D), jnp.zeros((1, D), dt)], axis=0)
    per_slot = y_flat[jnp.where(keep, dest, E * C)]  # (N*K, D)
    gates = top_p.reshape(-1)[order].astype(dt)
    contrib = per_slot * jnp.where(keep, gates, 0.0)[:, None]
    out = jnp.zeros((N, D), dtype=dt).at[src_token].add(contrib)
    if mc.n_shared:
        out = out + mlp(p["shared"], cfg, x).reshape(N, D)
    return out.reshape(B, S, D)


def moe_aux_loss(p, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Load-balancing auxiliary loss (switch-style)."""
    mc = cfg.moe
    xt = x.reshape(-1, x.shape[-1])
    probs = jax.nn.softmax((xt.astype(jnp.float32) @ p["router"]), axis=-1)
    top_e = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top_e, mc.n_experts, dtype=jnp.float32), axis=0)
    imp = jnp.mean(probs, axis=0)
    return mc.n_experts * jnp.sum(frac * imp)
