"""RWKV6 (Finch) blocks: time-mix with data-dependent per-channel decay
and channel-mix, in the chunked matmul-parallel form for train/prefill
(MXU-friendly — the TPU adaptation of the recurrence; the Pallas wkv6
kernel provides the fused per-step form) and O(1)-state decode.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import cdtype, rms_norm

LORA_R = 64


def init_rwkv_block(key, cfg: ModelConfig) -> Dict:
    D = cfg.d_model
    H = cfg.n_heads
    hd = D // H
    F = cfg.d_ff
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 12)
    s = 1.0 / np.sqrt(D)
    return {
        # time-mix
        "tm_norm": jnp.ones((D,), pd),
        "mix_r": jnp.full((D,), 0.5, pd),
        "mix_k": jnp.full((D,), 0.5, pd),
        "mix_v": jnp.full((D,), 0.5, pd),
        "mix_w": jnp.full((D,), 0.5, pd),
        "wr": (jax.random.normal(ks[0], (D, D)) * s).astype(pd),
        "wk": (jax.random.normal(ks[1], (D, D)) * s).astype(pd),
        "wv": (jax.random.normal(ks[2], (D, D)) * s).astype(pd),
        "wg": (jax.random.normal(ks[3], (D, D)) * s).astype(pd),
        "wo": (jax.random.normal(ks[4], (D, D)) * s).astype(pd),
        # data-dependent decay LoRA: w_t = exp(-exp(w0 + tanh(x A) B))
        "w0": jnp.full((D,), -2.0, pd),
        "wA": (jax.random.normal(ks[5], (D, LORA_R)) * s).astype(pd),
        "wB": (jax.random.normal(ks[6], (LORA_R, D)) * 0.1).astype(pd),
        "u": (jax.random.normal(ks[7], (H, hd)) * 0.1).astype(pd),
        # channel-mix
        "cm_norm": jnp.ones((D,), pd),
        "cmix_k": jnp.full((D,), 0.5, pd),
        "cmix_r": jnp.full((D,), 0.5, pd),
        "ck": (jax.random.normal(ks[8], (D, F)) * s).astype(pd),
        "cv": (jax.random.normal(ks[9], (F, D)) / np.sqrt(F)).astype(pd),
        "cr": (jax.random.normal(ks[10], (D, D)) * s).astype(pd),
    }


def _token_shift(x: jax.Array, prev: Optional[jax.Array]) -> jax.Array:
    """x (B,S,D): shift right by one; `prev` is the last token of the
    previous segment (decode/state carry), zeros at start."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def wkv6_chunked(
    r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array, u: jax.Array,
    state: Optional[jax.Array] = None, chunk: int = 32,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked-parallel WKV (the FLA-style matmul form).

    r/k/v/w: (B, H, T, hd); u: (H, hd); state: (B, H, hd, hd) f32.
      y_t = r_t @ S_{t-1} + (r_t . (u k_t)) v_t
      S_t = diag(w_t) S_{t-1} + k_t v_t^T
    Within a chunk: P_t = prod_{s<=t} w_s;
      y = ((r*P_prev) Kd^T ∘ mask) V + (r*P_prev) @ S0 + diag-term
      with Kd rows k_s / P_s (exact; chunk kept short for conditioning).
    """
    B, H, T, hd = r.shape
    assert T % chunk == 0, (T, chunk)
    if state is None:
        state = jnp.zeros((B, H, hd, hd), dtype=jnp.float32)
    nchunks = T // chunk

    def to_chunks(x):
        return jnp.moveaxis(
            x.astype(jnp.float32).reshape(B, H, nchunks, chunk, hd), 2, 0
        )  # (nC, B, H, C, hd)

    rc, kc, vc, wc = map(to_chunks, (r, k, v, w))
    uf = u.astype(jnp.float32)

    mask = jnp.tril(jnp.ones((chunk, chunk), dtype=jnp.float32), k=-1)

    def step(S, xs):
        rr, kk, vv, ww = xs  # (B,H,C,hd)
        logw = jnp.log(jnp.clip(ww, 1e-12))
        P = jnp.exp(jnp.cumsum(logw, axis=2))  # (B,H,C,hd) prod_{s<=t}
        P_prev = P / ww  # prod_{s<t}
        r_d = rr * P_prev
        k_d = kk / jnp.clip(P, 1e-30)
        att = jnp.einsum("bhti,bhsi->bhts", r_d, k_d) * mask  # s<t strictly
        diag = jnp.einsum("bhti,hi,bhti->bht", rr, uf, kk)
        y = (
            jnp.einsum("bhts,bhsj->bhtj", att, vv)
            + jnp.einsum("bhti,bhij->bhtj", r_d, S)
            + diag[..., None] * vv
        )
        S_new = P[:, :, -1, :, None] * S + jnp.einsum(
            "bhti,bhtj->bhij", k_d * P[:, :, -1:, :], vv
        )
        return S_new, y

    final, ys = jax.lax.scan(step, state, (rc, kc, vc, wc))
    y = jnp.moveaxis(ys, 0, 2).reshape(B, H, T, hd)
    return y.astype(r.dtype), final


def rwkv_block(
    p: Dict,
    cfg: ModelConfig,
    x: jax.Array,
    state: Optional[Dict] = None,
) -> Tuple[jax.Array, Dict]:
    """Full RWKV6 block (time-mix + channel-mix).  state carries
    {'S': (B,H,hd,hd), 'tm_prev': (B,1,D), 'cm_prev': (B,1,D)} for
    segment-chained prefill and O(1) decode."""
    B, S, D = x.shape
    H = cfg.n_heads
    hd = D // H
    dt = cdtype(cfg)
    st = state or {}

    # ---- time mix ----
    xn = rms_norm(x, p["tm_norm"], cfg.norm_eps)
    xs = _token_shift(xn, st.get("tm_prev"))

    def mixed(name):
        m = p["mix_" + name].astype(dt)
        return xn * m + xs * (1 - m)

    r = jnp.einsum("bsd,de->bse", mixed("r").astype(dt), p["wr"].astype(dt))
    k = jnp.einsum("bsd,de->bse", mixed("k").astype(dt), p["wk"].astype(dt))
    v = jnp.einsum("bsd,de->bse", mixed("v").astype(dt), p["wv"].astype(dt))
    g = jnp.einsum("bsd,de->bse", mixed("r").astype(dt), p["wg"].astype(dt))
    # data-dependent decay
    wl = jnp.einsum(
        "bsr,rd->bsd",
        jnp.tanh(jnp.einsum("bsd,dr->bsr", mixed("w").astype(dt), p["wA"].astype(dt))),
        p["wB"].astype(dt),
    )
    w = jnp.exp(-jnp.exp((p["w0"].astype(jnp.float32) + wl.astype(jnp.float32))))

    heads = lambda t: jnp.moveaxis(t.reshape(B, S, H, hd), 2, 1)  # (B,H,S,hd)
    chunk = 32
    while S % chunk:
        chunk //= 2
    y, S_out = wkv6_chunked(
        heads(r), heads(k), heads(v), heads(w.astype(dt)), p["u"],
        state=st.get("S"), chunk=chunk,
    )
    y = jnp.moveaxis(y, 1, 2).reshape(B, S, D)
    y = y * jax.nn.silu(g)
    y = jnp.einsum("bsd,de->bse", y.astype(dt), p["wo"].astype(dt))
    x = x + y

    # ---- channel mix ----
    xn2 = rms_norm(x, p["cm_norm"], cfg.norm_eps)
    xs2 = _token_shift(xn2, st.get("cm_prev"))
    mk = p["cmix_k"].astype(dt)
    mr = p["cmix_r"].astype(dt)
    kk = jnp.einsum("bsd,df->bsf", (xn2 * mk + xs2 * (1 - mk)).astype(dt), p["ck"].astype(dt))
    kk = jnp.square(jax.nn.relu(kk))
    vv = jnp.einsum("bsf,fd->bsd", kk, p["cv"].astype(dt))
    rr = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", (xn2 * mr + xs2 * (1 - mr)).astype(dt), p["cr"].astype(dt))
    )
    x = x + rr * vv

    new_state = {
        "S": S_out,
        "tm_prev": xn[:, -1:, :],
        "cm_prev": xn2[:, -1:, :],
    }
    return x, new_state
