"""Model configuration for the assigned architecture pool."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0  # shared (always-on) experts, DeepSeek/Kimi style
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # 'dense' | 'moe' | 'rwkv6' | 'mamba_hybrid'
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    # attention flavor flags
    qk_norm: bool = False
    qkv_bias: bool = False
    attn_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    # MoE
    moe: Optional[MoEConfig] = None
    # VLM: a cross-attention layer every `cross_attn_every` layers
    cross_attn_every: int = 0
    n_img_tokens: int = 0
    # audio backbone: inputs are precomputed frame embeddings (stub frontend)
    embed_inputs: bool = True
    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_heads: int = 0  # 0 -> n_heads; Zamba2 uses expand=2 (d_inner = 2*d_model)
    attn_every: int = 0  # hybrid: shared attention block period (Zamba2)
    # numerics / memory
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: str = "nothing"  # 'nothing' | 'dots' | 'none'
    kv_shard: str = "model"  # 'model' | 'replicated' (GQA kv_heads < |model|)
    q_chunk: int = 2048  # query chunking for long-sequence XLA attention
    # training
    optimizer: str = "adamw"  # 'adamw' | 'adafactor'
    grad_dtype: str = "float32"  # bf16 accumulation for the 1T config
    microbatches: int = 8

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "rwkv6"

    @property
    def subquadratic(self) -> bool:
        return self.family in ("rwkv6", "mamba_hybrid")

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        D, F, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        hd = self.hd
        n = V * D * 2  # embed + head
        if self.family == "rwkv6":
            per = D * (self.n_heads * hd) * 4 + self.n_heads * hd * D  # r,k,v,g,out
            per += 2 * (64 * D)  # decay lora
            per += D * F + F * D + D * D  # channel mix (k, v, r)
            return n + L * per
        if self.family == "mamba_hybrid":
            Hs = self.ssm_heads or self.n_heads
            d_in = Hs * self.ssm_head_dim
            # mamba mixer only per block (no per-block MLP in Zamba2)
            per = D * (2 * d_in + 2 * self.ssm_state + Hs) + d_in * D
            blocks = n + L * per
            # one shared attention+MLP block (parameters reused)
            shared = D * (self.n_heads + 2 * self.n_kv_heads) * hd + self.n_heads * hd * D
            shared += 3 * D * F
            return blocks + shared
        attn = D * (self.n_heads + 2 * self.n_kv_heads) * hd + self.n_heads * hd * D
        if self.moe:
            ffn = self.moe.n_experts * 3 * D * F + D * self.moe.n_experts
            ffn += self.moe.n_shared * 3 * D * F
        else:
            ffn = 3 * D * F
        per = attn + ffn
        if self.cross_attn_every:
            n_cross = L // self.cross_attn_every
            per_cross = attn  # cross-attn layers replace self-attn FLOPs-wise
            return n + L * per + n_cross * per_cross
        return n + L * per

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k + shared experts)."""
        if not self.moe:
            return self.param_count()
        D, F, L = self.d_model, self.d_ff, self.n_layers
        hd = self.hd
        attn = D * (self.n_heads + 2 * self.n_kv_heads) * hd + self.n_heads * hd * D
        ffn = (self.moe.top_k + self.moe.n_shared) * 3 * D * F + D * self.moe.n_experts
        return self.vocab * D * 2 + L * (attn + ffn)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    small = dict(
        n_layers=max(2, (cfg.attn_every or 0) and cfg.attn_every),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=128,
        vocab=256,
        head_dim=16,
        n_img_tokens=8 if cfg.cross_attn_every else 0,
        cross_attn_every=2 if cfg.cross_attn_every else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_heads=8 if cfg.ssm_heads else 0,
        attn_every=2 if cfg.attn_every else 0,
        moe=MoEConfig(4, 2, cfg.moe.n_shared and 1, cfg.moe.capacity_factor) if cfg.moe else None,
        param_dtype="float32",
        compute_dtype="float32",
        microbatches=1,
        q_chunk=64,
    )
    if cfg.attn_every:
        small["n_layers"] = 4
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
