"""Parameter/activation partition rules for the production meshes.

Axes: ("pod", "data", "model") multi-pod or ("data", "model") single-pod.
- TP over "model": attention heads, d_ff, vocab, experts (EP).
- ZeRO-style parameter sharding over "data" on the other major dim.
- DP batch over ("pod", "data").

Rules match on parameter-path key names, so every architecture family
shares one table.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# name -> spec builder over the trailing (non-layer-stacked) dims
_RULES = [
    # MoE experts: (E, D, F) / (E, F, D)
    ("moe.w_gate", ("model", "data", None)),
    ("moe.w_up", ("model", "data", None)),
    ("moe.w_down", ("model", "data", None)),
    ("moe.router", ("data", None)),
    ("moe.shared.w_gate", ("data", "model")),
    ("moe.shared.w_up", ("data", "model")),
    ("moe.shared.w_down", ("model", "data")),
    # attention
    (".attn.wq", ("data", "model")),
    (".attn.wk", ("data", "model")),
    (".attn.wv", ("data", "model")),
    (".attn.wo", ("model", "data")),
    (".attn.bq", ("model",)),
    (".attn.bk", ("model",)),
    (".attn.bv", ("model",)),
    # dense mlp
    ("mlp.w_gate", ("data", "model")),
    ("mlp.w_up", ("data", "model")),
    ("mlp.w_down", ("model", "data")),
    # rwkv
    (".wr", ("data", "model")),
    (".wk", ("data", "model")),
    (".wv", ("data", "model")),
    (".wg", ("data", "model")),
    (".wo", ("model", "data")),
    (".ck", ("data", "model")),
    (".cv", ("model", "data")),
    (".cr", ("data", "model")),
    (".wA", ("data", None)),
    (".wB", (None, "model")),
    # mamba
    ("in_proj", ("data", "model")),
    ("out_proj", ("model", "data")),
    # embeddings: vocab over data (ZeRO), d_model over model — the token
    # gather then only all-gathers a (V, D/|model|) slice over 'data'
    # instead of fully rematerializing a vocab-sharded table
    ("embed", ("data", "model")),
    ("lm_head", ("data", "model")),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return ".".join(parts)


def spec_for(path_str: str, ndim: int, stacked: bool) -> P:
    """PartitionSpec for a parameter; `stacked` = leading layer dim."""
    for pat, axes in _RULES:
        if pat in "." + path_str:
            trailing = list(axes)
            lead = [None] if stacked else []
            spec = lead + trailing
            # pad/trim to ndim
            while len(spec) < ndim:
                spec.append(None)
            return P(*spec[:ndim])
    return P(*([None] * ndim))


def _is_stacked(path_str: str) -> bool:
    return path_str.startswith("blocks") or path_str.startswith("cross_blocks")


_KV_PATTERNS = (".attn.wk", ".attn.wv", ".attn.bk", ".attn.bv")


def param_specs(params_shape: Any, cfg=None) -> Any:
    """Pytree of PartitionSpec matching a params (shape) pytree.

    cfg.kv_shard == 'replicated' keeps KV projections unsharded on the
    model axis: with GQA kv_heads < |model| the per-device KV slice is a
    fraction of a head and the attention einsums force resharding
    traffic; replicating the (small) KV projections removes it."""
    replicate_kv = cfg is not None and getattr(cfg, "kv_shard", "model") == "replicated"

    def fn(path, leaf):
        ps = _path_str(path)
        spec = spec_for(ps, len(leaf.shape), _is_stacked(ps))
        if replicate_kv and any(pat in "." + ps for pat in _KV_PATTERNS):
            spec = P(*[("data" if a == "data" else None) for a in (list(spec) + [None] * len(leaf.shape))[: len(leaf.shape)]])
        return spec

    return jax.tree_util.tree_map_with_path(fn, params_shape)


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _divisible_dp(mesh: Mesh, B: int) -> Optional[Tuple[str, ...]]:
    """Largest prefix of the dp axes that divides B (B=1 -> replicate)."""
    axes = []
    prod = 1
    for a in batch_axes(mesh):
        prod *= mesh.shape[a]
        if B % prod == 0:
            axes.append(a)
        else:
            break
    return tuple(axes) if axes else None


def batch_specs(mesh: Mesh, batch_shape: Any) -> Any:
    """Shard the leading batch dim over (pod, data) where divisible."""

    def fn(leaf):
        B = leaf.shape[0] if leaf.ndim else 1
        dp = _divisible_dp(mesh, B)
        spec = [dp] + [None] * (leaf.ndim - 1) if dp else [None] * leaf.ndim
        return P(*spec)

    return jax.tree.map(fn, batch_shape)


def decode_state_specs(mesh: Mesh, state_shape: Dict) -> Dict:
    """KV caches: (L, B, Smax, Hkv, hd) -> batch over dp axes, sequence
    over 'model' (flash-decode style sharded cache); recurrent states:
    batch over dp axes."""

    def fn(path, leaf):
        name = _path_str(path)
        if name in ("k", "v", "xk", "xv"):
            B = leaf.shape[1]
            dp = _divisible_dp(mesh, B)
            smax = leaf.shape[2]
            seq = "model" if smax % mesh.shape["model"] == 0 and smax >= 4096 else None
            return P(None, dp, seq, None, None)
        if name == "pos":
            return P()
        if name in ("S", "h"):  # (L, B, H, ...) recurrent states
            B = leaf.shape[1]
            dp = _divisible_dp(mesh, B)
            spec = [None, dp] + [None] * (leaf.ndim - 2)
            return P(*spec)
        # tm_prev/cm_prev: (L, B, 1, D)
        B = leaf.shape[1]
        dp = _divisible_dp(mesh, B)
        return P(*([None, dp] + [None] * (leaf.ndim - 2)))

    return jax.tree_util.tree_map_with_path(fn, state_shape)


def shardings_from_specs(mesh: Mesh, specs: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
