"""LM data pipeline built ON the paper's technique: TensorFrame is the
relational layer for corpus curation — quality-filter UDFs, dedup
group-bys, metadata joins and mixture re-weighting all run as dataframe
ops before tokens are batched for the model.

This is where the reproduction and the training framework meet: the
same stateless-UDF/filter/groupby/join engine benchmarked on TPC-H
curates the training corpus.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np


def synthetic_corpus(n_docs: int = 2000, seed: int = 0) -> Dict[str, np.ndarray]:
    """A toy document-metadata table: id, source, quality score, lang,
    length, and a comment-ish snippet for UDF filtering."""
    rng = np.random.default_rng(seed)
    sources = np.array(["web", "books", "code", "wiki", "forums"], dtype=object)
    langs = np.array(["en", "de", "fr", "zh"], dtype=object)
    snippets = np.array(
        ["clean text", "buzzword spam click here", "high quality prose",
         "lorem ipsum filler", "duplicate boilerplate header"], dtype=object,
    )
    return {
        "doc_id": np.arange(n_docs, dtype=np.int64),
        "source": sources[rng.integers(0, len(sources), n_docs)],
        "quality": np.round(rng.uniform(0, 1, n_docs), 3),
        "lang": langs[rng.choice(len(langs), n_docs, p=[0.7, 0.1, 0.1, 0.1])],
        "length": rng.integers(50, 4000, n_docs),
        "snippet": snippets[rng.integers(0, len(snippets), n_docs)],
        "dup_group": rng.integers(0, n_docs // 3, n_docs),
    }


def curate(corpus: Dict[str, np.ndarray], mixture: Optional[Dict[str, float]] = None):
    """The dataframe-powered curation plan:

    1. stateless UDF quality filter (paper §IV-A),
    2. dedup: keep the best-quality doc per dup_group (group-by + join),
    3. per-source mixture re-weighting (group-by sizes -> weights).

    Returns (doc_ids, weights) for the batcher."""
    from repro.core import TensorFrame, col

    f = TensorFrame.from_arrays(corpus)
    f = f.filter(
        (col("quality") > 0.25)
        & (col("lang") == "en")
        & (col("length") >= 100)
        & ~col("snippet").str.contains("spam")
        & ~col("snippet").str.contains("boilerplate")
    )
    best = f.groupby("dup_group").agg([("best_q", "max", "quality")])
    f = f.join(best, on="dup_group")
    f = f.filter(col("quality") == col("best_q"))
    sizes = f.groupby("source").agg([("n", "size", "")])
    src_n = dict(zip(sizes.column("source"), sizes.column("n")))
    mixture = mixture or {s: 1.0 for s in src_n}
    doc_ids = f.column("doc_id")
    srcs = f.column("source")
    weights = np.array(
        [mixture.get(s, 0.0) / max(1, src_n.get(s, 1)) for s in srcs], dtype=np.float64
    )
    weights = weights / weights.sum()
    return doc_ids.astype(np.int64), weights


def token_batches(
    doc_ids: np.ndarray,
    weights: np.ndarray,
    vocab: int,
    batch: int,
    seq: int,
    seed: int = 0,
    steps: Optional[int] = None,
) -> Iterator[Dict[str, np.ndarray]]:
    """Sample docs by mixture weight; synthesize deterministic token
    streams per doc id (stand-in for a real tokenizer/shard reader)."""
    rng = np.random.default_rng(seed)
    i = 0
    while steps is None or i < steps:
        picks = rng.choice(doc_ids, size=batch, p=weights)
        toks = np.empty((batch, seq + 1), dtype=np.int32)
        for b, did in enumerate(picks):
            drng = np.random.default_rng(int(did) * 1_000_003 + i)
            toks[b] = drng.integers(0, vocab, seq + 1)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        i += 1
