"""Data generation and pipelines: TPC-H/TPC-DS mini-dbgen + the LM
token pipeline that uses TensorFrame as its relational layer."""
