"""Mini-dbgen: a numpy TPC-H table generator (paper §VI-A).

Schema- and distribution-faithful where queries depend on it (key
relationships, date ranges, LIKE-able text patterns, value domains);
approximate elsewhere.  Row counts follow the spec: SF=1 gives 6M
lineitem rows.  Deterministic under ``seed``.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

Tables = Dict[str, Dict[str, np.ndarray]]

REGIONS = np.array(["AFRICA", "AMERICA", "ASIA", "EUROPE", "MIDDLE EAST"], dtype=object)
NATIONS = [
    ("ALGERIA", 0), ("ARGENTINA", 1), ("BRAZIL", 1), ("CANADA", 1),
    ("EGYPT", 4), ("ETHIOPIA", 0), ("FRANCE", 3), ("GERMANY", 3),
    ("INDIA", 2), ("INDONESIA", 2), ("IRAN", 4), ("IRAQ", 4),
    ("JAPAN", 2), ("JORDAN", 4), ("KENYA", 0), ("MOROCCO", 0),
    ("MOZAMBIQUE", 0), ("PERU", 1), ("CHINA", 2), ("ROMANIA", 3),
    ("SAUDI ARABIA", 4), ("VIETNAM", 2), ("RUSSIA", 3),
    ("UNITED KINGDOM", 3), ("UNITED STATES", 1),
]
SEGMENTS = np.array(["AUTOMOBILE", "BUILDING", "FURNITURE", "MACHINERY", "HOUSEHOLD"], dtype=object)
PRIORITIES = np.array(["1-URGENT", "2-HIGH", "3-MEDIUM", "4-NOT SPECIFIED", "5-LOW"], dtype=object)
SHIPMODES = np.array(["REG AIR", "AIR", "RAIL", "SHIP", "TRUCK", "MAIL", "FOB"], dtype=object)
INSTRUCTS = np.array(
    ["DELIVER IN PERSON", "COLLECT COD", "NONE", "TAKE BACK RETURN"], dtype=object
)
TYPE_S1 = ["STANDARD", "SMALL", "MEDIUM", "LARGE", "ECONOMY", "PROMO"]
TYPE_S2 = ["ANODIZED", "BURNISHED", "PLATED", "POLISHED", "BRUSHED"]
TYPE_S3 = ["TIN", "NICKEL", "BRASS", "STEEL", "COPPER"]
CONTAINER_S1 = ["SM", "LG", "MED", "JUMBO", "WRAP"]
CONTAINER_S2 = ["CASE", "BOX", "BAG", "JAR", "PKG", "PACK", "CAN", "DRUM"]
COLORS = [
    "almond", "antique", "aquamarine", "azure", "beige", "bisque", "black",
    "blanched", "blue", "blush", "brown", "burlywood", "burnished", "chartreuse",
    "chiffon", "chocolate", "coral", "cornflower", "cornsilk", "cream", "cyan",
    "dark", "deep", "dim", "dodger", "drab", "firebrick", "floral", "forest",
    "frosted", "gainsboro", "ghost", "goldenrod", "green", "grey", "honeydew",
    "hot", "indian", "ivory", "khaki", "lace", "lavender", "lawn", "lemon",
    "light", "lime", "linen", "magenta", "maroon", "medium", "metallic", "midnight",
    "mint", "misty", "moccasin", "navajo", "navy", "olive", "orange", "orchid",
    "pale", "papaya", "peach", "peru", "pink", "plum", "powder", "puff", "purple",
    "red", "rose", "rosy", "royal", "saddle", "salmon", "sandy", "seashell",
    "sienna", "sky", "slate", "smoke", "snow", "spring", "steel", "tan",
    "thistle", "tomato", "turquoise", "violet", "wheat", "white", "yellow",
]
WORDS = [
    "the", "quickly", "slyly", "carefully", "furiously", "express", "regular",
    "final", "ironic", "pending", "bold", "even", "silent", "blithely",
    "deposits", "accounts", "packages", "foxes", "theodolites", "pinto",
    "beans", "instructions", "dependencies", "platelets", "requests", "ideas",
    "asymptotes", "somas", "sheaves", "sauternes", "waters", "dugouts",
    "sleep", "wake", "nag", "haggle", "boost", "detect", "integrate", "among",
    "above", "according", "against", "along", "alongside",
]


def _rand_words(rng, n, k_lo=4, k_hi=9, vocab=None) -> np.ndarray:
    """Vectorized random word-salad sentences."""
    vocab = np.array(vocab if vocab is not None else WORDS)
    k = int(k_hi)
    picks = vocab[rng.integers(0, len(vocab), size=(n, k))]
    lens = rng.integers(k_lo, k_hi + 1, size=n)
    out = np.empty(n, dtype=object)
    for i in range(n):
        out[i] = " ".join(picks[i, : lens[i]])
    return out


def _inject_pattern(rng, comments: np.ndarray, first: str, second: str, prob: float) -> np.ndarray:
    """Inject '<first> ... <second>' into a fraction of comments (the
    dbgen trick that makes Q13/Q16 predicates selective)."""
    n = comments.shape[0]
    hit = rng.random(n) < prob
    mids = np.array(["packages", "ironic", "", "pending accounts"], dtype=object)
    for i in np.nonzero(hit)[0]:
        mid = mids[rng.integers(0, len(mids))]
        sep = f" {mid} " if mid else " "
        comments[i] = f"{comments[i][:20]} {first}{sep}{second} {comments[i][20:40]}"
    return comments


def _dates(rng, n, lo="1992-01-01", hi="1998-08-02"):
    base = np.datetime64(lo, "D")
    span = int((np.datetime64(hi, "D") - base).astype(int))
    return base + rng.integers(0, span + 1, n).astype("timedelta64[D]")


def generate(sf: float = 0.01, seed: int = 0) -> Tables:
    rng = np.random.default_rng(seed)
    n_supp = max(3, int(10_000 * sf))
    n_part = max(5, int(200_000 * sf))
    n_cust = max(5, int(150_000 * sf))
    n_ord = max(10, int(1_500_000 * sf))

    # ---- region / nation ----
    region = {
        "r_regionkey": np.arange(5, dtype=np.int64),
        "r_name": REGIONS.copy(),
        "r_comment": _rand_words(rng, 5),
    }
    nation = {
        "n_nationkey": np.arange(25, dtype=np.int64),
        "n_name": np.array([n for n, _ in NATIONS], dtype=object),
        "n_regionkey": np.array([r for _, r in NATIONS], dtype=np.int64),
        "n_comment": _rand_words(rng, 25),
    }

    # ---- supplier ----
    s_nat = rng.integers(0, 25, n_supp)
    supplier = {
        "s_suppkey": np.arange(1, n_supp + 1, dtype=np.int64),
        "s_name": np.array([f"Supplier#{i:09d}" for i in range(1, n_supp + 1)], dtype=object),
        "s_address": _rand_words(rng, n_supp, 2, 4),
        "s_nationkey": s_nat,
        "s_phone": _phones(rng, s_nat),
        "s_acctbal": np.round(rng.uniform(-999.99, 9999.99, n_supp), 2),
        "s_comment": _inject_pattern(
            rng, _rand_words(rng, n_supp), "Customer", "Complaints", 0.003
        ),
    }

    # ---- part ----
    mfgr = rng.integers(1, 6, n_part)
    brand = mfgr * 10 + rng.integers(1, 6, n_part)
    t1 = np.array(TYPE_S1, dtype=object)[rng.integers(0, 6, n_part)]
    t2 = np.array(TYPE_S2, dtype=object)[rng.integers(0, 5, n_part)]
    t3 = np.array(TYPE_S3, dtype=object)[rng.integers(0, 5, n_part)]
    colors = np.array(COLORS, dtype=object)
    name_words = colors[rng.integers(0, len(colors), size=(n_part, 5))]
    part = {
        "p_partkey": np.arange(1, n_part + 1, dtype=np.int64),
        "p_name": np.array([" ".join(r) for r in name_words], dtype=object),
        "p_mfgr": np.array([f"Manufacturer#{m}" for m in mfgr], dtype=object),
        "p_brand": np.array([f"Brand#{b}" for b in brand], dtype=object),
        "p_type": np.array([f"{a} {b} {c}" for a, b, c in zip(t1, t2, t3)], dtype=object),
        "p_size": rng.integers(1, 51, n_part),
        "p_container": np.array(
            [
                f"{a} {b}"
                for a, b in zip(
                    np.array(CONTAINER_S1, dtype=object)[rng.integers(0, 5, n_part)],
                    np.array(CONTAINER_S2, dtype=object)[rng.integers(0, 8, n_part)],
                )
            ],
            dtype=object,
        ),
        "p_retailprice": np.round(
            900 + (np.arange(1, n_part + 1) % 1000) / 10 + rng.uniform(0, 100, n_part), 2
        ),
        "p_comment": _rand_words(rng, n_part, 2, 5),
    }

    # ---- partsupp: 4 suppliers per part ----
    ps_part = np.repeat(np.arange(1, n_part + 1, dtype=np.int64), 4)
    n_ps = ps_part.shape[0]
    ps_supp = (
        (ps_part + (np.tile(np.arange(4), n_part) * (n_supp // 4 + 1))) % n_supp
    ) + 1
    partsupp = {
        "ps_partkey": ps_part,
        "ps_suppkey": ps_supp.astype(np.int64),
        "ps_availqty": rng.integers(1, 10_000, n_ps),
        "ps_supplycost": np.round(rng.uniform(1.0, 1000.0, n_ps), 2),
        "ps_comment": _rand_words(rng, n_ps, 3, 8),
    }

    # ---- customer ----
    c_nat = rng.integers(0, 25, n_cust)
    customer = {
        "c_custkey": np.arange(1, n_cust + 1, dtype=np.int64),
        "c_name": np.array([f"Customer#{i:09d}" for i in range(1, n_cust + 1)], dtype=object),
        "c_address": _rand_words(rng, n_cust, 2, 4),
        "c_nationkey": c_nat,
        "c_phone": _phones(rng, c_nat),
        "c_acctbal": np.round(rng.uniform(-999.99, 9999.99, n_cust), 2),
        "c_mktsegment": SEGMENTS[rng.integers(0, 5, n_cust)],
        "c_comment": _rand_words(rng, n_cust),
    }

    # ---- orders (1/3 of customers have no orders, per spec) ----
    cust_with_orders = np.arange(1, n_cust + 1)[: max(1, (n_cust * 2) // 3)]
    o_cust = cust_with_orders[rng.integers(0, len(cust_with_orders), n_ord)]
    o_date = _dates(rng, n_ord, "1992-01-01", "1998-08-02")
    orders = {
        "o_orderkey": np.arange(1, n_ord + 1, dtype=np.int64) * 4 - 3,  # sparse keys
        "o_custkey": o_cust.astype(np.int64),
        "o_orderstatus": np.array(["F", "O", "P"], dtype=object)[
            rng.choice(3, n_ord, p=[0.49, 0.49, 0.02])
        ],
        "o_totalprice": np.round(rng.uniform(850.0, 560_000.0, n_ord), 2),
        "o_orderdate": o_date,
        "o_orderpriority": PRIORITIES[rng.integers(0, 5, n_ord)],
        "o_clerk": np.array(
            [f"Clerk#{i:09d}" for i in rng.integers(1, max(2, n_supp), n_ord)], dtype=object
        ),
        "o_shippriority": np.zeros(n_ord, dtype=np.int64),
        "o_comment": _inject_pattern(
            rng, _rand_words(rng, n_ord), "special", "requests", 0.01
        ),
    }

    # ---- lineitem: 1..7 per order ----
    per_order = rng.integers(1, 8, n_ord)
    l_ordkey = np.repeat(orders["o_orderkey"], per_order)
    n_li = l_ordkey.shape[0]
    l_odate = np.repeat(o_date, per_order)
    l_part = rng.integers(1, n_part + 1, n_li)
    # supplier consistent with partsupp: one of the part's 4 suppliers
    pick = rng.integers(0, 4, n_li)
    l_supp = ((l_part + pick * (n_supp // 4 + 1)) % n_supp) + 1
    quantity = rng.integers(1, 51, n_li).astype(np.float64)
    retail = part["p_retailprice"][l_part - 1]
    extended = np.round(quantity * retail, 2)
    ship_lag = rng.integers(1, 122, n_li).astype("timedelta64[D]")
    commit_lag = rng.integers(30, 91, n_li).astype("timedelta64[D]")
    receipt_lag = rng.integers(1, 31, n_li).astype("timedelta64[D]")
    l_ship = l_odate + ship_lag
    lineitem = {
        "l_orderkey": l_ordkey.astype(np.int64),
        "l_partkey": l_part.astype(np.int64),
        "l_suppkey": l_supp.astype(np.int64),
        "l_linenumber": _line_numbers(per_order),
        "l_quantity": quantity,
        "l_extendedprice": extended,
        "l_discount": np.round(rng.uniform(0.0, 0.10, n_li), 2),
        "l_tax": np.round(rng.uniform(0.0, 0.08, n_li), 2),
        "l_returnflag": np.array(["R", "A", "N"], dtype=object)[
            rng.choice(3, n_li, p=[0.25, 0.25, 0.5])
        ],
        "l_linestatus": np.array(["O", "F"], dtype=object)[rng.integers(0, 2, n_li)],
        "l_shipdate": l_ship,
        "l_commitdate": l_odate + commit_lag,
        "l_receiptdate": l_ship + receipt_lag,
        "l_shipinstruct": INSTRUCTS[rng.integers(0, 4, n_li)],
        "l_shipmode": SHIPMODES[rng.integers(0, 7, n_li)],
        "l_comment": _rand_words(rng, n_li, 2, 5),
    }

    return {
        "region": region,
        "nation": nation,
        "supplier": supplier,
        "part": part,
        "partsupp": partsupp,
        "customer": customer,
        "orders": orders,
        "lineitem": lineitem,
    }


def _phones(rng, nationkeys: np.ndarray) -> np.ndarray:
    n = nationkeys.shape[0]
    a = rng.integers(100, 1000, n)
    b = rng.integers(100, 1000, n)
    c = rng.integers(1000, 10000, n)
    return np.array(
        [f"{10 + nk}-{x}-{y}-{z}" for nk, x, y, z in zip(nationkeys, a, b, c)],
        dtype=object,
    )


def _line_numbers(per_order: np.ndarray) -> np.ndarray:
    total = int(per_order.sum())
    out = np.ones(total, dtype=np.int64)
    ends = np.cumsum(per_order)
    starts = ends - per_order
    idx = np.arange(total)
    return (idx - np.repeat(starts, per_order) + 1).astype(np.int64)


# ----------------------------------------------------------------------
# convenience: load as TensorFrames / store tables / oracle dicts
# ----------------------------------------------------------------------
def as_frames(tables: Tables, **kwargs):
    from repro.core import TensorFrame

    return {name: TensorFrame.from_arrays(cols, **kwargs) for name, cols in tables.items()}


def as_store(tables: Tables, *, chunk_rows: int = 1 << 16, sort_fact_by_date: bool = False):
    """Tables as chunked ``repro.store`` tables (SQL scan-pushdown scope).

    ``sort_fact_by_date`` orders lineitem by ``l_shipdate`` and orders
    by ``o_orderdate`` before chunking — the date-clustered layout real
    fact tables have, which is what makes zone maps selective on date
    predicates (a time-ordered chunk grid skips everything outside the
    predicate's date range).
    """
    from repro import store

    out = {}
    for name, cols in tables.items():
        cols = dict(cols)
        key = {"lineitem": "l_shipdate", "orders": "o_orderdate"}.get(name)
        if sort_fact_by_date and key is not None:
            order = np.argsort(cols[key], kind="stable")
            cols = {c: v[order] for c, v in cols.items()}
        out[name] = store.Table.from_arrays(cols, chunk_rows=chunk_rows)
    return out
