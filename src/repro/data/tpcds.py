"""Mini-dsgen: the TPC-DS tables/columns needed by the paper's five
queries (Q3, Q6, Q7, Q42, Q96 — §VI-B Fig. 9)."""
from __future__ import annotations

from typing import Dict

import numpy as np

Tables = Dict[str, Dict[str, np.ndarray]]

CATEGORIES = ["Books", "Music", "Home", "Electronics", "Shoes", "Jewelry", "Men", "Women", "Sports", "Children"]
STATES = ["CA", "NY", "TX", "WA", "IL", "FL", "GA", "OH", "MI", "PA", "AZ", "TN"]


def generate(sf: float = 0.01, seed: int = 1) -> Tables:
    rng = np.random.default_rng(seed)
    n_item = max(60, int(18_000 * sf))
    n_cust = max(40, int(100_000 * sf))
    n_addr = max(40, int(50_000 * sf))
    n_cdemo = max(50, int(19_20_00 * sf))
    n_hdemo = 72_00 // 100 or 72
    n_promo = max(10, int(300 * sf))
    n_store = max(4, int(12 * max(sf, 1)))
    n_ss = max(200, int(2_880_000 * sf))

    # ---- date_dim: 1998-01-01 .. 2002-12-31 ----
    days = np.arange(np.datetime64("1998-01-01"), np.datetime64("2003-01-01"))
    n_date = days.shape[0]
    years = days.astype("datetime64[Y]").astype(int) + 1970
    months = days.astype("datetime64[M]").astype(int) % 12 + 1
    date_dim = {
        "d_date_sk": np.arange(1, n_date + 1, dtype=np.int64),
        "d_date": days,
        "d_year": years.astype(np.int64),
        "d_moy": months.astype(np.int64),
        "d_month_seq": ((years - 1990) * 12 + months - 1).astype(np.int64),
    }

    # ---- time_dim: all 86400/60 minutes ----
    n_time = 24 * 60
    hours = np.repeat(np.arange(24), 60)
    time_dim = {
        "t_time_sk": np.arange(1, n_time + 1, dtype=np.int64),
        "t_hour": hours.astype(np.int64),
        "t_minute": np.tile(np.arange(60), 24).astype(np.int64),
    }

    # ---- item ----
    cat_id = rng.integers(1, len(CATEGORIES) + 1, n_item)
    brand_id = rng.integers(1, 1000, n_item)
    item = {
        "i_item_sk": np.arange(1, n_item + 1, dtype=np.int64),
        "i_item_id": np.array([f"ITEM{i:08d}" for i in range(1, n_item + 1)], dtype=object),
        "i_brand_id": brand_id.astype(np.int64),
        "i_brand": np.array([f"brand-{b}" for b in brand_id], dtype=object),
        "i_manufact_id": rng.integers(1, 200, n_item).astype(np.int64),
        "i_category_id": cat_id.astype(np.int64),
        "i_category": np.array(CATEGORIES, dtype=object)[cat_id - 1],
        "i_current_price": np.round(rng.uniform(0.5, 100.0, n_item), 2),
        "i_manager_id": rng.integers(1, 20, n_item).astype(np.int64),
    }

    # ---- dimensions ----
    customer_address = {
        "ca_address_sk": np.arange(1, n_addr + 1, dtype=np.int64),
        "ca_state": np.array(STATES, dtype=object)[rng.integers(0, len(STATES), n_addr)],
    }
    customer = {
        "c_customer_sk": np.arange(1, n_cust + 1, dtype=np.int64),
        "c_current_addr_sk": rng.integers(1, n_addr + 1, n_cust).astype(np.int64),
    }
    customer_demographics = {
        "cd_demo_sk": np.arange(1, n_cdemo + 1, dtype=np.int64),
        "cd_gender": np.array(["M", "F"], dtype=object)[rng.integers(0, 2, n_cdemo)],
        "cd_marital_status": np.array(["S", "M", "D", "W", "U"], dtype=object)[
            rng.integers(0, 5, n_cdemo)
        ],
        "cd_education_status": np.array(
            ["College", "2 yr Degree", "4 yr Degree", "Secondary", "Advanced Degree", "Unknown"],
            dtype=object,
        )[rng.integers(0, 6, n_cdemo)],
    }
    household_demographics = {
        "hd_demo_sk": np.arange(1, n_hdemo + 1, dtype=np.int64),
        "hd_dep_count": rng.integers(0, 10, n_hdemo).astype(np.int64),
    }
    promotion = {
        "p_promo_sk": np.arange(1, n_promo + 1, dtype=np.int64),
        "p_channel_email": np.array(["N", "Y"], dtype=object)[rng.integers(0, 2, n_promo)],
        "p_channel_event": np.array(["N", "Y"], dtype=object)[rng.integers(0, 2, n_promo)],
    }
    store = {
        "s_store_sk": np.arange(1, n_store + 1, dtype=np.int64),
        "s_store_name": np.array(["ought", "able", "pri", "ese", "anti", "cally"], dtype=object)[
            np.arange(n_store) % 6
        ],
    }

    # ---- store_sales (fact) ----
    qty = rng.integers(1, 100, n_ss).astype(np.int64)
    list_price = np.round(rng.uniform(1.0, 200.0, n_ss), 2)
    sales_price = np.round(list_price * rng.uniform(0.2, 1.0, n_ss), 2)
    store_sales = {
        "ss_sold_date_sk": rng.integers(1, n_date + 1, n_ss).astype(np.int64),
        "ss_sold_time_sk": rng.integers(1, n_time + 1, n_ss).astype(np.int64),
        "ss_item_sk": rng.integers(1, n_item + 1, n_ss).astype(np.int64),
        "ss_customer_sk": rng.integers(1, n_cust + 1, n_ss).astype(np.int64),
        "ss_cdemo_sk": rng.integers(1, n_cdemo + 1, n_ss).astype(np.int64),
        "ss_hdemo_sk": rng.integers(1, n_hdemo + 1, n_ss).astype(np.int64),
        "ss_store_sk": rng.integers(1, n_store + 1, n_ss).astype(np.int64),
        "ss_promo_sk": rng.integers(1, n_promo + 1, n_ss).astype(np.int64),
        "ss_quantity": qty,
        "ss_list_price": list_price,
        "ss_sales_price": sales_price,
        "ss_ext_sales_price": np.round(sales_price * qty, 2),
        "ss_coupon_amt": np.round(rng.uniform(0, 20.0, n_ss) * (rng.random(n_ss) < 0.3), 2),
    }

    return {
        "date_dim": date_dim,
        "time_dim": time_dim,
        "item": item,
        "customer": customer,
        "customer_address": customer_address,
        "customer_demographics": customer_demographics,
        "household_demographics": household_demographics,
        "promotion": promotion,
        "store": store,
        "store_sales": store_sales,
    }


def as_frames(tables: Tables, **kwargs):
    from repro.core import TensorFrame

    return {name: TensorFrame.from_arrays(cols, **kwargs) for name, cols in tables.items()}


def as_store(tables: Tables, *, chunk_rows: int = 1 << 16, sort_fact_by_date: bool = False):
    """Tables as chunked ``repro.store`` tables.

    ``sort_fact_by_date`` clusters ``store_sales`` by its sold-date key
    before chunking so date-keyed zone maps become selective (the
    layout a date-partitioned warehouse load produces).
    """
    from repro import store as storelib

    out = {}
    for name, cols in tables.items():
        cols = dict(cols)
        if sort_fact_by_date and name == "store_sales":
            order = np.argsort(cols["ss_sold_date_sk"], kind="stable")
            cols = {c: v[order] for c, v in cols.items()}
        out[name] = storelib.Table.from_arrays(cols, chunk_rows=chunk_rows)
    return out
