"""rwkv6-7b [ssm]: 32L d_model=4096 (attention-free) d_ff=14336
vocab=65536 — RWKV6 "Finch", data-dependent decay.
[arXiv:2404.05892; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="rwkv6",
    n_layers=32,
    d_model=4096,
    n_heads=64,          # RWKV6 head_size = 64
    n_kv_heads=64,
    d_ff=14336,
    vocab=65536,
    optimizer="adamw",
    microbatches=8,
)
