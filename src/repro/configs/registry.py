"""Architecture registry: --arch <id> -> ModelConfig."""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

_MODULES = {
    "dbrx-132b": "dbrx_132b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "rwkv6-7b": "rwkv6_7b",
    "command-r-35b": "command_r_35b",
    "qwen3-14b": "qwen3_14b",
    "qwen2.5-14b": "qwen2_5_14b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "musicgen-medium": "musicgen_medium",
    "zamba2-2.7b": "zamba2_2_7b",
}

ARCHS = list(_MODULES)


def get(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG
