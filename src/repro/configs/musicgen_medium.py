"""musicgen-medium [audio]: 48L d_model=1536 24H (kv=24) d_ff=6144
vocab=2048 — decoder-only over EnCodec tokens.  The EnCodec frontend is
a STUB: input_specs() provides precomputed frame embeddings
(embed_inputs=False).  [arXiv:2306.05284; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="dense",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab=2048,
    embed_inputs=False,
    rope_theta=10_000.0,
    optimizer="adamw",
    microbatches=2,
)
