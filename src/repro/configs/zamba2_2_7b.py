"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 backbone + ONE shared attention
block applied every 6 layers (parameter sharing per the Zamba2 design).
[arXiv:2411.15242; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="mamba_hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    ssm_state=64,
    ssm_head_dim=80,
    ssm_heads=64,  # expand=2: d_inner = 5120
    attn_every=6,
    rope_theta=10_000.0,
    optimizer="adamw",
    microbatches=2,
)
