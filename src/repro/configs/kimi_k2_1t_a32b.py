"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) d_ff=2048
(per expert) vocab=163840, MoE 384 experts top-8 + 1 shared
(fine-grained, DeepSeek-V3-style).  [arXiv:2501.kimi2; unverified]

1T total / ~32B active params: optimizer defaults to Adafactor so the
full training state fits 512 v5e chips (see EXPERIMENTS.md §Dry-run).
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab=163840,
    moe=MoEConfig(n_experts=384, top_k=8, n_shared=1),
    rope_theta=50_000.0,
    optimizer="adafactor",
    grad_dtype="bfloat16",
    microbatches=8,
)
