"""command-r-35b [dense]: 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000 — GQA, no biases.  [hf:CohereForAI/c4ai-command-r-v01;
unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab=256000,
    rope_theta=8_000_000.0,
    optimizer="adamw",
    microbatches=8,
)
