"""Assigned-architecture configs (``--arch <id>``).

Each module defines CONFIG: ModelConfig with the exact published
hyperparameters from the assignment table.  ``registry.get(name)``
resolves ids.
"""
from .registry import ARCHS, get  # noqa: F401
