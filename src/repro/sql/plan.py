"""Logical plan: dataclass nodes + the planner (AST -> naive plan).

The planner is deliberately naive — it resolves names, builds the join
tree from equi-join predicates, and stacks the remaining WHERE
conjuncts as ONE Filter above the joins.  All pushdown/pruning smarts
live in ``optimize``; ``explain()`` shows the difference.

Subqueries: a nested SELECT is planned recursively into its own plan
tree and embedded in the enclosing expression as a *marker* node
(``SubqueryExpr`` / ``InSubExpr`` / ``ExistsExpr``), with references to
enclosing-scope columns wrapped in ``SOuter``.  The naive plan keeps
the markers (the oracle backend interprets them per row, nested-loop
style); ``optimize.decorrelate`` rewrites them to semi/anti joins,
group-by + join, or attached scalar constants before lowering.

Internal column naming: every scanned column is qualified as
``alias.column`` so self-joins (``nation n1, nation n2``) never
collide.  Post-aggregate columns use reserved ``__agg_<i>`` /
``__key_<i>`` names, subquery results ``__sq_<i>``; ``SCol("", name)``
refers to such an internal output column verbatim.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from .parser import (
    AGG_FUNCS,
    Boxed,
    FromItem,
    SCALAR_FUNCS,
    SqlError,
    SCol,
    SExists,
    SFunc,
    SInSub,
    SNot,
    SSub,
    SStar,
    Select,
    conjoin,
    expr_columns,
    format_expr,
    split_conjuncts,
    transform,
    walk,
    SCmp,
)


# ----------------------------------------------------------------------
# plan nodes
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Scan:
    table: str
    alias: str
    columns: Tuple[str, ...]  # unqualified physical columns to load
    # Sargable conjuncts pushed INTO the scan (store-backed tables:
    # zone-map chunk skipping + host-side row filter before any tensor
    # materializes).  Internal (qualified) column references; applied
    # exactly, so they are not re-checked above the scan.
    predicates: Tuple[object, ...] = ()


@dataclasses.dataclass(frozen=True)
class Filter:
    child: object
    pred: object


@dataclasses.dataclass(frozen=True)
class Join:
    left: object
    right: object
    left_keys: Tuple[str, ...]  # internal (qualified) names
    right_keys: Tuple[str, ...]
    how: str = "inner"


@dataclasses.dataclass(frozen=True)
class Aggregate:
    child: object
    keys: Tuple[Tuple[str, object], ...]  # (out_name, expr)
    aggs: Tuple[Tuple[str, str, object], ...]  # (out_name, fn, expr|None)


@dataclasses.dataclass(frozen=True)
class Project:
    child: object
    outputs: Tuple[Tuple[str, object], ...]  # (out_name, expr)


@dataclasses.dataclass(frozen=True)
class Sort:
    child: object
    keys: Tuple[Tuple[str, bool], ...]  # (output column, ascending)


@dataclasses.dataclass(frozen=True)
class Limit:
    child: object
    n: int


@dataclasses.dataclass(frozen=True)
class Distinct:
    """Row deduplication over all of the child's columns (SELECT
    DISTINCT); lowered onto TensorFrame group-by."""

    child: object


@dataclasses.dataclass(frozen=True)
class AttachScalar:
    """Broadcast the single value produced by an uncorrelated scalar
    subquery onto every row of ``child`` as column ``name`` (the
    cross-join-a-constant decorrelation of uncorrelated subqueries)."""

    child: object
    name: str
    sub: Boxed  # Boxed[plan] producing exactly one row / one column
    output: str  # the subplan's output column name


@dataclasses.dataclass(frozen=True)
class Shared:
    """A common subplan referenced from several places in the plan.

    The optimizer wraps a subtree it reuses (e.g. the inner relation of
    an EXISTS-with-``<>`` rewrite feeds both the semi join and the
    grouped anti join) so lowering evaluates it once and reuses the
    result.  Equality is structural: optimizer passes may copy the
    wrapper, and as long as the copies stay equal the lowering memo
    still collapses them to a single evaluation."""

    child: object


# ----------------------------------------------------------------------
# subquery expression markers (embedded in Filter predicates)
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SOuter:
    """A correlated reference from inside a subquery to a column of an
    enclosing scope (``ref`` is the resolved outer SCol)."""

    ref: SCol

    @property
    def internal(self) -> str:
        return self.ref.internal

    def render(self) -> str:
        return f"outer({self.ref.internal})"


@dataclasses.dataclass(frozen=True)
class SubqueryExpr:
    """Planned scalar subquery used as an expression value."""

    plan: Boxed  # Boxed[plan]
    output: str  # single output column of the subplan
    name: str  # unique __sq_<i> tag

    def render(self) -> str:
        return f"scalar-subquery[{self.name}]"


@dataclasses.dataclass(frozen=True)
class InSubExpr:
    """Planned ``e [NOT] IN (SELECT ...)`` predicate."""

    e: object
    plan: Boxed
    output: str
    name: str
    negated: bool = False

    def render(self) -> str:
        neg = "NOT " if self.negated else ""
        return f"({format_expr(self.e)} {neg}IN subquery[{self.name}])"


@dataclasses.dataclass(frozen=True)
class ExistsExpr:
    """Planned ``[NOT] EXISTS (SELECT ...)`` predicate."""

    plan: Boxed
    name: str
    negated: bool = False

    def render(self) -> str:
        neg = "NOT " if self.negated else ""
        return f"({neg}EXISTS subquery[{self.name}])"


SUBQUERY_MARKERS = (SubqueryExpr, InSubExpr, ExistsExpr)


def subquery_markers(e):
    """All planned-subquery marker nodes inside an expression."""
    return [n for n in walk(e) if isinstance(n, SUBQUERY_MARKERS)]


def plan_outer_refs(plan) -> Tuple[str, ...]:
    """Sorted internal names of enclosing-scope columns a subquery plan
    (including nested subqueries) depends on."""
    refs = set()
    for e in iter_plan_exprs(plan):
        for n in walk(e):
            if isinstance(n, SOuter):
                refs.add(n.internal)
            elif isinstance(n, SUBQUERY_MARKERS):
                refs.update(plan_outer_refs(n.plan.v))
    return tuple(sorted(refs))


def iter_plan_exprs(node):
    """Yield every expression embedded in a plan tree (this node and
    its children, not crossing into Boxed subquery plans)."""
    if isinstance(node, Filter):
        yield node.pred
    elif isinstance(node, Project):
        for _, e in node.outputs:
            yield e
    elif isinstance(node, Aggregate):
        for _, e in node.keys:
            yield e
        for _, _, e in node.aggs:
            if e is not None:
                yield e
    for attr in ("child", "left", "right"):
        c = getattr(node, attr, None)
        if c is not None:
            yield from iter_plan_exprs(c)


def walk_scans(node):
    """Yield every Scan in a plan tree, including the subplans boxed
    inside AttachScalar markers (post-decorrelation plans keep scalar
    subqueries there).  Consumers: the compiled path's base-table
    discovery and the serving layer's shared-scan grouping."""
    if isinstance(node, Scan):
        yield node
        return
    if isinstance(node, AttachScalar):
        yield from walk_scans(node.child)
        yield from walk_scans(node.sub.v)
        return
    for attr in ("child", "left", "right"):
        c = getattr(node, attr, None)
        if c is not None:
            yield from walk_scans(c)


def node_columns(node) -> set:
    """Internal column names produced by a plan node."""
    if isinstance(node, Scan):
        return {f"{node.alias}.{c}" for c in node.columns}
    if isinstance(node, Join):
        if node.how in ("semi", "anti"):
            return node_columns(node.left)
        return node_columns(node.left) | node_columns(node.right)
    if isinstance(node, Aggregate):
        return {n for n, _ in node.keys} | {n for n, _, _ in node.aggs}
    if isinstance(node, Project):
        return {n for n, _ in node.outputs}
    if isinstance(node, (Filter, Sort, Limit, Distinct, Shared)):
        return node_columns(node.child)
    if isinstance(node, AttachScalar):
        return node_columns(node.child) | {node.name}
    raise TypeError(f"unknown plan node {type(node).__name__}")


# ----------------------------------------------------------------------
# name resolution
# ----------------------------------------------------------------------
class _Resolver:
    """Column/name resolution for one SELECT scope.

    ``outer`` chains to the enclosing subquery scope; a reference that
    only an enclosing scope can satisfy resolves to ``SOuter`` (a
    correlated reference).  ``plan_sub`` is the planner callback that
    compiles nested SELECT nodes found during resolution."""

    def __init__(
        self,
        aliases: Dict[str, str],
        catalog: Dict[str, List[str]],
        outer: Optional["_Resolver"] = None,
        plan_sub=None,
    ):
        self.aliases = aliases  # alias -> table name
        self.catalog = catalog
        self.outer = outer
        self.plan_sub = plan_sub

    def all_aliases(self) -> set:
        out = set(self.aliases)
        if self.outer is not None:
            out |= self.outer.all_aliases()
        return out

    def resolve_col(self, c: SCol):
        if c.table == "":  # already-internal reference
            return c
        if c.table is not None:
            if c.table not in self.aliases:
                if self.outer is not None:
                    return _as_outer(self.outer.resolve_col(c))
                raise SqlError(
                    f"unknown table or alias {c.table!r}; "
                    f"in scope: {sorted(self.aliases)}"
                )
            cols = self.catalog[self.aliases[c.table]]
            if c.name not in cols:
                raise SqlError(
                    f"unknown column {c.name!r} in table "
                    f"{self.aliases[c.table]!r} (alias {c.table!r}); "
                    f"it has: {cols}"
                )
            return c
        hits = [
            a for a, t in self.aliases.items() if c.name in self.catalog[t]
        ]
        if not hits:
            if self.outer is not None:
                return _as_outer(self.outer.resolve_col(c))
            raise SqlError(
                f"unknown column {c.name!r}; no table in scope has it "
                f"(tables: {sorted(set(self.aliases.values()))})"
            )
        if len(hits) > 1:
            raise SqlError(
                f"ambiguous column {c.name!r}: present in aliases {sorted(hits)}; "
                f"qualify it"
            )
        return SCol(hits[0], c.name)

    def _fn(self, n):
        if isinstance(n, SCol):
            return self.resolve_col(n)
        if isinstance(n, (SSub, SInSub, SExists)):
            if self.plan_sub is None:
                raise SqlError("subqueries are not allowed in this context")
            return self.plan_sub(n, self)
        if isinstance(n, SNot) and isinstance(n.a, ExistsExpr):
            return dataclasses.replace(n.a, negated=not n.a.negated)
        if isinstance(n, SNot) and isinstance(n.a, InSubExpr):
            return dataclasses.replace(n.a, negated=not n.a.negated)
        if isinstance(n, SFunc) and not (
            n.name in AGG_FUNCS or n.name in SCALAR_FUNCS
        ):
            from .udf import active_udfs

            if n.name not in active_udfs():
                raise SqlError(
                    f"unknown function {n.name.upper()!r}; supported "
                    f"aggregates: {[f.upper() for f in AGG_FUNCS]}, scalar "
                    f"functions: {[f.upper() for f in SCALAR_FUNCS]}, "
                    f"registered UDFs: {sorted(active_udfs())}"
                )
        return n

    def resolve(self, e):
        return transform(e, self._fn)


def _as_outer(resolved) -> SOuter:
    # flatten: a reference that resolved 2+ scopes up is still one
    # SOuter wrapper around the final column
    return resolved if isinstance(resolved, SOuter) else SOuter(resolved)


def _replace_subexpr(e, target, replacement):
    """Top-down replacement of a whole subexpression."""
    if e == target:
        return replacement
    if not dataclasses.is_dataclass(e):
        return e
    changes = {}
    for f in dataclasses.fields(e):
        v = getattr(e, f.name)
        if dataclasses.is_dataclass(v):
            nv = _replace_subexpr(v, target, replacement)
        elif isinstance(v, tuple):
            nv = tuple(
                _replace_subexpr(x, target, replacement)
                if dataclasses.is_dataclass(x)
                else (
                    tuple(
                        _replace_subexpr(s, target, replacement)
                        if dataclasses.is_dataclass(s)
                        else s
                        for s in x
                    )
                    if isinstance(x, tuple)
                    else x
                )
                for x in v
            )
        else:
            nv = v
        if nv != v:
            changes[f.name] = nv
    return dataclasses.replace(e, **changes) if changes else e


# ----------------------------------------------------------------------
# planner
# ----------------------------------------------------------------------
def build_plan(sel: Select, catalog: Dict[str, List[str]]):
    """Compile a parsed SELECT into the naive logical plan."""
    from repro.resilience import checkpoint
    from repro.resilience.faults import fault_point

    checkpoint("sql.plan")
    fault_point("plan")
    return _Planner(catalog).plan_select(sel, None)


def plan_output_names(plan) -> List[str]:
    """Ordered output column names of a planned SELECT."""
    node = plan
    while isinstance(node, (Sort, Limit, Distinct, Filter)):
        node = node.child
    if isinstance(node, Project):
        return [n for n, _ in node.outputs]
    raise TypeError(f"plan root {type(node).__name__} has no Project")


class _Planner:
    """Recursive SELECT planner; one instance per query so subquery
    result names (``__sq_<i>``) stay unique across all scopes."""

    def __init__(self, catalog: Dict[str, List[str]]):
        self.catalog = dict(catalog)
        self._sq = 0

    def _fresh(self) -> str:
        name = f"__sq_{self._sq}"
        self._sq += 1
        return name

    def _plan_marker(self, node, res: _Resolver):
        """Compile a nested SELECT found during expression resolution
        into a planned subquery marker."""
        if isinstance(node, SSub):
            p = self.plan_select(_auto_alias(node.select.v), res)
            out = _single_output(p, "scalar subquery")
            return SubqueryExpr(Boxed(p), out, self._fresh())
        if isinstance(node, SInSub):
            p = self.plan_select(_auto_alias(node.select.v), res)
            out = _single_output(p, "IN subquery")
            return InSubExpr(node.e, Boxed(p), out, self._fresh(), node.negated)
        p = self.plan_select(node.select.v, res)
        return ExistsExpr(Boxed(p), self._fresh(), node.negated)

    def _derived(self, item: FromItem, outer: Optional[_Resolver]):
        """Plan a derived table: its SELECT, wrapped in a Project that
        qualifies the outputs with the alias.  Returns (source plan,
        unqualified output names for the catalog)."""
        subplan = self.plan_select(item.sub.v, outer)
        outnames = plan_output_names(subplan)
        outs = tuple((f"{item.alias}.{n}", SCol("", n)) for n in outnames)
        return Project(subplan, outs), outnames

    def plan_select(self, sel: Select, outer: Optional[_Resolver]):
        items = list(sel.from_items) + [j.item for j in sel.joins]
        aliases: Dict[str, str] = {}
        sources: Dict[str, object] = {}  # alias -> planned FROM source
        catalog = dict(self.catalog)  # local copy: derived tables register here
        outer_aliases = outer.all_aliases() if outer is not None else set()
        for item in items:
            if item.alias in aliases:
                raise SqlError(f"duplicate table alias {item.alias!r}")
            if item.alias in outer_aliases:
                raise SqlError(
                    f"subquery alias {item.alias!r} shadows an enclosing "
                    f"query's alias; rename it so correlated references "
                    f"stay unambiguous"
                )
            if item.sub is not None:
                src, outnames = self._derived(item, outer)
                table_key = f"__derived:{item.alias}"
                catalog[table_key] = outnames
                aliases[item.alias] = table_key
                sources[item.alias] = src
                continue
            if item.table not in catalog:
                raise SqlError(
                    f"unknown table {item.table!r}; scope has "
                    f"{sorted(c for c in catalog if not c.startswith('__derived:'))}"
                )
            aliases[item.alias] = item.table
            sources[item.alias] = Scan(
                item.table, item.alias, tuple(catalog[item.table])
            )
        res = _Resolver(aliases, catalog, outer, self._plan_marker)

        # ---- classify WHERE conjuncts ----
        equi: List[SCmp] = []  # cross-alias equality -> join key candidates
        residual: List[object] = []
        if sel.where is not None:
            for c in split_conjuncts(res.resolve(sel.where)):
                if _is_equi(c):
                    equi.append(c)
                else:
                    residual.append(c)

        # ---- join tree: FROM list greedily, then explicit JOINs ----
        first = sel.from_items[0]
        plan, joined = sources[first.alias], {first.alias}
        pending = list(sel.from_items[1:])
        while pending:
            progress = False
            for item in list(pending):
                keys = _take_link_preds(equi, joined, item.alias)
                if keys:
                    plan = Join(
                        plan,
                        sources[item.alias],
                        tuple(k for k, _ in keys),
                        tuple(k for _, k in keys),
                        "inner",
                    )
                    joined.add(item.alias)
                    pending.remove(item)
                    progress = True
            if not progress:
                stuck = [i.alias for i in pending]
                raise SqlError(
                    f"no equi-join predicate connects table(s) {stuck} to the "
                    f"rest of the FROM list; cross joins are not supported"
                )
        for jc in sel.joins:
            on = res.resolve(jc.on)
            keys, extra = [], []
            for c in split_conjuncts(on):
                if _is_equi(c) and _links(c, joined, jc.item.alias):
                    keys.append(_orient(c, joined))
                else:
                    extra.append(c)
            if not keys:
                raise SqlError(
                    f"JOIN {jc.item.table} ON clause has no equi-join predicate "
                    f"linking it to the tables already joined"
                )
            right = sources[jc.item.alias]
            if jc.how == "left" and extra:
                # For LEFT JOIN, ON residuals restrict which right rows
                # MATCH (failed matches NULL-extend, they don't drop the
                # left row), so hoisting them into WHERE would silently
                # turn the join inner.  Right-side-only conjuncts are
                # equivalent to pre-filtering the right input; anything
                # touching the left side cannot be expressed that way.
                rcols = node_columns(right)
                bad = [c for c in extra if not expr_columns(c) <= rcols]
                if bad:
                    raise SqlError(
                        f"LEFT JOIN {jc.item.table} ON supports extra "
                        f"conditions only on the joined (right) table's "
                        f"columns; move {format_expr(bad[0])} to WHERE if "
                        f"inner-join semantics are intended"
                    )
                right = Filter(right, conjoin(extra))
                extra = []
            plan = Join(
                plan,
                right,
                tuple(k for k, _ in keys),
                tuple(k for _, k in keys),
                jc.how,
            )
            joined.add(jc.item.alias)
            residual.extend(extra)
        # leftover equi predicates link already-joined aliases (e.g.
        # TPC-H Q5's c_nationkey = s_nationkey): plain filters
        residual.extend(equi)
        if residual:
            plan = Filter(plan, conjoin(residual))

        # ---- projection / aggregation ----
        select_items: List[Tuple[object, Optional[str]]] = []
        for e, alias in sel.columns:
            if isinstance(e, SStar):
                for a in (i.alias for i in items):
                    for cname in catalog[aliases[a]]:
                        select_items.append((SCol(a, cname), cname))
            else:
                select_items.append((res.resolve(e), alias))
        sel_aliases = {a: e for e, a in select_items if a is not None}

        has_agg = bool(sel.group_by) or any(
            _has_aggregate(e) for e, _ in select_items
        ) or (sel.having is not None)

        order_rewrite = None
        if has_agg:
            plan, outputs, order_rewrite = _plan_aggregate(
                sel, res, plan, select_items, sel_aliases
            )
        else:
            if sel.having is not None:
                raise SqlError("HAVING requires GROUP BY or aggregates")
            outputs = []
            for e, alias in select_items:
                name = alias or (e.name if isinstance(e, SCol) else None)
                if name is None:
                    raise SqlError(
                        f"computed select column {format_expr(e)} needs an AS alias"
                    )
                outputs.append((name, e))
        names = [n for n, _ in outputs]
        dup = {n for n in names if names.count(n) > 1}
        if dup:
            raise SqlError(f"duplicate output column name(s) {sorted(dup)}")
        plan = Project(plan, tuple(outputs))
        if sel.distinct:
            plan = Distinct(plan)

        # ---- order by / limit over the OUTPUT columns ----
        if sel.order_by:
            skeys = []
            for e, asc in sel.order_by:
                skeys.append(
                    (_output_name_for(e, outputs, res, order_rewrite), asc)
                )
            plan = Sort(plan, tuple(skeys))
        if sel.limit is not None:
            plan = Limit(plan, sel.limit)
        return plan


def _auto_alias(sel: Select) -> Select:
    """Give the single computed column of a scalar/IN subquery an
    implicit alias (standard SQL needs none there)."""
    if len(sel.columns) == 1:
        e, alias = sel.columns[0]
        if alias is None and not isinstance(e, (SCol, SStar)):
            return dataclasses.replace(sel, columns=((e, "__scalar"),))
    return sel


def _single_output(plan, what: str) -> str:
    names = plan_output_names(plan)
    if len(names) != 1:
        raise SqlError(
            f"{what} must produce exactly one column, got {names}"
        )
    return names[0]


def _is_equi(c) -> bool:
    return (
        isinstance(c, SCmp)
        and c.op == "="
        and isinstance(c.a, SCol)
        and isinstance(c.b, SCol)
        and c.a.table != c.b.table
    )


def _links(c: SCmp, joined: set, new_alias: str) -> bool:
    sides = {c.a.table, c.b.table}
    return new_alias in sides and bool((sides - {new_alias}) & joined)


def _orient(c: SCmp, joined: set) -> Tuple[str, str]:
    """(left_key, right_key) with left on the already-joined side."""
    if c.a.table in joined:
        return (c.a.internal, c.b.internal)
    return (c.b.internal, c.a.internal)


def _take_link_preds(equi: List[SCmp], joined: set, new_alias: str):
    keys = []
    for c in list(equi):
        if _links(c, joined, new_alias):
            keys.append(_orient(c, joined))
            equi.remove(c)
    return keys


def _has_aggregate(e) -> bool:
    from .parser import walk

    return any(isinstance(n, SFunc) and n.is_aggregate for n in walk(e))


_AGG_FN = {"sum": "sum", "avg": "mean", "min": "min", "max": "max"}


def _plan_aggregate(sel, res, plan, select_items, sel_aliases):
    # group keys: bare select-alias refs expand to the aliased expression
    keys: List[Tuple[str, object]] = []
    for i, g in enumerate(sel.group_by):
        if isinstance(g, SCol) and g.table is None and g.name in sel_aliases:
            ge = sel_aliases[g.name]
        else:
            ge = res.resolve(g)
        name = ge.internal if isinstance(ge, SCol) else f"__key_{i}"
        keys.append((name, ge))

    aggs: List[Tuple[str, str, object]] = []
    agg_map: Dict[SFunc, str] = {}

    def lift_agg(fn_call: SFunc) -> SCol:
        if fn_call not in agg_map:
            name = f"__agg_{len(agg_map)}"
            agg_map[fn_call] = name
            if fn_call.name == "count":
                if len(fn_call.args) != 1:
                    raise SqlError("COUNT takes one argument")
                arg = fn_call.args[0]
                if isinstance(arg, SStar):
                    aggs.append((name, "size", None))
                elif fn_call.distinct:
                    aggs.append((name, "nunique", arg))
                else:
                    aggs.append((name, "count", arg))
            else:
                if fn_call.distinct:
                    raise SqlError(
                        f"DISTINCT is only supported inside COUNT, not "
                        f"{fn_call.name.upper()}"
                    )
                if len(fn_call.args) != 1:
                    raise SqlError(f"{fn_call.name.upper()} takes one argument")
                aggs.append((name, _AGG_FN[fn_call.name], fn_call.args[0]))
        return SCol("", agg_map[fn_call])

    def rewrite(e):
        # replace group-key subexpressions first (top-down), then lift
        # aggregate calls
        for kname, kexpr in keys:
            e = _replace_subexpr(e, kexpr, SCol("", kname))
        return transform(
            e,
            lambda n: lift_agg(n)
            if isinstance(n, SFunc) and n.is_aggregate
            else n,
        )

    outputs = []
    for e, alias in select_items:
        re_ = rewrite(e)
        name = alias or (
            e.name if isinstance(e, SCol) else None
        )
        if name is None:
            raise SqlError(
                f"computed select column {format_expr(e)} needs an AS alias"
            )
        _check_grouped(re_, keys, f"select column {name!r}")
        outputs.append((name, re_))

    having = None
    if sel.having is not None:
        hv = sel.having
        # HAVING may reference select aliases
        for a, ae in sel_aliases.items():
            hv = _replace_subexpr(hv, SCol(None, a), ae)
        having = rewrite(res.resolve(hv))
        _check_grouped(having, keys, "HAVING")

    plan = Aggregate(plan, tuple(keys), tuple(aggs))
    if having is not None:
        plan = Filter(plan, having)

    def order_rewrite(e):
        # Same key/agg substitution the select list got, for ORDER BY
        # matching — but the Aggregate node is already built, so an
        # aggregate call NOT in the select list cannot be added here.
        n_before = len(agg_map)
        out = rewrite(e)
        if len(agg_map) != n_before:
            raise SqlError(
                f"ORDER BY aggregate {format_expr(e)} must also appear "
                f"in the select list"
            )
        return out

    return plan, outputs, order_rewrite


def _check_grouped(e, keys, where: str):
    key_names = {n for n, _ in keys}
    for c in expr_columns(e):
        if c.startswith("__agg_") or c in key_names:
            continue
        raise SqlError(
            f"column {c!r} in {where} must appear in GROUP BY or inside "
            f"an aggregate function"
        )


def _output_name_for(e, outputs, res, rewrite=None) -> str:
    out_names = {n for n, _ in outputs}
    if isinstance(e, SCol) and e.table is None and e.name in out_names:
        return e.name
    re_ = res.resolve(e)
    if rewrite is not None:
        re_ = rewrite(re_)
    for name, oe in outputs:
        if oe == re_:
            return name
    raise SqlError(
        f"ORDER BY expression {format_expr(e)} must be a select-list "
        f"column or alias (have {sorted(out_names)})"
    )


# ----------------------------------------------------------------------
# explain formatting
# ----------------------------------------------------------------------
def node_label(node) -> str:
    """One-line header for a plan node (no indentation, no children).
    Shared between ``format_plan`` and the EXPLAIN ANALYZE renderer
    (``repro.sql.analyze``)."""
    if isinstance(node, Scan):
        cols = ", ".join(node.columns)
        tag = node.table if node.alias == node.table else f"{node.table} {node.alias}"
        pushed = ""
        if node.predicates:
            pushed = " pushed=" + " AND ".join(
                format_expr(p) for p in node.predicates
            )
        return f"Scan {tag} [{cols}]{pushed}"
    if isinstance(node, Filter):
        return f"Filter {format_expr(node.pred)}"
    if isinstance(node, Join):
        on = ", ".join(
            f"{l} = {r}" for l, r in zip(node.left_keys, node.right_keys)
        )
        return f"Join {node.how} on [{on}]"
    if isinstance(node, Aggregate):
        keys = ", ".join(
            n if isinstance(e, SCol) else f"{n}={format_expr(e)}"
            for n, e in node.keys
        )
        aggs = ", ".join(
            f"{n}={fn.upper()}({format_expr(e) if e is not None else '*'})"
            for n, fn, e in node.aggs
        )
        return f"Aggregate keys=[{keys}] aggs=[{aggs}]"
    if isinstance(node, Project):
        outs = ", ".join(
            n
            if isinstance(e, SCol)
            and (e.internal == n or e.internal.endswith("." + n))
            else f"{n}={format_expr(e)}"
            for n, e in node.outputs
        )
        return f"Project [{outs}]"
    if isinstance(node, Sort):
        keys = ", ".join(f"{n} {'ASC' if a else 'DESC'}" for n, a in node.keys)
        return f"Sort [{keys}]"
    if isinstance(node, Limit):
        return f"Limit {node.n}"
    if isinstance(node, Distinct):
        return "Distinct"
    if isinstance(node, Shared):
        return "Shared"
    if isinstance(node, AttachScalar):
        return f"AttachScalar {node.name} = scalar of [{node.output}]"
    raise TypeError(f"unknown plan node {type(node).__name__}")


def format_plan(node, indent: int = 0) -> str:
    return _format_plan(node, indent, {})


def _format_plan(node, indent: int, shared: dict) -> str:
    pad = "  " * indent
    if isinstance(node, Scan):
        return pad + node_label(node)
    if isinstance(node, Filter):
        out = (
            f"{pad}{node_label(node)}\n"
            + _format_plan(node.child, indent + 1, shared)
        )
        for m in subquery_markers(node.pred):
            out += (
                f"\n{pad}  [{m.name}] subquery:\n"
                + _format_plan(m.plan.v, indent + 2, shared)
            )
        return out
    if isinstance(node, Join):
        return (
            f"{pad}{node_label(node)}\n"
            + _format_plan(node.left, indent + 1, shared)
            + "\n"
            + _format_plan(node.right, indent + 1, shared)
        )
    if isinstance(node, (Aggregate, Project, Sort, Limit, Distinct)):
        return f"{pad}{node_label(node)}\n" + _format_plan(
            node.child, indent + 1, shared
        )
    if isinstance(node, Shared):
        sid = shared.get(node)
        if sid is not None:
            return f"{pad}Shared #{sid} (reused, emitted once)"
        sid = len(shared) + 1
        shared[node] = sid
        return f"{pad}Shared #{sid}\n" + _format_plan(
            node.child, indent + 1, shared
        )
    if isinstance(node, AttachScalar):
        return (
            f"{pad}{node_label(node)}\n"
            + _format_plan(node.child, indent + 1, shared)
            + f"\n{pad}  [{node.name}] subquery:\n"
            + _format_plan(node.sub.v, indent + 2, shared)
        )
    raise TypeError(f"unknown plan node {type(node).__name__}")
