"""SQL front-end over TensorFrame (framequery-style, Petersohn et al.).

A declarative surface for the relational engine: ``SELECT`` text is
tokenized and parsed (``parser``), compiled to a logical plan of
dataclass nodes (``plan``), rewritten by a rule-based optimizer —
constant folding, filter pushdown through joins, projection pruning
(``optimize``) — and lowered onto the existing TensorFrame operators
``filter``/``join``/``groupby``/``sort_values``/``with_column``
(``lower``).  ``oracle_backend`` interprets the *unoptimized* plan
row-at-a-time on ``repro.core.oracle`` for differential testing.

Public API::

    from repro import sql

    out = sql.execute("SELECT a, SUM(b) AS s FROM t GROUP BY a", {"t": frame})
    print(sql.explain("SELECT ...", {"t": frame}))
"""
from __future__ import annotations

from typing import Dict

from .parser import SqlError, parse
from .plan import build_plan, format_plan
from .optimize import decorrelate as _decorrelate
from .optimize import optimize as _optimize
from .lower import lower_plan, scope_frames, store_table_names

__all__ = [
    "SqlError",
    "execute",
    "execute_plan",
    "explain",
    "parse",
    "plan_query",
]


def plan_query(query: str, scope: Dict, *, optimized: bool = True):
    """Parse + plan (+ optionally optimize) ``query`` against ``scope``.

    ``scope`` maps table name -> TensorFrame, ``repro.store.Table``, or
    dict of numpy arrays; only column names are consulted here, so any
    of them works.  Store-backed tables additionally enable the
    optimizer's scan-pushdown rule (sargable conjuncts move into the
    Scan and are answered with zone maps).
    """
    frames = scope_frames(scope)
    catalog = {name: list(f.column_names) for name, f in frames.items()}
    plan = build_plan(parse(query), catalog)
    if optimized:
        return _optimize(plan, store_tables=store_table_names(frames))
    return plan


def execute(query: str, scope: Dict, *, optimize: bool = True, explain=None):
    """Run a SQL ``SELECT`` over a scope of TensorFrames/store tables.

    Returns a TensorFrame (aggregate-only queries yield one row).
    ``optimize=False`` skips constant folding, filter pushdown,
    scan pushdown and projection pruning, but still decorrelates
    subqueries — the TensorFrame backend has no interpreted-subquery
    path (only the oracle backend interprets markers, row at a time).

    ``explain="analyze"`` executes the optimized plan op-by-op with
    span tracing forced on and returns an ``analyze.AnalyzeResult``:
    the result frame plus the plan tree annotated with per-operator
    wall time, row counts, bytes materialized and — for joins — the
    algorithm the stats-driven picker chose.  The compiled whole-plan
    path is bypassed (one fused program has no per-operator
    boundaries), so analyzed timings attribute work but are not
    production latencies.
    """
    frames = scope_frames(scope)
    plan = plan_query(query, frames, optimized=False)
    if explain is not None:
        if explain != "analyze":
            raise SqlError(
                f"unsupported explain mode {explain!r} (expected 'analyze')"
            )
        from .analyze import run_analyze

        plan = _optimize(plan, store_tables=store_table_names(frames))
        return run_analyze(plan, frames)
    if optimize:
        plan = _optimize(plan, store_tables=store_table_names(frames))
        return execute_plan(plan, frames)
    plan = _decorrelate(plan)
    return lower_plan(plan, frames)


def execute_plan(plan, frames: Dict, *, scan_cache=None):
    """Execute an already-optimized plan against resolved frames.

    The serving layer plans a whole micro-batch first (to group scans),
    then executes each member through here.  ``scan_cache`` maps
    ``lower.scan_cache_key`` -> pre-materialized TensorFrame from a
    shared store scan; the compiled whole-plan path is skipped when a
    cache is supplied (it performs its own scans).
    """
    from repro.core.config import CONFIG

    # out_of_core=force must reach the chunk-streaming lowering — the
    # compiled path materializes whole scans inside its jitted program
    if (
        CONFIG.compiled != "off"
        and CONFIG.out_of_core != "force"
        and not scan_cache
    ):
        from . import compile as _compile

        out = _compile.maybe_execute_compiled(plan, frames)
        if out is not None:
            return out
    return lower_plan(plan, frames, scan_cache=scan_cache)


def explain(query: str, scope: Dict) -> str:
    """Pre- and post-optimization logical plans, as printable text."""
    frames = scope_frames(scope)
    naive = plan_query(query, frames, optimized=False)
    opt = _optimize(naive, store_tables=store_table_names(frames))
    return (
        "== logical plan ==\n"
        + format_plan(naive)
        + "\n== optimized plan ==\n"
        + format_plan(opt)
    )
