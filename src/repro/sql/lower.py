"""Lower the logical plan onto TensorFrame operators.

Plan nodes map 1:1 onto the engine: Scan -> select+rename, Filter ->
``filter``, Join -> ``join``, Aggregate -> ``with_column`` (expression
materialization) + ``groupby``/``agg``, Project -> ``with_column`` +
``select``/``rename``, Sort -> ``sort_values``, Limit -> ``head``.

SQL expressions translate to the core trait-based ``Expr`` combinators,
so evaluation inherits every engine fast path (dictionary LUTs, packed
string kernels, fused arithmetic).
"""
from __future__ import annotations

import contextvars
import time
from typing import Dict, List

import numpy as np
import jax.numpy as jnp

from repro import obs
from repro.core import TensorFrame, col, if_else, lit
from repro.resilience import checkpoint as _checkpoint
from repro.resilience.faults import fault_point as _fault_point
from repro.core.expr import DateLit, Expr
from repro.store import Pred as StorePred, Table as StoreTable

from .parser import (
    SqlError,
    SBetween,
    SBin,
    SCase,
    SCmp,
    SCol,
    SDate,
    SExtract,
    SFunc,
    SIn,
    SInterval,
    SIsNull,
    SLike,
    SLit,
    SNot,
    SOr,
    SAnd,
    format_expr,
    like_prefix,
)
from .plan import (
    Aggregate,
    AttachScalar,
    Distinct,
    Filter,
    Join,
    Limit,
    Project,
    SOuter,
    SUBQUERY_MARKERS,
    Scan,
    Shared,
    Sort,
)


def scope_frames(scope: Dict) -> Dict:
    """Accept TensorFrames, store tables, or raw dict-of-numpy tables.

    ``repro.store.Table`` entries stay chunked: their Scans lower
    through ``TensorFrame.from_store`` with any pushed predicates, so
    zone-map chunk skipping happens before tensors materialize.
    """
    out = {}
    for name, obj in scope.items():
        if isinstance(obj, (TensorFrame, StoreTable)):
            out[name] = obj
        elif isinstance(obj, dict):
            out[name] = TensorFrame.from_arrays(obj)
        else:
            raise SqlError(
                f"scope entry {name!r} must be a TensorFrame, a "
                f"repro.store.Table, or a dict of numpy arrays, not "
                f"{type(obj).__name__}"
            )
    return out


def store_table_names(scope: Dict) -> frozenset:
    """Scope entries backed by chunked store tables (scan pushdown
    targets for the optimizer)."""
    return frozenset(
        name for name, obj in scope.items() if isinstance(obj, StoreTable)
    )


def _active_udfs():
    from .udf import active_udfs

    return active_udfs()


def scan_cache_key(node) -> tuple:
    """Hashable identity of a store-backed Scan's physical read:
    (table, sorted projection, physical predicates).  Alias-agnostic —
    two queries scanning the same table through different aliases with
    equal predicates share one cache entry.  The serving layer builds
    shared-scan results under these keys; ``lower_plan`` consumes them
    through its ``scan_cache``."""
    preds = tuple(_scan_pred(c, node.alias) for c in node.predicates)
    return (node.table, tuple(sorted(node.columns)), preds)


# ----------------------------------------------------------------------
# expression translation
# ----------------------------------------------------------------------
_CMP_OPS = {
    "=": lambda a, b: a == b,
    "<>": lambda a, b: a != b,
    "<": lambda a, b: a < b,
    "<=": lambda a, b: a <= b,
    ">": lambda a, b: a > b,
    ">=": lambda a, b: a >= b,
}

_BIN_OPS = {
    "+": lambda a, b: a + b,
    "-": lambda a, b: a - b,
    "*": lambda a, b: a * b,
    "/": lambda a, b: a / b,
}

_SCALAR_FNS = ("abs", "sqrt", "floor", "exp", "log", "sin", "cos")


def _literal_value(e):
    if isinstance(e, SLit):
        return e.value
    if isinstance(e, SDate):
        return int(e.days)
    raise SqlError(f"IN list supports only literals, got {format_expr(e)}")


def to_expr(e) -> Expr:
    """SQL expression AST -> core trait Expr."""
    if hasattr(e, "to_core_expr"):
        # bound plan parameters (repro.sql.compile) lower themselves:
        # their payload is a traced scalar that must not reach lit()
        return e.to_core_expr()
    if isinstance(e, SCol):
        return col(e.internal)
    if isinstance(e, SLit):
        if e.value is None:
            raise SqlError("bare NULL literal is not supported here")
        return lit(e.value)
    if isinstance(e, SDate):
        return DateLit(e.days)
    if isinstance(e, SInterval):
        return lit(e.days)
    if isinstance(e, SBin):
        return _BIN_OPS[e.op](to_expr(e.a), to_expr(e.b))
    if isinstance(e, SCmp):
        return _CMP_OPS[e.op](to_expr(e.a), to_expr(e.b))
    if isinstance(e, SAnd):
        return to_expr(e.a) & to_expr(e.b)
    if isinstance(e, SOr):
        return to_expr(e.a) | to_expr(e.b)
    if isinstance(e, SNot):
        return ~to_expr(e.a)
    if isinstance(e, SIn):
        out = to_expr(e.e).isin([_literal_value(v) for v in e.values])
        return ~out if e.negated else out
    if isinstance(e, SBetween):
        out = to_expr(e.e).between(to_expr(e.lo), to_expr(e.hi))
        return ~out if e.negated else out
    if isinstance(e, SLike):
        out = to_expr(e.e).str.like(e.pattern)
        return ~out if e.negated else out
    if isinstance(e, SIsNull):
        out = to_expr(e.e).is_null()
        return ~out if e.negated else out
    if isinstance(e, SCase):
        if e.default == SLit(None):
            raise SqlError("CASE requires an ELSE branch")
        acc = to_expr(e.default)
        for cond, res in reversed(e.whens):
            acc = if_else(to_expr(cond), to_expr(res), acc)
        return acc
    if isinstance(e, SExtract):
        dt = to_expr(e.e).dt
        return {"year": dt.year, "month": dt.month, "day": dt.day}[e.field]()
    if isinstance(e, SFunc):
        if e.is_aggregate:
            raise SqlError(
                f"aggregate {e.name.upper()} outside GROUP BY context"
            )
        if e.name == "substring":
            return _lower_substring(e)
        if e.name in _SCALAR_FNS and len(e.args) == 1:
            return getattr(to_expr(e.args[0]), e.name)()
        udf = _active_udfs().get(e.name)
        if udf is not None:
            from .udf import UdfCall

            return UdfCall(udf, tuple(to_expr(a) for a in e.args))
        raise SqlError(f"unsupported function {e.name.upper()}")
    if isinstance(e, SUBQUERY_MARKERS):
        raise SqlError(
            f"subquery {e.name} was not decorrelated; the TensorFrame "
            f"backend cannot interpret subqueries — run with the "
            f"optimizer's decorrelation pass enabled"
        )
    if isinstance(e, SOuter):
        raise SqlError(
            f"unresolved correlated reference {e.internal}; the plan "
            f"was not decorrelated"
        )
    raise SqlError(f"cannot lower expression {format_expr(e)}")


_FLIP_CMP = {"<": ">", "<=": ">=", ">": "<", ">=": "<=", "=": "=", "<>": "<>"}


def _scan_pred(c, alias: str) -> StorePred:
    """One sargable SQL conjunct -> a store predicate.

    Store predicates use the table's unqualified column names and plain
    python constants (dates as int days)."""
    strip = alias + "."

    def name(e: SCol) -> str:
        return e.internal[len(strip):] if e.internal.startswith(strip) else e.internal

    def const(e):
        return int(e.days) if isinstance(e, SDate) else e.value

    if isinstance(c, SCmp):
        if isinstance(c.a, SCol):
            return StorePred(name(c.a), c.op, const(c.b))
        return StorePred(name(c.b), _FLIP_CMP[c.op], const(c.a))
    if isinstance(c, SBetween):
        return StorePred(name(c.e), "between", (const(c.lo), const(c.hi)))
    if isinstance(c, SIn):
        return StorePred(name(c.e), "in", tuple(const(v) for v in c.values))
    if isinstance(c, SIsNull):
        return StorePred(name(c.e), "notnull" if c.negated else "isnull")
    if isinstance(c, SLike):
        prefix = like_prefix(c.pattern)
        if prefix is None or c.negated:
            raise SqlError(
                f"cannot push non-prefix LIKE {format_expr(c)} into a scan"
            )
        return StorePred(name(c.e), "like", prefix)
    raise SqlError(f"cannot push predicate {format_expr(c)} into a scan")


def _lower_substring(e: SFunc) -> Expr:
    if len(e.args) != 3:
        raise SqlError("SUBSTRING takes (string, start, length)")
    _, start, length = e.args
    if not (isinstance(start, SLit) and isinstance(length, SLit)):
        raise SqlError("SUBSTRING start/length must be integer literals")
    lo = int(start.value) - 1  # SQL is 1-based
    return to_expr(e.args[0]).str.slice(lo, lo + int(length.value))


# ----------------------------------------------------------------------
# plan lowering
# ----------------------------------------------------------------------
#: EXPLAIN ANALYZE collector (repro.sql.analyze) for the current
#: execution context; None = plain execution.
ANALYZE_COLLECTOR: contextvars.ContextVar = contextvars.ContextVar(
    "repro_sql_analyze", default=None
)


def lower_plan(
    node, frames: Dict[str, TensorFrame], _memo=None, scan_cache=None
) -> TensorFrame:
    """Execute ``node`` op-by-op.  With tracing on (``CONFIG.tracing``)
    every plan node records an ``sql.exec.<Node>`` span; with an
    EXPLAIN ANALYZE collector active it additionally records per-node
    wall time, output rows, and bytes (``repro.sql.analyze``)."""
    if _memo is None:
        _memo = {}  # Shared subplan -> TensorFrame (structural key)
    # operator-granularity resilience hooks: a cancel/deadline fires
    # between plan nodes, and the chaos suite can crash any operator
    _checkpoint("sql.exec")
    _fault_point("exec.operator")
    coll = ANALYZE_COLLECTOR.get()
    if coll is None and not obs.enabled():
        return _lower_node(node, frames, _memo, scan_cache)
    rows_in = None
    if isinstance(node, Scan):
        rows_in = getattr(frames.get(node.table), "nrows", None)
    with obs.span("sql.exec." + type(node).__name__) as sp:
        t0 = time.perf_counter_ns()
        out = _lower_node(node, frames, _memo, scan_cache)
        if coll is not None:
            coll.block(out)  # settle async dispatch: honest wall time
        dt = time.perf_counter_ns() - t0
        rows = getattr(out, "nrows", None)
        if rows is not None:
            sp.set(rows=rows)
        if coll is not None:
            coll.record(node, dt, out, sp.span_id, rows_in=rows_in)
    return out


def _lower_node(
    node, frames: Dict[str, TensorFrame], _memo, scan_cache
) -> TensorFrame:
    if isinstance(node, Shared):
        if node not in _memo:
            _memo[node] = lower_plan(node.child, frames, _memo, scan_cache)
        return _memo[node]
    if isinstance(node, Scan):
        try:
            src = frames[node.table]
        except KeyError:
            raise SqlError(
                f"table {node.table!r} missing from scope; have "
                f"{sorted(frames)}"
            ) from None
        if isinstance(src, StoreTable):
            f = scan_cache.get(scan_cache_key(node)) if scan_cache else None
            if f is None:
                preds = [_scan_pred(c, node.alias) for c in node.predicates]
                f = TensorFrame.from_store(src, list(node.columns), preds)
            else:
                # shared-scan result: materialized once per micro-batch
                # by repro.serve, projected down to this Scan's columns
                f = f.select(list(node.columns))
            return f.rename({c: f"{node.alias}.{c}" for c in node.columns})
        f = src.select(list(node.columns))
        f = f.rename({c: f"{node.alias}.{c}" for c in node.columns})
        if node.predicates:
            # defensive: predicates only land on store-backed scans,
            # but an in-memory frame can still apply them as a filter
            pred = node.predicates[0]
            for c in node.predicates[1:]:
                pred = SAnd(pred, c)
            f = f.filter(to_expr(pred))
        return f
    if isinstance(node, Filter):
        return lower_plan(node.child, frames, _memo, scan_cache).filter(to_expr(node.pred))
    if isinstance(node, Join):
        left = lower_plan(node.left, frames, _memo, scan_cache)
        right = lower_plan(node.right, frames, _memo, scan_cache)
        return left.join(
            right,
            left_on=list(node.left_keys),
            right_on=list(node.right_keys),
            how=node.how,
        )
    if isinstance(node, Aggregate):
        if scan_cache is None:
            # out-of-core: group-by-aggregates over store-backed scan
            # chains can run chunk-streamed instead of materializing
            # the whole scan (repro.sql.stream decides; returns None
            # when the plan shape or CONFIG gates it off)
            from . import stream as _stream

            out = _stream.try_stream_aggregate(node, frames, _memo)
            if out is not None:
                return out
        return _lower_aggregate(node, lower_plan(node.child, frames, _memo, scan_cache))
    if isinstance(node, Project):
        return _lower_project(node, lower_plan(node.child, frames, _memo, scan_cache))
    if isinstance(node, Sort):
        f = lower_plan(node.child, frames, _memo, scan_cache)
        return f.sort_values([n for n, _ in node.keys], [a for _, a in node.keys])
    if isinstance(node, Limit):
        return lower_plan(node.child, frames, _memo, scan_cache).head(node.n)
    if isinstance(node, Distinct):
        f = lower_plan(node.child, frames, _memo, scan_cache)
        cols = list(f.column_names)
        # keep first-occurrence row order (stable, like the oracle's
        # seen-set scan) so a later Sort+LIMIT breaks ties identically
        rep = jnp.sort(f.groupby(cols).rep)
        return f.take(rep, stats="subset").select(cols)
    if isinstance(node, AttachScalar):
        f = lower_plan(node.child, frames, _memo, scan_cache)
        sub = lower_plan(node.sub.v, frames, _memo, scan_cache)
        if sub.nrows > 1:
            raise SqlError(
                f"scalar subquery {node.name} returned {sub.nrows} rows"
            )
        arr = np.asarray(sub.column(node.output))
        if sub.nrows == 0:
            if arr.dtype.kind in "OUS":
                # no NULL string literal exists in the engine
                raise SqlError(
                    f"string scalar subquery {node.name} returned no rows "
                    f"(NULL string constants are not supported)"
                )
            # zero rows -> NULL; NaN makes every comparison false,
            # matching the oracle's None semantics
            return f.with_column(node.name, lit(float("nan")))
        v = arr[0]
        if arr.dtype.kind == "f":
            v = float(v)
        elif arr.dtype.kind in "iu":
            v = int(v)
        elif arr.dtype.kind == "b":
            v = bool(v)
        else:  # string/object dictionary column
            v = str(v)
        return f.with_column(node.name, lit(v))
    raise TypeError(f"unknown plan node {type(node).__name__}")


def prepare_aggregate_inputs(node: Aggregate, f: TensorFrame):
    """Materialize an Aggregate's key and input expressions on ``f``.

    Returns ``(frame, key_names, specs)`` with ``specs`` in engine
    ``(out_name, fn, column)`` form.  Shared between the eager lowering
    below and the chunk-streaming path (``repro.sql.stream``), which
    runs it once per probe chunk.
    """
    key_names: List[str] = []
    for name, e in node.keys:
        if not (isinstance(e, SCol) and e.internal == name and f.has_column(name)):
            f = f.with_column(name, to_expr(e))
        key_names.append(name)
    specs = []
    for name, fn, e in node.aggs:
        if fn == "size":
            specs.append((name, "size", ""))
            continue
        if isinstance(e, SCol) and f.has_column(e.internal):
            colname = e.internal
        else:
            colname = f"__in.{name}"
            f = f.with_column(colname, to_expr(e))
        specs.append((name, fn, colname))
    return f, key_names, specs


def _lower_aggregate(node: Aggregate, f: TensorFrame) -> TensorFrame:
    f, key_names, specs = prepare_aggregate_inputs(node, f)
    if key_names:
        return f.groupby(key_names).agg(specs)
    scalars = f.agg(specs)
    return TensorFrame.from_arrays(
        {name: np.asarray([scalars[name]]) for name, _, _ in specs}
    )


def _lower_project(node: Project, f: TensorFrame) -> TensorFrame:
    srcs: List[str] = []
    mapping: Dict[str, str] = {}
    used = set()
    for i, (name, e) in enumerate(node.outputs):
        if (
            isinstance(e, SCol)
            and f.has_column(e.internal)
            and e.internal not in used
        ):
            src = e.internal
        else:
            src = f"__o.{i}.{name}"
            f = f.with_column(src, to_expr(e))
        used.add(src)
        srcs.append(src)
        mapping[src] = name
    return f.select(srcs).rename(mapping)
